#!/usr/bin/env python3
"""CI bench-regression gate over the `BENCH_*.json` artifacts.

The Rust bench Runner (`rust/src/util/bench.rs`) writes one JSON file
per bench binary when `LUMINA_BENCH_JSON` is set:

    {"label": "sessions",
     "results": [{"name": ..., "iters": N,
                  "min_ns": ..., "median_ns": ..., "mean_ns": ...}, ...]}

Usage:
    bench_gate.py gate  <baseline.json> <fresh.json> [--tolerance 0.15]
    bench_gate.py update <baseline.json> <fresh.json>

`gate` fails (exit 1) when any benchmark present in both files lost
more than `tolerance` throughput (i.e. fresh median time exceeds
baseline by more than 1/(1-tolerance)).  A baseline with an empty
`results` list is the bootstrap state: the gate warns and passes, and a
maintainer promotes a trusted run with `update` (CI also uploads every
fresh file as an artifact, so there is always a candidate to promote).

Independent of the baseline, `gate` enforces the async-pipelining
invariant on the fresh file whenever both `pool_depth1/...` and
`pool_depth2/...` entries exist: the depth-2 (double-buffered) pool
must not be meaningfully slower than the depth-1 (synchronous) pool —
overlap is allowed to be a wash on starved runners, never a loss.  This
check is machine-independent (both numbers come from the same run).

Entries named `metric/...` are not timings: the bench Runner stores a
scalar (e.g. a hit rate in ppm) in the ns fields.  They are excluded
from the cross-run throughput diff and instead feed same-run
invariants.  Currently:

* whenever both `metric/hitrate_shared_ppm` and
  `metric/hitrate_private_ppm` exist in the fresh file, the
  shared-scope (snapshot/merge) radiance cache must reach at least the
  private-scope aggregate hit rate on the convergent-pose pool —
  cross-session sharing never loses hits, it can only add them;
* whenever both `metric/world_hit_rate` and
  `metric/geom_shared_hit_rate` exist in the fresh file (the
  mixed-tier convergent pool, one session demoted to half-res), the
  world-space hash cache must reach at least the geometry-keyed
  shared scope's aggregate hit rate — world keys quantize Gaussian
  positions, so they survive the resolution split that partitions the
  geometry-keyed snapshots;
* whenever both `metric/leader_sorts_clustered` and
  `metric/leader_sorts_private` exist, the pool-clustered S² sort scope
  must perform at most as many speculative sorts as private
  per-session windows on the convergent-pose pool — clustering
  deduplicates sorts, it never adds them;
* whenever both `metric/binned_entries_exact` and
  `metric/binned_entries_rect` exist, exact-intersection tile binning
  must emit at most as many (splat, tile) entries as the bounding-rect
  reference on the same projected scene — the exact test only culls,
  it never adds pairs;
* whenever both `metric/loadtest_refusals_run1` and
  `metric/loadtest_refusals_run2` exist (`lumina loadtest --smoke` runs
  the flash-crowd scenario twice at one seed), the admission-refusal
  counts must match exactly — churn and refusals are seeded, so any
  drift is a determinism regression;
* whenever both `metric/loadtest_broadcast_p99_clustered_ns` and
  `metric/loadtest_broadcast_p99_private_ns` exist, the clustered sort
  scope's p99 simulated frame latency on the spectator-broadcast
  scenario must not exceed the private scope's — on an identical pose
  stream one leader sort amortizes across the pool, so the latency
  tail can only shrink;
* whenever both `metric/steal_idle_worker_frames` and
  `metric/session_idle_worker_frames` exist, the work-stealing
  scheduler's occupancy model must show at most as many idle
  worker-frames as the per-session scheduler (the pool-wide task bag
  can only improve packing).  On the `sessions` bench file the check
  is STRICT (<): its straggler pool is heterogeneous by construction
  ([4,4,4,4,1,1,1,1] completions per epoch), so stealing must show a
  real win there, not a wash;
* whenever both `metric/loadtest_refusals_session` and
  `metric/loadtest_refusals_stealing` exist (and likewise the
  `_demotions_` pair), the counts must match exactly — the scheduler
  moves stage work between threads, it never changes what the
  admission controller sees, so any divergence means the stealing
  path leaked into serving semantics.
"""

import argparse
import json
import shutil
import sys

# Depth-2 must reach at least this fraction of depth-1 throughput
# (small head-room for runner noise; the expectation is > 1.0).
OVERLAP_FLOOR = 0.98


def load(path):
    with open(path) as f:
        data = json.load(f)
    if "results" not in data or not isinstance(data["results"], list):
        raise SystemExit(f"{path}: not a bench JSON (missing 'results')")
    return data


def by_name(data):
    return {r["name"]: r for r in data["results"]}


def gate(baseline_path, fresh_path, tolerance):
    baseline = load(baseline_path)
    fresh = load(fresh_path)
    failures = []

    fresh_by = by_name(fresh)
    base_by = by_name(baseline)

    if not baseline["results"]:
        print(f"{baseline_path}: empty baseline (bootstrap) — regression "
              f"diff skipped; promote a trusted run with "
              f"'bench_gate.py update'.")
    else:
        shared = sorted((set(base_by) & set(fresh_by))
                        - {n for n in fresh_by if n.startswith("metric/")})
        if not shared:
            print(f"warning: no overlapping benchmark names between "
                  f"{baseline_path} and {fresh_path}")
        for name in shared:
            old = base_by[name]["median_ns"]
            new = fresh_by[name]["median_ns"]
            if old <= 0:
                continue
            # Throughput ratio: < 1 means the fresh run is slower.
            ratio = old / new if new > 0 else float("inf")
            verdict = "ok"
            if ratio < 1.0 - tolerance:
                verdict = "REGRESSION"
                failures.append(
                    f"{name}: throughput fell to {ratio:.2f}x of baseline "
                    f"({old} ns -> {new} ns median)")
            print(f"  {name:<48} {old:>12} -> {new:>12} ns  "
                  f"({ratio:.2f}x)  {verdict}")

    # Same-run pipelining invariant: depth 2 vs depth 1.
    pairs = [(n, n.replace("pool_depth1", "pool_depth2"))
             for n in fresh_by if n.startswith("pool_depth1")]
    for d1, d2 in pairs:
        if d2 not in fresh_by:
            continue
        t1 = fresh_by[d1]["median_ns"]
        t2 = fresh_by[d2]["median_ns"]
        if t2 <= 0:
            continue
        speedup = t1 / t2
        verdict = "ok" if speedup >= OVERLAP_FLOOR else "REGRESSION"
        print(f"  pipelining {d2} vs {d1}: {speedup:.3f}x  {verdict}")
        if speedup < OVERLAP_FLOOR:
            failures.append(
                f"{d2}: pipelined pool at {speedup:.3f}x of synchronous "
                f"(floor {OVERLAP_FLOOR}) — stage overlap regressed")

    # Same-run cache-scope invariant: the shared (snapshot/merge) cache
    # must hit at least as often as per-session private caches on the
    # convergent-pose pool.
    sh = fresh_by.get("metric/hitrate_shared_ppm")
    pr = fresh_by.get("metric/hitrate_private_ppm")
    if sh is not None and pr is not None:
        shared_rate = sh["median_ns"] / 1e6
        private_rate = pr["median_ns"] / 1e6
        verdict = "ok" if shared_rate >= private_rate else "REGRESSION"
        print(f"  cache scope hit rate: shared {shared_rate:.4f} vs "
              f"private {private_rate:.4f}  {verdict}")
        if shared_rate < private_rate:
            failures.append(
                f"shared-scope hit rate {shared_rate:.4f} fell below "
                f"private-scope {private_rate:.4f} — cross-session cache "
                f"sharing regressed")

    # Same-run world-scope invariant: on the mixed-tier convergent pool
    # the world-space hash cache keys on quantized Gaussian positions,
    # so the half-res session keeps hitting the full-res sessions'
    # entries — it must never fall below the geometry-keyed shared
    # scope, which the resolution split partitions.
    wh = fresh_by.get("metric/world_hit_rate")
    gh = fresh_by.get("metric/geom_shared_hit_rate")
    if wh is not None and gh is not None:
        world_rate = wh["median_ns"] / 1e6
        geom_rate = gh["median_ns"] / 1e6
        verdict = "ok" if world_rate >= geom_rate else "REGRESSION"
        print(f"  mixed-tier hit rate: world {world_rate:.4f} vs "
              f"geometry-shared {geom_rate:.4f}  {verdict}")
        if world_rate < geom_rate:
            failures.append(
                f"world-scope hit rate {world_rate:.4f} fell below "
                f"geometry-shared {geom_rate:.4f} on the mixed-tier pool "
                f"— the world-space cache lost its resolution-survival "
                f"advantage")

    # Same-run sort-scope invariant: pool-clustered S² must not sort
    # more often than private per-session windows on the convergent
    # pool (the whole point of clustering is deduplicating sorts).
    sc = fresh_by.get("metric/leader_sorts_clustered")
    sp = fresh_by.get("metric/leader_sorts_private")
    if sc is not None and sp is not None:
        clustered_sorts = sc["median_ns"]
        private_sorts = sp["median_ns"]
        verdict = "ok" if clustered_sorts <= private_sorts else "REGRESSION"
        print(f"  sort scope sorts: clustered {clustered_sorts} vs "
              f"private {private_sorts}  {verdict}")
        if clustered_sorts > private_sorts:
            failures.append(
                f"clustered sort scope ran {clustered_sorts} speculative "
                f"sorts vs {private_sorts} private — pool-clustered S² "
                f"sharing regressed")

    # Same-run binning invariant: the exact circle-vs-tile test filters
    # the rect walk's candidates, so it can only shrink the entry count.
    be = fresh_by.get("metric/binned_entries_exact")
    br = fresh_by.get("metric/binned_entries_rect")
    if be is not None and br is not None:
        exact_entries = be["median_ns"]
        rect_entries = br["median_ns"]
        verdict = "ok" if exact_entries <= rect_entries else "REGRESSION"
        print(f"  binned entries: exact {exact_entries} vs "
              f"rect {rect_entries}  {verdict}")
        if exact_entries > rect_entries:
            failures.append(
                f"exact binning emitted {exact_entries} entries vs "
                f"{rect_entries} rect — exact-intersection culling "
                f"regressed")

    # Same-run loadtest determinism invariant: the smoke pass runs the
    # flash-crowd scenario twice at one seed; seeded churn + admission
    # must refuse exactly the same viewers both times.
    r1 = fresh_by.get("metric/loadtest_refusals_run1")
    r2 = fresh_by.get("metric/loadtest_refusals_run2")
    if r1 is not None and r2 is not None:
        refusals1 = r1["median_ns"]
        refusals2 = r2["median_ns"]
        verdict = "ok" if refusals1 == refusals2 else "REGRESSION"
        print(f"  loadtest refusals: run1 {refusals1} vs run2 {refusals2}  "
              f"{verdict}")
        if refusals1 != refusals2:
            failures.append(
                f"flash-crowd refusal counts diverged between same-seed "
                f"runs ({refusals1} vs {refusals2}) — loadtest churn lost "
                f"determinism")

    # Same-run loadtest SLO invariant: on the spectator broadcast (every
    # viewer replays one pose stream) the clustered sort scope amortizes
    # a single leader sort, so its p99 latency tail must not exceed the
    # private scope's.
    pc = fresh_by.get("metric/loadtest_broadcast_p99_clustered_ns")
    pp = fresh_by.get("metric/loadtest_broadcast_p99_private_ns")
    if pc is not None and pp is not None:
        clustered_p99 = pc["median_ns"]
        private_p99 = pp["median_ns"]
        verdict = "ok" if clustered_p99 <= private_p99 else "REGRESSION"
        print(f"  broadcast p99 latency: clustered {clustered_p99} ns vs "
              f"private {private_p99} ns  {verdict}")
        if clustered_p99 > private_p99:
            failures.append(
                f"clustered-scope broadcast p99 {clustered_p99} ns exceeds "
                f"private-scope {private_p99} ns — pool-clustered sort "
                f"sharing regressed the latency tail")

    # Same-run scheduler-occupancy invariant: the pool-wide stealing
    # bag never packs worse than per-session chunking, and on the
    # sessions bench's deliberately heterogeneous straggler pool it
    # must pack strictly better.
    si = fresh_by.get("metric/steal_idle_worker_frames")
    se = fresh_by.get("metric/session_idle_worker_frames")
    if si is not None and se is not None:
        steal_idle = si["median_ns"]
        session_idle = se["median_ns"]
        strict = fresh.get("label") == "sessions"
        ok = (steal_idle < session_idle if strict
              else steal_idle <= session_idle)
        verdict = "ok" if ok else "REGRESSION"
        rel = "<" if strict else "<="
        print(f"  scheduler idle worker-frames: stealing {steal_idle} "
              f"{rel} session {session_idle}  {verdict}")
        if not ok:
            failures.append(
                f"stealing scheduler left {steal_idle} idle worker-frames "
                f"vs {session_idle} for per-session chunking "
                f"(required {rel}) — pool-wide work stealing regressed")

    # Same-run scheduler-semantics invariant: both schedulers drain at
    # the same epoch boundaries, so the admission controller must
    # refuse and demote identically under either.
    for what in ("refusals", "demotions"):
        a = fresh_by.get(f"metric/loadtest_{what}_session")
        b = fresh_by.get(f"metric/loadtest_{what}_stealing")
        if a is None or b is None:
            continue
        va, vb = a["median_ns"], b["median_ns"]
        verdict = "ok" if va == vb else "REGRESSION"
        print(f"  scheduler {what}: session {va} vs stealing {vb}  "
              f"{verdict}")
        if va != vb:
            failures.append(
                f"flash-crowd {what} diverged across schedulers "
                f"({va} session vs {vb} stealing) — the stealing "
                f"scheduler changed admission-visible behavior")

    if failures:
        print(f"\nbench gate FAILED ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


def update(baseline_path, fresh_path):
    load(fresh_path)  # validate schema before promoting
    shutil.copyfile(fresh_path, baseline_path)
    print(f"promoted {fresh_path} -> {baseline_path}")
    return 0


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("command", choices=["gate", "update"])
    p.add_argument("baseline")
    p.add_argument("fresh")
    p.add_argument("--tolerance", type=float, default=0.15,
                   help="allowed fractional throughput loss vs baseline "
                        "(default 0.15)")
    args = p.parse_args()
    if args.command == "gate":
        sys.exit(gate(args.baseline, args.fresh, args.tolerance))
    sys.exit(update(args.baseline, args.fresh))


if __name__ == "__main__":
    main()
