"""AOT lowering: JAX/Pallas entry points -> HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all shapes fixed; the Rust runtime pads to them):
  raster_tile.hlo.txt   one tile x G_CHUNK Gaussians, carried (C, T, done)
  raster_batch.hlo.txt  TILE_BATCH tiles at once (vmapped)
  alpha_front.hlo.txt   frontend alphas, one tile x G_CHUNK
  sh_eval.hlo.txt       SH_CHUNK Gaussians of degree-3 SH color
  manifest.json         shapes + compositing constants for runtime checks
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import common, model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """(name, fn, example_args) for every AOT artifact."""
    g = common.G_CHUNK
    t = common.TILE
    b = common.TILE_BATCH
    n = common.SH_CHUNK
    raster_args = (
        _spec((g, 2)), _spec((g, 3)), _spec((g,)), _spec((g, 3)),
        _spec((2,)), _spec((t, t, 3)), _spec((t, t)), _spec((t, t)),
    )
    batch_args = (
        _spec((b, g, 2)), _spec((b, g, 3)), _spec((b, g)), _spec((b, g, 3)),
        _spec((b, 2)), _spec((b, t, t, 3)), _spec((b, t, t)), _spec((b, t, t)),
    )
    return [
        ("raster_tile", model.raster_chunk, raster_args),
        ("raster_batch", model.raster_chunk_batch, batch_args),
        ("alpha_front", model.alpha_chunk, (_spec((g, 2)), _spec((g, 3)), _spec((g,)), _spec((2,)))),
        ("sh_eval", model.sh_chunk, (_spec((n, 3)), _spec((n, 16, 3)))),
    ]


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "constants": {
            "tile": common.TILE,
            "g_chunk": common.G_CHUNK,
            "tile_batch": common.TILE_BATCH,
            "sh_chunk": common.SH_CHUNK,
            "alpha_min": common.ALPHA_MIN,
            "alpha_max": common.ALPHA_MAX,
            "t_eps": common.T_EPS,
        },
        "artifacts": {},
    }
    for name, fn, args in entry_points():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "num_inputs": len(args),
            "input_shapes": [list(a.shape) for a in args],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TOML twin for the Rust runtime (parsed by util::minitoml).
    with open(os.path.join(out_dir, "manifest.toml"), "w") as f:
        f.write("version = 1\n\n[constants]\n")
        for k, v in manifest["constants"].items():
            f.write(f"{k} = {v}\n")
        for name, a in manifest["artifacts"].items():
            f.write(f"\n[artifacts.{name}]\n")
            f.write(f"file = \"{a['file']}\"\n")
            f.write(f"num_inputs = {a['num_inputs']}\n")
            f.write(f"sha256 = \"{a['sha256']}\"\n")
            f.write(f"bytes = {a['bytes']}\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build(args.out)
    print(f"manifest -> {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
