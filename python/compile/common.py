"""Shared constants and the scene binary format for the Lumina stack.

These constants are mirrored in ``rust/src/constants.rs`` — the two sides
must agree bit-for-bit on the compositing semantics (Eqn. 1 of the paper)
so that the native Rust rasterizer, the Pallas kernels, and the AOT HLO
artifacts all produce identical images.
"""

from __future__ import annotations

import struct

import numpy as np

# --- Compositing semantics (match the official 3DGS rasterizer) ----------
TILE = 16  # image tile edge, pixels (paper: 16x16 tiles)
ALPHA_MIN = 1.0 / 255.0  # "significant Gaussian" threshold (paper Sec. 2.1)
ALPHA_MAX = 0.99  # opacity clamp of the reference CUDA rasterizer
T_EPS = 1e-4  # early-termination threshold theta on transmittance
G_CHUNK = 256  # Gaussians per rasterization chunk (AOT artifact shape)
TILE_BATCH = 32  # tiles per batched-raster artifact
SH_CHUNK = 4096  # Gaussians per SH-eval artifact call
SH_C0 = 0.28209479177387814  # degree-0 real SH constant

# --- Scene binary format ("LGSC") -----------------------------------------
# Shared with rust/src/scene/io.rs. Little-endian:
#   magic:  4 bytes  b"LGSC"
#   version:u32      (1)
#   count:  u32      N
#   sh_deg: u32      (3)
#   pos:    f32[N,3]
#   scale:  f32[N,3]      (linear scale, not log)
#   quat:   f32[N,4]      (w, x, y, z; unnormalized ok)
#   opacity:f32[N]        (post-sigmoid, in [0,1])
#   sh:     f32[N,16,3]   (RGB SH coefficients, degree 3)
SCENE_MAGIC = b"LGSC"
SCENE_VERSION = 1
SH_COEFFS = 16


def write_scene(path: str, pos, scale, quat, opacity, sh) -> None:
    """Serialize a Gaussian scene to the LGSC binary format."""
    n = pos.shape[0]
    assert pos.shape == (n, 3) and scale.shape == (n, 3)
    assert quat.shape == (n, 4) and opacity.shape == (n,)
    assert sh.shape == (n, SH_COEFFS, 3)
    with open(path, "wb") as f:
        f.write(SCENE_MAGIC)
        f.write(struct.pack("<III", SCENE_VERSION, n, 3))
        for arr in (pos, scale, quat, opacity, sh):
            f.write(np.ascontiguousarray(arr, dtype=np.float32).tobytes())


def read_scene(path: str):
    """Deserialize an LGSC scene. Returns (pos, scale, quat, opacity, sh)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != SCENE_MAGIC:
            raise ValueError(f"bad scene magic {magic!r}")
        version, n, sh_deg = struct.unpack("<III", f.read(12))
        if version != SCENE_VERSION:
            raise ValueError(f"unsupported scene version {version}")
        if sh_deg != 3:
            raise ValueError(f"unsupported sh degree {sh_deg}")

        def rd(shape):
            cnt = int(np.prod(shape))
            buf = f.read(4 * cnt)
            return np.frombuffer(buf, dtype="<f4").reshape(shape).copy()

        pos = rd((n, 3))
        scale = rd((n, 3))
        quat = rd((n, 4))
        opacity = rd((n,))
        sh = rd((n, SH_COEFFS, 3))
    return pos, scale, quat, opacity, sh
