"""Cache-aware fine-tuning (paper Sec. 3.3, Eqn. 4).

The radiance cache assumes the first few significant Gaussians a ray hits
are *small*, so matching their IDs implies matching rays. Oversized
Gaussians break that assumption and cause artifacts (paper Fig. 13). The
fix is a scale-constrained loss:

    L_total = L_orig + alpha * L_scale(S, theta)

where S is the geometric mean of a Gaussian's three scale parameters and
L_scale penalizes S > theta. Sorting and cache lookup stay outside the
gradient (the permutation is stop-gradient'ed in model.render_image).

This module runs at *build time*: it synthesizes a scene with a tail of
oversized Gaussians, fine-tunes it against its own renders, and writes
both the original and fine-tuned scenes to LGSC files that the Rust fig21
harness replays through the radiance cache.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import common, model


def synth_scene(rng, n: int, big_frac: float = 0.1, extent: float = 1.2):
    """Procedural Gaussian cloud with a fraction of oversized Gaussians.

    Mirrors the statistics the Rust scene generator targets: cluster-heavy
    placement, log-normal scales, mostly-opaque splats — plus ``big_frac``
    of Gaussians with ~10x scale to trigger the Fig. 13 failure mode.
    """
    pos = rng.normal(0.0, extent / 2.0, (n, 3))
    scale = np.exp(rng.normal(np.log(0.04), 0.4, (n, 3)))
    nbig = int(n * big_frac)
    big_idx = rng.choice(n, nbig, replace=False)
    scale[big_idx] *= 10.0
    quat = rng.normal(size=(n, 4))
    opac_logit = rng.normal(1.0, 1.0, n)
    sh = rng.normal(0.0, 0.25, (n, 16, 3))
    sh[:, 0, :] += rng.uniform(-0.5, 1.0, (n, 3))
    return dict(
        pos=jnp.asarray(pos, jnp.float32),
        log_scale=jnp.asarray(np.log(scale), jnp.float32),
        quat=jnp.asarray(quat, jnp.float32),
        opacity_logit=jnp.asarray(opac_logit, jnp.float32),
        sh=jnp.asarray(sh, jnp.float32),
    )


def orbit_cameras(n_views: int, radius: float = 3.0, height: float = 0.5):
    """Camera ring around the origin; returns list of (view, eye)."""
    out = []
    for i in range(n_views):
        th = 2.0 * np.pi * i / n_views
        eye = jnp.array([radius * np.sin(th), height, -radius * np.cos(th)], jnp.float32)
        out.append((model.look_at(eye, jnp.zeros(3)), eye))
    return out


def scale_loss(log_scale, theta: float):
    """L_scale: mean penalty on geometric-mean scale exceeding theta."""
    s_geo = jnp.exp(jnp.mean(log_scale, axis=-1))  # geometric mean of 3 scales
    return jnp.mean(jnp.maximum(s_geo - theta, 0.0) ** 2)


def l1_ssim_loss(img, target):
    """L_orig: the 3DGS training loss shape (L1 + 0.2 * (1 - SSIM-lite)).

    SSIM-lite uses 8x8 local windows via average pooling — enough signal
    for fine-tuning-scale images without a full Gaussian pyramid.
    """
    l1 = jnp.mean(jnp.abs(img - target))

    def pool(x):
        h, w = x.shape[0] // 8, x.shape[1] // 8
        return x[: h * 8, : w * 8].reshape(h, 8, w, 8, -1).mean(axis=(1, 3))

    mu_x, mu_y = pool(img), pool(target)
    mu_x2, mu_y2 = pool(img**2), pool(target**2)
    mu_xy = pool(img * target)
    var_x = jnp.maximum(mu_x2 - mu_x**2, 0.0)
    var_y = jnp.maximum(mu_y2 - mu_y**2, 0.0)
    cov = mu_xy - mu_x * mu_y
    c1, c2 = 0.01**2, 0.03**2
    ssim = ((2 * mu_x * mu_y + c1) * (2 * cov + c2)) / (
        (mu_x**2 + mu_y**2 + c1) * (var_x + var_y + c2)
    )
    return l1 + 0.2 * (1.0 - jnp.mean(ssim))


def finetune(
    params,
    cameras,
    targets,
    hw,
    intr,
    steps: int = 200,
    lr: float = 5e-3,
    alpha: float = 0.0,
    theta: float = 0.08,
):
    """Adam fine-tune of all Gaussian parameters against target renders.

    alpha = 0 disables the scale constraint (the ablation baseline).
    Returns (params, history) where history logs total/orig/scale losses.
    """
    h, w = hw
    fx, fy, cx, cy = intr

    def total_loss(p, view, eye, target):
        img = model.render_image(p, view, eye, h, w, fx, fy, cx, cy)
        lo = l1_ssim_loss(img, target)
        ls = scale_loss(p["log_scale"], theta)
        return lo + alpha * ls, (lo, ls)

    grad_fn = jax.jit(jax.value_and_grad(total_loss, has_aux=True))

    # Minimal Adam (no optax dependency in the build image).
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    history = []
    for step in range(steps):
        k = step % len(cameras)
        (loss, (lo, ls)), g = grad_fn(params, cameras[k][0], cameras[k][1], targets[k])
        t = step + 1
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
        )
        history.append(dict(step=step, total=float(loss), orig=float(lo), scale=float(ls)))
    return params, history


def params_to_scene_arrays(params):
    """Convert the optimization pytree to the LGSC array tuple."""
    pos = np.asarray(params["pos"], np.float32)
    scale = np.exp(np.asarray(params["log_scale"], np.float32))
    quat = np.asarray(params["quat"], np.float32)
    q = quat / (np.linalg.norm(quat, axis=-1, keepdims=True) + 1e-12)
    opac = 1.0 / (1.0 + np.exp(-np.asarray(params["opacity_logit"], np.float32)))
    sh = np.asarray(params["sh"], np.float32)
    return pos, scale.astype(np.float32), q.astype(np.float32), opac.astype(np.float32), sh


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/finetune", help="output dir")
    ap.add_argument("--n", type=int, default=512, help="Gaussian count")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.05, help="L_scale weight")
    ap.add_argument("--theta", type=float, default=0.08, help="scale threshold")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    rng = np.random.default_rng(args.seed)
    params = synth_scene(rng, args.n)
    cams = orbit_cameras(args.views)
    hw = (args.res, args.res)
    intr = (args.res * 0.9, args.res * 0.9, args.res / 2, args.res / 2)

    render = jax.jit(
        lambda p, view, eye: model.render_image(p, view, eye, *hw, *intr)
    )
    targets = [render(params, v, e) for v, e in cams]

    base = params_to_scene_arrays(params)
    common.write_scene(os.path.join(args.out, "scene_base.lgsc"), *base)

    tuned, hist = finetune(
        params, cams, targets, hw, intr, steps=args.steps,
        alpha=args.alpha, theta=args.theta,
    )
    common.write_scene(
        os.path.join(args.out, "scene_finetuned.lgsc"), *params_to_scene_arrays(tuned)
    )
    # Ablation: same budget, no scale constraint.
    plain, hist0 = finetune(
        params, cams, targets, hw, intr, steps=args.steps, alpha=0.0
    )
    common.write_scene(
        os.path.join(args.out, "scene_plain.lgsc"), *params_to_scene_arrays(plain)
    )
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump({"with_scale": hist, "without_scale": hist0}, f, indent=2)
    print(
        f"finetune done: L_scale {hist[0]['scale']:.5f} -> {hist[-1]['scale']:.5f}, "
        f"L_orig {hist[0]['orig']:.4f} -> {hist[-1]['orig']:.4f}"
    )


if __name__ == "__main__":
    main()
