"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

* ``raster_tile``  -- chunked front-to-back alpha compositing over a tile.
* ``alpha_front``  -- dense frontend alpha/significance pass.
* ``sh_eval``      -- degree-3 SH view-dependent color.
* ``ref``          -- pure-jnp oracles for all of the above.
"""

from .alpha_front import alpha_front
from .raster_tile import raster_tile, raster_tile_fresh
from .sh_eval import sh_eval

__all__ = ["alpha_front", "raster_tile", "raster_tile_fresh", "sh_eval"]
