"""Pallas kernel: the LuminCore *frontend* pass in isolation.

Computes the alpha of every Gaussian at every pixel of a tile — the cheap,
dense computation the paper assigns to the NRU frontend PEs. The Rust
coordinator uses this to (a) form radiance-cache tags (IDs of the first k
significant Gaussians per pixel) and (b) drive the cycle-accurate simulator
with real significance masks.

Lowered with ``interpret=True`` (see raster_tile.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import ALPHA_MAX


def _alpha_kernel(means_ref, conics_ref, opacs_ref, origin_ref, out_ref, *, tile: int):
    row = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
    col = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
    px = origin_ref[0] + col + 0.5
    py = origin_ref[1] + row + 0.5

    means = means_ref[...]
    conics = conics_ref[...]
    opacs = opacs_ref[...]

    # Dense over (G, tile, tile): broadcast Gaussians against the pixel
    # block. This is exactly the frontend's "apply to all Gaussians" shape.
    dx = px[None, :, :] - means[:, 0][:, None, None]
    dy = py[None, :, :] - means[:, 1][:, None, None]
    a = conics[:, 0][:, None, None]
    b = conics[:, 1][:, None, None]
    c = conics[:, 2][:, None, None]
    power = -0.5 * (a * dx * dx + c * dy * dy) - b * dx * dy
    alpha = jnp.minimum(ALPHA_MAX, opacs[:, None, None] * jnp.exp(power))
    out_ref[...] = jnp.where(power > 0.0, 0.0, alpha)


def alpha_front(means, conics, opacs, origin, tile: int):
    """Alphas of a Gaussian chunk over a tile: (G,2),(G,3),(G,),(2,) -> (G,T,T)."""
    g = means.shape[0]
    kernel = functools.partial(_alpha_kernel, tile=tile)
    out_shape = jax.ShapeDtypeStruct((g, tile, tile), jnp.float32)
    return pl.pallas_call(kernel, out_shape=out_shape, interpret=True)(
        means, conics, opacs, origin
    )
