"""Pallas kernel: per-tile front-to-back alpha compositing (the paper's
Rasterization hot-spot, Eqn. 1).

Hardware-adaptation note (DESIGN.md §Hardware-Adaptation): the paper fixes
GPU warp divergence with LuminCore's frontend/backend split. On a
TPU-shaped target the same insight becomes *masked dense lanes*: the kernel
evaluates the cheap alpha test for the whole 16x16 pixel block at once
(VPU-dense, the "frontend"), and carries a per-pixel (transmittance, done)
mask through a ``fori_loop`` over depth-sorted Gaussians so the expensive
accumulate only contributes where the mask is live (the "backend"), with no
divergent control flow. The HBM->VMEM schedule the paper expresses with its
double-buffered Feature Buffer is expressed here by chunking: callers stream
G_CHUNK Gaussians per invocation and carry (C, T, done) between chunks.

Lowered with ``interpret=True`` — the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU perf is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import ALPHA_MAX, ALPHA_MIN, T_EPS, TILE


def _raster_kernel(
    means_ref,
    conics_ref,
    opacs_ref,
    colors_ref,
    origin_ref,
    c_in_ref,
    t_in_ref,
    done_in_ref,
    c_out_ref,
    t_out_ref,
    done_out_ref,
    *,
    tile: int,
):
    # Pixel-center grid for this tile (tile x tile), built from 2D iota so
    # the kernel also lowers on real TPU targets (1D iota is not allowed).
    row = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
    col = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
    px = origin_ref[0] + col + 0.5
    py = origin_ref[1] + row + 0.5

    means = means_ref[...]
    conics = conics_ref[...]
    opacs = opacs_ref[...]
    colors = colors_ref[...]
    n = means.shape[0]

    def body(i, carry):
        c, t, done = carry
        mean = means[i]
        conic = conics[i]
        dx = px - mean[0]
        dy = py - mean[1]
        power = -0.5 * (conic[0] * dx * dx + conic[2] * dy * dy) - conic[1] * dx * dy
        alpha = jnp.minimum(ALPHA_MAX, opacs[i] * jnp.exp(power))
        alpha = jnp.where(power > 0.0, 0.0, alpha)
        sig = alpha >= ALPHA_MIN
        test_t = t * (1.0 - alpha)
        live = done < 0.5
        newly_done = sig & (test_t < T_EPS) & live
        active = sig & (test_t >= T_EPS) & live
        w = jnp.where(active, alpha * t, 0.0)
        c = c + w[..., None] * colors[i]
        t = jnp.where(active, test_t, t)
        done = jnp.where(newly_done, 1.0, done)
        return c, t, done

    c0 = c_in_ref[...]
    t0 = t_in_ref[...]
    done0 = done_in_ref[...]
    c, t, done = jax.lax.fori_loop(0, n, body, (c0, t0, done0))
    c_out_ref[...] = c
    t_out_ref[...] = t
    done_out_ref[...] = done


def raster_tile(means, conics, opacs, colors, origin, c_in, t_in, done_in):
    """Composite one chunk of depth-sorted Gaussians onto one tile.

    Args:
      means:  (G, 2) projected 2D means (pixel coords).
      conics: (G, 3) inverse 2D covariance packed (a, b, c).
      opacs:  (G,)   opacity after sigmoid; padding rows use 0.
      colors: (G, 3) per-Gaussian RGB (already SH-evaluated for this view).
      origin: (2,)   tile origin in pixels (x, y).
      c_in:   (T, T, 3) accumulated color carried from previous chunks.
      t_in:   (T, T)    carried transmittance (starts at 1).
      done_in:(T, T)    carried termination flag as f32 0/1.

    Returns (c_out, t_out, done_out) with the same shapes as the carries.
    """
    tile = c_in.shape[0]
    kernel = functools.partial(_raster_kernel, tile=tile)
    out_shapes = (
        jax.ShapeDtypeStruct((tile, tile, 3), jnp.float32),
        jax.ShapeDtypeStruct((tile, tile), jnp.float32),
        jax.ShapeDtypeStruct((tile, tile), jnp.float32),
    )
    return pl.pallas_call(kernel, out_shape=out_shapes, interpret=True)(
        means, conics, opacs, colors, origin, c_in, t_in, done_in
    )


def raster_tile_fresh(means, conics, opacs, colors, origin, tile: int = TILE):
    """Convenience wrapper starting from an empty carry (first chunk)."""
    c0 = jnp.zeros((tile, tile, 3), jnp.float32)
    t0 = jnp.ones((tile, tile), jnp.float32)
    d0 = jnp.zeros((tile, tile), jnp.float32)
    return raster_tile(means, conics, opacs, colors, origin, c0, t0, d0)
