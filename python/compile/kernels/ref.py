"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: slow, obvious implementations of
per-tile alpha compositing (Eqn. 1 of the paper), the frontend alpha pass,
and degree-3 spherical-harmonic color evaluation. The Pallas kernels and
the Rust native rasterizer are both validated against these.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..common import ALPHA_MAX, ALPHA_MIN, SH_C0, T_EPS

# Real SH basis constants, degrees 1-3 (same as the reference 3DGS impl).
SH_C1 = 0.4886025119029199
SH_C2 = (
    1.0925484305920792,
    -1.0925484305920792,
    0.31539156525252005,
    -1.0925484305920792,
    0.5462742152960396,
)
SH_C3 = (
    -0.5900435899266435,
    2.890611442640554,
    -0.4570457994644658,
    0.3731763325901154,
    -0.4570457994644658,
    1.445305721320277,
    -0.5900435899266435,
)


def pixel_grid(origin, tile: int):
    """Pixel-center coordinates of a ``tile`` x ``tile`` block at ``origin``.

    Returns (px, py) each of shape (tile, tile); pixel centers are at
    integer coordinates + 0.5.
    """
    ys = origin[1] + jnp.arange(tile, dtype=jnp.float32) + 0.5
    xs = origin[0] + jnp.arange(tile, dtype=jnp.float32) + 0.5
    py, px = jnp.meshgrid(ys, xs, indexing="ij")
    return px, py


def gaussian_alpha(mean, conic, opac, px, py):
    """Alpha of one projected Gaussian at pixel centers (px, py).

    Matches the official rasterizer: positive exponent -> discard,
    alpha clamped to ALPHA_MAX, conic is the inverse 2D covariance
    packed as (a, b, c) with exponent -0.5*(a dx^2 + c dy^2) - b dx dy.
    """
    dx = px - mean[0]
    dy = py - mean[1]
    power = -0.5 * (conic[0] * dx * dx + conic[2] * dy * dy) - conic[1] * dx * dy
    alpha = jnp.minimum(ALPHA_MAX, opac * jnp.exp(power))
    return jnp.where(power > 0.0, 0.0, alpha)


def alpha_front_ref(means, conics, opacs, origin, tile: int):
    """Frontend pass: alpha of every Gaussian at every pixel of the tile.

    Returns (G, tile, tile) float32. This is what the LuminCore frontend
    PEs compute; significance is alpha >= ALPHA_MIN.
    """
    px, py = pixel_grid(origin, tile)
    out = []
    for i in range(means.shape[0]):
        out.append(gaussian_alpha(means[i], conics[i], opacs[i], px, py))
    return jnp.stack(out, axis=0)


def raster_tile_ref(means, conics, opacs, colors, origin, c_in, t_in, done_in, tile: int):
    """Reference front-to-back compositing over one tile (Eqn. 1).

    Semantics (official 3DGS rasterizer):
      * skip Gaussians with positive exponent or alpha < ALPHA_MIN,
      * test_T = T * (1 - alpha); if test_T < T_EPS the pixel is done and
        this Gaussian is NOT accumulated,
      * otherwise C += alpha * T * color and T = test_T.

    Carries (c, t, done) so chunked invocations compose exactly.
    """
    px, py = pixel_grid(origin, tile)
    c = jnp.asarray(c_in, dtype=jnp.float32)
    t = jnp.asarray(t_in, dtype=jnp.float32)
    done = jnp.asarray(done_in, dtype=jnp.float32)
    for i in range(means.shape[0]):
        alpha = gaussian_alpha(means[i], conics[i], opacs[i], px, py)
        sig = alpha >= ALPHA_MIN
        test_t = t * (1.0 - alpha)
        newly_done = sig & (test_t < T_EPS) & (done < 0.5)
        active = sig & (test_t >= T_EPS) & (done < 0.5)
        w = jnp.where(active, alpha * t, 0.0)
        c = c + w[..., None] * colors[i]
        t = jnp.where(active, test_t, t)
        done = jnp.where(newly_done, 1.0, done)
    return c, t, done


def raster_pixel_scalar(means, conics, opacs, colors, px: float, py: float):
    """Scalar (numpy, per-pixel) compositor — the most literal transcription
    of the algorithm, used to cross-check the vectorized references and as
    documentation of the exact skip/terminate order."""
    c = np.zeros(3, dtype=np.float64)
    t = 1.0
    n_iter = 0
    n_sig = 0
    for i in range(len(means)):
        n_iter += 1
        dx = px - means[i][0]
        dy = py - means[i][1]
        power = (
            -0.5 * (conics[i][0] * dx * dx + conics[i][2] * dy * dy)
            - conics[i][1] * dx * dy
        )
        if power > 0.0:
            continue
        alpha = min(ALPHA_MAX, opacs[i] * np.exp(power))
        if alpha < ALPHA_MIN:
            continue
        n_sig += 1
        test_t = t * (1.0 - alpha)
        if test_t < T_EPS:
            break
        c += alpha * t * np.asarray(colors[i], dtype=np.float64)
        t = test_t
    return c, t, n_iter, n_sig


def sh_basis(dirs):
    """Degree-3 real SH basis evaluated at unit directions (N, 3) -> (N, 16)."""
    x, y, z = dirs[:, 0], dirs[:, 1], dirs[:, 2]
    xx, yy, zz = x * x, y * y, z * z
    xy, yz, xz = x * y, y * z, x * z
    one = jnp.ones_like(x)
    basis = [
        SH_C0 * one,
        -SH_C1 * y,
        SH_C1 * z,
        -SH_C1 * x,
        SH_C2[0] * xy,
        SH_C2[1] * yz,
        SH_C2[2] * (2.0 * zz - xx - yy),
        SH_C2[3] * xz,
        SH_C2[4] * (xx - yy),
        SH_C3[0] * y * (3.0 * xx - yy),
        SH_C3[1] * xy * z,
        SH_C3[2] * y * (4.0 * zz - xx - yy),
        SH_C3[3] * z * (2.0 * zz - 3.0 * xx - 3.0 * yy),
        SH_C3[4] * x * (4.0 * zz - xx - yy),
        SH_C3[5] * z * (xx - yy),
        SH_C3[6] * x * (xx - 3.0 * yy),
    ]
    return jnp.stack(basis, axis=1)


def sh_eval_ref(dirs, coeffs):
    """View-dependent RGB from degree-3 SH: (N,3) dirs, (N,16,3) coeffs.

    Matches 3DGS: result + 0.5, clamped at 0 from below.
    """
    basis = sh_basis(dirs)  # (N, 16)
    rgb = jnp.einsum("nk,nkc->nc", basis, coeffs) + 0.5
    return jnp.maximum(rgb, 0.0)
