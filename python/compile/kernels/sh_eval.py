"""Pallas kernel: degree-3 spherical-harmonic color evaluation.

S² sorting-shared rendering reuses a stale sort but MUST recompute each
Gaussian's view-dependent RGB at the *current* pose (paper Sec. 3.1), so
this runs every frame and is worth a kernel. The basis construction is
element-wise (VPU); the (N,16) x (N,16,3) contraction is the MXU-friendly
part on a real TPU (bf16 matmul after blocking over N).

Lowered with ``interpret=True`` (see raster_tile.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import SH_C0
from .ref import SH_C1, SH_C2, SH_C3


def _sh_kernel(dirs_ref, coeffs_ref, out_ref):
    d = dirs_ref[...]
    coeffs = coeffs_ref[...]
    x, y, z = d[:, 0], d[:, 1], d[:, 2]
    xx, yy, zz = x * x, y * y, z * z
    xy, yz, xz = x * y, y * z, x * z
    one = jnp.ones_like(x)
    basis = jnp.stack(
        [
            SH_C0 * one,
            -SH_C1 * y,
            SH_C1 * z,
            -SH_C1 * x,
            SH_C2[0] * xy,
            SH_C2[1] * yz,
            SH_C2[2] * (2.0 * zz - xx - yy),
            SH_C2[3] * xz,
            SH_C2[4] * (xx - yy),
            SH_C3[0] * y * (3.0 * xx - yy),
            SH_C3[1] * xy * z,
            SH_C3[2] * y * (4.0 * zz - xx - yy),
            SH_C3[3] * z * (2.0 * zz - 3.0 * xx - 3.0 * yy),
            SH_C3[4] * x * (4.0 * zz - xx - yy),
            SH_C3[5] * z * (xx - yy),
            SH_C3[6] * x * (xx - 3.0 * yy),
        ],
        axis=1,
    )  # (N, 16)
    rgb = jnp.einsum("nk,nkc->nc", basis, coeffs) + 0.5
    out_ref[...] = jnp.maximum(rgb, 0.0)


def sh_eval(dirs, coeffs):
    """View-dependent RGB: (N,3) unit dirs, (N,16,3) coeffs -> (N,3)."""
    n = dirs.shape[0]
    out_shape = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    return pl.pallas_call(_sh_kernel, out_shape=out_shape, interpret=True)(dirs, coeffs)
