"""Layer-2: the JAX 3DGS compute graph (build-time only).

Two roles:

1. **AOT entry points** — the per-frame compute the Rust coordinator runs
   via PJRT: chunked tile rasterization (calls the L1 Pallas kernel), the
   frontend alpha pass, and SH color evaluation. These are lowered to HLO
   text by ``aot.py`` with fixed artifact shapes (common.G_CHUNK etc.).

2. **Differentiable renderer** — a pure-jnp, fully differentiable 3DGS
   forward pass (projection -> depth sort -> dense compositing) used by
   ``finetune.py`` for the paper's cache-aware fine-tuning (Eqn. 4). The
   sort is a stop-gradient permutation, matching the paper's note that
   sorting and cache lookup do not participate in gradient descent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .kernels import alpha_front, raster_tile, sh_eval
from .kernels.ref import sh_basis

# --------------------------------------------------------------------------
# AOT entry points (fixed shapes; called from the Rust hot path via PJRT)
# --------------------------------------------------------------------------


def raster_chunk(means, conics, opacs, colors, origin, c_in, t_in, done_in):
    """One (tile, Gaussian-chunk) compositing step. Shapes: see aot.py."""
    return raster_tile(means, conics, opacs, colors, origin, c_in, t_in, done_in)


def raster_chunk_batch(means, conics, opacs, colors, origins, c_in, t_in, done_in):
    """Batched variant: leading axis = common.TILE_BATCH tiles."""
    return jax.vmap(raster_tile)(means, conics, opacs, colors, origins, c_in, t_in, done_in)


def alpha_chunk(means, conics, opacs, origin):
    """Frontend alphas for one tile chunk: -> (G, TILE, TILE)."""
    return alpha_front(means, conics, opacs, origin, common.TILE)


def sh_chunk(dirs, coeffs):
    """View-dependent RGB for a chunk of Gaussians: -> (N, 3)."""
    return sh_eval(dirs, coeffs)


# --------------------------------------------------------------------------
# Differentiable mini-renderer (fine-tuning path)
# --------------------------------------------------------------------------


def quat_to_rotmat(q):
    """Unit-normalized quaternion (..., 4) [w,x,y,z] -> rotation matrix (...,3,3)."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1 - 2 * (y * y + z * z)
    r01 = 2 * (x * y - w * z)
    r02 = 2 * (x * z + w * y)
    r10 = 2 * (x * y + w * z)
    r11 = 1 - 2 * (x * x + z * z)
    r12 = 2 * (y * z - w * x)
    r20 = 2 * (x * z - w * y)
    r21 = 2 * (y * z + w * x)
    r22 = 1 - 2 * (x * x + y * y)
    return jnp.stack(
        [
            jnp.stack([r00, r01, r02], -1),
            jnp.stack([r10, r11, r12], -1),
            jnp.stack([r20, r21, r22], -1),
        ],
        -2,
    )


def covariance_3d(scale, quat):
    """Sigma = R S S^T R^T for (N,3) scales and (N,4) quaternions."""
    r = quat_to_rotmat(quat)  # (N,3,3)
    m = r * scale[:, None, :]  # R @ diag(s), without a vmapped gather
    return m @ jnp.swapaxes(m, -1, -2)


def project_gaussians(pos, scale, quat, view, fx, fy, cx, cy):
    """EWA projection of 3D Gaussians to screen space.

    Args:
      pos: (N,3) world positions. scale: (N,3). quat: (N,4).
      view: (4,4) world-to-camera matrix (camera looks down +z).
      fx, fy, cx, cy: pinhole intrinsics.

    Returns (means2d (N,2), conics (N,3), depths (N,), radii (N,)).
    Gaussians behind the camera get depth <= 0 and conic of a point
    (callers mask on depth > near).
    """
    n = pos.shape[0]
    r = view[:3, :3]
    t = view[:3, 3]
    cam = pos @ r.T + t  # (N,3) camera-space
    z = cam[:, 2]
    zc = jnp.maximum(z, 1e-6)

    # Perspective means.
    mx = fx * cam[:, 0] / zc + cx
    my = fy * cam[:, 1] / zc + cy

    # Jacobian of the projection at each Gaussian center.
    j00 = fx / zc
    j02 = -fx * cam[:, 0] / (zc * zc)
    j11 = fy / zc
    j12 = -fy * cam[:, 1] / (zc * zc)
    zero = jnp.zeros(n, dtype=pos.dtype)
    jmat = jnp.stack(
        [
            jnp.stack([j00, zero, j02], -1),
            jnp.stack([zero, j11, j12], -1),
        ],
        -2,
    )  # (N,2,3)

    sigma = covariance_3d(scale, quat)  # (N,3,3)
    w = jnp.broadcast_to(r, (n, 3, 3))
    cov_cam = w @ sigma @ jnp.swapaxes(w, -1, -2)
    cov2d = jmat @ cov_cam @ jnp.swapaxes(jmat, -1, -2)  # (N,2,2)

    # Low-pass: ensure each splat covers >= ~1px (official +0.3 dilation).
    a = cov2d[:, 0, 0] + 0.3
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + 0.3
    det = a * c - b * b
    det = jnp.maximum(det, 1e-12)
    inv_a = c / det
    inv_b = -b / det
    inv_c = a / det
    conics = jnp.stack([inv_a, inv_b, inv_c], -1)

    # 3-sigma cutoff radius from the max eigenvalue of cov2d.
    mid = 0.5 * (a + c)
    eig = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radii = 3.0 * jnp.sqrt(eig)

    means2d = jnp.stack([mx, my], -1)
    return means2d, conics, z, radii


def eval_colors(pos, sh, cam_center):
    """Per-Gaussian view-dependent RGB from degree-3 SH (differentiable)."""
    dirs = pos - cam_center[None, :]
    dirs = dirs / (jnp.linalg.norm(dirs, axis=-1, keepdims=True) + 1e-12)
    basis = sh_basis(dirs)  # (N,16)
    rgb = jnp.einsum("nk,nkc->nc", basis, sh) + 0.5
    return jnp.maximum(rgb, 0.0)


def render_image(params, view, cam_center, height, width, fx, fy, cx, cy, near=0.2):
    """Dense differentiable render: every pixel composites every Gaussian
    in depth order. O(H*W*N) — for fine-tuning-scale scenes only.

    params: dict(pos, scale, quat, opacity_logit, sh).
    Returns (H, W, 3) image on white=0 background (black).
    """
    pos = params["pos"]
    scale = jnp.exp(params["log_scale"])
    quat = params["quat"]
    opac = jax.nn.sigmoid(params["opacity_logit"])
    sh = params["sh"]

    means2d, conics, depth, _radii = project_gaussians(pos, scale, quat, view, fx, fy, cx, cy)
    colors = eval_colors(pos, sh, cam_center)

    visible = depth > near
    # Depth sort (stop-gradient permutation; paper: sorting is not
    # differentiated through).
    order = jnp.argsort(jax.lax.stop_gradient(jnp.where(visible, depth, jnp.inf)))
    means2d = means2d[order]
    conics = conics[order]
    opac = jnp.where(visible[order], opac[order], 0.0)
    colors = colors[order]

    ys = jnp.arange(height, dtype=jnp.float32) + 0.5
    xs = jnp.arange(width, dtype=jnp.float32) + 0.5
    py, px = jnp.meshgrid(ys, xs, indexing="ij")  # (H,W)

    def body(carry, g):
        c, t = carry
        mean, conic, op, col = g
        dx = px - mean[0]
        dy = py - mean[1]
        power = -0.5 * (conic[0] * dx * dx + conic[2] * dy * dy) - conic[1] * dx * dy
        alpha = jnp.minimum(common.ALPHA_MAX, op * jnp.exp(power))
        alpha = jnp.where(power > 0.0, 0.0, alpha)
        # Smooth significance for differentiability; hard mask in fwd.
        sig = alpha >= common.ALPHA_MIN
        test_t = t * (1.0 - alpha)
        active = sig & (test_t >= common.T_EPS)
        w = jnp.where(active, alpha * t, 0.0)
        c = c + w[..., None] * col
        t = jnp.where(active, test_t, t)
        return (c, t), None

    c0 = jnp.zeros((height, width, 3), jnp.float32)
    t0 = jnp.ones((height, width), jnp.float32)
    (c, _t), _ = jax.lax.scan(body, (c0, t0), (means2d, conics, opac, colors))
    return c


def look_at(eye, target, up=jnp.array([0.0, 1.0, 0.0])):
    """World-to-camera (4,4) view matrix, camera looks down +z at target."""
    fwd = target - eye
    fwd = fwd / (jnp.linalg.norm(fwd) + 1e-12)
    right = jnp.cross(up, fwd)
    right = right / (jnp.linalg.norm(right) + 1e-12)
    true_up = jnp.cross(fwd, right)
    r = jnp.stack([right, true_up, fwd], axis=0)  # (3,3) rows
    t = -r @ eye
    view = jnp.eye(4)
    view = view.at[:3, :3].set(r)
    view = view.at[:3, 3].set(t)
    return view
