"""Shared fixtures/helpers for the python-side test suite."""

import numpy as np
import pytest


def make_splats(rng, g, extent=16.0, opac_range=(0.0, 1.0)):
    """Random projected Gaussians: means, conics (SPD inverse cov), opacity, colors."""
    means = rng.uniform(-2.0, extent + 2.0, (g, 2)).astype(np.float32)
    l1 = rng.uniform(0.02, 0.8, g)
    l2 = rng.uniform(0.02, 0.8, g)
    th = rng.uniform(0, np.pi, g)
    a = l1 * np.cos(th) ** 2 + l2 * np.sin(th) ** 2
    c = l1 * np.sin(th) ** 2 + l2 * np.cos(th) ** 2
    b = (l1 - l2) * np.sin(th) * np.cos(th)
    conics = np.stack([a, b, c], 1).astype(np.float32)
    opacs = rng.uniform(*opac_range, g).astype(np.float32)
    colors = rng.uniform(0, 1, (g, 3)).astype(np.float32)
    return means, conics, opacs, colors


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
