"""AOT lowering tests: artifact generation, manifest integrity, and
numeric agreement of the lowered HLO with the JAX-level function."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, common, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out)
    return out, manifest


class TestBuild:
    def test_all_artifacts_written(self, built):
        out, manifest = built
        assert set(manifest["artifacts"]) == {
            "raster_tile",
            "raster_batch",
            "alpha_front",
            "sh_eval",
        }
        for entry in manifest["artifacts"].values():
            path = os.path.join(out, entry["file"])
            assert os.path.getsize(path) == entry["bytes"]

    def test_manifest_json_and_toml_agree(self, built):
        out, manifest = built
        with open(os.path.join(out, "manifest.json")) as f:
            js = json.load(f)
        assert js["constants"]["tile"] == common.TILE
        toml_text = open(os.path.join(out, "manifest.toml")).read()
        assert f"tile = {common.TILE}" in toml_text
        for name in manifest["artifacts"]:
            assert f"[artifacts.{name}]" in toml_text

    def test_hlo_text_is_parseable_hlo(self, built):
        out, manifest = built
        for entry in manifest["artifacts"].values():
            text = open(os.path.join(out, entry["file"])).read()
            assert text.startswith("HloModule"), entry["file"]
            assert "ENTRY" in text

    def test_constants_match_module(self, built):
        _, manifest = built
        c = manifest["constants"]
        assert c["g_chunk"] == common.G_CHUNK
        assert c["alpha_min"] == pytest.approx(common.ALPHA_MIN)
        assert c["t_eps"] == pytest.approx(common.T_EPS)


class TestLoweredNumerics:
    def test_raster_entry_matches_direct_call(self):
        """jit-compiled entry == direct kernel call on random inputs."""
        rng = np.random.default_rng(11)
        g, t = common.G_CHUNK, common.TILE
        means = rng.uniform(0, t, (g, 2)).astype(np.float32)
        conics = np.tile(np.array([0.3, 0.0, 0.3], np.float32), (g, 1))
        opacs = rng.uniform(0, 1, g).astype(np.float32)
        colors = rng.uniform(0, 1, (g, 3)).astype(np.float32)
        origin = np.zeros(2, np.float32)
        c0 = np.zeros((t, t, 3), np.float32)
        t0 = np.ones((t, t), np.float32)
        d0 = np.zeros((t, t), np.float32)
        direct = model.raster_chunk(means, conics, opacs, colors, origin, c0, t0, d0)
        jitted = jax.jit(model.raster_chunk)(
            means, conics, opacs, colors, origin, c0, t0, d0
        )
        for a, b in zip(direct, jitted):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_batch_entry_is_vmap_of_single(self):
        rng = np.random.default_rng(13)
        b, g, t = 3, 8, common.TILE  # small batch, generic shapes
        means = rng.uniform(0, t, (b, g, 2)).astype(np.float32)
        conics = np.tile(np.array([0.4, 0.0, 0.4], np.float32), (b, g, 1))
        opacs = rng.uniform(0, 1, (b, g)).astype(np.float32)
        colors = rng.uniform(0, 1, (b, g, 3)).astype(np.float32)
        origins = np.zeros((b, 2), np.float32)
        c0 = np.zeros((b, t, t, 3), np.float32)
        t0 = np.ones((b, t, t), np.float32)
        d0 = np.zeros((b, t, t), np.float32)
        batch = model.raster_chunk_batch(
            means, conics, opacs, colors, origins, c0, t0, d0
        )
        for i in range(b):
            single = model.raster_chunk(
                means[i], conics[i], opacs[i], colors[i], origins[i],
                c0[i], t0[i], d0[i],
            )
            for a, bb in zip(single, (batch[0][i], batch[1][i], batch[2][i])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-6)

    def test_to_hlo_text_roundtrips_simple_fn(self):
        lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
