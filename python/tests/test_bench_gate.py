"""Tests for the CI bench-regression gate (`python/bench_gate.py`).

The tests themselves are pure-stdlib (no jax), but collecting this file
loads `python/tests/conftest.py`, which imports numpy — so running it
needs `pytest` and `numpy` installed (the CI bench job installs both),
just not the jax stack the sibling test modules require. Each test
writes baseline/fresh JSON fixtures to a tmp dir and calls `gate()` /
`update()` directly (they return process exit codes).
"""

import json

import bench_gate


def entry(name, median_ns, iters=3):
    return {
        "name": name,
        "iters": iters,
        "min_ns": median_ns,
        "median_ns": median_ns,
        "mean_ns": median_ns,
    }


def write(path, results, label="sessions"):
    path.write_text(json.dumps({"label": label, "results": results}))
    return str(path)


def test_empty_baseline_bootstrap_passes(tmp_path):
    base = write(tmp_path / "base.json", [])
    fresh = write(tmp_path / "fresh.json", [entry("pool/1", 1000)])
    assert bench_gate.gate(base, fresh, 0.15) == 0


def test_regression_beyond_tolerance_fails(tmp_path):
    base = write(tmp_path / "base.json", [entry("pool/1", 1000)])
    ok = write(tmp_path / "ok.json", [entry("pool/1", 1100)])
    bad = write(tmp_path / "bad.json", [entry("pool/1", 2000)])
    assert bench_gate.gate(base, ok, 0.15) == 0
    assert bench_gate.gate(base, bad, 0.15) == 1


def test_metric_rows_excluded_from_timing_diff(tmp_path):
    # A metric present in both files with a wild "timing" change must
    # not trip the throughput gate — metrics are not timings.
    base = write(tmp_path / "base.json",
                 [entry("pool/1", 1000), entry("metric/hitrate_shared_ppm", 1)])
    fresh = write(tmp_path / "fresh.json",
                  [entry("pool/1", 1000),
                   entry("metric/hitrate_shared_ppm", 1_000_000)])
    assert bench_gate.gate(base, fresh, 0.15) == 0


def test_pipelining_invariant(tmp_path):
    base = write(tmp_path / "base.json", [])
    bad = write(tmp_path / "bad.json",
                [entry("pool_depth1/2x", 1000), entry("pool_depth2/2x", 2000)])
    ok = write(tmp_path / "ok.json",
               [entry("pool_depth1/2x", 1000), entry("pool_depth2/2x", 900)])
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, ok, 0.15) == 0


def test_cache_hitrate_invariant(tmp_path):
    base = write(tmp_path / "base.json", [])
    bad = write(tmp_path / "bad.json",
                [entry("metric/hitrate_shared_ppm", 100_000),
                 entry("metric/hitrate_private_ppm", 200_000)])
    ok = write(tmp_path / "ok.json",
               [entry("metric/hitrate_shared_ppm", 200_000),
                entry("metric/hitrate_private_ppm", 100_000)])
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, ok, 0.15) == 0


def test_world_hitrate_invariant(tmp_path):
    base = write(tmp_path / "base.json", [])
    # World scope must reach at least the geometry-keyed shared scope's
    # hit rate on the mixed-tier pool.
    bad = write(tmp_path / "bad.json",
                [entry("metric/world_hit_rate", 100_000),
                 entry("metric/geom_shared_hit_rate", 200_000)])
    eq = write(tmp_path / "eq.json",
               [entry("metric/world_hit_rate", 200_000),
                entry("metric/geom_shared_hit_rate", 200_000)])
    ok = write(tmp_path / "ok.json",
               [entry("metric/world_hit_rate", 300_000),
                entry("metric/geom_shared_hit_rate", 200_000)])
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, eq, 0.15) == 0
    assert bench_gate.gate(base, ok, 0.15) == 0
    # One metric alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/world_hit_rate", 100_000)])
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_clustered_sort_invariant(tmp_path):
    base = write(tmp_path / "base.json", [])
    # Clustered must sort at most as often as private.
    bad = write(tmp_path / "bad.json",
                [entry("metric/leader_sorts_clustered", 9),
                 entry("metric/leader_sorts_private", 6)])
    eq = write(tmp_path / "eq.json",
               [entry("metric/leader_sorts_clustered", 6),
                entry("metric/leader_sorts_private", 6)])
    ok = write(tmp_path / "ok.json",
               [entry("metric/leader_sorts_clustered", 2),
                entry("metric/leader_sorts_private", 6)])
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, eq, 0.15) == 0
    assert bench_gate.gate(base, ok, 0.15) == 0
    # One metric alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/leader_sorts_clustered", 9)])
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_exact_binning_invariant(tmp_path):
    base = write(tmp_path / "base.json", [], label="raster")
    # Exact binning may only shrink the entry count vs the rect walk.
    bad = write(tmp_path / "bad.json",
                [entry("metric/binned_entries_exact", 5000),
                 entry("metric/binned_entries_rect", 4000)],
                label="raster")
    eq = write(tmp_path / "eq.json",
               [entry("metric/binned_entries_exact", 4000),
                entry("metric/binned_entries_rect", 4000)],
               label="raster")
    ok = write(tmp_path / "ok.json",
               [entry("metric/binned_entries_exact", 3000),
                entry("metric/binned_entries_rect", 4000)],
               label="raster")
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, eq, 0.15) == 0
    assert bench_gate.gate(base, ok, 0.15) == 0
    # One metric alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/binned_entries_rect", 4000)],
                    label="raster")
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_loadtest_refusal_determinism_invariant(tmp_path):
    base = write(tmp_path / "base.json", [], label="loadtest")
    # Same-seed flash-crowd runs must refuse identically.
    bad = write(tmp_path / "bad.json",
                [entry("metric/loadtest_refusals_run1", 4),
                 entry("metric/loadtest_refusals_run2", 5)],
                label="loadtest")
    ok = write(tmp_path / "ok.json",
               [entry("metric/loadtest_refusals_run1", 4),
                entry("metric/loadtest_refusals_run2", 4)],
               label="loadtest")
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, ok, 0.15) == 0
    # One metric alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/loadtest_refusals_run1", 4)],
                    label="loadtest")
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_loadtest_broadcast_p99_invariant(tmp_path):
    base = write(tmp_path / "base.json", [], label="loadtest")
    # Clustered-scope p99 on the broadcast may not exceed private-scope.
    bad = write(tmp_path / "bad.json",
                [entry("metric/loadtest_broadcast_p99_clustered_ns", 9000),
                 entry("metric/loadtest_broadcast_p99_private_ns", 7000)],
                label="loadtest")
    eq = write(tmp_path / "eq.json",
               [entry("metric/loadtest_broadcast_p99_clustered_ns", 7000),
                entry("metric/loadtest_broadcast_p99_private_ns", 7000)],
               label="loadtest")
    ok = write(tmp_path / "ok.json",
               [entry("metric/loadtest_broadcast_p99_clustered_ns", 5000),
                entry("metric/loadtest_broadcast_p99_private_ns", 7000)],
               label="loadtest")
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, eq, 0.15) == 0
    assert bench_gate.gate(base, ok, 0.15) == 0
    # One metric alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/loadtest_broadcast_p99_private_ns", 7000)],
                    label="loadtest")
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_steal_idle_invariant_strict_on_sessions_bench(tmp_path):
    base = write(tmp_path / "base.json", [])
    # On the sessions bench file the straggler pool is heterogeneous
    # by construction, so stealing must be strictly better.
    eq = write(tmp_path / "eq.json",
               [entry("metric/steal_idle_worker_frames", 12),
                entry("metric/session_idle_worker_frames", 12)])
    bad = write(tmp_path / "bad.json",
                [entry("metric/steal_idle_worker_frames", 13),
                 entry("metric/session_idle_worker_frames", 12)])
    ok = write(tmp_path / "ok.json",
               [entry("metric/steal_idle_worker_frames", 0),
                entry("metric/session_idle_worker_frames", 12)])
    assert bench_gate.gate(base, eq, 0.15) == 1
    assert bench_gate.gate(base, bad, 0.15) == 1
    assert bench_gate.gate(base, ok, 0.15) == 0
    # One metric alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/steal_idle_worker_frames", 12)])
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_steal_idle_invariant_non_strict_on_loadtest(tmp_path):
    base = write(tmp_path / "base.json", [], label="loadtest")
    # Loadtest epochs may be homogeneous (every live session serves the
    # full epoch), where the two schedulers legitimately tie.
    eq = write(tmp_path / "eq.json",
               [entry("metric/steal_idle_worker_frames", 12),
                entry("metric/session_idle_worker_frames", 12)],
               label="loadtest")
    bad = write(tmp_path / "bad.json",
                [entry("metric/steal_idle_worker_frames", 13),
                 entry("metric/session_idle_worker_frames", 12)],
                label="loadtest")
    assert bench_gate.gate(base, eq, 0.15) == 0
    assert bench_gate.gate(base, bad, 0.15) == 1


def test_scheduler_admission_parity_invariant(tmp_path):
    base = write(tmp_path / "base.json", [], label="loadtest")
    # Refusal and demotion counts must match exactly across schedulers.
    ok = write(tmp_path / "ok.json",
               [entry("metric/loadtest_refusals_session", 4),
                entry("metric/loadtest_refusals_stealing", 4),
                entry("metric/loadtest_demotions_session", 2),
                entry("metric/loadtest_demotions_stealing", 2)],
               label="loadtest")
    bad_refusals = write(tmp_path / "bad_refusals.json",
                         [entry("metric/loadtest_refusals_session", 4),
                          entry("metric/loadtest_refusals_stealing", 5)],
                         label="loadtest")
    bad_demotions = write(tmp_path / "bad_demotions.json",
                          [entry("metric/loadtest_demotions_session", 2),
                           entry("metric/loadtest_demotions_stealing", 0)],
                          label="loadtest")
    assert bench_gate.gate(base, ok, 0.15) == 0
    assert bench_gate.gate(base, bad_refusals, 0.15) == 1
    assert bench_gate.gate(base, bad_demotions, 0.15) == 1
    # One side alone (a partial run) must not trip anything.
    partial = write(tmp_path / "partial.json",
                    [entry("metric/loadtest_refusals_session", 4)],
                    label="loadtest")
    assert bench_gate.gate(base, partial, 0.15) == 0


def test_update_promotes_fresh_file(tmp_path):
    fresh = write(tmp_path / "fresh.json", [entry("pool/1", 1000)])
    base = tmp_path / "base.json"
    write(base, [])
    assert bench_gate.update(str(base), fresh) == 0
    promoted = json.loads(base.read_text())
    assert promoted["results"][0]["name"] == "pool/1"
