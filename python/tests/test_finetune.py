"""Cache-aware fine-tuning tests (Eqn. 4): the scale-constrained loss
shrinks oversized Gaussians while preserving render fidelity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, finetune, model


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(5)
    params = finetune.synth_scene(rng, 96, big_frac=0.15)
    cams = finetune.orbit_cameras(3)
    hw = (32, 32)
    intr = (28.0, 28.0, 16.0, 16.0)
    targets = [model.render_image(params, v, e, *hw, *intr) for v, e in cams]
    return params, cams, targets, hw, intr


class TestScaleLoss:
    def test_zero_when_all_small(self):
        log_scale = jnp.full((10, 3), np.log(0.01))
        assert float(finetune.scale_loss(log_scale, theta=0.05)) == 0.0

    def test_positive_when_oversized(self):
        log_scale = jnp.full((4, 3), np.log(0.5))
        assert float(finetune.scale_loss(log_scale, theta=0.05)) > 0.0

    def test_uses_geometric_mean(self):
        # One huge axis with two tiny ones can stay under theta.
        log_scale = jnp.log(jnp.array([[1.0, 1e-4, 1e-4]]))
        geo = float(jnp.exp(jnp.mean(log_scale)))
        assert geo < 0.05
        assert float(finetune.scale_loss(log_scale, theta=0.05)) == 0.0


class TestL1Ssim:
    def test_zero_for_identical(self):
        img = jnp.ones((32, 32, 3)) * 0.5
        assert float(finetune.l1_ssim_loss(img, img)) == pytest.approx(0.0, abs=1e-6)

    def test_positive_for_different(self):
        a = jnp.zeros((32, 32, 3))
        b = jnp.ones((32, 32, 3))
        assert float(finetune.l1_ssim_loss(a, b)) > 0.5


class TestFinetune:
    def test_scale_constraint_shrinks_big_gaussians(self, setup):
        params, cams, targets, hw, intr = setup
        tuned, hist = finetune.finetune(
            params, cams, targets, hw, intr, steps=30, alpha=1.0, theta=0.03,
        )
        assert hist[-1]["scale"] < hist[0]["scale"], "L_scale did not decrease"
        # The oversized tail shrinks.
        geo = lambda p: np.exp(np.mean(np.asarray(p["log_scale"]), axis=1))
        assert np.percentile(geo(tuned), 99) < np.percentile(geo(params), 99)

    def test_without_constraint_scales_drift_free(self, setup):
        params, cams, targets, hw, intr = setup
        plain, hist = finetune.finetune(
            params, cams, targets, hw, intr, steps=10, alpha=0.0,
        )
        # alpha=0: the scale term is reported but not optimized against.
        assert "scale" in hist[0]
        assert np.isfinite(np.asarray(plain["log_scale"])).all()

    def test_history_records_every_step(self, setup):
        params, cams, targets, hw, intr = setup
        _, hist = finetune.finetune(params, cams, targets, hw, intr, steps=7)
        assert [h["step"] for h in hist] == list(range(7))


class TestSceneExport:
    def test_params_to_scene_arrays_valid(self, setup):
        params, _, _, _, _ = setup
        pos, scale, quat, opac, sh = finetune.params_to_scene_arrays(params)
        n = pos.shape[0]
        assert scale.shape == (n, 3) and np.all(scale > 0)
        assert quat.shape == (n, 4)
        np.testing.assert_allclose(np.linalg.norm(quat, axis=1), 1.0, atol=1e-5)
        assert np.all((opac >= 0) & (opac <= 1))
        assert sh.shape == (n, common.SH_COEFFS, 3)

    def test_lgsc_roundtrip_of_export(self, setup, tmp_path):
        params, _, _, _, _ = setup
        arrays = finetune.params_to_scene_arrays(params)
        path = str(tmp_path / "export.lgsc")
        common.write_scene(path, *arrays)
        back = common.read_scene(path)
        for a, b in zip(arrays, back):
            np.testing.assert_array_equal(a, b)
