"""L1 Pallas kernels vs pure-jnp oracle (the core correctness signal)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import common
from compile.kernels import alpha_front, raster_tile, raster_tile_fresh, sh_eval
from compile.kernels import ref

from .conftest import make_splats


def fresh_carry(tile):
    return (
        np.zeros((tile, tile, 3), np.float32),
        np.ones((tile, tile), np.float32),
        np.zeros((tile, tile), np.float32),
    )


class TestRasterTile:
    def test_matches_ref_random(self, rng):
        means, conics, opacs, colors = make_splats(rng, 64)
        origin = np.zeros(2, np.float32)
        c0, t0, d0 = fresh_carry(common.TILE)
        got = raster_tile(means, conics, opacs, colors, origin, c0, t0, d0)
        want = ref.raster_tile_ref(
            means, conics, opacs, colors, origin, c0, t0, d0, common.TILE
        )
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)

    def test_matches_scalar_loop(self, rng):
        """Cross-check the vectorized kernel against the literal per-pixel loop."""
        means, conics, opacs, colors = make_splats(rng, 48)
        origin = np.array([4.0, 8.0], np.float32)
        c, t, _ = raster_tile_fresh(means, conics, opacs, colors, origin, 8)
        c, t = np.asarray(c), np.asarray(t)
        for iy, ix in [(0, 0), (3, 5), (7, 7)]:
            cs, ts, _, _ = ref.raster_pixel_scalar(
                means, conics, opacs, colors, origin[0] + ix + 0.5, origin[1] + iy + 0.5
            )
            np.testing.assert_allclose(c[iy, ix], cs, atol=1e-5)
            np.testing.assert_allclose(t[iy, ix], ts, atol=1e-5)

    def test_chunked_equals_monolithic(self, rng):
        """Carry semantics: 4 chunks of 32 == one call with 128 Gaussians."""
        means, conics, opacs, colors = make_splats(rng, 128)
        origin = np.zeros(2, np.float32)
        mono = raster_tile_fresh(means, conics, opacs, colors, origin, common.TILE)
        c, t, d = fresh_carry(common.TILE)
        for s in range(0, 128, 32):
            c, t, d = raster_tile(
                means[s : s + 32], conics[s : s + 32], opacs[s : s + 32],
                colors[s : s + 32], origin, c, t, d,
            )
        for g, w in zip((c, t, d), mono):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)

    def test_zero_opacity_padding_is_identity(self, rng):
        means, conics, _, colors = make_splats(rng, 16)
        opacs = np.zeros(16, np.float32)
        origin = np.zeros(2, np.float32)
        c, t, d = raster_tile_fresh(means, conics, opacs, colors, origin, common.TILE)
        assert np.all(np.asarray(c) == 0.0)
        assert np.all(np.asarray(t) == 1.0)
        assert np.all(np.asarray(d) == 0.0)

    def test_opaque_wall_terminates(self):
        """A huge opaque Gaussian saturates every pixel; later ones are ignored."""
        g = 8
        means = np.full((g, 2), 8.0, np.float32)
        conics = np.tile(np.array([1e-6, 0.0, 1e-6], np.float32), (g, 1))
        opacs = np.full(g, 0.995, np.float32)
        colors = np.zeros((g, 3), np.float32)
        colors[0] = 1.0  # only the first contributes fully
        origin = np.zeros(2, np.float32)
        c, t, d = raster_tile_fresh(means, conics, opacs, colors, origin, common.TILE)
        # alpha clamps to .99: after the first Gaussian T=0.01; the second
        # would push test_T to 1e-4-eps < T_EPS -> done, T keeps its value.
        assert np.all(np.asarray(d) == 1.0)
        assert np.all(np.asarray(t) <= 0.01 + 1e-6)
        # Only the first Gaussian accumulated: C = 0.99 * color0.
        np.testing.assert_allclose(np.asarray(c)[..., 0], 0.99, atol=1e-6)

    def test_transmittance_monotone_nonincreasing(self, rng):
        means, conics, opacs, colors = make_splats(rng, 32)
        origin = np.zeros(2, np.float32)
        c, t, d = fresh_carry(common.TILE)
        prev_t = t.copy()
        for s in range(0, 32, 8):
            c, t, d = raster_tile(
                means[s : s + 8], conics[s : s + 8], opacs[s : s + 8],
                colors[s : s + 8], origin, c, t, d,
            )
            assert np.all(np.asarray(t) <= prev_t + 1e-7)
            prev_t = np.asarray(t).copy()

    @settings(max_examples=20, deadline=None)
    @given(
        g=st.integers(1, 40),
        tile=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, g, tile, seed):
        """Shape sweep: kernel == oracle for arbitrary (G, tile) combos."""
        rng = np.random.default_rng(seed)
        means, conics, opacs, colors = make_splats(rng, g, extent=float(tile))
        origin = rng.uniform(-8, 8, 2).astype(np.float32)
        c0 = rng.uniform(0, 1, (tile, tile, 3)).astype(np.float32)
        t0 = rng.uniform(0, 1, (tile, tile)).astype(np.float32)
        d0 = (rng.uniform(0, 1, (tile, tile)) < 0.2).astype(np.float32)
        got = raster_tile(means, conics, opacs, colors, origin, c0, t0, d0)
        want = ref.raster_tile_ref(means, conics, opacs, colors, origin, c0, t0, d0, tile)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestAlphaFront:
    def test_matches_ref(self, rng):
        means, conics, opacs, _ = make_splats(rng, 96)
        origin = np.array([16.0, 32.0], np.float32)
        got = alpha_front(means, conics, opacs, origin, common.TILE)
        want = ref.alpha_front_ref(means, conics, opacs, origin, common.TILE)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_alpha_bounds(self, rng):
        means, conics, opacs, _ = make_splats(rng, 64)
        origin = np.zeros(2, np.float32)
        a = np.asarray(alpha_front(means, conics, opacs, origin, common.TILE))
        assert np.all(a >= 0.0)
        assert np.all(a <= common.ALPHA_MAX + 1e-7)

    def test_alpha_peaks_at_center(self):
        """Alpha is maximal at the pixel nearest the Gaussian mean."""
        means = np.array([[8.5, 8.5]], np.float32)
        conics = np.array([[0.5, 0.0, 0.5]], np.float32)
        opacs = np.array([0.9], np.float32)
        a = np.asarray(alpha_front(means, conics, opacs, np.zeros(2, np.float32), 16))[0]
        iy, ix = np.unravel_index(np.argmax(a), a.shape)
        assert (iy, ix) == (8, 8)
        np.testing.assert_allclose(a[8, 8], 0.9, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(g=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, g, seed):
        rng = np.random.default_rng(seed)
        means, conics, opacs, _ = make_splats(rng, g)
        origin = rng.uniform(-4, 4, 2).astype(np.float32)
        got = alpha_front(means, conics, opacs, origin, 8)
        want = ref.alpha_front_ref(means, conics, opacs, origin, 8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


class TestShEval:
    def test_matches_ref(self, rng):
        n = 128
        dirs = rng.normal(size=(n, 3)).astype(np.float32)
        dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
        coeffs = (rng.normal(size=(n, 16, 3)) * 0.3).astype(np.float32)
        got = sh_eval(dirs, coeffs)
        want = ref.sh_eval_ref(dirs, coeffs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_dc_only(self, rng):
        """With only the DC coefficient set, color is view-independent."""
        n = 8
        coeffs = np.zeros((n, 16, 3), np.float32)
        coeffs[:, 0, :] = 1.0
        dirs = rng.normal(size=(n, 3)).astype(np.float32)
        dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
        got = np.asarray(sh_eval(dirs, coeffs))
        expect = common.SH_C0 * 1.0 + 0.5
        np.testing.assert_allclose(got, expect, atol=1e-6)

    def test_clamped_at_zero(self, rng):
        n = 16
        coeffs = np.zeros((n, 16, 3), np.float32)
        coeffs[:, 0, :] = -10.0  # strongly negative DC
        dirs = np.tile(np.array([0.0, 0.0, 1.0], np.float32), (n, 1))
        got = np.asarray(sh_eval(dirs, coeffs))
        assert np.all(got == 0.0)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 256), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, n, seed):
        rng = np.random.default_rng(seed)
        dirs = rng.normal(size=(n, 3)).astype(np.float32)
        dirs /= np.linalg.norm(dirs, axis=1, keepdims=True) + 1e-9
        coeffs = (rng.normal(size=(n, 16, 3)) * 0.5).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(sh_eval(dirs, coeffs)),
            np.asarray(ref.sh_eval_ref(dirs, coeffs)),
            atol=1e-5,
        )
