"""L2 model tests: projection geometry, differentiability, scene IO."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model


def make_params(rng, n):
    return dict(
        pos=jnp.asarray(rng.normal(0, 0.5, (n, 3)), jnp.float32),
        log_scale=jnp.asarray(np.log(rng.uniform(0.02, 0.2, (n, 3))), jnp.float32),
        quat=jnp.asarray(rng.normal(size=(n, 4)), jnp.float32),
        opacity_logit=jnp.asarray(rng.normal(0, 1, n), jnp.float32),
        sh=jnp.asarray(rng.normal(0, 0.3, (n, 16, 3)), jnp.float32),
    )


@pytest.fixture
def rng():
    return np.random.default_rng(7)


class TestProjection:
    def test_center_gaussian_projects_to_principal_point(self):
        pos = jnp.array([[0.0, 0.0, 0.0]])
        scale = jnp.full((1, 3), 0.1)
        quat = jnp.array([[1.0, 0.0, 0.0, 0.0]])
        eye = jnp.array([0.0, 0.0, -4.0])
        view = model.look_at(eye, jnp.zeros(3))
        means, conics, depth, radii = model.project_gaussians(
            pos, scale, quat, view, 100.0, 100.0, 64.0, 64.0
        )
        np.testing.assert_allclose(np.asarray(means[0]), [64.0, 64.0], atol=1e-4)
        np.testing.assert_allclose(float(depth[0]), 4.0, atol=1e-5)
        assert float(radii[0]) > 0.0

    def test_depth_increases_along_view_axis(self):
        pos = jnp.array([[0.0, 0.0, z] for z in (-1.0, 0.0, 2.0)])
        scale = jnp.full((3, 3), 0.1)
        quat = jnp.tile(jnp.array([[1.0, 0.0, 0.0, 0.0]]), (3, 1))
        eye = jnp.array([0.0, 0.0, -4.0])
        view = model.look_at(eye, jnp.zeros(3))
        _, _, depth, _ = model.project_gaussians(pos, scale, quat, view, 50.0, 50.0, 32.0, 32.0)
        d = np.asarray(depth)
        assert d[0] < d[1] < d[2]

    def test_conic_is_spd(self, rng):
        n = 64
        p = make_params(rng, n)
        eye = jnp.array([0.0, 0.0, -3.0])
        view = model.look_at(eye, jnp.zeros(3))
        _, conics, depth, _ = model.project_gaussians(
            p["pos"], jnp.exp(p["log_scale"]), p["quat"], view, 60.0, 60.0, 32.0, 32.0
        )
        conics = np.asarray(conics)[np.asarray(depth) > 0.2]
        a, b, c = conics[:, 0], conics[:, 1], conics[:, 2]
        assert np.all(a > 0) and np.all(c > 0)
        assert np.all(a * c - b * b > 0)  # positive determinant

    def test_isotropic_conic_for_isotropic_gaussian(self):
        """A spherical Gaussian at the optical axis projects to an
        isotropic conic (a == c, b == 0)."""
        pos = jnp.array([[0.0, 0.0, 0.0]])
        scale = jnp.full((1, 3), 0.3)
        quat = jnp.array([[1.0, 0.0, 0.0, 0.0]])
        eye = jnp.array([0.0, 0.0, -5.0])
        view = model.look_at(eye, jnp.zeros(3))
        _, conics, _, _ = model.project_gaussians(pos, scale, quat, view, 80.0, 80.0, 0.0, 0.0)
        a, b, c = (float(x) for x in conics[0])
        assert abs(a - c) < 1e-5
        assert abs(b) < 1e-6

    def test_rotation_invariance_of_sphere(self, rng):
        """Rotating a spherical Gaussian must not change its projection."""
        pos = jnp.array([[0.3, -0.2, 0.1]])
        scale = jnp.full((1, 3), 0.2)
        eye = jnp.array([0.0, 0.0, -3.0])
        view = model.look_at(eye, jnp.zeros(3))
        qs = [jnp.array([[1.0, 0, 0, 0]]), jnp.asarray(rng.normal(size=(1, 4)), jnp.float32)]
        outs = [
            np.asarray(model.project_gaussians(pos, scale, q, view, 60.0, 60.0, 32.0, 32.0)[1])
            for q in qs
        ]
        np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)


class TestQuatRotation:
    def test_identity(self):
        r = model.quat_to_rotmat(jnp.array([1.0, 0.0, 0.0, 0.0]))
        np.testing.assert_allclose(np.asarray(r), np.eye(3), atol=1e-6)

    def test_orthonormal(self, rng):
        q = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
        r = model.quat_to_rotmat(q)
        rtr = np.asarray(r @ jnp.swapaxes(r, -1, -2))
        np.testing.assert_allclose(rtr, np.tile(np.eye(3), (32, 1, 1)), atol=1e-5)

    def test_determinant_one(self, rng):
        q = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
        det = np.linalg.det(np.asarray(model.quat_to_rotmat(q)))
        np.testing.assert_allclose(det, 1.0, atol=1e-5)


class TestRenderImage:
    def test_render_shape_and_range(self, rng):
        p = make_params(rng, 48)
        eye = jnp.array([0.0, 0.0, -3.0])
        view = model.look_at(eye, jnp.zeros(3))
        img = model.render_image(p, view, eye, 24, 24, 30.0, 30.0, 12.0, 12.0)
        assert img.shape == (24, 24, 3)
        assert float(img.min()) >= 0.0
        assert np.isfinite(np.asarray(img)).all()

    def test_empty_scene_is_black(self):
        p = dict(
            pos=jnp.zeros((4, 3)),
            log_scale=jnp.full((4, 3), -3.0),
            quat=jnp.tile(jnp.array([[1.0, 0, 0, 0]]), (4, 1)),
            opacity_logit=jnp.full((4,), -20.0),  # sigmoid ~ 0
            sh=jnp.zeros((4, 16, 3)),
        )
        eye = jnp.array([0.0, 0.0, -3.0])
        view = model.look_at(eye, jnp.zeros(3))
        img = model.render_image(p, view, eye, 8, 8, 10.0, 10.0, 4.0, 4.0)
        np.testing.assert_allclose(np.asarray(img), 0.0, atol=1e-6)

    def test_gradients_finite(self, rng):
        p = make_params(rng, 32)
        eye = jnp.array([0.0, 0.0, -3.0])
        view = model.look_at(eye, jnp.zeros(3))
        loss = lambda q: jnp.mean(model.render_image(q, view, eye, 16, 16, 20.0, 20.0, 8.0, 8.0) ** 2)
        g = jax.grad(loss)(p)
        for k, v in g.items():
            assert np.isfinite(np.asarray(v)).all(), k

    def test_behind_camera_invisible(self):
        """A Gaussian behind the eye must not contribute."""
        p = dict(
            pos=jnp.array([[0.0, 0.0, -10.0]]),  # behind eye at z=-4
            log_scale=jnp.full((1, 3), -1.0),
            quat=jnp.array([[1.0, 0, 0, 0]]),
            opacity_logit=jnp.array([5.0]),
            sh=jnp.ones((1, 16, 3)),
        )
        eye = jnp.array([0.0, 0.0, -4.0])
        view = model.look_at(eye, jnp.zeros(3))
        img = model.render_image(p, view, eye, 8, 8, 10.0, 10.0, 4.0, 4.0)
        np.testing.assert_allclose(np.asarray(img), 0.0, atol=1e-6)


class TestSceneIO:
    def test_roundtrip(self, rng, tmp_path):
        n = 37
        pos = rng.normal(size=(n, 3)).astype(np.float32)
        scale = rng.uniform(0.01, 0.5, (n, 3)).astype(np.float32)
        quat = rng.normal(size=(n, 4)).astype(np.float32)
        opac = rng.uniform(0, 1, n).astype(np.float32)
        sh = rng.normal(size=(n, 16, 3)).astype(np.float32)
        path = str(tmp_path / "scene.lgsc")
        common.write_scene(path, pos, scale, quat, opac, sh)
        got = common.read_scene(path)
        for a, b in zip((pos, scale, quat, opac, sh), got):
            np.testing.assert_array_equal(a, b)

    def test_bad_magic_rejected(self, tmp_path):
        path = tmp_path / "bad.lgsc"
        path.write_bytes(b"XXXX" + b"\0" * 32)
        with pytest.raises(ValueError, match="magic"):
            common.read_scene(str(path))
