//! Radiance-cache benchmarks: lookup/insert microcosts and the cached
//! rasterization path (paper Table/Fig. 22's RC rows reduce to these).

use lumina::camera::{Intrinsics, Pose};
use lumina::constants::TILE;
use lumina::lumina::rc::{
    rasterize_cached, rasterize_cached_ex, GroupedRadianceCache, RadianceCache,
};
use lumina::pipeline::raster::{rasterize, RasterConfig};
use lumina::math::Vec3;
use lumina::pipeline::project::project;
use lumina::pipeline::sort::bin_and_sort;
use lumina::scene::synth::{synth_scene, SceneClass};
use lumina::util::bench::Runner;
use lumina::util::prng::Pcg32;

fn main() {
    let mut r = Runner::new("cache");
    r.header();

    // Micro: lookup / insert against a warm bank.
    let mut bank = RadianceCache::paper_default(5);
    let mut rng = Pcg32::seeded(7);
    let tags: Vec<[u32; 5]> = (0..4096)
        .map(|_| std::array::from_fn(|_| rng.next_u32() >> 10))
        .collect();
    for t in &tags {
        bank.insert(t, [0.5, 0.5, 0.5]);
    }
    let mut i = 0usize;
    r.bench("lookup/warm", || {
        i = (i + 1) & 4095;
        bank.lookup(&tags[i])
    });
    let mut j = 0usize;
    r.bench("insert/evicting", || {
        j = j.wrapping_add(1);
        let tag: [u32; 5] = std::array::from_fn(|k| (j as u32) << 7 | k as u32);
        bank.insert(&tag, [0.1, 0.2, 0.3]);
    });

    // Macro: cached rasterization, cold vs warm cache.
    let scene = synth_scene(SceneClass::SyntheticSmall, 42, 40_000);
    let pose = Pose::look_at(Vec3::new(0.0, 0.3, -2.3), Vec3::ZERO);
    let intr = Intrinsics::with_fov(256, 256, 0.87);
    let p = project(&scene, &pose, &intr, 0.2, 1000.0, 0.0);
    let bins = bin_and_sort(&p, &intr, TILE, 0.0);

    r.bench("rasterize_cached/cold", || {
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache)
    });

    let mut warm = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
    rasterize_cached(&p, &bins, intr.width, intr.height, &mut warm);
    r.bench("rasterize_cached/warm", || {
        rasterize_cached(&p, &bins, intr.width, intr.height, &mut warm)
    });

    // Single-pass uncached recording (the RC-GPU cost path) vs the old
    // two-pass approach (cached + a full plain stats pass).
    let mut rec = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
    rasterize_cached_ex(&p, &bins, intr.width, intr.height, &mut rec, true);
    r.bench("rasterize_cached/warm+record_uncached", || {
        rasterize_cached_ex(&p, &bins, intr.width, intr.height, &mut rec, true)
    });
    let mut two = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
    rasterize_cached(&p, &bins, intr.width, intr.height, &mut two);
    let stats_cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
    r.bench("rasterize_cached/warm+separate_uncached_pass", || {
        let cached = rasterize_cached(&p, &bins, intr.width, intr.height, &mut two);
        let plain = rasterize(&p, &bins, intr.width, intr.height, &stats_cfg);
        (cached, plain)
    });

    r.finish();
}
