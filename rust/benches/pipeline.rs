//! Pipeline hot-path benchmarks: projection, binning+sorting,
//! rasterization — the per-stage costs behind every end-to-end number.
//! (Custom harness: the offline vendor set has no criterion.)
//!
//! `LUMINA_BENCH_SMOKE=1` shrinks the scenes for the CI bench job.

use lumina::camera::{Intrinsics, Pose};
use lumina::constants::TILE;
use lumina::math::Vec3;
use lumina::pipeline::project::{project, refresh_colors, reproject_geometry};
use lumina::pipeline::raster::{rasterize, RasterConfig};
use lumina::pipeline::sort::bin_and_sort;
use lumina::scene::synth::{synth_scene, SceneClass};
use lumina::util::bench::Runner;

fn main() {
    let mut r = Runner::new("pipeline");
    r.header();
    let smoke = std::env::var("LUMINA_BENCH_SMOKE").is_ok();

    let count = if smoke { 12_000 } else { 60_000 };
    let side = if smoke { 128 } else { 256 };
    let scene = synth_scene(SceneClass::SyntheticSmall, 42, count);
    let pose = Pose::look_at(Vec3::new(0.0, 0.3, -2.3), Vec3::ZERO);
    let intr = Intrinsics::with_fov(side, side, 0.87);

    r.bench("project/scene", || project(&scene, &pose, &intr, 0.2, 1000.0, 0.0));

    let projected = project(&scene, &pose, &intr, 0.2, 1000.0, 0.0);
    r.bench("bin_and_sort/scene", || bin_and_sort(&projected, &intr, TILE, 0.0));

    let bins = bin_and_sort(&projected, &intr, TILE, 0.0);
    let plain = RasterConfig::default();
    r.bench("rasterize/scene", || {
        rasterize(&projected, &bins, intr.width, intr.height, &plain)
    });

    let stats_cfg = RasterConfig { collect_stats: true, sig_record_k: 5 };
    r.bench("rasterize+stats+records/scene", || {
        rasterize(&projected, &bins, intr.width, intr.height, &stats_cfg)
    });

    r.bench("reproject_geometry/visible", || {
        let mut p = projected.clone();
        reproject_geometry(&mut p, &scene, &pose, &intr);
        p
    });

    r.bench("refresh_colors/visible", || {
        let mut p = projected.clone();
        refresh_colors(&mut p, &scene, &pose);
        p
    });

    // Large-scene projection (the U360-class frustum-cull workload).
    let big = synth_scene(SceneClass::RealUnbounded, 42, if smoke { 60_000 } else { 600_000 });
    let big_pose = Pose::look_at(Vec3::new(0.0, 3.0, -25.0), Vec3::ZERO);
    r.bench("project/unbounded", || project(&big, &big_pose, &intr, 0.2, 1000.0, 0.0));

    r.finish();
}
