//! Raster hot-path benchmarks: exact-intersection binning vs the rect
//! reference, the parallel scatter, and chunked rasterization — the
//! costs the FlashGS-style overhaul targets (DESIGN.md §"Raster hot
//! path"). (Custom harness: the offline vendor set has no criterion.)
//!
//! Besides timings, this emits `metric/binned_entries_{rect,exact}`
//! rows; `python/bench_gate.py` enforces `exact <= rect` on every run
//! (machine-independent — it compares counts, not times).
//!
//! `LUMINA_BENCH_SMOKE=1` shrinks the scenes for the CI bench job.

use lumina::camera::{Intrinsics, Pose};
use lumina::constants::TILE;
use lumina::math::Vec3;
use lumina::pipeline::project::project;
use lumina::pipeline::raster::{rasterize, PartialRaster, RasterConfig};
use lumina::pipeline::sort::{bin_and_sort, bin_and_sort_rect};
use lumina::scene::synth::{synth_scene, SceneClass};
use lumina::util::bench::Runner;

fn main() {
    let mut r = Runner::new("raster");
    r.header();
    let smoke = std::env::var("LUMINA_BENCH_SMOKE").is_ok();

    let count = if smoke { 12_000 } else { 60_000 };
    let side = if smoke { 128 } else { 256 };
    let scene = synth_scene(SceneClass::SyntheticSmall, 42, count);
    let pose = Pose::look_at(Vec3::new(0.0, 0.3, -2.3), Vec3::ZERO);
    let intr = Intrinsics::with_fov(side, side, 0.87);
    let projected = project(&scene, &pose, &intr, 0.2, 1000.0, 0.0);

    r.bench("bin/rect", || bin_and_sort_rect(&projected, &intr, TILE, 0.0));
    r.bench("bin/exact", || bin_and_sort(&projected, &intr, TILE, 0.0));
    // The S² shared-sort shape: margin-inflated candidate rects.
    r.bench("bin/exact+margin", || bin_and_sort(&projected, &intr, TILE, 16.0));

    // Machine-independent workload counters for the bench gate: the
    // exact test may only shrink the per-tile lists.
    let rect = bin_and_sort_rect(&projected, &intr, TILE, 0.0);
    let exact = bin_and_sort(&projected, &intr, TILE, 0.0);
    r.metric("metric/binned_entries_rect", rect.total_entries() as u64);
    r.metric("metric/binned_entries_exact", exact.total_entries() as u64);
    r.metric("metric/bin_candidates", exact.rect_candidates() as u64);

    let cfg = RasterConfig::default();
    r.bench("rasterize/exact_bins", || {
        rasterize(&projected, &exact, intr.width, intr.height, &cfg)
    });
    r.bench("rasterize/rect_bins", || {
        rasterize(&projected, &rect, intr.width, intr.height, &cfg)
    });
    // Sub-stage dispatch overhead: the same frame in 4 chunked passes
    // (what a depth-3 pipelined session runs).
    r.bench("rasterize/4_chunks", || {
        let mut acc = PartialRaster::new(&exact, intr.width, intr.height, &cfg);
        let tiles = exact.tile_count();
        let mut start = 0;
        for i in 0..4 {
            let end = tiles * (i + 1) / 4;
            acc.render_tiles(&projected, &exact, start..end);
            start = end;
        }
        acc.finish()
    });

    r.finish();
}
