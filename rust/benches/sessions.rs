//! Multi-session serving benchmarks: end-to-end pool throughput at
//! several session counts over one shared scene — the scaling curve of
//! the first multi-user serving scenario.

use std::sync::Arc;

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::SessionPool;
use lumina::scene::synth::synth_scene;
use lumina::util::bench::Runner;

fn main() {
    let mut r = Runner::new("sessions");
    r.header();

    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 20_000;
    cfg.camera.width = 128;
    cfg.camera.height = 128;
    cfg.camera.frames = 4;
    cfg.variant = HardwareVariant::Lumina;

    // The scene is built once and shared — construction inside the
    // closure measures session setup + run, not scene synthesis.
    let scene = Arc::new(synth_scene(cfg.scene.class, cfg.scene.seed, cfg.gaussian_count()));

    for n in [1usize, 4, 8] {
        let cfg = cfg.clone();
        let scene = scene.clone();
        r.bench(&format!("session_pool/{n}x4frames"), move || {
            let mut pool = SessionPool::with_scene(cfg.clone(), scene.clone(), n).unwrap();
            pool.run().unwrap()
        });
    }

    r.finish();
}
