//! Multi-session serving benchmarks: end-to-end pool throughput at
//! several session counts over one shared scene — the scaling curve of
//! the first multi-user serving scenario — plus the async-pipelining
//! comparison (`pool_depth1` vs `pool_depth2`) the CI bench gate
//! watches.
//!
//! `LUMINA_BENCH_SMOKE=1` shrinks every scene so the whole file runs in
//! CI smoke mode (it also implies the quick measurement budget).

use std::sync::Arc;

use lumina::config::{CacheScope, HardwareVariant, LuminaConfig, SchedulerMode, SortScope, Tier};
use lumina::coordinator::admission::{price_workload, ADMISSION_HEADROOM};
use lumina::coordinator::{steal, AdmissionController, SessionPool};
use lumina::scene::synth::synth_scene;
use lumina::util::bench::Runner;

fn main() {
    let mut r = Runner::new("sessions");
    r.header();
    let smoke = std::env::var("LUMINA_BENCH_SMOKE").is_ok();

    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = if smoke { 5000 } else { 20_000 };
    cfg.camera.width = if smoke { 64 } else { 128 };
    cfg.camera.height = cfg.camera.width;
    cfg.camera.frames = 4;
    cfg.variant = HardwareVariant::Lumina;

    // The scene is built once and shared — construction inside the
    // closure measures session setup + run, not scene synthesis.
    let scene = Arc::new(synth_scene(cfg.scene.class, cfg.scene.seed, cfg.gaussian_count()));

    for n in [1usize, 4, 8] {
        let cfg = cfg.clone();
        let scene = scene.clone();
        r.bench(&format!("session_pool/{n}x4frames"), move || {
            let mut pool = SessionPool::builder(cfg.clone())
                .sessions(n)
                .scene(scene.clone())
                .build()
                .unwrap();
            pool.run().unwrap()
        });
    }

    // Tiered serving under admission control: a target sized to ~2.5
    // full-tier sessions forces a mixed ladder on larger pools (this is
    // the capacity-managed path: probe -> plan -> epoch re-plans).
    let full_cost = {
        let mut probe = SessionPool::builder(cfg.clone())
            .sessions(1)
            .scene(scene.clone())
            .build()
            .unwrap();
        let demands = probe.probe_demands().unwrap();
        price_workload(&demands[0].workload, cfg.variant)
    };
    for n in [4usize, 8] {
        // Budget 0.75 full-frames per session: all-full cannot fit, the
        // cheaper mixes can — every run exercises demotion.
        let target = (1.0 - ADMISSION_HEADROOM) / (0.75 * n as f64 * full_cost);
        let cfg = cfg.clone();
        let scene = scene.clone();
        r.bench(&format!("tiered_pool/{n}x4frames@target"), move || {
            let ctrl = AdmissionController::new(
                target,
                vec![Tier::Full, Tier::Reduced, Tier::Half],
                cfg.pool.reduced_fraction,
            )
            .unwrap();
            let mut pool = SessionPool::builder(cfg.clone())
                .sessions(n)
                .scene(scene.clone())
                .build()
                .unwrap();
            pool.serve(&ctrl).unwrap()
        });
    }

    // End-of-run SLOs straight off the PoolReport accessors: p99
    // simulated frame latency and demotion rate for the 8-session
    // tiered pool. Both derive from the deterministic cost model, so
    // the rows are machine-independent.
    let p99_name = "metric/tiered_pool8_p99_us";
    let dem_name = "metric/tiered_pool8_demotion_ppm";
    if r.enabled(p99_name) || r.enabled(dem_name) {
        let target = (1.0 - ADMISSION_HEADROOM) / (0.75 * 8.0 * full_cost);
        let ctrl = AdmissionController::new(
            target,
            vec![Tier::Full, Tier::Reduced, Tier::Half],
            cfg.pool.reduced_fraction,
        )
        .unwrap();
        let mut pool = SessionPool::builder(cfg.clone())
            .sessions(8)
            .scene(scene.clone())
            .build()
            .unwrap();
        let report = pool.serve(&ctrl).unwrap();
        if r.enabled(p99_name) {
            r.metric(p99_name, (report.latency_percentile(99.0) * 1e6).round() as u64);
        }
        if r.enabled(dem_name) {
            r.metric(dem_name, (report.demotion_rate() * 1e6).round() as u64);
        }
    }

    // Cross-session radiance caching: convergent viewers served against
    // one pool-wide snapshot/merge cache vs per-session private caches.
    // Timing rows measure the pool end to end; the metric rows export
    // each scope's aggregate hit rate (in ppm) for the bench gate's
    // machine-independent shared >= private invariant.
    let mut ccfg = cfg.clone();
    ccfg.variant = HardwareVariant::Lumina;
    ccfg.pool.epoch_frames = 2;
    // One 4x4-tile cache group (1024 px): the merged inserts fit the
    // 4096-entry bank, so the hit-rate comparison measures sharing,
    // not eviction thrash.
    ccfg.camera.width = 32;
    ccfg.camera.height = 32;
    for scope in [CacheScope::Private, CacheScope::Shared] {
        let mut run_cfg = ccfg.clone();
        run_cfg.pool.cache_scope = scope;
        let stagger = run_cfg.pool.epoch_frames;
        let bench_cfg = run_cfg.clone();
        let bench_scene = scene.clone();
        r.bench(&format!("cache_scope_{}/3x4frames_convergent", scope.label()), move || {
            SessionPool::builder(bench_cfg.clone())
                .sessions(3)
                .stagger(stagger)
                .scene(bench_scene.clone())
                .build()
                .unwrap()
                .run()
                .unwrap()
        });
        let metric_name = format!("metric/hitrate_{}_ppm", scope.label());
        if r.enabled(&metric_name) {
            let report = SessionPool::builder(run_cfg)
                .sessions(3)
                .stagger(stagger)
                .scene(scene.clone())
                .build()
                .unwrap()
                .run()
                .unwrap();
            r.metric(&metric_name, (report.cache_hit_rate() * 1e6).round() as u64);
        }
    }

    // Mixed-tier cross-session caching: the same convergent pool with
    // one session demoted to the half-res tier. Geometry-keyed sharing
    // (`shared`) cannot pool across the resolution split — the demoted
    // session bins a different tile grid — while the world-space scope
    // keys on quantized Gaussian positions and keeps all three viewers
    // on one snapshot. The metric rows feed the bench gate's
    // machine-independent `world >= geom_shared` invariant.
    for scope in [CacheScope::Shared, CacheScope::World] {
        let mut run_cfg = ccfg.clone();
        run_cfg.pool.cache_scope = scope;
        let stagger = run_cfg.pool.epoch_frames;
        let bench_cfg = run_cfg.clone();
        let bench_scene = scene.clone();
        r.bench(&format!("cache_scope_{}/3xmixed_tier", scope.label()), move || {
            let mut pool = SessionPool::builder(bench_cfg.clone())
                .sessions(3)
                .stagger(stagger)
                .scene(bench_scene.clone())
                .build()
                .unwrap();
            pool.set_session_tier(2, Tier::Half).unwrap();
            pool.run().unwrap()
        });
        let metric_name = match scope {
            CacheScope::World => "metric/world_hit_rate",
            _ => "metric/geom_shared_hit_rate",
        };
        if r.enabled(metric_name) {
            let mut pool = SessionPool::builder(run_cfg)
                .sessions(3)
                .stagger(stagger)
                .scene(scene.clone())
                .build()
                .unwrap();
            pool.set_session_tier(2, Tier::Half).unwrap();
            let report = pool.run().unwrap();
            r.metric(metric_name, (report.cache_hit_rate() * 1e6).round() as u64);
        }
    }

    // Pool-clustered S² sorting: convergent viewers share one leader
    // sort per pose cluster per epoch vs private per-session windows.
    // Timing rows measure the pool end to end; the metric rows export
    // each scope's speculative-sort count for the bench gate's
    // machine-independent clustered <= private invariant. The divergent
    // pool (distinct camera seeds, tight radius) is the degenerate
    // case: singleton clusters, one sort per session per epoch.
    let mut scfg = cfg.clone();
    scfg.variant = HardwareVariant::S2Gpu;
    scfg.camera.width = 32;
    scfg.camera.height = 32;
    scfg.pool.epoch_frames = 2;
    scfg.s2.sharing_window = 2;
    scfg.pool.cluster_radius = 3.2;
    for scope in [SortScope::Private, SortScope::Clustered] {
        let mut run_cfg = scfg.clone();
        run_cfg.pool.sort_scope = scope;
        let stagger = run_cfg.pool.epoch_frames;
        let bench_cfg = run_cfg.clone();
        let bench_scene = scene.clone();
        r.bench(&format!("sort_scope_{}/3x4frames_convergent", scope.label()), move || {
            SessionPool::builder(bench_cfg.clone())
                .sessions(3)
                .stagger(stagger)
                .scene(bench_scene.clone())
                .build()
                .unwrap()
                .run()
                .unwrap()
        });
        let metric_name = format!("metric/leader_sorts_{}", scope.label());
        if r.enabled(&metric_name) {
            let report = SessionPool::builder(run_cfg)
                .sessions(3)
                .stagger(stagger)
                .scene(scene.clone())
                .build()
                .unwrap()
                .run()
                .unwrap();
            r.metric(&metric_name, report.sorted_frames() as u64);
        }
    }
    {
        let mut div_cfg = scfg.clone();
        div_cfg.pool.sort_scope = SortScope::Clustered;
        div_cfg.pool.cluster_radius = 0.01;
        let scene = scene.clone();
        r.bench("sort_scope_clustered/3x4frames_divergent", move || {
            SessionPool::builder(div_cfg.clone())
                .sessions(3)
                .scene(scene.clone())
                .build()
                .unwrap()
                .run()
                .unwrap()
        });
    }

    // Async frame pipelining: depth 2 overlaps frame N+1's frontend with
    // frame N's rasterization inside each session. Frontend-heavy
    // config — plain GPU variant (sorts every frame), large scene,
    // small framebuffer — so the two stages are comparable and the
    // overlap, not raw data parallelism, sets the frame rate. The CI
    // gate compares pool_depth2 against pool_depth1.
    let mut fcfg = LuminaConfig::quick_test();
    fcfg.variant = HardwareVariant::Gpu;
    fcfg.scene.count = if smoke { 12_000 } else { 60_000 };
    fcfg.camera.width = 48;
    fcfg.camera.height = 48;
    fcfg.camera.frames = 4;
    let fscene =
        Arc::new(synth_scene(fcfg.scene.class, fcfg.scene.seed, fcfg.gaussian_count()));
    for depth in [1usize, 2] {
        let mut cfg = fcfg.clone();
        cfg.pool.pipeline_depth = depth;
        let scene = fscene.clone();
        r.bench(&format!("pool_depth{depth}/2x4frames"), move || {
            let mut pool = SessionPool::builder(cfg.clone())
                .sessions(2)
                .scene(scene.clone())
                .build()
                .unwrap();
            pool.run().unwrap()
        });
    }

    // Pool-wide work stealing on a deliberately heterogeneous
    // "straggler" pool: four 4-frame sessions plus four 1-frame
    // stragglers — the post-spike shape of a flash crowd after most
    // late joiners are refused. One 4-frame epoch drains the whole
    // pool, so the per-session completion counts are [4,4,4,4,1,1,1,1]
    // by construction. Timing rows compare wall time per scheduler;
    // the metric rows export the machine-independent occupancy model
    // (idle worker-frames at the fixed MODEL_WORKERS budget, plus the
    // epoch critical path) for the bench gate's strict
    // stealing < session invariant — per-session chunking strands
    // workers behind the 4-frame sessions while the stragglers' lanes
    // sit empty; the pool-wide bag keeps every worker fed.
    let mut wcfg = cfg.clone();
    wcfg.camera.width = 48;
    wcfg.camera.height = 48;
    wcfg.pool.pipeline_depth = 2;
    wcfg.pool.epoch_frames = 4;
    let straggler_pool = |scheduler: SchedulerMode| {
        let mut run_cfg = wcfg.clone();
        run_cfg.pool.scheduler = scheduler;
        let mut pool = SessionPool::builder(run_cfg)
            .sessions(8)
            .scene(scene.clone())
            .build()
            .unwrap();
        for coord in &mut pool.sessions_mut()[4..] {
            coord.trajectory.poses.truncate(1);
        }
        pool
    };
    for scheduler in [SchedulerMode::Session, SchedulerMode::Stealing] {
        let make = &straggler_pool;
        r.bench(&format!("steal_sched_{}/8xstraggler", scheduler.label()), move || {
            let mut pool = make(scheduler);
            let mut reports = Vec::new();
            while pool.sessions().iter().any(|c| c.remaining() > 0 || c.in_flight() > 0)
            {
                reports.push(pool.run_epoch(4).unwrap());
            }
            reports
        });
    }
    let steal_metrics = [
        "metric/steal_idle_worker_frames",
        "metric/session_idle_worker_frames",
        "metric/steal_epoch_critical_path",
    ];
    if steal_metrics.iter().any(|n| r.enabled(n)) {
        let mut pool = straggler_pool(SchedulerMode::Stealing);
        let (mut steal_idle, mut session_idle, mut critical) = (0u64, 0u64, 0u64);
        while pool.sessions().iter().any(|c| c.remaining() > 0 || c.in_flight() > 0) {
            let frames = pool.run_epoch(4).unwrap();
            let counts: Vec<usize> = frames.iter().map(|v| v.len()).collect();
            steal_idle += steal::idle_worker_frames_stealing(&counts, steal::MODEL_WORKERS);
            session_idle += steal::idle_worker_frames_session(&counts, steal::MODEL_WORKERS);
            critical += steal::epoch_critical_path_frames(&counts);
        }
        if r.enabled(steal_metrics[0]) {
            r.metric(steal_metrics[0], steal_idle);
        }
        if r.enabled(steal_metrics[1]) {
            r.metric(steal_metrics[1], session_idle);
        }
        if r.enabled(steal_metrics[2]) {
            r.metric(steal_metrics[2], critical);
        }
    }

    r.finish();
}
