//! Multi-session serving benchmarks: end-to-end pool throughput at
//! several session counts over one shared scene — the scaling curve of
//! the first multi-user serving scenario.

use std::sync::Arc;

use lumina::config::{HardwareVariant, LuminaConfig, Tier};
use lumina::coordinator::admission::{price_workload, ADMISSION_HEADROOM};
use lumina::coordinator::{AdmissionController, SessionPool};
use lumina::scene::synth::synth_scene;
use lumina::util::bench::Runner;

fn main() {
    let mut r = Runner::new("sessions");
    r.header();

    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 20_000;
    cfg.camera.width = 128;
    cfg.camera.height = 128;
    cfg.camera.frames = 4;
    cfg.variant = HardwareVariant::Lumina;

    // The scene is built once and shared — construction inside the
    // closure measures session setup + run, not scene synthesis.
    let scene = Arc::new(synth_scene(cfg.scene.class, cfg.scene.seed, cfg.gaussian_count()));

    for n in [1usize, 4, 8] {
        let cfg = cfg.clone();
        let scene = scene.clone();
        r.bench(&format!("session_pool/{n}x4frames"), move || {
            let mut pool = SessionPool::with_scene(cfg.clone(), scene.clone(), n).unwrap();
            pool.run().unwrap()
        });
    }

    // Tiered serving under admission control: a target sized to ~2.5
    // full-tier sessions forces a mixed ladder on larger pools (this is
    // the capacity-managed path: probe -> plan -> epoch re-plans).
    let full_cost = {
        let mut probe = SessionPool::with_scene(cfg.clone(), scene.clone(), 1).unwrap();
        let demands = probe.probe_demands().unwrap();
        price_workload(&demands[0].workload, cfg.variant)
    };
    for n in [4usize, 8] {
        // Budget 0.75 full-frames per session: all-full cannot fit, the
        // cheaper mixes can — every run exercises demotion.
        let target = (1.0 - ADMISSION_HEADROOM) / (0.75 * n as f64 * full_cost);
        let cfg = cfg.clone();
        let scene = scene.clone();
        r.bench(&format!("tiered_pool/{n}x4frames@target"), move || {
            let ctrl = AdmissionController::new(
                target,
                vec![Tier::Full, Tier::Reduced, Tier::Half],
                cfg.pool.reduced_fraction,
            )
            .unwrap();
            let mut pool = SessionPool::with_scene(cfg.clone(), scene.clone(), n).unwrap();
            pool.serve(&ctrl).unwrap()
        });
    }

    r.finish();
}
