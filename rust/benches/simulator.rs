//! Simulator benchmarks: the cycle-accurate LuminCore model and the GPU
//! warp-aggregate extraction must stay cheap relative to the functional
//! render they annotate.

use lumina::camera::{Intrinsics, Pose};
use lumina::config::HardwareVariant;
use lumina::config::LuminaConfig;
use lumina::constants::TILE;
use lumina::coordinator::Coordinator;
use lumina::math::Vec3;
use lumina::pipeline::project::project;
use lumina::pipeline::raster::{rasterize, RasterConfig};
use lumina::pipeline::sort::bin_and_sort;
use lumina::scene::synth::{synth_scene, SceneClass};
use lumina::sim::gpu::WarpAggregates;
use lumina::sim::lumincore::{tiles_from_stats, LuminCoreSim};
use lumina::util::bench::Runner;

fn main() {
    let mut r = Runner::new("simulator");
    r.header();

    let scene = synth_scene(SceneClass::SyntheticSmall, 42, 40_000);
    let pose = Pose::look_at(Vec3::new(0.0, 0.3, -2.3), Vec3::ZERO);
    let intr = Intrinsics::with_fov(256, 256, 0.87);
    let p = project(&scene, &pose, &intr, 0.2, 1000.0, 0.0);
    let bins = bin_and_sort(&p, &intr, TILE, 0.0);
    let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
    let out = rasterize(&p, &bins, intr.width, intr.height, &cfg);
    let stats = out.stats.unwrap();

    r.bench("warp_aggregates/256px", || {
        WarpAggregates::from_stats(&stats, intr.width, intr.height)
    });

    let lists: Vec<usize> = (0..bins.tile_count()).map(|t| bins.list(t).len()).collect();
    r.bench("tiles_from_stats/256px", || {
        tiles_from_stats(
            &lists, bins.tiles_x, bins.tiles_y, TILE, intr.width, intr.height,
            &stats.iterated, &stats.significant, None,
        )
    });

    let tiles = tiles_from_stats(
        &lists, bins.tiles_x, bins.tiles_y, TILE, intr.width, intr.height,
        &stats.iterated, &stats.significant, None,
    );
    let sim = LuminCoreSim::paper_default();
    r.bench("lumincore_frame/256tiles", || sim.frame(&tiles, 0));

    // Whole-coordinator frame (the end-to-end unit everything builds on).
    let mut cc = LuminaConfig::quick_test();
    cc.scene.count = 20_000;
    cc.camera.frames = 100_000; // effectively unbounded for the bench
    cc.variant = HardwareVariant::Lumina;
    let mut coord = Coordinator::new(cc).unwrap();
    r.bench("coordinator_step/lumina/20k", || coord.step().unwrap());

    r.finish();
}
