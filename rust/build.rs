fn main() {
    // `tests/loom.rs` is gated on the custom `--cfg loom` (set by the CI
    // analysis job); declare it so `unexpected_cfgs` stays deny-clean in
    // normal builds.
    println!("cargo:rustc-check-cfg=cfg(loom)");
}
