//! Hardware-algorithm co-design sweep: how the sharing window, expanded
//! margin, and alpha-record length trade quality against performance —
//! the design space the paper's Figs. 23-24 explore.
//!
//! Run with: `cargo run --release --example codesign_sweep`

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::Coordinator;
use lumina::metrics::psnr;

fn run(cfg: LuminaConfig, frames: usize) -> anyhow::Result<(f64, f64, f64)> {
    let mut coord = Coordinator::new(cfg)?;
    let (mut t, mut q, mut hits, mut lookups) = (0.0, 0.0, 0u64, 0u64);
    for i in 0..frames {
        let pose = coord.trajectory.poses[i];
        let (reference, _, _, _) = coord.reference_frame(&pose);
        let f = coord.step()?;
        t += f.report.time_s;
        q += psnr(&reference, &f.image);
        hits += f.report.cache.hits;
        lookups += f.report.cache.lookups;
    }
    Ok((
        q / frames as f64,
        t / frames as f64,
        hits as f64 / lookups.max(1) as f64,
    ))
}

fn base_cfg() -> LuminaConfig {
    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 15_000;
    cfg.camera.frames = 12;
    cfg
}

fn main() -> anyhow::Result<()> {
    let frames = 10;
    println!("== sharing window sweep (S2-Acc, margin 2) ==");
    println!("{:>8} {:>10} {:>10}", "window", "psnr dB", "ms/frame");
    for window in [1usize, 2, 4, 6, 8, 12] {
        let mut cfg = base_cfg();
        cfg.variant = HardwareVariant::S2Acc;
        cfg.s2.sharing_window = window;
        cfg.s2.expanded_margin = 2;
        let (q, t, _) = run(cfg, frames)?;
        println!("{:>8} {:>10.2} {:>10.3}", window, q, t * 1e3);
    }

    println!("\n== expanded margin sweep (S2-Acc, window 6) ==");
    println!("{:>8} {:>10} {:>10}", "margin", "psnr dB", "ms/frame");
    for margin in [0usize, 1, 2, 4, 8] {
        let mut cfg = base_cfg();
        cfg.variant = HardwareVariant::S2Acc;
        cfg.s2.expanded_margin = margin;
        let (q, t, _) = run(cfg, frames)?;
        println!("{:>8} {:>10.2} {:>10.3}", margin, q, t * 1e3);
    }

    println!("\n== alpha-record sweep (RC-Acc) ==");
    println!("{:>8} {:>10} {:>10} {:>10}", "k", "psnr dB", "ms/frame", "hit-rate");
    for k in [1usize, 2, 3, 5, 7, 10] {
        let mut cfg = base_cfg();
        cfg.variant = HardwareVariant::RcAcc;
        cfg.rc.alpha_record = k;
        let (q, t, h) = run(cfg, frames)?;
        println!("{:>8} {:>10.2} {:>10.3} {:>9.1}%", k, q, t * 1e3, h * 100.0);
    }
    Ok(())
}
