//! Multi-session serving demo: N concurrent viewers over one shared
//! scene, each with its own trajectory, S² scheduler, and radiance
//! cache, stepped in parallel by the `SessionPool`.
//!
//! Run with: `cargo run --release --example multi_session`
//! (equivalent CLI: `lumina serve --sessions 4`)

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::SessionPool;

fn main() -> anyhow::Result<()> {
    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 20_000;
    cfg.camera.frames = 12;
    cfg.variant = HardwareVariant::Lumina;

    for n in [1usize, 2, 4, 8] {
        let mut pool = SessionPool::builder(cfg.clone()).sessions(n).build()?;
        let report = pool.run()?;
        println!("{}", report.summary());
        if n == 4 {
            for (i, r) in report.sessions.iter().enumerate() {
                println!("  session {i}: {}", r.summary());
            }
        }
    }
    Ok(())
}
