//! Quickstart: render a few frames of a synthetic scene with the full
//! Lumina pipeline and print per-frame metrics.
//!
//! Run with: `cargo run --release --example quickstart`

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    // A small scene so this finishes in seconds.
    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 20_000;
    cfg.camera.frames = 10;
    cfg.variant = HardwareVariant::Lumina;

    let mut coord = Coordinator::new(cfg)?;
    println!(
        "scene: {} Gaussians | image: {}x{} | variant: {}",
        coord.scene.len(),
        coord.intr.width,
        coord.intr.height,
        coord.cfg.variant.label()
    );

    let mut report = lumina::coordinator::RunReport::new("quickstart");
    while coord.remaining() > 0 {
        let frame = coord.step()?;
        println!(
            "frame {:>2}: {:>7.3} ms | raster {:>7.3} ms | hit {:>5.1}% | sorted={}",
            frame.report.frame,
            frame.report.time_s * 1e3,
            frame.report.raster_s * 1e3,
            frame.report.cache.hit_rate() * 100.0,
            frame.report.sorted_this_frame
        );
        report.push(frame.report);
    }
    println!("\n{}", report.summary());
    Ok(())
}
