//! Capacity-managed serving demo: the CostModel-driven admission
//! controller assigns each viewer a serving tier (full res / reduced
//! Gaussians / half res) so one modeled device holds a pool-wide
//! simulated-FPS target — and admits strictly more viewers than an
//! all-full-res pool can.
//!
//! Run with: `cargo run --release --example tiered_serving`
//! (equivalent CLI: `lumina serve --sessions N --target-fps F`)

use lumina::config::{HardwareVariant, LuminaConfig, Tier};
use lumina::coordinator::admission::{price_workload, ADMISSION_HEADROOM};
use lumina::coordinator::{AdmissionController, SessionPool};

fn main() -> anyhow::Result<()> {
    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 20_000;
    cfg.camera.width = 128;
    cfg.camera.height = 128;
    cfg.camera.frames = 12;
    cfg.pool.epoch_frames = 4;
    cfg.variant = HardwareVariant::Lumina;

    // Size the target from a one-session probe: the device budget fits
    // about 2.5 full-tier viewers, so every additional admission must
    // come from tiering.
    let mut probe = SessionPool::builder(cfg.clone()).build()?;
    let demands = probe.probe_demands()?;
    let full_cost = price_workload(&demands[0].workload, cfg.variant);
    let target = (1.0 - ADMISSION_HEADROOM) / (2.5 * full_cost);
    println!(
        "one full-tier frame costs {:.3} ms -> target {:.1} pool sim-fps",
        full_cost * 1e3,
        target
    );

    let max_admitted = |ladder: Vec<Tier>| -> anyhow::Result<usize> {
        let ctrl = AdmissionController::new(target, ladder, cfg.pool.reduced_fraction)?;
        let mut admitted = 0;
        for n in 1..=16 {
            let mut pool = SessionPool::builder(cfg.clone()).sessions(n).build()?;
            match pool.probe_demands().and_then(|d| ctrl.plan(&d)) {
                Ok(_) => admitted = n,
                Err(e) => {
                    println!("  {n} viewers: {e}");
                    break;
                }
            }
        }
        Ok(admitted)
    };

    println!("\nall-full-res ladder:");
    let full_max = max_admitted(vec![Tier::Full])?;
    println!("  admits {full_max} viewers");

    println!("\ntiered ladder [full,reduced,half]:");
    let tiered_max = max_admitted(cfg.pool.tiers.clone())?;
    println!("  admits {tiered_max} viewers (+{} over full-res)", tiered_max - full_max);

    // Serve the tiered pool at its maximum admission and verify the
    // target held end to end.
    let ctrl =
        AdmissionController::new(target, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)?;
    let mut pool = SessionPool::builder(cfg.clone()).sessions(tiered_max).build()?;
    let report = pool.serve(&ctrl)?;
    println!();
    for (i, r) in report.sessions.iter().enumerate() {
        println!("  session {i} [{}]: {}", r.tier_sequence().join(">"), r.summary());
    }
    println!("{}", report.summary());
    println!(
        "pool sim-fps {:.1} vs target {:.1} -> {}",
        report.pool_fps(),
        target,
        if report.pool_fps() >= target { "target held" } else { "TARGET MISSED" }
    );
    Ok(())
}
