//! End-to-end driver (DESIGN.md §"End-to-end validation"): a simulated
//! VR session exercising every layer of the stack on a real workload:
//!
//! 1. loads the AOT HLO artifacts and executes them via PJRT (Layer 1/2
//!    compute on the request path),
//! 2. runs the full LuminSys frame loop — S^2 speculative sorting,
//!    radiance caching, LuminCore simulation (Layer 3),
//! 3. cross-checks one rendered tile per sampled frame against the AOT
//!    kernel,
//! 4. reports the paper's headline metrics (FPS, speedup vs GPU, energy,
//!    hit rate, PSNR) for the session.
//!
//! Run with: `cargo run --release --example vr_session`
//! (requires `make artifacts`)

use lumina::config::{HardwareVariant, LuminaConfig};
use lumina::constants::TILE;
use lumina::coordinator::Coordinator;
use lumina::metrics::psnr;
use lumina::runtime::ArtifactRuntime;

fn main() -> anyhow::Result<()> {
    // --- Layer 1/2: load AOT artifacts -------------------------------
    let rt = ArtifactRuntime::load("artifacts")?;
    println!("PJRT platform: {} | artifacts: {:?}", rt.platform(), rt.artifact_names());

    // --- Session config ----------------------------------------------
    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.count = 40_000;
    cfg.camera.frames = 30;
    cfg.variant = HardwareVariant::Lumina;
    let mut lumina_coord = Coordinator::new(cfg.clone())?;
    cfg.variant = HardwareVariant::Gpu;
    let mut gpu_coord = Coordinator::new(cfg)?;

    println!(
        "session: {} frames @ {} FPS trajectory | {} Gaussians",
        lumina_coord.cfg.camera.frames,
        lumina_coord.trajectory.fps,
        lumina_coord.scene.len()
    );

    let mut lumina_report = lumina::coordinator::RunReport::new("Lumina");
    let mut gpu_report = lumina::coordinator::RunReport::new("GPU");
    let mut psnr_sum = 0.0;
    let mut checked_tiles = 0usize;
    let mut q_frames = 0u32;

    for i in 0..lumina_coord.cfg.camera.frames {
        let pose = lumina_coord.trajectory.poses[i];
        let frame = lumina_coord.step()?;
        gpu_report.push(gpu_coord.step()?.report);

        // Quality vs the exact pipeline every 5th frame.
        if i % 5 == 0 {
            let (reference, _, _, _) = lumina_coord.reference_frame(&pose);
            psnr_sum += psnr(&reference, &frame.image);
            q_frames += 1;

            // Cross-check one tile against the AOT Pallas kernel via PJRT:
            // proves the Rust hot path and the Layer-1 kernel agree.
            let p = lumina::pipeline::project::project(
                &lumina_coord.scene, &pose, &lumina_coord.intr, 0.2, 1000.0, 0.0,
            );
            let bins =
                lumina::pipeline::sort::bin_and_sort(&p, &lumina_coord.intr, TILE, 0.0);
            let tile = (0..bins.tile_count())
                .max_by_key(|&t| bins.list(t).len())
                .unwrap();
            let list = bins.list(tile);
            if !list.is_empty() {
                let (ox, oy) = bins.tile_origin(tile);
                let means: Vec<[f32; 2]> =
                    list.iter().map(|&i| p.means[i as usize]).collect();
                let conics: Vec<[f32; 3]> = list
                    .iter()
                    .map(|&i| {
                        let c = p.conics[i as usize];
                        [c.a, c.b, c.c]
                    })
                    .collect();
                let opacs: Vec<f32> = list.iter().map(|&i| p.opacity[i as usize]).collect();
                let colors: Vec<[f32; 3]> =
                    list.iter().map(|&i| p.colors[i as usize]).collect();
                let hlo = rt.raster_tile_full(&means, &conics, &opacs, &colors, [ox, oy])?;
                let (native, _, _, _, _) = lumina::pipeline::raster::composite_pixel(
                    &p, list, ox + 8.5, oy + 8.5, 0,
                );
                let off = 8 * TILE + 8;
                let diff = (native[0] - hlo.color[off * 3]).abs();
                assert!(diff < 1e-3, "HLO/native divergence {diff}");
                checked_tiles += 1;
            }
        }
        lumina_report.push(frame.report);
    }

    println!("\n--- session results ---");
    println!("{}", gpu_report.summary());
    println!("{}", lumina_report.summary());
    println!(
        "speedup vs GPU: {:.2}x | energy: {:.2}x | PSNR vs exact: {:.2} dB | \
         HLO tile checks passed: {}",
        gpu_report.mean_time_s() / lumina_report.mean_time_s(),
        lumina_report.mean_energy_j() / gpu_report.mean_energy_j(),
        psnr_sum / q_frames as f64,
        checked_tiles
    );
    Ok(())
}
