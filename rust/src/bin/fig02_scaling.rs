//! Fig. 2 — model size and GPU rendering performance vs scene complexity.
//! Paper: S-NeRF <1M Gaussians at 66 FPS down to U360 >6M at 5 FPS.

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::harness;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 2",
        "model size & GPU FPS vs scene complexity",
        "66 -> 5 FPS as scenes go synthetic -> unbounded real; >10x model growth",
    );
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>12}",
        "dataset", "paper-size", "our-size", "gpu-fps", "frame-ms"
    );
    for (label, class) in harness::all_classes() {
        let traj = if label == "S-NeRF" {
            TrajectoryKind::VrHeadMotion
        } else {
            TrajectoryKind::Walkthrough
        };
        let cfg = harness::harness_config(class, traj, HardwareVariant::Gpu);
        let count = cfg.gaussian_count();
        let report = harness::run_variant(cfg)?;
        println!(
            "{:<10} {:>12} {:>12} {:>10.1} {:>12.3}",
            label,
            class.default_count(),
            count,
            report.fps(),
            report.mean_time_s() * 1e3
        );
    }
    Ok(())
}
