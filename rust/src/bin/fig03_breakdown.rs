//! Fig. 3 — normalized GPU execution breakdown across scenes.
//! Paper: Sorting 23% and Rasterization 67% on average; no significant
//! shift in the distribution as scenes scale.

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::sim::gpu::{GpuModel, WarpAggregates};
use lumina::pipeline::raster::RasterStats;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 3",
        "GPU execution breakdown (projection / sorting / rasterization)",
        "sorting+rasterization dominate with 23% + 67% on average",
    );
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>8}",
        "dataset", "proj%", "sort%", "raster%", "other%"
    );
    let gpu = GpuModel::xavier_volta();
    for (label, class) in harness::all_classes() {
        let cfg = harness::harness_config(
            class,
            TrajectoryKind::Walkthrough,
            HardwareVariant::Gpu,
        );
        let coord = Coordinator::new(cfg)?;
        let pose = coord.trajectory.poses[0];
        let (_, stats, projected, entries) = coord.reference_frame(&pose);
        let stats = RasterStats { iterated: stats.iterated, significant: stats.significant };
        let agg = WarpAggregates::from_stats(&stats, coord.intr.width, coord.intr.height);
        let t = gpu.frame_times(coord.scene.len(), entries, &agg);
        let total = t.total();
        println!(
            "{:<10} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            label,
            100.0 * t.projection / total,
            100.0 * t.sorting / total,
            100.0 * t.rasterization / total,
            100.0 * t.overhead / total
        );
        let _ = projected;
    }
    Ok(())
}
