//! Fig. 4 — percentage of significant Gaussians per pixel and average
//! iterated Gaussians per pixel.
//! Paper: ~10.3% significant (std 2.1%) while iterating ~1000s/pixel.

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 4",
        "significant-Gaussian sparsity in rasterization",
        "~10.3% of iterated Gaussians are significant (alpha > 1/255)",
    );
    println!(
        "{:<10} {:>14} {:>14} {:>12}",
        "dataset", "iterated/px", "significant/px", "sig-frac%"
    );
    for (label, class) in harness::all_classes() {
        let cfg = harness::harness_config(
            class,
            TrajectoryKind::Walkthrough,
            HardwareVariant::Gpu,
        );
        let coord = Coordinator::new(cfg)?;
        let pose = coord.trajectory.poses[0];
        let (_, stats, _, _) = coord.reference_frame(&pose);
        println!(
            "{:<10} {:>14.1} {:>14.2} {:>11.1}%",
            label,
            stats.mean_iterated(),
            stats.mean_significant(),
            100.0 * stats.significant_fraction()
        );
    }
    Ok(())
}
