//! Fig. 5 — warp divergence during GPU rasterization.
//! Paper: threads remain masked over 69% of the time (std 10%).

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::pipeline::raster::RasterStats;
use lumina::sim::gpu::{GpuModel, WarpAggregates};

fn main() -> Result<()> {
    harness::banner(
        "Fig. 5",
        "SIMT lane masking during rasterization",
        "lanes masked ~69% of the time across scenes",
    );
    println!("{:<10} {:>10} {:>12}", "dataset", "masked%", "warps");
    let gpu = GpuModel::xavier_volta();
    for (label, class) in harness::all_classes() {
        let cfg = harness::harness_config(
            class,
            TrajectoryKind::Walkthrough,
            HardwareVariant::Gpu,
        );
        let coord = Coordinator::new(cfg)?;
        let pose = coord.trajectory.poses[0];
        let (_, stats, _, _) = coord.reference_frame(&pose);
        let stats = RasterStats { iterated: stats.iterated, significant: stats.significant };
        let agg = WarpAggregates::from_stats(&stats, coord.intr.width, coord.intr.height);
        println!(
            "{:<10} {:>9.1}% {:>12}",
            label,
            100.0 * agg.masked_fraction(&gpu),
            agg.warps
        );
    }
    Ok(())
}
