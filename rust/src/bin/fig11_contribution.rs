//! Fig. 11 — significance of Gaussian points toward the final radiance.
//! Paper: over 99% of the pixel value comes from <1.5% of the Gaussians
//! a pixel iterates.

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::constants::TILE;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::pipeline::project::project;
use lumina::pipeline::raster::contribution_profile;
use lumina::pipeline::sort::bin_and_sort;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 11",
        "contribution CDF of iterated Gaussians (sorted by contribution)",
        ">99% of pixel value from <1.5% of iterated Gaussians",
    );
    println!(
        "{:<10} {:>12} {:>14} {:>16}",
        "dataset", "pixels", "mean-iter/px", "%gauss for 99%"
    );
    for (label, class) in harness::all_classes() {
        let cfg = harness::harness_config(
            class,
            TrajectoryKind::Walkthrough,
            HardwareVariant::Gpu,
        );
        let coord = Coordinator::new(cfg)?;
        let pose = coord.trajectory.poses[0];
        let p = project(&coord.scene, &pose, &coord.intr, 0.2, 1000.0, 0.0);
        let bins = bin_and_sort(&p, &coord.intr, TILE, 0.0);
        let profiles =
            contribution_profile(&p, &bins, coord.intr.width, coord.intr.height, 16);
        let (_, stats, _, _) = coord.reference_frame(&pose);
        // For each sampled pixel: how many of its *iterated* Gaussians
        // cover 99% of the accumulated value.
        let mut fracs = Vec::new();
        let mean_iter = stats.mean_iterated().max(1.0);
        for prof in &profiles {
            let mut acc = 0.0f32;
            let mut needed = 0usize;
            for w in prof {
                acc += w;
                needed += 1;
                if acc >= 0.99 {
                    break;
                }
            }
            fracs.push(needed as f64 / mean_iter * 100.0);
        }
        if fracs.is_empty() {
            continue;
        }
        let mean_frac = fracs.iter().sum::<f64>() / fracs.len() as f64;
        println!(
            "{:<10} {:>12} {:>14.1} {:>15.2}%",
            label,
            profiles.len(),
            mean_iter,
            mean_frac
        );
    }
    Ok(())
}
