//! Fig. 12 — average color difference between pixels that share the same
//! first-k significant Gaussians, as a function of k.
//! Paper: below 1.0/255 at k=3, below 0.5/255 at k=5.

use anyhow::Result;
use std::collections::HashMap;

use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::constants::TILE;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::pipeline::project::project;
use lumina::pipeline::raster::{rasterize, RasterConfig};
use lumina::pipeline::sort::bin_and_sort;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 12",
        "color difference between pixels sharing the first k significant Gaussians",
        "avg diff < 1.0/255 at k=3, < 0.5/255 at k=5 (trained scenes)",
    );
    let cfg = harness::harness_config(
        lumina::scene::synth::SceneClass::SyntheticSmall,
        TrajectoryKind::VrHeadMotion,
        HardwareVariant::Gpu,
    );
    let mut coord = Coordinator::new(cfg)?;
    // Simulate the fine-tuned regime (Sec. 3.3): clamp the oversized tail
    // exactly as the scale-constrained loss does.
    let cap = 0.04;
    for s in coord.scene_mut().scale.iter_mut() {
        s.x = s.x.min(cap);
        s.y = s.y.min(cap);
        s.z = s.z.min(cap);
    }
    let pose_a = coord.trajectory.poses[0];
    let pose_b = coord.trajectory.poses[1];
    println!(
        "{:>4} {:>14} {:>14} {:>12}",
        "k", "avg diff /255", "med diff /255", "pairs"
    );
    for k in 1..=8usize {
        let mut diffs: Vec<f64> = Vec::new();
        // Match pixels across the two poses by their first-k significant
        // Gaussian IDs (exactly the cache-tag equivalence class), within
        // the same 64x64 cache group — the region one LuminCache bank
        // serves (Sec. 5), so pairs reflect what RC can actually alias.
        let mut table: HashMap<(usize, Vec<u32>), [f32; 3]> = HashMap::new();
        for (pi, pose) in [pose_a, pose_b].iter().enumerate() {
            let p = project(&coord.scene, pose, &coord.intr, 0.2, 1000.0, 0.0);
            let bins = bin_and_sort(&p, &coord.intr, TILE, 0.0);
            let rcfg = RasterConfig { collect_stats: false, sig_record_k: k };
            let out = rasterize(&p, &bins, coord.intr.width, coord.intr.height, &rcfg);
            let recs = out.sig_records.unwrap();
            for (i, rec) in recs.iter().enumerate() {
                let Some(ids) = rec.first_k(k) else { continue };
                let (x, y) = (i % coord.intr.width, i / coord.intr.width);
                let group = (y / 64) * coord.intr.width.div_ceil(64) + x / 64;
                let c = out.image.at(x, y);
                if pi == 0 {
                    table.insert((group, ids.to_vec()), c);
                } else if let Some(prev) = table.get(&(group, ids.to_vec())) {
                    let d = ((c[0] - prev[0]).abs()
                        + (c[1] - prev[1]).abs()
                        + (c[2] - prev[2]).abs())
                        / 3.0
                        * 255.0;
                    diffs.push(d as f64);
                }
            }
        }
        if !diffs.is_empty() {
            diffs.sort_by(f64::total_cmp);
            let mean = diffs.iter().sum::<f64>() / diffs.len() as f64;
            let median = diffs[diffs.len() / 2];
            println!("{:>4} {:>14.3} {:>14.3} {:>12}", k, mean, median, diffs.len());
        }
    }
    Ok(())
}
