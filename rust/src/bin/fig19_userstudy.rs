//! Fig. 19 — user study (SIMULATED; see DESIGN.md §8).
//!
//! The paper ran a 30-participant 2IFC study: 73% noticed no difference
//! between Lumina and baseline 3DGS; of those who did, preference split
//! ~50/50. We have no human subjects, so a psychometric observer model
//! stands in: per frame pair, detection probability follows a Weber-
//! contrast psychometric curve on the per-pixel error map (detection
//! requires a cluster of super-threshold pixels); preference among
//! detected differences is an unbiased coin flip at sub-JND severity.
//! This reproduces the *claim structure* (error below JND -> mostly
//! "no difference", tie preference), not human data.

use anyhow::Result;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::util::prng::Pcg32;

/// Fraction of clearly-super-threshold pixels above which an observer
/// reports a difference with high probability (Weber ~2% contrast over
/// a cluster of pixels).
const JND_PIXEL_LEVEL: f32 = 8.0 / 255.0;
const DETECT_SLOPE: f64 = 2200.0;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 19 (simulated observers)",
        "2IFC user study: variant vs baseline 3DGS",
        "~73% notice no difference; detected cases split ~50/50",
    );
    // The paper studies full Lumina on *trained* scenes, whose RC error
    // sits below the JND (Fig. 12: <0.5/255). Our procedural scenes give
    // RC a heavier error tail (EXPERIMENTS.md), so we report the
    // psychometric observer on both variants: S2-only demonstrates the
    // sub-JND regime the paper's system occupies; Lumina shows the
    // observer correctly flagging super-JND error at our scene
    // statistics.
    for variant in [HardwareVariant::S2Acc, HardwareVariant::Lumina] {
        run_study(variant)?;
    }
    Ok(())
}

fn run_study(variant: HardwareVariant) -> Result<()> {
    let mut rng = Pcg32::seeded(2026);
    let mut no_diff = 0u32;
    let mut prefer_ours = 0u32;
    let mut prefer_base = 0u32;
    let mut trials = 0u32;
    for (label, class, traj) in harness::eval_settings() {
        let cfg = harness::harness_config(class, traj, variant);
        let mut coord = Coordinator::new(cfg)?;
        // Fine-tuned regime: clamp the oversized tail (Sec. 3.3).
        let cap = 0.005 * coord.cfg.scene.class.extent() * 4.0;
        for s in coord.scene_mut().scale.iter_mut() {
            s.x = s.x.min(cap);
            s.y = s.y.min(cap);
            s.z = s.z.min(cap);
        }
        let mut frames = 0;
        while coord.remaining() > 0 && frames < 12 {
            let pose = coord.trajectory.poses[coord.trajectory.poses.len() - coord.remaining()];
            let f = coord.step()?;
            let (reference, _, _, _) = coord.reference_frame(&pose);
            // Super-threshold pixel fraction.
            let mut bad = 0usize;
            for (a, b) in f.image.data.iter().zip(&reference.data) {
                let d = ((a[0] - b[0]).abs() + (a[1] - b[1]).abs() + (a[2] - b[2]).abs())
                    / 3.0;
                if d > JND_PIXEL_LEVEL {
                    bad += 1;
                }
            }
            let frac = bad as f64 / f.image.data.len() as f64;
            // Psychometric detection probability (repeated 3x per trace
            // like the paper's protocol; 30 observers).
            for _ in 0..3 {
                trials += 1;
                let p_detect = 1.0 - (-frac * DETECT_SLOPE).exp();
                if rng.f64() < p_detect {
                    // Detected: sub-JND severity -> unbiased preference.
                    if rng.chance(0.5) {
                        prefer_ours += 1;
                    } else {
                        prefer_base += 1;
                    }
                } else {
                    no_diff += 1;
                }
            }
            frames += 1;
        }
        let _ = label;
    }
    let no_diff_pct = 100.0 * no_diff as f64 / trials as f64;
    let detected = prefer_ours + prefer_base;
    println!("--- {} vs baseline ---", variant.label());
    println!("trials:               {trials}");
    println!("no difference:        {no_diff_pct:.1}%   (paper, full Lumina: ~73%)");
    if detected > 0 {
        println!(
            "prefer variant:       {:.1}% of detected   (paper: ~50%)",
            100.0 * prefer_ours as f64 / detected as f64
        );
    }
    println!();
    Ok(())
}
