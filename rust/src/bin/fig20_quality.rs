//! Fig. 20 — rendering quality across methods: baseline, DS-2, S^2-only,
//! RC-only, Lumina, on synthetic (90 FPS) and real (30 FPS) settings.
//! Paper: S^2-only matches baseline; RC-only -0.2 dB; Lumina -0.3 dB;
//! DS-2 -1.0..-1.4 dB. SSIM/LPIPS follow the same ordering.
//!
//! Ground truth here is the exact 3DGS render (the paper compares to
//! held-out photos; our scenes are synthetic, so exact 3DGS *is* GT and
//! the baseline row reads as the metric ceiling).

use anyhow::Result;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::metrics::{lpips_proxy, psnr, ssim};

fn main() -> Result<()> {
    harness::banner(
        "Fig. 20",
        "quality: PSNR / SSIM / LPIPS-proxy vs exact 3DGS",
        "S2 ~= baseline; RC -0.2 dB; Lumina -0.3 dB; DS-2 -1.0..-1.4 dB",
    );
    for (setting, class, traj) in harness::eval_settings() {
        println!("--- {setting} ---");
        println!(
            "{:<10} {:>10} {:>8} {:>12}",
            "method", "psnr dB", "ssim", "lpips-proxy"
        );
        for (name, variant) in [
            ("S2-only", HardwareVariant::S2Acc),
            ("RC-only", HardwareVariant::RcAcc),
            ("Lumina", HardwareVariant::Lumina),
            // DS-2 rides the ordinary stage graph as a real variant:
            // half-res frontend + plain raster + 2x upsample finalize.
            ("DS-2", HardwareVariant::Ds2Gpu),
        ] {
            let cfg = harness::harness_config(class, traj, variant);
            let mut coord = Coordinator::new(cfg)?;
            // Fine-tuned regime (Sec. 3.3) for the RC variants.
            let cap = 0.005 * coord.cfg.scene.class.extent() * 4.0;
            for s in coord.scene_mut().scale.iter_mut() {
                s.x = s.x.min(cap);
                s.y = s.y.min(cap);
                s.z = s.z.min(cap);
            }
            let (mut p_sum, mut s_sum, mut l_sum, mut n) = (0.0, 0.0, 0.0, 0u32);
            let frames = 10usize;
            for i in 0..frames {
                let pose = coord.trajectory.poses[i];
                let (reference, _, _, _) = coord.reference_frame(&pose);
                let img = coord.step()?.image;
                p_sum += psnr(&reference, &img);
                s_sum += ssim(&reference, &img);
                l_sum += lpips_proxy(&reference, &img);
                n += 1;
            }
            println!(
                "{:<10} {:>10.2} {:>8.4} {:>12.4}",
                name,
                p_sum / n as f64,
                s_sum / n as f64,
                l_sum / n as f64
            );
        }
        println!();
    }
    Ok(())
}
