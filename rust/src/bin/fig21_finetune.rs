//! Fig. 21 — cache-aware fine-tuning: rendering quality and cache hit
//! rate of RC-only with and without the scale-constrained loss L_scale.
//! Paper: +0.6 dB average PSNR with L_scale, at a marginally lower hit
//! rate.
//!
//! Scene source: `python/compile/finetune.py` writes LGSC pairs
//! (scene_plain.lgsc = fine-tuned without L_scale, scene_finetuned.lgsc
//! = with). When those artifacts are absent the harness falls back to an
//! in-Rust surrogate of the constraint (clamping the geometric-mean
//! scale at theta), which captures the same mechanism: smaller splats ->
//! better RC fidelity, slightly fewer hits.

use anyhow::Result;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::metrics::psnr;
use lumina::scene::GaussianScene;

fn surrogate_finetune(scene: &GaussianScene, theta: f32) -> GaussianScene {
    let mut out = scene.clone();
    for s in out.scale.iter_mut() {
        let geo = (s.x * s.y * s.z).abs().powf(1.0 / 3.0);
        if geo > theta {
            let f = theta / geo;
            s.x *= f;
            s.y *= f;
            s.z *= f;
        }
    }
    out
}

fn run_rc(scene: GaussianScene, label: &str) -> Result<(f64, f64)> {
    let cfg = harness::harness_config(
        lumina::scene::synth::SceneClass::SyntheticSmall,
        lumina::camera::trajectory::TrajectoryKind::VrHeadMotion,
        HardwareVariant::RcAcc,
    );
    let mut coord = Coordinator::with_scene(cfg, std::sync::Arc::new(scene))?;
    let mut psnr_sum = 0.0;
    let mut n = 0u32;
    let mut hits = 0u64;
    let mut lookups = 0u64;
    for i in 0..12usize {
        let pose = coord.trajectory.poses[i];
        let (reference, _, _, _) = coord.reference_frame(&pose);
        let f = coord.step()?;
        psnr_sum += psnr(&reference, &f.image);
        hits += f.report.cache.hits;
        lookups += f.report.cache.lookups;
        n += 1;
    }
    let quality = psnr_sum / n as f64;
    let hit_rate = hits as f64 / lookups.max(1) as f64;
    println!("{label:<22} psnr={quality:>7.2} dB  hit-rate={:>5.1}%", hit_rate * 100.0);
    Ok((quality, hit_rate))
}

fn main() -> Result<()> {
    harness::banner(
        "Fig. 21",
        "RC-only quality & hit rate with vs without L_scale",
        "+0.6 dB PSNR with the scale-constrained loss; slightly fewer hits",
    );
    // Primary: the controlled comparison — the *same* scene with and
    // without the scale constraint applied (the clamp is exactly what
    // L_scale's penalty converges to at the constraint boundary). This
    // isolates the one variable the paper's Fig. 21 varies.
    println!("[A] controlled scale-constraint comparison (30k-Gaussian scene)");
    let base = lumina::scene::synth::synth_scene(
        lumina::scene::synth::SceneClass::SyntheticSmall,
        42,
        30_000,
    );
    let theta = 0.02;
    let (q0, h0) = run_rc(base.clone(), "  without L_scale")?;
    let (q1, h1) = run_rc(surrogate_finetune(&base, theta), "  with L_scale")?;
    println!(
        "  delta: {:+.2} dB PSNR (paper: +0.6), {:+.1}% hit rate (paper: slightly lower)",
        q1 - q0,
        (h1 - h0) * 100.0
    );

    // Secondary: the Layer-2 gradient-descent path (python finetune.py
    // artifacts) — the end-to-end differentiable pipeline of Sec. 3.3.
    // Statistical power is limited by the small trainable scene.
    let ft_dir = std::path::Path::new("artifacts/finetune");
    if ft_dir.join("scene_plain.lgsc").exists() {
        println!();
        println!("[B] L2 gradient-descent fine-tuning artifacts ({ft_dir:?})");
        let plain = lumina::scene::io::read_scene(ft_dir.join("scene_plain.lgsc"))?;
        let tuned = lumina::scene::io::read_scene(ft_dir.join("scene_finetuned.lgsc"))?;
        let (p0, g0) = run_rc(plain, "  adam, alpha=0")?;
        let (p1, g1) = run_rc(tuned, "  adam, alpha>0")?;
        println!(
            "  delta: {:+.2} dB PSNR, {:+.1}% hit rate (small-scene training run)",
            p1 - p0,
            (g1 - g0) * 100.0
        );
    } else {
        println!("
[B] skipped: run `make finetune` for the L2 gradient path");
    }
    Ok(())
}
