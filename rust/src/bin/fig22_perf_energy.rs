//! Fig. 22 — speedup and normalized energy of every variant vs the GPU
//! baseline, across the paper's two evaluation settings.
//! Paper: S2-GPU 1.2x, RC-GPU <1x, NRU+GPU 1.9x, S2-Acc 3.1x,
//! RC-Acc 1.7-2.7x, Lumina 4.5x; energy savings 20%..81%.

use anyhow::Result;
use lumina::config::HardwareVariant;
use lumina::harness;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 22",
        "speedup & normalized energy vs mobile-GPU baseline",
        "S2-GPU 1.2x | RC-GPU <1x | NRU+GPU 1.9x | S2-Acc 3.1x | RC-Acc 1.7-2.7x | Lumina 4.5x; energy -20%..-81%",
    );
    for (setting, class, traj) in harness::eval_settings() {
        println!("--- {setting} ---");
        println!(
            "{:<10} {:>10} {:>10} {:>12} {:>10}",
            "variant", "fps", "speedup", "norm-energy", "hit-rate"
        );
        let mut base_t = None;
        let mut base_e = None;
        for variant in HardwareVariant::evaluation_set() {
            let cfg = harness::harness_config(class, traj, variant);
            let report = harness::run_variant(cfg)?;
            let t = report.mean_time_s();
            let e = report.mean_energy_j();
            if variant == HardwareVariant::Gpu {
                base_t = Some(t);
                base_e = Some(e);
            }
            println!(
                "{:<10} {:>10.1} {:>9.2}x {:>12.3} {:>9.1}%",
                variant.label(),
                report.fps(),
                base_t.unwrap() / t,
                e / base_e.unwrap(),
                report.cache_hit_rate() * 100.0
            );
        }
        println!();
    }
    Ok(())
}
