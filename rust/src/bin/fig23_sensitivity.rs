//! Fig. 23 — sensitivity of rendering quality and speedup to the S^2
//! expanded margin and sharing (skipped) window, on a Drums-like
//! synthetic scene.
//! Paper: quality rises with margin (30.9 -> 31.4 dB at window 8) but
//! speedup falls (1.1x -> 0.6-1.0x); more skipped frames trade quality
//! (31.4 -> 30.2 dB) for speed.

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::metrics::psnr;
use lumina::scene::synth::SceneClass;

fn run_setting(window: usize, margin: usize) -> Result<(f64, f64)> {
    let mut cfg = harness::harness_config(
        SceneClass::SyntheticSmall,
        TrajectoryKind::VrHeadMotion,
        HardwareVariant::S2Acc,
    );
    cfg.s2.sharing_window = window;
    cfg.s2.expanded_margin = margin;
    cfg.camera.frames = 16;
    let mut coord = Coordinator::new(cfg)?;
    let mut time_sum = 0.0;
    let mut psnr_sum = 0.0;
    let mut n = 0u32;
    for i in 0..16usize {
        let pose = coord.trajectory.poses[i];
        let (reference, _, _, _) = coord.reference_frame(&pose);
        let f = coord.step()?;
        time_sum += f.report.time_s;
        psnr_sum += psnr(&reference, &f.image);
        n += 1;
    }
    Ok((psnr_sum / n as f64, time_sum / n as f64))
}

fn main() -> Result<()> {
    harness::banner(
        "Fig. 23",
        "S^2 sensitivity: expanded margin x sharing window (S2-only)",
        "quality up / speedup down with margin; quality down / speedup up with window",
    );
    // Reference normalization point: margin 4 (scaled: 2), window 6.
    let (_, t_ref) = run_setting(6, 2)?;
    println!(
        "{:>8} {:>8} {:>10} {:>10}",
        "window", "margin", "psnr dB", "speedup*"
    );
    println!("(* normalized to window=6, margin=2 as the paper normalizes to its default)");
    for window in [2usize, 4, 8, 16] {
        for margin in [1usize, 2, 4, 8] {
            let (q, t) = run_setting(window, margin)?;
            println!("{:>8} {:>8} {:>10.2} {:>9.2}x", window, margin, q, t_ref / t);
        }
        println!();
    }
    Ok(())
}
