//! Fig. 24 — sensitivity to the alpha-record length k (significant
//! Gaussians per cache tag), RC-only on the accelerator.
//! Paper: quality rises to the baseline as k grows; rasterization
//! speedup falls 2.3x -> 0.7x as the hit rate drops 82% -> 31%.

use anyhow::Result;
use lumina::camera::trajectory::TrajectoryKind;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::metrics::psnr;
use lumina::scene::synth::SceneClass;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 24",
        "alpha-record length k: quality, raster speedup, hit rate (RC-only)",
        "PSNR -> baseline as k grows; raster speedup 2.3x -> 0.7x; hits 82% -> 31%",
    );
    // Raster-stage time with RC disabled (the normalization target).
    let base_cfg = harness::harness_config(
        SceneClass::SyntheticSmall,
        TrajectoryKind::VrHeadMotion,
        HardwareVariant::NruGpu,
    );
    let base_raster: f64 = {
        let mut coord = Coordinator::new(base_cfg)?;
        let mut sum = 0.0;
        for _ in 0..10 {
            sum += coord.step()?.report.raster_s;
        }
        sum / 10.0
    };
    println!(
        "{:>4} {:>10} {:>16} {:>10}",
        "k", "psnr dB", "raster-speedup", "hit-rate"
    );
    for k in 1..=10usize {
        let mut cfg = harness::harness_config(
            SceneClass::SyntheticSmall,
            TrajectoryKind::VrHeadMotion,
            HardwareVariant::RcAcc,
        );
        cfg.rc.alpha_record = k;
        let mut coord = Coordinator::new(cfg)?;
        let mut raster = 0.0;
        let mut q = 0.0;
        let mut hits = 0u64;
        let mut lookups = 0u64;
        for i in 0..10usize {
            let pose = coord.trajectory.poses[i];
            let (reference, _, _, _) = coord.reference_frame(&pose);
            let f = coord.step()?;
            raster += f.report.raster_s;
            q += psnr(&reference, &f.image);
            hits += f.report.cache.hits;
            lookups += f.report.cache.lookups;
        }
        println!(
            "{:>4} {:>10.2} {:>15.2}x {:>9.1}%",
            k,
            q / 10.0,
            base_raster / (raster / 10.0),
            100.0 * hits as f64 / lookups.max(1) as f64
        );
    }
    Ok(())
}
