//! Fig. 25 — Lumina vs GSCore. For fairness the paper hosts projection
//! and sorting on GSCore's CCU + GSU for all Lumina variants.
//! Paper (normalized to the GPU baseline): GSCore 3.2x; Lumina baseline
//! hardware 9.6x; +S2 12.8x; +RC 16.4x; full Lumina 29.6x.

use anyhow::Result;
use lumina::config::HardwareVariant;
use lumina::coordinator::Coordinator;
use lumina::harness;
use lumina::sim::gscore::GsCoreModel;

fn main() -> Result<()> {
    harness::banner(
        "Fig. 25",
        "speedup vs GSCore (all on CCU/GSU frontends)",
        "GSCore 3.2x | base-HW 9.6x | +S2 12.8x | +RC 16.4x | Lumina 29.6x over GPU",
    );
    for (setting, class, traj) in harness::eval_settings() {
        println!("--- {setting} ---");
        // GPU baseline for normalization.
        let gpu = harness::run_variant(harness::harness_config(class, traj, HardwareVariant::Gpu))?;
        let base_t = gpu.mean_time_s();
        println!("{:<18} {:>10} {:>10}", "config", "fps", "speedup");
        println!("{:<18} {:>10.1} {:>9.2}x", "GPU", gpu.fps(), 1.0);
        let entries: Vec<(&str, HardwareVariant)> = vec![
            ("GSCore", HardwareVariant::GsCore),
            ("base-HW (NRU)", HardwareVariant::LuminaOnGscoreFrontend),
            ("+S2", HardwareVariant::S2Acc),
            ("+RC", HardwareVariant::RcAcc),
            ("Lumina", HardwareVariant::Lumina),
        ];
        for (name, variant) in entries {
            let cfg = harness::harness_config(class, traj, variant);
            let mut coord = Coordinator::new(cfg)?;
            // All accelerator variants use the CCU/GSU frontend here:
            // swap the frontend cost-model seam of the stage graph.
            if variant != HardwareVariant::GsCore {
                coord.set_frontend_cost(Box::new(GsCoreModel::published()));
            }
            let r = coord.run()?;
            println!("{:<18} {:>10.1} {:>9.2}x", name, r.fps(), base_t / r.mean_time_s());
        }
        println!();
    }
    Ok(())
}
