//! Hardware budget table (paper Sec. 5): LuminCore component inventory,
//! SRAM sizing, and the area-overhead claim (1.05 mm^2, ~0.4% of a
//! ~350 mm^2 Xavier-class SoC).

use lumina::constants::*;

fn main() {
    println!("=== LuminCore hardware budget (paper Sec. 5) ===\n");
    println!("{:<34} {:>14} {:>14}", "component", "ours", "paper");
    println!(
        "{:<34} {:>14} {:>14}",
        "NRU array",
        format!("{}x{}", NRU_ARRAY, NRU_ARRAY),
        "8x8"
    );
    println!("{:<34} {:>14} {:>14}", "PEs per NRU (3-stage)", PES_PER_NRU, 4);
    println!(
        "{:<34} {:>14} {:>14}",
        "clock",
        format!("{:.1} GHz", NRU_CLOCK_HZ / 1e9),
        "1 GHz"
    );
    println!(
        "{:<34} {:>14} {:>14}",
        "feature buffer (double-buffered)",
        format!("{} KB", FEATURE_BUF_BYTES / 1024),
        "176 KB"
    );
    println!(
        "{:<34} {:>14} {:>14}",
        "output buffer (double-buffered)",
        format!("{} KB", OUTPUT_BUF_BYTES / 1024),
        "6 KB"
    );
    let cache_entries = CACHE_WAYS * CACHE_SETS;
    let cache_bytes = cache_entries * 13; // 10 B tag + 3 B RGB
    println!(
        "{:<34} {:>14} {:>14}",
        "LuminCache",
        format!("{}x{} = {} KB", CACHE_WAYS, CACHE_SETS, cache_bytes / 1024),
        "52 KB"
    );
    println!(
        "{:<34} {:>14} {:>14}",
        "cache coverage",
        format!(
            "{0}x{0} px / {1}x{1} tiles",
            CACHE_TILE_GROUP * TILE,
            CACHE_TILE_GROUP
        ),
        "64x64 px"
    );
    println!(
        "{:<34} {:>14} {:>14}",
        "tag source bits per Gaussian ID",
        format!("[{}..{})", CACHE_ID_LO_BIT, CACHE_ID_LO_BIT + CACHE_ID_BITS),
        "3rd..18th LSB"
    );
    println!(
        "{:<34} {:>14} {:>14}",
        "gaussian feature stream",
        format!("{} B", GAUSSIAN_FEATURE_BYTES),
        "~40 B"
    );
    // Area: the paper's 1.05 mm^2 at 12 nm for 64 NRUs + SRAMs. We carry
    // the published figure (no RTL in this reproduction; DESIGN.md §8).
    println!(
        "{:<34} {:>14} {:>14}",
        "area (published, 12 nm)", "1.05 mm^2", "1.05 mm^2"
    );
    println!(
        "{:<34} {:>14} {:>14}",
        "SoC overhead (vs ~350 mm^2)", "~0.3%", "<0.4%"
    );
}
