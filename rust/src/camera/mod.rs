//! Camera substrate: intrinsics, SE(3) poses, and motion trajectories.
//!
//! Trajectories substitute for the paper's capture data (DESIGN.md §8):
//! a smooth VR head-motion model (~25 deg/s average rotation at 90 FPS,
//! matching the paper's Synthetic-NeRF VR simulation) and a slower,
//! noisier 30 FPS walk standing in for the Tanks&Temples video clips.

pub mod trajectory;

use crate::math::{Mat3, Quat, Vec3};

/// Pinhole camera intrinsics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Intrinsics {
    pub width: usize,
    pub height: usize,
    pub fx: f32,
    pub fy: f32,
    pub cx: f32,
    pub cy: f32,
}

impl Intrinsics {
    /// Square image with a given vertical field of view (radians).
    pub fn with_fov(width: usize, height: usize, fov_y: f32) -> Self {
        let fy = 0.5 * height as f32 / (0.5 * fov_y).tan();
        Intrinsics {
            width,
            height,
            fx: fy,
            fy,
            cx: width as f32 / 2.0,
            cy: height as f32 / 2.0,
        }
    }

    /// Number of 16x16 tiles in x and y (ceiling division).
    pub fn tiles(&self, tile: usize) -> (usize, usize) {
        (self.width.div_ceil(tile), self.height.div_ceil(tile))
    }
}

/// A camera pose: position + orientation (camera-to-world rotation).
///
/// Convention: the camera looks down its local +z axis; `rotation` maps
/// camera-space vectors to world space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pose {
    pub position: Vec3,
    pub rotation: Quat,
}

impl Pose {
    pub fn new(position: Vec3, rotation: Quat) -> Self {
        Pose { position, rotation }
    }

    /// Pose at `eye` looking at `target` with +y up.
    pub fn look_at(eye: Vec3, target: Vec3) -> Self {
        let fwd = (target - eye).normalized();
        let up = Vec3::new(0.0, 1.0, 0.0);
        let right = up.cross(fwd).normalized();
        let true_up = fwd.cross(right);
        // Camera-to-world: columns are the camera axes in world space.
        let m = Mat3::from_rows(
            [right.x, true_up.x, fwd.x],
            [right.y, true_up.y, fwd.y],
            [right.z, true_up.z, fwd.z],
        );
        Pose { position: eye, rotation: mat3_to_quat(&m) }
    }

    /// World-to-camera rotation matrix.
    pub fn world_to_cam(&self) -> Mat3 {
        self.rotation.to_mat3().transpose()
    }

    /// Transform a world point into camera space.
    #[inline]
    pub fn to_camera(&self, p: Vec3) -> Vec3 {
        self.world_to_cam().mul_vec(p - self.position)
    }

    /// Linear position + slerp rotation interpolation.
    pub fn interpolate(&self, other: &Pose, t: f32) -> Pose {
        Pose {
            position: self.position.lerp(other.position, t),
            rotation: self.rotation.slerp(other.rotation, t),
        }
    }

    /// Constant-velocity extrapolation used by S^2 speculative sorting
    /// (paper Eqns. 2-3): velocity from (prev -> cur), extrapolated
    /// `steps` frame intervals past `cur`. Rotation extrapolates by
    /// applying the inter-frame delta rotation `steps` times (slerp with
    /// t > 1 equivalent, numerically stabler stepwise).
    pub fn extrapolate(prev: &Pose, cur: &Pose, steps: f32) -> Pose {
        let vel = cur.position - prev.position;
        let position = cur.position + vel * steps;
        // Delta rotation prev -> cur.
        let delta = cur.rotation.mul(conjugate(prev.rotation)).normalized();
        let mut rotation = cur.rotation;
        let whole = steps.floor() as i32;
        for _ in 0..whole.max(0) {
            rotation = delta.mul(rotation).normalized();
        }
        let frac = steps - whole.max(0) as f32;
        if frac > 1e-6 {
            let next = delta.mul(rotation).normalized();
            rotation = rotation.slerp(next, frac);
        }
        Pose { position, rotation }
    }

    /// Angular distance to another pose's rotation, in radians.
    pub fn angular_distance(&self, other: &Pose) -> f32 {
        let a = self.rotation.normalized();
        let b = other.rotation.normalized();
        let dot = (a.w * b.w + a.x * b.x + a.y * b.y + a.z * b.z).abs().min(1.0);
        2.0 * dot.acos()
    }
}

fn conjugate(q: Quat) -> Quat {
    Quat::new(q.w, -q.x, -q.y, -q.z)
}

/// Shepperd's method: rotation matrix -> quaternion.
fn mat3_to_quat(m: &Mat3) -> Quat {
    let t = m.m[0][0] + m.m[1][1] + m.m[2][2];
    if t > 0.0 {
        let s = (t + 1.0).sqrt() * 2.0;
        Quat::new(
            0.25 * s,
            (m.m[2][1] - m.m[1][2]) / s,
            (m.m[0][2] - m.m[2][0]) / s,
            (m.m[1][0] - m.m[0][1]) / s,
        )
        .normalized()
    } else if m.m[0][0] > m.m[1][1] && m.m[0][0] > m.m[2][2] {
        let s = (1.0 + m.m[0][0] - m.m[1][1] - m.m[2][2]).sqrt() * 2.0;
        Quat::new(
            (m.m[2][1] - m.m[1][2]) / s,
            0.25 * s,
            (m.m[0][1] + m.m[1][0]) / s,
            (m.m[0][2] + m.m[2][0]) / s,
        )
        .normalized()
    } else if m.m[1][1] > m.m[2][2] {
        let s = (1.0 + m.m[1][1] - m.m[0][0] - m.m[2][2]).sqrt() * 2.0;
        Quat::new(
            (m.m[0][2] - m.m[2][0]) / s,
            (m.m[0][1] + m.m[1][0]) / s,
            0.25 * s,
            (m.m[1][2] + m.m[2][1]) / s,
        )
        .normalized()
    } else {
        let s = (1.0 + m.m[2][2] - m.m[0][0] - m.m[1][1]).sqrt() * 2.0;
        Quat::new(
            (m.m[1][0] - m.m[0][1]) / s,
            (m.m[0][2] + m.m[2][0]) / s,
            (m.m[1][2] + m.m[2][1]) / s,
            0.25 * s,
        )
        .normalized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn look_at_puts_target_on_axis() {
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let cam = pose.to_camera(Vec3::ZERO);
        assert!(cam.x.abs() < 1e-5 && cam.y.abs() < 1e-5);
        assert!((cam.z - 4.0).abs() < 1e-5);
    }

    #[test]
    fn to_camera_preserves_distance() {
        let pose = Pose::look_at(Vec3::new(1.0, 2.0, -3.0), Vec3::new(0.5, 0.0, 0.0));
        let p = Vec3::new(0.3, -0.8, 1.2);
        let cam = pose.to_camera(p);
        assert!((cam.norm() - (p - pose.position).norm()).abs() < 1e-4);
    }

    #[test]
    fn extrapolate_linear_position() {
        let p0 = Pose::new(Vec3::new(0.0, 0.0, 0.0), Quat::IDENTITY);
        let p1 = Pose::new(Vec3::new(0.1, 0.0, 0.0), Quat::IDENTITY);
        let pred = Pose::extrapolate(&p0, &p1, 3.0);
        assert!((pred.position.x - 0.4).abs() < 1e-6);
    }

    #[test]
    fn extrapolate_rotation_continues() {
        let step = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), 0.05);
        let p0 = Pose::new(Vec3::ZERO, Quat::IDENTITY);
        let p1 = Pose::new(Vec3::ZERO, step);
        let pred = Pose::extrapolate(&p0, &p1, 2.0);
        let expect = step.mul(step).mul(step); // identity + 3 steps total
        let d = pred.rotation.w * expect.w
            + pred.rotation.x * expect.x
            + pred.rotation.y * expect.y
            + pred.rotation.z * expect.z;
        assert!(d.abs() > 1.0 - 1e-4, "rotation extrapolation off: {d}");
    }

    #[test]
    fn angular_distance_symmetric() {
        let a = Pose::new(Vec3::ZERO, Quat::from_axis_angle(Vec3::new(1.0, 0.0, 0.0), 0.3));
        let b = Pose::new(Vec3::ZERO, Quat::from_axis_angle(Vec3::new(1.0, 0.0, 0.0), 0.8));
        assert!((a.angular_distance(&b) - 0.5).abs() < 1e-4);
        assert!((b.angular_distance(&a) - 0.5).abs() < 1e-4);
    }

    #[test]
    fn intrinsics_tiles_round_up() {
        let intr = Intrinsics::with_fov(100, 50, 0.8);
        assert_eq!(intr.tiles(16), (7, 4));
    }
}
