//! Camera-motion trajectory synthesis.
//!
//! Substitutes for the paper's pose sources (DESIGN.md §8):
//! * `vr_head_motion` — the paper simulates "a typical VR scenario with
//!   the average head rotation of 25 degrees [per second] at 90 FPS" for
//!   Synthetic-NeRF scenes.
//! * `walkthrough` — stands in for the 30 FPS Tanks&Temples video clips
//!   with COLMAP poses: slower, larger translation, mild jitter.
//! * `rapid_rotation` — the pathological case of Sec. 8 (fast head spin)
//!   used by failure-injection tests.
//! * `teleport` — dwell-and-jump inspection: instant relocations whose
//!   heading change (>= 1 rad) exceeds any realistic
//!   `pool.cluster_radius`, defeating both S² temporal coherence and
//!   pool-clustered sort sharing at every jump.
//! * `jittery_head_tracking` — the VR walk as a real tracker reports
//!   it: a smooth base path carrying per-frame zero-mean rotational
//!   tremor (the workload-harness pose family for head-mounted churn).

use super::Pose;
use crate::math::{Quat, Vec3};
use crate::util::prng::Pcg32;

/// Kind of synthetic camera trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrajectoryKind {
    /// 90 FPS VR head motion: ~25 deg/s average angular velocity, small
    /// positional sway (head-on-neck).
    VrHeadMotion,
    /// 30 FPS handheld walkthrough: dominant translation, slow pan.
    Walkthrough,
    /// Pathological rapid rotation (>200 deg/s bursts), Sec. 8.
    RapidRotation,
    /// 30 FPS dwell-and-jump inspection: slow pans punctuated by
    /// instant relocations with >= 1 rad heading changes — larger than
    /// any realistic `pool.cluster_radius`, so every jump breaks sort
    /// clusters and S² coherence.
    Teleport,
    /// 90 FPS VR head motion with per-frame rotational tremor — the
    /// pose stream a real head tracker reports.
    JitteryHeadTracking,
}

impl TrajectoryKind {
    /// Native frame rate of the trajectory class.
    pub fn fps(self) -> f64 {
        match self {
            TrajectoryKind::VrHeadMotion => 90.0,
            TrajectoryKind::Walkthrough => 30.0,
            TrajectoryKind::RapidRotation => 90.0,
            TrajectoryKind::Teleport => 30.0,
            TrajectoryKind::JitteryHeadTracking => 90.0,
        }
    }
}

/// A timed sequence of camera poses at a fixed frame rate.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub kind: TrajectoryKind,
    pub fps: f64,
    pub poses: Vec<Pose>,
}

impl Trajectory {
    pub fn len(&self) -> usize {
        self.poses.len()
    }

    pub fn is_empty(&self) -> bool {
        self.poses.is_empty()
    }

    /// Mean angular velocity across the trajectory, deg/s.
    pub fn mean_angular_velocity_deg(&self) -> f64 {
        if self.poses.len() < 2 {
            return 0.0;
        }
        let total: f32 = self
            .poses
            .windows(2)
            .map(|w| w[0].angular_distance(&w[1]))
            .sum();
        (total as f64).to_degrees() * self.fps / (self.poses.len() - 1) as f64
    }

    /// Mean translation speed, scene units/s.
    pub fn mean_speed(&self) -> f64 {
        if self.poses.len() < 2 {
            return 0.0;
        }
        let total: f32 = self
            .poses
            .windows(2)
            .map(|w| (w[1].position - w[0].position).norm())
            .sum();
        total as f64 * self.fps / (self.poses.len() - 1) as f64
    }
}

/// Generate a trajectory of `frames` poses orbiting/inspecting a scene of
/// half-extent `extent`, deterministic in `(kind, seed)`.
pub fn generate(kind: TrajectoryKind, seed: u64, frames: usize, extent: f32) -> Trajectory {
    let mut rng = Pcg32::new(seed, 0xC0FFEE);
    let fps = kind.fps();
    let dt = 1.0 / fps as f32;
    let radius = extent * 1.8;

    let mut poses = Vec::with_capacity(frames);
    match kind {
        TrajectoryKind::VrHeadMotion => {
            // Head yaw follows a band-limited random walk targeting
            // ~25 deg/s mean |angular velocity|; position sways slightly.
            let mut yaw = 0.0f32;
            let mut pitch = 0.0f32;
            let mut yaw_vel = 25f32.to_radians();
            let mut pitch_vel = 0.0f32;
            let base = Vec3::new(0.0, extent * 0.2, -radius);
            for i in 0..frames {
                // Ornstein-Uhlenbeck-ish velocity: keeps |v| near target.
                yaw_vel += (rng.f32() - 0.5) * 0.35 * dt * 60.0;
                yaw_vel = yaw_vel.clamp((-80f32).to_radians(), 80f32.to_radians());
                // Nudge magnitude back toward 25 deg/s.
                let target = 25f32.to_radians();
                let mag = yaw_vel.abs().max(1e-5);
                yaw_vel *= 1.0 + 0.25 * dt * (target - mag) / mag;
                pitch_vel += (rng.f32() - 0.5) * 0.12 * dt * 60.0;
                pitch_vel = pitch_vel.clamp((-20f32).to_radians(), 20f32.to_radians());
                yaw += yaw_vel * dt;
                pitch = (pitch + pitch_vel * dt).clamp(-0.4, 0.4);
                let sway = Vec3::new(
                    (i as f32 * 0.011).sin() * extent * 0.02,
                    (i as f32 * 0.017).sin() * extent * 0.012,
                    (i as f32 * 0.007).sin() * extent * 0.02,
                );
                let rot = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), yaw)
                    .mul(Quat::from_axis_angle(Vec3::new(1.0, 0.0, 0.0), pitch));
                let look = Pose::look_at(base + sway, Vec3::ZERO);
                poses.push(Pose::new(base + sway, rot.mul(look.rotation).normalized()));
            }
        }
        TrajectoryKind::Walkthrough => {
            // Slow arc around the scene with forward drift and hand jitter.
            let speed = extent * 0.12; // units/s
            let mut theta = rng.f32() * std::f32::consts::TAU;
            for _ in 0..frames {
                theta += speed * dt / radius;
                let jitter = Vec3::new(
                    (rng.f32() - 0.5) * extent * 0.004,
                    (rng.f32() - 0.5) * extent * 0.003,
                    (rng.f32() - 0.5) * extent * 0.004,
                );
                let eye = Vec3::new(
                    radius * theta.sin(),
                    extent * 0.25,
                    -radius * theta.cos(),
                ) + jitter;
                poses.push(Pose::look_at(eye, Vec3::new(0.0, extent * 0.1, 0.0)));
            }
        }
        TrajectoryKind::RapidRotation => {
            // Bursts above 200 deg/s interleaved with calm segments.
            let base = Vec3::new(0.0, extent * 0.2, -radius);
            let mut yaw = 0.0f32;
            for i in 0..frames {
                let burst = (i / 30) % 2 == 0;
                let v = if burst { 240f32 } else { 15f32 }.to_radians();
                yaw += v * dt;
                let rot = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), yaw);
                let look = Pose::look_at(base, Vec3::ZERO);
                poses.push(Pose::new(base, rot.mul(look.rotation).normalized()));
            }
        }
        TrajectoryKind::Teleport => {
            // Dwell-and-jump: `hop`-frame segments of slow pan on the
            // orbit, then an instant relocation with a heading change
            // drawn from [1, pi) rad — always beyond the default
            // cluster radius (0.35 rad), so a jump never lands a
            // session back in its old sort cluster.
            let hop = 12usize;
            let pan = 4f32.to_radians() * dt;
            let mut theta = rng.f32() * std::f32::consts::TAU;
            for i in 0..frames {
                if i > 0 && i % hop == 0 {
                    let sign = if rng.chance(0.5) { 1.0 } else { -1.0 };
                    theta += sign * rng.range_f32(1.0, std::f32::consts::PI);
                } else if i > 0 {
                    theta += pan;
                }
                let eye = Vec3::new(
                    radius * theta.sin(),
                    extent * 0.25,
                    -radius * theta.cos(),
                );
                poses.push(Pose::look_at(eye, Vec3::new(0.0, extent * 0.1, 0.0)));
            }
        }
        TrajectoryKind::JitteryHeadTracking => {
            // Smooth ~20 deg/s yaw walk carrying independent per-frame
            // tremor (~0.25 deg sigma): each delta stays far inside the
            // S^2 kill switch, but the measured angular velocity sits
            // well above the clean VR path.
            let base = Vec3::new(0.0, extent * 0.2, -radius);
            let mut yaw = 0.0f32;
            let mut pitch = 0.0f32;
            for i in 0..frames {
                yaw += 20f32.to_radians() * dt;
                pitch = (pitch + (rng.f32() - 0.5) * 0.02 * dt * 60.0).clamp(-0.3, 0.3);
                let jitter_yaw = rng.gauss() * 0.25f32.to_radians();
                let jitter_pitch = rng.gauss() * 0.18f32.to_radians();
                let sway = Vec3::new(
                    (i as f32 * 0.031).sin() * extent * 0.015,
                    (i as f32 * 0.043).sin() * extent * 0.01,
                    0.0,
                );
                let rot = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), yaw + jitter_yaw)
                    .mul(Quat::from_axis_angle(
                        Vec3::new(1.0, 0.0, 0.0),
                        pitch + jitter_pitch,
                    ));
                let look = Pose::look_at(base + sway, Vec3::ZERO);
                poses.push(Pose::new(base + sway, rot.mul(look.rotation).normalized()));
            }
        }
    }
    Trajectory { kind, fps, poses }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(TrajectoryKind::VrHeadMotion, 5, 60, 1.3);
        let b = generate(TrajectoryKind::VrHeadMotion, 5, 60, 1.3);
        assert_eq!(a.poses.len(), 60);
        for (x, y) in a.poses.iter().zip(&b.poses) {
            assert_eq!(x.position, y.position);
        }
    }

    #[test]
    fn vr_head_motion_near_25_deg_per_s() {
        let t = generate(TrajectoryKind::VrHeadMotion, 1, 900, 1.3);
        let v = t.mean_angular_velocity_deg();
        assert!(v > 12.0 && v < 45.0, "angular velocity {v} deg/s not VR-like");
    }

    #[test]
    fn walkthrough_translates() {
        let t = generate(TrajectoryKind::Walkthrough, 2, 300, 6.0);
        assert!(t.mean_speed() > 0.1);
        // Much slower rotation than VR.
        assert!(t.mean_angular_velocity_deg() < 15.0);
    }

    #[test]
    fn rapid_rotation_is_fast() {
        let t = generate(TrajectoryKind::RapidRotation, 3, 300, 1.3);
        assert!(t.mean_angular_velocity_deg() > 80.0);
    }

    #[test]
    fn teleport_jumps_exceed_cluster_radius_between_coherent_dwells() {
        let t = generate(TrajectoryKind::Teleport, 6, 120, 1.3);
        let deltas: Vec<f32> =
            t.poses.windows(2).map(|w| w[0].angular_distance(&w[1])).collect();
        let max = deltas.iter().cloned().fold(0.0f32, f32::max);
        assert!(max > 0.9, "teleport jump {max} rad must exceed any cluster radius");
        // Dwell frames dominate and stay coherent (S^2-friendly pans).
        let coherent = deltas.iter().filter(|&&d| d < 0.05).count();
        assert!(coherent * 2 > deltas.len(), "dwells must dominate: {coherent}/{}", deltas.len());
    }

    #[test]
    fn jittery_head_tracking_is_rougher_than_clean_vr() {
        let smooth = generate(TrajectoryKind::VrHeadMotion, 11, 600, 1.3);
        let jittery = generate(TrajectoryKind::JitteryHeadTracking, 11, 600, 1.3);
        assert!(
            jittery.mean_angular_velocity_deg() > smooth.mean_angular_velocity_deg() + 5.0,
            "tremor must raise measured angular velocity: jittery {} vs smooth {}",
            jittery.mean_angular_velocity_deg(),
            smooth.mean_angular_velocity_deg()
        );
        // Each tremor delta stays far inside the S^2 kill switch.
        for w in jittery.poses.windows(2) {
            assert!(w[0].angular_distance(&w[1]).to_degrees() < 5.0);
        }
    }

    #[test]
    fn consecutive_poses_are_close() {
        // S^2 relies on temporal coherence: inter-frame deltas stay small.
        let t = generate(TrajectoryKind::VrHeadMotion, 4, 300, 1.3);
        for w in t.poses.windows(2) {
            assert!(w[0].angular_distance(&w[1]).to_degrees() < 1.5);
            assert!((w[1].position - w[0].position).norm() < 0.05);
        }
    }
}
