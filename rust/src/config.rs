//! Run configuration: the TOML-backed config system of the launcher.
//!
//! A [`LuminaConfig`] fully determines a run: scene class/size, trajectory,
//! camera, algorithm parameters (S^2 window/margin, RC k), and which
//! hardware variant the simulator models. `configs/*.toml` hold the
//! presets used by the experiment harnesses; CLI `--set key=value`
//! overrides individual fields (dotted paths).

use anyhow::{bail, Context, Result};

use crate::camera::trajectory::TrajectoryKind;
use crate::constants::{
    DEFAULT_ALPHA_RECORD, DEFAULT_EXPANDED_MARGIN, DEFAULT_SHARING_WINDOW,
};
use crate::scene::synth::SceneClass;
use crate::util::minitoml::{self, Value};

/// Which hardware the cost models simulate (paper Sec. 5 "Variants").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HardwareVariant {
    /// Mobile Volta GPU baseline (full 3DGS on GPU).
    Gpu,
    /// S^2 algorithm on GPU, no radiance cache.
    S2Gpu,
    /// RC mechanism on GPU (slower than baseline; Sec. 6.2).
    RcGpu,
    /// GPU for Projection+Sorting, NRU for Rasterization, no cache, no S^2.
    NruGpu,
    /// S^2 on the accelerator, RC disabled.
    S2Acc,
    /// RC on the accelerator, S^2 disabled.
    RcAcc,
    /// Full Lumina: S^2 + RC + LuminCore.
    Lumina,
    /// GSCore comparator (CCU + GSU + GSCore rasterizer).
    GsCore,
    /// Lumina's baseline hardware hosted on GSCore's CCU/GSU frontend
    /// (Sec. 6.4 comparison).
    LuminaOnGscoreFrontend,
    /// DS-2 quality baseline (Fig. 20): full 3DGS pipeline at half
    /// resolution on the GPU, bilinearly upsampled 2x.
    Ds2Gpu,
}

impl HardwareVariant {
    /// True when the variant runs the S^2 sorting-sharing algorithm.
    pub fn uses_s2(self) -> bool {
        matches!(
            self,
            HardwareVariant::S2Gpu | HardwareVariant::S2Acc | HardwareVariant::Lumina
        )
    }

    /// True when the variant runs radiance caching.
    pub fn uses_rc(self) -> bool {
        matches!(
            self,
            HardwareVariant::RcGpu | HardwareVariant::RcAcc | HardwareVariant::Lumina
        )
    }

    /// True when rasterization runs on LuminCore NRUs.
    pub fn uses_nru(self) -> bool {
        matches!(
            self,
            HardwareVariant::NruGpu
                | HardwareVariant::S2Acc
                | HardwareVariant::RcAcc
                | HardwareVariant::Lumina
                | HardwareVariant::LuminaOnGscoreFrontend
        )
    }

    pub fn label(self) -> &'static str {
        match self {
            HardwareVariant::Gpu => "GPU",
            HardwareVariant::S2Gpu => "S2-GPU",
            HardwareVariant::RcGpu => "RC-GPU",
            HardwareVariant::NruGpu => "NRU+GPU",
            HardwareVariant::S2Acc => "S2-Acc",
            HardwareVariant::RcAcc => "RC-Acc",
            HardwareVariant::Lumina => "Lumina",
            HardwareVariant::GsCore => "GSCore",
            HardwareVariant::LuminaOnGscoreFrontend => "Lumina(CCU/GSU)",
            HardwareVariant::Ds2Gpu => "DS-2",
        }
    }

    /// Parse the kebab-case config name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gpu" => HardwareVariant::Gpu,
            "s2-gpu" => HardwareVariant::S2Gpu,
            "rc-gpu" => HardwareVariant::RcGpu,
            "nru-gpu" => HardwareVariant::NruGpu,
            "s2-acc" => HardwareVariant::S2Acc,
            "rc-acc" => HardwareVariant::RcAcc,
            "lumina" => HardwareVariant::Lumina,
            "gscore" => HardwareVariant::GsCore,
            "lumina-gscore-frontend" => HardwareVariant::LuminaOnGscoreFrontend,
            "ds2-gpu" => HardwareVariant::Ds2Gpu,
            other => bail!("unknown hardware variant: {other}"),
        })
    }

    /// Kebab-case config name.
    pub fn name(self) -> &'static str {
        match self {
            HardwareVariant::Gpu => "gpu",
            HardwareVariant::S2Gpu => "s2-gpu",
            HardwareVariant::RcGpu => "rc-gpu",
            HardwareVariant::NruGpu => "nru-gpu",
            HardwareVariant::S2Acc => "s2-acc",
            HardwareVariant::RcAcc => "rc-acc",
            HardwareVariant::Lumina => "lumina",
            HardwareVariant::GsCore => "gscore",
            HardwareVariant::LuminaOnGscoreFrontend => "lumina-gscore-frontend",
            HardwareVariant::Ds2Gpu => "ds2-gpu",
        }
    }

    /// All paper variants in evaluation order (Fig. 22).
    pub fn evaluation_set() -> [HardwareVariant; 7] {
        [
            HardwareVariant::Gpu,
            HardwareVariant::S2Gpu,
            HardwareVariant::RcGpu,
            HardwareVariant::NruGpu,
            HardwareVariant::S2Acc,
            HardwareVariant::RcAcc,
            HardwareVariant::Lumina,
        ]
    }
}

/// Per-session serving tier: the LoD/resolution ladder tiered pools
/// serve viewers on. `Ds2Raster` proved resolution is just a backend
/// policy (PR 1); a tier generalizes that into a per-session quality
/// level the admission controller can trade against pool capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Session resolution, whole scene — the quality reference.
    Full,
    /// Whole resolution, reduced Gaussian budget (a prefix subsample of
    /// the shared scene; fraction set by `pool.reduced_fraction`).
    Reduced,
    /// Half-resolution pipeline + 2x upsample (the DS-2 mechanism),
    /// composed around whatever raster backend the variant uses.
    Half,
}

impl Tier {
    pub fn label(self) -> &'static str {
        match self {
            Tier::Full => "full",
            Tier::Reduced => "reduced",
            Tier::Half => "half",
        }
    }

    /// Parse the kebab-case config name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "full" => Tier::Full,
            "reduced" => Tier::Reduced,
            "half" => Tier::Half,
            other => bail!("unknown tier: {other} (expected full|reduced|half)"),
        })
    }

    /// Parse a comma-separated tier ladder, best quality first. Blank
    /// segments are skipped; an all-blank ladder is an error.
    pub fn parse_ladder(s: &str) -> Result<Vec<Tier>> {
        let tiers = s
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(Tier::parse)
            .collect::<Result<Vec<_>>>()?;
        if tiers.is_empty() {
            bail!("tier ladder is empty");
        }
        Ok(tiers)
    }

    /// Serialize a ladder back to the comma-separated config form.
    pub fn ladder_name(ladder: &[Tier]) -> String {
        ladder.iter().map(|t| t.label()).collect::<Vec<_>>().join(",")
    }
}

/// Radiance-cache ownership across a pool's sessions (the
/// cache-topology seam of `lumina::rc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheScope {
    /// Each session owns its cache outright — the pre-sharing behavior.
    Private,
    /// One pool-wide snapshot/merge cache: sessions render epochs
    /// against a frozen shared snapshot and their insert deltas are
    /// merged at epoch boundaries in session-index order, so nearby
    /// viewers serve each other's hits deterministically.
    Shared,
    /// Pool-shared with world-space keys: the same epoch protocol as
    /// `shared`, but entries are keyed by quantized Gaussian world
    /// position + view-direction bucket in a fixed-size hash table
    /// (`pool.world_*` knobs), so they survive pose, tier, and
    /// resolution changes and every session shares one table.
    World,
}

impl CacheScope {
    pub fn label(self) -> &'static str {
        match self {
            CacheScope::Private => "private",
            CacheScope::Shared => "shared",
            CacheScope::World => "world",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "private" => CacheScope::Private,
            "shared" => CacheScope::Shared,
            "world" => CacheScope::World,
            other => bail!("unknown cache scope: {other} (expected private|shared|world)"),
        })
    }

    /// Whether sessions render against pool-shared cache state (either
    /// key scheme) — the scopes that need the hub + epoch merge.
    pub fn is_pooled(self) -> bool {
        matches!(self, CacheScope::Shared | CacheScope::World)
    }
}

/// Speculative-sort ownership across a pool's sessions (the
/// sort-topology seam of `lumina::s2`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortScope {
    /// Each S² session runs its own windowed speculative sort — the
    /// pre-sharing behavior, bit-for-bit.
    Private,
    /// Pool-clustered sorting: at every epoch boundary the pool groups
    /// sessions by sort geometry and predicted-pose proximity
    /// (`pool.cluster_radius`), computes one speculative sort per
    /// cluster (leader = lowest session index), and every member
    /// renders the epoch against the frozen shared sort while still
    /// refreshing colors/geometry at its own pose.
    Clustered,
}

impl SortScope {
    pub fn label(self) -> &'static str {
        match self {
            SortScope::Private => "private",
            SortScope::Clustered => "clustered",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "private" => SortScope::Private,
            "clustered" => SortScope::Clustered,
            other => bail!("unknown sort scope: {other} (expected private|clustered)"),
        })
    }
}

/// How a pool's epoch work is distributed across worker threads (the
/// scheduling seam of `coordinator::steal`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Per-session outer workers: sessions are partitioned across the
    /// thread budget and each worker drives its slice serially — the
    /// pre-stealing behavior, bit-for-bit.
    Session,
    /// Pool-wide deterministic work stealing: every session's dispatch
    /// expands into stage tasks (frontend step, raster plan) claimed by
    /// a fixed worker pool in static task-ID priority order, so an idle
    /// worker runs another session's stage instead of waiting. Output
    /// is bitwise identical to `session` — results merge in (session
    /// index, frame, chunk) order, never completion order.
    Stealing,
}

impl SchedulerMode {
    pub fn label(self) -> &'static str {
        match self {
            SchedulerMode::Session => "session",
            SchedulerMode::Stealing => "stealing",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "session" => SchedulerMode::Session,
            "stealing" => SchedulerMode::Stealing,
            other => bail!("unknown scheduler mode: {other} (expected session|stealing)"),
        })
    }
}

/// How the admission controller prices tier-ladder rungs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PricingMode {
    /// Re-grid the full per-pixel workload record at every rung
    /// (O(pixels) per rung) — the reference path.
    Exact,
    /// Price rungs from an O(tiles) per-tile aggregate built once per
    /// session (uniform-within-tile assumption, conservative maxima) —
    /// keeps epoch re-plans cheap at high resolutions.
    Aggregate,
}

impl PricingMode {
    pub fn label(self) -> &'static str {
        match self {
            PricingMode::Exact => "exact",
            PricingMode::Aggregate => "aggregate",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "exact" => PricingMode::Exact,
            "aggregate" => PricingMode::Aggregate,
            other => bail!("unknown pricing mode: {other} (expected exact|aggregate)"),
        })
    }
}

/// Multi-session pool block: tier ladder + admission-control target.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Aggregate simulated-FPS target the admission controller holds
    /// across the whole pool (the modeled device must deliver one frame
    /// to *every* session at this rate). `0` disables admission control.
    pub target_fps: f64,
    /// Tier ladder, best quality first; demotion walks down it.
    pub tiers: Vec<Tier>,
    /// Frames between admission re-plans in `SessionPool::serve`.
    pub epoch_frames: usize,
    /// Fraction of the scene's Gaussians the reduced tier serves.
    pub reduced_fraction: f64,
    /// Frame slots per session: 1 = synchronous stepping (the
    /// determinism baseline), 2 = double-buffered — frame N+1's frontend
    /// (projection + speculative sort) overlaps frame N's rasterization
    /// and the pool schedules *stages* instead of whole sessions, 3 =
    /// chunk-interleaved — two frames in flight, their rasterization
    /// dispatched at `raster_substages` tile-range granularity.
    pub pipeline_depth: usize,
    /// Raster sub-stages each frame splits into under pipelining (the
    /// `RasterChunk` granularity; meaningful at `pipeline_depth = 3`,
    /// where it should be at least `pipeline_depth - 1`).
    pub raster_substages: usize,
    /// Admission rung-pricing path (exact per-pixel vs O(tiles)
    /// aggregate).
    pub pricing: PricingMode,
    /// Radiance-cache ownership: `private` (per-session caches, the
    /// pre-sharing behavior) or `shared` (one pool-wide snapshot/merge
    /// cache; only meaningful on RC variants). Shared pools run in
    /// epochs of `epoch_frames` even outside admission control, since
    /// the epoch boundary is where deltas merge.
    pub cache_scope: CacheScope,
    /// Speculative-sort ownership: `private` (per-session windowed S²,
    /// the pre-sharing behavior) or `clustered` (one pool-wide sort per
    /// pose cluster per epoch; only meaningful on S² variants).
    /// Clustered pools run in epochs of `epoch_frames` even outside
    /// admission control — the boundary is where clusters re-form and
    /// sorts re-publish.
    pub sort_scope: SortScope,
    /// Maximum angular distance (radians) between two sessions'
    /// predicted sort poses for them to share one cluster sort.
    pub cluster_radius: f64,
    /// Maximum positional distance (world units) between two sessions'
    /// predicted sort poses for them to share one cluster sort — the
    /// translation-aware gate: distant viewers with parallel gaze must
    /// not cluster (their tile lists differ even though their view
    /// directions match). The default is generous enough that co-orbiting
    /// pools keep clustering; tighten it for scenes where viewers roam.
    pub cluster_position_radius: f64,
    /// World-scope cache: fixed hash-table size in cells.
    pub world_cells: usize,
    /// World-scope cache: positional cell edge (world units) before
    /// distance LOD scaling.
    pub world_cell_size: f64,
    /// World-scope cache: distance at which positional cells start
    /// doubling (LOD pivot).
    pub world_lod_distance: f64,
    /// World-scope cache: full cell lifetime in pool epochs (decay
    /// eviction reclaims cells that go this many epochs without a hit).
    pub world_lifetime: usize,
    /// World-scope cache: bounded linear-probe chain length on slot
    /// collision (also the shared-lookup contention multiplier the cost
    /// models charge).
    pub world_probe_len: usize,
    /// World-scope cache: per-axis view-direction buckets of the key.
    pub world_dir_buckets: usize,
    /// Epoch scheduling policy: `session` (per-session outer workers,
    /// the pre-stealing behavior) or `stealing` (pool-wide
    /// deterministic stage-task claiming — idle workers run other
    /// sessions' stages). Both produce bitwise-identical output.
    pub scheduler: SchedulerMode,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            target_fps: 0.0,
            tiers: vec![Tier::Full, Tier::Reduced, Tier::Half],
            epoch_frames: 6,
            reduced_fraction: 0.5,
            pipeline_depth: 1,
            raster_substages: crate::pipeline::stage::DEFAULT_RASTER_SUBSTAGES,
            pricing: PricingMode::Exact,
            cache_scope: CacheScope::Private,
            sort_scope: SortScope::Private,
            cluster_radius: 0.35,
            cluster_position_radius: 16.0,
            world_cells: 65_536,
            world_cell_size: 0.25,
            world_lod_distance: 4.0,
            world_lifetime: 30,
            world_probe_len: 3,
            world_dir_buckets: 4,
            scheduler: SchedulerMode::Session,
        }
    }
}

fn scene_class_name(c: SceneClass) -> &'static str {
    match c {
        SceneClass::SyntheticSmall => "synthetic-small",
        SceneClass::RealMedium => "real-medium",
        SceneClass::RealIndoor => "real-indoor",
        SceneClass::RealUnbounded => "real-unbounded",
    }
}

fn parse_scene_class(s: &str) -> Result<SceneClass> {
    Ok(match s {
        "synthetic-small" => SceneClass::SyntheticSmall,
        "real-medium" => SceneClass::RealMedium,
        "real-indoor" => SceneClass::RealIndoor,
        "real-unbounded" => SceneClass::RealUnbounded,
        other => bail!("unknown scene class: {other}"),
    })
}

fn trajectory_name(t: TrajectoryKind) -> &'static str {
    match t {
        TrajectoryKind::VrHeadMotion => "vr-head-motion",
        TrajectoryKind::Walkthrough => "walkthrough",
        TrajectoryKind::RapidRotation => "rapid-rotation",
        TrajectoryKind::Teleport => "teleport",
        TrajectoryKind::JitteryHeadTracking => "jittery-head-tracking",
    }
}

fn parse_trajectory(s: &str) -> Result<TrajectoryKind> {
    Ok(match s {
        "vr-head-motion" => TrajectoryKind::VrHeadMotion,
        "walkthrough" => TrajectoryKind::Walkthrough,
        "rapid-rotation" => TrajectoryKind::RapidRotation,
        "teleport" => TrajectoryKind::Teleport,
        "jittery-head-tracking" => TrajectoryKind::JitteryHeadTracking,
        other => bail!("unknown trajectory kind: {other}"),
    })
}

/// Scene block of the config.
#[derive(Debug, Clone)]
pub struct SceneConfig {
    pub class: SceneClass,
    /// Gaussian count; 0 = the class default (paper-scale).
    pub count: usize,
    pub seed: u64,
    /// Optional LGSC file to load instead of synthesizing.
    pub path: Option<String>,
}

/// Camera/trajectory block.
#[derive(Debug, Clone)]
pub struct CameraConfig {
    pub width: usize,
    pub height: usize,
    /// Vertical field of view in degrees.
    pub fov_deg: f32,
    pub trajectory: TrajectoryKind,
    pub frames: usize,
    pub seed: u64,
}

/// S^2 algorithm block (paper Sec. 3.1).
#[derive(Debug, Clone)]
pub struct S2Config {
    pub sharing_window: usize,
    /// Expanded viewport margin in pixels per dimension.
    pub expanded_margin: usize,
}

impl Default for S2Config {
    fn default() -> Self {
        S2Config {
            sharing_window: DEFAULT_SHARING_WINDOW,
            expanded_margin: DEFAULT_EXPANDED_MARGIN,
        }
    }
}

/// Radiance-cache block (paper Sec. 3.2 + Sec. 5).
#[derive(Debug, Clone)]
pub struct RcConfig {
    /// Alpha-record length k: significant-Gaussian IDs per tag.
    pub alpha_record: usize,
}

impl Default for RcConfig {
    fn default() -> Self {
        RcConfig { alpha_record: DEFAULT_ALPHA_RECORD }
    }
}

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct LuminaConfig {
    pub scene: SceneConfig,
    pub camera: CameraConfig,
    pub s2: S2Config,
    pub rc: RcConfig,
    pub pool: PoolConfig,
    pub variant: HardwareVariant,
    /// Near clip plane.
    pub near: f32,
    /// Far clip plane.
    pub far: f32,
}

impl LuminaConfig {
    /// A fast default config for tests and the quickstart example.
    pub fn quick_test() -> Self {
        LuminaConfig {
            scene: SceneConfig {
                class: SceneClass::SyntheticSmall,
                count: 20_000,
                seed: 42,
                path: None,
            },
            camera: CameraConfig {
                width: 256,
                height: 256,
                fov_deg: 50.0,
                trajectory: TrajectoryKind::VrHeadMotion,
                frames: 24,
                seed: 42,
            },
            s2: S2Config::default(),
            rc: RcConfig::default(),
            pool: PoolConfig::default(),
            variant: HardwareVariant::Lumina,
            near: 0.2,
            far: 1000.0,
        }
    }

    /// Parse from a TOML string (missing fields take defaults).
    pub fn from_toml(s: &str) -> Result<Self> {
        let root = minitoml::parse(s).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        Self::from_value(&root)
    }

    fn from_value(root: &Value) -> Result<Self> {
        let mut cfg = Self::quick_test();
        if let Some(v) = root.get_path("variant") {
            cfg.variant =
                HardwareVariant::parse(v.as_str().context("variant must be a string")?)?;
        }
        if let Some(v) = root.get_path("near") {
            cfg.near = v.as_float().context("near must be a number")? as f32;
        }
        if let Some(v) = root.get_path("far") {
            cfg.far = v.as_float().context("far must be a number")? as f32;
        }
        if let Some(v) = root.get_path("scene.class") {
            cfg.scene.class = parse_scene_class(v.as_str().context("scene.class")?)?;
            // A class change without explicit count means class default.
            cfg.scene.count = 0;
        }
        if let Some(v) = root.get_path("scene.count") {
            cfg.scene.count = v.as_int().context("scene.count")? as usize;
        }
        if let Some(v) = root.get_path("scene.seed") {
            cfg.scene.seed = v.as_int().context("scene.seed")? as u64;
        }
        if let Some(v) = root.get_path("scene.path") {
            cfg.scene.path = Some(v.as_str().context("scene.path")?.to_string());
        }
        if let Some(v) = root.get_path("camera.width") {
            cfg.camera.width = v.as_int().context("camera.width")? as usize;
        }
        if let Some(v) = root.get_path("camera.height") {
            cfg.camera.height = v.as_int().context("camera.height")? as usize;
        }
        if let Some(v) = root.get_path("camera.fov_deg") {
            cfg.camera.fov_deg = v.as_float().context("camera.fov_deg")? as f32;
        }
        if let Some(v) = root.get_path("camera.trajectory") {
            cfg.camera.trajectory = parse_trajectory(v.as_str().context("camera.trajectory")?)?;
        }
        if let Some(v) = root.get_path("camera.frames") {
            cfg.camera.frames = v.as_int().context("camera.frames")? as usize;
        }
        if let Some(v) = root.get_path("camera.seed") {
            cfg.camera.seed = v.as_int().context("camera.seed")? as u64;
        }
        if let Some(v) = root.get_path("s2.sharing_window") {
            cfg.s2.sharing_window = v.as_int().context("s2.sharing_window")? as usize;
        }
        if let Some(v) = root.get_path("s2.expanded_margin") {
            cfg.s2.expanded_margin = v.as_int().context("s2.expanded_margin")? as usize;
        }
        if let Some(v) = root.get_path("rc.alpha_record") {
            let k = v.as_int().context("rc.alpha_record")? as usize;
            if k == 0 || k > crate::pipeline::raster::MAX_SIG_K {
                bail!(
                    "rc.alpha_record must be 1..={}, got {k}",
                    crate::pipeline::raster::MAX_SIG_K
                );
            }
            cfg.rc.alpha_record = k;
        }
        if let Some(v) = root.get_path("pool.target_fps") {
            let t = v.as_float().context("pool.target_fps must be a number")?;
            if t < 0.0 || !t.is_finite() {
                bail!("pool.target_fps must be finite and >= 0, got {t}");
            }
            cfg.pool.target_fps = t;
        }
        if let Some(v) = root.get_path("pool.tiers") {
            let ladder = v.as_str().context("pool.tiers must be a string")?;
            cfg.pool.tiers = Tier::parse_ladder(ladder)?;
        }
        if let Some(v) = root.get_path("pool.epoch_frames") {
            let e = v.as_int().context("pool.epoch_frames")?;
            if e < 1 {
                bail!("pool.epoch_frames must be >= 1, got {e}");
            }
            cfg.pool.epoch_frames = e as usize;
        }
        if let Some(v) = root.get_path("pool.reduced_fraction") {
            let f = v.as_float().context("pool.reduced_fraction must be a number")?;
            if !(f > 0.0 && f <= 1.0) {
                bail!("pool.reduced_fraction must be in (0, 1], got {f}");
            }
            cfg.pool.reduced_fraction = f;
        }
        if let Some(v) = root.get_path("pool.pipeline_depth") {
            let d = v.as_int().context("pool.pipeline_depth")?;
            if !(1..=3).contains(&d) {
                bail!(
                    "pool.pipeline_depth must be 1 (synchronous), 2 \
                     (double-buffered), or 3 (chunk-interleaved), got {d}"
                );
            }
            cfg.pool.pipeline_depth = d as usize;
        }
        if let Some(v) = root.get_path("pool.raster_substages") {
            let s = v.as_int().context("pool.raster_substages")?;
            if s < 1 {
                bail!("pool.raster_substages must be >= 1, got {s}");
            }
            cfg.pool.raster_substages = s as usize;
        }
        if let Some(v) = root.get_path("pool.pricing") {
            cfg.pool.pricing =
                PricingMode::parse(v.as_str().context("pool.pricing must be a string")?)?;
        }
        if let Some(v) = root.get_path("pool.cache_scope") {
            cfg.pool.cache_scope =
                CacheScope::parse(v.as_str().context("pool.cache_scope must be a string")?)?;
        }
        if let Some(v) = root.get_path("pool.sort_scope") {
            cfg.pool.sort_scope =
                SortScope::parse(v.as_str().context("pool.sort_scope must be a string")?)?;
        }
        if let Some(v) = root.get_path("pool.cluster_radius") {
            let r = v.as_float().context("pool.cluster_radius must be a number")?;
            if !(r > 0.0) || !r.is_finite() {
                bail!("pool.cluster_radius must be finite and > 0, got {r}");
            }
            cfg.pool.cluster_radius = r;
        }
        if let Some(v) = root.get_path("pool.cluster_position_radius") {
            let r = v.as_float().context("pool.cluster_position_radius must be a number")?;
            if !(r > 0.0) || !r.is_finite() {
                bail!("pool.cluster_position_radius must be finite and > 0, got {r}");
            }
            cfg.pool.cluster_position_radius = r;
        }
        if let Some(v) = root.get_path("pool.world_cells") {
            let c = v.as_int().context("pool.world_cells")?;
            if c < 1 {
                bail!("pool.world_cells must be >= 1, got {c}");
            }
            cfg.pool.world_cells = c as usize;
        }
        if let Some(v) = root.get_path("pool.world_cell_size") {
            let s = v.as_float().context("pool.world_cell_size must be a number")?;
            if !(s > 0.0) || !s.is_finite() {
                bail!("pool.world_cell_size must be finite and > 0, got {s}");
            }
            cfg.pool.world_cell_size = s;
        }
        if let Some(v) = root.get_path("pool.world_lod_distance") {
            let d = v.as_float().context("pool.world_lod_distance must be a number")?;
            if !(d > 0.0) || !d.is_finite() {
                bail!("pool.world_lod_distance must be finite and > 0, got {d}");
            }
            cfg.pool.world_lod_distance = d;
        }
        if let Some(v) = root.get_path("pool.world_lifetime") {
            let l = v.as_int().context("pool.world_lifetime")?;
            if !(1..=i64::from(u16::MAX)).contains(&l) {
                bail!("pool.world_lifetime must be 1..={}, got {l}", u16::MAX);
            }
            cfg.pool.world_lifetime = l as usize;
        }
        if let Some(v) = root.get_path("pool.world_probe_len") {
            let p = v.as_int().context("pool.world_probe_len")?;
            if !(1..=256).contains(&p) {
                bail!("pool.world_probe_len must be 1..=256, got {p}");
            }
            cfg.pool.world_probe_len = p as usize;
        }
        if let Some(v) = root.get_path("pool.world_dir_buckets") {
            let b = v.as_int().context("pool.world_dir_buckets")?;
            if !(1..=256).contains(&b) {
                bail!("pool.world_dir_buckets must be 1..=256, got {b}");
            }
            cfg.pool.world_dir_buckets = b as usize;
        }
        if let Some(v) = root.get_path("pool.scheduler") {
            cfg.pool.scheduler =
                SchedulerMode::parse(v.as_str().context("pool.scheduler must be a string")?)?;
        }
        Ok(cfg)
    }

    /// Serialize to TOML text.
    pub fn to_toml(&self) -> String {
        let mut root = Value::Table(Default::default());
        let set = |root: &mut Value, k: &str, v: Value| {
            root.set_path(k, v).expect("set_path on fresh table");
        };
        set(&mut root, "variant", Value::String(self.variant.name().into()));
        set(&mut root, "near", Value::Float(self.near as f64));
        set(&mut root, "far", Value::Float(self.far as f64));
        set(&mut root, "scene.class", Value::String(scene_class_name(self.scene.class).into()));
        set(&mut root, "scene.count", Value::Integer(self.scene.count as i64));
        set(&mut root, "scene.seed", Value::Integer(self.scene.seed as i64));
        if let Some(p) = &self.scene.path {
            set(&mut root, "scene.path", Value::String(p.clone()));
        }
        set(&mut root, "camera.width", Value::Integer(self.camera.width as i64));
        set(&mut root, "camera.height", Value::Integer(self.camera.height as i64));
        set(&mut root, "camera.fov_deg", Value::Float(self.camera.fov_deg as f64));
        set(
            &mut root,
            "camera.trajectory",
            Value::String(trajectory_name(self.camera.trajectory).into()),
        );
        set(&mut root, "camera.frames", Value::Integer(self.camera.frames as i64));
        set(&mut root, "camera.seed", Value::Integer(self.camera.seed as i64));
        set(&mut root, "s2.sharing_window", Value::Integer(self.s2.sharing_window as i64));
        set(&mut root, "s2.expanded_margin", Value::Integer(self.s2.expanded_margin as i64));
        set(&mut root, "rc.alpha_record", Value::Integer(self.rc.alpha_record as i64));
        set(&mut root, "pool.target_fps", Value::Float(self.pool.target_fps));
        set(&mut root, "pool.tiers", Value::String(Tier::ladder_name(&self.pool.tiers)));
        set(&mut root, "pool.epoch_frames", Value::Integer(self.pool.epoch_frames as i64));
        set(&mut root, "pool.reduced_fraction", Value::Float(self.pool.reduced_fraction));
        set(
            &mut root,
            "pool.pipeline_depth",
            Value::Integer(self.pool.pipeline_depth as i64),
        );
        set(
            &mut root,
            "pool.raster_substages",
            Value::Integer(self.pool.raster_substages as i64),
        );
        set(&mut root, "pool.pricing", Value::String(self.pool.pricing.label().into()));
        set(
            &mut root,
            "pool.cache_scope",
            Value::String(self.pool.cache_scope.label().into()),
        );
        set(
            &mut root,
            "pool.sort_scope",
            Value::String(self.pool.sort_scope.label().into()),
        );
        set(&mut root, "pool.cluster_radius", Value::Float(self.pool.cluster_radius));
        set(
            &mut root,
            "pool.cluster_position_radius",
            Value::Float(self.pool.cluster_position_radius),
        );
        set(&mut root, "pool.world_cells", Value::Integer(self.pool.world_cells as i64));
        set(&mut root, "pool.world_cell_size", Value::Float(self.pool.world_cell_size));
        set(
            &mut root,
            "pool.world_lod_distance",
            Value::Float(self.pool.world_lod_distance),
        );
        set(&mut root, "pool.world_lifetime", Value::Integer(self.pool.world_lifetime as i64));
        set(&mut root, "pool.world_probe_len", Value::Integer(self.pool.world_probe_len as i64));
        set(
            &mut root,
            "pool.world_dir_buckets",
            Value::Integer(self.pool.world_dir_buckets as i64),
        );
        set(
            &mut root,
            "pool.scheduler",
            Value::String(self.pool.scheduler.label().into()),
        );
        minitoml::serialize(&root)
    }

    /// Load from a TOML file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_toml(
            &std::fs::read_to_string(path.as_ref())
                .with_context(|| format!("reading config {:?}", path.as_ref()))?,
        )
    }

    /// Apply a `section.key=value` override.
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let (key, value) = spec
            .split_once('=')
            .with_context(|| format!("override must be key=value: {spec}"))?;
        // Round-trip through the TOML tree to reuse the typed parser.
        let mut root =
            minitoml::parse(&self.to_toml()).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        if root.get_path(key).is_none() {
            bail!("unknown config key: {key}");
        }
        let parsed = value
            .parse::<i64>()
            .map(Value::Integer)
            .or_else(|_| value.parse::<f64>().map(Value::Float))
            .or_else(|_| value.parse::<bool>().map(Value::Boolean))
            .unwrap_or_else(|_| Value::String(value.to_string()));
        root.set_path(key, parsed)
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        *self = Self::from_value(&root)?;
        Ok(())
    }

    /// Effective Gaussian count (0 = class default).
    pub fn gaussian_count(&self) -> usize {
        if self.scene.count == 0 {
            self.scene.class.default_count()
        } else {
            self.scene.count
        }
    }

    /// Camera intrinsics implied by the config.
    pub fn intrinsics(&self) -> crate::camera::Intrinsics {
        crate::camera::Intrinsics::with_fov(
            self.camera.width,
            self.camera.height,
            self.camera.fov_deg.to_radians(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_test_valid() {
        let c = LuminaConfig::quick_test();
        assert_eq!(c.s2.sharing_window, 6);
        assert_eq!(c.rc.alpha_record, 5);
    }

    #[test]
    fn toml_roundtrip() {
        let c = LuminaConfig::quick_test();
        let s = c.to_toml();
        let back = LuminaConfig::from_toml(&s).unwrap();
        assert_eq!(back.scene.count, c.scene.count);
        assert_eq!(back.variant, c.variant);
        assert_eq!(back.camera.trajectory, c.camera.trajectory);
    }

    #[test]
    fn minimal_toml_uses_defaults() {
        let c = LuminaConfig::from_toml(
            r#"
            variant = "gpu"
            [scene]
            class = "synthetic-small"
            [camera]
            trajectory = "vr-head-motion"
            "#,
        )
        .unwrap();
        assert_eq!(c.variant, HardwareVariant::Gpu);
        assert_eq!(c.s2.sharing_window, 6);
        assert_eq!(c.camera.width, 256);
        assert_eq!(c.gaussian_count(), 300_000);
    }

    #[test]
    fn workload_trajectories_roundtrip() {
        for kind in [TrajectoryKind::Teleport, TrajectoryKind::JitteryHeadTracking] {
            let mut c = LuminaConfig::quick_test();
            c.camera.trajectory = kind;
            let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
            assert_eq!(back.camera.trajectory, kind);
        }
        assert!(parse_trajectory("teleport").is_ok());
        assert!(parse_trajectory("jittery-head-tracking").is_ok());
        assert!(parse_trajectory("orbit-of-nowhere").is_err());
    }

    #[test]
    fn override_applies() {
        let mut c = LuminaConfig::quick_test();
        c.apply_override("s2.sharing_window=12").unwrap();
        assert_eq!(c.s2.sharing_window, 12);
        c.apply_override("rc.alpha_record=3").unwrap();
        assert_eq!(c.rc.alpha_record, 3);
        c.apply_override("scene.count=999").unwrap();
        assert_eq!(c.scene.count, 999);
        c.apply_override("variant=rc-acc").unwrap();
        assert_eq!(c.variant, HardwareVariant::RcAcc);
    }

    #[test]
    fn override_rejects_garbage() {
        let mut c = LuminaConfig::quick_test();
        assert!(c.apply_override("nonsense").is_err());
        assert!(c.apply_override("does.not.exist=1").is_err());
        assert!(c.apply_override("rc.alpha_record=99").is_err());
    }

    #[test]
    fn pool_section_roundtrips_and_validates() {
        let mut c = LuminaConfig::quick_test();
        assert_eq!(c.pool.target_fps, 0.0);
        assert_eq!(c.pool.tiers, vec![Tier::Full, Tier::Reduced, Tier::Half]);
        c.pool.target_fps = 45.0;
        c.pool.tiers = vec![Tier::Full, Tier::Half];
        c.pool.epoch_frames = 3;
        c.pool.reduced_fraction = 0.25;
        let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.pool.target_fps, 45.0);
        assert_eq!(back.pool.tiers, vec![Tier::Full, Tier::Half]);
        assert_eq!(back.pool.epoch_frames, 3);
        assert_eq!(back.pool.reduced_fraction, 0.25);

        let mut c = LuminaConfig::quick_test();
        c.apply_override("pool.target_fps=60").unwrap();
        assert_eq!(c.pool.target_fps, 60.0);
        c.apply_override("pool.tiers=full,half").unwrap();
        assert_eq!(c.pool.tiers, vec![Tier::Full, Tier::Half]);
        assert!(c.apply_override("pool.reduced_fraction=1.5").is_err());
        assert!(c.apply_override("pool.epoch_frames=0").is_err());
        assert!(c.apply_override("pool.epoch_frames=-1").is_err());
        assert!(c.apply_override("pool.tiers=full,bogus").is_err());
    }

    #[test]
    fn pipeline_depth_and_pricing_roundtrip_and_validate() {
        let mut c = LuminaConfig::quick_test();
        assert_eq!(c.pool.pipeline_depth, 1, "synchronous by default");
        assert_eq!(c.pool.pricing, PricingMode::Exact);
        c.apply_override("pool.pipeline_depth=2").unwrap();
        assert_eq!(c.pool.pipeline_depth, 2);
        c.apply_override("pool.pipeline_depth=3").unwrap();
        assert_eq!(c.pool.pipeline_depth, 3);
        assert_eq!(
            c.pool.raster_substages,
            crate::pipeline::stage::DEFAULT_RASTER_SUBSTAGES,
            "sub-stage default"
        );
        c.apply_override("pool.raster_substages=6").unwrap();
        assert_eq!(c.pool.raster_substages, 6);
        c.apply_override("pool.pricing=aggregate").unwrap();
        assert_eq!(c.pool.pricing, PricingMode::Aggregate);
        let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.pool.pipeline_depth, 3);
        assert_eq!(back.pool.raster_substages, 6);
        assert_eq!(back.pool.pricing, PricingMode::Aggregate);
        assert!(c.apply_override("pool.pipeline_depth=0").is_err());
        assert!(c.apply_override("pool.pipeline_depth=4").is_err());
        assert!(c.apply_override("pool.raster_substages=0").is_err());
        assert!(c.apply_override("pool.pricing=bogus").is_err());
        for m in [PricingMode::Exact, PricingMode::Aggregate] {
            assert_eq!(PricingMode::parse(m.label()).unwrap(), m);
        }
    }

    #[test]
    fn cache_scope_roundtrips_and_validates() {
        let mut c = LuminaConfig::quick_test();
        assert_eq!(c.pool.cache_scope, CacheScope::Private, "private by default");
        c.apply_override("pool.cache_scope=shared").unwrap();
        assert_eq!(c.pool.cache_scope, CacheScope::Shared);
        let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.pool.cache_scope, CacheScope::Shared);
        assert!(c.apply_override("pool.cache_scope=bogus").is_err());
        for s in [CacheScope::Private, CacheScope::Shared, CacheScope::World] {
            assert_eq!(CacheScope::parse(s.label()).unwrap(), s);
        }
        assert!(!CacheScope::Private.is_pooled());
        assert!(CacheScope::Shared.is_pooled());
        assert!(CacheScope::World.is_pooled());
    }

    #[test]
    fn world_cache_knobs_roundtrip_and_validate() {
        let mut c = LuminaConfig::quick_test();
        assert_eq!(c.pool.world_cells, 65_536);
        assert_eq!(c.pool.world_probe_len, 3);
        c.apply_override("pool.cache_scope=world").unwrap();
        assert_eq!(c.pool.cache_scope, CacheScope::World);
        c.apply_override("pool.world_cells=1024").unwrap();
        c.apply_override("pool.world_cell_size=0.5").unwrap();
        c.apply_override("pool.world_lod_distance=8.0").unwrap();
        c.apply_override("pool.world_lifetime=12").unwrap();
        c.apply_override("pool.world_probe_len=5").unwrap();
        c.apply_override("pool.world_dir_buckets=8").unwrap();
        c.apply_override("pool.cluster_position_radius=3.5").unwrap();
        let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.pool.cache_scope, CacheScope::World);
        assert_eq!(back.pool.world_cells, 1024);
        assert_eq!(back.pool.world_cell_size, 0.5);
        assert_eq!(back.pool.world_lod_distance, 8.0);
        assert_eq!(back.pool.world_lifetime, 12);
        assert_eq!(back.pool.world_probe_len, 5);
        assert_eq!(back.pool.world_dir_buckets, 8);
        assert_eq!(back.pool.cluster_position_radius, 3.5);
        assert!(c.apply_override("pool.world_cells=0").is_err());
        assert!(c.apply_override("pool.world_cell_size=0").is_err());
        assert!(c.apply_override("pool.world_lod_distance=-1").is_err());
        assert!(c.apply_override("pool.world_lifetime=0").is_err());
        assert!(c.apply_override("pool.world_lifetime=70000").is_err());
        assert!(c.apply_override("pool.world_probe_len=0").is_err());
        assert!(c.apply_override("pool.world_dir_buckets=0").is_err());
        assert!(c.apply_override("pool.cluster_position_radius=0").is_err());
    }

    #[test]
    fn sort_scope_and_cluster_radius_roundtrip_and_validate() {
        let mut c = LuminaConfig::quick_test();
        assert_eq!(c.pool.sort_scope, SortScope::Private, "private by default");
        assert_eq!(c.pool.cluster_radius, 0.35);
        c.apply_override("pool.sort_scope=clustered").unwrap();
        assert_eq!(c.pool.sort_scope, SortScope::Clustered);
        c.apply_override("pool.cluster_radius=1.2").unwrap();
        assert_eq!(c.pool.cluster_radius, 1.2);
        let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.pool.sort_scope, SortScope::Clustered);
        assert_eq!(back.pool.cluster_radius, 1.2);
        assert!(c.apply_override("pool.sort_scope=bogus").is_err());
        assert!(c.apply_override("pool.cluster_radius=0").is_err());
        assert!(c.apply_override("pool.cluster_radius=-1").is_err());
        for s in [SortScope::Private, SortScope::Clustered] {
            assert_eq!(SortScope::parse(s.label()).unwrap(), s);
        }
    }

    #[test]
    fn scheduler_mode_roundtrips_and_validates() {
        let mut c = LuminaConfig::quick_test();
        assert_eq!(c.pool.scheduler, SchedulerMode::Session, "session by default");
        c.apply_override("pool.scheduler=stealing").unwrap();
        assert_eq!(c.pool.scheduler, SchedulerMode::Stealing);
        let back = LuminaConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.pool.scheduler, SchedulerMode::Stealing);
        assert!(c.apply_override("pool.scheduler=bogus").is_err());
        for s in [SchedulerMode::Session, SchedulerMode::Stealing] {
            assert_eq!(SchedulerMode::parse(s.label()).unwrap(), s);
        }
    }

    #[test]
    fn tier_name_roundtrip() {
        for t in [Tier::Full, Tier::Reduced, Tier::Half] {
            assert_eq!(Tier::parse(t.label()).unwrap(), t);
        }
        assert_eq!(
            Tier::parse_ladder("full, reduced ,half").unwrap(),
            vec![Tier::Full, Tier::Reduced, Tier::Half]
        );
        assert!(Tier::parse_ladder("").is_err());
    }

    #[test]
    fn variant_flags() {
        assert!(HardwareVariant::Lumina.uses_s2());
        assert!(HardwareVariant::Lumina.uses_rc());
        assert!(HardwareVariant::Lumina.uses_nru());
        assert!(!HardwareVariant::Gpu.uses_s2());
        assert!(HardwareVariant::RcGpu.uses_rc());
        assert!(!HardwareVariant::RcGpu.uses_nru());
        assert!(HardwareVariant::S2Acc.uses_nru());
    }

    #[test]
    fn variant_name_roundtrip() {
        for v in HardwareVariant::evaluation_set() {
            assert_eq!(HardwareVariant::parse(v.name()).unwrap(), v);
        }
        for v in [
            HardwareVariant::GsCore,
            HardwareVariant::LuminaOnGscoreFrontend,
            HardwareVariant::Ds2Gpu,
        ] {
            assert_eq!(HardwareVariant::parse(v.name()).unwrap(), v);
        }
    }

    #[test]
    fn ds2_is_a_plain_gpu_path() {
        let v = HardwareVariant::Ds2Gpu;
        assert!(!v.uses_s2() && !v.uses_rc() && !v.uses_nru());
    }
}
