//! Compositing and hardware constants, mirrored from `python/compile/common.py`.
//!
//! The Rust rasterizer, the Pallas kernels, and the AOT HLO artifacts must
//! agree bit-for-bit on these — `tests/runtime_parity.rs` enforces it.

/// Image tile edge in pixels (paper: 16x16 tiles).
pub const TILE: usize = 16;

/// "Significant Gaussian" opacity threshold (paper Sec. 2.1: alpha > 1/255).
pub const ALPHA_MIN: f32 = 1.0 / 255.0;

/// Opacity clamp of the reference CUDA rasterizer.
pub const ALPHA_MAX: f32 = 0.99;

/// Early-termination threshold theta on accumulated transmittance.
pub const T_EPS: f32 = 1e-4;

/// Gaussians per rasterization chunk (AOT artifact shape).
pub const G_CHUNK: usize = 256;

/// Tiles per batched-raster artifact.
pub const TILE_BATCH: usize = 32;

/// Gaussians per SH-eval artifact call.
pub const SH_CHUNK: usize = 4096;

/// Number of degree-3 SH coefficients per color channel.
pub const SH_COEFFS: usize = 16;

// --- Default algorithm parameters (paper Sec. 6) --------------------------

/// Default S^2 sharing window: frames sharing one sorting result.
pub const DEFAULT_SHARING_WINDOW: usize = 6;

/// Default expanded viewport margin, in pixels per dimension.
pub const DEFAULT_EXPANDED_MARGIN: usize = 4;

/// Default alpha-record length k: significant-Gaussian IDs per cache tag.
pub const DEFAULT_ALPHA_RECORD: usize = 5;

// --- LuminCache geometry (paper Sec. 5) ------------------------------------

/// Cache associativity.
pub const CACHE_WAYS: usize = 4;

/// Number of cache sets (4 x 1024 entries total).
pub const CACHE_SETS: usize = 1024;

/// Lowest Gaussian-ID bit used for the tag/index split (bits 3..18 used).
pub const CACHE_ID_LO_BIT: u32 = 3;

/// Number of Gaussian-ID bits used per ID (3rd..18th LSB).
pub const CACHE_ID_BITS: u32 = 16;

/// LuminCache covers 64x64 pixels = a 4x4 group of 16x16 tiles.
pub const CACHE_TILE_GROUP: usize = 4;

// --- LuminCore geometry (paper Sec. 5) -------------------------------------

/// NRU array edge (8x8 NRUs).
pub const NRU_ARRAY: usize = 8;

/// Processing elements per NRU (three-stage pipelined frontend PEs).
pub const PES_PER_NRU: usize = 4;

/// NRU clock in Hz (1 GHz).
pub const NRU_CLOCK_HZ: f64 = 1.0e9;

/// Double-buffered feature buffer capacity in bytes (total 176 KB).
pub const FEATURE_BUF_BYTES: usize = 176 * 1024;

/// Double-buffered output buffer capacity in bytes (6 KB).
pub const OUTPUT_BUF_BYTES: usize = 6 * 1024;

/// Bytes of Gaussian features streamed per Gaussian into the NRU:
/// mean2d (8) + conic (12) + opacity (4) + rgb (12) + id (4) = 40 B.
pub const GAUSSIAN_FEATURE_BYTES: usize = 40;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_capacity_matches_paper() {
        // 4-way x 1024 sets; tag 10 B + RGB value 3 B -> ~52 KB total.
        let entries = CACHE_WAYS * CACHE_SETS;
        assert_eq!(entries, 4096);
        let bytes = entries * (10 + 3);
        assert!(bytes <= 53 * 1024, "cache {} B exceeds ~52 KB budget", bytes);
    }

    #[test]
    fn tag_bits_cover_five_ids() {
        // 5 IDs x 16 bits = 80 bits = 10 bytes of tag+index material.
        assert_eq!(5 * CACHE_ID_BITS as usize, 80);
    }

    #[test]
    fn cache_group_covers_64px() {
        assert_eq!(CACHE_TILE_GROUP * TILE, 64);
    }
}
