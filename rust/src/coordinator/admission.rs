//! CostModel-driven admission control for tiered multi-session serving.
//!
//! The controller answers one question per planning epoch: *which tier
//! should each session serve so the modeled device delivers a frame to
//! every session at the pool's target rate?* It prices each session's
//! most recent measured [`FrameWorkload`] through the existing
//! [`crate::sim::cost`] seams — re-scaled per candidate tier by
//! [`FrameWorkload::tier_estimate`] — and walks sessions down the tier
//! ladder, lowest priority first, until the mix fits the frame-time
//! budget. When even the all-lowest-tier mix cannot fit, admission is
//! refused with a clear error instead of silently missing the target.
//!
//! The capacity model is time-slicing: one modeled device renders every
//! session's frame each display interval, so a pool sustains
//! `target_fps` iff the per-frame costs sum to at most
//! `1 / target_fps` seconds (minus a safety headroom that absorbs
//! estimator error). A *pipelined* pool (`pool.pipeline_depth = 2`)
//! overlaps frame N+1's frontend with frame N's rasterization, so its
//! per-frame device time is `max(frontend, raster + overhead)` rather
//! than the sum — the controller must price with the same arithmetic
//! ([`price_workload_at_depth`]) or it would refuse viewers the
//! pipelined device actually holds.
//!
//! Rung pricing has two paths: the exact one re-grids the per-pixel
//! record at every ladder rung (O(pixels) per rung), and the
//! [`PricingMode::Aggregate`] one collapses each session's record once
//! into O(tiles) per-tile statistics and re-scales those — the path
//! that keeps epoch re-plans cheap at high resolutions, pinned to the
//! exact path's demotion decisions by `tests/admission.rs`.
//!
//! Shared-cache pools change the pricing inputs in both directions: the
//! LuminCore model charges shared-lookup port contention (a structural
//! cost that survives tier re-estimation), and the raster stage is
//! discounted by the **pool-wide** observed hit rate
//! ([`SHARED_HIT_RASTER_SAVINGS`]) — under shared scope a session's
//! future hits come from the pool's merged inserts, not its own
//! history, so per-session rates would be the wrong signal.
//!
//! Everything here is deterministic — float arithmetic over
//! deterministic workloads, no clocks, no randomness — so planned tier
//! sequences are bitwise thread-count-invariant like the rest of the
//! pipeline (`tests/admission.rs`).

use anyhow::{bail, ensure, Result};

use crate::config::{HardwareVariant, LuminaConfig, PricingMode, Tier};
use crate::coordinator::cost_models_for;
use crate::pipeline::stage::{AggregateWorkload, FrameWorkload};

/// Fraction of the frame-time budget held back from the planner to
/// absorb tier-estimate error (the estimates are conservative, but the
/// controller's promise — "the pool holds the target" — should not
/// hinge on that).
pub const ADMISSION_HEADROOM: f64 = 0.15;

/// Fraction of a hit pixel's rasterization cost the shared cache
/// actually saves. A hit still pays projection-side work, the first-k
/// significant iterations, and the lookup itself, so the discount the
/// planner applies to the conservative cold-cache price is deliberately
/// partial — and it never touches the *structural* floor (fixed
/// overhead + shared-lookup contention, [`StagePrices`]), which is paid
/// warm or cold. Private sessions keep the plain cold-cache price —
/// their cache is wiped by every tier swap, so banking on yesterday's
/// hit rate would blow the budget; a *shared* snapshot survives any one
/// session's re-tiering, which is what makes the pool-wide observed
/// rate a sound pricing input.
pub const SHARED_HIT_RASTER_SAVINGS: f64 = 0.5;

/// One session's input to a planning round.
pub struct SessionDemand {
    /// Most recent measured workload (under `tier`).
    pub workload: FrameWorkload,
    /// Tier the workload was measured under.
    pub tier: Tier,
    /// Hardware variant whose cost models price this session.
    pub variant: HardwareVariant,
    /// Whether the session can serve the half-res tier — false for the
    /// `ds2-gpu` variant (already half) and for odd camera dimensions
    /// (see `Coordinator::tier_servable`). The planner must never
    /// assign a tier the session's `set_tier` would reject.
    pub half_capable: bool,
    /// Higher = demoted later.
    pub priority: f64,
    /// Whether this session renders against the pool-shared cache
    /// snapshot (false = private scope, today's pricing unchanged).
    pub cache_shared: bool,
    /// Pool-wide observed cache hit rate (0..1) across every served
    /// frame so far — the same value for all sessions, because under
    /// shared scope a session's future hits come from the *pool's*
    /// merged inserts, not its own history. Consumed only when
    /// `cache_shared` ([`SHARED_HIT_RASTER_SAVINGS`]).
    pub pool_hit_rate: f64,
}

impl SessionDemand {
    /// Whether the planner may put this session on `tier`.
    pub fn supports(&self, tier: Tier) -> bool {
        tier != Tier::Half || self.half_capable
    }
}

/// The outcome of a planning round.
#[derive(Debug, Clone, PartialEq)]
pub struct TierPlan {
    /// Planned tier per session, in session order.
    pub tiers: Vec<Tier>,
    /// Predicted summed per-frame device time for the mix (s).
    pub predicted_time_s: f64,
    /// Frame-time budget the mix was fitted to (headroom included, s).
    pub budget_s: f64,
}

impl TierPlan {
    /// Pool rate the planned mix is predicted to sustain.
    pub fn predicted_pool_fps(&self) -> f64 {
        if self.predicted_time_s > 0.0 {
            1.0 / self.predicted_time_s
        } else {
            0.0
        }
    }
}

/// Price one workload through a variant's cost-model seams: frontend +
/// rasterization + fixed per-frame overhead, in modeled seconds.
pub fn price_workload(w: &FrameWorkload, variant: HardwareVariant) -> f64 {
    price_workload_at_depth(w, variant, 1)
}

/// Combine the two stage times under a `depth`-slot frame pipeline: at
/// depth >= 2 the frontend overlaps the previous frame's rasterization,
/// so a steady-state frame occupies the modeled device for the *slower*
/// stage instead of the sum. The single home of the overlap arithmetic:
/// both the planner (here) and the report side
/// (`FrameReport::device_time_s`) go through it, so they cannot
/// diverge.
pub(crate) fn combine_stage_times(front_s: f64, raster_s: f64, depth: usize) -> f64 {
    if depth >= 2 {
        front_s.max(raster_s)
    } else {
        front_s + raster_s
    }
}

/// One workload's stage prices, split the way the planner needs them:
/// frontend, raster (fixed overhead and any structural contention
/// included), and the *structural floor* — the part of the raster price
/// cache hits cannot save (fixed per-frame overhead plus shared-lookup
/// contention, which is paid per lookup whether it hits or misses).
#[derive(Debug, Clone, Copy)]
pub struct StagePrices {
    pub front_s: f64,
    pub raster_s: f64,
    pub structural_s: f64,
}

impl StagePrices {
    /// Raster price with the shared-scope pool-hit-rate discount
    /// applied to the discountable (non-structural) part only. A
    /// discount of 1.0 returns `raster_s` bit-exactly, so private
    /// pricing is untouched.
    pub fn discounted_raster_s(&self, hit_discount: f64) -> f64 {
        if hit_discount >= 1.0 {
            self.raster_s
        } else {
            self.structural_s + (self.raster_s - self.structural_s) * hit_discount
        }
    }
}

/// Price one workload's stages separately — the split the planner needs
/// so it can discount the hit-savable raster work by the pool-wide
/// observed hit rate without touching the frontend (hits save
/// compositing, not sorting) or the structural floor.
pub fn price_stages(w: &FrameWorkload, variant: HardwareVariant) -> StagePrices {
    let (frontend_cost, mut raster_cost) = cost_models_for(variant);
    let (front_s, _front_j) = frontend_cost.frontend_cost(w);
    let raster = raster_cost.raster_cost(w);
    let overhead = raster_cost.overhead_s();
    let structural_s = overhead
        + if w.cache_shared { raster_cost.shared_lookup_cost_s(w.pixels()) } else { 0.0 };
    StagePrices { front_s, raster_s: raster.time_s + overhead, structural_s }
}

/// [`price_stages`] over the O(tiles) aggregate record.
pub fn price_aggregate_stages(a: &AggregateWorkload, variant: HardwareVariant) -> StagePrices {
    let (frontend_cost, mut raster_cost) = cost_models_for(variant);
    let (front_s, _front_j) = frontend_cost.frontend_work_cost(&a.frontend_work());
    let raster = raster_cost.raster_cost_aggregate(a);
    let overhead = raster_cost.overhead_s();
    let structural_s = overhead
        + if a.cache_shared {
            raster_cost.shared_lookup_cost_s(a.width * a.height)
        } else {
            0.0
        };
    StagePrices { front_s, raster_s: raster.time_s + overhead, structural_s }
}

/// [`price_workload`] under a `depth`-slot frame pipeline: per-frame
/// device time is `max(frontend, raster + overhead)` at depth >= 2 —
/// the arithmetic the planner must use for a pool that overlaps frame
/// N+1's frontend with frame N's rasterization, or it would refuse
/// viewers the pipelined device can actually hold.
pub fn price_workload_at_depth(
    w: &FrameWorkload,
    variant: HardwareVariant,
    depth: usize,
) -> f64 {
    let p = price_stages(w, variant);
    combine_stage_times(p.front_s, p.raster_s, depth)
}

/// [`price_workload_at_depth`] over the O(tiles) aggregate record — the
/// fast rung-pricing path ([`PricingMode::Aggregate`]).
pub fn price_aggregate_at_depth(
    a: &AggregateWorkload,
    variant: HardwareVariant,
    depth: usize,
) -> f64 {
    let p = price_aggregate_stages(a, variant);
    combine_stage_times(p.front_s, p.raster_s, depth)
}

/// Picks the cheapest tier mix (best quality first) that holds a
/// per-pool simulated-FPS target.
pub struct AdmissionController {
    target_fps: f64,
    ladder: Vec<Tier>,
    reduced_fraction: f64,
    /// Frame-slot depth the pool serves at: depth >= 2 prices a frame
    /// as `max(frontend, raster + overhead)` instead of the sum.
    pipeline_depth: usize,
    /// Exact per-pixel rung pricing vs the O(tiles) aggregate path.
    pricing: PricingMode,
}

impl AdmissionController {
    /// `ladder` is quality-ordered, best first; demotion walks down it.
    /// Defaults to synchronous (depth 1) exact pricing; see
    /// [`Self::with_pipeline_depth`] and [`Self::with_pricing`].
    pub fn new(target_fps: f64, ladder: Vec<Tier>, reduced_fraction: f64) -> Result<Self> {
        ensure!(
            target_fps > 0.0 && target_fps.is_finite(),
            "admission target must be a positive fps, got {target_fps}"
        );
        ensure!(!ladder.is_empty(), "tier ladder is empty");
        ensure!(
            reduced_fraction > 0.0 && reduced_fraction <= 1.0,
            "reduced fraction must be in (0, 1], got {reduced_fraction}"
        );
        Ok(AdmissionController {
            target_fps,
            ladder,
            reduced_fraction,
            pipeline_depth: 1,
            pricing: PricingMode::Exact,
        })
    }

    /// Price frames for a `depth`-slot pipelined pool (clamped to the
    /// supported 1..=2 range).
    pub fn with_pipeline_depth(mut self, depth: usize) -> Self {
        self.pipeline_depth = depth.clamp(1, 2);
        self
    }

    /// Select the rung-pricing path.
    pub fn with_pricing(mut self, pricing: PricingMode) -> Self {
        self.pricing = pricing;
        self
    }

    /// Build from the `[pool]` config block (`pool.target_fps` must be
    /// set); picks up `pool.pipeline_depth` and `pool.pricing`.
    pub fn from_config(cfg: &LuminaConfig) -> Result<Self> {
        Ok(Self::new(
            cfg.pool.target_fps,
            cfg.pool.tiers.clone(),
            cfg.pool.reduced_fraction,
        )?
        .with_pipeline_depth(cfg.pool.pipeline_depth)
        .with_pricing(cfg.pool.pricing))
    }

    pub fn target_fps(&self) -> f64 {
        self.target_fps
    }

    pub fn ladder(&self) -> &[Tier] {
        &self.ladder
    }

    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    pub fn pricing(&self) -> PricingMode {
        self.pricing
    }

    /// Plan a tier per session. Starts everyone at the ladder's best
    /// tier and demotes one step at a time until the priced mix fits
    /// the budget, spreading the pain: among demotable sessions it
    /// picks the least-demoted first, breaking ties toward lower
    /// priority and then toward the later session. (Level-first order
    /// walks through every "prefix of sessions one rung down" mix, so
    /// a feasible mix is never skipped even when a lower rung prices
    /// higher than the one above it.) Re-planning each epoch restarts
    /// from all-best, so sessions promote back up automatically
    /// whenever headroom appears. Refuses admission when no mix fits.
    pub fn plan(&self, demands: &[SessionDemand]) -> Result<TierPlan> {
        ensure!(!demands.is_empty(), "cannot plan an empty pool");
        let budget_s = (1.0 - ADMISSION_HEADROOM) / self.target_fps;

        // Per-session rungs: the ladder tiers the session can actually
        // serve, each priced by re-scaling the measured workload from
        // the tier it was measured under. The aggregate path collapses
        // the per-pixel record once per session (O(pixels)), then every
        // rung re-scales and prices in O(tiles).
        let mut rungs: Vec<Vec<(Tier, f64)>> = Vec::with_capacity(demands.len());
        for d in demands {
            let agg = (self.pricing == PricingMode::Aggregate)
                .then(|| d.workload.aggregate());
            // Shared scope prices the raster stage with the pool-wide
            // observed hit rate: a viewer joining a warm pool inherits
            // the pool's hits (the snapshot outlives any one session's
            // tier swaps), so the cold-cache price would systematically
            // refuse viewers the shared device actually holds. Private
            // scope keeps the conservative cold-cache price unchanged.
            let base_discount = if d.cache_shared {
                1.0 - d.pool_hit_rate.clamp(0.0, 1.0) * SHARED_HIT_RASTER_SAVINGS
            } else {
                1.0
            };
            let r: Vec<(Tier, f64)> = self
                .ladder
                .iter()
                .copied()
                .filter(|&t| d.supports(t))
                .map(|t| {
                    let p = match &agg {
                        Some(a) => price_aggregate_stages(
                            &a.tier_estimate(d.tier, t, self.reduced_fraction),
                            d.variant,
                        ),
                        None => price_stages(
                            &d.workload.tier_estimate(d.tier, t, self.reduced_fraction),
                            d.variant,
                        ),
                    };
                    // The observed rate only transfers to rungs that
                    // keep the session's cache geometry: full and
                    // reduced share the render grid (one snapshot),
                    // while the half-res tier re-attaches to a
                    // different — possibly cold — snapshot, so
                    // geometry-changing rungs are priced cold.
                    let same_geometry = (t == Tier::Half) == (d.tier == Tier::Half);
                    let hit_discount = if same_geometry { base_discount } else { 1.0 };
                    let price = combine_stage_times(
                        p.front_s,
                        p.discounted_raster_s(hit_discount),
                        self.pipeline_depth,
                    );
                    (t, price)
                })
                .collect();
            ensure!(
                !r.is_empty(),
                "no tier in the ladder [{}] is servable by a {} session",
                Tier::ladder_name(&self.ladder),
                d.variant.label()
            );
            rungs.push(r);
        }

        let mut level = vec![0usize; demands.len()];
        let mut total: f64 = rungs.iter().map(|r| r[0].1).sum();
        while total > budget_s {
            // Least-demoted session first; among those, lowest priority.
            let mut pick: Option<usize> = None;
            for (i, d) in demands.iter().enumerate() {
                if level[i] + 1 >= rungs[i].len() {
                    continue;
                }
                let better = match pick {
                    None => true,
                    Some(p) => {
                        level[i] < level[p]
                            || (level[i] == level[p] && d.priority <= demands[p].priority)
                    }
                };
                if better {
                    pick = Some(i);
                }
            }
            let Some(i) = pick else {
                bail!(
                    "admission refused: {} sessions cannot sustain {:.1} pool fps even at \
                     the lowest tier (predicted {:.1} fps, budget {:.3} ms/frame, \
                     predicted {:.3} ms/frame)",
                    demands.len(),
                    self.target_fps,
                    1.0 / total,
                    budget_s * 1e3,
                    total * 1e3
                );
            };
            total -= rungs[i][level[i]].1;
            level[i] += 1;
            total += rungs[i][level[i]].1;
        }

        let tiers = level.iter().zip(&rungs).map(|(&l, r)| r[l].0).collect();
        Ok(TierPlan { tiers, predicted_time_s: total, budget_s })
    }

    /// Each session's lowest servable rung — the best-effort fallback a
    /// pool pins admitted viewers to when a mid-run re-plan cannot fit.
    pub fn floor_tiers(&self, demands: &[SessionDemand]) -> Vec<Tier> {
        demands
            .iter()
            .map(|d| {
                self.ladder
                    .iter()
                    .rev()
                    .copied()
                    .find(|&t| d.supports(t))
                    .unwrap_or(Tier::Full)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lumina::rc::CacheStats;

    fn demand(px: usize, priority: f64) -> SessionDemand {
        let side = (px as f64).sqrt() as usize;
        let tiles = side.div_ceil(16);
        SessionDemand {
            workload: FrameWorkload {
                frame: 0,
                width: side,
                height: side,
                tile_size: 16,
                tiles_x: tiles,
                tiles_y: tiles,
                tile_list_lens: vec![100; tiles * tiles],
                scene_gaussians: 10_000,
                sorted: true,
                sort_entries: 50_000,
                refreshed_gaussians: 0,
                consumed: vec![100; side * side],
                significant: vec![10; side * side],
                uncached: None,
                cache_outcomes: None,
                cache: CacheStats::default(),
                cache_shared: false,
                swap_bytes: 0,
            },
            tier: Tier::Full,
            variant: HardwareVariant::Gpu,
            half_capable: true,
            priority,
            cache_shared: false,
            pool_hit_rate: 0.0,
        }
    }

    fn ladder() -> Vec<Tier> {
        vec![Tier::Full, Tier::Reduced, Tier::Half]
    }

    #[test]
    fn generous_target_keeps_everyone_full() {
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        // Target low enough that 3 full sessions fit with headroom.
        let target = 0.5 * (1.0 - ADMISSION_HEADROOM) / (3.0 * one);
        let ctrl = AdmissionController::new(target, ladder(), 0.5).unwrap();
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let plan = ctrl.plan(&demands).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3]);
        assert!(plan.predicted_pool_fps() >= target);
    }

    #[test]
    fn pressure_demotes_lowest_priority_first() {
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        // Budget fits ~2.5 full-tier sessions: someone must drop.
        let target = (1.0 - ADMISSION_HEADROOM) / (2.5 * one);
        let ctrl = AdmissionController::new(target, ladder(), 0.5).unwrap();
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let plan = ctrl.plan(&demands).unwrap();
        assert_eq!(plan.tiers[0], Tier::Full, "highest priority demoted first");
        assert_ne!(plan.tiers[2], Tier::Full, "lowest priority kept full under pressure");
        assert!(plan.predicted_time_s <= plan.budget_s);
    }

    #[test]
    fn impossible_target_refuses_admission() {
        let ctrl = AdmissionController::new(1e9, ladder(), 0.5).unwrap();
        let demands = vec![demand(128 * 128, 1.0), demand(128 * 128, 0.0)];
        let err = ctrl.plan(&demands).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("admission refused"), "unhelpful refusal: {msg}");
    }

    #[test]
    fn demoted_tiers_price_cheaper() {
        let d = demand(128 * 128, 0.0);
        let full = price_workload(
            &d.workload.tier_estimate(Tier::Full, Tier::Full, 0.5),
            d.variant,
        );
        let reduced = price_workload(
            &d.workload.tier_estimate(Tier::Full, Tier::Reduced, 0.5),
            d.variant,
        );
        let half = price_workload(
            &d.workload.tier_estimate(Tier::Full, Tier::Half, 0.5),
            d.variant,
        );
        assert!(reduced < full, "reduced {reduced} !< full {full}");
        assert!(half < full, "half {half} !< full {full}");
    }

    #[test]
    fn half_incapable_sessions_never_planned_onto_the_half_rung() {
        // ds2-gpu (already half) and odd-dimension sessions both report
        // half_capable = false; the planner must respect it.
        let mut d = demand(64 * 64, 0.0);
        d.variant = HardwareVariant::Ds2Gpu;
        d.half_capable = false;
        let one = price_workload(&d.workload, HardwareVariant::Ds2Gpu);
        // Tight enough to force demotion off full: the only legal rung
        // below is reduced — never half (set_tier would reject it).
        let target = (1.0 - ADMISSION_HEADROOM) / (0.8 * one);
        let ctrl = AdmissionController::new(target, ladder(), 0.5).unwrap();
        let plan = ctrl.plan(&[d]).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Reduced]);
        // And the best-effort floor is reduced, not half.
        let d2 = SessionDemand { half_capable: false, ..demand(64 * 64, 0.0) };
        assert_eq!(ctrl.floor_tiers(&[d2]), vec![Tier::Reduced]);
    }

    #[test]
    fn pipelined_pricing_is_the_stage_max() {
        let d = demand(128 * 128, 0.0);
        let synchronous = price_workload_at_depth(&d.workload, d.variant, 1);
        let pipelined = price_workload_at_depth(&d.workload, d.variant, 2);
        assert!(pipelined < synchronous, "overlap must price below the stage sum");
        assert_eq!(synchronous, price_workload(&d.workload, d.variant));
        // max(frontend, raster+overhead) decomposition: the two depths
        // bound each other by the frontend share.
        assert!(pipelined * 2.0 >= synchronous, "max >= sum/2");
    }

    #[test]
    fn pipelined_controller_admits_what_sum_pricing_refuses_to_keep_full() {
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        // Budget fits ~2.5 sum-priced sessions: synchronous pricing must
        // demote someone, overlapped pricing holds all three at full
        // (the frontend share is well above the ~17% break-even).
        let target = (1.0 - ADMISSION_HEADROOM) / (2.5 * one);
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let sync = AdmissionController::new(target, ladder(), 0.5).unwrap();
        assert_eq!(sync.pipeline_depth(), 1);
        let plan = sync.plan(&demands).unwrap();
        assert!(plan.tiers.iter().any(|&t| t != Tier::Full));
        let piped = AdmissionController::new(target, ladder(), 0.5)
            .unwrap()
            .with_pipeline_depth(2);
        assert_eq!(piped.pipeline_depth(), 2);
        let plan = piped.plan(&demands).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3], "pipelined device holds all three");
    }

    #[test]
    fn aggregate_pricing_pins_exact_demotion_decisions() {
        // Uniform synthetic demands: the aggregate transforms are exact,
        // so the two pricing paths must plan identical tier mixes across
        // the whole pressure range, and refuse identically.
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        let demands = || {
            vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)]
        };
        for fit in [6.0, 3.2, 2.5, 2.2, 1.6, 1.1, 0.8] {
            let target = (1.0 - ADMISSION_HEADROOM) / (fit * one);
            let exact = AdmissionController::new(target, ladder(), 0.5).unwrap();
            let fast = AdmissionController::new(target, ladder(), 0.5)
                .unwrap()
                .with_pricing(PricingMode::Aggregate);
            assert_eq!(fast.pricing(), PricingMode::Aggregate);
            match (exact.plan(&demands()), fast.plan(&demands())) {
                (Ok(e), Ok(f)) => {
                    assert_eq!(e.tiers, f.tiers, "plans diverged at fit={fit}");
                }
                (Err(_), Err(_)) => {} // both refuse: also parity
                (e, f) => panic!(
                    "pricing paths disagree on admission at fit={fit}: exact {:?} vs \
                     aggregate {:?}",
                    e.map(|p| p.tiers),
                    f.map(|p| p.tiers)
                ),
            }
        }
    }

    #[test]
    fn pool_hit_rate_discount_admits_what_cold_pricing_refuses() {
        // Shared-scope demands at a high observed pool hit rate price
        // their raster stage cheaper; a budget sitting between the
        // discounted and undiscounted sums separates the two plans.
        let mk = |rate: f64| -> Vec<SessionDemand> {
            (0..3)
                .map(|i| SessionDemand {
                    cache_shared: true,
                    pool_hit_rate: rate,
                    ..demand(128 * 128, (3 - i) as f64)
                })
                .collect()
        };
        let d = demand(128 * 128, 0.0);
        let p = price_stages(&d.workload, d.variant);
        let cold = p.front_s + p.raster_s;
        let warm = p.front_s + p.discounted_raster_s(1.0 - 0.9 * SHARED_HIT_RASTER_SAVINGS);
        assert!(warm < cold);
        assert!(
            p.discounted_raster_s(0.0) >= p.structural_s,
            "even a perfect hit rate cannot discount the structural floor"
        );
        let per_session = (cold + warm) / 2.0;
        let target = (1.0 - ADMISSION_HEADROOM) / (3.0 * per_session);
        let ctrl = AdmissionController::new(target, vec![Tier::Full], 0.5).unwrap();
        assert!(ctrl.plan(&mk(0.0)).is_err(), "cold pricing must refuse");
        let plan = ctrl.plan(&mk(0.9)).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3], "warm pool holds all three");
        // Private scope ignores the rate entirely.
        let mut private = mk(0.9);
        for p in private.iter_mut() {
            p.cache_shared = false;
        }
        assert!(ctrl.plan(&private).is_err(), "discount must be shared-scope only");

        // Geometry-changing rungs are never discounted: the half-res
        // tier re-attaches to a different (possibly cold) snapshot, so
        // the observed rate does not transfer there.
        let ph = price_stages(&d.workload.tier_estimate(Tier::Full, Tier::Half, 0.5), d.variant);
        let half_cold = ph.front_s + ph.raster_s;
        let half_target = (1.0 - ADMISSION_HEADROOM) / (3.0 * half_cold * 0.9);
        let half_ctrl = AdmissionController::new(half_target, vec![Tier::Half], 0.5).unwrap();
        assert!(
            half_ctrl.plan(&mk(0.9)).is_err(),
            "a half rung from full-tier demands must price cold"
        );
    }

    #[test]
    fn controller_validates_inputs() {
        assert!(AdmissionController::new(0.0, ladder(), 0.5).is_err());
        assert!(AdmissionController::new(30.0, vec![], 0.5).is_err());
        assert!(AdmissionController::new(30.0, ladder(), 0.0).is_err());
        let ctrl = AdmissionController::new(30.0, ladder(), 0.5).unwrap();
        assert!(ctrl.plan(&[]).is_err());
    }
}
