//! CostModel-driven admission control for tiered multi-session serving.
//!
//! The controller answers one question per planning epoch: *which tier
//! should each session serve so the modeled device delivers a frame to
//! every session at the pool's target rate?* It prices each session's
//! most recent measured [`FrameWorkload`] through the existing
//! [`crate::sim::cost`] seams — re-scaled per candidate tier by
//! [`FrameWorkload::tier_estimate`] — and walks sessions down the tier
//! ladder, lowest priority first, until the mix fits the frame-time
//! budget. When even the all-lowest-tier mix cannot fit, admission is
//! refused with a clear error instead of silently missing the target.
//!
//! The capacity model is time-slicing: one modeled device renders every
//! session's frame each display interval, so a pool sustains
//! `target_fps` iff the per-frame costs sum to at most
//! `1 / target_fps` seconds (minus a safety headroom that absorbs
//! estimator error). A *pipelined* pool (`pool.pipeline_depth = 2`)
//! overlaps frame N+1's frontend with frame N's rasterization, so its
//! per-frame device time is `max(frontend, raster + overhead)` rather
//! than the sum — the controller must price with the same arithmetic
//! ([`price_workload_at_depth`]) or it would refuse viewers the
//! pipelined device actually holds. Because every epoch boundary drains
//! the frame slots, the planner additionally charges the epoch's
//! un-overlapped fill/drain share — `max + min/epoch_frames` per frame
//! ([`combine_stage_times_epoch`]) — the critical path of the epoch's
//! task graph rather than its steady-state interior.
//!
//! Rung pricing has two paths: the exact one re-grids the per-pixel
//! record at every ladder rung (O(pixels) per rung), and the
//! [`PricingMode::Aggregate`] one collapses each session's record once
//! into O(tiles) per-tile statistics and re-scales those — the path
//! that keeps epoch re-plans cheap at high resolutions, pinned to the
//! exact path's demotion decisions by `tests/admission.rs`.
//!
//! Shared-cache pools change the pricing inputs in both directions: the
//! LuminCore model charges shared-lookup port contention (a structural
//! cost that survives tier re-estimation), and the raster stage is
//! discounted by the **pool-wide** observed hit rate
//! ([`SHARED_HIT_RASTER_SAVINGS`]) — under shared scope a session's
//! future hits come from the pool's merged inserts, not its own
//! history, so per-session rates would be the wrong signal.
//!
//! Pool-clustered S² pools amortize the *frontend* the same way: a
//! cluster runs one speculative sort per epoch, so every clustered
//! session's sorting rung carries that sort amortized over the epoch's
//! frames (`sorted_front_s`, estimated from the frozen tile lists when
//! the measured frame was a reuse frame — which in steady state it
//! almost always is), while a multi-member cluster's followers are
//! priced at their per-frame refresh plus a broadcast/contention term —
//! never below the refresh floor ([`StagePrices::follower_front_s`]),
//! the same discipline that keeps the raster discount off the
//! structural floor.
//!
//! Everything here is deterministic — float arithmetic over
//! deterministic workloads, no clocks, no randomness — so planned tier
//! sequences are bitwise thread-count-invariant like the rest of the
//! pipeline (`tests/admission.rs`).

use anyhow::{bail, ensure, Result};

use crate::config::{HardwareVariant, LuminaConfig, PricingMode, Tier};
use crate::coordinator::cost_models_for;
use crate::pipeline::stage::{AggregateWorkload, FrameWorkload, FrontendWork};

/// Fraction of the frame-time budget held back from the planner to
/// absorb tier-estimate error (the estimates are conservative, but the
/// controller's promise — "the pool holds the target" — should not
/// hinge on that).
pub const ADMISSION_HEADROOM: f64 = 0.15;

/// Fraction of a hit pixel's rasterization cost the shared cache
/// actually saves. A hit still pays projection-side work, the first-k
/// significant iterations, and the lookup itself, so the discount the
/// planner applies to the conservative cold-cache price is deliberately
/// partial — and it never touches the *structural* floor (fixed
/// overhead + shared-lookup contention, [`StagePrices`]), which is paid
/// warm or cold. Private sessions keep the plain cold-cache price —
/// their cache is wiped by every tier swap, so banking on yesterday's
/// hit rate would blow the budget; a *shared* snapshot survives any one
/// session's re-tiering, which is what makes the pool-wide observed
/// rate a sound pricing input.
pub const SHARED_HIT_RASTER_SAVINGS: f64 = 0.5;

/// One session's input to a planning round.
pub struct SessionDemand {
    /// Most recent measured workload (under `tier`).
    pub workload: FrameWorkload,
    /// Tier the workload was measured under.
    pub tier: Tier,
    /// Hardware variant whose cost models price this session.
    pub variant: HardwareVariant,
    /// Whether the session can serve the half-res tier — false for the
    /// `ds2-gpu` variant (already half) and for odd camera dimensions
    /// (see `Coordinator::tier_servable`). The planner must never
    /// assign a tier the session's `set_tier` would reject.
    pub half_capable: bool,
    /// Higher = demoted later.
    pub priority: f64,
    /// Whether this session renders against the pool-shared cache
    /// snapshot (false = private scope, today's pricing unchanged).
    pub cache_shared: bool,
    /// Whether the pool-shared snapshot is the *world-space* hash cache.
    /// World keys survive resolution and tier changes (they quantize
    /// Gaussian positions, not pixels), so the hit-rate discount below
    /// also applies to geometry-changing rungs — a half-res candidate
    /// still hits the entries full-res sessions populated.
    pub cache_world: bool,
    /// Pool-wide observed cache hit rate (0..1) across every served
    /// frame so far — the same value for all sessions, because under
    /// shared scope a session's future hits come from the *pool's*
    /// merged inserts, not its own history. Consumed only when
    /// `cache_shared` ([`SHARED_HIT_RASTER_SAVINGS`]).
    pub pool_hit_rate: f64,
    /// Whether this session runs the pool-clustered sort topology —
    /// its cluster (a singleton included) sorts once per epoch, so its
    /// sorting rungs price the per-epoch sort amortized over
    /// `epoch_frames` even when the measured frame was a reuse frame.
    pub sort_clustered: bool,
    /// Sessions sharing this session's speculative sort (itself
    /// included); 1 outside the pool-clustered S² sort scope. With
    /// `sort_leader`, the frontend amortization seam: a cluster pays
    /// its leader's sort once, and followers pay only their per-frame
    /// refresh plus a broadcast/contention term — never below the
    /// refresh floor ([`StagePrices::follower_front_s`]).
    pub sort_sharers: usize,
    /// Whether this session pays for its own sorts (private topology
    /// or cluster leader). Followers (`sort_sharers >= 2` and not
    /// leader) get the amortized frontend price.
    pub sort_leader: bool,
}

impl SessionDemand {
    /// Whether the planner may put this session on `tier`.
    pub fn supports(&self, tier: Tier) -> bool {
        tier != Tier::Half || self.half_capable
    }
}

/// The outcome of a planning round.
#[derive(Debug, Clone, PartialEq)]
pub struct TierPlan {
    /// Planned tier per session, in session order.
    pub tiers: Vec<Tier>,
    /// Predicted summed per-frame device time for the mix (s).
    pub predicted_time_s: f64,
    /// Frame-time budget the mix was fitted to (headroom included, s).
    pub budget_s: f64,
}

impl TierPlan {
    /// Pool rate the planned mix is predicted to sustain.
    pub fn predicted_pool_fps(&self) -> f64 {
        if self.predicted_time_s > 0.0 {
            1.0 / self.predicted_time_s
        } else {
            0.0
        }
    }
}

/// Price one workload through a variant's cost-model seams: frontend +
/// rasterization + fixed per-frame overhead, in modeled seconds.
pub fn price_workload(w: &FrameWorkload, variant: HardwareVariant) -> f64 {
    price_workload_at_depth(w, variant, 1)
}

/// Combine the two stage times under a `depth`-slot frame pipeline: at
/// depth >= 2 the frontend overlaps the previous frame's rasterization,
/// so a steady-state frame occupies the modeled device for the *slower*
/// stage instead of the sum. The single home of the overlap arithmetic:
/// both the planner (here) and the report side
/// (`FrameReport::device_time_s`) go through it, so they cannot
/// diverge.
pub(crate) fn combine_stage_times(front_s: f64, raster_s: f64, depth: usize) -> f64 {
    if depth >= 2 {
        front_s.max(raster_s)
    } else {
        front_s + raster_s
    }
}

/// [`combine_stage_times`] plus the pipeline's fill/drain cost, spread
/// over an `epoch_frames`-frame epoch. Stage overlap only exists
/// *between* consecutive frames, and every epoch boundary drains the
/// frame slots (`SessionPool::run_epoch`), so an `e`-frame epoch pays
/// the un-overlapped fill (the first frame's lone frontend) and drain
/// (the last frame's lone raster) in full: total device time is
/// `front + (e-1)*max(front, raster) + raster = e*max + min`, i.e.
/// `max + min/e` per frame. Steady-state [`combine_stage_times`] is the
/// `e -> inf` limit; this charges the fill/drain gap the planner used
/// to ignore, so short epochs can no longer admit mixes whose boundary
/// overhead the device cannot actually hold. At `e = 1` it degenerates
/// to the synchronous sum — a one-frame epoch has no overlap at all.
///
/// Epoch pricing is deliberately *scheduler-independent*: both the
/// per-session and the stealing scheduler (`pool.scheduler`) drain at
/// the same epoch boundaries, so plans — and refusal/demotion counts —
/// are identical across schedulers (`python/bench_gate.py` enforces
/// this on every bench run).
pub(crate) fn combine_stage_times_epoch(
    front_s: f64,
    raster_s: f64,
    depth: usize,
    epoch_frames: usize,
) -> f64 {
    if depth >= 2 {
        front_s.max(raster_s) + front_s.min(raster_s) / epoch_frames.max(1) as f64
    } else {
        front_s + raster_s
    }
}

/// One workload's stage prices, split the way the planner needs them:
/// frontend, raster (fixed overhead and any structural contention
/// included), and two floors the discounts must respect — the raster's
/// *structural floor* (fixed per-frame overhead plus shared-lookup
/// contention, paid per lookup whether it hits or misses) and the
/// frontend's *refresh floor* (the per-frame S² color/geometry refresh,
/// which every cluster member runs at its own pose no matter who
/// sorted). `broadcast_s` is the frontend's shared-sort receive term
/// for clustered followers.
#[derive(Debug, Clone, Copy)]
pub struct StagePrices {
    pub front_s: f64,
    /// Frontend price with the sort stripped (refresh only) — the part
    /// of the frontend no amortization can save.
    pub refresh_floor_s: f64,
    /// Frontend price *with* a sort — `front_s` when the measured frame
    /// sorted; estimated from the frozen tile-list total otherwise
    /// (steady-state S² frames reuse a sort, so their measured record
    /// carries no sort work, but a session that leaves its cluster must
    /// run one). The planner prices clustered sessions' tier-change
    /// rungs with this, so "demote and exit the cluster" can never look
    /// cheaper than the sort it implies.
    pub sorted_front_s: f64,
    /// Broadcast/arbitration cost of receiving the cluster's frozen
    /// tile lists instead of sorting them
    /// ([`crate::sim::cost::FrontendCostModel::shared_sort_broadcast_s`]).
    pub broadcast_s: f64,
    pub raster_s: f64,
    pub structural_s: f64,
}

impl StagePrices {
    /// Raster price with the shared-scope pool-hit-rate discount
    /// applied to the discountable (non-structural) part only. A
    /// discount of 1.0 returns `raster_s` bit-exactly, so private
    /// pricing is untouched.
    pub fn discounted_raster_s(&self, hit_discount: f64) -> f64 {
        if hit_discount >= 1.0 {
            self.raster_s
        } else {
            self.structural_s + (self.raster_s - self.structural_s) * hit_discount
        }
    }

    /// Frontend price for a pool-clustered S² *follower*: the leader's
    /// sort is paid once per cluster (on the leader's own demand), so
    /// a follower pays only its per-frame refresh plus the
    /// broadcast/contention term. `broadcast_s >= 0`, so this can never
    /// fall below the refresh floor — the same never-discount-the-floor
    /// discipline as the raster's [`Self::discounted_raster_s`].
    pub fn follower_front_s(&self) -> f64 {
        self.refresh_floor_s + self.broadcast_s
    }
}

/// The frontend prices shared by both pricing paths, derived from the
/// frontend scalars + frozen tile-list total.
fn frontend_prices(
    frontend_cost: &dyn crate::sim::cost::FrontendCostModel,
    fw: FrontendWork,
    tile_entries: usize,
) -> (f64, f64, f64, f64) {
    let (front_s, _) = frontend_cost.frontend_work_cost(&fw);
    let (refresh_floor_s, _) = frontend_cost.frontend_work_cost(&FrontendWork {
        sorted: false,
        sort_entries: 0,
        bin_candidates: 0,
        ..fw
    });
    // A frame that reused a sort measured none: estimate the sort a
    // private re-sort would run from the frozen tile-list total it
    // rendered against. The binning candidates of that re-sort are
    // unknown; the frozen entry total is their lower bound (every
    // surviving entry was a candidate), keeping the estimate
    // conservative without inventing rect geometry.
    let sorted_front_s = if fw.sorted {
        front_s
    } else {
        let sorted = FrontendWork {
            sorted: true,
            sort_entries: tile_entries,
            bin_candidates: tile_entries,
            ..fw
        };
        frontend_cost.frontend_work_cost(&sorted).0
    };
    let broadcast_s = frontend_cost.shared_sort_broadcast_s(tile_entries);
    (front_s, refresh_floor_s, sorted_front_s, broadcast_s)
}

/// Price one workload's stages separately — the split the planner needs
/// so it can discount the hit-savable raster work by the pool-wide
/// observed hit rate, and amortize a clustered follower's sort, without
/// ever touching the structural and refresh floors.
pub fn price_stages(w: &FrameWorkload, variant: HardwareVariant) -> StagePrices {
    let (frontend_cost, mut raster_cost) = cost_models_for(variant);
    let (front_s, refresh_floor_s, sorted_front_s, broadcast_s) = frontend_prices(
        frontend_cost.as_ref(),
        w.frontend_work(),
        w.tile_list_lens.iter().sum::<usize>(),
    );
    let raster = raster_cost.raster_cost(w);
    let shared_lookup_s = if w.cache_shared {
        raster_cost.shared_lookup_cost_s(w.pixels(), w.shared_probe_len)
    } else {
        0.0
    };
    StagePrices {
        front_s,
        refresh_floor_s,
        sorted_front_s,
        broadcast_s,
        raster_s: raster.time_s + raster_cost.overhead_s(),
        structural_s: raster_cost.overhead_s() + shared_lookup_s,
    }
}

/// [`price_stages`] over the O(tiles) aggregate record.
pub fn price_aggregate_stages(a: &AggregateWorkload, variant: HardwareVariant) -> StagePrices {
    let (frontend_cost, mut raster_cost) = cost_models_for(variant);
    let (front_s, refresh_floor_s, sorted_front_s, broadcast_s) = frontend_prices(
        frontend_cost.as_ref(),
        a.frontend_work(),
        a.tiles.iter().map(|t| t.list_len).sum::<usize>(),
    );
    let raster = raster_cost.raster_cost_aggregate(a);
    let shared_lookup_s = if a.cache_shared {
        raster_cost.shared_lookup_cost_s(a.width * a.height, a.shared_probe_len)
    } else {
        0.0
    };
    StagePrices {
        front_s,
        refresh_floor_s,
        sorted_front_s,
        broadcast_s,
        raster_s: raster.time_s + raster_cost.overhead_s(),
        structural_s: raster_cost.overhead_s() + shared_lookup_s,
    }
}

/// [`price_workload`] under a `depth`-slot frame pipeline: per-frame
/// device time is `max(frontend, raster + overhead)` at depth >= 2 —
/// the arithmetic the planner must use for a pool that overlaps frame
/// N+1's frontend with frame N's rasterization, or it would refuse
/// viewers the pipelined device can actually hold.
pub fn price_workload_at_depth(
    w: &FrameWorkload,
    variant: HardwareVariant,
    depth: usize,
) -> f64 {
    let p = price_stages(w, variant);
    combine_stage_times(p.front_s, p.raster_s, depth)
}

/// [`price_workload_at_depth`] over the O(tiles) aggregate record — the
/// fast rung-pricing path ([`PricingMode::Aggregate`]).
pub fn price_aggregate_at_depth(
    a: &AggregateWorkload,
    variant: HardwareVariant,
    depth: usize,
) -> f64 {
    let p = price_aggregate_stages(a, variant);
    combine_stage_times(p.front_s, p.raster_s, depth)
}

/// Picks the cheapest tier mix (best quality first) that holds a
/// per-pool simulated-FPS target.
pub struct AdmissionController {
    target_fps: f64,
    ladder: Vec<Tier>,
    reduced_fraction: f64,
    /// Frame-slot depth the pool serves at: depth >= 2 prices a frame
    /// as `max(frontend, raster + overhead)` instead of the sum.
    pipeline_depth: usize,
    /// Exact per-pixel rung pricing vs the O(tiles) aggregate path.
    pricing: PricingMode,
    /// Frames per pool epoch — the amortization window for clustered
    /// sessions' per-epoch sorts *and* for the pipeline's fill/drain
    /// cost ([`combine_stage_times_epoch`]). Defaults to 1 (sort and
    /// fill/drain charged in full per frame, the conservative end).
    epoch_frames: usize,
}

impl AdmissionController {
    /// `ladder` is quality-ordered, best first; demotion walks down it.
    /// Defaults to synchronous (depth 1) exact pricing; see
    /// [`Self::with_pipeline_depth`] and [`Self::with_pricing`].
    pub fn new(target_fps: f64, ladder: Vec<Tier>, reduced_fraction: f64) -> Result<Self> {
        ensure!(
            target_fps > 0.0 && target_fps.is_finite(),
            "admission target must be a positive fps, got {target_fps}"
        );
        ensure!(!ladder.is_empty(), "tier ladder is empty");
        ensure!(
            reduced_fraction > 0.0 && reduced_fraction <= 1.0,
            "reduced fraction must be in (0, 1], got {reduced_fraction}"
        );
        Ok(AdmissionController {
            target_fps,
            ladder,
            reduced_fraction,
            pipeline_depth: 1,
            pricing: PricingMode::Exact,
            epoch_frames: 1,
        })
    }

    /// Price frames for a `depth`-slot pipelined pool (clamped to the
    /// supported 1..=3 range). Depths 2 and 3 price identically — the
    /// steady-state device time is `max(frontend, raster + overhead)`
    /// either way; depth 3 only changes *scheduling* granularity
    /// (raster sub-stages), not the per-frame work.
    pub fn with_pipeline_depth(mut self, depth: usize) -> Self {
        self.pipeline_depth = depth.clamp(1, 3);
        self
    }

    /// Select the rung-pricing path.
    pub fn with_pricing(mut self, pricing: PricingMode) -> Self {
        self.pricing = pricing;
        self
    }

    /// Amortize clustered sessions' per-epoch sorts and the pipeline's
    /// fill/drain cost over `epoch_frames` frames (clamped to >= 1).
    pub fn with_epoch_frames(mut self, epoch_frames: usize) -> Self {
        self.epoch_frames = epoch_frames.max(1);
        self
    }

    /// Build from the `[pool]` config block (`pool.target_fps` must be
    /// set); picks up `pool.pipeline_depth`, `pool.pricing`, and
    /// `pool.epoch_frames`.
    pub fn from_config(cfg: &LuminaConfig) -> Result<Self> {
        Ok(Self::new(
            cfg.pool.target_fps,
            cfg.pool.tiers.clone(),
            cfg.pool.reduced_fraction,
        )?
        .with_pipeline_depth(cfg.pool.pipeline_depth)
        .with_pricing(cfg.pool.pricing)
        .with_epoch_frames(cfg.pool.epoch_frames))
    }

    pub fn target_fps(&self) -> f64 {
        self.target_fps
    }

    pub fn ladder(&self) -> &[Tier] {
        &self.ladder
    }

    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    pub fn pricing(&self) -> PricingMode {
        self.pricing
    }

    pub fn epoch_frames(&self) -> usize {
        self.epoch_frames
    }

    /// Plan a tier per session. Starts everyone at the ladder's best
    /// tier and demotes one step at a time until the priced mix fits
    /// the budget, spreading the pain: among demotable sessions it
    /// picks the least-demoted first, breaking ties toward lower
    /// priority and then toward the later session. (Level-first order
    /// walks through every "prefix of sessions one rung down" mix, so
    /// a feasible mix is never skipped even when a lower rung prices
    /// higher than the one above it.) Re-planning each epoch restarts
    /// from all-best, so sessions promote back up automatically
    /// whenever headroom appears. Refuses admission when no mix fits.
    pub fn plan(&self, demands: &[SessionDemand]) -> Result<TierPlan> {
        ensure!(!demands.is_empty(), "cannot plan an empty pool");
        let budget_s = (1.0 - ADMISSION_HEADROOM) / self.target_fps;

        // Per-session rungs: the ladder tiers the session can actually
        // serve, each priced by re-scaling the measured workload from
        // the tier it was measured under. The aggregate path collapses
        // the per-pixel record once per session (O(pixels)), then every
        // rung re-scales and prices in O(tiles).
        let mut rungs: Vec<Vec<(Tier, f64)>> = Vec::with_capacity(demands.len());
        for d in demands {
            let agg = (self.pricing == PricingMode::Aggregate)
                .then(|| d.workload.aggregate());
            // Shared scope prices the raster stage with the pool-wide
            // observed hit rate: a viewer joining a warm pool inherits
            // the pool's hits (the snapshot outlives any one session's
            // tier swaps), so the cold-cache price would systematically
            // refuse viewers the shared device actually holds. Private
            // scope keeps the conservative cold-cache price unchanged.
            let base_discount = if d.cache_shared {
                1.0 - d.pool_hit_rate.clamp(0.0, 1.0) * SHARED_HIT_RASTER_SAVINGS
            } else {
                1.0
            };
            let r: Vec<(Tier, f64)> = self
                .ladder
                .iter()
                .copied()
                .filter(|&t| d.supports(t))
                .map(|t| {
                    let p = match &agg {
                        Some(a) => price_aggregate_stages(
                            &a.tier_estimate(d.tier, t, self.reduced_fraction),
                            d.variant,
                        ),
                        None => price_stages(
                            &d.workload.tier_estimate(d.tier, t, self.reduced_fraction),
                            d.variant,
                        ),
                    };
                    // The observed rate only transfers to rungs that
                    // keep the session's cache geometry: full and
                    // reduced share the render grid (one snapshot),
                    // while the half-res tier re-attaches to a
                    // different — possibly cold — snapshot, so
                    // geometry-changing rungs are priced cold. The
                    // world scope is the exception: its keys quantize
                    // Gaussian positions, not pixels, so the same
                    // snapshot serves every resolution and the rate
                    // transfers across geometry-changing rungs too.
                    let same_geometry = (t == Tier::Half) == (d.tier == Tier::Half);
                    let hit_discount =
                        if same_geometry || d.cache_world { base_discount } else { 1.0 };
                    // Clustered-S² frontend amortization. On the rung
                    // that keeps a follower in its (multi-member)
                    // cluster, it pays refresh + broadcast instead of
                    // the sort. Every other clustered rung — the
                    // leader's, a singleton cluster's, or any tier
                    // change (which alters the sort geometry and drops
                    // the session to a singleton until the next
                    // re-cluster) — runs one sort per epoch, priced as
                    // the epoch-amortized `sorted_front_s` over the
                    // refresh floor. The measured frame of a clustered
                    // session is almost always a reuse frame carrying
                    // no sort work of its own; pricing it as measured
                    // would omit every cluster's sort from every plan.
                    let front_s = if d.sort_clustered {
                        let amortized = p.refresh_floor_s
                            + (p.sorted_front_s - p.refresh_floor_s)
                                / self.epoch_frames as f64;
                        if t == d.tier && d.sort_sharers >= 2 && !d.sort_leader {
                            // Floored at the measured price: a follower
                            // whose kill switch is tripping sorts
                            // privately every frame, and that measured
                            // cost must not be amortized away.
                            p.front_s.max(p.follower_front_s())
                        } else {
                            p.front_s.max(amortized)
                        }
                    } else {
                        p.front_s
                    };
                    // Critical-path epoch pricing: steady-state overlap
                    // plus the epoch's fill/drain share, so the planner
                    // charges exactly the device time an epoch-drained
                    // pipeline occupies (either scheduler).
                    let price = combine_stage_times_epoch(
                        front_s,
                        p.discounted_raster_s(hit_discount),
                        self.pipeline_depth,
                        self.epoch_frames,
                    );
                    (t, price)
                })
                .collect();
            ensure!(
                !r.is_empty(),
                "no tier in the ladder [{}] is servable by a {} session",
                Tier::ladder_name(&self.ladder),
                d.variant.label()
            );
            rungs.push(r);
        }

        let mut level = vec![0usize; demands.len()];
        let mut total: f64 = rungs.iter().map(|r| r[0].1).sum();
        while total > budget_s {
            // Least-demoted session first; among those, lowest priority.
            let mut pick: Option<usize> = None;
            for (i, d) in demands.iter().enumerate() {
                if level[i] + 1 >= rungs[i].len() {
                    continue;
                }
                let better = match pick {
                    None => true,
                    Some(p) => {
                        level[i] < level[p]
                            || (level[i] == level[p] && d.priority <= demands[p].priority)
                    }
                };
                if better {
                    pick = Some(i);
                }
            }
            let Some(i) = pick else {
                bail!(
                    "admission refused: {} sessions cannot sustain {:.1} pool fps even at \
                     the lowest tier (predicted {:.1} fps, budget {:.3} ms/frame, \
                     predicted {:.3} ms/frame)",
                    demands.len(),
                    self.target_fps,
                    1.0 / total,
                    budget_s * 1e3,
                    total * 1e3
                );
            };
            total -= rungs[i][level[i]].1;
            level[i] += 1;
            total += rungs[i][level[i]].1;
        }

        let tiers = level.iter().zip(&rungs).map(|(&l, r)| r[l].0).collect();
        Ok(TierPlan { tiers, predicted_time_s: total, budget_s })
    }

    /// Each session's lowest servable rung — the best-effort fallback a
    /// pool pins admitted viewers to when a mid-run re-plan cannot fit.
    pub fn floor_tiers(&self, demands: &[SessionDemand]) -> Vec<Tier> {
        demands
            .iter()
            .map(|d| {
                self.ladder
                    .iter()
                    .rev()
                    .copied()
                    .find(|&t| d.supports(t))
                    .unwrap_or(Tier::Full)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lumina::rc::CacheStats;

    fn demand(px: usize, priority: f64) -> SessionDemand {
        let side = (px as f64).sqrt() as usize;
        let tiles = side.div_ceil(16);
        SessionDemand {
            workload: FrameWorkload {
                frame: 0,
                width: side,
                height: side,
                tile_size: 16,
                tiles_x: tiles,
                tiles_y: tiles,
                tile_list_lens: vec![100; tiles * tiles],
                scene_gaussians: 10_000,
                sorted: true,
                sort_entries: 50_000,
                bin_candidates: 60_000,
                refreshed_gaussians: 0,
                consumed: vec![100; side * side],
                significant: vec![10; side * side],
                uncached: None,
                cache_outcomes: None,
                cache: CacheStats::default(),
                cache_shared: false,
                shared_probe_len: 1,
                swap_bytes: 0,
            },
            tier: Tier::Full,
            variant: HardwareVariant::Gpu,
            half_capable: true,
            priority,
            cache_shared: false,
            cache_world: false,
            pool_hit_rate: 0.0,
            sort_clustered: false,
            sort_sharers: 1,
            sort_leader: true,
        }
    }

    /// A demand shaped like an S² session's sorted frame: the frontend
    /// carries projection + sorting + a per-frame refresh, so the
    /// clustered amortization has something to strip and a floor to
    /// respect.
    fn s2_demand(priority: f64) -> SessionDemand {
        let mut d = demand(128 * 128, priority);
        d.workload.refreshed_gaussians = 8_000;
        d
    }

    fn ladder() -> Vec<Tier> {
        vec![Tier::Full, Tier::Reduced, Tier::Half]
    }

    #[test]
    fn generous_target_keeps_everyone_full() {
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        // Target low enough that 3 full sessions fit with headroom.
        let target = 0.5 * (1.0 - ADMISSION_HEADROOM) / (3.0 * one);
        let ctrl = AdmissionController::new(target, ladder(), 0.5).unwrap();
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let plan = ctrl.plan(&demands).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3]);
        assert!(plan.predicted_pool_fps() >= target);
    }

    #[test]
    fn pressure_demotes_lowest_priority_first() {
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        // Budget fits ~2.5 full-tier sessions: someone must drop.
        let target = (1.0 - ADMISSION_HEADROOM) / (2.5 * one);
        let ctrl = AdmissionController::new(target, ladder(), 0.5).unwrap();
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let plan = ctrl.plan(&demands).unwrap();
        assert_eq!(plan.tiers[0], Tier::Full, "highest priority demoted first");
        assert_ne!(plan.tiers[2], Tier::Full, "lowest priority kept full under pressure");
        assert!(plan.predicted_time_s <= plan.budget_s);
    }

    #[test]
    fn impossible_target_refuses_admission() {
        let ctrl = AdmissionController::new(1e9, ladder(), 0.5).unwrap();
        let demands = vec![demand(128 * 128, 1.0), demand(128 * 128, 0.0)];
        let err = ctrl.plan(&demands).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("admission refused"), "unhelpful refusal: {msg}");
    }

    #[test]
    fn demoted_tiers_price_cheaper() {
        let d = demand(128 * 128, 0.0);
        let full = price_workload(
            &d.workload.tier_estimate(Tier::Full, Tier::Full, 0.5),
            d.variant,
        );
        let reduced = price_workload(
            &d.workload.tier_estimate(Tier::Full, Tier::Reduced, 0.5),
            d.variant,
        );
        let half = price_workload(
            &d.workload.tier_estimate(Tier::Full, Tier::Half, 0.5),
            d.variant,
        );
        assert!(reduced < full, "reduced {reduced} !< full {full}");
        assert!(half < full, "half {half} !< full {full}");
    }

    #[test]
    fn half_incapable_sessions_never_planned_onto_the_half_rung() {
        // ds2-gpu (already half) and odd-dimension sessions both report
        // half_capable = false; the planner must respect it.
        let mut d = demand(64 * 64, 0.0);
        d.variant = HardwareVariant::Ds2Gpu;
        d.half_capable = false;
        let one = price_workload(&d.workload, HardwareVariant::Ds2Gpu);
        // Tight enough to force demotion off full: the only legal rung
        // below is reduced — never half (set_tier would reject it).
        let target = (1.0 - ADMISSION_HEADROOM) / (0.8 * one);
        let ctrl = AdmissionController::new(target, ladder(), 0.5).unwrap();
        let plan = ctrl.plan(&[d]).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Reduced]);
        // And the best-effort floor is reduced, not half.
        let d2 = SessionDemand { half_capable: false, ..demand(64 * 64, 0.0) };
        assert_eq!(ctrl.floor_tiers(&[d2]), vec![Tier::Reduced]);
    }

    #[test]
    fn pipelined_pricing_is_the_stage_max() {
        let d = demand(128 * 128, 0.0);
        let synchronous = price_workload_at_depth(&d.workload, d.variant, 1);
        let pipelined = price_workload_at_depth(&d.workload, d.variant, 2);
        assert!(pipelined < synchronous, "overlap must price below the stage sum");
        assert_eq!(synchronous, price_workload(&d.workload, d.variant));
        // max(frontend, raster+overhead) decomposition: the two depths
        // bound each other by the frontend share.
        assert!(pipelined * 2.0 >= synchronous, "max >= sum/2");
    }

    #[test]
    fn pipelined_controller_admits_what_sum_pricing_refuses_to_keep_full() {
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        // Budget fits ~2.5 sum-priced sessions: synchronous pricing must
        // demote someone, overlapped pricing holds all three at full
        // (the frontend share is well above the ~17% break-even). A
        // long epoch keeps the fill/drain share negligible, so this
        // pins the steady-state overlap win.
        let target = (1.0 - ADMISSION_HEADROOM) / (2.5 * one);
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let sync = AdmissionController::new(target, ladder(), 0.5).unwrap();
        assert_eq!(sync.pipeline_depth(), 1);
        let plan = sync.plan(&demands).unwrap();
        assert!(plan.tiers.iter().any(|&t| t != Tier::Full));
        let piped = AdmissionController::new(target, ladder(), 0.5)
            .unwrap()
            .with_pipeline_depth(2)
            .with_epoch_frames(1024);
        assert_eq!(piped.pipeline_depth(), 2);
        let plan = piped.plan(&demands).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3], "pipelined device holds all three");
    }

    #[test]
    fn epoch_pricing_charges_the_fill_drain_gap() {
        // Per-frame epoch price: sum at e = 1 (no overlap in a
        // one-frame epoch), monotonically down toward the steady-state
        // max as the epoch lengthens, never below it.
        let (f, r) = (0.3, 0.7);
        let sum = combine_stage_times(f, r, 1);
        let max = combine_stage_times(f, r, 2);
        assert_eq!(combine_stage_times_epoch(f, r, 2, 1), sum);
        let mut last = f64::INFINITY;
        for e in [1, 2, 4, 8, 1024] {
            let p = combine_stage_times_epoch(f, r, 2, e);
            assert!(p <= last, "per-frame price must fall as the epoch grows");
            assert!(p >= max, "fill/drain can only add to the steady-state price");
            // Critical-path identity: e frames occupy e*max + min.
            assert!((p * e as f64 - (max * e as f64 + f.min(r))).abs() < 1e-12);
            last = p;
        }
        // Depth 1 has no overlap to fill or drain: epoch-independent.
        assert_eq!(combine_stage_times_epoch(f, r, 1, 7), sum);
        // Zero-guard: e = 0 clamps to 1 rather than dividing by zero.
        assert_eq!(combine_stage_times_epoch(f, r, 2, 0), sum);
    }

    #[test]
    fn pipelined_controller_refuses_short_epoch_fill_drain_overload() {
        // A budget sitting between the steady-state price and the
        // 2-frame-epoch price: the old planner (steady-state max) would
        // admit all three at full, but a pool draining every 2 frames
        // pays the fill/drain gap and must demote. Separates the two
        // models with the same demands.
        let d = demand(128 * 128, 0.0);
        let p = price_stages(&d.workload, d.variant);
        let steady = combine_stage_times(p.front_s, p.raster_s, 2);
        let short = combine_stage_times_epoch(p.front_s, p.raster_s, 2, 2);
        assert!(short > steady);
        let budget = 3.0 * (steady + short) / 2.0;
        let target = (1.0 - ADMISSION_HEADROOM) / budget;
        let demands = vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)];
        let long = AdmissionController::new(target, ladder(), 0.5)
            .unwrap()
            .with_pipeline_depth(2)
            .with_epoch_frames(1 << 20);
        assert_eq!(
            long.plan(&demands).unwrap().tiers,
            vec![Tier::Full; 3],
            "steady-state pricing holds all three at full"
        );
        let short_epochs = AdmissionController::new(target, ladder(), 0.5)
            .unwrap()
            .with_pipeline_depth(2)
            .with_epoch_frames(2);
        let plan = short_epochs.plan(&demands).unwrap();
        assert!(
            plan.tiers.iter().any(|&t| t != Tier::Full),
            "2-frame epochs pay fill/drain; the same budget cannot hold all three at full"
        );
    }

    #[test]
    fn aggregate_pricing_pins_exact_demotion_decisions() {
        // Uniform synthetic demands: the aggregate transforms are exact,
        // so the two pricing paths must plan identical tier mixes across
        // the whole pressure range, and refuse identically.
        let one = price_workload(&demand(128 * 128, 0.0).workload, HardwareVariant::Gpu);
        let demands = || {
            vec![demand(128 * 128, 3.0), demand(128 * 128, 2.0), demand(128 * 128, 1.0)]
        };
        for fit in [6.0, 3.2, 2.5, 2.2, 1.6, 1.1, 0.8] {
            let target = (1.0 - ADMISSION_HEADROOM) / (fit * one);
            let exact = AdmissionController::new(target, ladder(), 0.5).unwrap();
            let fast = AdmissionController::new(target, ladder(), 0.5)
                .unwrap()
                .with_pricing(PricingMode::Aggregate);
            assert_eq!(fast.pricing(), PricingMode::Aggregate);
            match (exact.plan(&demands()), fast.plan(&demands())) {
                (Ok(e), Ok(f)) => {
                    assert_eq!(e.tiers, f.tiers, "plans diverged at fit={fit}");
                }
                (Err(_), Err(_)) => {} // both refuse: also parity
                (e, f) => panic!(
                    "pricing paths disagree on admission at fit={fit}: exact {:?} vs \
                     aggregate {:?}",
                    e.map(|p| p.tiers),
                    f.map(|p| p.tiers)
                ),
            }
        }
    }

    #[test]
    fn pool_hit_rate_discount_admits_what_cold_pricing_refuses() {
        // Shared-scope demands at a high observed pool hit rate price
        // their raster stage cheaper; a budget sitting between the
        // discounted and undiscounted sums separates the two plans.
        let mk = |rate: f64| -> Vec<SessionDemand> {
            (0..3)
                .map(|i| SessionDemand {
                    cache_shared: true,
                    pool_hit_rate: rate,
                    ..demand(128 * 128, (3 - i) as f64)
                })
                .collect()
        };
        let d = demand(128 * 128, 0.0);
        let p = price_stages(&d.workload, d.variant);
        let cold = p.front_s + p.raster_s;
        let warm = p.front_s + p.discounted_raster_s(1.0 - 0.9 * SHARED_HIT_RASTER_SAVINGS);
        assert!(warm < cold);
        assert!(
            p.discounted_raster_s(0.0) >= p.structural_s,
            "even a perfect hit rate cannot discount the structural floor"
        );
        let per_session = (cold + warm) / 2.0;
        let target = (1.0 - ADMISSION_HEADROOM) / (3.0 * per_session);
        let ctrl = AdmissionController::new(target, vec![Tier::Full], 0.5).unwrap();
        assert!(ctrl.plan(&mk(0.0)).is_err(), "cold pricing must refuse");
        let plan = ctrl.plan(&mk(0.9)).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3], "warm pool holds all three");
        // Private scope ignores the rate entirely.
        let mut private = mk(0.9);
        for p in private.iter_mut() {
            p.cache_shared = false;
        }
        assert!(ctrl.plan(&private).is_err(), "discount must be shared-scope only");

        // Geometry-changing rungs are never discounted: the half-res
        // tier re-attaches to a different (possibly cold) snapshot, so
        // the observed rate does not transfer there.
        let ph = price_stages(&d.workload.tier_estimate(Tier::Full, Tier::Half, 0.5), d.variant);
        let half_cold = ph.front_s + ph.raster_s;
        let half_target = (1.0 - ADMISSION_HEADROOM) / (3.0 * half_cold * 0.9);
        let half_ctrl = AdmissionController::new(half_target, vec![Tier::Half], 0.5).unwrap();
        assert!(
            half_ctrl.plan(&mk(0.9)).is_err(),
            "a half rung from full-tier demands must price cold"
        );
    }

    #[test]
    fn follower_front_price_sits_between_refresh_floor_and_full_frontend() {
        let d = s2_demand(0.0);
        let p = price_stages(&d.workload, d.variant);
        assert!(p.refresh_floor_s > 0.0, "refresh work must price above zero");
        assert!(p.broadcast_s > 0.0, "sharing a sort is not free");
        assert!(
            p.follower_front_s() >= p.refresh_floor_s,
            "amortization must never discount below the refresh floor"
        );
        assert!(
            p.follower_front_s() < p.front_s,
            "a follower must price below a sorting session: follower {} vs full {}",
            p.follower_front_s(),
            p.front_s
        );
        // A sorted workload's as-if-sorted price is its real price.
        assert_eq!(p.sorted_front_s, p.front_s);
        // Stripping the sort from an unsorted workload changes nothing:
        // the floor equals the full frontend price.
        let mut unsorted = d.workload.clone();
        unsorted.sorted = false;
        unsorted.sort_entries = 0;
        unsorted.bin_candidates = 0;
        let pu = price_stages(&unsorted, d.variant);
        assert_eq!(pu.front_s, pu.refresh_floor_s);
        // Aggregate path carries the same floors.
        let pa = price_aggregate_stages(&d.workload.aggregate(), d.variant);
        assert!((pa.refresh_floor_s - p.refresh_floor_s).abs() < 1e-15);
        assert!((pa.broadcast_s - p.broadcast_s).abs() < 1e-15);
    }

    #[test]
    fn tier_change_rungs_price_the_sort_a_cluster_exit_implies() {
        // Steady state: a clustered session's measured frame *reused*
        // the cluster sort, so its record carries no sort work. The
        // as-if-sorted estimate (from the frozen tile-list total) must
        // still price the sort a cluster exit implies — above both the
        // refresh floor and the follower's amortized price — or
        // demotion would look frontend-free.
        let mut d = s2_demand(0.0);
        d.sort_clustered = true;
        d.sort_sharers = 3;
        d.sort_leader = false;
        d.workload.sorted = false;
        d.workload.sort_entries = 0;
        d.workload.bin_candidates = 0;
        let p = price_stages(&d.workload, d.variant);
        assert_eq!(p.front_s, p.refresh_floor_s, "reuse frames measure no sort");
        assert!(
            p.sorted_front_s > p.follower_front_s(),
            "a private re-sort must price above the amortized follower frontend: \
             sorted {} vs follower {}",
            p.sorted_front_s,
            p.follower_front_s()
        );
        let pa = price_aggregate_stages(&d.workload.aggregate(), d.variant);
        assert!((pa.sorted_front_s - p.sorted_front_s).abs() <= 1e-12 * p.sorted_front_s);
    }

    #[test]
    fn steady_state_cluster_pricing_charges_the_per_epoch_sort() {
        // Steady state: every clustered demand is an unsorted reuse
        // frame. A clustered session's sorting rung (here a singleton
        // cluster's) must still carry the per-epoch sort, amortized
        // over the epoch — pricing the measured (refresh-only) frame
        // would omit every cluster's sort from every plan and
        // over-admit.
        let mk = |clustered: bool| {
            let mut d = s2_demand(1.0);
            d.workload.sorted = false;
            d.workload.sort_entries = 0;
            d.workload.bin_candidates = 0;
            d.sort_clustered = clustered;
            d.sort_sharers = 1;
            d.sort_leader = true;
            d
        };
        let d = mk(true);
        let p = price_stages(&d.workload, d.variant);
        let epoch = 4usize;
        let amortized =
            p.refresh_floor_s + (p.sorted_front_s - p.refresh_floor_s) / epoch as f64;
        assert!(amortized > p.refresh_floor_s);
        // Budget between the refresh-only and amortized-sort totals.
        let budget = p.raster_s + (p.refresh_floor_s + amortized) / 2.0;
        let target = (1.0 - ADMISSION_HEADROOM) / budget;
        let ctrl = AdmissionController::new(target, vec![Tier::Full], 0.5)
            .unwrap()
            .with_epoch_frames(epoch);
        assert_eq!(ctrl.epoch_frames(), epoch);
        assert!(ctrl.plan(&[mk(true)]).is_err(), "the per-epoch sort must be priced");
        // A private-scope S² session still prices its measured frame
        // (steady-state amortization for private windows is a recorded
        // ROADMAP follow-on, unchanged here).
        assert!(ctrl.plan(&[mk(false)]).is_ok());
    }

    #[test]
    fn cluster_amortization_prices_followers_below_singleton_sorters() {
        // Steady state (unsorted reuse frames): one leader sort per
        // epoch serves the whole cluster, so a 3-member cluster must
        // fit a budget that three singleton clusters — each paying its
        // own per-epoch sort — miss.
        let epoch = 2usize;
        let mk = |sharers: usize, leader: bool, priority: f64| {
            let mut d = s2_demand(priority);
            d.workload.sorted = false;
            d.workload.sort_entries = 0;
            d.workload.bin_candidates = 0;
            d.sort_clustered = true;
            d.sort_sharers = sharers;
            d.sort_leader = leader;
            d
        };
        let cluster = || vec![mk(3, true, 3.0), mk(3, false, 2.0), mk(3, false, 1.0)];
        let singletons = || vec![mk(1, true, 3.0), mk(1, true, 2.0), mk(1, true, 1.0)];
        let d = mk(3, false, 0.0);
        let p = price_stages(&d.workload, d.variant);
        let amortized =
            p.refresh_floor_s + (p.sorted_front_s - p.refresh_floor_s) / epoch as f64;
        let leader_total = amortized + p.raster_s;
        let follower_total = p.follower_front_s() + p.raster_s;
        assert!(follower_total < leader_total, "amortization must bite");
        // Budget: one sorter + two followers fit; three sorters miss.
        let budget = 2.0 * leader_total + follower_total;
        let target = (1.0 - ADMISSION_HEADROOM) / budget;
        let ctrl = AdmissionController::new(target, vec![Tier::Full], 0.5)
            .unwrap()
            .with_epoch_frames(epoch);
        assert!(ctrl.plan(&singletons()).is_err(), "three solo sorters must refuse");
        let plan = ctrl.plan(&cluster()).unwrap();
        assert_eq!(plan.tiers, vec![Tier::Full; 3], "one shared sort fits all three");

        // A follower that is measurably sorting every frame (a tripping
        // kill switch) is floored at its measured price: the target
        // that admitted the healthy cluster refuses when all three
        // members measure full sorts.
        let killed: Vec<SessionDemand> = cluster()
            .into_iter()
            .map(|mut d| {
                d.workload.sorted = true;
                d.workload.sort_entries = 50_000;
                d.workload.bin_candidates = 60_000;
                d
            })
            .collect();
        assert!(ctrl.plan(&killed).is_err(), "measured sorts must not amortize away");
    }

    #[test]
    fn controller_validates_inputs() {
        assert!(AdmissionController::new(0.0, ladder(), 0.5).is_err());
        assert!(AdmissionController::new(30.0, vec![], 0.5).is_err());
        assert!(AdmissionController::new(30.0, ladder(), 0.0).is_err());
        let ctrl = AdmissionController::new(30.0, ladder(), 0.5).unwrap();
        assert!(ctrl.plan(&[]).is_err());
    }
}
