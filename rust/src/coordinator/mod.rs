//! The frame-loop coordinator: LuminSys end-to-end (paper Fig. 14).
//!
//! Per frame: ingest the pose, run the variant's algorithm path
//! functionally (baseline 3DGS, S^2 sorting-sharing, radiance-cached
//! rasterization, or their combination), hand the *measured* workload to
//! the hardware cost models (GPU / LuminCore / GSCore), and log quality
//! + performance + energy. This is the Layer-3 system contribution: Rust
//! owns the loop, the scheduling, and every model; Python never runs.

pub mod report;

use anyhow::{Context, Result};

use crate::camera::trajectory::{generate, Trajectory};
use crate::camera::{Intrinsics, Pose};
use crate::config::{HardwareVariant, LuminaConfig};
use crate::constants::TILE;
use crate::lumina::ds2::render_ds2;
use crate::lumina::rc::{rasterize_cached, CacheStats, GroupedRadianceCache};
use crate::lumina::s2::S2Scheduler;
use crate::pipeline::image::Image;
use crate::pipeline::project::project;
use crate::pipeline::raster::{rasterize, RasterConfig, RasterStats};
use crate::pipeline::sort::bin_and_sort;
use crate::scene::synth::synth_scene;
use crate::scene::GaussianScene;
use crate::sim::energy::{EnergyBreakdown, EnergyModel};
use crate::sim::gpu::{GpuModel, GpuStageTimes, WarpAggregates};
use crate::sim::gscore::GsCoreModel;
use crate::sim::lumincore::{tiles_from_stats, LuminCoreSim};

pub use report::{FrameReport, RunReport};

/// Which units execute projection+sorting for a variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontendHw {
    Gpu,
    /// GSCore's CCU + GSU (Sec. 6.4 comparison).
    CcuGsu,
}

/// The LuminSys coordinator.
pub struct Coordinator {
    pub cfg: LuminaConfig,
    pub scene: GaussianScene,
    pub intr: Intrinsics,
    pub trajectory: Trajectory,
    pub gpu: GpuModel,
    pub lumincore: LuminCoreSim,
    pub gscore: GsCoreModel,
    pub energy: EnergyModel,
    /// Frontend hardware override (defaults by variant).
    pub frontend: FrontendHw,
    s2: Option<S2Scheduler>,
    rc: Option<GroupedRadianceCache>,
    frame_idx: usize,
}

/// Everything one frame produced.
pub struct FrameResult {
    pub image: Image,
    pub report: FrameReport,
}

impl Coordinator {
    /// Build a coordinator from a config (synthesizes or loads the scene,
    /// generates the trajectory, instantiates algorithm state).
    pub fn new(cfg: LuminaConfig) -> Result<Self> {
        let scene = match &cfg.scene.path {
            Some(p) => crate::scene::io::read_scene(p)
                .with_context(|| format!("loading scene {p}"))?,
            None => synth_scene(cfg.scene.class, cfg.scene.seed, cfg.gaussian_count()),
        };
        let intr = cfg.intrinsics();
        let trajectory = generate(
            cfg.camera.trajectory,
            cfg.camera.seed,
            cfg.camera.frames,
            cfg.scene.class.extent(),
        );
        let (tiles_x, tiles_y) = intr.tiles(TILE);
        let s2 = cfg.variant.uses_s2().then(|| {
            S2Scheduler::new(cfg.s2.sharing_window, cfg.s2.expanded_margin, TILE, cfg.near, cfg.far)
        });
        let rc = cfg
            .variant
            .uses_rc()
            .then(|| GroupedRadianceCache::new(tiles_x, tiles_y, cfg.rc.alpha_record));
        let frontend = match cfg.variant {
            HardwareVariant::GsCore | HardwareVariant::LuminaOnGscoreFrontend => {
                FrontendHw::CcuGsu
            }
            _ => FrontendHw::Gpu,
        };
        Ok(Coordinator {
            cfg,
            scene,
            intr,
            trajectory,
            gpu: GpuModel::xavier_volta(),
            lumincore: LuminCoreSim::paper_default(),
            gscore: GsCoreModel::published(),
            energy: EnergyModel::nm12(),
            frontend,
            s2,
            rc,
            frame_idx: 0,
        })
    }

    /// Reference (exact 3DGS) render at a pose, with stats.
    pub fn reference_frame(&self, pose: &Pose) -> (Image, RasterStats, usize, usize) {
        let p = project(&self.scene, pose, &self.intr, self.cfg.near, self.cfg.far, 0.0);
        let bins = bin_and_sort(&p, &self.intr, TILE, 0.0);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let out = rasterize(&p, &bins, self.intr.width, self.intr.height, &cfg);
        (out.image, out.stats.unwrap(), p.len(), bins.total_entries())
    }

    /// Render the next frame under the configured variant.
    pub fn step(&mut self) -> Result<FrameResult> {
        let pose = *self
            .trajectory
            .poses
            .get(self.frame_idx)
            .context("trajectory exhausted")?;
        let idx = self.frame_idx;
        self.frame_idx += 1;
        self.render_at(idx, &pose)
    }

    /// Frames remaining in the trajectory.
    pub fn remaining(&self) -> usize {
        self.trajectory.poses.len().saturating_sub(self.frame_idx)
    }

    /// Run the full trajectory.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut report = RunReport::new(self.cfg.variant.label());
        while self.remaining() > 0 {
            let f = self.step()?;
            report.push(f.report);
        }
        Ok(report)
    }

    fn render_at(&mut self, idx: usize, pose: &Pose) -> Result<FrameResult> {
        let (w, h) = (self.intr.width, self.intr.height);
        let variant = self.cfg.variant;

        // --- Functional algorithm path -------------------------------
        // Projection + sorting (shared or per-frame).
        let mut s2_sorted = true; // whether proj+sort ran this frame
        let sort_entries;
        let (projected, bins) = if let Some(s2) = self.s2.as_mut() {
            let f = s2.frame(&self.scene, pose, &self.intr);
            s2_sorted = f.work.sorted;
            sort_entries = if s2_sorted { f.work.sort_entries } else { 0 };
            (f.projected, f.bins)
        } else {
            let p =
                project(&self.scene, pose, &self.intr, self.cfg.near, self.cfg.far, 0.0);
            let bins = bin_and_sort(&p, &self.intr, TILE, 0.0);
            sort_entries = bins.total_entries();
            (p, bins)
        };

        // Rasterization: cached or plain, always with stats.
        let raster_cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let (image, consumed, significant, cache_outcomes, cache_stats, swap_bytes) =
            if let Some(rc) = self.rc.as_mut() {
                let out = rasterize_cached(&projected, &bins, w, h, rc);
                let consumed: Vec<u32> = out.outcomes.iter().map(|o| o.iterated).collect();
                let sig: Vec<u32> = out.outcomes.iter().map(|o| o.significant).collect();
                let cache: Vec<u8> = out
                    .outcomes
                    .iter()
                    .map(|o| if o.hit { 2u8 } else { 1u8 })
                    .collect();
                let swap = rc.swap_traffic_bytes() as u64;
                (out.image, consumed, sig, Some(cache), out.stats, swap)
            } else {
                let out = rasterize(&projected, &bins, w, h, &raster_cfg);
                let stats = out.stats.unwrap();
                (
                    out.image,
                    stats.iterated.clone(),
                    stats.significant.clone(),
                    None,
                    CacheStats::default(),
                    0,
                )
            };

        // DS-2 is a pure-software baseline variant rendered separately by
        // the fig20 harness; the coordinator handles the hardware variants.
        let _ = render_ds2; // referenced for documentation purposes

        // --- Hardware cost models ------------------------------------
        // GPU raster aggregates use the *actual* per-pixel work.
        let stats_for_gpu = RasterStats {
            iterated: consumed.clone(),
            significant: significant.clone(),
        };
        let agg = WarpAggregates::from_stats(&stats_for_gpu, w, h);

        // Frontend (projection+sorting) time/energy.
        let (front_time, front_energy_j) = match self.frontend {
            FrontendHw::Gpu => {
                // Projection processes the whole scene (frustum culling
                // touches every Gaussian), not just the survivors.
                let proj = if s2_sorted { self.gpu.projection_time_s(self.scene.len()) } else { 0.0 };
                let sort = if s2_sorted { self.gpu.sorting_time_s(sort_entries) } else { 0.0 };
                // S^2 recomputes SH colors (and light per-Gaussian
                // geometry) every frame on the GPU: ~35% of projection.
                let refresh = if self.s2.is_some() {
                    0.35 * self.gpu.projection_time_s(projected.len())
                } else {
                    0.0
                };
                let t = proj + sort + refresh;
                (t, self.energy.gpu_energy_j(t))
            }
            FrontendHw::CcuGsu => {
                let proj = if s2_sorted { self.gscore.ccu_time_s(self.scene.len()) } else { 0.0 };
                let sort = if s2_sorted { self.gscore.gsu_time_s(sort_entries) } else { 0.0 };
                let refresh = if self.s2.is_some() {
                    0.35 * self.gscore.ccu_time_s(projected.len())
                } else {
                    0.0
                };
                let t = proj + sort + refresh;
                (t, self.gscore.energy_j(t))
            }
        };

        // Rasterization time/energy per backend hardware.
        let lists: Vec<usize> = bins.lists.iter().map(|l| l.len()).collect();
        let (raster_time, raster_energy, pe_util) = if variant.uses_nru() {
            let tiles = tiles_from_stats(
                &lists,
                bins.tiles_x,
                bins.tiles_y,
                TILE,
                w,
                h,
                &consumed,
                &significant,
                cache_outcomes.as_deref(),
            );
            let frame = self.lumincore.frame(&tiles, swap_bytes);
            let mut e = frame.energy;
            // GPU idles (leakage only) while the NRUs rasterize.
            e.gpu += self.energy.gpu_idle_energy_j(frame.raster_s);
            (frame.raster_s, e, frame.pe_utilization)
        } else if variant == HardwareVariant::GsCore {
            let pairs: u64 = consumed.iter().map(|&v| v as u64).sum();
            let t = self.gscore.raster_time_s(pairs);
            let e = EnergyBreakdown { gpu: self.gscore.energy_j(t), ..Default::default() };
            (t, e, 1.0)
        } else {
            // GPU rasterization. RC-GPU pays warp-bound time: the warp
            // advances at the pace of its slowest (miss) lane, so cache
            // hits do not shorten rounds (paper Sec. 4) — charge the
            // *uncached* warp structure plus lookup/lock overhead.
            let agg_for_time = if variant.uses_rc() {
                let plain = rasterize(&projected, &bins, w, h, &raster_cfg);
                let ps = plain.stats.unwrap();
                WarpAggregates::from_stats(&ps, w, h)
            } else {
                agg
            };
            let mut t = self.gpu.raster_time_s(&agg_for_time);
            if variant.uses_rc() {
                t += self.gpu.rc_overhead_time_s(w * h);
            }
            let e = EnergyBreakdown { gpu: self.energy.gpu_energy_j(t), ..Default::default() };
            (t, e, 1.0 - agg_for_time.masked_fraction(&self.gpu))
        };

        let stage = GpuStageTimes {
            projection: front_time,
            sorting: 0.0, // folded into front_time above
            rasterization: raster_time,
            // LuminCore variants replace kernel launches with DMA
            // descriptor setup; only a sliver of overhead remains.
            overhead: self.gpu.launch_overhead_s * if variant.uses_nru() { 0.1 } else { 1.0 },
        };
        let total_time = stage.total();

        let mut energy = raster_energy;
        energy.gpu += front_energy_j;

        let report = FrameReport {
            frame: idx,
            time_s: total_time,
            frontend_s: front_time,
            raster_s: raster_time,
            energy_j: energy.total(),
            energy,
            sorted_this_frame: s2_sorted,
            cache: cache_stats,
            pe_utilization: pe_util,
            mean_iterated: consumed.iter().map(|&v| v as f64).sum::<f64>()
                / consumed.len().max(1) as f64,
            psnr_vs_ref: None,
        };
        Ok(FrameResult { image, report })
    }

    /// Render a frame and also compute quality vs the exact pipeline.
    pub fn step_with_quality(&mut self) -> Result<FrameResult> {
        let pose = *self
            .trajectory
            .poses
            .get(self.frame_idx)
            .context("trajectory exhausted")?;
        let idx = self.frame_idx;
        self.frame_idx += 1;
        let mut result = self.render_at(idx, &pose)?;
        let (reference, _, _, _) = self.reference_frame(&pose);
        result.report.psnr_vs_ref = Some(crate::metrics::psnr(&reference, &result.image));
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(variant: HardwareVariant) -> LuminaConfig {
        let mut cfg = LuminaConfig::quick_test();
        cfg.scene.count = 5000;
        cfg.camera.width = 128;
        cfg.camera.height = 128;
        cfg.camera.frames = 8;
        cfg.variant = variant;
        cfg
    }

    #[test]
    fn baseline_runs_and_reports() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let report = c.run().unwrap();
        assert_eq!(report.frames.len(), 8);
        assert!(report.mean_time_s() > 0.0);
        assert!(report.fps() > 0.0);
        assert!(report.mean_energy_j() > 0.0);
    }

    #[test]
    fn all_variants_execute() {
        for v in HardwareVariant::evaluation_set() {
            let mut c = Coordinator::new(small_cfg(v)).unwrap();
            let f = c.step().unwrap();
            assert!(f.report.time_s > 0.0, "{v:?} produced zero time");
            assert!(f.report.energy_j > 0.0, "{v:?} produced zero energy");
            assert_eq!(f.image.data.len(), 128 * 128);
        }
    }

    #[test]
    fn s2_amortizes_frontend() {
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut s2 = Coordinator::new(small_cfg(HardwareVariant::S2Gpu)).unwrap();
        let rb = base.run().unwrap();
        let rs = s2.run().unwrap();
        // S^2 sorts once per window: mean frontend time drops.
        let fb: f64 =
            rb.frames.iter().map(|f| f.frontend_s).sum::<f64>() / rb.frames.len() as f64;
        let fs: f64 =
            rs.frames.iter().map(|f| f.frontend_s).sum::<f64>() / rs.frames.len() as f64;
        assert!(fs < fb, "S2 frontend {fs} !< baseline {fb}");
    }

    #[test]
    fn lumina_beats_gpu_baseline() {
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut lum = Coordinator::new(small_cfg(HardwareVariant::Lumina)).unwrap();
        let rb = base.run().unwrap();
        let rl = lum.run().unwrap();
        let speedup = rb.mean_time_s() / rl.mean_time_s();
        assert!(speedup > 1.5, "Lumina speedup {speedup} too low");
        let energy_ratio = rl.mean_energy_j() / rb.mean_energy_j();
        assert!(energy_ratio < 0.7, "Lumina energy ratio {energy_ratio} too high");
    }

    #[test]
    fn rc_gpu_slower_than_baseline() {
        // Paper Sec. 6.2: the GPU implementation of RC is a net slowdown.
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut rc = Coordinator::new(small_cfg(HardwareVariant::RcGpu)).unwrap();
        let rb = base.run().unwrap();
        let rr = rc.run().unwrap();
        assert!(rr.mean_time_s() > rb.mean_time_s());
    }

    #[test]
    fn quality_step_reports_psnr() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Lumina)).unwrap();
        let f = c.step_with_quality().unwrap();
        let psnr = f.report.psnr_vs_ref.unwrap();
        assert!(psnr > 20.0, "Lumina frame PSNR {psnr}");
    }
}
