//! The frame-loop coordinator: LuminSys end-to-end (paper Fig. 14),
//! decomposed into an explicit stage graph.
//!
//! Per frame the coordinator drives the pipeline stages and cost models
//! it composed at construction time:
//!
//! 1. [`FrontendStage`] runs (or S²-shares) projection + sorting,
//! 2. a [`RasterBackend`] (plain / radiance-cached / DS-2) renders and
//!    measures per-pixel work,
//! 3. the measured [`FrameWorkload`] is priced by a
//!    [`FrontendCostModel`] and a [`CostModel`]
//!    (GPU / LuminCore / GSCore), and
//! 4. quality + performance + energy land in a [`FrameReport`].
//!
//! `render_at` contains **no** `HardwareVariant` dispatch: the variant
//! is resolved once in [`Coordinator::with_scene`] into trait objects.
//! This is the Layer-3 system contribution: Rust owns the loop, the
//! scheduling, and every model; Python never runs.
//!
//! [`session::SessionPool`] runs many coordinators — independent viewer
//! sessions over one shared `Arc<GaussianScene>` — in parallel.

pub mod admission;
pub mod report;
pub mod session;
pub mod steal;

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::camera::trajectory::{generate, Trajectory};
use crate::camera::{Intrinsics, Pose};
use crate::config::{CacheScope, HardwareVariant, LuminaConfig, Tier};
use crate::constants::TILE;
use crate::lumina::ds2::{half_intrinsics, Ds2Raster};
use crate::lumina::rc::{
    CacheDelta, CacheGeometry, CacheHub, CacheSnapshot, CachedRaster, GroupedRadianceCache,
    WorldDelta, WorldParams, WorldSnapshot,
};
use crate::lumina::s2::{speculative_sort, S2Scheduler, SharedSort, SortGeometry, SortView};
use crate::pipeline::image::Image;
use crate::pipeline::project::project;
use crate::pipeline::raster::{rasterize, RasterConfig, RasterStats};
use crate::pipeline::sort::bin_and_sort;
use crate::pipeline::stage::{
    CompletedFrame, FrameWorkload, FrontendStage, NextFrameInput, PipelinedSession,
    PlainRaster, RasterBackend,
};
use crate::scene::synth::synth_scene;
use crate::scene::GaussianScene;
use crate::sim::cost::{CostModel, FrontendCostModel};
use crate::sim::gpu::{GpuModel, GpuStageTimes};
use crate::sim::gscore::GsCoreModel;
use crate::sim::lumincore::LuminCoreSim;

pub use admission::{AdmissionController, SessionDemand, TierPlan};
pub use report::{FrameReport, RunReport};
pub use session::{PoolBuilder, PoolReport, SessionPool};

/// The LuminSys coordinator: one viewer session's frame loop.
pub struct Coordinator {
    pub cfg: LuminaConfig,
    /// The scene, shareable across sessions (see [`SessionPool`]).
    pub scene: Arc<GaussianScene>,
    /// Output intrinsics (what the viewer sees).
    pub intr: Intrinsics,
    /// Pipeline intrinsics — differs from `intr` for DS-2 and for the
    /// half-res serving tier, whose render passes run at half resolution.
    render_intr: Intrinsics,
    pub trajectory: Trajectory,
    frontend: FrontendStage,
    raster: Box<dyn RasterBackend>,
    frontend_cost: Box<dyn FrontendCostModel>,
    raster_cost: Box<dyn CostModel>,
    /// Double-buffered frame-slot state machine (depth from
    /// `cfg.pool.pipeline_depth`; depth 1 = synchronous stepping).
    pipeline: PipelinedSession,
    /// Frames completed by an implicit drain (a tier swap with a frame
    /// in flight) awaiting pickup by the next step call.
    drained: VecDeque<FrameResult>,
    frame_idx: usize,
    /// Serving tier (LoD ladder); swapped mid-run by [`Self::set_tier`].
    tier: Tier,
    /// Reduced-Gaussian subsample served instead of `scene` on the
    /// reduced tier (shared across a pool's reduced sessions).
    lod_scene: Option<Arc<GaussianScene>>,
    /// The most recent frame's measured workload — what the admission
    /// controller prices through the cost-model seams.
    last_workload: Option<FrameWorkload>,
    /// Admission priority: higher keeps quality longer under pressure
    /// (pools default this to first-admitted-highest).
    pub priority: f64,
    /// Stable pool-wide identity, assigned monotonically at build /
    /// [`SessionPool::admit`] time and never reused: session *indices*
    /// shift when a viewer is retired mid-run, so churn-aware reporting
    /// (the workload harness) keys per-session results by this instead.
    /// 0 for a standalone coordinator.
    pub session_id: u64,
    /// Pool-shared cache hub (shared scope only): the raster backend
    /// renders against the hub's snapshot for its geometry, and tier
    /// rebuilds re-attach through it — invalidating only this session's
    /// delta, never the pool's snapshots.
    cache_hub: Option<Arc<CacheHub>>,
    #[cfg(test)]
    pub(crate) fail_at_frame: Option<usize>,
    #[cfg(test)]
    pub(crate) panic_at_frame: Option<usize>,
}

/// Everything one frame produced.
pub struct FrameResult {
    pub image: Image,
    pub report: FrameReport,
}

/// Resolve a variant into its (frontend, raster) cost-model pair — the
/// one place `HardwareVariant` meets hardware models. Also used by the
/// admission controller to price tier estimates.
pub(crate) fn cost_models_for(
    variant: HardwareVariant,
) -> (Box<dyn FrontendCostModel>, Box<dyn CostModel>) {
    use HardwareVariant::*;
    let frontend: Box<dyn FrontendCostModel> = match variant {
        GsCore | LuminaOnGscoreFrontend => Box::new(GsCoreModel::published()),
        _ => Box::new(GpuModel::xavier_volta()),
    };
    let raster: Box<dyn CostModel> = match variant {
        NruGpu | S2Acc | RcAcc | Lumina | LuminaOnGscoreFrontend => {
            Box::new(LuminCoreSim::paper_default())
        }
        GsCore => Box::new(GsCoreModel::published()),
        Gpu | S2Gpu | RcGpu | Ds2Gpu => Box::new(GpuModel::xavier_volta()),
    };
    (frontend, raster)
}

/// The pipeline resolution implied by a config + serving tier: DS-2 and
/// the half-res tier run the render pass at half the session resolution
/// (the 2x upsample must land exactly back on it).
fn tier_intrinsics(cfg: &LuminaConfig, tier: Tier) -> Result<Intrinsics> {
    let intr = cfg.intrinsics();
    let base = if cfg.variant == HardwareVariant::Ds2Gpu {
        anyhow::ensure!(
            intr.width % 2 == 0 && intr.height % 2 == 0 && intr.width >= 2 && intr.height >= 2,
            "ds2-gpu needs even camera dimensions, got {}x{}",
            intr.width,
            intr.height
        );
        half_intrinsics(&intr)
    } else {
        intr
    };
    if tier == Tier::Half {
        anyhow::ensure!(
            cfg.variant != HardwareVariant::Ds2Gpu,
            "the ds2-gpu variant already renders at half resolution; \
             it cannot be demoted to the half-res tier"
        );
        anyhow::ensure!(
            base.width % 2 == 0 && base.height % 2 == 0 && base.width >= 2 && base.height >= 2,
            "the half-res tier needs even camera dimensions, got {}x{}",
            base.width,
            base.height
        );
        return Ok(half_intrinsics(&base));
    }
    Ok(base)
}

/// Compose the frontend stage for a config (fresh cross-frame state).
/// `clustered` selects the pool-clustered sort topology for S²
/// variants; standalone coordinators always run the private view
/// (clustering needs a pool to publish cluster sorts).
fn compose_frontend(cfg: &LuminaConfig, clustered: bool) -> FrontendStage {
    if cfg.variant.uses_s2() {
        let sched = S2Scheduler::new(
            cfg.s2.sharing_window,
            cfg.s2.expanded_margin,
            TILE,
            cfg.near,
            cfg.far,
        );
        if clustered {
            FrontendStage::with_sort_view(SortView::clustered(sched))
        } else {
            FrontendStage::with_s2(sched)
        }
    } else {
        FrontendStage::plain(cfg.near, cfg.far, TILE)
    }
}

/// The world-scope cache parameters a config implies.
pub(crate) fn world_params_for(cfg: &LuminaConfig) -> WorldParams {
    WorldParams {
        cells: cfg.pool.world_cells,
        base_cell_size: cfg.pool.world_cell_size as f32,
        lod_distance: cfg.pool.world_lod_distance as f32,
        lifetime: cfg.pool.world_lifetime as u16,
        probe_len: cfg.pool.world_probe_len as u32,
        dir_buckets: cfg.pool.world_dir_buckets as u32,
    }
}

/// Compose the raster backend for a config + pipeline resolution +
/// serving tier. The half-res tier wraps the variant's own backend in
/// [`Ds2Raster`], so cached variants keep their cache (sized for the
/// half-res tile grid) while demoted. With a [`CacheHub`] attached,
/// the cached backend renders against the hub's pool-wide state
/// instead of a private cache: per-geometry snapshots under the shared
/// scope, the single world-space hash table under the world scope.
/// World keys quantize positions in the *full* scene (reduced tiers
/// are prefix subsamples, so Gaussian ids stay valid), which is what
/// lets one snapshot serve every tier and resolution.
fn compose_raster(
    cfg: &LuminaConfig,
    render_intr: &Intrinsics,
    record_uncached: bool,
    tier: Tier,
    hub: Option<&Arc<CacheHub>>,
    scene: &Arc<GaussianScene>,
) -> Box<dyn RasterBackend> {
    let (tiles_x, tiles_y) = render_intr.tiles(TILE);
    let base: Box<dyn RasterBackend> = if cfg.variant.uses_rc() {
        match hub {
            Some(h) if cfg.pool.cache_scope == CacheScope::World => {
                Box::new(CachedRaster::world(
                    h.world_snapshot(world_params_for(cfg)),
                    scene.clone(),
                    cfg.rc.alpha_record,
                    record_uncached,
                ))
            }
            Some(h) => Box::new(CachedRaster::shared(
                h.snapshot_for(CacheGeometry { tiles_x, tiles_y, k: cfg.rc.alpha_record }),
                record_uncached,
            )),
            None => Box::new(CachedRaster::new(
                GroupedRadianceCache::new(tiles_x, tiles_y, cfg.rc.alpha_record),
                record_uncached,
            )),
        }
    } else if cfg.variant == HardwareVariant::Ds2Gpu {
        Box::new(Ds2Raster::new())
    } else {
        Box::new(PlainRaster::new())
    };
    if tier == Tier::Half {
        Box::new(Ds2Raster::wrap(base))
    } else {
        base
    }
}

impl Coordinator {
    /// Build a coordinator from a config (synthesizes or loads the scene,
    /// generates the trajectory, instantiates algorithm state).
    pub fn new(cfg: LuminaConfig) -> Result<Self> {
        let scene = match &cfg.scene.path {
            Some(p) => crate::scene::io::read_scene(p)
                .with_context(|| format!("loading scene {p}"))?,
            None => synth_scene(cfg.scene.class, cfg.scene.seed, cfg.gaussian_count()),
        };
        Self::with_scene(cfg, Arc::new(scene))
    }

    /// Build a coordinator over an existing (possibly shared) scene.
    /// This is the seam [`SessionPool`] uses to run many sessions over
    /// one `Arc<GaussianScene>` without duplicating it.
    pub fn with_scene(cfg: LuminaConfig, scene: Arc<GaussianScene>) -> Result<Self> {
        Self::with_scene_in_pool(cfg, scene, None)
    }

    /// [`Self::with_scene`] for a session joining a shared-cache pool:
    /// with a hub, the raster backend renders against the hub's
    /// snapshot for this session's cache geometry from the start — no
    /// private cache is ever allocated just to be thrown away.
    pub fn with_scene_in_pool(
        cfg: LuminaConfig,
        scene: Arc<GaussianScene>,
        cache_hub: Option<Arc<CacheHub>>,
    ) -> Result<Self> {
        let intr = cfg.intrinsics();
        let render_intr = tier_intrinsics(&cfg, Tier::Full)?;
        let trajectory = generate(
            cfg.camera.trajectory,
            cfg.camera.seed,
            cfg.camera.frames,
            cfg.scene.class.extent(),
        );

        let frontend = compose_frontend(&cfg, false);
        let (frontend_cost, raster_cost) = cost_models_for(cfg.variant);
        let raster = compose_raster(
            &cfg,
            &render_intr,
            raster_cost.needs_uncached_stats(),
            Tier::Full,
            cache_hub.as_ref(),
            &scene,
        );
        let pipeline = PipelinedSession::with_substages(
            cfg.pool.pipeline_depth,
            cfg.pool.raster_substages,
        );

        Ok(Coordinator {
            cfg,
            scene,
            intr,
            render_intr,
            trajectory,
            frontend,
            raster,
            frontend_cost,
            raster_cost,
            pipeline,
            drained: VecDeque::new(),
            frame_idx: 0,
            tier: Tier::Full,
            lod_scene: None,
            last_workload: None,
            priority: 0.0,
            session_id: 0,
            cache_hub,
            #[cfg(test)]
            fail_at_frame: None,
            #[cfg(test)]
            panic_at_frame: None,
        })
    }

    /// Current serving tier.
    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// Configured pipeline depth (1 = synchronous).
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline.depth()
    }

    /// Frames this session still owes beyond the unfed trajectory:
    /// slots mid-flight (frontend done, raster pending) plus drained
    /// results awaiting pickup.
    pub fn in_flight(&self) -> usize {
        self.pipeline.in_flight() + self.drained.len()
    }

    /// Whether this session can serve a tier: `ds2-gpu` cannot halve
    /// again, and odd camera dimensions cannot halve at all. The
    /// admission planner consults this so it never assigns a tier
    /// [`Self::set_tier`] would reject.
    pub fn tier_servable(&self, tier: Tier) -> bool {
        tier_intrinsics(&self.cfg, tier).is_ok()
    }

    /// The most recent frame's measured workload, if any frame (or
    /// probe) has rendered yet.
    pub fn last_workload(&self) -> Option<&FrameWorkload> {
        self.last_workload.as_ref()
    }

    /// Switch the session to a serving tier, rebuilding the stages the
    /// tier parameterizes: pipeline resolution, raster backend (cache
    /// geometry is tile-grid-sized), the frontend's cross-frame state
    /// (a stale speculative sort would reference the old tile grid),
    /// and the reduced-Gaussian LoD scene. A no-op when the tier is
    /// unchanged; `force_rebuild` resets the stages even then.
    pub fn set_tier(&mut self, tier: Tier) -> Result<()> {
        self.set_tier_with(tier, None, false)
    }

    /// [`Self::set_tier`] with an optional pre-built reduced-Gaussian
    /// scene (pools share one subsample across their reduced sessions
    /// instead of cutting it per session).
    pub fn set_tier_with(
        &mut self,
        tier: Tier,
        reduced: Option<Arc<GaussianScene>>,
        force_rebuild: bool,
    ) -> Result<()> {
        if tier == self.tier && !force_rebuild {
            return Ok(());
        }
        let render_intr = tier_intrinsics(&self.cfg, tier)?;
        // A tier swap rebuilds the raster backend and resets the
        // frontend's cross-frame state, but a frame mid-flight through
        // the slot machine must finish under the stages (and pipeline
        // resolution) that started it: drain it now, under the *old*
        // tier, and stage the result for the next step call. Only then
        // may the stages be rebuilt.
        while self.pipeline.in_flight() > 0 {
            let (w, h) = (self.render_intr.width, self.render_intr.height);
            if let Some(done) =
                self.pipeline.advance(&mut self.frontend, self.raster.as_mut(), None, w, h)
            {
                let result = self.complete_frame(done);
                self.drained.push_back(result);
            }
        }
        self.lod_scene = if tier == Tier::Reduced {
            Some(match reduced {
                Some(s) => s,
                None => Arc::new(self.scene.reduced_prefix(self.cfg.pool.reduced_fraction)),
            })
        } else {
            None
        };
        self.render_intr = render_intr;
        self.frontend.reset();
        // Shared scope: the rebuild re-attaches to the hub's snapshot
        // for the *new* geometry with a fresh delta — this session's
        // un-merged inserts are invalidated (they referenced the old
        // tile grid), while every other session's snapshot view is
        // untouched. World scope re-attaches to the *same* pool-wide
        // snapshot (world keys don't reference the tile grid), so only
        // the un-merged delta is dropped.
        self.raster = compose_raster(
            &self.cfg,
            &self.render_intr,
            self.raster_cost.needs_uncached_stats(),
            tier,
            self.cache_hub.as_ref(),
            &self.scene,
        );
        self.tier = tier;
        Ok(())
    }

    /// Whether this session renders against a pool-shared cache.
    pub fn shares_cache(&self) -> bool {
        self.cache_hub.is_some() && self.cfg.variant.uses_rc()
    }

    /// Whether the pool-shared cache is the world-space hash cache.
    pub fn caches_world(&self) -> bool {
        self.shares_cache() && self.cfg.pool.cache_scope == CacheScope::World
    }

    /// The cache geometry this session's render pass bins (None for
    /// uncached variants) — the key under which shared-scope sessions
    /// pool their snapshots.
    pub fn cache_geometry(&self) -> Option<CacheGeometry> {
        if !self.cfg.variant.uses_rc() {
            return None;
        }
        let (tiles_x, tiles_y) = self.render_intr.tiles(TILE);
        Some(CacheGeometry { tiles_x, tiles_y, k: self.cfg.rc.alpha_record })
    }

    /// Detach the session's shared-cache delta (epoch merge; None under
    /// private scope).
    pub fn take_cache_delta(&mut self) -> Option<CacheDelta> {
        self.raster.take_cache_delta()
    }

    /// Install the next epoch's merged snapshot (no-op under private
    /// scope).
    pub fn install_cache_snapshot(&mut self, snapshot: Arc<CacheSnapshot>, sharers: usize) {
        self.raster.install_cache_snapshot(snapshot, sharers);
    }

    /// Detach the session's world-scope delta (epoch merge; None
    /// outside the world scope).
    pub fn take_world_delta(&mut self) -> Option<WorldDelta> {
        self.raster.take_world_delta()
    }

    /// Install the next epoch's merged world snapshot (no-op outside
    /// the world scope).
    pub fn install_world_snapshot(&mut self, snapshot: Arc<WorldSnapshot>, sharers: usize) {
        self.raster.install_world_snapshot(snapshot, sharers);
    }

    /// Switch this session's S² frontend between the private and the
    /// pool-clustered sort topology (a no-op for non-S² variants and
    /// when the requested topology is already composed). An actual
    /// switch recomposes the frontend — dropping all cross-frame sort
    /// state, as any topology change must — but preserves runtime
    /// scheduler overrides (the kill-switch threshold). Pools call this
    /// right after construction (before any frame renders) and for
    /// per-session clustering opt-outs.
    pub fn set_sort_clustered(&mut self, clustered: bool) {
        if self.sorts_clustered() == clustered {
            return;
        }
        let max_rotation =
            self.frontend.sort_view().map(|v| v.scheduler().max_rotation_per_frame);
        self.frontend = compose_frontend(&self.cfg, clustered);
        if let (Some(r), Some(v)) = (max_rotation, self.frontend.sort_view_mut()) {
            v.scheduler_mut().max_rotation_per_frame = r;
        }
    }

    /// Whether this session renders against pool-clustered sorts.
    pub fn sorts_clustered(&self) -> bool {
        self.frontend.sort_view().is_some_and(SortView::is_clustered)
    }

    /// Sessions sharing this session's current sort (itself included);
    /// 1 outside clustered scope.
    pub fn sort_sharers(&self) -> usize {
        self.frontend.sort_view().map_or(1, SortView::sharers)
    }

    /// Whether this session pays for its own sorts (private topology
    /// or cluster leader) rather than reusing a cluster leader's.
    pub fn sort_is_leader(&self) -> bool {
        self.frontend.sort_view().is_none_or(SortView::is_cluster_leader)
    }

    /// Set the S² rapid-rotation kill-switch threshold (rad/frame;
    /// `f32::INFINITY` disables). A no-op for non-S² variants.
    pub fn set_s2_max_rotation(&mut self, max_rotation_per_frame: f32) {
        if let Some(v) = self.frontend.sort_view_mut() {
            v.scheduler_mut().max_rotation_per_frame = max_rotation_per_frame;
        }
    }

    /// This session's input to an epoch-boundary sort-clustering round:
    /// its sort geometry and predicted sort pose for the upcoming
    /// epoch. `None` when the session does not participate (not a
    /// clustered-S² frontend, or nothing left to render).
    pub fn sort_candidate(&self) -> Option<(SortGeometry, Pose)> {
        let view = self.frontend.sort_view()?;
        if !view.is_clustered() || self.remaining() == 0 {
            return None;
        }
        let next = self.trajectory.poses[self.frame_idx];
        // The cluster sort serves the whole epoch, so predict its pose
        // at the epoch's center — the same N/2 rule the private
        // scheduler uses for its window.
        let horizon = self.cfg.pool.epoch_frames.max(1) as f32 / 2.0;
        let pose = view.predicted_pose(&next, horizon);
        let scene_gaussians = match &self.lod_scene {
            Some(s) => s.len(),
            None => self.scene.len(),
        };
        let geometry = SortGeometry {
            width: self.render_intr.width,
            height: self.render_intr.height,
            tile_size: TILE,
            scene_gaussians,
        };
        Some((geometry, pose))
    }

    /// Compute the cluster's speculative sort at `pose` over this
    /// session's served scene and pipeline intrinsics — the leader's
    /// contribution, run serially on the pool's coordination thread at
    /// the epoch boundary (so it is deterministic at any thread count).
    pub fn compute_shared_sort(&self, pose: &Pose) -> SharedSort {
        let scene = match &self.lod_scene {
            Some(s) => s.clone(),
            None => self.scene.clone(),
        };
        speculative_sort(
            &scene,
            *pose,
            &self.render_intr,
            self.cfg.near,
            self.cfg.far,
            TILE,
            self.cfg.s2.expanded_margin as f32,
        )
    }

    /// Install the epoch's frozen cluster sort (no-op for non-S² or
    /// private-topology frontends). The leader also takes on the sort's
    /// work accounting, charged to its next frame.
    pub fn install_shared_sort(&mut self, sort: Arc<SharedSort>, leader: bool, sharers: usize) {
        if let Some(v) = self.frontend.sort_view_mut() {
            v.install_shared_sort(sort, leader, sharers);
        }
    }

    /// Render the *current* pose once to measure a [`FrameWorkload`]
    /// without advancing the trajectory — how a pool prices sessions
    /// before any frame has been served. Mutates per-frame stage state
    /// (deterministically); callers that need a pristine session reset
    /// tiers afterwards with `force_rebuild`.
    pub fn probe_workload(&mut self) -> Result<FrameWorkload> {
        let pose = *self
            .trajectory
            .poses
            .get(self.frame_idx)
            .context("trajectory exhausted")?;
        let idx = self.frame_idx;
        self.render_at(idx, &pose)?;
        self.last_workload.clone().context("probe recorded no workload")
    }

    /// Mutable access to the scene. Panics when the scene `Arc` is
    /// shared (i.e. inside a [`SessionPool`]); intended for harnesses
    /// that post-process a freshly built scene (scale clamping etc.).
    pub fn scene_mut(&mut self) -> &mut GaussianScene {
        Arc::get_mut(&mut self.scene).expect("scene is shared; mutate before pooling")
    }

    /// Replace the frontend cost model (e.g. host projection + sorting
    /// on GSCore's CCU/GSU for the Sec. 6.4 fair comparison).
    pub fn set_frontend_cost(&mut self, model: Box<dyn FrontendCostModel>) {
        self.frontend_cost = model;
    }

    /// Labels of the composed stages/models: (raster backend, frontend
    /// cost, raster cost).
    pub fn stage_labels(&self) -> (&'static str, &'static str, &'static str) {
        (self.raster.label(), self.frontend_cost.label(), self.raster_cost.label())
    }

    /// Reference (exact 3DGS) render at a pose, with stats.
    pub fn reference_frame(&self, pose: &Pose) -> (Image, RasterStats, usize, usize) {
        let p =
            project(&self.scene, pose, &self.intr, self.cfg.near, self.cfg.far, 0.0);
        let bins = bin_and_sort(&p, &self.intr, TILE, 0.0);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let out = rasterize(&p, &bins, self.intr.width, self.intr.height, &cfg);
        (out.image, out.stats.unwrap(), p.len(), bins.total_entries())
    }

    /// Render the next frame under the configured variant. Synchronous
    /// semantics: any frame left over from pipelined stepping (drained
    /// or mid-flight) is delivered before a new pose is consumed.
    pub fn step(&mut self) -> Result<FrameResult> {
        if let Some(result) = self.drained.pop_front() {
            return Ok(result);
        }
        if self.pipeline.in_flight() > 0 {
            let result = self.drain_one()?.expect("in-flight frame drains");
            return Ok(result);
        }
        #[cfg(test)]
        {
            if self.fail_at_frame == Some(self.frame_idx) {
                anyhow::bail!("injected session failure at frame {}", self.frame_idx);
            }
            if self.panic_at_frame == Some(self.frame_idx) {
                panic!("injected session panic at frame {}", self.frame_idx);
            }
        }
        let pose = *self
            .trajectory
            .poses
            .get(self.frame_idx)
            .context("trajectory exhausted")?;
        let idx = self.frame_idx;
        self.frame_idx += 1;
        self.render_at(idx, &pose)
    }

    /// One dispatch of the frame-slot state machine: start the next
    /// pose's frontend (when poses remain) while the in-flight frame
    /// rasterizes — at depth 2 the two stages run concurrently on a
    /// split thread budget. Returns the frame that completed; `None` on
    /// the priming dispatch that only starts a frontend. Depth-1
    /// sessions complete the fed frame immediately (synchronous
    /// semantics), and frames drained by a mid-run tier swap are
    /// delivered first.
    pub fn step_pipelined(&mut self) -> Result<Option<FrameResult>> {
        if let Some(result) = self.drained.pop_front() {
            return Ok(Some(result));
        }
        if self.remaining() == 0 {
            return self.drain_one();
        }
        let idx = self.frame_idx;
        #[cfg(test)]
        {
            if self.fail_at_frame == Some(idx) {
                anyhow::bail!("injected session failure at frame {idx}");
            }
            if self.panic_at_frame == Some(idx) {
                panic!("injected session panic at frame {idx}");
            }
        }
        let pose = self.trajectory.poses[idx];
        self.frame_idx += 1;
        let (w, h) = (self.render_intr.width, self.render_intr.height);
        let scene = match &self.lod_scene {
            Some(s) => s.clone(),
            None => self.scene.clone(),
        };
        let intr = self.render_intr;
        let next = NextFrameInput { frame: idx, scene: &*scene, pose: &pose, intr: &intr };
        let done =
            self.pipeline.advance(&mut self.frontend, self.raster.as_mut(), Some(next), w, h);
        Ok(done.map(|d| self.complete_frame(d)))
    }

    /// Complete the in-flight frame, if any, without feeding a new one
    /// (epoch boundaries, end of trajectory).
    pub fn drain_one(&mut self) -> Result<Option<FrameResult>> {
        if let Some(result) = self.drained.pop_front() {
            return Ok(Some(result));
        }
        let (w, h) = (self.render_intr.width, self.render_intr.height);
        let done = self.pipeline.advance(&mut self.frontend, self.raster.as_mut(), None, w, h);
        Ok(done.map(|d| self.complete_frame(d)))
    }

    /// Frames remaining in the trajectory.
    pub fn remaining(&self) -> usize {
        self.trajectory.poses.len().saturating_sub(self.frame_idx)
    }

    /// Run the full trajectory (delivering any frames left over from
    /// pipelined stepping first).
    pub fn run(&mut self) -> Result<RunReport> {
        let mut report = RunReport::new(self.cfg.variant.label());
        while self.remaining() > 0 || self.in_flight() > 0 {
            let f = self.step()?;
            report.push(f.report);
        }
        Ok(report)
    }

    /// One synchronous pass of the stage graph: frontend -> raster ->
    /// workload -> cost models -> report. Variant-free by construction.
    fn render_at(&mut self, idx: usize, pose: &Pose) -> Result<FrameResult> {
        let (w, h) = (self.render_intr.width, self.render_intr.height);
        // The reduced tier serves the LoD subsample instead of the full
        // shared scene (cheap Arc clone; sidesteps a field-borrow clash
        // with the mutable frontend).
        let scene = match &self.lod_scene {
            Some(s) => s.clone(),
            None => self.scene.clone(),
        };

        // --- Functional stages ---------------------------------------
        let fo = self.frontend.run(&scene, pose, &self.render_intr);
        let frame = self.raster.render(&fo.projected, &fo.bins, w, h);
        Ok(self.complete_frame(CompletedFrame {
            frame: idx,
            scene_gaussians: scene.len(),
            frontend: fo,
            raster: frame,
        }))
    }

    /// The back half of the stage graph, shared by the synchronous and
    /// pipelined paths: assemble the measured [`FrameWorkload`], price
    /// it through the cost-model seams, finalize the image.
    fn complete_frame(&mut self, done: CompletedFrame) -> FrameResult {
        let workload = FrameWorkload::from_stages(
            done.frame,
            done.scene_gaussians,
            &done.frontend,
            done.raster.work,
        );
        let image = self.raster.finalize(done.raster.image);

        // --- Cost models ---------------------------------------------
        let (front_s, front_j) = self.frontend_cost.frontend_cost(&workload);
        let raster = self.raster_cost.raster_cost(&workload);
        let stage = GpuStageTimes {
            projection: front_s,
            sorting: 0.0, // folded into the frontend seam
            rasterization: raster.time_s,
            overhead: self.raster_cost.overhead_s(),
        };

        let mut energy = raster.energy;
        energy.gpu += front_j;

        let report = FrameReport {
            frame: workload.frame,
            time_s: stage.total(),
            frontend_s: front_s,
            raster_s: raster.time_s,
            energy_j: energy.total(),
            energy,
            sorted_this_frame: workload.sorted,
            cache: workload.cache,
            pe_utilization: raster.pe_utilization,
            mean_iterated: workload.mean_iterated(),
            psnr_vs_ref: None,
            tier: self.tier.label(),
        };
        self.last_workload = Some(workload);
        FrameResult { image, report }
    }

    /// Render a frame and also compute quality vs the exact pipeline.
    pub fn step_with_quality(&mut self) -> Result<FrameResult> {
        let pose = *self
            .trajectory
            .poses
            .get(self.frame_idx)
            .context("trajectory exhausted")?;
        let idx = self.frame_idx;
        self.frame_idx += 1;
        let mut result = self.render_at(idx, &pose)?;
        let (reference, _, _, _) = self.reference_frame(&pose);
        result.report.psnr_vs_ref = Some(crate::metrics::psnr(&reference, &result.image));
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(variant: HardwareVariant) -> LuminaConfig {
        let mut cfg = LuminaConfig::quick_test();
        cfg.scene.count = 5000;
        cfg.camera.width = 128;
        cfg.camera.height = 128;
        cfg.camera.frames = 8;
        cfg.variant = variant;
        cfg
    }

    #[test]
    fn baseline_runs_and_reports() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let report = c.run().unwrap();
        assert_eq!(report.frames.len(), 8);
        assert!(report.mean_time_s() > 0.0);
        assert!(report.fps() > 0.0);
        assert!(report.mean_energy_j() > 0.0);
    }

    #[test]
    fn all_variants_execute() {
        for v in HardwareVariant::evaluation_set() {
            let mut c = Coordinator::new(small_cfg(v)).unwrap();
            let f = c.step().unwrap();
            assert!(f.report.time_s > 0.0, "{v:?} produced zero time");
            assert!(f.report.energy_j > 0.0, "{v:?} produced zero energy");
            assert_eq!(f.image.data.len(), 128 * 128);
        }
    }

    #[test]
    fn stage_composition_matches_variant() {
        let c = Coordinator::new(small_cfg(HardwareVariant::Lumina)).unwrap();
        assert_eq!(c.stage_labels(), ("radiance-cached", "gpu-frontend", "lumincore"));
        let c = Coordinator::new(small_cfg(HardwareVariant::GsCore)).unwrap();
        assert_eq!(c.stage_labels(), ("plain", "ccu-gsu", "gscore"));
        let c = Coordinator::new(small_cfg(HardwareVariant::Ds2Gpu)).unwrap();
        assert_eq!(c.stage_labels(), ("ds2", "gpu-frontend", "gpu"));
    }

    #[test]
    fn ds2_variant_renders_full_res_via_half_res_pipeline() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Ds2Gpu)).unwrap();
        let f = c.step_with_quality().unwrap();
        // Output is session resolution even though the pipeline ran at
        // half res.
        assert_eq!(f.image.data.len(), 128 * 128);
        assert!(f.report.time_s > 0.0);
        let psnr = f.report.psnr_vs_ref.unwrap();
        // Recognizably the scene, measurably below exact (Fig. 20).
        assert!(psnr > 15.0 && psnr < 45.0, "DS-2 PSNR {psnr}");
        // Half-res pipeline does less raster work than the baseline.
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let fb = base.step().unwrap();
        assert!(f.report.raster_s < fb.report.raster_s);
    }

    #[test]
    fn s2_amortizes_frontend() {
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut s2 = Coordinator::new(small_cfg(HardwareVariant::S2Gpu)).unwrap();
        let rb = base.run().unwrap();
        let rs = s2.run().unwrap();
        // S^2 sorts once per window: mean frontend time drops.
        let fb: f64 =
            rb.frames.iter().map(|f| f.frontend_s).sum::<f64>() / rb.frames.len() as f64;
        let fs: f64 =
            rs.frames.iter().map(|f| f.frontend_s).sum::<f64>() / rs.frames.len() as f64;
        assert!(fs < fb, "S2 frontend {fs} !< baseline {fb}");
    }

    #[test]
    fn lumina_beats_gpu_baseline() {
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut lum = Coordinator::new(small_cfg(HardwareVariant::Lumina)).unwrap();
        let rb = base.run().unwrap();
        let rl = lum.run().unwrap();
        let speedup = rb.mean_time_s() / rl.mean_time_s();
        assert!(speedup > 1.5, "Lumina speedup {speedup} too low");
        let energy_ratio = rl.mean_energy_j() / rb.mean_energy_j();
        assert!(energy_ratio < 0.7, "Lumina energy ratio {energy_ratio} too high");
    }

    #[test]
    fn rc_gpu_slower_than_baseline() {
        // Paper Sec. 6.2: the GPU implementation of RC is a net slowdown.
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut rc = Coordinator::new(small_cfg(HardwareVariant::RcGpu)).unwrap();
        let rb = base.run().unwrap();
        let rr = rc.run().unwrap();
        assert!(rr.mean_time_s() > rb.mean_time_s());
    }

    #[test]
    fn rc_gpu_raster_time_matches_plain_gpu() {
        // The warp-bound claim, now via single-pass recording: RC-GPU's
        // raster time equals the plain GPU's on the same frames (hits
        // don't shorten rounds), plus the fixed lookup overhead.
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let mut rc = Coordinator::new(small_cfg(HardwareVariant::RcGpu)).unwrap();
        let gpu = GpuModel::xavier_volta();
        let overhead = gpu.rc_overhead_time_s(128 * 128);
        for _ in 0..3 {
            let fb = base.step().unwrap();
            let fr = rc.step().unwrap();
            let delta = fr.report.raster_s - fb.report.raster_s;
            assert!(
                (delta - overhead).abs() < 1e-12,
                "raster delta {delta} != rc overhead {overhead}"
            );
        }
    }

    #[test]
    fn half_tier_halves_pipeline_keeps_output_resolution() {
        let mut base = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        let fb = base.step().unwrap();
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        c.set_tier(Tier::Half).unwrap();
        assert_eq!(c.tier(), Tier::Half);
        let f = c.step().unwrap();
        // Viewer still sees session resolution; pipeline ran at half.
        assert_eq!(f.image.data.len(), 128 * 128);
        assert_eq!(f.report.tier, "half");
        assert!(f.report.raster_s < fb.report.raster_s, "half tier must cut raster cost");
        let w = c.last_workload().unwrap();
        assert_eq!((w.width, w.height), (64, 64));
    }

    #[test]
    fn reduced_tier_serves_fewer_gaussians() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        c.set_tier(Tier::Reduced).unwrap();
        let f = c.step().unwrap();
        assert_eq!(f.report.tier, "reduced");
        let w = c.last_workload().unwrap();
        assert_eq!(w.scene_gaussians, 2500, "default fraction 0.5 of 5000");
        // Output resolution is untouched.
        assert_eq!(f.image.data.len(), 128 * 128);
    }

    #[test]
    fn tier_swaps_mid_run_and_promotes_back() {
        // Cached variant: tier changes rebuild the cache geometry.
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Lumina)).unwrap();
        let f0 = c.step().unwrap();
        assert_eq!(f0.report.tier, "full");
        c.set_tier(Tier::Half).unwrap();
        let f1 = c.step().unwrap();
        assert_eq!(f1.report.tier, "half");
        assert_eq!(f1.image.data.len(), 128 * 128);
        c.set_tier(Tier::Full).unwrap();
        let f2 = c.step().unwrap();
        assert_eq!(f2.report.tier, "full");
        assert_eq!(f2.image.data.len(), 128 * 128);
        let mut r = RunReport::new("tiers");
        for f in [f0.report, f1.report, f2.report] {
            r.push(f);
        }
        assert_eq!(r.tier_sequence(), vec!["full", "half", "full"]);
    }

    #[test]
    fn ds2_variant_refuses_half_tier() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Ds2Gpu)).unwrap();
        let err = c.set_tier(Tier::Half);
        assert!(err.is_err(), "ds2-gpu cannot halve twice");
        // Reduced is still allowed.
        c.set_tier(Tier::Reduced).unwrap();
        let f = c.step().unwrap();
        assert_eq!(f.image.data.len(), 128 * 128);
    }

    #[test]
    fn tier_servable_reflects_dimension_and_variant_limits() {
        let mut cfg = small_cfg(HardwareVariant::Gpu);
        cfg.camera.width = 127; // odd: the half-res tier cannot land back
        let c = Coordinator::new(cfg).unwrap();
        assert!(c.tier_servable(Tier::Full));
        assert!(c.tier_servable(Tier::Reduced));
        assert!(!c.tier_servable(Tier::Half));
        let c = Coordinator::new(small_cfg(HardwareVariant::Ds2Gpu)).unwrap();
        assert!(!c.tier_servable(Tier::Half), "ds2-gpu cannot halve twice");
        let c = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        assert!(c.tier_servable(Tier::Half));
    }

    #[test]
    fn probe_measures_without_consuming() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Gpu)).unwrap();
        assert!(c.last_workload().is_none());
        let w = c.probe_workload().unwrap();
        assert_eq!(c.remaining(), 8, "probe must not consume the trajectory");
        assert!(w.mean_iterated() > 0.0);
        assert!(c.last_workload().is_some());
    }

    #[test]
    fn quality_step_reports_psnr() {
        let mut c = Coordinator::new(small_cfg(HardwareVariant::Lumina)).unwrap();
        let f = c.step_with_quality().unwrap();
        let psnr = f.report.psnr_vs_ref.unwrap();
        assert!(psnr > 20.0, "Lumina frame PSNR {psnr}");
    }
}
