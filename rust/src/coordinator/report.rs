//! Run/frame reporting structures and text rendering.

use crate::coordinator::admission::combine_stage_times;
use crate::lumina::rc::CacheStats;
use crate::sim::energy::EnergyBreakdown;

/// One frame's metrics.
///
/// `PartialEq` is bitwise on the f64 fields — exactly what the
/// determinism tests want (identical runs must produce identical bits,
/// not just close values).
#[derive(Debug, Clone, PartialEq)]
pub struct FrameReport {
    pub frame: usize,
    /// Total modeled frame time (s).
    pub time_s: f64,
    /// Projection + sorting (+ S^2 refresh) time (s).
    pub frontend_s: f64,
    /// Rasterization time (s).
    pub raster_s: f64,
    /// Total energy (J).
    pub energy_j: f64,
    pub energy: EnergyBreakdown,
    /// Whether speculative sorting executed this frame.
    pub sorted_this_frame: bool,
    /// Radiance-cache statistics for this frame.
    pub cache: CacheStats,
    /// NRU PE utilization (1.0 for non-NRU variants).
    pub pe_utilization: f64,
    /// Mean Gaussians iterated per pixel.
    pub mean_iterated: f64,
    /// Quality vs the exact pipeline (when measured).
    pub psnr_vs_ref: Option<f64>,
    /// Serving tier the frame was rendered under (`"full"` outside
    /// tiered pools). Part of `PartialEq`, so the determinism tests
    /// also pin mid-run promotion/demotion sequences.
    pub tier: &'static str,
}

impl FrameReport {
    /// Modeled device occupancy of this frame under a `depth`-slot
    /// frame pipeline: at depth >= 2 the frontend overlaps the next
    /// frame's rasterization, so a steady-state frame costs the device
    /// the *slower* stage — `max(frontend, raster + overhead)` — while
    /// `time_s` remains the frame's end-to-end latency. Shares the
    /// planner's `combine_stage_times`, so report and admission
    /// arithmetic cannot diverge.
    pub fn device_time_s(&self, depth: usize) -> f64 {
        if depth < 2 {
            // Bit-exact latency at the synchronous baseline (adding the
            // re-derived raster term back would cost a ulp).
            return self.time_s;
        }
        combine_stage_times(self.frontend_s, self.time_s - self.frontend_s, depth)
    }
}

/// Quality rank of a serving tier label: lower is better. Unknown
/// labels rank worst, so a malformed tier can only ever read as a
/// demotion, never mask one.
pub(crate) fn tier_rank(tier: &str) -> u8 {
    match tier {
        "full" => 0,
        "reduced" => 1,
        "half" => 2,
        _ => 3,
    }
}

/// Nearest-rank percentile (`p` in 0..=100) over frame latencies.
/// Sorts with `total_cmp`, so the answer is deterministic for any
/// input order — callers feed frames in session/epoch order and get
/// the same bits at any thread count. 0 for an empty set.
pub(crate) fn latency_percentile_s(times: &mut Vec<f64>, p: f64) -> f64 {
    if times.is_empty() {
        return 0.0;
    }
    times.sort_by(f64::total_cmp);
    let rank = ((p.clamp(0.0, 100.0) / 100.0) * times.len() as f64).ceil() as usize;
    times[rank.saturating_sub(1).min(times.len() - 1)]
}

/// A whole run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub label: String,
    pub frames: Vec<FrameReport>,
}

impl RunReport {
    pub fn new(label: &str) -> Self {
        RunReport { label: label.to_string(), frames: Vec::new() }
    }

    pub fn push(&mut self, f: FrameReport) {
        self.frames.push(f);
    }

    pub fn mean_time_s(&self) -> f64 {
        if self.frames.is_empty() {
            return 0.0;
        }
        self.frames.iter().map(|f| f.time_s).sum::<f64>() / self.frames.len() as f64
    }

    pub fn fps(&self) -> f64 {
        let t = self.mean_time_s();
        if t > 0.0 {
            1.0 / t
        } else {
            0.0
        }
    }

    pub fn mean_energy_j(&self) -> f64 {
        if self.frames.is_empty() {
            return 0.0;
        }
        self.frames.iter().map(|f| f.energy_j).sum::<f64>() / self.frames.len() as f64
    }

    /// Mean per-frame device occupancy under a `depth`-slot pipeline
    /// (see [`FrameReport::device_time_s`]); equals [`Self::mean_time_s`]
    /// at depth 1.
    pub fn mean_device_time_s(&self, depth: usize) -> f64 {
        if self.frames.is_empty() {
            return 0.0;
        }
        self.frames.iter().map(|f| f.device_time_s(depth)).sum::<f64>()
            / self.frames.len() as f64
    }

    /// Aggregate cache hit rate over the run.
    pub fn cache_hit_rate(&self) -> f64 {
        let mut s = CacheStats::default();
        for f in &self.frames {
            s.merge(&f.cache);
        }
        s.hit_rate()
    }

    /// Distinct serving tiers in frame order (one entry per change) —
    /// `["full"]` for an untiered run, e.g. `["full", "half"]` after a
    /// mid-run demotion.
    pub fn tier_sequence(&self) -> Vec<&'static str> {
        let mut seq: Vec<&'static str> = Vec::new();
        for f in &self.frames {
            if seq.last() != Some(&f.tier) {
                seq.push(f.tier);
            }
        }
        seq
    }

    /// Nearest-rank latency percentile over this session's frame times
    /// (`p` in 0..=100); the pool-wide version is
    /// [`crate::coordinator::PoolReport::latency_percentile`].
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let mut times: Vec<f64> = self.frames.iter().map(|f| f.time_s).collect();
        latency_percentile_s(&mut times, p)
    }

    /// Tier demotions observed across consecutive frames (transitions
    /// to a lower-quality tier; promotions do not count).
    pub fn demotions(&self) -> usize {
        self.frames
            .windows(2)
            .filter(|w| tier_rank(w[1].tier) > tier_rank(w[0].tier))
            .count()
    }

    /// Mean PSNR over frames that measured quality.
    pub fn mean_psnr(&self) -> Option<f64> {
        let vals: Vec<f64> = self.frames.iter().filter_map(|f| f.psnr_vs_ref).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// One-line summary for harness output.
    pub fn summary(&self) -> String {
        format!(
            "{:<16} fps={:>8.1}  frame={:>8.3} ms  energy={:>8.3} mJ  hit={:>5.1}%  psnr={}",
            self.label,
            self.fps(),
            self.mean_time_s() * 1e3,
            self.mean_energy_j() * 1e3,
            self.cache_hit_rate() * 100.0,
            match self.mean_psnr() {
                Some(p) => format!("{p:.2} dB"),
                None => "-".to_string(),
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(t: f64, e: f64) -> FrameReport {
        FrameReport {
            frame: 0,
            time_s: t,
            frontend_s: t * 0.3,
            raster_s: t * 0.7,
            energy_j: e,
            energy: EnergyBreakdown::default(),
            sorted_this_frame: true,
            cache: CacheStats::default(),
            pe_utilization: 1.0,
            mean_iterated: 100.0,
            psnr_vs_ref: Some(30.0),
            tier: "full",
        }
    }

    #[test]
    fn aggregates() {
        let mut r = RunReport::new("test");
        r.push(frame(0.01, 0.1));
        r.push(frame(0.03, 0.3));
        assert!((r.mean_time_s() - 0.02).abs() < 1e-12);
        assert!((r.fps() - 50.0).abs() < 1e-9);
        assert!((r.mean_energy_j() - 0.2).abs() < 1e-12);
        assert_eq!(r.mean_psnr(), Some(30.0));
        assert!(r.summary().contains("fps"));
    }

    #[test]
    fn device_time_overlaps_stages_at_depth_two() {
        let f = frame(0.01, 0.1);
        assert_eq!(f.device_time_s(1), f.time_s);
        // frontend 0.3t vs raster-and-overhead 0.7t: the slower wins.
        assert!((f.device_time_s(2) - 0.007).abs() < 1e-12);
        let mut r = RunReport::new("depth");
        r.push(frame(0.01, 0.1));
        r.push(frame(0.03, 0.3));
        assert!((r.mean_device_time_s(1) - r.mean_time_s()).abs() < 1e-15);
        assert!(r.mean_device_time_s(2) < r.mean_time_s());
    }

    #[test]
    fn empty_run_is_safe() {
        let r = RunReport::new("empty");
        assert_eq!(r.mean_time_s(), 0.0);
        assert_eq!(r.fps(), 0.0);
        assert_eq!(r.mean_psnr(), None);
        assert_eq!(r.latency_percentile(99.0), 0.0);
        assert_eq!(r.demotions(), 0);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let mut r = RunReport::new("pct");
        for t in [0.03, 0.01, 0.02, 0.04] {
            r.push(frame(t, 0.0));
        }
        assert_eq!(r.latency_percentile(50.0), 0.02);
        assert_eq!(r.latency_percentile(99.0), 0.04);
        assert_eq!(r.latency_percentile(0.0), 0.01);
        assert_eq!(r.latency_percentile(100.0), 0.04);
    }

    #[test]
    fn demotions_count_downgrades_only() {
        let mut r = RunReport::new("tiers");
        for tier in ["full", "reduced", "reduced", "half", "full", "reduced"] {
            let mut f = frame(0.01, 0.0);
            f.tier = tier;
            r.push(f);
        }
        // full->reduced, reduced->half, full->reduced; the half->full
        // promotion does not count.
        assert_eq!(r.demotions(), 3);
    }
}
