//! Multi-session serving: N independent viewer sessions over one shared
//! scene, stepped in parallel — optionally under tiered admission
//! control.
//!
//! Each session is a full [`Coordinator`] — its own trajectory (camera
//! seed offset per viewer), its own S² scheduler state, its own radiance
//! cache — but all sessions read the same `Arc<GaussianScene>`, so scene
//! memory is paid once no matter how many viewers are attached. Sessions
//! run concurrently via [`crate::util::par`]; every session is fully
//! deterministic given its config, so the pool's output is independent
//! of `LUMINA_THREADS` (enforced by `tests/sessions.rs`).
//!
//! The machine's thread budget is split between the two nesting levels
//! with no stranded workers ([`par::split_budget`]) and applied per
//! worker thread through an RAII [`par::ThreadBudgetGuard`], so the
//! process-global budget is never mutated — a panicking session cannot
//! leak a clamped thread count to the rest of the process.
//!
//! With `pool.pipeline_depth = 2` the pool schedules *stages* instead
//! of whole sessions: each session runs a double-buffered
//! [`crate::pipeline::stage::PipelinedSession`] frame slot, so frame
//! N+1's frontend (projection + S² speculative sort) overlaps frame N's
//! rasterization on a split thread budget, and the outer worker count
//! is sized by stage slots. Slots drain at every epoch boundary, so
//! re-planning sees exactly the state a synchronous pool would — and
//! the rendered output stays bitwise identical to depth 1 at any
//! thread count (`tests/sessions.rs`).
//!
//! [`SessionPool::serve`] adds the capacity-managed mode: an
//! [`AdmissionController`] prices every session's recent
//! [`crate::pipeline::stage::FrameWorkload`] through the cost-model
//! seams and assigns each viewer a serving [`Tier`] (full / reduced
//! Gaussians / half resolution), re-planning every `pool.epoch_frames`
//! frames — demoting low-priority viewers under pressure, promoting
//! them back on headroom, and refusing admission when no mix can hold
//! the pool's simulated-FPS target.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{LuminaConfig, SchedulerMode, SortScope, Tier};
use crate::coordinator::admission::{AdmissionController, SessionDemand};
use crate::coordinator::report::FrameReport;
use crate::coordinator::steal;
use crate::coordinator::{Coordinator, FrameResult, RunReport};
use crate::lumina::rc::{CacheDelta, CacheGeometry, CacheHub, CacheStats, WorldDelta};
use crate::camera::Pose;
use crate::lumina::s2::{SharedSort, SortCandidate, SortGeometry, SortHub};
use crate::scene::synth::synth_scene;
use crate::scene::GaussianScene;
use crate::util::par;

/// A pool of independent viewer sessions over one shared scene.
///
/// # Lifecycle
///
/// A session moves through a small state machine, every transition of
/// which happens on the coordination thread at an epoch boundary (frame
/// slots drained), so churn can never race rendering:
///
/// ```text
///   PoolBuilder::build ──> attached ──run_epoch/serve──> serving
///        (id assigned)        ^  |                          |
///                             |  `──────── retire ──> drained+detached
///   admit (plan Ok) ──────────'
///   admit (plan Err) ──> refused  (pool untouched, refusal counted)
/// ```
///
/// * **Build** — [`SessionPool::builder`] constructs N sessions over
///   one shared scene, wires the shared-cache / clustered-sort hubs the
///   config scopes ask for, and assigns each viewer a stable
///   [`Coordinator::session_id`] (monotonic, never reused).
/// * **Admit** — [`Self::admit`] prices a probed joiner alongside the
///   active sessions; on refusal the pool is byte-identical to one that
///   never saw the joiner (the refusal is only *counted*). On success
///   the joiner gets the next `session_id` and the warm-handoff tier
///   plan is applied pool-wide.
/// * **Retire** — [`Self::retire`] is the symmetric departure path:
///   the session's pipelined slots are drained under its current tier
///   (the completed frames are returned to the caller — they were real
///   served frames), its un-merged shared-cache delta leaves with it
///   (only epoch-boundary merges ever publish writes), and the hubs
///   re-sync so sharer counts and cluster membership match the
///   remaining sessions. Remaining sessions keep their relative order,
///   so the session-index-ordered cache merge stays deterministic;
///   reports key churned viewers by `session_id`, which never shifts.
/// * **Serve** — [`Self::serve`] (or [`Self::run_epoch`] +
///   [`Self::replan`] for callers that interleave churn) renders
///   epochs, re-planning tiers between them.
pub struct SessionPool {
    sessions: Vec<Coordinator>,
    /// Lazily cut reduced-Gaussian subsample, shared by every session
    /// demoted to [`Tier::Reduced`] (scene memory paid once per tier).
    reduced: Option<Arc<GaussianScene>>,
    /// Shared-scope cache hub (`pool.cache_scope = "shared"` on an RC
    /// variant): sessions render whole epochs against frozen snapshots
    /// and the pool merges their insert deltas at epoch boundaries, in
    /// session-index order — bitwise identical at any thread count and
    /// pipeline depth.
    cache_hub: Option<Arc<CacheHub>>,
    /// Clustered-scope sort hub (`pool.sort_scope = "clustered"` on an
    /// S² variant): at every epoch boundary the pool re-clusters
    /// sessions by sort geometry and predicted pose, computes one
    /// speculative sort per cluster (on the coordination thread, leader
    /// = lowest session index), and publishes it as a frozen
    /// `Arc<SharedSort>` every member renders against.
    sort_hub: Option<SortHub>,
    /// Cluster sorts published by the most recent sort sync, keyed by
    /// (leader geometry, leader predicted pose) — the only inputs the
    /// sort depends on. Consecutive syncs with an unchanged key (e.g.
    /// the epoch boundary's merge sync followed immediately by a no-op
    /// tier application, or a membership-only change) reuse the
    /// published `Arc` instead of recomputing a sort that determinism
    /// guarantees would be identical.
    sort_published: Vec<(SortGeometry, Pose, Arc<SharedSort>)>,
    /// Pool-wide cache statistics over every epoch-served frame — the
    /// observed hit rate admission pricing consumes (shared scope), and
    /// the warm-handoff rate for viewers admitted mid-run.
    served: CacheStats,
    /// World-scope cells reclaimed by lifetime decay across every epoch
    /// merge so far — eviction provenance the summary line surfaces
    /// (decay happens pool-side at the merge, never inside a frame, so
    /// no per-frame stat can carry it).
    world_decay_evictions: u64,
    /// Next [`Coordinator::session_id`] to hand out — monotonic, never
    /// reused, so churn-aware reports keep a stable per-viewer key even
    /// as `retire` shifts session *indices*.
    next_id: u64,
    /// Cumulative refused admissions: initial [`Self::serve`] refusals
    /// plus mid-run [`Self::admit`] refusals. Surfaces on
    /// [`PoolReport::refusals`] — the loadtest SLO counter.
    refused: usize,
}

/// Aggregated result of running every session to completion.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolReport {
    /// Per-session run reports, in session order.
    pub sessions: Vec<RunReport>,
    /// Host wall-clock time for the whole parallel run (s).
    pub wall_s: f64,
    /// Frame-slot depth the pool served at (1 = synchronous). Decides
    /// whether [`Self::pool_fps`] charges full frame latency or the
    /// overlapped `max(frontend, raster)` device time per frame.
    pub pipeline_depth: usize,
    /// Refused admissions accumulated by the pool *so far* (initial
    /// `serve` refusals + mid-run `admit` refusals) — cumulative over
    /// the pool's lifetime, not scoped to the run that produced this
    /// report.
    pub refusals: usize,
    /// World-scope cells reclaimed by lifetime decay, cumulative over
    /// the pool's epoch merges (0 outside the world cache scope).
    pub decay_evictions: u64,
}

impl PoolReport {
    /// Total frames rendered across sessions.
    pub fn total_frames(&self) -> usize {
        self.sessions.iter().map(|r| r.frames.len()).sum()
    }

    /// Frames that executed a speculative sort (projection + binning +
    /// depth sort) across all sessions — the cross-session redundancy
    /// measure pool-clustered S² sorting minimizes: cluster leaders'
    /// boundary sorts and kill-switch fallbacks count, followers' reuse
    /// frames do not.
    pub fn sorted_frames(&self) -> usize {
        self.sessions
            .iter()
            .flat_map(|r| &r.frames)
            .filter(|f| f.sorted_this_frame)
            .count()
    }

    /// Aggregate *simulated* throughput: the summed frame rate the
    /// modeled hardware sustains serving all sessions at once.
    pub fn aggregate_fps(&self) -> f64 {
        self.sessions.iter().map(|r| r.fps()).sum()
    }

    /// Mean simulated frame rate per session.
    pub fn mean_session_fps(&self) -> f64 {
        if self.sessions.is_empty() {
            0.0
        } else {
            self.aggregate_fps() / self.sessions.len() as f64
        }
    }

    /// Pool rate under the time-slicing capacity model: the rate at
    /// which one modeled device delivers a frame to *every* session
    /// (the quantity the admission controller targets). A pipelined
    /// pool (depth >= 2) charges each frame the overlapped device time
    /// — `max(frontend, raster + overhead)` — matching the admission
    /// controller's pipelined pricing.
    pub fn pool_fps(&self) -> f64 {
        let t: f64 = self
            .sessions
            .iter()
            .map(|r| r.mean_device_time_s(self.pipeline_depth))
            .sum();
        if t > 0.0 {
            1.0 / t
        } else {
            0.0
        }
    }

    /// Host rendering throughput: functional frames per wall second.
    pub fn host_fps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_frames() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Pool-wide radiance-cache statistics: every session's per-frame
    /// stats merged (hit provenance included). All-zero for uncached
    /// variants.
    pub fn cache_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for r in &self.sessions {
            for f in &r.frames {
                s.merge(&f.cache);
            }
        }
        s
    }

    /// Merged pool-wide cache hit rate (see [`Self::cache_stats`]);
    /// per-session rates are on each session's
    /// [`RunReport::cache_hit_rate`].
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache_stats().hit_rate()
    }

    /// Nearest-rank latency percentile (`p` in 0..=100) over every
    /// frame's end-to-end `time_s`, pool-wide — the SLO quantity
    /// ("p99 frame latency across all viewers"). Deterministic for any
    /// thread count: frames are collected in session/epoch order and
    /// sorted with `total_cmp`. 0 for an empty pool.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let mut times: Vec<f64> = self
            .sessions
            .iter()
            .flat_map(|r| &r.frames)
            .map(|f| f.time_s)
            .collect();
        crate::coordinator::report::latency_percentile_s(&mut times, p)
    }

    /// Total tier demotions across sessions (consecutive-frame
    /// transitions to a lower-quality tier; promotions do not count).
    pub fn demotions(&self) -> usize {
        self.sessions.iter().map(|r| r.demotions()).sum()
    }

    /// Demotions per served frame (0 when no frames were served) — the
    /// "how often did quality drop" SLO rate.
    pub fn demotion_rate(&self) -> f64 {
        let total = self.total_frames();
        if total == 0 {
            0.0
        } else {
            self.demotions() as f64 / total as f64
        }
    }

    /// One-line throughput summary. Heterogeneous trajectories (tiered
    /// pools, mixed configs) report the min-max frame-count range
    /// rather than pretending every session matched the first.
    pub fn summary(&self) -> String {
        let lo = self.sessions.iter().map(|r| r.frames.len()).min().unwrap_or(0);
        let hi = self.sessions.iter().map(|r| r.frames.len()).max().unwrap_or(0);
        let frames = if lo == hi { format!("{lo}") } else { format!("{lo}-{hi}") };
        let cache = self.cache_stats();
        let hit = if cache.lookups > 0 {
            format!(
                " | cache hit {:.1}% ({:.1}% cross-session)",
                cache.hit_rate() * 100.0,
                if cache.hits > 0 {
                    cache.snapshot_hits as f64 / cache.hits as f64 * 100.0
                } else {
                    0.0
                }
            )
        } else {
            String::new()
        };
        let slo = if self.total_frames() > 0 {
            format!(
                " | p50/p95/p99 {:.3}/{:.3}/{:.3} ms",
                self.latency_percentile(50.0) * 1e3,
                self.latency_percentile(95.0) * 1e3,
                self.latency_percentile(99.0) * 1e3
            )
        } else {
            String::new()
        };
        let refused = if self.refusals > 0 {
            format!(" | {} refused", self.refusals)
        } else {
            String::new()
        };
        // World-scope provenance: mean probe-chain length (from the
        // per-frame probe histogram) and pool-side decay evictions.
        let probes = cache.probes_recorded();
        let world = if probes > 0 || self.decay_evictions > 0 {
            let chain_sum: u64 = cache
                .probe_hist
                .iter()
                .enumerate()
                .map(|(i, &n)| (i as u64 + 1) * n)
                .sum();
            let mean = if probes > 0 { chain_sum as f64 / probes as f64 } else { 0.0 };
            format!(" | world probe {mean:.2} avg, {} decayed", self.decay_evictions)
        } else {
            String::new()
        };
        format!(
            "pool: {} sessions x {} frames | aggregate {:.1} sim-fps ({:.1}/session) | \
             pool {:.1} sim-fps | host {:.1} fps | wall {:.3} s{}{}{}{}",
            self.sessions.len(),
            frames,
            self.aggregate_fps(),
            self.mean_session_fps(),
            self.pool_fps(),
            self.host_fps(),
            self.wall_s,
            hit,
            world,
            slo,
            refused
        )
    }
}

/// Staged construction of a [`SessionPool`] — the one front door that
/// replaced the pool's four historical constructors. Defaults build a
/// single-session pool with a per-viewer camera seed (base + i) and
/// divergent trajectories; opt into convergence ([`Self::stagger`]), a
/// pre-built scene ([`Self::scene`]), or a heterogeneous device mix
/// ([`Self::device_mix`]).
///
/// ```no_run
/// # use lumina::config::LuminaConfig;
/// # use lumina::coordinator::SessionPool;
/// # fn main() -> anyhow::Result<()> {
/// let pool = SessionPool::builder(LuminaConfig::quick_test())
///     .sessions(4)
///     .stagger(2)
///     .build()?;
/// # let _ = pool; Ok(())
/// # }
/// ```
pub struct PoolBuilder {
    base: LuminaConfig,
    n: usize,
    stagger: Option<usize>,
    scene: Option<Arc<GaussianScene>>,
    device_mix: Vec<crate::config::HardwareVariant>,
}

impl PoolBuilder {
    /// Number of sessions (default 1; must stay >= 1).
    pub fn sessions(mut self, n: usize) -> Self {
        self.n = n;
        self
    }

    /// Converge every viewer onto session 0's camera path, viewer `i`
    /// trailing viewer `i-1` by `k` frames, each serving
    /// `base.camera.frames` frames of its window — the cross-view
    /// redundancy workload the shared cache scope targets (trailing
    /// viewers revisit poses the pool has already cached). `k = 0` is
    /// the *spectator broadcast*: every viewer replays the identical
    /// pose stream, clustered sorting's best case.
    pub fn stagger(mut self, k: usize) -> Self {
        self.stagger = Some(k);
        self
    }

    /// Share an already-built scene instead of building one from the
    /// config (scene reuse across pools, benches).
    pub fn scene(mut self, scene: Arc<GaussianScene>) -> Self {
        self.scene = Some(scene);
        self
    }

    /// Heterogeneous device mix: session `i` simulates
    /// `mix[i % mix.len()]` instead of the base config's variant —
    /// GPU, LuminCore, and GSCore cost models serving from one pool.
    /// Empty (the default) keeps every session on `base.variant`.
    pub fn device_mix(mut self, mix: Vec<crate::config::HardwareVariant>) -> Self {
        self.device_mix = mix;
        self
    }

    /// Build the pool. Admission priority defaults to
    /// first-admitted-highest (session 0 is the last demoted); stable
    /// `session_id`s are assigned 0..n. Cluster sorts are deliberately
    /// NOT published at construction — the first `run_epoch` publishes
    /// lazily against the poses it actually renders.
    pub fn build(self) -> Result<SessionPool> {
        let PoolBuilder { base, n, stagger, scene, device_mix } = self;
        anyhow::ensure!(n > 0, "a pool needs at least one session");
        let scene = match scene {
            Some(s) => s,
            None => SessionPool::built_scene(&base)?,
        };
        let frames = base.camera.frames;
        let mut base = base;
        if let Some(k) = stagger {
            // Generate one long path on session 0 so every window below
            // is a slice of the same trajectory.
            base.camera.frames = frames + k * n.saturating_sub(1);
        }
        let variant_at = |i: usize| {
            if device_mix.is_empty() {
                base.variant
            } else {
                device_mix[i % device_mix.len()]
            }
        };
        // Hubs exist when the scope is enabled and *any* session's
        // variant can use them; sessions whose variant lacks the
        // mechanism simply never produce a cache geometry / sort
        // candidate, so mixed pools degrade per-session.
        let cache_hub = (base.pool.cache_scope.is_pooled()
            && (0..n).any(|i| variant_at(i).uses_rc()))
        .then(|| Arc::new(CacheHub::new()));
        let sort_hub = (base.pool.sort_scope == SortScope::Clustered
            && (0..n).any(|i| variant_at(i).uses_s2()))
        .then(|| {
            SortHub::with_position_radius(
                base.pool.cluster_radius as f32,
                base.pool.cluster_position_radius as f32,
            )
        });
        let sessions = (0..n)
            .map(|i| {
                let mut cfg = base.clone();
                cfg.camera.seed = base.camera.seed.wrapping_add(i as u64);
                cfg.variant = variant_at(i);
                let mut coord =
                    Coordinator::with_scene_in_pool(cfg, scene.clone(), cache_hub.clone())?;
                if sort_hub.is_some() {
                    coord.set_sort_clustered(true);
                }
                coord.priority = (n - i) as f64;
                coord.session_id = i as u64;
                Ok(coord)
            })
            .collect::<Result<Vec<_>>>()?;
        let mut pool = SessionPool {
            sessions,
            reduced: None,
            cache_hub,
            sort_hub,
            sort_published: Vec::new(),
            served: CacheStats::default(),
            world_decay_evictions: 0,
            next_id: n as u64,
            refused: 0,
        };
        if let Some(k) = stagger {
            let full = pool.sessions[0].trajectory.clone();
            for (i, c) in pool.sessions.iter_mut().enumerate() {
                let mut t = full.clone();
                t.poses = t.poses[i * k..i * k + frames].to_vec();
                c.trajectory = t;
            }
        }
        // Shared scope: set sharer counts (each view attached with its
        // own full-reload charge; the install below is snapshot-ptr
        // idempotent). A no-op for private pools.
        pool.sync_shared_cache();
        Ok(pool)
    }
}

impl SessionPool {
    /// Start building a pool from a base config — see [`PoolBuilder`].
    pub fn builder(base: LuminaConfig) -> PoolBuilder {
        PoolBuilder { base, n: 1, stagger: None, scene: None, device_mix: Vec::new() }
    }

    /// The scene a config describes (loaded or synthesized), ready to
    /// share across sessions.
    fn built_scene(base: &LuminaConfig) -> Result<Arc<GaussianScene>> {
        Ok(Arc::new(match &base.scene.path {
            Some(p) => crate::scene::io::read_scene(p)
                .with_context(|| format!("loading scene {p}"))?,
            None => synth_scene(base.scene.class, base.scene.seed, base.gaussian_count()),
        }))
    }

    /// Number of sessions.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// The sessions (for per-session inspection).
    pub fn sessions(&self) -> &[Coordinator] {
        &self.sessions
    }

    /// Mutable session access (tier experiments, priority overrides).
    pub fn sessions_mut(&mut self) -> &mut [Coordinator] {
        &mut self.sessions
    }

    /// Put session `i` on a serving tier, sharing the pool's one
    /// reduced-Gaussian subsample across demoted sessions. Under shared
    /// cache scope the swap re-attaches the session to the snapshot for
    /// its new cache geometry (its old-geometry delta is invalidated;
    /// the pool's snapshots — and every other session — are untouched).
    /// Under clustered sort scope the swap re-clusters immediately —
    /// the session's sort geometry changed, so it leaves its old
    /// cluster (whose shared sort is untouched) and joins whatever
    /// cluster its new geometry and predicted pose land it in.
    pub fn set_session_tier(&mut self, i: usize, tier: Tier) -> Result<()> {
        anyhow::ensure!(i < self.sessions.len(), "no session {i}");
        let reduced =
            if tier == Tier::Reduced { Some(self.shared_reduced_scene()) } else { None };
        self.sessions[i].set_tier_with(tier, reduced, false)?;
        self.sync_shared_cache();
        self.sync_shared_sorts();
        Ok(())
    }

    /// Opt session `i` out of (or back into) pool-clustered sorting:
    /// opted-out sessions keep the private windowed scheduler — their
    /// per-session kill switch from sharing — while the rest of the
    /// pool keeps clustering without them. A no-op on pools without a
    /// clustered sort scope.
    pub fn set_sort_opt_out(&mut self, i: usize, opt_out: bool) -> Result<()> {
        anyhow::ensure!(i < self.sessions.len(), "no session {i}");
        if self.sort_hub.is_none() {
            return Ok(());
        }
        self.sessions[i].set_sort_clustered(!opt_out);
        self.sync_shared_sorts();
        Ok(())
    }

    /// Pool-wide observed cache hit rate across every epoch-served
    /// frame so far (0 before any serving) — the rate shared-scope
    /// admission pricing and mid-run warm handoff consume.
    pub fn pool_hit_rate(&self) -> f64 {
        self.served.hit_rate()
    }

    /// (Re)install every shared-scope session's snapshot from the hub,
    /// with sharer counts per cache geometry — called after
    /// construction, tier changes, and epoch merges. Re-installing an
    /// unchanged snapshot is free, so this is idempotent.
    fn sync_shared_cache(&mut self) {
        let Some(hub) = self.cache_hub.clone() else { return };
        // World scope: one pool-wide snapshot regardless of tier or
        // resolution (world keys don't reference the tile grid), so
        // every world-caching session shares the same install and the
        // swap/decay traffic amortizes over all of them.
        let world_sharers = self.sessions.iter().filter(|c| c.caches_world()).count();
        if world_sharers > 0 {
            let params = super::world_params_for(
                &self.sessions.iter().find(|c| c.caches_world()).expect("counted above").cfg,
            );
            let snap = hub.world_snapshot(params);
            for c in self.sessions.iter_mut().filter(|c| c.caches_world()) {
                c.install_world_snapshot(snap.clone(), world_sharers);
            }
            return;
        }
        let geoms: Vec<Option<CacheGeometry>> =
            self.sessions.iter().map(|c| c.cache_geometry()).collect();
        for (i, g) in geoms.iter().enumerate() {
            let Some(g) = g else { continue };
            let sharers = geoms.iter().flatten().filter(|x| *x == g).count();
            self.sessions[i].install_cache_snapshot(hub.snapshot_for(*g), sharers);
        }
    }

    /// Epoch boundary of the shared cache: collect every session's
    /// insert delta **in session-index order**, replay them into the
    /// next snapshots, and re-install. The order is the whole
    /// shared-scope determinism argument — rendering inside an epoch
    /// reads only (frozen snapshot, own delta), and this merge is the
    /// single, serial, index-ordered point where sessions' writes meet.
    /// A no-op under private scope.
    fn merge_cache_epoch(&mut self) {
        let Some(hub) = self.cache_hub.clone() else { return };
        // Exactly one of the two collections is non-empty: a session's
        // view is either tile-keyed (shared) or world-keyed, never both.
        let world: Vec<WorldDelta> =
            self.sessions.iter_mut().filter_map(|c| c.take_world_delta()).collect();
        if !world.is_empty() {
            self.world_decay_evictions += hub.merge_world_in_order(world);
        }
        let deltas: Vec<CacheDelta> =
            self.sessions.iter_mut().filter_map(|c| c.take_cache_delta()).collect();
        hub.merge_in_order(deltas);
        self.sync_shared_cache();
    }

    /// Epoch boundary of the clustered sort scope: re-cluster the
    /// participating sessions by sort geometry and predicted pose,
    /// compute one speculative sort per cluster at the *leader's*
    /// predicted pose (serially, on this coordination thread — frame
    /// slots are drained at every boundary, so the predictions see
    /// exactly the state a synchronous pool would), and install it as a
    /// frozen `Arc` into every member. Followers render whole epochs
    /// against the frozen sort while refreshing colors/geometry at
    /// their own poses; nothing a rendering thread touches is shared,
    /// so clustered-scope output is bitwise identical at any thread
    /// count and pipeline depth. A no-op under private sort scope.
    fn sync_shared_sorts(&mut self) {
        let Some(hub) = self.sort_hub else { return };
        let cands: Vec<SortCandidate> = self
            .sessions
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                c.sort_candidate().map(|(geometry, pose)| SortCandidate {
                    session: i,
                    geometry,
                    pose,
                })
            })
            .collect();
        let mut published = Vec::new();
        for cluster in hub.cluster(&cands) {
            let lead = cands
                .iter()
                .find(|c| c.session == cluster[0])
                .expect("leader is a candidate");
            let (geometry, pose) = (lead.geometry, lead.pose);
            // Reuse the published sort when its inputs — the leader's
            // geometry and predicted pose — are unchanged: the
            // recompute is deterministic, so it could only produce the
            // identical result. Installs still run — a tier rebuild may
            // have dropped a member's copy — and re-set the leader's
            // pending work accounting (idempotent until a frame
            // consumes it).
            let sort = match self
                .sort_published
                .iter()
                .find(|(g, p, _)| *g == geometry && *p == pose)
            {
                Some((_, _, s)) => s.clone(),
                None => Arc::new(self.sessions[cluster[0]].compute_shared_sort(&pose)),
            };
            for (pos, &s) in cluster.iter().enumerate() {
                self.sessions[s].install_shared_sort(sort.clone(), pos == 0, cluster.len());
            }
            published.push((geometry, pose, sort));
        }
        self.sort_published = published;
    }

    /// The pool-wide reduced-tier scene (cut lazily, then shared).
    fn shared_reduced_scene(&mut self) -> Arc<GaussianScene> {
        if let Some(s) = &self.reduced {
            return s.clone();
        }
        let base = &self.sessions[0];
        let s = Arc::new(base.scene.reduced_prefix(base.cfg.pool.reduced_fraction));
        self.reduced = Some(s.clone());
        s
    }

    /// Run every session to the end of its trajectory, sessions in
    /// parallel (each session's frames stay sequential — S² and RC
    /// state are inherently frame-ordered). Pools with a shared cache
    /// or clustered sort scope run in epochs of `pool.epoch_frames` —
    /// the boundary is where cache deltas merge and cluster sorts
    /// re-publish; fully private pools run straight through.
    pub fn run(&mut self) -> Result<PoolReport> {
        // detlint: allow(wall-clock) -- report-only wall time for PoolReport; never read back into frame math
        let start = Instant::now();
        let mut epochs = Vec::new();
        // (`with_scene` guarantees a non-empty pool; the emptiness
        // check keeps the indexing below robust regardless.)
        let epoch_scoped = self.cache_hub.is_some() || self.sort_hub.is_some();
        if epoch_scoped && !self.sessions.is_empty() {
            let epoch = self.sessions[0].cfg.pool.epoch_frames.max(1);
            while self.sessions.iter().any(|c| c.remaining() > 0 || c.in_flight() > 0) {
                epochs.push(self.run_epoch(epoch)?);
            }
        } else {
            epochs.push(self.run_parallel(None)?);
        }
        let wall_s = start.elapsed().as_secs_f64();
        Ok(self.assemble_report(epochs, wall_s))
    }

    /// One pool epoch: step every session up to `frames` completed
    /// frames (sessions in parallel, pipelined slots drained at the
    /// boundary), then merge the shared-cache deltas in session-index
    /// order and re-cluster/re-publish the shared sorts (each a no-op
    /// under the corresponding private scope). Returns the epoch's
    /// frame reports per session.
    pub fn run_epoch(&mut self, frames: usize) -> Result<Vec<Vec<FrameReport>>> {
        // First epoch of a clustered pool: nothing is published yet
        // (construction defers, since builders may rewrite
        // trajectories), so publish now against the poses this epoch
        // actually renders. A cheap no-op whenever sorts are already
        // installed or there are no candidates.
        if self.sort_published.is_empty() {
            self.sync_shared_sorts();
        }
        let out = self.run_parallel(Some(frames.max(1)))?;
        for frames in &out {
            for f in frames {
                self.served.merge(&f.cache);
            }
        }
        self.merge_cache_epoch();
        self.sync_shared_sorts();
        Ok(out)
    }

    /// [`run_epoch`](Self::run_epoch), but returning full
    /// [`FrameResult`]s — rendered images included — per session.
    /// Scheduler-parity tests use this to compare pixels; production
    /// paths should prefer `run_epoch`, which drops images per frame
    /// instead of holding an epoch's worth.
    pub fn run_epoch_results(&mut self, frames: usize) -> Result<Vec<Vec<FrameResult>>> {
        if self.sort_published.is_empty() {
            self.sync_shared_sorts();
        }
        let out = self.run_parallel_with(Some(frames.max(1)), |f: FrameResult| f)?;
        for frames in &out {
            for f in frames {
                self.served.merge(&f.report.cache);
            }
        }
        self.merge_cache_epoch();
        self.sync_shared_sorts();
        Ok(out)
    }

    /// Capacity-managed serving: plan tiers from a probe of every
    /// session, then run the pool in epochs of `pool.epoch_frames`
    /// frames, re-pricing the sessions' recent workloads and
    /// re-planning tiers between epochs (promotion on headroom,
    /// demotion under pressure). Errors — including a refused
    /// admission — restore the pool.
    pub fn serve(&mut self, ctrl: &AdmissionController) -> Result<PoolReport> {
        anyhow::ensure!(!self.sessions.is_empty(), "cannot serve an empty pool");
        let epoch = self.sessions[0].cfg.pool.epoch_frames.max(1);
        // detlint: allow(wall-clock) -- report-only wall time for PoolReport; never read back into frame math
        let start = Instant::now();

        // Probe: render (without consuming) one frame per session so
        // the controller has a measured workload to price, then apply
        // the initial plan with a forced rebuild — wiping the probe's
        // stage-state side effects so served frames start pristine.
        // Refusal here is fatal: these viewers were not admitted.
        let (active, demands) = self.probe_active_demands()?;
        if !demands.is_empty() {
            match ctrl.plan(&demands) {
                Ok(plan) => self.apply_tiers_at(&active, &plan.tiers, true)?,
                Err(refusal) => {
                    // Wipe the probe's stage-state side effects before
                    // surfacing the refusal, so the un-admitted pool
                    // renders byte-identically to one that never
                    // attempted serving.
                    let current: Vec<Tier> =
                        active.iter().map(|&i| self.sessions[i].tier()).collect();
                    self.apply_tiers_at(&active, &current, true)?;
                    self.refused += 1;
                    return Err(refusal);
                }
            }
        }

        let mut epochs: Vec<Vec<Vec<FrameReport>>> = Vec::new();
        // `self.served` accumulates pool-wide observed cache stats over
        // every epoch-served frame: the hit rate shared-scope pricing
        // consumes (a session's future hits come from the pool's merged
        // inserts, not its own history). Deterministic: merged in
        // epoch/session order.
        while self.sessions.iter().any(|c| c.remaining() > 0 || c.in_flight() > 0) {
            epochs.push(self.run_epoch(epoch)?);
            // Re-plan over the sessions that still have frames to serve
            // — finished viewers consume no device time and must not
            // demote (or refuse) the live ones.
            let (active, demands) = self.active_demands(self.pool_hit_rate())?;
            if active.is_empty() {
                break;
            }
            match ctrl.plan(&demands) {
                Ok(plan) => self.apply_tiers_at(&active, &plan.tiers, false)?,
                Err(_) => {
                    // Admitted viewers are never kicked mid-run: when
                    // transient load makes even the bottom mix miss the
                    // target, serve best-effort at each session's lowest
                    // servable tier until the pressure clears.
                    let floors = ctrl.floor_tiers(&demands);
                    self.apply_tiers_at(&active, &floors, false)?;
                }
            }
        }
        let wall_s = start.elapsed().as_secs_f64();
        Ok(self.assemble_report(epochs, wall_s))
    }

    /// (indices, demands) of the sessions that still have frames to
    /// serve, from each one's most recent measured workload.
    /// `pool_hit_rate` is the pool-wide observed cache hit rate the
    /// shared-scope pricing discount consumes (0 before any serving).
    fn active_demands(
        &self,
        pool_hit_rate: f64,
    ) -> Result<(Vec<usize>, Vec<SessionDemand>)> {
        let mut indices = Vec::new();
        let mut demands = Vec::new();
        for (i, c) in self.sessions.iter().enumerate() {
            if c.remaining() == 0 && c.in_flight() == 0 {
                continue;
            }
            indices.push(i);
            demands.push(Self::demand_for(c, pool_hit_rate)?);
        }
        Ok((indices, demands))
    }

    /// One session's planning input from its most recent measured
    /// workload.
    fn demand_for(c: &Coordinator, pool_hit_rate: f64) -> Result<SessionDemand> {
        let w = c
            .last_workload()
            .context("session has no measured workload to price")?;
        Ok(SessionDemand {
            workload: w.clone(),
            tier: c.tier(),
            variant: c.cfg.variant,
            half_capable: c.tier_servable(Tier::Half),
            priority: c.priority,
            cache_shared: c.shares_cache(),
            cache_world: c.caches_world(),
            pool_hit_rate,
            sort_clustered: c.sorts_clustered(),
            sort_sharers: c.sort_sharers(),
            sort_leader: c.sort_is_leader(),
        })
    }

    /// [`Self::active_demands`] for a pool that has not served a frame
    /// yet: probe-render each active session's current pose first. The
    /// shared-scope discount uses whatever hit rate the pool has
    /// observed so far — zero on a fresh pool.
    fn probe_active_demands(&mut self) -> Result<(Vec<usize>, Vec<SessionDemand>)> {
        for c in self.sessions.iter_mut() {
            if c.remaining() > 0 && c.last_workload().is_none() {
                c.probe_workload()?;
            }
        }
        self.active_demands(self.pool_hit_rate())
    }

    /// Demands for every session with frames to serve, probing those
    /// that have not measured a workload yet (admission what-ifs, e.g.
    /// "how many viewers fit?" sweeps).
    pub fn probe_demands(&mut self) -> Result<Vec<SessionDemand>> {
        Ok(self.probe_active_demands()?.1)
    }

    /// Apply planned tiers to the sessions at `indices`; `force_rebuild`
    /// resets stage state even on sessions whose tier is unchanged.
    fn apply_tiers_at(
        &mut self,
        indices: &[usize],
        tiers: &[Tier],
        force_rebuild: bool,
    ) -> Result<()> {
        anyhow::ensure!(indices.len() == tiers.len(), "plan/pool size mismatch");
        for (&i, &tier) in indices.iter().zip(tiers) {
            let reduced =
                if tier == Tier::Reduced { Some(self.shared_reduced_scene()) } else { None };
            self.sessions[i].set_tier_with(tier, reduced, force_rebuild)?;
        }
        // Tier swaps can change cache geometries (and rebuilds detach
        // deltas): refresh every shared session's snapshot + sharer
        // count. They change sort geometries too (and rebuilds drop
        // installed cluster sorts), so re-cluster and re-publish.
        self.sync_shared_cache();
        self.sync_shared_sorts();
        Ok(())
    }

    /// Admit a new viewer mid-run. The session is built over the pool's
    /// shared scene (joining the cache hub and clustered sort scope
    /// when the pool has them), probe-rendered once, and priced
    /// alongside the still-active sessions — with its raster stage
    /// discounted by the **pool-wide observed hit rate** rather than
    /// cold (the warm handoff): under shared cache scope the snapshot a
    /// late joiner attaches to is already merged and warm, so its hits
    /// arrive from frame one and cold pricing would refuse viewers the
    /// pool actually holds. The joiner enters at the lowest priority
    /// (demoted first under pressure). On refusal the pool is left
    /// exactly as it was; on success the new session's index is
    /// returned and the planned tiers are applied pool-wide.
    pub fn admit(&mut self, cfg: LuminaConfig, ctrl: &AdmissionController) -> Result<usize> {
        anyhow::ensure!(!self.sessions.is_empty(), "cannot admit into an empty pool");
        let scene = self.sessions[0].scene.clone();
        let mut joiner = Coordinator::with_scene_in_pool(cfg, scene, self.cache_hub.clone())?;
        if self.sort_hub.is_some() {
            joiner.set_sort_clustered(true);
        }
        joiner.priority = 0.0;
        joiner.probe_workload()?;
        let rate = self.pool_hit_rate();
        let (active, mut demands) = self.active_demands(rate)?;
        demands.push(Self::demand_for(&joiner, rate)?);
        // A refusal drops the joiner here and touches nothing else
        // (except the refusal counter the loadtest SLOs report).
        let plan = match ctrl.plan(&demands) {
            Ok(p) => p,
            Err(refusal) => {
                self.refused += 1;
                return Err(refusal);
            }
        };
        let (existing, joined) = plan.tiers.split_at(active.len());
        let tier = joined[0];
        let reduced =
            if tier == Tier::Reduced { Some(self.shared_reduced_scene()) } else { None };
        // Forced rebuild: wipe the probe's stage-state side effects so
        // the admitted session serves pristine frames.
        joiner.set_tier_with(tier, reduced, true)?;
        joiner.session_id = self.next_id;
        self.next_id += 1;
        let idx = self.sessions.len();
        self.sessions.push(joiner);
        // Applies the re-planned tiers and re-syncs shared cache
        // snapshots (sharer counts grew) and cluster sorts.
        self.apply_tiers_at(&active, existing, false)?;
        Ok(idx)
    }

    /// Retire session `i` — the departure path symmetric with
    /// [`Self::admit`]. The session's pipelined frame slots are drained
    /// under its current tier (those frames were already dispatched, so
    /// they complete and are returned), its un-merged shared-cache
    /// delta leaves with it — only epoch-boundary merges publish
    /// writes, so a departing viewer cannot perturb the pool's cache
    /// contents — and the shared-cache sharer counts and sort-cluster
    /// membership re-sync over the remaining sessions, whose relative
    /// order (and therefore the index-ordered epoch merge) is
    /// unchanged. Call at an epoch boundary for bitwise-reproducible
    /// churn; retiring the last session leaves a valid empty pool.
    pub fn retire(&mut self, i: usize) -> Result<Vec<FrameReport>> {
        anyhow::ensure!(i < self.sessions.len(), "no session {i}");
        let mut departing = self.sessions.remove(i);
        let mut drained = Vec::new();
        while departing.in_flight() > 0 {
            match departing.drain_one()? {
                Some(f) => {
                    self.served.merge(&f.report.cache);
                    drained.push(f.report);
                }
                None => break,
            }
        }
        // Discard the delta rather than merging it: mid-epoch inserts
        // are invisible to other sessions until the boundary merge, and
        // a viewer that leaves before the boundary must stay invisible
        // — otherwise retire timing inside an epoch would change the
        // pool's cache bits.
        let _ = departing.take_cache_delta();
        let _ = departing.take_world_delta();
        self.sync_shared_cache();
        self.sync_shared_sorts();
        Ok(drained)
    }

    /// Re-plan serving tiers over the still-active sessions without
    /// rendering an epoch: probe sessions that have no measured
    /// workload yet (fresh pools, new joiners), price everyone, and
    /// apply the plan — falling back to each session's floor tier when
    /// even the bottom mix misses the target (admitted viewers are
    /// never kicked). The churn driver's building block: interleave
    /// [`Self::admit`]/[`Self::retire`]/[`Self::run_epoch`] and call
    /// this at the boundaries [`Self::serve`] would have re-planned at.
    pub fn replan(&mut self, ctrl: &AdmissionController, force_rebuild: bool) -> Result<()> {
        let (active, demands) = self.probe_active_demands()?;
        if active.is_empty() {
            return Ok(());
        }
        match ctrl.plan(&demands) {
            Ok(plan) => self.apply_tiers_at(&active, &plan.tiers, force_rebuild),
            Err(_) => {
                let floors = ctrl.floor_tiers(&demands);
                self.apply_tiers_at(&active, &floors, force_rebuild)
            }
        }
    }

    /// Cumulative refused admissions (see [`PoolReport::refusals`]).
    pub fn refusals(&self) -> usize {
        self.refused
    }

    /// Step every session up to `cap` frames (or to the end of its
    /// trajectory when `None`), sessions in parallel.
    ///
    /// The thread budget is *split* between the two nesting levels —
    /// outer session workers whose pipeline stages parallelize over a
    /// per-worker share — instead of letting every session spawn a full
    /// complement (which would oversubscribe roughly quadratically).
    /// The split wastes no threads on non-divisible budgets, and each
    /// share is installed thread-locally via an RAII guard. Results are
    /// thread-count invariant, so the split affects throughput only.
    fn run_parallel(&mut self, cap: Option<usize>) -> Result<Vec<Vec<FrameReport>>> {
        // Map inside the workers so epoch images are dropped per frame;
        // only `run_epoch_results` (parity tests) retains them.
        self.run_parallel_with(cap, |f: FrameResult| f.report)
    }

    /// Engine behind [`run_parallel`](Self::run_parallel): steps every
    /// live session up to `cap` frames under the configured scheduler
    /// and maps each completed [`FrameResult`] through `map` at the
    /// point of delivery (so callers that only need reports never hold
    /// a whole epoch of images).
    ///
    /// `pool.scheduler = "session"` keeps whole sessions on outer
    /// workers; `"stealing"` hands all live sessions to the pool-wide
    /// task-graph scheduler ([`steal::run_sessions`]) where idle
    /// workers claim other sessions' stage tasks. Both produce bitwise
    /// identical frames (`tests/stealing.rs`).
    fn run_parallel_with<T: Send>(
        &mut self,
        cap: Option<usize>,
        map: impl Fn(FrameResult) -> T + Sync,
    ) -> Result<Vec<Vec<T>>> {
        let n = self.sessions.len();
        let mode = self
            .sessions
            .first()
            .map(|c| c.cfg.pool.scheduler)
            .unwrap_or(SchedulerMode::Session);
        // Only sessions with frames left occupy workers — in the tail
        // epochs of a heterogeneous pool the whole budget goes to the
        // sessions still rendering instead of idling on finished ones.
        let mut work: Vec<(usize, Coordinator, Option<Result<Vec<T>>>)> = Vec::new();
        let mut idle: Vec<(usize, Coordinator)> = Vec::new();
        for (i, c) in std::mem::take(&mut self.sessions).into_iter().enumerate() {
            if c.remaining() > 0 || c.in_flight() > 0 {
                work.push((i, c, None));
            } else {
                idle.push((i, c));
            }
        }
        match mode {
            SchedulerMode::Session if !work.is_empty() => {
                // detlint: allow(thread-count) -- scheduling site: sizes outer workers and splits the thread budget; rendered values never depend on it
                let total = par::num_threads();
                // Stage-level scheduling: a depth-d session dispatches up to
                // d stages concurrently (frame N+1's frontend alongside
                // frame N's raster), so size the outer worker count by
                // *stage slots* rather than whole sessions — fewer outer
                // workers, each holding the >= depth threads its session's
                // concurrent stages can actually occupy.
                let depth =
                    work.iter().map(|(_, c, _)| c.pipeline_depth()).max().unwrap_or(1).max(1);
                let outer = (total / depth).clamp(1, work.len());
                let chunk = work.len().div_ceil(outer);
                let n_workers = work.len().div_ceil(chunk);
                let budgets = par::split_budget(total, n_workers);
                let map = &map;
                std::thread::scope(|scope| {
                    for (t, slice) in work.chunks_mut(chunk).enumerate() {
                        let inner = budgets[t];
                        scope.spawn(move || {
                            let _budget = par::local_budget_guard(inner);
                            for (_, coord, slot) in slice.iter_mut() {
                                *slot = Some(step_session(coord, cap, map));
                            }
                        });
                    }
                });
            }
            SchedulerMode::Stealing if !work.is_empty() => {
                let outs = steal::run_sessions(
                    work.iter_mut().map(|(_, c, _)| c).collect(),
                    cap,
                    &map,
                );
                for ((_, _, slot), out) in work.iter_mut().zip(outs) {
                    *slot = Some(out);
                }
            }
            _ => {}
        }
        // Restore every session (original order) before surfacing any
        // error so the pool stays intact even when one session fails.
        let mut slots: Vec<Option<(Coordinator, Result<Vec<T>>)>> =
            (0..n).map(|_| None).collect();
        for (i, c, s) in work {
            slots[i] = Some((c, s.expect("session executed")));
        }
        for (i, c) in idle {
            slots[i] = Some((c, Ok(Vec::new())));
        }
        let mut results = Vec::with_capacity(n);
        for slot in slots {
            let (coord, res) = slot.expect("every session accounted for");
            self.sessions.push(coord);
            results.push(res);
        }
        results.into_iter().collect()
    }

    /// Stitch per-epoch, per-session frame batches into one
    /// [`RunReport`] per session.
    fn assemble_report(
        &self,
        epochs: Vec<Vec<Vec<FrameReport>>>,
        wall_s: f64,
    ) -> PoolReport {
        let mut sessions: Vec<RunReport> = self
            .sessions
            .iter()
            .map(|c| RunReport::new(c.cfg.variant.label()))
            .collect();
        for epoch in epochs {
            for (i, frames) in epoch.into_iter().enumerate() {
                for f in frames {
                    sessions[i].push(f);
                }
            }
        }
        let pipeline_depth = self
            .sessions
            .iter()
            .map(|c| c.pipeline_depth())
            .max()
            .unwrap_or(1);
        PoolReport {
            sessions,
            wall_s,
            pipeline_depth,
            refusals: self.refused,
            decay_evictions: self.world_decay_evictions,
        }
    }
}

/// Run one session for up to `cap` *completed* frames (whole trajectory
/// if `None`).
///
/// Depth-1 sessions step synchronously. Pipelined sessions dispatch
/// stages: keep feeding frontends while the in-flight frame rasterizes,
/// then drain — no new frontend — once the epoch's completion target is
/// covered, so every epoch boundary (where the pool re-plans tiers) sees
/// empty frame slots and the admission controller prices the same
/// final-frame workload a synchronous pool would.
fn step_session<T, M: Fn(FrameResult) -> T>(
    coord: &mut Coordinator,
    cap: Option<usize>,
    map: &M,
) -> Result<Vec<T>> {
    let limit = cap.unwrap_or(usize::MAX);
    let mut frames = Vec::new();
    if coord.pipeline_depth() <= 1 {
        while coord.remaining() > 0 && frames.len() < limit {
            frames.push(map(coord.step()?));
        }
        return Ok(frames);
    }
    let target = limit.min(coord.remaining() + coord.in_flight());
    while frames.len() < target {
        let feed = frames.len() + coord.in_flight() < target && coord.remaining() > 0;
        let done = if feed { coord.step_pipelined()? } else { coord.drain_one()? };
        if let Some(f) = done {
            frames.push(map(f));
        } else if !feed && coord.in_flight() == 0 {
            // Defensive: nothing in flight and nothing to feed.
            break;
        }
    }
    Ok(frames)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareVariant;

    fn small_cfg() -> LuminaConfig {
        let mut c = LuminaConfig::quick_test();
        c.scene.count = 3000;
        c.camera.width = 64;
        c.camera.height = 64;
        c.camera.frames = 4;
        c.variant = HardwareVariant::Gpu;
        c
    }

    #[test]
    fn erroring_session_restores_thread_budget_and_pool() {
        let before = par::num_threads();
        let mut pool = SessionPool::builder(small_cfg()).sessions(3).build().unwrap();
        pool.sessions[1].fail_at_frame = Some(2);
        let err = pool.run();
        assert!(err.is_err(), "injected failure must surface");
        assert_eq!(
            par::num_threads(),
            before,
            "session error leaked a clamped thread budget"
        );
        // The pool itself survives (sessions restored in order).
        assert_eq!(pool.len(), 3);
        pool.sessions[1].fail_at_frame = None;
        let report = pool.run().unwrap();
        // Session 1 already consumed frames 0-1 before the injected
        // failure; the others were fully consumed by the first run.
        assert_eq!(report.sessions[1].frames.len(), 2);
    }

    #[test]
    fn panicking_session_restores_thread_budget() {
        let before = par::num_threads();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut pool = SessionPool::builder(small_cfg()).sessions(2).build().unwrap();
            pool.sessions[0].panic_at_frame = Some(1);
            let _ = pool.run();
        }));
        assert!(result.is_err(), "injected panic must propagate");
        assert_eq!(
            par::num_threads(),
            before,
            "session panic leaked a clamped thread budget"
        );
    }

    #[test]
    fn pool_priorities_default_first_admitted_highest() {
        let pool = SessionPool::builder(small_cfg()).sessions(3).build().unwrap();
        let p: Vec<f64> = pool.sessions().iter().map(|c| c.priority).collect();
        assert!(p[0] > p[1] && p[1] > p[2]);
    }

    #[test]
    fn serve_excludes_finished_sessions_from_replanning() {
        let mut cfg = small_cfg();
        cfg.pool.epoch_frames = 2;
        let mut pool = SessionPool::builder(cfg.clone()).sessions(3).build().unwrap();
        // Session 2 finishes after a single frame; later epochs re-plan
        // over the two live sessions only.
        pool.sessions[2].trajectory.poses.truncate(1);
        // Generous target: nobody should be demoted for a dead session.
        let ctrl =
            AdmissionController::new(1e-3, cfg.pool.tiers.clone(), cfg.pool.reduced_fraction)
                .unwrap();
        let report = pool.serve(&ctrl).unwrap();
        let frames: Vec<usize> = report.sessions.iter().map(|r| r.frames.len()).collect();
        assert_eq!(frames, vec![4, 4, 1]);
        for r in &report.sessions {
            assert_eq!(r.tier_sequence(), vec!["full"], "generous target must stay full");
        }
    }

    #[test]
    fn heterogeneous_summary_reports_range() {
        let mut pool = SessionPool::builder(small_cfg()).sessions(2).build().unwrap();
        // Make the trajectories heterogeneous: truncate session 1.
        pool.sessions[1].trajectory.poses.truncate(2);
        let report = pool.run().unwrap();
        let s = report.summary();
        assert!(s.contains("2 sessions"), "summary: {s}");
        assert!(s.contains("2-4 frames"), "summary must not lie about counts: {s}");
    }

    #[test]
    fn builder_pins_historical_constructor_semantics() {
        // The removed `new`/`convergent` shims delegated straight to the
        // builder; this pins the builder against their documented
        // semantics so the migration stays behavior-preserving: distinct
        // camera seeds (base + i), descending priorities, and the
        // staggered-window rewrite (session i+1 starts `stagger` poses
        // behind session i on session 0's long path).
        let base_seed = small_cfg().camera.seed;
        let pool = SessionPool::builder(small_cfg()).sessions(2).build().unwrap();
        let seeds: Vec<u64> =
            pool.sessions().iter().map(|c| c.cfg.camera.seed).collect();
        assert_eq!(seeds, vec![base_seed, base_seed + 1]);
        let prios: Vec<f64> = pool.sessions().iter().map(|c| c.priority).collect();
        assert_eq!(prios, vec![2.0, 1.0]);

        let pool =
            SessionPool::builder(small_cfg()).sessions(3).stagger(2).build().unwrap();
        let t: Vec<Vec<Pose>> = pool
            .sessions()
            .iter()
            .map(|c| c.trajectory.poses.clone())
            .collect();
        let frames = small_cfg().camera.frames;
        assert!(t.iter().all(|p| p.len() == frames));
        // Overlap: session i's tail re-walks session i+1's head.
        assert_eq!(t[0][2..4], t[1][0..2]);
        assert_eq!(t[1][2..4], t[2][0..2]);
    }

    #[test]
    fn builder_assigns_stable_session_ids() {
        let pool = SessionPool::builder(small_cfg()).sessions(3).build().unwrap();
        let ids: Vec<u64> = pool.sessions().iter().map(|c| c.session_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn device_mix_round_robins_variants() {
        let mix = vec![HardwareVariant::Gpu, HardwareVariant::GsCore];
        let pool = SessionPool::builder(small_cfg())
            .sessions(3)
            .device_mix(mix)
            .build()
            .unwrap();
        let labels: Vec<&str> =
            pool.sessions().iter().map(|c| c.cfg.variant.label()).collect();
        assert_eq!(labels, vec!["GPU", "GSCore", "GPU"]);
    }

    #[test]
    fn retire_shifts_indices_but_not_ids() {
        let mut pool = SessionPool::builder(small_cfg()).sessions(3).build().unwrap();
        let drained = pool.retire(1).unwrap();
        assert!(drained.is_empty(), "synchronous sessions have no in-flight frames");
        assert_eq!(pool.len(), 2);
        let ids: Vec<u64> = pool.sessions().iter().map(|c| c.session_id).collect();
        assert_eq!(ids, vec![0, 2], "identity survives the index shift");
        // The remaining pool still runs to completion.
        let report = pool.run().unwrap();
        assert_eq!(report.sessions.len(), 2);
        assert!(report.sessions.iter().all(|r| r.frames.len() == 4));
        // Retiring everyone leaves a valid empty pool.
        pool.retire(1).unwrap();
        pool.retire(0).unwrap();
        assert!(pool.is_empty());
        assert!(pool.retire(0).is_err(), "no session left to retire");
        assert_eq!(pool.run().unwrap().total_frames(), 0);
    }

    #[test]
    fn empty_pool_report_slos_are_zero() {
        let mut pool = SessionPool::builder(small_cfg()).sessions(1).build().unwrap();
        pool.retire(0).unwrap();
        let report = pool.run().unwrap();
        assert_eq!(report.latency_percentile(99.0), 0.0);
        assert_eq!(report.demotions(), 0);
        assert_eq!(report.demotion_rate(), 0.0);
        assert_eq!(report.refusals, 0);
    }
}
