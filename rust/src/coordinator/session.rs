//! Multi-session serving: N independent viewer sessions over one shared
//! scene, stepped in parallel.
//!
//! Each session is a full [`Coordinator`] — its own trajectory (camera
//! seed offset per viewer), its own S² scheduler state, its own radiance
//! cache — but all sessions read the same `Arc<GaussianScene>`, so scene
//! memory is paid once no matter how many viewers are attached. Sessions
//! run concurrently via [`crate::util::par`]; every session is fully
//! deterministic given its config, so the pool's output is independent
//! of `LUMINA_THREADS` (enforced by `tests/sessions.rs`).
//!
//! This is the first multi-user serving scenario on the stage-graph
//! frame loop; ROADMAP "Open items" lists the follow-ons it unlocks
//! (batched cross-session frontends, async pipelining, LoD tiers).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::LuminaConfig;
use crate::coordinator::{Coordinator, RunReport};
use crate::scene::synth::synth_scene;
use crate::scene::GaussianScene;
use crate::util::par;

/// A pool of independent viewer sessions over one shared scene.
pub struct SessionPool {
    sessions: Vec<Coordinator>,
}

/// Aggregated result of running every session to completion.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolReport {
    /// Per-session run reports, in session order.
    pub sessions: Vec<RunReport>,
    /// Host wall-clock time for the whole parallel run (s).
    pub wall_s: f64,
}

impl PoolReport {
    /// Total frames rendered across sessions.
    pub fn total_frames(&self) -> usize {
        self.sessions.iter().map(|r| r.frames.len()).sum()
    }

    /// Aggregate *simulated* throughput: the summed frame rate the
    /// modeled hardware sustains serving all sessions at once.
    pub fn aggregate_fps(&self) -> f64 {
        self.sessions.iter().map(|r| r.fps()).sum()
    }

    /// Mean simulated frame rate per session.
    pub fn mean_session_fps(&self) -> f64 {
        if self.sessions.is_empty() {
            0.0
        } else {
            self.aggregate_fps() / self.sessions.len() as f64
        }
    }

    /// Host rendering throughput: functional frames per wall second.
    pub fn host_fps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_frames() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// One-line throughput summary.
    pub fn summary(&self) -> String {
        format!(
            "pool: {} sessions x {} frames | aggregate {:.1} sim-fps ({:.1}/session) | \
             host {:.1} fps | wall {:.3} s",
            self.sessions.len(),
            self.sessions.first().map(|r| r.frames.len()).unwrap_or(0),
            self.aggregate_fps(),
            self.mean_session_fps(),
            self.host_fps(),
            self.wall_s
        )
    }
}

impl SessionPool {
    /// Build `n` sessions from a base config. The scene is built once
    /// and shared; each session gets a distinct camera seed (base + i)
    /// so the viewers follow different trajectories.
    pub fn new(base: LuminaConfig, n: usize) -> Result<Self> {
        let scene = match &base.scene.path {
            Some(p) => crate::scene::io::read_scene(p)
                .with_context(|| format!("loading scene {p}"))?,
            None => synth_scene(base.scene.class, base.scene.seed, base.gaussian_count()),
        };
        Self::with_scene(base, Arc::new(scene), n)
    }

    /// Build `n` sessions over an already-built shared scene.
    pub fn with_scene(
        base: LuminaConfig,
        scene: Arc<GaussianScene>,
        n: usize,
    ) -> Result<Self> {
        anyhow::ensure!(n > 0, "a pool needs at least one session");
        let sessions = (0..n)
            .map(|i| {
                let mut cfg = base.clone();
                cfg.camera.seed = base.camera.seed.wrapping_add(i as u64);
                Coordinator::with_scene(cfg, scene.clone())
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(SessionPool { sessions })
    }

    /// Number of sessions.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// The sessions (for per-session inspection).
    pub fn sessions(&self) -> &[Coordinator] {
        &self.sessions
    }

    /// Run every session to the end of its trajectory, sessions in
    /// parallel (each session's frames stay sequential — S² and RC
    /// state are inherently frame-ordered).
    ///
    /// The machine's thread budget is *split* between the two nesting
    /// levels — `outer` session workers, each of whose pipeline stages
    /// parallelizes over `total / outer` workers — instead of letting
    /// every session independently spawn a full complement (which would
    /// oversubscribe roughly quadratically). Results are thread-count
    /// invariant, so the cap affects throughput only.
    pub fn run(&mut self) -> Result<PoolReport> {
        let start = Instant::now();
        let mut work: Vec<(Coordinator, Option<Result<RunReport>>)> =
            std::mem::take(&mut self.sessions)
                .into_iter()
                .map(|c| (c, None))
                .collect();
        let total = par::num_threads();
        let outer = total.min(work.len()).max(1);
        let inner = (total / outer).max(1);
        par::set_num_threads(inner);
        let chunk = work.len().div_ceil(outer);
        std::thread::scope(|scope| {
            for slice in work.chunks_mut(chunk) {
                scope.spawn(move || {
                    for (coord, slot) in slice.iter_mut() {
                        *slot = Some(coord.run());
                    }
                });
            }
        });
        par::set_num_threads(total);
        let wall_s = start.elapsed().as_secs_f64();
        // Restore every session before surfacing any error so the pool
        // stays intact even when one session fails.
        let mut results = Vec::with_capacity(work.len());
        for (coord, slot) in work {
            self.sessions.push(coord);
            results.push(slot.expect("session executed"));
        }
        let sessions = results.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(PoolReport { sessions, wall_s })
    }
}
