//! Deterministic pool-wide work-stealing stage scheduler
//! (`pool.scheduler = "stealing"`).
//!
//! [`run_sessions`] replaces the per-session outer-worker split of
//! `SessionPool::run_epoch`: instead of pinning each worker to a
//! contiguous slice of sessions, the epoch unrolls into *rounds* of
//! independent stage tasks — per live session, one frame-granular step
//! (depth 1) or a frontend step and/or a [`DispatchPlan`] of raster
//! chunks (depth >= 2) — and a fixed worker pool claims tasks through
//! the same atomic claim/write publication pattern as `util::par`'s
//! dynamic-claim loops ([`par::TaskClaimer`]). An idle worker claims
//! the lowest-ID ready task regardless of which session owns it, so a
//! straggler session (a cluster leader paying the shared sort, the slow
//! end of a heterogeneous device mix) is swarmed by the whole pool
//! instead of serializing its lone worker while the rest idle.
//!
//! # Determinism argument
//!
//! Output is bitwise identical to the per-session scheduler — and
//! across 1/2/4 worker threads — because nothing a task *computes*
//! depends on who runs it or when:
//!
//! 1. **The round's task graph is fixed before any worker starts.**
//!    [`SessionRun::prepare`] replays `step_session`'s feed/drain
//!    sequencing per session on the coordination thread, so the set of
//!    ready tasks (and every task's inputs: the consumed pose, the
//!    chunk ranges) derives purely from session state, never from
//!    timing.
//! 2. **Stage outputs are thread-budget invariant** (pinned by
//!    `tests/sessions.rs`), so which worker claims a task, and how many
//!    threads its nested `par_*` calls see, affect wall-clock only.
//! 3. **Results merge in task-ID order, never completion order.** Each
//!    task writes its own pre-allocated slot ([`TaskSlots`]); after the
//!    scope joins, the coordination thread commits slots in (session
//!    index, stage) order through
//!    [`PipelinedSession::apply_dispatch`], exactly where the
//!    per-session scheduler would have.
//!
//! The module also hosts the *occupancy model* the benches and the
//! loadtest harness emit ([`idle_worker_frames_session`] /
//! [`idle_worker_frames_stealing`] /
//! [`epoch_critical_path_frames`]): a machine-independent account of
//! worker idleness at a nominal [`MODEL_WORKERS`]-worker pool, so the
//! bench gate can assert the scheduling win without trusting host
//! timing.

use std::cell::UnsafeCell;
use std::sync::Arc;

use anyhow::Result;

use crate::camera::{Intrinsics, Pose};
use crate::coordinator::{Coordinator, FrameResult};
use crate::pipeline::stage::{DispatchPlan, FeedMeta, FrontendOutput, RasterFrame};
use crate::scene::GaussianScene;
use crate::util::par;

/// Nominal worker count the occupancy model evaluates at. A fixed
/// constant — deliberately not the host's thread count — so the
/// idle-frame metrics the bench gate compares are identical on every
/// machine and at every `LUMINA_THREADS`.
pub const MODEL_WORKERS: usize = 4;

/// A frame fed this round: the inputs `Coordinator::step_pipelined`
/// would hand the frontend stage, captured by value (`Arc` scene, pose
/// and intrinsics copies) so the stage can run on any worker while the
/// coordination thread holds no borrow of the session.
struct FeedInput {
    frame: usize,
    scene: Arc<GaussianScene>,
    pose: Pose,
    intr: Intrinsics,
}

/// One pipelined session's stage work for the current round: the raster
/// ready-set fixed by `PipelinedSession::plan_dispatch` plus the
/// optional frontend feed, at the session's current pipeline
/// resolution.
struct RoundWork {
    plan: DispatchPlan,
    feed: Option<FeedInput>,
    width: usize,
    height: usize,
}

/// What a session contributes to the current round.
enum Round {
    /// Depth-1 synchronous step: both stages run as one frame-granular
    /// task (`Coordinator::step`), stolen whole.
    Step,
    /// Depth >= 2 stage dispatch: up to two independent tasks (raster
    /// plan, frontend feed).
    Dispatch(RoundWork),
}

/// One stage task in the round's static priority order.
enum Task {
    /// Whole synchronous step of session `s`.
    Step { s: usize },
    /// Session `s`'s raster-chunk plan.
    Raster { s: usize },
    /// Session `s`'s next-frame frontend.
    Frontend { s: usize },
}

/// A task's output, written into its claimed slot.
enum TaskOut {
    Step(Result<FrameResult>),
    Raster(Option<RasterFrame>),
    Frontend(FrontendOutput),
}

/// Per-task output slots shared with the claiming workers — the write
/// half of the claim/write publication pattern (see
/// [`par::TaskClaimer`]).
struct TaskSlots(Vec<UnsafeCell<Option<TaskOut>>>);

// SAFETY: slot `i` is written exactly once, by the single worker whose
// `TaskClaimer::next` returned `i` (the fetch_add hands each ID to
// exactly one claimant), and the coordination thread reads the slots
// only after the enclosing `thread::scope` has joined every worker —
// the same disjoint-claim + join-publication discipline as
// `par::SendPtr`'s users.
unsafe impl Sync for TaskSlots {}

/// One session's replay of `step_session`'s sequencing, plus its
/// in-order result buffer. The per-slot buffers' merge order is fixed
/// by session index and frame order — never by task completion order.
/// `T` is the caller's per-frame projection of [`FrameResult`] (the
/// report alone for production epochs, the full result for parity
/// tests), applied at delivery so images drop as early as the
/// per-session scheduler drops them.
struct SessionRun<'c, T> {
    coord: &'c mut Coordinator,
    frames: Vec<T>,
    limit: usize,
    /// Epoch completion target, fixed once at entry exactly as
    /// `step_session` fixes it (pipelined sessions only).
    target: usize,
    /// Depth-1 synchronous stepping (no stage-level decomposition).
    sync: bool,
    done: bool,
    error: Option<anyhow::Error>,
}

impl<'c, T> SessionRun<'c, T> {
    fn new(coord: &'c mut Coordinator, cap: Option<usize>) -> Self {
        let limit = cap.unwrap_or(usize::MAX);
        let sync = coord.pipeline_depth() <= 1;
        let target = if sync { 0 } else { limit.min(coord.remaining() + coord.in_flight()) };
        SessionRun { coord, frames: Vec::new(), limit, target, sync, done: false, error: None }
    }

    /// Advance this session's state machine to its next stage round:
    /// deliver zero-work frames (tier-swap leftovers in `drained`)
    /// inline, consume the next pose when this round feeds, and return
    /// the round's stage work — or `None` when the session finished its
    /// epoch. Mirrors `step_session` exactly; see the module docs for
    /// why the equivalence holds.
    fn prepare(&mut self, map: &impl Fn(FrameResult) -> T) -> Option<Round> {
        if self.done {
            return None;
        }
        if self.sync {
            loop {
                if self.coord.remaining() == 0 || self.frames.len() >= self.limit {
                    self.done = true;
                    return None;
                }
                // `Coordinator::step` delivers drained leftovers before
                // consuming a pose; popping them here is the same
                // delivery, minus a task round-trip for zero stage work.
                if let Some(f) = self.coord.drained.pop_front() {
                    self.frames.push(map(f));
                    continue;
                }
                return Some(Round::Step);
            }
        }
        loop {
            if self.frames.len() >= self.target {
                self.done = true;
                return None;
            }
            // Both `step_pipelined` and `drain_one` deliver drained
            // leftovers before any stage work; a pop leaves the feed
            // condition below unchanged (`frames + in_flight` is
            // invariant under it), so inlining the delivery preserves
            // `step_session`'s decision sequence.
            if let Some(f) = self.coord.drained.pop_front() {
                self.frames.push(map(f));
                continue;
            }
            let feed = self.frames.len() + self.coord.in_flight() < self.target
                && self.coord.remaining() > 0;
            if !feed && self.coord.in_flight() == 0 {
                // `step_session`'s defensive break: nothing in flight
                // and nothing left to feed.
                self.done = true;
                return None;
            }
            let fed = if feed {
                let idx = self.coord.frame_idx;
                #[cfg(test)]
                {
                    if self.coord.fail_at_frame == Some(idx) {
                        self.error =
                            Some(anyhow::anyhow!("injected session failure at frame {idx}"));
                        self.done = true;
                        return None;
                    }
                    if self.coord.panic_at_frame == Some(idx) {
                        panic!("injected session panic at frame {idx}");
                    }
                }
                let pose = self.coord.trajectory.poses[idx];
                self.coord.frame_idx += 1;
                let scene = match &self.coord.lod_scene {
                    Some(s) => s.clone(),
                    None => self.coord.scene.clone(),
                };
                Some(FeedInput { frame: idx, scene, pose, intr: self.coord.render_intr })
            } else {
                None
            };
            let plan = self.coord.pipeline.plan_dispatch(fed.is_some());
            return Some(Round::Dispatch(RoundWork {
                plan,
                feed: fed,
                width: self.coord.render_intr.width,
                height: self.coord.render_intr.height,
            }));
        }
    }

    /// Commit this session's round on the coordination thread: advance
    /// chunk cursors, pop/complete the finished frame, enqueue the fed
    /// frontend output — in exactly the order `PipelinedSession::
    /// advance` would have applied under the per-session scheduler.
    fn commit(
        &mut self,
        round: Round,
        rf: Option<RasterFrame>,
        fo: Option<FrontendOutput>,
        map: &impl Fn(FrameResult) -> T,
    ) {
        match round {
            Round::Step => unreachable!("sync rounds commit through their step result"),
            Round::Dispatch(work) => {
                let fed = work.feed.map(|fi| {
                    (
                        FeedMeta { frame: fi.frame, scene_gaussians: fi.scene.len() },
                        fo.expect("feeding round ran a frontend task"),
                    )
                });
                if let Some(d) = self.coord.pipeline.apply_dispatch(&work.plan, rf, fed) {
                    let f = self.coord.complete_frame(d);
                    self.frames.push(map(f));
                }
            }
        }
    }
}

/// Run one epoch of `coords` (up to `cap` completed frames per session,
/// whole trajectories when `None`) under the pool-wide stealing
/// scheduler. Returns each session's completed frames in session order
/// — bitwise identical to the per-session scheduler's output at any
/// thread count.
pub(crate) fn run_sessions<T>(
    coords: Vec<&mut Coordinator>,
    cap: Option<usize>,
    map: impl Fn(FrameResult) -> T,
) -> Vec<Result<Vec<T>>> {
    let mut runs: Vec<SessionRun<T>> =
        coords.into_iter().map(|c| SessionRun::new(c, cap)).collect();
    loop {
        // Prep (serial, session-index order): fix the round's task
        // graph before any worker starts.
        let mut rounds: Vec<Option<Round>> =
            runs.iter_mut().map(|r| r.prepare(&map)).collect();
        // Static priority order over task IDs: session index ascending,
        // raster before frontend within a session (the heavier stage
        // first packs the claim sequence better; the order is fixed
        // per round either way).
        let mut tasks: Vec<Task> = Vec::new();
        let mut ids: Vec<(Option<usize>, Option<usize>)> = vec![(None, None); runs.len()];
        for (s, round) in rounds.iter().enumerate() {
            match round {
                None => {}
                Some(Round::Step) => {
                    ids[s].0 = Some(tasks.len());
                    tasks.push(Task::Step { s });
                }
                Some(Round::Dispatch(work)) => {
                    if !work.plan.is_empty() {
                        ids[s].0 = Some(tasks.len());
                        tasks.push(Task::Raster { s });
                    }
                    if work.feed.is_some() {
                        ids[s].1 = Some(tasks.len());
                        tasks.push(Task::Frontend { s });
                    }
                }
            }
        }
        if tasks.is_empty() {
            // `prepare` returns work for every unfinished session (and
            // a feeding round always has at least a frontend task), so
            // an empty round means every session is done.
            break;
        }
        let slots = run_round(&tasks, &mut runs, &rounds);
        // Commit (serial, session-index order): merge task results in
        // ID order, never completion order.
        let mut outs: Vec<Option<TaskOut>> =
            slots.0.into_iter().map(UnsafeCell::into_inner).collect();
        for (s, run) in runs.iter_mut().enumerate() {
            let Some(round) = rounds[s].take() else { continue };
            if matches!(round, Round::Step) {
                let Some(TaskOut::Step(res)) = outs[ids[s].0.unwrap()].take() else {
                    unreachable!("step task wrote a step result")
                };
                match res {
                    Ok(f) => run.frames.push(map(f)),
                    Err(e) => {
                        run.error = Some(e);
                        run.done = true;
                    }
                }
                continue;
            }
            let rf = ids[s].0.and_then(|i| match outs[i].take() {
                Some(TaskOut::Raster(rf)) => rf,
                _ => unreachable!("raster task wrote a raster result"),
            });
            let fo = ids[s].1.map(|i| match outs[i].take() {
                Some(TaskOut::Frontend(fo)) => fo,
                _ => unreachable!("frontend task wrote a frontend result"),
            });
            run.commit(round, rf, fo, &map);
        }
    }
    runs.into_iter()
        .map(|r| match r.error {
            Some(e) => Err(e),
            None => Ok(r.frames),
        })
        .collect()
}

/// Execute one round's tasks on the claiming worker pool and return the
/// filled slots. Claim order is the tasks' static priority order; slot
/// writes publish to the caller via the scope join.
fn run_round<T>(
    tasks: &[Task],
    runs: &mut [SessionRun<T>],
    rounds: &[Option<Round>],
) -> TaskSlots {
    let slots =
        TaskSlots((0..tasks.len()).map(|_| UnsafeCell::new(None)).collect());
    // Raw session pointers for the workers. No `&mut Coordinator` is
    // live while workers run: tasks project disjoint fields through
    // `addr_of_mut!` (see `run_task`), and the coordination thread does
    // not touch the sessions again until the scope has joined.
    let ptrs: Vec<par::SendPtr<Coordinator>> = runs
        .iter_mut()
        .map(|r| par::SendPtr::new(std::ptr::from_mut(&mut *r.coord)))
        .collect();
    // detlint: allow(thread-count) -- scheduling site: sizes the claiming worker pool and its budget shares; task outputs are thread-budget invariant, so rendered values never depend on it
    let total = par::num_threads();
    let workers = total.min(tasks.len()).max(1);
    if workers <= 1 {
        // One worker claims everything: run the priority order inline.
        for (i, t) in tasks.iter().enumerate() {
            let out = run_task(t, &ptrs, rounds);
            // SAFETY: single-threaded — no concurrent access to any slot.
            unsafe { *slots.0[i].get() = Some(out) };
        }
        return slots;
    }
    let shares = par::split_budget(total, workers);
    let claimer = par::TaskClaimer::new(tasks.len());
    std::thread::scope(|scope| {
        for &share in shares.iter().take(workers) {
            let claimer = &claimer;
            let slots = &slots;
            let ptrs = &ptrs;
            scope.spawn(move || {
                let _budget = par::local_budget_guard(share);
                while let Some(i) = claimer.next() {
                    let out = run_task(&tasks[i], ptrs, rounds);
                    // SAFETY: task `i` was claimed by exactly this
                    // worker (TaskClaimer hands each ID out once), so no
                    // other thread writes slot `i`; the coordination
                    // thread reads it only after the scope joins.
                    unsafe { *slots.0[i].get() = Some(out) };
                }
            });
        }
    });
    slots
}

/// Run one claimed task. Tasks touch their session through raw
/// field projections so that the two stage tasks a pipelined session
/// contributes in one round never materialize aliasing `&mut
/// Coordinator` borrows.
fn run_task(task: &Task, ptrs: &[par::SendPtr<Coordinator>], rounds: &[Option<Round>]) -> TaskOut {
    let dispatch = |s: usize| match &rounds[s] {
        Some(Round::Dispatch(work)) => work,
        _ => unreachable!("stage task implies a dispatch round"),
    };
    match *task {
        Task::Step { s } => {
            // SAFETY: a depth-1 session contributes exactly one task per
            // round, so this worker holds the only live access to
            // session `s` for the scope's duration; the coordination
            // thread re-borrows it only after every worker joins.
            let coord = unsafe { &mut *ptrs[s].get() };
            TaskOut::Step(coord.step())
        }
        Task::Raster { s } => {
            let work = dispatch(s);
            // SAFETY: disjoint-field projection. This task mutates only
            // `raster` and reads `pipeline`; the only other task that
            // can touch session `s` this round is its Frontend task,
            // which mutates only `frontend`. `addr_of_mut!` projects
            // the fields without materializing a `&mut Coordinator`,
            // so the workers' borrows are per-field and never alias;
            // the pointee outlives the scope (the coordination thread's
            // `SessionRun` borrow spans it).
            let raster = unsafe { &mut *std::ptr::addr_of_mut!((*ptrs[s].get()).raster) };
            // SAFETY: shared read of `pipeline` — no task writes it;
            // cursors move only in the post-join commit.
            let pipe = unsafe { &*std::ptr::addr_of!((*ptrs[s].get()).pipeline) };
            TaskOut::Raster(pipe.run_plan(raster.as_mut(), &work.plan, work.width, work.height))
        }
        Task::Frontend { s } => {
            let work = dispatch(s);
            let fi = work.feed.as_ref().expect("frontend task implies a feed");
            // SAFETY: disjoint-field projection, mirroring Raster above:
            // this task mutates only `frontend`, which no other task in
            // the round touches.
            let fe = unsafe { &mut *std::ptr::addr_of_mut!((*ptrs[s].get()).frontend) };
            TaskOut::Frontend(fe.run(&fi.scene, &fi.pose, &fi.intr))
        }
    }
}

/// Idle worker-frames the **per-session** scheduler spends on one epoch
/// with the given per-session completed-frame counts: live sessions are
/// chunked contiguously onto `workers` outer workers (mirroring
/// `run_parallel`'s split), the epoch's wall is the most-loaded
/// worker's frame total, and every worker-frame not rendering is idle.
/// Finished sessions (0 frames) occupy no worker, as in the real
/// scheduler's work/idle split. Frame counts weight every frame
/// equally, so the model is machine-independent.
pub fn idle_worker_frames_session(frames_per_session: &[usize], workers: usize) -> u64 {
    let live: Vec<usize> =
        frames_per_session.iter().copied().filter(|&f| f > 0).collect();
    let total: usize = live.iter().sum();
    if total == 0 {
        return 0;
    }
    let workers = workers.max(1);
    let chunk = live.len().div_ceil(workers.min(live.len()));
    let wall = live.chunks(chunk).map(|c| c.iter().sum::<usize>()).max().unwrap_or(0);
    (workers * wall - total) as u64
}

/// Idle worker-frames the **stealing** scheduler spends on the same
/// epoch: any idle worker picks up any session's next frame, so the
/// wall is the work-conservation bound `ceil(total / workers)` — unless
/// one session's frame chain (frames within a session are strictly
/// sequential) is itself the critical path. Always <= the per-session
/// model; strictly less whenever contiguous chunking leaves a worker
/// loaded beyond both bounds.
pub fn idle_worker_frames_stealing(frames_per_session: &[usize], workers: usize) -> u64 {
    let live: Vec<usize> =
        frames_per_session.iter().copied().filter(|&f| f > 0).collect();
    let total: usize = live.iter().sum();
    if total == 0 {
        return 0;
    }
    let workers = workers.max(1);
    let critical = live.iter().copied().max().unwrap_or(0);
    let wall = critical.max(total.div_ceil(workers));
    (workers * wall - total) as u64
}

/// Critical path of one epoch's task graph, in frames: the longest
/// single-session frame chain — the floor no scheduler can beat, and
/// what the stealing scheduler's wall converges to once workers stop
/// idling.
pub fn epoch_critical_path_frames(frames_per_session: &[usize]) -> u64 {
    frames_per_session.iter().copied().max().unwrap_or(0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareVariant, LuminaConfig};

    fn tiny_cfg(depth: usize) -> LuminaConfig {
        let mut c = LuminaConfig::quick_test();
        c.scene.count = if cfg!(miri) { 200 } else { 2000 };
        c.camera.width = 32;
        c.camera.height = 32;
        c.camera.frames = if cfg!(miri) { 3 } else { 5 };
        c.variant = HardwareVariant::Gpu;
        c.pool.pipeline_depth = depth;
        c
    }

    fn build(depth: usize, n: usize) -> Vec<Coordinator> {
        (0..n)
            .map(|i| {
                let mut cfg = tiny_cfg(depth);
                cfg.camera.seed = cfg.camera.seed.wrapping_add(i as u64);
                Coordinator::new(cfg).unwrap()
            })
            .collect()
    }

    /// Reference sequencing: `step_session`'s loop, inlined.
    fn step_reference(coord: &mut Coordinator, cap: Option<usize>) -> Vec<FrameResult> {
        let limit = cap.unwrap_or(usize::MAX);
        let mut frames = Vec::new();
        if coord.pipeline_depth() <= 1 {
            while coord.remaining() > 0 && frames.len() < limit {
                frames.push(coord.step().unwrap());
            }
            return frames;
        }
        let target = limit.min(coord.remaining() + coord.in_flight());
        while frames.len() < target {
            let feed = frames.len() + coord.in_flight() < target && coord.remaining() > 0;
            let done =
                if feed { coord.step_pipelined().unwrap() } else { coord.drain_one().unwrap() };
            if let Some(f) = done {
                frames.push(f);
            } else if !feed && coord.in_flight() == 0 {
                break;
            }
        }
        frames
    }

    #[test]
    fn stealing_matches_session_sequencing_bitwise() {
        for depth in [1, 2, 3] {
            let mut expect = build(depth, 2);
            let mut got = build(depth, 2);
            // Two epochs — a capped one (exercising the feed/drain
            // boundary mid-trajectory) and the remainder.
            for cap in [Some(2), None] {
                let want: Vec<Vec<FrameResult>> =
                    expect.iter_mut().map(|c| step_reference(c, cap)).collect();
                let out = run_sessions(got.iter_mut().collect(), cap, |f| f);
                for (s, (w, g)) in want.iter().zip(&out).enumerate() {
                    let g = g.as_ref().unwrap();
                    assert_eq!(w.len(), g.len(), "depth {depth} session {s} frame count");
                    for (a, b) in w.iter().zip(g) {
                        assert_eq!(a.report, b.report, "depth {depth} session {s}");
                        assert_eq!(
                            a.image.data, b.image.data,
                            "depth {depth} session {s} image bits"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn stealing_is_thread_budget_invariant() {
        let run_at = |budget: usize| {
            let _g = par::local_budget_guard(budget);
            let mut coords = build(2, 2);
            run_sessions(coords.iter_mut().collect(), None, |f| f)
                .into_iter()
                .map(|r| r.unwrap())
                .collect::<Vec<_>>()
        };
        let one = run_at(1);
        let four = run_at(4);
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.report, y.report);
                assert_eq!(x.image.data, y.image.data);
            }
        }
    }

    #[test]
    fn occupancy_model_pins_and_invariant() {
        // Balanced pool: neither scheduler idles.
        assert_eq!(idle_worker_frames_session(&[2, 2, 2, 2], 4), 0);
        assert_eq!(idle_worker_frames_stealing(&[2, 2, 2, 2], 4), 0);
        // Heterogeneous counts with imbalanced contiguous chunks: the
        // per-session split strands 12 worker-frames, stealing none.
        assert_eq!(idle_worker_frames_session(&[4, 4, 4, 4, 1, 1, 1, 1], 4), 12);
        assert_eq!(idle_worker_frames_stealing(&[4, 4, 4, 4, 1, 1, 1, 1], 4), 0);
        // One dominant chain: the critical path binds both equally.
        assert_eq!(idle_worker_frames_session(&[6, 1, 1, 1], 4), 15);
        assert_eq!(idle_worker_frames_stealing(&[6, 1, 1, 1], 4), 15);
        // Finished sessions occupy no worker.
        assert_eq!(idle_worker_frames_session(&[0, 0, 3], 4), 9);
        assert_eq!(idle_worker_frames_stealing(&[0, 0, 3], 4), 9);
        // Empty epochs are free.
        assert_eq!(idle_worker_frames_session(&[], 4), 0);
        assert_eq!(idle_worker_frames_stealing(&[0, 0], 4), 0);
        // Critical path.
        assert_eq!(epoch_critical_path_frames(&[3, 5, 2]), 5);
        assert_eq!(epoch_critical_path_frames(&[]), 0);
        // Invariant: stealing never idles more than the session split.
        let cases: [&[usize]; 6] = [
            &[2, 2, 2, 2],
            &[4, 4, 4, 4, 1, 1, 1, 1],
            &[6, 1, 1, 1],
            &[5, 3, 2, 2, 1],
            &[1],
            &[7, 7, 1, 1, 1, 1, 1],
        ];
        for counts in cases {
            for workers in [1, 2, 4, 8] {
                assert!(
                    idle_worker_frames_stealing(counts, workers)
                        <= idle_worker_frames_session(counts, workers),
                    "{counts:?} @ {workers}"
                );
            }
        }
    }
}
