//! Shared support for the per-figure experiment harnesses
//! (`rust/src/bin/figNN_*.rs`): standard workloads, variant execution,
//! and table formatting. See DESIGN.md §7 for the experiment index.

use anyhow::Result;

use crate::camera::trajectory::{generate, Trajectory, TrajectoryKind};
use crate::camera::Intrinsics;
use crate::config::{HardwareVariant, LuminaConfig};
use crate::coordinator::{Coordinator, RunReport};
use crate::scene::synth::SceneClass;

/// Workload scale: the figure harnesses run each paper dataset class at
/// 1/10th of its paper Gaussian count and 256x256 resolution so a full
/// figure regenerates in minutes on a laptop CPU. The cost models are
/// workload-driven, so *ratios* between variants (the paper's claims)
/// are preserved; EXPERIMENTS.md reports the scale factor next to every
/// measured number.
pub const SCENE_SCALE_DIV: usize = 10;

/// Resolution used by the figure harnesses.
pub const HARNESS_RES: usize = 256;

/// Frames per harness run (enough for cache warmup + steady state).
pub const HARNESS_FRAMES: usize = 24;

/// The two evaluation settings of the paper (Sec. 5 Datasets).
pub fn eval_settings() -> Vec<(&'static str, SceneClass, TrajectoryKind)> {
    vec![
        ("synthetic@90fps", SceneClass::SyntheticSmall, TrajectoryKind::VrHeadMotion),
        ("real@30fps", SceneClass::RealMedium, TrajectoryKind::Walkthrough),
    ]
}

/// All four dataset classes (characterization figures).
pub fn all_classes() -> Vec<(&'static str, SceneClass)> {
    SceneClass::all()
        .into_iter()
        .map(|c| (c.paper_label(), c))
        .collect()
}

/// Standard harness config for a class/trajectory/variant.
pub fn harness_config(
    class: SceneClass,
    traj: TrajectoryKind,
    variant: HardwareVariant,
) -> LuminaConfig {
    let mut cfg = LuminaConfig::quick_test();
    cfg.scene.class = class;
    cfg.scene.count = (class.default_count() / SCENE_SCALE_DIV).max(10_000);
    cfg.scene.seed = 42;
    cfg.camera.width = HARNESS_RES;
    cfg.camera.height = HARNESS_RES;
    cfg.camera.trajectory = traj;
    cfg.camera.frames = HARNESS_FRAMES;
    cfg.variant = variant;
    // The paper's margin-4 default is relative to 800x800 frames; at the
    // harness's 256x256 the proportional margin is ~2 px (Fig. 23's
    // trade-off is resolution-relative).
    cfg.s2.expanded_margin = 2;
    cfg
}

/// Run a config to completion.
pub fn run_variant(cfg: LuminaConfig) -> Result<RunReport> {
    Coordinator::new(cfg)?.run()
}

/// Run with per-frame quality measurement (slower: renders the exact
/// pipeline alongside).
pub fn run_variant_with_quality(cfg: LuminaConfig) -> Result<RunReport> {
    let mut coord = Coordinator::new(cfg)?;
    let mut report = RunReport::new(coord.cfg.variant.label());
    while coord.remaining() > 0 {
        report.push(coord.step_with_quality()?.report);
    }
    Ok(report)
}

/// Trajectory for a config (for harnesses that drive the pipeline
/// manually instead of through the coordinator).
pub fn trajectory_for(cfg: &LuminaConfig) -> Trajectory {
    generate(
        cfg.camera.trajectory,
        cfg.camera.seed,
        cfg.camera.frames,
        cfg.scene.class.extent(),
    )
}

/// Intrinsics for a config.
pub fn intrinsics_for(cfg: &LuminaConfig) -> Intrinsics {
    cfg.intrinsics()
}

/// Print a standard table header for figure harnesses.
pub fn banner(fig: &str, what: &str, paper_claim: &str) {
    println!("=== {fig}: {what} ===");
    println!("paper: {paper_claim}");
    println!("workload: classes at 1/{SCENE_SCALE_DIV} paper Gaussian count, {HARNESS_RES}x{HARNESS_RES}, {HARNESS_FRAMES} frames");
    println!();
}
