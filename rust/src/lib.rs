//! # Lumina — real-time mobile neural rendering by exploiting computational redundancy
//!
//! A reproduction of the Lumina paper's full system as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: the 3DGS pipeline substrate
//!   (projection, sorting, rasterization) and its stage graph
//!   ([`pipeline::stage`]), the paper's two algorithms
//!   ([`lumina::s2`] Sorting-Sharing and [`lumina::rc`] Radiance Caching),
//!   the cycle-accurate [`sim`] of the LuminCore accelerator plus GPU /
//!   GSCore cost models behind the [`sim::cost`] trait seams, quality
//!   [`metrics`], the frame-loop [`coordinator`], multi-viewer
//!   serving via [`coordinator::SessionPool`], and the population-scale
//!   loadtest harness ([`workload`]).
//! * **Layer 2** — `python/compile/model.py`: the JAX compute graph,
//!   AOT-lowered to HLO-text artifacts at build time.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels for the
//!   rasterization hot-spot, validated against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API (the
//! `xla` crate) so the per-frame path never touches Python; it is gated
//! behind the off-by-default `xla-runtime` feature so the stock build
//! carries no external native dependencies.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// Determinism-audit lint floor (DESIGN.md §"Determinism audit"). The
// unsafe surface is small and concentrated in `util::par` and the sort
// scatter; these keep it that way:
// - `unsafe_op_in_unsafe_fn`: an `unsafe fn` body gets no blanket
//   license — every operation needs its own `unsafe` block (and so its
//   own `// SAFETY:` comment under detlint R3).
// - `unused_unsafe`: a stale block would carry a stale SAFETY argument.
// - `non_ascii_idents`: keeps detlint's byte-offset lexing exact.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unused_unsafe)]
#![deny(non_ascii_idents)]

pub mod camera;
pub mod config;
pub mod constants;
pub mod coordinator;
pub mod harness;
pub mod lumina;
pub mod math;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod scene;
pub mod sim;
pub mod util;
pub mod workload;
