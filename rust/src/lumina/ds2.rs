//! DS-2 — the downsample-2x baseline of the paper's quality evaluation
//! (Fig. 20): render at half resolution through the full 3DGS pipeline,
//! then bilinearly upsample to the target resolution.

use std::sync::Arc;

use crate::camera::{Intrinsics, Pose};
use crate::lumina::rc::{CacheDelta, CacheSnapshot, WorldDelta, WorldSnapshot};
use crate::pipeline::image::Image;
use crate::pipeline::project::{project, ProjectedScene};
use crate::pipeline::raster::{rasterize, RasterConfig};
use crate::pipeline::sort::{bin_and_sort, TileBins};
use crate::pipeline::stage::{PlainRaster, RasterBackend, RasterFrame};
use crate::scene::GaussianScene;

/// Half-resolution intrinsics for the DS-2 render pass.
pub fn half_intrinsics(intr: &Intrinsics) -> Intrinsics {
    Intrinsics {
        width: intr.width / 2,
        height: intr.height / 2,
        fx: intr.fx / 2.0,
        fy: intr.fy / 2.0,
        cx: intr.cx / 2.0,
        cy: intr.cy / 2.0,
    }
}

/// Render one DS-2 frame: half-res full pipeline + 2x bilinear upsample.
///
/// Returns (image, half_res_raster_work) where work = total Gaussians
/// iterated by the half-res rasterization (for the cost models).
pub fn render_ds2(
    scene: &GaussianScene,
    pose: &Pose,
    intr: &Intrinsics,
    tile_size: usize,
    near: f32,
    far: f32,
) -> (Image, u64) {
    let half = half_intrinsics(intr);
    let projected = project(scene, pose, &half, near, far, 0.0);
    let bins = bin_and_sort(&projected, &half, tile_size, 0.0);
    let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
    let out = rasterize(&projected, &bins, half.width, half.height, &cfg);
    let work: u64 = out
        .stats
        .as_ref()
        .map(|s| s.iterated.iter().map(|&v| v as u64).sum())
        .unwrap_or(0);
    (out.image.upsample2(), work)
}

/// The DS-2 [`RasterBackend`]: rasterization of the half-res projection
/// through an arbitrary inner backend, upsampled 2x at finalize. The
/// coordinator feeds it half-resolution intrinsics (see
/// [`half_intrinsics`]) so the whole variant rides the ordinary stage
/// graph.
///
/// Because it *wraps* rather than replaces the inner backend, the
/// half-res serving tier can demote any variant mid-run — including the
/// radiance-cached ones — by composing `Ds2Raster` around the variant's
/// own backend ([`Ds2Raster::wrap`]).
pub struct Ds2Raster {
    inner: Box<dyn RasterBackend>,
}

impl Ds2Raster {
    /// The classic DS-2 baseline: plain rasterization + 2x upsample.
    pub fn new() -> Self {
        Self::wrap(Box::new(PlainRaster::new()))
    }

    /// Compose the half-res + upsample mechanism around an existing
    /// backend (the half-res tier over cached/plain rasterization).
    pub fn wrap(inner: Box<dyn RasterBackend>) -> Self {
        Ds2Raster { inner }
    }
}

impl Default for Ds2Raster {
    fn default() -> Self {
        Self::new()
    }
}

impl RasterBackend for Ds2Raster {
    fn label(&self) -> &'static str {
        "ds2"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        self.inner.render(projected, bins, width, height)
    }

    fn finalize(&self, image: Image) -> Image {
        self.inner.finalize(image).upsample2()
    }

    // The half-res tier wraps cached backends, so the cache-topology
    // hooks must pass through to the inner backend.
    fn take_cache_delta(&mut self) -> Option<CacheDelta> {
        self.inner.take_cache_delta()
    }

    fn install_cache_snapshot(&mut self, snapshot: Arc<CacheSnapshot>, sharers: usize) {
        self.inner.install_cache_snapshot(snapshot, sharers);
    }

    fn take_world_delta(&mut self) -> Option<WorldDelta> {
        self.inner.take_world_delta()
    }

    fn install_world_snapshot(&mut self, snapshot: Arc<WorldSnapshot>, sharers: usize) {
        self.inner.install_world_snapshot(snapshot, sharers);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::metrics::psnr;
    use crate::scene::synth::test_scene;

    #[test]
    fn output_matches_target_resolution() {
        let scene = test_scene(3, 2000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let (img, work) = render_ds2(&scene, &pose, &intr, 16, 0.2, 100.0);
        assert_eq!((img.width, img.height), (128, 128));
        assert!(work > 0);
    }

    #[test]
    fn ds2_quality_below_full_render() {
        // DS-2 must be measurably worse than the full-res render —
        // the paper reports a ~1.4 dB PSNR gap on synthetic scenes.
        let scene = test_scene(3, 6000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let full_p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let full_b = bin_and_sort(&full_p, &intr, 16, 0.0);
        let full =
            rasterize(&full_p, &full_b, intr.width, intr.height, &RasterConfig::default());
        let (ds2, _) = render_ds2(&scene, &pose, &intr, 16, 0.2, 100.0);
        let q = psnr(&full.image, &ds2);
        assert!(q < 45.0, "DS-2 should visibly differ from full render, got {q} dB");
        assert!(q > 15.0, "DS-2 should still resemble the scene, got {q} dB");
    }

    #[test]
    fn ds2_saves_raster_work() {
        let scene = test_scene(3, 6000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let full_p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let full_b = bin_and_sort(&full_p, &intr, 16, 0.0);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let full = rasterize(&full_p, &full_b, intr.width, intr.height, &cfg);
        let full_work: u64 = full.stats.unwrap().iterated.iter().map(|&v| v as u64).sum();
        let (_, half_work) = render_ds2(&scene, &pose, &intr, 16, 0.2, 100.0);
        // Savings are sublinear in pixel count: each half-res pixel
        // iterates a longer tile list (tiles cover 2x the world area), so
        // DS-2 saves well under 4x — consistent with the paper treating
        // DS-2 as a *quality* baseline rather than a 4x-speed one.
        assert!(half_work < full_work, "half-res work {half_work} vs full {full_work}");
    }
}
