//! The paper's two algorithmic contributions plus the DS-2 baseline.
//!
//! * [`s2`]  — Sorting-Sharing: speculative sorting at a predicted pose,
//!   shared across a window of frames (Sec. 3.1).
//! * [`rc`]  — Radiance Caching: tag pixels by their first-k significant
//!   Gaussian IDs and skip redundant color integration (Sec. 3.2), with
//!   the LuminCache-faithful cache organization (Sec. 4/5).
//! * [`ds2`] — the downsample-2x quality baseline (Fig. 20).

pub mod ds2;
pub mod rc;
pub mod s2;
