//! RC — Radiance Caching (paper Sec. 3.2) and the LuminCache-faithful
//! cache organization (paper Sec. 4/5).
//!
//! Key insight: two rays that intersect the same sequence of initial
//! *significant* Gaussians (alpha > 1/255) almost surely produce the same
//! pixel value. Per pixel, rasterization runs only until the first k
//! significant Gaussians are identified; their IDs form a cache tag. On a
//! hit, the cached RGB replaces the remaining color integration; on a
//! miss, integration completes and the cache is updated.
//!
//! The cache geometry mirrors LuminCache (Sec. 5): 4-way set-associative,
//! 1024 sets, tag/index built from bits [3..19) of each of the k Gaussian
//! IDs (paper: "3rd to 18th least significant bits", 16 bits per ID, 10
//! bytes of tag material for k=5), tree pseudo-LRU replacement, and
//! contents partitioned per 4x4-tile group (64x64 px) with save/flush/
//! reload semantics between groups (modeled functionally as per-group
//! sub-caches; the traffic is charged by the simulator).

use crate::constants::{
    CACHE_ID_BITS, CACHE_ID_LO_BIT, CACHE_SETS, CACHE_TILE_GROUP, CACHE_WAYS, T_EPS,
};
use crate::pipeline::image::Image;
use crate::pipeline::project::ProjectedScene;
use crate::pipeline::raster::{gather_tile, splat_alpha, GatheredSplat, RasterStats, MAX_SIG_K};
use crate::pipeline::sort::TileBins;
use crate::pipeline::stage::{RasterBackend, RasterFrame, RasterWork};

/// One cache entry: packed high-bit tag + cached pixel RGB.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    tag: u128,
    value: [f32; 3],
}

/// Running cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Pixels whose ray met fewer than k significant Gaussians
    /// (uncacheable; rendered fully).
    pub short_rays: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.inserts += o.inserts;
        self.evictions += o.evictions;
        self.short_rays += o.short_rays;
    }
}

/// A single LuminCache bank: N-way set-associative with tree pseudo-LRU.
#[derive(Debug, Clone)]
pub struct RadianceCache {
    ways: usize,
    sets: usize,
    k: usize,
    entries: Vec<Option<Entry>>,
    /// Per-set pseudo-LRU tree bits (3 bits for 4 ways, packed in u8).
    plru: Vec<u8>,
    pub stats: CacheStats,
}

impl RadianceCache {
    /// Paper-default geometry: 4 ways x 1024 sets, tag from k IDs.
    pub fn paper_default(k: usize) -> Self {
        Self::new(CACHE_WAYS, CACHE_SETS, k)
    }

    pub fn new(ways: usize, sets: usize, k: usize) -> Self {
        assert!(ways == 2 || ways == 4 || ways == 8, "plru tree supports 2/4/8 ways");
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!((1..=MAX_SIG_K).contains(&k));
        RadianceCache {
            ways,
            sets,
            k,
            entries: vec![None; ways * sets],
            plru: vec![0; sets],
            stats: CacheStats::default(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Build (set index, tag) from the first k significant Gaussian IDs.
    ///
    /// Per the paper (Fig. 16): the *lower* bits of each ID concatenate
    /// into the set index; the *higher* bits concatenate into the tag.
    /// IDs contribute bits [CACHE_ID_LO_BIT .. CACHE_ID_LO_BIT+16).
    fn index_tag(&self, ids: &[u32]) -> (usize, u128) {
        debug_assert_eq!(ids.len(), self.k);
        let index_bits = self.sets.trailing_zeros();
        let per_id = (index_bits as usize).div_ceil(self.k).max(1) as u32;
        let mut index: u64 = 0;
        let mut tag: u128 = 0;
        for &id in ids {
            let field = ((id >> CACHE_ID_LO_BIT) & ((1u32 << CACHE_ID_BITS) - 1)) as u64;
            let low = field & ((1u64 << per_id) - 1);
            let high = field >> per_id;
            index = (index << per_id) | low;
            tag = (tag << (CACHE_ID_BITS - per_id)) | high as u128;
        }
        ((index as usize) & (self.sets - 1), tag)
    }

    /// Look up a tag; on hit returns the cached RGB and touches pLRU.
    pub fn lookup(&mut self, ids: &[u32]) -> Option<[f32; 3]> {
        self.stats.lookups += 1;
        let (set, tag) = self.index_tag(ids);
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if let Some(e) = self.entries[slot] {
                if e.tag == tag {
                    self.stats.hits += 1;
                    self.touch(set, w);
                    return Some(e.value);
                }
            }
        }
        None
    }

    /// Insert (or update) a tag with a pixel value, evicting pseudo-LRU.
    pub fn insert(&mut self, ids: &[u32], value: [f32; 3]) {
        let (set, tag) = self.index_tag(ids);
        // Update in place on tag match.
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if let Some(e) = &mut self.entries[slot] {
                if e.tag == tag {
                    e.value = value;
                    self.touch(set, w);
                    return;
                }
            }
        }
        // Free way?
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if self.entries[slot].is_none() {
                self.entries[slot] = Some(Entry { tag, value });
                self.stats.inserts += 1;
                self.touch(set, w);
                return;
            }
        }
        // Evict the pseudo-LRU victim.
        let w = self.victim(set);
        self.entries[set * self.ways + w] = Some(Entry { tag, value });
        self.stats.inserts += 1;
        self.stats.evictions += 1;
        self.touch(set, w);
    }

    /// Tree-pLRU touch: flip node bits toward the accessed way.
    fn touch(&mut self, set: usize, way: usize) {
        // For 4 ways: bit0 = root (0: left pair younger), bit1 = left
        // pair, bit2 = right pair. Generalized for 2/8 analogously.
        match self.ways {
            2 => {
                self.plru[set] = way as u8 ^ 1;
            }
            4 => {
                let mut b = self.plru[set];
                if way < 2 {
                    b |= 1; // root points right next
                    if way == 0 {
                        b |= 2;
                    } else {
                        b &= !2;
                    }
                } else {
                    b &= !1; // root points left next
                    if way == 2 {
                        b |= 4;
                    } else {
                        b &= !4;
                    }
                }
                self.plru[set] = b;
            }
            8 => {
                // 7-bit tree; index math kept simple.
                let mut b = self.plru[set];
                let top = way / 4;
                let mid = (way / 2) % 2;
                let leaf = way % 2;
                set_bit(&mut b, 0, top == 0);
                set_bit(&mut b, 1 + top as u8, mid == 0);
                set_bit(&mut b, 3 + (way / 2) as u8, leaf == 0);
                self.plru[set] = b;
            }
            _ => unreachable!(),
        }
    }

    /// Tree-pLRU victim selection.
    fn victim(&self, set: usize) -> usize {
        let b = self.plru[set];
        match self.ways {
            2 => (b & 1) as usize,
            4 => {
                if b & 1 == 0 {
                    // go left pair
                    if b & 2 == 0 {
                        0
                    } else {
                        1
                    }
                } else if b & 4 == 0 {
                    2
                } else {
                    3
                }
            }
            8 => {
                let top = usize::from(b & 1 == 0);
                let mid = usize::from(b & (1 << (1 + top)) == 0);
                let half = top * 4 + mid * 2;
                let leaf = usize::from(b & (1 << (3 + half / 2)) == 0);
                half + leaf
            }
            _ => unreachable!(),
        }
    }

    /// Flush all contents (the per-tile-group flush of Sec. 4).
    pub fn flush(&mut self) {
        self.entries.iter_mut().for_each(|e| *e = None);
        self.plru.iter_mut().for_each(|b| *b = 0);
    }

    /// Number of live entries.
    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }
}

fn set_bit(b: &mut u8, bit: u8, value: bool) {
    if value {
        *b |= 1 << bit;
    } else {
        *b &= !(1 << bit);
    }
}

/// The full LuminCache: one [`RadianceCache`] bank per 4x4-tile group,
/// persisted across frames (the hardware saves/reloads group contents to
/// DRAM between tile batches; double-buffering hides the latency, the
/// simulator charges the traffic).
pub struct GroupedRadianceCache {
    pub groups_x: usize,
    pub groups_y: usize,
    banks: Vec<RadianceCache>,
    k: usize,
}

impl GroupedRadianceCache {
    pub fn new(tiles_x: usize, tiles_y: usize, k: usize) -> Self {
        let groups_x = tiles_x.div_ceil(CACHE_TILE_GROUP);
        let groups_y = tiles_y.div_ceil(CACHE_TILE_GROUP);
        GroupedRadianceCache {
            groups_x,
            groups_y,
            banks: (0..groups_x * groups_y)
                .map(|_| RadianceCache::paper_default(k))
                .collect(),
            k,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Bank serving a tile coordinate.
    pub fn bank_for_tile(&mut self, tx: usize, ty: usize) -> &mut RadianceCache {
        let gx = tx / CACHE_TILE_GROUP;
        let gy = ty / CACHE_TILE_GROUP;
        &mut self.banks[gy * self.groups_x + gx]
    }

    /// Aggregate statistics over all banks.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for b in &self.banks {
            s.merge(&b.stats);
        }
        s
    }

    /// Bytes moved per frame for group save+reload (entries * entry size *
    /// 2 directions) — the DRAM traffic the simulator charges.
    pub fn swap_traffic_bytes(&self) -> usize {
        // Entry: 10 B tag material + 3 B RGB (paper Sec. 5).
        let entry_bytes = 13;
        self.banks.iter().map(|b| b.occupancy() * entry_bytes * 2).sum()
    }

    pub fn num_banks(&self) -> usize {
        self.banks.len()
    }
}

/// Per-pixel outcome of cached rasterization.
#[derive(Debug, Clone, Copy, Default)]
pub struct PixelOutcome {
    /// Gaussians iterated by this pixel (stops early on cache hit).
    pub iterated: u32,
    /// Significant Gaussians encountered while iterating.
    pub significant: u32,
    /// True when the pixel's value came from the cache.
    pub hit: bool,
    /// Gaussians the *uncached* pipeline would have iterated. Equal to
    /// `iterated` except on hit pixels rendered with
    /// `record_uncached = true`, where the scan continues (without
    /// compositing) to recover the exact plain-rasterizer count.
    pub uncached_iterated: u32,
    /// Significant Gaussians the uncached pipeline would have seen.
    pub uncached_significant: u32,
}

/// Output of radiance-cached rasterization.
pub struct CachedRasterOutput {
    pub image: Image,
    pub outcomes: Vec<PixelOutcome>,
    pub stats: CacheStats,
    /// Per-pixel uncached counts (present when `record_uncached` was
    /// requested): exactly what a plain [`rasterize`] stats pass over
    /// the same projected set would produce, recovered in this single
    /// pass.
    ///
    /// [`rasterize`]: crate::pipeline::raster::rasterize
    pub uncached: Option<RasterStats>,
}

/// Rasterize with radiance caching (paper Fig. 10).
///
/// Per pixel: composite until the first k significant Gaussians are seen
/// (the alpha-record), query the cache with their IDs; on hit, emit the
/// cached value and stop; on miss, finish compositing and insert.
/// Serial over tiles because the cache is shared mutable state — exactly
/// the lock-contention hazard the paper ascribes to RC-on-GPU; the
/// accelerator sims recover parallelism by charging per-bank timing.
pub fn rasterize_cached(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cache: &mut GroupedRadianceCache,
) -> CachedRasterOutput {
    rasterize_cached_ex(projected, bins, width, height, cache, false)
}

/// [`rasterize_cached`] with optional single-pass recording of the
/// *uncached* per-pixel counts (see [`CachedRasterOutput::uncached`]):
/// hit pixels continue scanning their tile list without compositing, so
/// the RC-GPU cost model gets the exact uncached warp structure without
/// a second full rasterization.
pub fn rasterize_cached_ex(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cache: &mut GroupedRadianceCache,
    record_uncached: bool,
) -> CachedRasterOutput {
    let ts = bins.tile_size;
    let k = cache.k();
    let mut image = Image::new(width, height);
    let mut outcomes = vec![PixelOutcome::default(); width * height];
    let stats_before = cache.stats();

    for ty in 0..bins.tiles_y {
        for tx in 0..bins.tiles_x {
            let tile = ty * bins.tiles_x + tx;
            let splats = gather_tile(projected, &bins.lists[tile]);
            let bank = cache.bank_for_tile(tx, ty);
            for ly in 0..ts {
                let y = ty * ts + ly;
                if y >= height {
                    break;
                }
                for lx in 0..ts {
                    let x = tx * ts + lx;
                    if x >= width {
                        break;
                    }
                    let (value, outcome) = composite_pixel_cached_ex(
                        &splats,
                        x as f32 + 0.5,
                        y as f32 + 0.5,
                        k,
                        bank,
                        record_uncached,
                    );
                    image.set(x, y, value);
                    outcomes[y * width + x] = outcome;
                }
            }
        }
    }

    let mut stats = cache.stats();
    // Report only this call's deltas.
    stats.lookups -= stats_before.lookups;
    stats.hits -= stats_before.hits;
    stats.inserts -= stats_before.inserts;
    stats.evictions -= stats_before.evictions;
    stats.short_rays -= stats_before.short_rays;
    let uncached = record_uncached.then(|| RasterStats {
        iterated: outcomes.iter().map(|o| o.uncached_iterated).collect(),
        significant: outcomes.iter().map(|o| o.uncached_significant).collect(),
    });
    CachedRasterOutput { image, outcomes, stats, uncached }
}

/// One pixel with cache interaction. Mirrors `raster::composite_pixel`
/// semantics exactly for the compositing math (including the gathered
/// significance-radius fast reject).
pub fn composite_pixel_cached(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut RadianceCache,
) -> ([f32; 3], PixelOutcome) {
    composite_pixel_cached_ex(splats, px, py, k, bank, false)
}

/// [`composite_pixel_cached`] with optional uncached-count recording: on
/// a hit, the scan continues past the cache cutoff — counting, not
/// compositing — so the outcome also carries the exact counts the plain
/// compositor would have produced for this pixel.
pub fn composite_pixel_cached_ex(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut RadianceCache,
    record_uncached: bool,
) -> ([f32; 3], PixelOutcome) {
    let mut c = [0.0f32; 3];
    let mut t = 1.0f32;
    let mut iterated = 0u32;
    let mut significant = 0u32;
    let mut sig_ids = [0u32; MAX_SIG_K];
    let mut sig_n = 0usize;
    let mut queried = false;

    for (si, s) in splats.iter().enumerate() {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        if sig_n < k {
            sig_ids[sig_n] = s.id;
            sig_n += 1;
        }
        significant += 1;
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            // Terminated before the cache query resolved: value is final
            // and identical to the uncached pipeline's.
            return (
                c,
                PixelOutcome {
                    iterated,
                    significant,
                    hit: false,
                    uncached_iterated: iterated,
                    uncached_significant: significant,
                },
            );
        }
        let w = alpha * t;
        c[0] += w * s.color[0];
        c[1] += w * s.color[1];
        c[2] += w * s.color[2];
        t = test_t;

        // Once the alpha-record fills, query the cache (paper step 4).
        if sig_n == k && !queried {
            queried = true;
            if let Some(value) = bank.lookup(&sig_ids[..k]) {
                // Hit: the cached RGB replaces the remaining integration.
                // When recording, keep scanning (count-only, same math
                // and transmittance) to recover the uncached counts the
                // plain compositor would have produced.
                let (ui, us) = if record_uncached {
                    scan_uncached(&splats[si + 1..], px, py, t, iterated, significant)
                } else {
                    (iterated, significant)
                };
                return (
                    value,
                    PixelOutcome {
                        iterated,
                        significant,
                        hit: true,
                        uncached_iterated: ui,
                        uncached_significant: us,
                    },
                );
            }
        }
    }

    // Miss (or short ray): full value computed; update the cache.
    if queried {
        bank.insert(&sig_ids[..k], c);
    } else {
        bank.stats.short_rays += 1;
    }
    (
        c,
        PixelOutcome {
            iterated,
            significant,
            hit: false,
            uncached_iterated: iterated,
            uncached_significant: significant,
        },
    )
}

/// Continue a pixel's tile-list scan past a cache hit without
/// accumulating color: replicates the plain compositor's control flow
/// (fast reject, alpha test, early termination) so the returned counts
/// are bit-identical to an uncached stats pass.
fn scan_uncached(
    rest: &[GatheredSplat],
    px: f32,
    py: f32,
    mut t: f32,
    mut iterated: u32,
    mut significant: u32,
) -> (u32, u32) {
    for s in rest {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        significant += 1;
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            break;
        }
        t = test_t;
    }
    (iterated, significant)
}

/// The radiance-cached [`RasterBackend`]: the RC raster stage of the
/// frame loop, carrying per-session cache state across frames.
pub struct CachedRaster {
    cache: GroupedRadianceCache,
    record_uncached: bool,
}

impl CachedRaster {
    /// `record_uncached` asks every frame for single-pass uncached
    /// per-pixel counts (required by cost models whose
    /// `needs_uncached_stats` is true, e.g. the GPU warp model).
    pub fn new(cache: GroupedRadianceCache, record_uncached: bool) -> Self {
        CachedRaster { cache, record_uncached }
    }

    /// The underlying cache (for occupancy/stats inspection).
    pub fn cache(&self) -> &GroupedRadianceCache {
        &self.cache
    }
}

impl RasterBackend for CachedRaster {
    fn label(&self) -> &'static str {
        "radiance-cached"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        let out = rasterize_cached_ex(
            projected,
            bins,
            width,
            height,
            &mut self.cache,
            self.record_uncached,
        );
        RasterFrame {
            image: out.image,
            work: RasterWork {
                width,
                height,
                consumed: out.outcomes.iter().map(|o| o.iterated).collect(),
                significant: out.outcomes.iter().map(|o| o.significant).collect(),
                uncached: out.uncached,
                cache_outcomes: Some(
                    out.outcomes.iter().map(|o| if o.hit { 2u8 } else { 1u8 }).collect(),
                ),
                cache: out.stats,
                swap_bytes: self.cache.swap_traffic_bytes() as u64,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Intrinsics, Pose};
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::pipeline::raster::{rasterize, RasterConfig};
    use crate::pipeline::sort::bin_and_sort;
    use crate::scene::synth::test_scene;

    #[test]
    fn index_tag_deterministic_and_sensitive() {
        let cache = RadianceCache::paper_default(5);
        let ids = [100, 200, 300, 400, 500];
        let (s1, t1) = cache.index_tag(&ids);
        let (s2, t2) = cache.index_tag(&ids);
        assert_eq!((s1, t1), (s2, t2));
        let ids2 = [100, 200, 300, 400, 1000]; // differs above bit 3
        // Changing one ID changes index and/or tag.
        assert_ne!(cache.index_tag(&ids2), (s1, t1));
        assert!(s1 < CACHE_SETS);
    }

    #[test]
    fn id_bits_outside_window_ignored() {
        // Bits below CACHE_ID_LO_BIT (=3) are not part of index/tag:
        // matches the paper's 3rd..18th-LSB field.
        let cache = RadianceCache::paper_default(2);
        let a = cache.index_tag(&[0b1000, 0b10000]);
        let b = cache.index_tag(&[0b1001, 0b10111]);
        assert_eq!(a, b);
    }

    #[test]
    fn lookup_insert_roundtrip() {
        let mut cache = RadianceCache::paper_default(5);
        let ids = [1 << 3, 2 << 3, 3 << 3, 4 << 3, 5 << 3];
        assert!(cache.lookup(&ids).is_none());
        cache.insert(&ids, [0.1, 0.2, 0.3]);
        assert_eq!(cache.lookup(&ids), Some([0.1, 0.2, 0.3]));
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(cache.stats.lookups, 2);
    }

    #[test]
    fn plru_evicts_cold_way() {
        let mut cache = RadianceCache::new(4, 2, 1);
        // 5 tags mapping to the same set (set bits = lowest index bit of
        // the 16-bit field; craft IDs that share it).
        let mk = |i: u32| [((i << 1) | 0) << CACHE_ID_LO_BIT];
        for i in 0..4 {
            cache.insert(&mk(i), [i as f32; 3]);
        }
        assert_eq!(cache.occupancy(), 4);
        // Touch tags 1..3 so tag 0 becomes the pLRU victim.
        for i in 1..4 {
            assert!(cache.lookup(&mk(i)).is_some());
        }
        cache.insert(&mk(9), [9.0; 3]);
        assert_eq!(cache.stats.evictions, 1);
        assert!(cache.lookup(&mk(0)).is_none(), "cold way should be evicted");
        assert!(cache.lookup(&mk(9)).is_some());
    }

    #[test]
    fn flush_empties() {
        let mut cache = RadianceCache::paper_default(3);
        cache.insert(&[8, 16, 24], [0.5; 3]);
        assert_eq!(cache.occupancy(), 1);
        cache.flush();
        assert_eq!(cache.occupancy(), 0);
        assert!(cache.lookup(&[8, 16, 24]).is_none());
    }

    /// Test scene with the oversized-Gaussian tail clamped — the regime
    /// cache-aware fine-tuning produces (Sec. 3.3); the unclamped tail is
    /// exercised by the fig13/fig21 harnesses instead.
    fn clamped_scene(seed: u64, n: usize) -> crate::scene::GaussianScene {
        let mut scene = test_scene(seed, n);
        let cap = 0.06; // ~5x the median scale for SyntheticSmall
        for s in scene.scale.iter_mut() {
            s.x = s.x.min(cap);
            s.y = s.y.min(cap);
            s.z = s.z.min(cap);
        }
        scene
    }

    fn render_setup() -> (crate::pipeline::project::ProjectedScene, crate::pipeline::sort::TileBins, Intrinsics)
    {
        let scene = clamped_scene(77, 4000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        (p, bins, intr)
    }

    #[test]
    fn cold_cache_first_frame_stays_faithful() {
        // Frame 0: the cache starts empty but fills as pixels complete,
        // so *intra-frame* hits occur between pixels sharing the same
        // initial significant Gaussians (the paper's ray-similarity
        // insight applied within a frame). Quality must stay near-exact.
        let (p, bins, intr) = render_setup();
        let plain = rasterize(&p, &bins, intr.width, intr.height, &RasterConfig::default());
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let cached = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        let q = crate::metrics::psnr(&plain.image, &cached.image);
        assert!(q > 28.0, "first-frame RC quality {q} dB");
        // Miss pixels must be bit-exact: check a hit-free pixel.
        let miss_idx = cached
            .outcomes
            .iter()
            .position(|o| !o.hit)
            .expect("some pixel missed");
        let (x, y) = (miss_idx % intr.width, miss_idx / intr.width);
        assert_eq!(plain.image.at(x, y), cached.image.at(x, y));
    }

    #[test]
    fn second_frame_hits_and_saves_work() {
        let (p, bins, intr) = render_setup();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let first = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        let second = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        assert!(second.stats.hit_rate() > 0.5, "hit rate {}", second.stats.hit_rate());
        // Identical pose -> replay reproduces the first frame closely
        // (hit pixels return cached values; those were themselves RC
        // outputs, so the images converge rather than match bitwise).
        let q = crate::metrics::psnr(&first.image, &second.image);
        assert!(q > 38.0, "same-pose replay diverged: {q} dB");
        // Work saved: hits iterate less than the first pass.
        let w1: u64 = first.outcomes.iter().map(|o| o.iterated as u64).sum();
        let w2: u64 = second.outcomes.iter().map(|o| o.iterated as u64).sum();
        assert!(w2 < w1, "cached pass did not save work: {w1} -> {w2}");
    }

    #[test]
    fn nearby_pose_still_hits_often() {
        let scene = clamped_scene(77, 4000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose1 = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let pose2 = Pose::look_at(Vec3::new(0.01, 0.002, -4.0), Vec3::ZERO);
        let p1 = project(&scene, &pose1, &intr, 0.2, 100.0, 0.0);
        let b1 = bin_and_sort(&p1, &intr, 16, 0.0);
        let p2 = project(&scene, &pose2, &intr, 0.2, 100.0, 0.0);
        let b2 = bin_and_sort(&p2, &intr, 16, 0.0);
        let mut cache = GroupedRadianceCache::new(b1.tiles_x, b1.tiles_y, 5);
        rasterize_cached(&p1, &b1, intr.width, intr.height, &mut cache);
        let out = rasterize_cached(&p2, &b2, intr.width, intr.height, &mut cache);
        assert!(
            out.stats.hit_rate() > 0.3,
            "nearby pose hit rate {}",
            out.stats.hit_rate()
        );
        // Quality: overall PSNR stays high, and the *median* hit-pixel
        // color error reproduces the paper's Fig. 12 claim (average color
        // difference ~0.5-1.0 out of 255 for k=5). The tail is heavier
        // than in trained scenes (DESIGN.md §5: synthetic statistics),
        // which is what cache-aware fine-tuning addresses.
        let exact = rasterize(&p2, &b2, intr.width, intr.height, &RasterConfig::default());
        let psnr = crate::metrics::psnr(&exact.image, &out.image);
        assert!(psnr > 27.0, "cached quality {psnr} dB");
        let mut diffs: Vec<f32> = out
            .outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| o.hit)
            .map(|(i, _)| {
                let (x, y) = (i % intr.width, i / intr.width);
                let a = out.image.at(x, y);
                let b = exact.image.at(x, y);
                ((a[0] - b[0]).abs() + (a[1] - b[1]).abs() + (a[2] - b[2]).abs()) / 3.0
                    * 255.0
            })
            .collect();
        diffs.sort_by(f32::total_cmp);
        let median = diffs[diffs.len() / 2];
        assert!(median < 3.0, "median hit color diff {median}/255 (paper: <1.0)");
    }

    #[test]
    fn single_pass_uncached_stats_match_two_pass() {
        // The RC-GPU cost model used to re-rasterize the whole frame
        // uncached just to recover warp aggregates; the single-pass
        // recording must reproduce that second pass bit-for-bit.
        let (p, bins, intr) = render_setup();
        let plain_cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let plain = rasterize(&p, &bins, intr.width, intr.height, &plain_cfg);
        let plain_stats = plain.stats.unwrap();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        // Cold pass (intra-frame hits) and warm pass (heavy hits): the
        // recorded uncached counts must match the plain pass in both.
        for pass in 0..2 {
            let out =
                rasterize_cached_ex(&p, &bins, intr.width, intr.height, &mut cache, true);
            let unc = out.uncached.expect("recording requested");
            assert_eq!(unc.iterated, plain_stats.iterated, "pass {pass} iterated");
            assert_eq!(unc.significant, plain_stats.significant, "pass {pass} significant");
            if pass == 1 {
                assert!(out.stats.hits > 0, "warm pass should hit");
            }
        }
    }

    #[test]
    fn unrecorded_pass_reports_actual_counts() {
        let (p, bins, intr) = render_setup();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let out = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        assert!(out.uncached.is_none());
        for o in &out.outcomes {
            assert_eq!(o.uncached_iterated, o.iterated);
            assert_eq!(o.uncached_significant, o.significant);
        }
    }

    #[test]
    fn smaller_k_hits_more() {
        let (p, bins, intr) = render_setup();
        let mut rates = Vec::new();
        for k in [2usize, 5, 8] {
            let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, k);
            rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
            let out = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
            rates.push(out.stats.hit_rate());
        }
        // Fig. 24: hit rate falls as the alpha-record grows. Same-pose
        // replay saturates near 100%, so only the endpoints separate
        // cleanly here; the full monotone sweep is fig24's harness (which
        // uses a moving trajectory).
        assert!(rates[0] > rates[2], "rates {rates:?}");
        assert!(rates[0] > 0.9, "k=2 same-pose replay should saturate: {rates:?}");
    }

    #[test]
    fn groups_are_independent_banks() {
        let mut cache = GroupedRadianceCache::new(8, 8, 5);
        assert_eq!(cache.num_banks(), 4);
        let ids = [8, 16, 24, 32, 40];
        cache.bank_for_tile(0, 0).insert(&ids, [1.0; 3]);
        assert!(cache.bank_for_tile(0, 0).lookup(&ids).is_some());
        assert!(cache.bank_for_tile(7, 7).lookup(&ids).is_none());
    }

    #[test]
    fn swap_traffic_grows_with_occupancy() {
        let mut cache = GroupedRadianceCache::new(4, 4, 5);
        assert_eq!(cache.swap_traffic_bytes(), 0);
        cache.bank_for_tile(0, 0).insert(&[8, 16, 24, 32, 40], [0.5; 3]);
        assert_eq!(cache.swap_traffic_bytes(), 26); // 13 B x 2 directions
    }
}

