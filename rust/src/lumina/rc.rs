//! RC — Radiance Caching (paper Sec. 3.2) and the LuminCache-faithful
//! cache organization (paper Sec. 4/5).
//!
//! Key insight: two rays that intersect the same sequence of initial
//! *significant* Gaussians (alpha > 1/255) almost surely produce the same
//! pixel value. Per pixel, rasterization runs only until the first k
//! significant Gaussians are identified; their IDs form a cache tag. On a
//! hit, the cached RGB replaces the remaining color integration; on a
//! miss, integration completes and the cache is updated.
//!
//! The cache geometry mirrors LuminCache (Sec. 5): 4-way set-associative,
//! 1024 sets, tag/index built from bits [3..19) of each of the k Gaussian
//! IDs (paper: "3rd to 18th least significant bits", 16 bits per ID, 10
//! bytes of tag material for k=5), tree pseudo-LRU replacement, and
//! contents partitioned per 4x4-tile group (64x64 px) with save/flush/
//! reload semantics between groups (modeled functionally as per-group
//! sub-caches; the traffic is charged by the simulator).
//!
//! **Cache topology** (DESIGN.md §4): nearby viewers produce the same
//! first-k tags, so a pool can serve one viewer's miss from another's
//! earlier insert. Ownership is a seam ([`CacheView`]) with two
//! implementations: `private` — the session owns a
//! [`GroupedRadianceCache`] outright (today's behavior, bit-for-bit) —
//! and `shared` — every session of a pool reads one frozen, immutable
//! [`CacheSnapshot`] for the whole epoch while logging its own inserts
//! into a private [`CacheDelta`]; at epoch boundaries the pool replays
//! the deltas into the next snapshot **in session-index order**
//! ([`CacheHub::merge_in_order`]), so shared-scope output is bitwise
//! identical at any thread count and pipeline depth.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::constants::{
    CACHE_ID_BITS, CACHE_ID_LO_BIT, CACHE_SETS, CACHE_TILE_GROUP, CACHE_WAYS, T_EPS,
};
use crate::pipeline::image::Image;
use crate::pipeline::project::ProjectedScene;
use crate::pipeline::raster::{gather_tile, splat_alpha, GatheredSplat, RasterStats, MAX_SIG_K};
use crate::pipeline::sort::TileBins;
use crate::pipeline::stage::{RasterBackend, RasterFrame, RasterWork};

/// Bytes one cache entry occupies in DRAM during a group save/reload:
/// 10 B tag material + 3 B RGB (paper Sec. 5).
pub const CACHE_ENTRY_BYTES: usize = 13;

/// One cache entry: packed high-bit tag + cached pixel RGB.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    tag: u128,
    value: [f32; 3],
}

/// What an insert did to the set it landed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InsertOutcome {
    /// Tag already present; value updated in place.
    Updated,
    /// Placed in a free way.
    Filled,
    /// Placed by evicting the pseudo-LRU victim.
    Evicted,
}

/// Running cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    /// Of `hits`, how many were served from the pool-shared frozen
    /// snapshot rather than the session's own inserts — the hit
    /// provenance that tells cross-session sharing apart from the
    /// private hit path (always 0 under private scope).
    pub snapshot_hits: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Pixels whose ray met fewer than k significant Gaussians
    /// (uncacheable; rendered fully).
    pub short_rays: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.snapshot_hits += o.snapshot_hits;
        self.inserts += o.inserts;
        self.evictions += o.evictions;
        self.short_rays += o.short_rays;
    }
}

/// A single LuminCache bank: N-way set-associative with tree pseudo-LRU.
#[derive(Debug, Clone)]
pub struct RadianceCache {
    ways: usize,
    sets: usize,
    k: usize,
    entries: Vec<Option<Entry>>,
    /// Per-set pseudo-LRU tree bits (3 bits for 4 ways, packed in u8).
    plru: Vec<u8>,
    pub stats: CacheStats,
}

impl RadianceCache {
    /// Paper-default geometry: 4 ways x 1024 sets, tag from k IDs.
    pub fn paper_default(k: usize) -> Self {
        Self::new(CACHE_WAYS, CACHE_SETS, k)
    }

    pub fn new(ways: usize, sets: usize, k: usize) -> Self {
        assert!(ways == 2 || ways == 4 || ways == 8, "plru tree supports 2/4/8 ways");
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!((1..=MAX_SIG_K).contains(&k));
        RadianceCache {
            ways,
            sets,
            k,
            entries: vec![None; ways * sets],
            plru: vec![0; sets],
            stats: CacheStats::default(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Build (set index, tag) from the first k significant Gaussian IDs.
    ///
    /// Per the paper (Fig. 16): the *lower* bits of each ID concatenate
    /// into the set index; the *higher* bits concatenate into the tag.
    /// IDs contribute bits [CACHE_ID_LO_BIT .. CACHE_ID_LO_BIT+16).
    fn index_tag(&self, ids: &[u32]) -> (usize, u128) {
        debug_assert_eq!(ids.len(), self.k);
        let index_bits = self.sets.trailing_zeros();
        let per_id = (index_bits as usize).div_ceil(self.k).max(1) as u32;
        let mut index: u64 = 0;
        let mut tag: u128 = 0;
        for &id in ids {
            let field = ((id >> CACHE_ID_LO_BIT) & ((1u32 << CACHE_ID_BITS) - 1)) as u64;
            let low = field & ((1u64 << per_id) - 1);
            let high = field >> per_id;
            index = (index << per_id) | low;
            tag = (tag << (CACHE_ID_BITS - per_id)) | high as u128;
        }
        ((index as usize) & (self.sets - 1), tag)
    }

    /// The set a tag indexes — the compaction key of the shared-scope
    /// insertion log.
    fn set_index(&self, ids: &[u32]) -> usize {
        self.index_tag(ids).0
    }

    /// Structural equality of cache contents — entries and pLRU state,
    /// statistics ignored. What "bitwise-identical replay" means for a
    /// bank: two banks that are `state_eq` respond identically to every
    /// future lookup/insert sequence.
    pub fn state_eq(&self, other: &RadianceCache) -> bool {
        self.ways == other.ways
            && self.sets == other.sets
            && self.k == other.k
            && self.entries == other.entries
            && self.plru == other.plru
    }

    /// Look up a tag; on hit returns the cached RGB and touches pLRU.
    pub fn lookup(&mut self, ids: &[u32]) -> Option<[f32; 3]> {
        self.stats.lookups += 1;
        let hit = self.probe_touch(ids);
        if hit.is_some() {
            self.stats.hits += 1;
        }
        hit
    }

    /// Read-only probe against frozen contents: tag compare without
    /// touching stats or pLRU — the shared-snapshot lookup path, safe
    /// for any number of concurrent readers.
    pub fn probe(&self, ids: &[u32]) -> Option<[f32; 3]> {
        let (set, tag) = self.index_tag(ids);
        for w in 0..self.ways {
            if let Some(e) = self.entries[set * self.ways + w] {
                if e.tag == tag {
                    return Some(e.value);
                }
            }
        }
        None
    }

    /// Probe that refreshes pLRU on a hit but leaves stats untouched —
    /// the delta-overlay read path, whose stats live in the
    /// [`CacheDelta`].
    fn probe_touch(&mut self, ids: &[u32]) -> Option<[f32; 3]> {
        let (set, tag) = self.index_tag(ids);
        for w in 0..self.ways {
            if let Some(e) = self.entries[set * self.ways + w] {
                if e.tag == tag {
                    self.touch(set, w);
                    return Some(e.value);
                }
            }
        }
        None
    }

    /// Insert (or update) a tag with a pixel value, evicting pseudo-LRU.
    pub fn insert(&mut self, ids: &[u32], value: [f32; 3]) {
        match self.insert_tracked(ids, value) {
            InsertOutcome::Updated => {}
            InsertOutcome::Filled => self.stats.inserts += 1,
            InsertOutcome::Evicted => {
                self.stats.inserts += 1;
                self.stats.evictions += 1;
            }
        }
    }

    /// [`Self::insert`] without the stats side effects, reporting what
    /// happened — lets callers that account stats elsewhere (the shared
    /// delta overlay) reuse the placement/eviction logic.
    fn insert_tracked(&mut self, ids: &[u32], value: [f32; 3]) -> InsertOutcome {
        let (set, tag) = self.index_tag(ids);
        // Update in place on tag match.
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if let Some(e) = &mut self.entries[slot] {
                if e.tag == tag {
                    e.value = value;
                    self.touch(set, w);
                    return InsertOutcome::Updated;
                }
            }
        }
        // Free way?
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if self.entries[slot].is_none() {
                self.entries[slot] = Some(Entry { tag, value });
                self.touch(set, w);
                return InsertOutcome::Filled;
            }
        }
        // Evict the pseudo-LRU victim.
        let w = self.victim(set);
        self.entries[set * self.ways + w] = Some(Entry { tag, value });
        self.touch(set, w);
        InsertOutcome::Evicted
    }

    /// Tree-pLRU touch: flip node bits toward the accessed way.
    fn touch(&mut self, set: usize, way: usize) {
        // For 4 ways: bit0 = root (0: left pair younger), bit1 = left
        // pair, bit2 = right pair. Generalized for 2/8 analogously.
        match self.ways {
            2 => {
                self.plru[set] = way as u8 ^ 1;
            }
            4 => {
                let mut b = self.plru[set];
                if way < 2 {
                    b |= 1; // root points right next
                    if way == 0 {
                        b |= 2;
                    } else {
                        b &= !2;
                    }
                } else {
                    b &= !1; // root points left next
                    if way == 2 {
                        b |= 4;
                    } else {
                        b &= !4;
                    }
                }
                self.plru[set] = b;
            }
            8 => {
                // 7-bit tree; index math kept simple.
                let mut b = self.plru[set];
                let top = way / 4;
                let mid = (way / 2) % 2;
                let leaf = way % 2;
                set_bit(&mut b, 0, top == 0);
                set_bit(&mut b, 1 + top as u8, mid == 0);
                set_bit(&mut b, 3 + (way / 2) as u8, leaf == 0);
                self.plru[set] = b;
            }
            _ => unreachable!(),
        }
    }

    /// Tree-pLRU victim selection.
    fn victim(&self, set: usize) -> usize {
        let b = self.plru[set];
        match self.ways {
            2 => (b & 1) as usize,
            4 => {
                if b & 1 == 0 {
                    // go left pair
                    if b & 2 == 0 {
                        0
                    } else {
                        1
                    }
                } else if b & 4 == 0 {
                    2
                } else {
                    3
                }
            }
            8 => {
                let top = usize::from(b & 1 == 0);
                let mid = usize::from(b & (1 << (1 + top)) == 0);
                let half = top * 4 + mid * 2;
                let leaf = usize::from(b & (1 << (3 + half / 2)) == 0);
                half + leaf
            }
            _ => unreachable!(),
        }
    }

    /// Flush all contents (the per-tile-group flush of Sec. 4).
    pub fn flush(&mut self) {
        self.entries.iter_mut().for_each(|e| *e = None);
        self.plru.iter_mut().for_each(|b| *b = 0);
    }

    /// Number of live entries.
    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }
}

fn set_bit(b: &mut u8, bit: u8, value: bool) {
    if value {
        *b |= 1 << bit;
    } else {
        *b &= !(1 << bit);
    }
}

/// The tile-grid shape (and alpha-record length) a cache serves: the
/// key under which shared-scope sessions pool their snapshots — two
/// sessions share if and only if their render passes bin the same tile
/// grid with the same k (tiers change the grid, hence the geometry).
/// `Ord` (derived lexicographically) gives multi-geometry merges a
/// canonical publish order — see [`CacheHub::merge_in_order`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheGeometry {
    pub tiles_x: usize,
    pub tiles_y: usize,
    pub k: usize,
}

/// The full LuminCache: one [`RadianceCache`] bank per 4x4-tile group,
/// persisted across frames (the hardware saves/reloads group contents to
/// DRAM between tile batches; double-buffering hides the latency, the
/// simulator charges the traffic).
#[derive(Debug, Clone)]
pub struct GroupedRadianceCache {
    pub groups_x: usize,
    pub groups_y: usize,
    tiles_x: usize,
    tiles_y: usize,
    banks: Vec<RadianceCache>,
    k: usize,
}

impl GroupedRadianceCache {
    pub fn new(tiles_x: usize, tiles_y: usize, k: usize) -> Self {
        let groups_x = tiles_x.div_ceil(CACHE_TILE_GROUP);
        let groups_y = tiles_y.div_ceil(CACHE_TILE_GROUP);
        GroupedRadianceCache {
            groups_x,
            groups_y,
            tiles_x,
            tiles_y,
            banks: (0..groups_x * groups_y)
                .map(|_| RadianceCache::paper_default(k))
                .collect(),
            k,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The tile-grid geometry this cache was sized for.
    pub fn geometry(&self) -> CacheGeometry {
        CacheGeometry { tiles_x: self.tiles_x, tiles_y: self.tiles_y, k: self.k }
    }

    /// Bank index serving a tile coordinate.
    pub fn group_for_tile(&self, tx: usize, ty: usize) -> usize {
        let gx = tx / CACHE_TILE_GROUP;
        let gy = ty / CACHE_TILE_GROUP;
        gy * self.groups_x + gx
    }

    /// Read access to the bank serving a tile — the lookup path, which
    /// an `Arc`-shared snapshot can serve concurrently. (The old
    /// `&mut self` accessor forced exclusive access even for reads,
    /// structurally ruling out any sharing.)
    pub fn bank_for_tile(&self, tx: usize, ty: usize) -> &RadianceCache {
        &self.banks[self.group_for_tile(tx, ty)]
    }

    /// Write access to the bank serving a tile — the insert/pLRU path.
    pub fn bank_for_tile_mut(&mut self, tx: usize, ty: usize) -> &mut RadianceCache {
        let g = self.group_for_tile(tx, ty);
        &mut self.banks[g]
    }

    /// Replay an ordered insertion log — the epoch-merge path. Entries
    /// land through the normal placement path (in-place update,
    /// free-way fill, pLRU eviction), in log order, without touching
    /// bank stats: insert/eviction accounting belongs to the session
    /// deltas, not the published snapshot.
    fn replay(&mut self, log: &[LoggedInsert]) {
        for e in log {
            self.banks[e.group as usize].insert_tracked(&e.ids[..e.k as usize], e.value);
        }
    }

    /// Aggregate statistics over all banks.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for b in &self.banks {
            s.merge(&b.stats);
        }
        s
    }

    /// Live entries across all banks.
    pub fn occupancy(&self) -> usize {
        self.banks.iter().map(|b| b.occupancy()).sum()
    }

    /// Bytes moved per frame for group save+reload (entries * entry size
    /// * 2 directions) — the DRAM traffic the simulator charges for a
    /// **private** (per-session) cache, which really is spilled and
    /// refilled around every frame's tile batches. A pool-shared
    /// snapshot is saved/reloaded once per pool epoch instead; that
    /// scope-aware accounting lives in [`CacheView::swap_bytes_for_frame`],
    /// built from [`Self::occupancy`] and [`CACHE_ENTRY_BYTES`].
    pub fn swap_traffic_bytes(&self) -> usize {
        self.occupancy() * CACHE_ENTRY_BYTES * 2
    }

    pub fn num_banks(&self) -> usize {
        self.banks.len()
    }

    /// Structural equality over all banks (see
    /// [`RadianceCache::state_eq`]).
    pub fn state_eq(&self, other: &GroupedRadianceCache) -> bool {
        self.groups_x == other.groups_x
            && self.groups_y == other.groups_y
            && self.tiles_x == other.tiles_x
            && self.tiles_y == other.tiles_y
            && self.banks.len() == other.banks.len()
            && self.banks.iter().zip(&other.banks).all(|(a, b)| a.state_eq(b))
    }
}

/// An immutable, epoch-stamped view of a merged radiance cache: what
/// every session of a shared-scope pool reads for the whole epoch.
/// Lookups are pure reads (no stats, no pLRU touch), so any number of
/// sessions can probe one snapshot concurrently with bitwise-identical
/// results — the determinism half of the snapshot/merge contract
/// (DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct CacheSnapshot {
    cache: GroupedRadianceCache,
    /// Merge generation: bumped every time [`CacheHub::merge_in_order`]
    /// publishes a successor, so views can tell a genuinely new
    /// snapshot from a sharer-count refresh.
    epoch: u64,
}

impl CacheSnapshot {
    /// An empty snapshot for a cache geometry (epoch 0).
    pub fn empty(geom: CacheGeometry) -> Self {
        CacheSnapshot {
            cache: GroupedRadianceCache::new(geom.tiles_x, geom.tiles_y, geom.k),
            epoch: 0,
        }
    }

    /// Frozen lookup: the cached RGB for a tag, if present.
    pub fn lookup(&self, tx: usize, ty: usize, ids: &[u32]) -> Option<[f32; 3]> {
        self.cache.bank_for_tile(tx, ty).probe(ids)
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.cache.geometry()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Live entries across all banks.
    pub fn occupancy(&self) -> usize {
        self.cache.occupancy()
    }

    /// DRAM bytes to save + reload the whole snapshot once — charged
    /// once per pool epoch (amortized over the sharers), not once per
    /// session per frame.
    pub fn swap_traffic_bytes(&self) -> usize {
        self.cache.swap_traffic_bytes()
    }
}

/// One logged insert of a [`CacheDelta`]: enough to replay the exact
/// insert against the next snapshot at the epoch merge.
#[derive(Debug, Clone, Copy)]
pub struct LoggedInsert {
    group: u32,
    k: u8,
    ids: [u32; MAX_SIG_K],
    value: [f32; 3],
}

/// A session's private epoch-local cache state under shared scope: an
/// overlay cache answering lookups for the session's own fresh inserts
/// (so intra-frame and intra-epoch self-hits keep working), plus the
/// ordered insertion log the pool replays into the next snapshot at the
/// epoch merge. Nothing here is visible to other sessions until the
/// merge publishes it.
///
/// The log is compacted **at record time, per cache set** (see
/// [`SharedBank::store`]): a re-insert whose tag matches the most
/// recent insert into the same `(group, set)` folds into that entry —
/// exactly equivalent under ordered replay, because inserts into other
/// sets never touch this set's ways or pLRU bits. The dominant log
/// growth — the same hot tags re-missing frame after frame within an
/// epoch — therefore collapses to one entry per tag run, bounding delta
/// memory by tag *alternations* across the touched sets rather than the
/// epoch's raw miss count. (`last_in_set` carries one index per touched
/// set to find the fold target in O(1).)
#[derive(Debug)]
pub struct CacheDelta {
    overlay: GroupedRadianceCache,
    log: Vec<LoggedInsert>,
    /// Per-(group, set): index into `log` of the most recent insert
    /// into that set — the set-level compaction cursor.
    last_in_set: HashMap<(u32, u32), u32>,
    stats: CacheStats,
}

impl CacheDelta {
    pub fn new(geom: CacheGeometry) -> Self {
        CacheDelta {
            overlay: GroupedRadianceCache::new(geom.tiles_x, geom.tiles_y, geom.k),
            log: Vec::new(),
            last_in_set: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.overlay.geometry()
    }

    /// Inserts logged since the delta was (re)created.
    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    /// View statistics accumulated while rendering against this delta.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// The cache-topology seam: where one session's lookups and inserts go.
pub enum CacheView {
    /// Session-owned cache — the pre-sharing behavior, bit-for-bit.
    Private(GroupedRadianceCache),
    /// Pool-shared: reads check the session's own delta overlay first
    /// (freshest), then the frozen epoch snapshot; writes go to the
    /// delta only.
    Shared {
        snapshot: Arc<CacheSnapshot>,
        delta: CacheDelta,
        /// Snapshot-reload DRAM bytes still to charge — the session's
        /// amortized share of the once-per-pool-epoch snapshot swap,
        /// consumed by the next rendered frame.
        pending_snapshot_bytes: u64,
    },
}

impl CacheView {
    pub fn private(cache: GroupedRadianceCache) -> Self {
        CacheView::Private(cache)
    }

    /// A shared view over a snapshot, with a fresh (empty) delta. The
    /// freshly attached session must reload the whole snapshot once, so
    /// the full swap traffic is pending; pool installs that follow a
    /// merge amortize over the sharer count instead
    /// ([`Self::install_snapshot`]).
    pub fn shared(snapshot: Arc<CacheSnapshot>) -> Self {
        let delta = CacheDelta::new(snapshot.geometry());
        let pending = snapshot.swap_traffic_bytes() as u64;
        CacheView::Shared { snapshot, delta, pending_snapshot_bytes: pending }
    }

    pub fn is_shared(&self) -> bool {
        matches!(self, CacheView::Shared { .. })
    }

    pub fn k(&self) -> usize {
        match self {
            CacheView::Private(c) => c.k(),
            CacheView::Shared { delta, .. } => delta.overlay.k(),
        }
    }

    /// Lifetime view statistics (bank stats under private scope, delta
    /// stats under shared).
    pub fn stats(&self) -> CacheStats {
        match self {
            CacheView::Private(c) => c.stats(),
            CacheView::Shared { delta, .. } => delta.stats,
        }
    }

    /// Detach the accumulated delta, leaving a fresh one behind (`None`
    /// under private scope). The pool calls this at every epoch
    /// boundary, in session-index order.
    pub fn take_delta(&mut self) -> Option<CacheDelta> {
        match self {
            CacheView::Private(_) => None,
            CacheView::Shared { delta, .. } => {
                let fresh = CacheDelta::new(delta.geometry());
                Some(std::mem::replace(delta, fresh))
            }
        }
    }

    /// Swap in the next epoch's merged snapshot. `sharers` is how many
    /// sessions read this snapshot: the once-per-pool-epoch save+reload
    /// traffic is split across them, so the pool as a whole is charged
    /// the swap once — not once per session per frame. Re-installing
    /// the same snapshot (a sharer-count refresh) charges nothing.
    pub fn install_snapshot(&mut self, snap: Arc<CacheSnapshot>, sharers: usize) {
        if let CacheView::Shared { snapshot, delta, pending_snapshot_bytes } = self {
            if Arc::ptr_eq(snapshot, &snap) {
                return;
            }
            if snap.geometry() != delta.geometry() {
                // Defensive: a geometry change must come with a fresh
                // delta (set_tier rebuilds the whole view; this path
                // covers direct installs only).
                *delta = CacheDelta::new(snap.geometry());
            }
            *pending_snapshot_bytes +=
                (snap.swap_traffic_bytes() as u64).div_ceil(sharers.max(1) as u64);
            *snapshot = snap;
        }
    }

    /// DRAM swap traffic to charge the frame that is being rendered
    /// right now. Private: the whole cache is spilled/refilled around
    /// the frame's tile batches, every frame (the pre-sharing model,
    /// unchanged). Shared: the session's delta working set is
    /// saved+reloaded each frame exactly like a private cache of the
    /// same occupancy, plus whatever share of the epoch's snapshot swap
    /// is still pending (consumed here, charged once per install).
    pub fn swap_bytes_for_frame(&mut self) -> u64 {
        match self {
            CacheView::Private(c) => c.swap_traffic_bytes() as u64,
            CacheView::Shared { delta, pending_snapshot_bytes, .. } => {
                let snapshot_share = std::mem::take(pending_snapshot_bytes);
                snapshot_share + delta.overlay.swap_traffic_bytes() as u64
            }
        }
    }
}

/// Pool-wide owner of the shared snapshots, keyed by [`CacheGeometry`]
/// (sessions on different serving tiers render different tile grids and
/// therefore share with their geometry peers only — a `set_tier` swap
/// invalidates just that session's delta, never the snapshots).
///
/// The hub is only ever touched from the pool's coordination thread
/// (construction, tier application, epoch merges); during rendering,
/// sessions hold their own `Arc<CacheSnapshot>` and never reach the
/// hub, so the mutex is uncontended and cannot order-scramble anything.
#[derive(Debug, Default)]
pub struct CacheHub {
    snapshots: Mutex<HashMap<CacheGeometry, Arc<CacheSnapshot>>>,
}

impl CacheHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// The current snapshot for a geometry (an empty epoch-0 snapshot
    /// is created on first request).
    pub fn snapshot_for(&self, geom: CacheGeometry) -> Arc<CacheSnapshot> {
        self.snapshots
            .lock()
            .expect("cache hub poisoned")
            .entry(geom)
            .or_insert_with(|| Arc::new(CacheSnapshot::empty(geom)))
            .clone()
    }

    /// Merge session deltas into next-epoch snapshots **in the order
    /// given** — the pool passes session-index order, which is the
    /// whole determinism contract: the merged contents (values, pLRU
    /// state, evictions) depend only on that order, never on how many
    /// threads rendered the epoch. Geometries untouched by any delta
    /// keep their current snapshot (same `Arc`, same epoch), so idle
    /// epochs charge no snapshot swap.
    pub fn merge_in_order(&self, deltas: Vec<CacheDelta>) {
        let mut map = self.snapshots.lock().expect("cache hub poisoned");
        // BTreeMap, not HashMap: publication below iterates this map, and
        // the publish order must be a function of the deltas alone (hash
        // iteration order is seeded per-process). Today publish order is
        // not value-observable — geometries are independent keys — but
        // keeping it canonical (ascending CacheGeometry) means log
        // readers, future cross-geometry accounting, and the detlint R1
        // rule never have to reason about it.
        let mut dirty: BTreeMap<CacheGeometry, (GroupedRadianceCache, u64)> = BTreeMap::new();
        for d in deltas {
            if d.log.is_empty() {
                continue;
            }
            let geom = d.geometry();
            let (work, _) = dirty.entry(geom).or_insert_with(|| match map.get(&geom) {
                Some(s) => (s.cache.clone(), s.epoch),
                None => (GroupedRadianceCache::new(geom.tiles_x, geom.tiles_y, geom.k), 0),
            });
            work.replay(&d.log);
        }
        for (geom, (cache, epoch)) in dirty {
            map.insert(geom, Arc::new(CacheSnapshot { cache, epoch: epoch + 1 }));
        }
    }
}

/// Per-pixel outcome of cached rasterization.
#[derive(Debug, Clone, Copy, Default)]
pub struct PixelOutcome {
    /// Gaussians iterated by this pixel (stops early on cache hit).
    pub iterated: u32,
    /// Significant Gaussians encountered while iterating.
    pub significant: u32,
    /// True when the pixel's value came from the cache.
    pub hit: bool,
    /// Hit provenance: true when the value came from the pool-shared
    /// frozen snapshot rather than the session's own inserts (always
    /// false under private scope).
    pub snapshot_hit: bool,
    /// Gaussians the *uncached* pipeline would have iterated. Equal to
    /// `iterated` except on hit pixels rendered with
    /// `record_uncached = true`, where the scan continues (without
    /// compositing) to recover the exact plain-rasterizer count.
    pub uncached_iterated: u32,
    /// Significant Gaussians the uncached pipeline would have seen.
    pub uncached_significant: u32,
}

/// Output of radiance-cached rasterization.
pub struct CachedRasterOutput {
    pub image: Image,
    pub outcomes: Vec<PixelOutcome>,
    pub stats: CacheStats,
    /// Per-pixel uncached counts (present when `record_uncached` was
    /// requested): exactly what a plain [`rasterize`] stats pass over
    /// the same projected set would produce, recovered in this single
    /// pass.
    ///
    /// [`rasterize`]: crate::pipeline::raster::rasterize
    pub uncached: Option<RasterStats>,
}

/// Rasterize with radiance caching (paper Fig. 10).
///
/// Per pixel: composite until the first k significant Gaussians are seen
/// (the alpha-record), query the cache with their IDs; on hit, emit the
/// cached value and stop; on miss, finish compositing and insert.
/// Serial over tiles because the cache is shared mutable state — exactly
/// the lock-contention hazard the paper ascribes to RC-on-GPU; the
/// accelerator sims recover parallelism by charging per-bank timing.
pub fn rasterize_cached(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cache: &mut GroupedRadianceCache,
) -> CachedRasterOutput {
    rasterize_cached_ex(projected, bins, width, height, cache, false)
}

/// [`rasterize_cached`] with optional single-pass recording of the
/// *uncached* per-pixel counts (see [`CachedRasterOutput::uncached`]):
/// hit pixels continue scanning their tile list without compositing, so
/// the RC-GPU cost model gets the exact uncached warp structure without
/// a second full rasterization.
pub fn rasterize_cached_ex(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cache: &mut GroupedRadianceCache,
    record_uncached: bool,
) -> CachedRasterOutput {
    rasterize_cached_source(
        projected,
        bins,
        width,
        height,
        &mut TileSource::Private(cache),
        record_uncached,
    )
}

/// Report only one call's statistics: `after` minus `before`.
fn stats_delta(after: CacheStats, before: CacheStats) -> CacheStats {
    CacheStats {
        lookups: after.lookups - before.lookups,
        hits: after.hits - before.hits,
        snapshot_hits: after.snapshot_hits - before.snapshot_hits,
        inserts: after.inserts - before.inserts,
        evictions: after.evictions - before.evictions,
        short_rays: after.short_rays - before.short_rays,
    }
}

/// [`rasterize_cached_ex`] over the topology seam: both scopes run the
/// same loop driver; only the per-tile bank construction differs.
pub fn rasterize_cached_view(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    view: &mut CacheView,
    record_uncached: bool,
) -> CachedRasterOutput {
    let mut source = match view {
        CacheView::Private(cache) => TileSource::Private(cache),
        CacheView::Shared { snapshot, delta, .. } => {
            debug_assert_eq!(
                snapshot.geometry(),
                delta.geometry(),
                "snapshot/delta geometry split"
            );
            TileSource::Shared { snapshot: &**snapshot, delta }
        }
    };
    rasterize_cached_source(projected, bins, width, height, &mut source, record_uncached)
}

/// Where a rasterization call's per-tile banks come from — the driver's
/// end of the topology seam. Private: the session's own mutable bank.
/// Shared: a frozen snapshot bank paired with the session's delta
/// overlay/log — the snapshot is never written, so concurrent sessions
/// cannot observe each other mid-epoch; sharing becomes visible only
/// through the deterministic epoch merge.
enum TileSource<'s> {
    Private(&'s mut GroupedRadianceCache),
    Shared { snapshot: &'s CacheSnapshot, delta: &'s mut CacheDelta },
}

impl TileSource<'_> {
    fn k(&self) -> usize {
        match self {
            TileSource::Private(c) => c.k(),
            TileSource::Shared { delta, .. } => delta.overlay.k(),
        }
    }

    fn stats(&self) -> CacheStats {
        match self {
            TileSource::Private(c) => c.stats(),
            TileSource::Shared { delta, .. } => delta.stats,
        }
    }
}

/// The one tile/pixel loop driver both topologies share — any change to
/// tile iteration, edge clamping, or stats assembly lands on private
/// and shared scope alike, preserving their documented equivalence.
fn rasterize_cached_source(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    source: &mut TileSource<'_>,
    record_uncached: bool,
) -> CachedRasterOutput {
    let ts = bins.tile_size;
    let k = source.k();
    let mut image = Image::new(width, height);
    let mut outcomes = vec![PixelOutcome::default(); width * height];
    let stats_before = source.stats();

    for ty in 0..bins.tiles_y {
        for tx in 0..bins.tiles_x {
            let tile = ty * bins.tiles_x + tx;
            let splats = gather_tile(projected, bins.list(tile));
            match source {
                TileSource::Private(cache) => run_tile(
                    cache.bank_for_tile_mut(tx, ty),
                    &splats,
                    (tx, ty),
                    ts,
                    (width, height),
                    k,
                    record_uncached,
                    &mut image,
                    &mut outcomes,
                ),
                TileSource::Shared { snapshot, delta } => {
                    let CacheDelta { overlay, log, last_in_set, stats } = &mut **delta;
                    let group = overlay.group_for_tile(tx, ty) as u32;
                    let mut bank = SharedBank {
                        frozen: snapshot.cache.bank_for_tile(tx, ty),
                        overlay: overlay.bank_for_tile_mut(tx, ty),
                        log,
                        last_in_set,
                        stats,
                        group,
                    };
                    run_tile(
                        &mut bank,
                        &splats,
                        (tx, ty),
                        ts,
                        (width, height),
                        k,
                        record_uncached,
                        &mut image,
                        &mut outcomes,
                    );
                }
            }
        }
    }

    let stats = stats_delta(source.stats(), stats_before);
    let uncached = record_uncached.then(|| RasterStats {
        iterated: outcomes.iter().map(|o| o.uncached_iterated).collect(),
        significant: outcomes.iter().map(|o| o.uncached_significant).collect(),
    });
    CachedRasterOutput { image, outcomes, stats, uncached }
}

/// One tile's pixel loop over a cache endpoint.
#[allow(clippy::too_many_arguments)]
fn run_tile<B: PixelCache>(
    bank: &mut B,
    splats: &[GatheredSplat],
    (tx, ty): (usize, usize),
    ts: usize,
    (width, height): (usize, usize),
    k: usize,
    record_uncached: bool,
    image: &mut Image,
    outcomes: &mut [PixelOutcome],
) {
    for ly in 0..ts {
        let y = ty * ts + ly;
        if y >= height {
            break;
        }
        for lx in 0..ts {
            let x = tx * ts + lx;
            if x >= width {
                break;
            }
            let (value, outcome) = composite_pixel_cached_generic(
                splats,
                x as f32 + 0.5,
                y as f32 + 0.5,
                k,
                bank,
                record_uncached,
            );
            image.set(x, y, value);
            outcomes[y * width + x] = outcome;
        }
    }
}

/// One pixel with cache interaction. Mirrors `raster::composite_pixel`
/// semantics exactly for the compositing math (including the gathered
/// significance-radius fast reject).
pub fn composite_pixel_cached(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut RadianceCache,
) -> ([f32; 3], PixelOutcome) {
    composite_pixel_cached_ex(splats, px, py, k, bank, false)
}

/// [`composite_pixel_cached`] with optional uncached-count recording: on
/// a hit, the scan continues past the cache cutoff — counting, not
/// compositing — so the outcome also carries the exact counts the plain
/// compositor would have produced for this pixel.
pub fn composite_pixel_cached_ex(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut RadianceCache,
    record_uncached: bool,
) -> ([f32; 3], PixelOutcome) {
    composite_pixel_cached_generic(splats, px, py, k, bank, record_uncached)
}

/// The per-pixel cache endpoint the compositor talks to — one tile's
/// end of the topology seam. Private scope is a bank; shared scope is a
/// frozen bank + the session's delta overlay/log.
trait PixelCache {
    /// Query a tag: the cached RGB plus provenance (`true` = served
    /// from the shared frozen snapshot).
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)>;
    /// Record a fully-composited value under its tag.
    fn store(&mut self, ids: &[u32], value: [f32; 3]);
    /// Note an uncacheable short ray.
    fn short_ray(&mut self);
}

impl PixelCache for RadianceCache {
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)> {
        self.lookup(ids).map(|v| (v, false))
    }

    fn store(&mut self, ids: &[u32], value: [f32; 3]) {
        self.insert(ids, value);
    }

    fn short_ray(&mut self) {
        self.stats.short_rays += 1;
    }
}

/// One tile's shared-scope cache endpoint: frozen snapshot bank +
/// session-private overlay bank + the delta's insertion log (with its
/// set-level compaction cursor) and stats.
struct SharedBank<'a> {
    frozen: &'a RadianceCache,
    overlay: &'a mut RadianceCache,
    log: &'a mut Vec<LoggedInsert>,
    last_in_set: &'a mut HashMap<(u32, u32), u32>,
    stats: &'a mut CacheStats,
    group: u32,
}

impl PixelCache for SharedBank<'_> {
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)> {
        self.stats.lookups += 1;
        // The session's own inserts are freshest: overlay first.
        if let Some(v) = self.overlay.probe_touch(ids) {
            self.stats.hits += 1;
            return Some((v, false));
        }
        if let Some(v) = self.frozen.probe(ids) {
            self.stats.hits += 1;
            self.stats.snapshot_hits += 1;
            return Some((v, true));
        }
        None
    }

    fn store(&mut self, ids: &[u32], value: [f32; 3]) {
        let mut rec = LoggedInsert {
            group: self.group,
            k: ids.len() as u8,
            ids: [0; MAX_SIG_K],
            value,
        };
        rec.ids[..ids.len()].copy_from_slice(ids);
        // Set-level net-effect coalescing: when the most recent insert
        // into this (group, set) carries the same tag, replaying
        // [X=a, <other-set inserts>, X=b] is state-identical to
        // replaying [X=b at X=a's position, <other-set inserts>] —
        // inserts into other sets never touch this set's ways or pLRU
        // bits, and the later insert is an in-place update touching
        // exactly the way the earlier one placed (X cannot be evicted
        // in between: nothing else landed in its set). So the earlier
        // entry absorbs the new value, exactly — `tests` pins bitwise
        // replay equivalence. Re-misses of the same hot tags across an
        // epoch's frames (the dominant log growth) collapse to one
        // entry per tag run, bounding delta memory by tag alternations
        // per touched set rather than the epoch's miss count.
        let set = self.overlay.set_index(ids) as u32;
        let key = (self.group, set);
        let coalesced = match self.last_in_set.get(&key) {
            Some(&idx) => {
                let last = &mut self.log[idx as usize];
                if last.k == rec.k && last.ids == rec.ids {
                    last.value = rec.value;
                    true
                } else {
                    false
                }
            }
            None => false,
        };
        if !coalesced {
            self.last_in_set.insert(key, self.log.len() as u32);
            self.log.push(rec);
        }
        match self.overlay.insert_tracked(ids, value) {
            InsertOutcome::Updated => {}
            InsertOutcome::Filled => self.stats.inserts += 1,
            InsertOutcome::Evicted => {
                self.stats.inserts += 1;
                self.stats.evictions += 1;
            }
        }
    }

    fn short_ray(&mut self) {
        self.stats.short_rays += 1;
    }
}

/// The compositing loop shared by both topologies — identical math and
/// control flow to the original private-path compositor; only the cache
/// endpoint is generic.
fn composite_pixel_cached_generic<C: PixelCache>(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut C,
    record_uncached: bool,
) -> ([f32; 3], PixelOutcome) {
    let mut c = [0.0f32; 3];
    let mut t = 1.0f32;
    let mut iterated = 0u32;
    let mut significant = 0u32;
    let mut sig_ids = [0u32; MAX_SIG_K];
    let mut sig_n = 0usize;
    let mut queried = false;

    for (si, s) in splats.iter().enumerate() {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        if sig_n < k {
            sig_ids[sig_n] = s.id;
            sig_n += 1;
        }
        significant += 1;
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            // Terminated before the cache query resolved: value is final
            // and identical to the uncached pipeline's.
            return (
                c,
                PixelOutcome {
                    iterated,
                    significant,
                    hit: false,
                    snapshot_hit: false,
                    uncached_iterated: iterated,
                    uncached_significant: significant,
                },
            );
        }
        let w = alpha * t;
        c[0] += w * s.color[0];
        c[1] += w * s.color[1];
        c[2] += w * s.color[2];
        t = test_t;

        // Once the alpha-record fills, query the cache (paper step 4).
        if sig_n == k && !queried {
            queried = true;
            if let Some((value, from_snapshot)) = bank.query(&sig_ids[..k]) {
                // Hit: the cached RGB replaces the remaining integration.
                // When recording, keep scanning (count-only, same math
                // and transmittance) to recover the uncached counts the
                // plain compositor would have produced.
                let (ui, us) = if record_uncached {
                    scan_uncached(&splats[si + 1..], px, py, t, iterated, significant)
                } else {
                    (iterated, significant)
                };
                return (
                    value,
                    PixelOutcome {
                        iterated,
                        significant,
                        hit: true,
                        snapshot_hit: from_snapshot,
                        uncached_iterated: ui,
                        uncached_significant: us,
                    },
                );
            }
        }
    }

    // Miss (or short ray): full value computed; update the cache.
    if queried {
        bank.store(&sig_ids[..k], c);
    } else {
        bank.short_ray();
    }
    (
        c,
        PixelOutcome {
            iterated,
            significant,
            hit: false,
            snapshot_hit: false,
            uncached_iterated: iterated,
            uncached_significant: significant,
        },
    )
}

/// Continue a pixel's tile-list scan past a cache hit without
/// accumulating color: replicates the plain compositor's control flow
/// (fast reject, alpha test, early termination) so the returned counts
/// are bit-identical to an uncached stats pass.
fn scan_uncached(
    rest: &[GatheredSplat],
    px: f32,
    py: f32,
    mut t: f32,
    mut iterated: u32,
    mut significant: u32,
) -> (u32, u32) {
    for s in rest {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        significant += 1;
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            break;
        }
        t = test_t;
    }
    (iterated, significant)
}

/// The radiance-cached [`RasterBackend`]: the RC raster stage of the
/// frame loop, carrying per-session cache state across frames — a
/// private [`GroupedRadianceCache`] or a shared snapshot + delta,
/// behind the [`CacheView`] topology seam.
pub struct CachedRaster {
    view: CacheView,
    record_uncached: bool,
}

impl CachedRaster {
    /// Private scope: the session owns its cache outright (today's
    /// behavior, bit-for-bit). `record_uncached` asks every frame for
    /// single-pass uncached per-pixel counts (required by cost models
    /// whose `needs_uncached_stats` is true, e.g. the GPU warp model).
    pub fn new(cache: GroupedRadianceCache, record_uncached: bool) -> Self {
        CachedRaster { view: CacheView::private(cache), record_uncached }
    }

    /// Shared scope: render against a pool snapshot, logging inserts
    /// into a fresh session delta.
    pub fn shared(snapshot: Arc<CacheSnapshot>, record_uncached: bool) -> Self {
        CachedRaster { view: CacheView::shared(snapshot), record_uncached }
    }

    /// The underlying cache view (for occupancy/stats inspection).
    pub fn view(&self) -> &CacheView {
        &self.view
    }
}

impl RasterBackend for CachedRaster {
    fn label(&self) -> &'static str {
        "radiance-cached"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        let out = rasterize_cached_view(
            projected,
            bins,
            width,
            height,
            &mut self.view,
            self.record_uncached,
        );
        let swap_bytes = self.view.swap_bytes_for_frame();
        RasterFrame {
            image: out.image,
            work: RasterWork {
                width,
                height,
                consumed: out.outcomes.iter().map(|o| o.iterated).collect(),
                significant: out.outcomes.iter().map(|o| o.significant).collect(),
                uncached: out.uncached,
                cache_outcomes: Some(
                    out.outcomes
                        .iter()
                        .map(|o| match (o.hit, o.snapshot_hit) {
                            (true, true) => 3u8,
                            (true, false) => 2,
                            _ => 1,
                        })
                        .collect(),
                ),
                cache: out.stats,
                cache_shared: self.view.is_shared(),
                swap_bytes,
            },
        }
    }

    fn take_cache_delta(&mut self) -> Option<CacheDelta> {
        self.view.take_delta()
    }

    fn install_cache_snapshot(&mut self, snapshot: Arc<CacheSnapshot>, sharers: usize) {
        self.view.install_snapshot(snapshot, sharers);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Intrinsics, Pose};
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::pipeline::raster::{rasterize, RasterConfig};
    use crate::pipeline::sort::bin_and_sort;
    use crate::scene::synth::test_scene;

    #[test]
    fn index_tag_deterministic_and_sensitive() {
        let cache = RadianceCache::paper_default(5);
        let ids = [100, 200, 300, 400, 500];
        let (s1, t1) = cache.index_tag(&ids);
        let (s2, t2) = cache.index_tag(&ids);
        assert_eq!((s1, t1), (s2, t2));
        let ids2 = [100, 200, 300, 400, 1000]; // differs above bit 3
        // Changing one ID changes index and/or tag.
        assert_ne!(cache.index_tag(&ids2), (s1, t1));
        assert!(s1 < CACHE_SETS);
    }

    #[test]
    fn id_bits_outside_window_ignored() {
        // Bits below CACHE_ID_LO_BIT (=3) are not part of index/tag:
        // matches the paper's 3rd..18th-LSB field.
        let cache = RadianceCache::paper_default(2);
        let a = cache.index_tag(&[0b1000, 0b10000]);
        let b = cache.index_tag(&[0b1001, 0b10111]);
        assert_eq!(a, b);
    }

    #[test]
    fn lookup_insert_roundtrip() {
        let mut cache = RadianceCache::paper_default(5);
        let ids = [1 << 3, 2 << 3, 3 << 3, 4 << 3, 5 << 3];
        assert!(cache.lookup(&ids).is_none());
        cache.insert(&ids, [0.1, 0.2, 0.3]);
        assert_eq!(cache.lookup(&ids), Some([0.1, 0.2, 0.3]));
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(cache.stats.lookups, 2);
    }

    #[test]
    fn plru_evicts_cold_way() {
        let mut cache = RadianceCache::new(4, 2, 1);
        // 5 tags mapping to the same set (set bits = lowest index bit of
        // the 16-bit field; craft IDs that share it).
        let mk = |i: u32| [((i << 1) | 0) << CACHE_ID_LO_BIT];
        for i in 0..4 {
            cache.insert(&mk(i), [i as f32; 3]);
        }
        assert_eq!(cache.occupancy(), 4);
        // Touch tags 1..3 so tag 0 becomes the pLRU victim.
        for i in 1..4 {
            assert!(cache.lookup(&mk(i)).is_some());
        }
        cache.insert(&mk(9), [9.0; 3]);
        assert_eq!(cache.stats.evictions, 1);
        assert!(cache.lookup(&mk(0)).is_none(), "cold way should be evicted");
        assert!(cache.lookup(&mk(9)).is_some());
    }

    #[test]
    fn flush_empties() {
        let mut cache = RadianceCache::paper_default(3);
        cache.insert(&[8, 16, 24], [0.5; 3]);
        assert_eq!(cache.occupancy(), 1);
        cache.flush();
        assert_eq!(cache.occupancy(), 0);
        assert!(cache.lookup(&[8, 16, 24]).is_none());
    }

    /// Test scene with the oversized-Gaussian tail clamped — the regime
    /// cache-aware fine-tuning produces (Sec. 3.3); the unclamped tail is
    /// exercised by the fig13/fig21 harnesses instead.
    fn clamped_scene(seed: u64, n: usize) -> crate::scene::GaussianScene {
        let mut scene = test_scene(seed, n);
        let cap = 0.06; // ~5x the median scale for SyntheticSmall
        for s in scene.scale.iter_mut() {
            s.x = s.x.min(cap);
            s.y = s.y.min(cap);
            s.z = s.z.min(cap);
        }
        scene
    }

    fn render_setup() -> (crate::pipeline::project::ProjectedScene, crate::pipeline::sort::TileBins, Intrinsics)
    {
        let scene = clamped_scene(77, 4000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        (p, bins, intr)
    }

    #[test]
    fn cold_cache_first_frame_stays_faithful() {
        // Frame 0: the cache starts empty but fills as pixels complete,
        // so *intra-frame* hits occur between pixels sharing the same
        // initial significant Gaussians (the paper's ray-similarity
        // insight applied within a frame). Quality must stay near-exact.
        let (p, bins, intr) = render_setup();
        let plain = rasterize(&p, &bins, intr.width, intr.height, &RasterConfig::default());
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let cached = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        let q = crate::metrics::psnr(&plain.image, &cached.image);
        assert!(q > 28.0, "first-frame RC quality {q} dB");
        // Miss pixels must be bit-exact: check a hit-free pixel.
        let miss_idx = cached
            .outcomes
            .iter()
            .position(|o| !o.hit)
            .expect("some pixel missed");
        let (x, y) = (miss_idx % intr.width, miss_idx / intr.width);
        assert_eq!(plain.image.at(x, y), cached.image.at(x, y));
    }

    #[test]
    fn second_frame_hits_and_saves_work() {
        let (p, bins, intr) = render_setup();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let first = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        let second = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        assert!(second.stats.hit_rate() > 0.5, "hit rate {}", second.stats.hit_rate());
        // Identical pose -> replay reproduces the first frame closely
        // (hit pixels return cached values; those were themselves RC
        // outputs, so the images converge rather than match bitwise).
        let q = crate::metrics::psnr(&first.image, &second.image);
        assert!(q > 38.0, "same-pose replay diverged: {q} dB");
        // Work saved: hits iterate less than the first pass.
        let w1: u64 = first.outcomes.iter().map(|o| o.iterated as u64).sum();
        let w2: u64 = second.outcomes.iter().map(|o| o.iterated as u64).sum();
        assert!(w2 < w1, "cached pass did not save work: {w1} -> {w2}");
    }

    #[test]
    fn nearby_pose_still_hits_often() {
        let scene = clamped_scene(77, 4000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose1 = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let pose2 = Pose::look_at(Vec3::new(0.01, 0.002, -4.0), Vec3::ZERO);
        let p1 = project(&scene, &pose1, &intr, 0.2, 100.0, 0.0);
        let b1 = bin_and_sort(&p1, &intr, 16, 0.0);
        let p2 = project(&scene, &pose2, &intr, 0.2, 100.0, 0.0);
        let b2 = bin_and_sort(&p2, &intr, 16, 0.0);
        let mut cache = GroupedRadianceCache::new(b1.tiles_x, b1.tiles_y, 5);
        rasterize_cached(&p1, &b1, intr.width, intr.height, &mut cache);
        let out = rasterize_cached(&p2, &b2, intr.width, intr.height, &mut cache);
        assert!(
            out.stats.hit_rate() > 0.3,
            "nearby pose hit rate {}",
            out.stats.hit_rate()
        );
        // Quality: overall PSNR stays high, and the *median* hit-pixel
        // color error reproduces the paper's Fig. 12 claim (average color
        // difference ~0.5-1.0 out of 255 for k=5). The tail is heavier
        // than in trained scenes (DESIGN.md §8: synthetic statistics),
        // which is what cache-aware fine-tuning addresses.
        let exact = rasterize(&p2, &b2, intr.width, intr.height, &RasterConfig::default());
        let psnr = crate::metrics::psnr(&exact.image, &out.image);
        assert!(psnr > 27.0, "cached quality {psnr} dB");
        let mut diffs: Vec<f32> = out
            .outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| o.hit)
            .map(|(i, _)| {
                let (x, y) = (i % intr.width, i / intr.width);
                let a = out.image.at(x, y);
                let b = exact.image.at(x, y);
                ((a[0] - b[0]).abs() + (a[1] - b[1]).abs() + (a[2] - b[2]).abs()) / 3.0
                    * 255.0
            })
            .collect();
        diffs.sort_by(f32::total_cmp);
        let median = diffs[diffs.len() / 2];
        assert!(median < 3.0, "median hit color diff {median}/255 (paper: <1.0)");
    }

    #[test]
    fn single_pass_uncached_stats_match_two_pass() {
        // The RC-GPU cost model used to re-rasterize the whole frame
        // uncached just to recover warp aggregates; the single-pass
        // recording must reproduce that second pass bit-for-bit.
        let (p, bins, intr) = render_setup();
        let plain_cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let plain = rasterize(&p, &bins, intr.width, intr.height, &plain_cfg);
        let plain_stats = plain.stats.unwrap();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        // Cold pass (intra-frame hits) and warm pass (heavy hits): the
        // recorded uncached counts must match the plain pass in both.
        for pass in 0..2 {
            let out =
                rasterize_cached_ex(&p, &bins, intr.width, intr.height, &mut cache, true);
            let unc = out.uncached.expect("recording requested");
            assert_eq!(unc.iterated, plain_stats.iterated, "pass {pass} iterated");
            assert_eq!(unc.significant, plain_stats.significant, "pass {pass} significant");
            if pass == 1 {
                assert!(out.stats.hits > 0, "warm pass should hit");
            }
        }
    }

    #[test]
    fn unrecorded_pass_reports_actual_counts() {
        let (p, bins, intr) = render_setup();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let out = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        assert!(out.uncached.is_none());
        for o in &out.outcomes {
            assert_eq!(o.uncached_iterated, o.iterated);
            assert_eq!(o.uncached_significant, o.significant);
        }
    }

    #[test]
    fn smaller_k_hits_more() {
        let (p, bins, intr) = render_setup();
        let mut rates = Vec::new();
        for k in [2usize, 5, 8] {
            let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, k);
            rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
            let out = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
            rates.push(out.stats.hit_rate());
        }
        // Fig. 24: hit rate falls as the alpha-record grows. Same-pose
        // replay saturates near 100%, so only the endpoints separate
        // cleanly here; the full monotone sweep is fig24's harness (which
        // uses a moving trajectory).
        assert!(rates[0] > rates[2], "rates {rates:?}");
        assert!(rates[0] > 0.9, "k=2 same-pose replay should saturate: {rates:?}");
    }

    #[test]
    fn groups_are_independent_banks() {
        let mut cache = GroupedRadianceCache::new(8, 8, 5);
        assert_eq!(cache.num_banks(), 4);
        let ids = [8, 16, 24, 32, 40];
        cache.bank_for_tile_mut(0, 0).insert(&ids, [1.0; 3]);
        assert!(cache.bank_for_tile_mut(0, 0).lookup(&ids).is_some());
        assert!(cache.bank_for_tile_mut(7, 7).lookup(&ids).is_none());
        // The read accessor probes without exclusive access — the split
        // that makes Arc-shared snapshots possible at all.
        assert!(cache.bank_for_tile(0, 0).probe(&ids).is_some());
        assert!(cache.bank_for_tile(7, 7).probe(&ids).is_none());
    }

    #[test]
    fn swap_traffic_grows_with_occupancy() {
        let mut cache = GroupedRadianceCache::new(4, 4, 5);
        assert_eq!(cache.swap_traffic_bytes(), 0);
        cache.bank_for_tile_mut(0, 0).insert(&[8, 16, 24, 32, 40], [0.5; 3]);
        assert_eq!(cache.swap_traffic_bytes(), 26); // 13 B x 2 directions
    }

    #[test]
    fn stats_merge_and_hit_rate_on_empty_and_partial() {
        // Empty stats: no lookups -> defined 0.0 hit rate, and merging
        // an empty into an empty stays empty.
        let mut a = CacheStats::default();
        assert_eq!(a.hit_rate(), 0.0);
        a.merge(&CacheStats::default());
        assert_eq!(a, CacheStats::default());
        // Partial: merge accumulates every field and hit_rate follows.
        let b = CacheStats {
            lookups: 8,
            hits: 2,
            snapshot_hits: 1,
            inserts: 6,
            evictions: 1,
            short_rays: 3,
        };
        a.merge(&b);
        assert_eq!(a, b);
        assert_eq!(a.hit_rate(), 0.25);
        let c = CacheStats { lookups: 8, hits: 6, ..CacheStats::default() };
        a.merge(&c);
        assert_eq!(a.lookups, 16);
        assert_eq!(a.hits, 8);
        assert_eq!(a.snapshot_hits, 1);
        assert_eq!(a.inserts, 6);
        assert_eq!(a.hit_rate(), 0.5);
        // Merging empty into partial changes nothing.
        let before = a;
        a.merge(&CacheStats::default());
        assert_eq!(a, before);
    }

    #[test]
    fn frozen_probe_never_mutates() {
        let mut bank = RadianceCache::paper_default(5);
        let ids = [8, 16, 24, 32, 40];
        bank.insert(&ids, [0.25; 3]);
        let stats = bank.stats;
        for _ in 0..3 {
            assert_eq!(bank.probe(&ids), Some([0.25; 3]));
            assert_eq!(bank.probe(&[48, 56, 64, 72, 80]), None);
        }
        assert_eq!(bank.stats, stats, "probe must not touch stats");
        assert_eq!(bank.occupancy(), 1);
    }

    fn geom(tiles: usize, k: usize) -> CacheGeometry {
        CacheGeometry { tiles_x: tiles, tiles_y: tiles, k }
    }

    #[test]
    fn shared_view_overlay_snapshot_precedence_and_provenance() {
        // Snapshot holds tag A; the session inserts tag B and re-inserts
        // A with a fresher value: lookups must prefer the overlay, and
        // provenance must tell snapshot hits from own hits.
        let g = geom(4, 5);
        let ids_a = [8u32, 16, 24, 32, 40];
        let ids_b = [48u32, 56, 64, 72, 80];
        let mut base = CacheSnapshot::empty(g);
        base.cache.bank_for_tile_mut(0, 0).insert(&ids_a, [0.1; 3]);
        let snap = Arc::new(base);
        let mut view = CacheView::shared(snap.clone());
        let CacheView::Shared { snapshot, delta, .. } = &mut view else { unreachable!() };
        let probe = |snapshot: &CacheSnapshot, delta: &mut CacheDelta, ids: &[u32]| {
            let group = delta.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snapshot.cache.bank_for_tile(0, 0),
                overlay: delta.overlay.bank_for_tile_mut(0, 0),
                log: &mut delta.log,
                last_in_set: &mut delta.last_in_set,
                stats: &mut delta.stats,
                group,
            };
            bank.query(ids)
        };
        assert_eq!(probe(&**snapshot, delta, &ids_a), Some(([0.1; 3], true)), "snapshot hit");
        assert_eq!(probe(&**snapshot, delta, &ids_b), None);
        // Session inserts B and overrides A.
        {
            let group = delta.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snapshot.cache.bank_for_tile(0, 0),
                overlay: delta.overlay.bank_for_tile_mut(0, 0),
                log: &mut delta.log,
                last_in_set: &mut delta.last_in_set,
                stats: &mut delta.stats,
                group,
            };
            bank.store(&ids_b, [0.5; 3]);
            bank.store(&ids_a, [0.9; 3]);
        }
        assert_eq!(probe(&**snapshot, delta, &ids_b), Some(([0.5; 3], false)), "own hit");
        assert_eq!(probe(&**snapshot, delta, &ids_a), Some(([0.9; 3], false)), "overlay wins");
        let s = delta.stats();
        assert_eq!(s.lookups, 5);
        assert_eq!(s.hits, 3);
        assert_eq!(s.snapshot_hits, 1);
        assert_eq!(s.inserts, 2);
        assert_eq!(delta.len(), 2, "every store is logged, updates included");
        // The snapshot itself never changed.
        assert_eq!(snap.occupancy(), 1);
        assert_eq!(snap.lookup(0, 0, &ids_a), Some([0.1; 3]));
    }

    #[test]
    fn compacted_log_replays_bitwise_identically_to_uncompacted() {
        // The set-level coalescing contract: a compacted delta log,
        // replayed into a (non-empty) snapshot, must produce a cache
        // whose entries AND pLRU state match an uncompacted
        // insert-by-insert replay of the exact store sequence — while
        // the log itself stays bounded by tag alternations per set.
        let g = geom(4, 2);
        // k = 2, 1024 sets => 5 index bits per ID: `field(hi, lo)`
        // places `lo` in the set-index bits and `hi` in the tag bits,
        // so same-`lo` ids share a set and same-`hi` ids share a tag.
        let field = |hi: u32, lo: u32| ((hi << 5) | lo) << 3;
        let tag_a = [field(0, 1), field(0, 2)]; // set S1
        let tag_b = [field(1, 1), field(0, 2)]; // set S1, different tag
        let tag_c = [field(0, 3), field(0, 4)]; // a different set S2

        // Non-empty initial state: the snapshot already holds tag A.
        let mut base = CacheSnapshot::empty(g);
        base.cache.bank_for_tile_mut(0, 0).insert(&tag_a, [0.05; 3]);
        let snap = Arc::new(base);

        // The store sequence, with same-set repeats (fold), an
        // other-set interleave (must not break the fold), and a tag
        // alternation (must NOT fold).
        let seq: Vec<([u32; 2], [f32; 3])> = vec![
            (tag_a, [0.1; 3]),
            (tag_a, [0.2; 3]), // folds into the previous entry
            (tag_b, [0.3; 3]), // same set, new tag: alternation
            (tag_c, [0.4; 3]), // other set
            (tag_a, [0.5; 3]), // set's last insert is B: no fold
            (tag_c, [0.6; 3]), // folds across the set boundary above
            (tag_a, [0.7; 3]), // folds into the 0.5 entry: C was other-set
        ];

        let mut delta = CacheDelta::new(g);
        // Uncompacted reference: every store applied in true order.
        let mut reference = snap.cache.clone();
        {
            let group = delta.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snap.cache.bank_for_tile(0, 0),
                overlay: delta.overlay.bank_for_tile_mut(0, 0),
                log: &mut delta.log,
                last_in_set: &mut delta.last_in_set,
                stats: &mut delta.stats,
                group,
            };
            for (ids, v) in &seq {
                bank.store(ids, *v);
                reference.bank_for_tile_mut(0, 0).insert_tracked(ids, *v);
            }
        }
        assert_eq!(delta.len(), 4, "7 stores must compact to 4 log entries");

        let mut merged = snap.cache.clone();
        merged.replay(&delta.log);
        assert!(
            merged.state_eq(&reference),
            "compacted replay diverged from uncompacted replay"
        );
        // And the values landed: the folds kept the *last* value.
        assert_eq!(merged.bank_for_tile(0, 0).probe(&tag_a), Some([0.7; 3]));
        assert_eq!(merged.bank_for_tile(0, 0).probe(&tag_b), Some([0.3; 3]));
        assert_eq!(merged.bank_for_tile(0, 0).probe(&tag_c), Some([0.6; 3]));

        // The ordered multi-session merge stays equivalent too:
        // session 1's (compacted) delta replayed before session 2's
        // must match the sequential uncompacted replay of both.
        let mk = |stores: &[([u32; 2], [f32; 3])], reference: &mut GroupedRadianceCache| {
            let mut d = CacheDelta::new(g);
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snap.cache.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            for (ids, v) in stores {
                bank.store(ids, *v);
                reference.bank_for_tile_mut(0, 0).insert_tracked(ids, *v);
            }
            d
        };
        let mut reference = snap.cache.clone();
        let d1 = mk(&[(tag_a, [0.11; 3]), (tag_a, [0.12; 3])], &mut reference);
        let d2 = mk(&[(tag_b, [0.21; 3]), (tag_a, [0.22; 3])], &mut reference);
        assert_eq!(d1.len(), 1, "session 1's run of A folds to one entry");
        let mut merged = snap.cache.clone();
        merged.replay(&d1.log);
        merged.replay(&d2.log);
        assert!(merged.state_eq(&reference), "ordered merge equivalence broke");

        // A detached delta starts with a fresh compaction cursor.
        let mut d = CacheDelta::new(g);
        {
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snap.cache.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            bank.store(&tag_a, [0.9; 3]);
        }
        let mut view = CacheView::Shared {
            snapshot: snap.clone(),
            delta: d,
            pending_snapshot_bytes: 0,
        };
        let taken = view.take_delta().unwrap();
        assert_eq!(taken.len(), 1);
        let CacheView::Shared { delta, .. } = &view else { unreachable!() };
        assert!(delta.is_empty() && delta.last_in_set.is_empty());
    }

    #[test]
    fn hub_merges_deltas_in_session_index_order() {
        let g = geom(4, 5);
        let hub = CacheHub::new();
        let empty = hub.snapshot_for(g);
        assert_eq!(empty.epoch(), 0);
        let ids = [8u32, 16, 24, 32, 40];
        // Two sessions insert the same tag with different values: the
        // later session's insert must win (session-index replay order).
        let mk_delta = |value: [f32; 3]| {
            let mut d = CacheDelta::new(g);
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: empty.cache.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            bank.store(&ids, value);
            d
        };
        hub.merge_in_order(vec![mk_delta([0.1; 3]), mk_delta([0.7; 3])]);
        let merged = hub.snapshot_for(g);
        assert_eq!(merged.epoch(), 1);
        assert_eq!(merged.lookup(0, 0, &ids), Some([0.7; 3]), "later session wins");
        assert_eq!(merged.occupancy(), 1);
        // Reversed order flips the winner — order is the contract.
        let hub2 = CacheHub::new();
        hub2.merge_in_order(vec![mk_delta([0.7; 3]), mk_delta([0.1; 3])]);
        assert_eq!(hub2.snapshot_for(g).lookup(0, 0, &ids), Some([0.1; 3]));
        // An all-empty merge keeps the snapshot (same Arc, same epoch).
        let before = hub.snapshot_for(g);
        hub.merge_in_order(vec![CacheDelta::new(g)]);
        assert!(Arc::ptr_eq(&before, &hub.snapshot_for(g)));
    }

    #[test]
    fn multi_geometry_merge_publishes_deterministically() {
        // Pins the publish contract behind the dirty-map BTreeMap swap:
        // a merge touching several geometries at once must produce
        // snapshots that are a pure function of the delta sequence —
        // identical across repeated merges into fresh hubs — with
        // last-session-wins within each geometry and untouched
        // geometries keeping their exact Arc.
        let ga = geom(4, 5);
        let gb = geom(8, 5);
        let gc = geom(2, 5); // never dirtied
        let ids = [8u32, 16, 24, 32, 40];
        let mk_delta = |g: CacheGeometry, value: [f32; 3]| {
            let mut d = CacheDelta::new(g);
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let frozen = GroupedRadianceCache::new(g.tiles_x, g.tiles_y, g.k);
            let mut bank = SharedBank {
                frozen: frozen.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            bank.store(&ids, value);
            d
        };
        // Interleave geometries so the dirty map sees gb before ga is
        // finished — publish order must still be canonical.
        let run = || {
            let hub = CacheHub::new();
            let untouched = hub.snapshot_for(gc);
            hub.merge_in_order(vec![
                mk_delta(ga, [0.1; 3]),
                mk_delta(gb, [0.4; 3]),
                mk_delta(ga, [0.9; 3]),
            ]);
            assert!(
                Arc::ptr_eq(&untouched, &hub.snapshot_for(gc)),
                "untouched geometry must keep its Arc"
            );
            assert_eq!(hub.snapshot_for(gc).epoch(), 0);
            (hub.snapshot_for(ga), hub.snapshot_for(gb))
        };
        let (a1, b1) = run();
        let (a2, b2) = run();
        assert_eq!(a1.epoch(), 1);
        assert_eq!(b1.epoch(), 1);
        assert_eq!(a1.lookup(0, 0, &ids), Some([0.9; 3]), "last session wins");
        assert_eq!(b1.lookup(0, 0, &ids), Some([0.4; 3]));
        assert!(a1.cache.state_eq(&a2.cache), "merge must be a pure function of deltas");
        assert!(b1.cache.state_eq(&b2.cache));
        assert_eq!(a1.epoch(), a2.epoch());
        assert_eq!(b1.epoch(), b2.epoch());
    }

    #[test]
    fn shared_swap_traffic_charged_once_per_snapshot_install() {
        let g = geom(4, 5);
        let mut base = CacheSnapshot::empty(g);
        // Leading IDs spread across sets (low index bits vary), so all
        // ten inserts coexist without evictions.
        for i in 0..10u32 {
            base.cache.bank_for_tile_mut(0, 0).insert(&[(i + 1) << 3, 16, 24, 32, 40], [0.5; 3]);
        }
        assert_eq!(base.occupancy(), 10);
        let bytes = base.swap_traffic_bytes() as u64;
        assert_eq!(bytes, 10 * 13 * 2);
        let snap = Arc::new(base);

        // Private scope: the whole occupancy is charged EVERY frame.
        let mut private = CacheView::private(snap.cache.clone());
        assert_eq!(private.swap_bytes_for_frame(), bytes);
        assert_eq!(private.swap_bytes_for_frame(), bytes);

        // Shared scope: the snapshot share is charged once per install,
        // then only the session's own delta working set.
        let mut view = CacheView::shared(snap.clone());
        assert_eq!(view.swap_bytes_for_frame(), bytes, "fresh attach reloads once");
        assert_eq!(view.swap_bytes_for_frame(), 0, "steady frames charge only the delta");
        // Re-installing the same snapshot (sharer refresh) is free.
        view.install_snapshot(snap.clone(), 4);
        assert_eq!(view.swap_bytes_for_frame(), 0);
        // A new merged snapshot charges the amortized share only.
        let next = Arc::new(CacheSnapshot { cache: snap.cache.clone(), epoch: snap.epoch() + 1 });
        view.install_snapshot(next, 4);
        assert_eq!(view.swap_bytes_for_frame(), bytes.div_ceil(4));
        assert_eq!(view.swap_bytes_for_frame(), 0);
    }

    #[test]
    fn shared_rasterization_hits_across_sessions_after_merge() {
        // Session A renders a frame (cold snapshot), the pool merges its
        // delta, session B renders the same pose against the merged
        // snapshot: B's first frame must hit where A inserted, with
        // snapshot provenance — the cross-session redundancy win.
        let (p, bins, intr) = render_setup();
        let g = CacheGeometry { tiles_x: bins.tiles_x, tiles_y: bins.tiles_y, k: 5 };
        let hub = CacheHub::new();
        let mut a = CacheView::shared(hub.snapshot_for(g));
        let cold =
            rasterize_cached_view(&p, &bins, intr.width, intr.height, &mut a, false);
        assert_eq!(cold.stats.snapshot_hits, 0, "cold snapshot cannot hit");
        hub.merge_in_order(vec![a.take_delta().unwrap()]);

        let mut b = CacheView::shared(hub.snapshot_for(g));
        let warm =
            rasterize_cached_view(&p, &bins, intr.width, intr.height, &mut b, false);
        assert!(
            warm.stats.snapshot_hits > 0,
            "cross-session hits expected: {:?}",
            warm.stats
        );
        assert!(warm.stats.hit_rate() > cold.stats.hit_rate());
        // Provenance is consistent between stats and outcomes.
        let snap_hits =
            warm.outcomes.iter().filter(|o| o.snapshot_hit).count() as u64;
        assert_eq!(snap_hits, warm.stats.snapshot_hits);
        // B hits at least as often as a private second pass over the
        // same pose would, since A's inserts cover the same rays.
        assert!(warm.stats.hit_rate() > 0.5, "hit rate {}", warm.stats.hit_rate());
    }
}

