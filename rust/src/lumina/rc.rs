//! RC — Radiance Caching (paper Sec. 3.2) and the LuminCache-faithful
//! cache organization (paper Sec. 4/5).
//!
//! Key insight: two rays that intersect the same sequence of initial
//! *significant* Gaussians (alpha > 1/255) almost surely produce the same
//! pixel value. Per pixel, rasterization runs only until the first k
//! significant Gaussians are identified; their IDs form a cache tag. On a
//! hit, the cached RGB replaces the remaining color integration; on a
//! miss, integration completes and the cache is updated.
//!
//! The cache geometry mirrors LuminCache (Sec. 5): 4-way set-associative,
//! 1024 sets, tag/index built from bits [3..19) of each of the k Gaussian
//! IDs (paper: "3rd to 18th least significant bits", 16 bits per ID, 10
//! bytes of tag material for k=5), tree pseudo-LRU replacement, and
//! contents partitioned per 4x4-tile group (64x64 px) with save/flush/
//! reload semantics between groups (modeled functionally as per-group
//! sub-caches; the traffic is charged by the simulator).
//!
//! **Cache topology** (DESIGN.md §4): nearby viewers produce the same
//! first-k tags, so a pool can serve one viewer's miss from another's
//! earlier insert. Ownership is a seam ([`CacheView`]) with two
//! implementations: `private` — the session owns a
//! [`GroupedRadianceCache`] outright (today's behavior, bit-for-bit) —
//! and `shared` — every session of a pool reads one frozen, immutable
//! [`CacheSnapshot`] for the whole epoch while logging its own inserts
//! into a private [`CacheDelta`]; at epoch boundaries the pool replays
//! the deltas into the next snapshot **in session-index order**
//! ([`CacheHub::merge_in_order`]), so shared-scope output is bitwise
//! identical at any thread count and pipeline depth.
//!
//! A third scope, `world`, replaces the screen-tile tag with a
//! world-space hash key (quantized first-significant-Gaussian position +
//! view-direction bucket, distance-scaled cell sizes), so entries stay
//! meaningful across poses, tiers, and resolutions — see
//! [`WorldRadianceCache`] and DESIGN.md "World-space radiance cache".

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::constants::{
    CACHE_ID_BITS, CACHE_ID_LO_BIT, CACHE_SETS, CACHE_TILE_GROUP, CACHE_WAYS, T_EPS,
};
use crate::math::Vec3;
use crate::pipeline::image::Image;
use crate::pipeline::project::ProjectedScene;
use crate::pipeline::raster::{gather_tile, splat_alpha, GatheredSplat, RasterStats, MAX_SIG_K};
use crate::pipeline::sort::TileBins;
use crate::pipeline::stage::{RasterBackend, RasterFrame, RasterWork};
use crate::scene::GaussianScene;

/// Bytes one cache entry occupies in DRAM during a group save/reload:
/// 10 B tag material + 3 B RGB (paper Sec. 5).
pub const CACHE_ENTRY_BYTES: usize = 13;

/// One cache entry: packed high-bit tag + cached pixel RGB.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    tag: u128,
    value: [f32; 3],
}

/// What an insert did to the set it landed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InsertOutcome {
    /// Tag already present; value updated in place.
    Updated,
    /// Placed in a free way.
    Filled,
    /// Placed by evicting the pseudo-LRU victim.
    Evicted,
}

/// Running cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    /// Of `hits`, how many were served from the pool-shared frozen
    /// snapshot rather than the session's own inserts — the hit
    /// provenance that tells cross-session sharing apart from the
    /// private hit path (always 0 under private scope).
    pub snapshot_hits: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Pixels whose ray met fewer than k significant Gaussians
    /// (uncacheable; rendered fully).
    pub short_rays: u64,
    /// World-scope provenance: cells freed by the per-epoch lifetime
    /// decay sweep (always 0 under private/geometry-shared scope, where
    /// eviction is pLRU and counted in `evictions`).
    pub decay_evictions: u64,
    /// World-scope provenance: histogram of linear-probe chain lengths
    /// observed against the frozen world table — bucket `i` counts
    /// probes that examined `i + 1` slots (the last bucket saturates).
    /// All-zero under private/geometry-shared scope.
    pub probe_hist: [u64; PROBE_HIST_BUCKETS],
}

/// Buckets of [`CacheStats::probe_hist`] (chain lengths 1..=8, last
/// bucket saturating). Sized to cover any sane `pool.world_probe_len`.
pub const PROBE_HIST_BUCKETS: usize = 8;

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.snapshot_hits += o.snapshot_hits;
        self.inserts += o.inserts;
        self.evictions += o.evictions;
        self.short_rays += o.short_rays;
        self.decay_evictions += o.decay_evictions;
        for (a, b) in self.probe_hist.iter_mut().zip(&o.probe_hist) {
            *a += b;
        }
    }

    /// Record one frozen-table probe that examined `slots` slots.
    fn record_probe(&mut self, slots: u32) {
        let b = (slots.max(1) as usize - 1).min(PROBE_HIST_BUCKETS - 1);
        self.probe_hist[b] += 1;
    }

    /// Total frozen-table probes recorded (world scope only).
    pub fn probes_recorded(&self) -> u64 {
        self.probe_hist.iter().sum()
    }
}

/// A single LuminCache bank: N-way set-associative with tree pseudo-LRU.
#[derive(Debug, Clone)]
pub struct RadianceCache {
    ways: usize,
    sets: usize,
    k: usize,
    entries: Vec<Option<Entry>>,
    /// Per-set pseudo-LRU tree bits (3 bits for 4 ways, packed in u8).
    plru: Vec<u8>,
    pub stats: CacheStats,
}

impl RadianceCache {
    /// Paper-default geometry: 4 ways x 1024 sets, tag from k IDs.
    pub fn paper_default(k: usize) -> Self {
        Self::new(CACHE_WAYS, CACHE_SETS, k)
    }

    pub fn new(ways: usize, sets: usize, k: usize) -> Self {
        assert!(ways == 2 || ways == 4 || ways == 8, "plru tree supports 2/4/8 ways");
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!((1..=MAX_SIG_K).contains(&k));
        RadianceCache {
            ways,
            sets,
            k,
            entries: vec![None; ways * sets],
            plru: vec![0; sets],
            stats: CacheStats::default(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Build (set index, tag) from the first k significant Gaussian IDs.
    ///
    /// Per the paper (Fig. 16): the *lower* bits of each ID concatenate
    /// into the set index; the *higher* bits concatenate into the tag.
    /// IDs contribute bits [CACHE_ID_LO_BIT .. CACHE_ID_LO_BIT+16).
    fn index_tag(&self, ids: &[u32]) -> (usize, u128) {
        debug_assert_eq!(ids.len(), self.k);
        let index_bits = self.sets.trailing_zeros();
        let per_id = (index_bits as usize).div_ceil(self.k).max(1) as u32;
        let mut index: u64 = 0;
        let mut tag: u128 = 0;
        for &id in ids {
            let field = ((id >> CACHE_ID_LO_BIT) & ((1u32 << CACHE_ID_BITS) - 1)) as u64;
            let low = field & ((1u64 << per_id) - 1);
            let high = field >> per_id;
            index = (index << per_id) | low;
            tag = (tag << (CACHE_ID_BITS - per_id)) | high as u128;
        }
        ((index as usize) & (self.sets - 1), tag)
    }

    /// The set a tag indexes — the compaction key of the shared-scope
    /// insertion log.
    fn set_index(&self, ids: &[u32]) -> usize {
        self.index_tag(ids).0
    }

    /// Structural equality of cache contents — entries and pLRU state,
    /// statistics ignored. What "bitwise-identical replay" means for a
    /// bank: two banks that are `state_eq` respond identically to every
    /// future lookup/insert sequence.
    pub fn state_eq(&self, other: &RadianceCache) -> bool {
        self.ways == other.ways
            && self.sets == other.sets
            && self.k == other.k
            && self.entries == other.entries
            && self.plru == other.plru
    }

    /// Look up a tag; on hit returns the cached RGB and touches pLRU.
    pub fn lookup(&mut self, ids: &[u32]) -> Option<[f32; 3]> {
        self.stats.lookups += 1;
        let hit = self.probe_touch(ids);
        if hit.is_some() {
            self.stats.hits += 1;
        }
        hit
    }

    /// Read-only probe against frozen contents: tag compare without
    /// touching stats or pLRU — the shared-snapshot lookup path, safe
    /// for any number of concurrent readers.
    pub fn probe(&self, ids: &[u32]) -> Option<[f32; 3]> {
        let (set, tag) = self.index_tag(ids);
        for w in 0..self.ways {
            if let Some(e) = self.entries[set * self.ways + w] {
                if e.tag == tag {
                    return Some(e.value);
                }
            }
        }
        None
    }

    /// Probe that refreshes pLRU on a hit but leaves stats untouched —
    /// the delta-overlay read path, whose stats live in the
    /// [`CacheDelta`].
    fn probe_touch(&mut self, ids: &[u32]) -> Option<[f32; 3]> {
        let (set, tag) = self.index_tag(ids);
        for w in 0..self.ways {
            if let Some(e) = self.entries[set * self.ways + w] {
                if e.tag == tag {
                    self.touch(set, w);
                    return Some(e.value);
                }
            }
        }
        None
    }

    /// Insert (or update) a tag with a pixel value, evicting pseudo-LRU.
    pub fn insert(&mut self, ids: &[u32], value: [f32; 3]) {
        match self.insert_tracked(ids, value) {
            InsertOutcome::Updated => {}
            InsertOutcome::Filled => self.stats.inserts += 1,
            InsertOutcome::Evicted => {
                self.stats.inserts += 1;
                self.stats.evictions += 1;
            }
        }
    }

    /// [`Self::insert`] without the stats side effects, reporting what
    /// happened — lets callers that account stats elsewhere (the shared
    /// delta overlay) reuse the placement/eviction logic.
    fn insert_tracked(&mut self, ids: &[u32], value: [f32; 3]) -> InsertOutcome {
        let (set, tag) = self.index_tag(ids);
        // Update in place on tag match.
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if let Some(e) = &mut self.entries[slot] {
                if e.tag == tag {
                    e.value = value;
                    self.touch(set, w);
                    return InsertOutcome::Updated;
                }
            }
        }
        // Free way?
        for w in 0..self.ways {
            let slot = set * self.ways + w;
            if self.entries[slot].is_none() {
                self.entries[slot] = Some(Entry { tag, value });
                self.touch(set, w);
                return InsertOutcome::Filled;
            }
        }
        // Evict the pseudo-LRU victim.
        let w = self.victim(set);
        self.entries[set * self.ways + w] = Some(Entry { tag, value });
        self.touch(set, w);
        InsertOutcome::Evicted
    }

    /// Tree-pLRU touch: flip node bits toward the accessed way.
    fn touch(&mut self, set: usize, way: usize) {
        // For 4 ways: bit0 = root (0: left pair younger), bit1 = left
        // pair, bit2 = right pair. Generalized for 2/8 analogously.
        match self.ways {
            2 => {
                self.plru[set] = way as u8 ^ 1;
            }
            4 => {
                let mut b = self.plru[set];
                if way < 2 {
                    b |= 1; // root points right next
                    if way == 0 {
                        b |= 2;
                    } else {
                        b &= !2;
                    }
                } else {
                    b &= !1; // root points left next
                    if way == 2 {
                        b |= 4;
                    } else {
                        b &= !4;
                    }
                }
                self.plru[set] = b;
            }
            8 => {
                // 7-bit tree; index math kept simple.
                let mut b = self.plru[set];
                let top = way / 4;
                let mid = (way / 2) % 2;
                let leaf = way % 2;
                set_bit(&mut b, 0, top == 0);
                set_bit(&mut b, 1 + top as u8, mid == 0);
                set_bit(&mut b, 3 + (way / 2) as u8, leaf == 0);
                self.plru[set] = b;
            }
            _ => unreachable!(),
        }
    }

    /// Tree-pLRU victim selection.
    fn victim(&self, set: usize) -> usize {
        let b = self.plru[set];
        match self.ways {
            2 => (b & 1) as usize,
            4 => {
                if b & 1 == 0 {
                    // go left pair
                    if b & 2 == 0 {
                        0
                    } else {
                        1
                    }
                } else if b & 4 == 0 {
                    2
                } else {
                    3
                }
            }
            8 => {
                let top = usize::from(b & 1 == 0);
                let mid = usize::from(b & (1 << (1 + top)) == 0);
                let half = top * 4 + mid * 2;
                let leaf = usize::from(b & (1 << (3 + half / 2)) == 0);
                half + leaf
            }
            _ => unreachable!(),
        }
    }

    /// Flush all contents (the per-tile-group flush of Sec. 4).
    pub fn flush(&mut self) {
        self.entries.iter_mut().for_each(|e| *e = None);
        self.plru.iter_mut().for_each(|b| *b = 0);
    }

    /// Number of live entries.
    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }
}

fn set_bit(b: &mut u8, bit: u8, value: bool) {
    if value {
        *b |= 1 << bit;
    } else {
        *b &= !(1 << bit);
    }
}

/// The tile-grid shape (and alpha-record length) a cache serves: the
/// key under which shared-scope sessions pool their snapshots — two
/// sessions share if and only if their render passes bin the same tile
/// grid with the same k (tiers change the grid, hence the geometry).
/// `Ord` (derived lexicographically) gives multi-geometry merges a
/// canonical publish order — see [`CacheHub::merge_in_order`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheGeometry {
    pub tiles_x: usize,
    pub tiles_y: usize,
    pub k: usize,
}

/// The full LuminCache: one [`RadianceCache`] bank per 4x4-tile group,
/// persisted across frames (the hardware saves/reloads group contents to
/// DRAM between tile batches; double-buffering hides the latency, the
/// simulator charges the traffic).
#[derive(Debug, Clone)]
pub struct GroupedRadianceCache {
    pub groups_x: usize,
    pub groups_y: usize,
    tiles_x: usize,
    tiles_y: usize,
    banks: Vec<RadianceCache>,
    k: usize,
}

impl GroupedRadianceCache {
    pub fn new(tiles_x: usize, tiles_y: usize, k: usize) -> Self {
        let groups_x = tiles_x.div_ceil(CACHE_TILE_GROUP);
        let groups_y = tiles_y.div_ceil(CACHE_TILE_GROUP);
        GroupedRadianceCache {
            groups_x,
            groups_y,
            tiles_x,
            tiles_y,
            banks: (0..groups_x * groups_y)
                .map(|_| RadianceCache::paper_default(k))
                .collect(),
            k,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The tile-grid geometry this cache was sized for.
    pub fn geometry(&self) -> CacheGeometry {
        CacheGeometry { tiles_x: self.tiles_x, tiles_y: self.tiles_y, k: self.k }
    }

    /// Bank index serving a tile coordinate.
    pub fn group_for_tile(&self, tx: usize, ty: usize) -> usize {
        let gx = tx / CACHE_TILE_GROUP;
        let gy = ty / CACHE_TILE_GROUP;
        gy * self.groups_x + gx
    }

    /// Read access to the bank serving a tile — the lookup path, which
    /// an `Arc`-shared snapshot can serve concurrently. (The old
    /// `&mut self` accessor forced exclusive access even for reads,
    /// structurally ruling out any sharing.)
    pub fn bank_for_tile(&self, tx: usize, ty: usize) -> &RadianceCache {
        &self.banks[self.group_for_tile(tx, ty)]
    }

    /// Write access to the bank serving a tile — the insert/pLRU path.
    pub fn bank_for_tile_mut(&mut self, tx: usize, ty: usize) -> &mut RadianceCache {
        let g = self.group_for_tile(tx, ty);
        &mut self.banks[g]
    }

    /// Replay an ordered insertion log — the epoch-merge path. Entries
    /// land through the normal placement path (in-place update,
    /// free-way fill, pLRU eviction), in log order, without touching
    /// bank stats: insert/eviction accounting belongs to the session
    /// deltas, not the published snapshot.
    fn replay(&mut self, log: &[LoggedInsert]) {
        for e in log {
            self.banks[e.group as usize].insert_tracked(&e.ids[..e.k as usize], e.value);
        }
    }

    /// Aggregate statistics over all banks.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for b in &self.banks {
            s.merge(&b.stats);
        }
        s
    }

    /// Live entries across all banks.
    pub fn occupancy(&self) -> usize {
        self.banks.iter().map(|b| b.occupancy()).sum()
    }

    /// Bytes moved per frame for group save+reload (entries * entry size
    /// * 2 directions) — the DRAM traffic the simulator charges for a
    /// **private** (per-session) cache, which really is spilled and
    /// refilled around every frame's tile batches. A pool-shared
    /// snapshot is saved/reloaded once per pool epoch instead; that
    /// scope-aware accounting lives in [`CacheView::swap_bytes_for_frame`],
    /// built from [`Self::occupancy`] and [`CACHE_ENTRY_BYTES`].
    pub fn swap_traffic_bytes(&self) -> usize {
        self.occupancy() * CACHE_ENTRY_BYTES * 2
    }

    pub fn num_banks(&self) -> usize {
        self.banks.len()
    }

    /// Structural equality over all banks (see
    /// [`RadianceCache::state_eq`]).
    pub fn state_eq(&self, other: &GroupedRadianceCache) -> bool {
        self.groups_x == other.groups_x
            && self.groups_y == other.groups_y
            && self.tiles_x == other.tiles_x
            && self.tiles_y == other.tiles_y
            && self.banks.len() == other.banks.len()
            && self.banks.iter().zip(&other.banks).all(|(a, b)| a.state_eq(b))
    }
}

/// An immutable, epoch-stamped view of a merged radiance cache: what
/// every session of a shared-scope pool reads for the whole epoch.
/// Lookups are pure reads (no stats, no pLRU touch), so any number of
/// sessions can probe one snapshot concurrently with bitwise-identical
/// results — the determinism half of the snapshot/merge contract
/// (DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct CacheSnapshot {
    cache: GroupedRadianceCache,
    /// Merge generation: bumped every time [`CacheHub::merge_in_order`]
    /// publishes a successor, so views can tell a genuinely new
    /// snapshot from a sharer-count refresh.
    epoch: u64,
}

impl CacheSnapshot {
    /// An empty snapshot for a cache geometry (epoch 0).
    pub fn empty(geom: CacheGeometry) -> Self {
        CacheSnapshot {
            cache: GroupedRadianceCache::new(geom.tiles_x, geom.tiles_y, geom.k),
            epoch: 0,
        }
    }

    /// Frozen lookup: the cached RGB for a tag, if present.
    pub fn lookup(&self, tx: usize, ty: usize, ids: &[u32]) -> Option<[f32; 3]> {
        self.cache.bank_for_tile(tx, ty).probe(ids)
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.cache.geometry()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Live entries across all banks.
    pub fn occupancy(&self) -> usize {
        self.cache.occupancy()
    }

    /// DRAM bytes to save + reload the whole snapshot once — charged
    /// once per pool epoch (amortized over the sharers), not once per
    /// session per frame.
    pub fn swap_traffic_bytes(&self) -> usize {
        self.cache.swap_traffic_bytes()
    }
}

/// One logged insert of a [`CacheDelta`]: enough to replay the exact
/// insert against the next snapshot at the epoch merge.
#[derive(Debug, Clone, Copy)]
pub struct LoggedInsert {
    group: u32,
    k: u8,
    ids: [u32; MAX_SIG_K],
    value: [f32; 3],
}

/// A session's private epoch-local cache state under shared scope: an
/// overlay cache answering lookups for the session's own fresh inserts
/// (so intra-frame and intra-epoch self-hits keep working), plus the
/// ordered insertion log the pool replays into the next snapshot at the
/// epoch merge. Nothing here is visible to other sessions until the
/// merge publishes it.
///
/// The log is compacted **at record time, per cache set** (see
/// [`SharedBank::store`]): a re-insert whose tag matches the most
/// recent insert into the same `(group, set)` folds into that entry —
/// exactly equivalent under ordered replay, because inserts into other
/// sets never touch this set's ways or pLRU bits. The dominant log
/// growth — the same hot tags re-missing frame after frame within an
/// epoch — therefore collapses to one entry per tag run, bounding delta
/// memory by tag *alternations* across the touched sets rather than the
/// epoch's raw miss count. (`last_in_set` carries one index per touched
/// set to find the fold target in O(1).)
#[derive(Debug)]
pub struct CacheDelta {
    overlay: GroupedRadianceCache,
    log: Vec<LoggedInsert>,
    /// Per-(group, set): index into `log` of the most recent insert
    /// into that set — the set-level compaction cursor.
    last_in_set: HashMap<(u32, u32), u32>,
    stats: CacheStats,
}

impl CacheDelta {
    pub fn new(geom: CacheGeometry) -> Self {
        CacheDelta {
            overlay: GroupedRadianceCache::new(geom.tiles_x, geom.tiles_y, geom.k),
            log: Vec::new(),
            last_in_set: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.overlay.geometry()
    }

    /// Inserts logged since the delta was (re)created.
    pub fn len(&self) -> usize {
        self.log.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    /// View statistics accumulated while rendering against this delta.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// Bytes one world-cache entry occupies in DRAM during a snapshot
/// save/reload: 4 B checksum + 12 B RGB + 2 B lifetime.
pub const WORLD_ENTRY_BYTES: usize = 18;

/// Parameters of the world-space hash cache (`pool.world_*` knobs),
/// frozen into every [`WorldSnapshot`] so sessions and the epoch merge
/// agree on key derivation and probe bounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorldParams {
    /// Fixed table size in cells.
    pub cells: usize,
    /// Positional cell edge (world units) before distance LOD scaling.
    pub base_cell_size: f32,
    /// Distance at which positional cells start doubling: the edge
    /// doubles every power-of-two multiple of this (positional LOD, so
    /// far geometry lands in coarse cells and near geometry keeps fine
    /// ones).
    pub lod_distance: f32,
    /// Full lifetime, in pool epochs, a cell is granted on insert and
    /// reset to on every snapshot hit. Cells age one per epoch and are
    /// freed at zero — the world scope's eviction policy.
    pub lifetime: u16,
    /// Bounded linear-probe chain length on slot collision.
    pub probe_len: u32,
    /// Per-axis view-direction buckets of the key.
    pub dir_buckets: u32,
}

/// Distance-scaled positional cell edge: doubles every time the camera
/// distance crosses another power-of-two multiple of `lod_distance`
/// (bevy_solari-style positional LOD).
fn world_cell_size(dist: f32, params: &WorldParams) -> f32 {
    let lod = (dist / params.lod_distance.max(1e-6)).max(1.0).log2().floor() as u32;
    params.base_cell_size.max(1e-6) * (1u64 << lod.min(24)) as f32
}

/// Mix a quantized (position cell, direction bucket) tuple into the
/// 64-bit world key — splitmix64-style finalization per lane, pure
/// integer arithmetic, platform-independent.
fn world_key(qp: [i32; 3], qd: [u32; 3]) -> u64 {
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
    for v in [qp[0] as u32, qp[1] as u32, qp[2] as u32, qd[0], qd[1], qd[2]] {
        h ^= u64::from(v);
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 31;
    }
    h
}

/// Build the world key for a Gaussian world position seen from `cam`:
/// quantize the position into its distance-scaled cell and bucket the
/// view direction per axis.
fn world_key_for(pos: Vec3, cam: [f32; 3], params: &WorldParams) -> u64 {
    let d = [pos.x - cam[0], pos.y - cam[1], pos.z - cam[2]];
    let dist = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt();
    let cell = world_cell_size(dist, params);
    let q = |v: f32| (v / cell).floor() as i32;
    let inv = if dist > 1e-6 { 1.0 / dist } else { 0.0 };
    let buckets = params.dir_buckets.max(1);
    let bucket = |v: f32| (((v * inv + 1.0) * 0.5 * buckets as f32) as u32).min(buckets - 1);
    world_key(
        [q(pos.x), q(pos.y), q(pos.z)],
        [bucket(d[0]), bucket(d[1]), bucket(d[2])],
    )
}

/// Slot-chain start of a key.
fn world_slot(key: u64, cells: usize) -> usize {
    (key % cells.max(1) as u64) as usize
}

/// Occupancy checksum of a key: a second, independent hash round forced
/// nonzero (0 marks an empty cell). Two distinct keys alias a cell only
/// if they collide on *both* the slot chain and this 32-bit checksum.
fn world_checksum(key: u64) -> u32 {
    let mut h = key ^ 0xC2B2_AE3D_27D4_EB4F;
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 29;
    (h as u32).max(1)
}

/// The fixed-size world-space hash table: flat checksum/value/lifetime
/// arrays, no per-tile banks — one table serves every pose, tier, and
/// resolution in the pool. Slots are claimed by checksum, chained by
/// bounded linear probing, and reclaimed by lifetime decay at the epoch
/// merge ([`CacheHub::merge_world_in_order`]).
#[derive(Debug, Clone)]
pub struct WorldRadianceCache {
    /// Per-cell key checksum; 0 = empty.
    checksums: Vec<u32>,
    values: Vec<[f32; 3]>,
    lifetimes: Vec<u16>,
}

impl WorldRadianceCache {
    pub fn new(cells: usize) -> Self {
        let cells = cells.max(1);
        WorldRadianceCache {
            checksums: vec![0; cells],
            values: vec![[0.0; 3]; cells],
            lifetimes: vec![0; cells],
        }
    }

    pub fn cells(&self) -> usize {
        self.checksums.len()
    }

    /// Live (claimed) cells.
    pub fn occupancy(&self) -> usize {
        self.checksums.iter().filter(|&&c| c != 0).count()
    }

    /// Bounded linear probe for `key`: `(Some(slot), probes)` on a
    /// checksum match, `(None, probes)` when the chain reached an empty
    /// cell (the key cannot be further along — claims always take the
    /// first empty slot) or exhausted the bound.
    fn find(&self, key: u64, probe_len: u32) -> (Option<usize>, u32) {
        let cells = self.checksums.len();
        let cs = world_checksum(key);
        let start = world_slot(key, cells);
        let n = (probe_len.max(1) as usize).min(cells);
        for i in 0..n {
            let slot = (start + i) % cells;
            match self.checksums[slot] {
                0 => return (None, i as u32 + 1),
                c if c == cs => return (Some(slot), i as u32 + 1),
                _ => {}
            }
        }
        (None, n as u32)
    }

    /// Claim-or-update along the probe chain: checksum match updates the
    /// value (keeping the higher lifetime), an empty cell is claimed,
    /// and an exhausted chain replaces its weakest (minimum-lifetime,
    /// first-occurrence) slot only when the candidate's lifetime is
    /// strictly higher — otherwise the insert is dropped. Returns
    /// whether the value landed.
    fn insert(&mut self, key: u64, value: [f32; 3], lifetime: u16, probe_len: u32) -> bool {
        let cells = self.checksums.len();
        let cs = world_checksum(key);
        let start = world_slot(key, cells);
        let n = (probe_len.max(1) as usize).min(cells);
        let (mut weakest, mut weakest_life) = (usize::MAX, u16::MAX);
        for i in 0..n {
            let slot = (start + i) % cells;
            match self.checksums[slot] {
                0 => {
                    self.checksums[slot] = cs;
                    self.values[slot] = value;
                    self.lifetimes[slot] = lifetime;
                    return true;
                }
                c if c == cs => {
                    self.values[slot] = value;
                    self.lifetimes[slot] = self.lifetimes[slot].max(lifetime);
                    return true;
                }
                _ => {
                    if self.lifetimes[slot] < weakest_life {
                        weakest_life = self.lifetimes[slot];
                        weakest = slot;
                    }
                }
            }
        }
        if weakest != usize::MAX && lifetime > weakest_life {
            self.checksums[weakest] = cs;
            self.values[weakest] = value;
            self.lifetimes[weakest] = lifetime;
            return true;
        }
        false
    }
}

/// An immutable, epoch-stamped view of the merged world cache: what
/// every world-scope session reads for the whole epoch. One snapshot
/// per pool — world keys are geometry-independent, so all tiers and
/// resolutions share it (the cross-tier sharing the screen-tile
/// [`CacheSnapshot`] structurally cannot offer).
#[derive(Debug, Clone)]
pub struct WorldSnapshot {
    table: WorldRadianceCache,
    params: WorldParams,
    epoch: u64,
    /// DRAM bytes the merge's decay sweep moved to produce this
    /// snapshot — charged once per pool epoch, amortized over sharers
    /// by [`CacheView::install_world_snapshot`].
    decay_sweep_bytes: u64,
}

impl WorldSnapshot {
    /// An empty snapshot (epoch 0).
    pub fn empty(params: WorldParams) -> Self {
        WorldSnapshot {
            table: WorldRadianceCache::new(params.cells),
            params,
            epoch: 0,
            decay_sweep_bytes: 0,
        }
    }

    pub fn params(&self) -> WorldParams {
        self.params
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Live cells.
    pub fn occupancy(&self) -> usize {
        self.table.occupancy()
    }

    /// Frozen lookup: the cached RGB for a world key plus the chain
    /// slots examined (probe-histogram material).
    pub fn probe(&self, key: u64) -> (Option<[f32; 3]>, u32) {
        let (slot, probes) = self.table.find(key, self.params.probe_len);
        (slot.map(|s| self.table.values[s]), probes)
    }

    /// DRAM bytes to save + reload the snapshot once — charged once per
    /// pool epoch, amortized over sharers.
    pub fn swap_traffic_bytes(&self) -> usize {
        self.table.occupancy() * WORLD_ENTRY_BYTES * 2
    }

    /// DRAM bytes the producing merge's decay sweep moved.
    pub fn decay_sweep_bytes(&self) -> u64 {
        self.decay_sweep_bytes
    }
}

/// One logged world-cache insert with its within-epoch re-store count —
/// the frequency the lifetime-weighted merge consumes.
#[derive(Debug, Clone, Copy)]
pub struct WorldInsert {
    key: u64,
    value: [f32; 3],
    freq: u32,
}

/// A session's private epoch-local world-cache state: a point-lookup
/// overlay answering the session's own fresh inserts, the per-key
/// compacted insert log, and the set of snapshot keys the session hit
/// (whose lifetimes the merge refreshes). Nothing here is visible to
/// other sessions until the epoch merge publishes it.
#[derive(Debug, Default)]
pub struct WorldDelta {
    /// Own fresh inserts for intra-epoch self-hits. Point lookups only
    /// — never iterated, so hash order stays off the render path.
    overlay: HashMap<u64, [f32; 3]>,
    /// Insert log, compacted per key at record time: a re-store folds
    /// into its existing entry (exact — the merge is last-value-wins
    /// per (key, session)) and bumps `freq`.
    log: Vec<WorldInsert>,
    log_index: HashMap<u64, u32>,
    /// Snapshot keys hit this epoch, first-touch order (dedup via
    /// `touched_set`); the merge unions these across sessions.
    touched: Vec<u64>,
    touched_set: HashSet<u64>,
    stats: CacheStats,
}

impl WorldDelta {
    pub fn new() -> Self {
        WorldDelta::default()
    }

    /// Distinct keys logged for insert this epoch.
    pub fn len(&self) -> usize {
        self.log.len()
    }

    /// True when the delta carries nothing the merge would act on —
    /// neither inserts nor lifetime refreshes.
    pub fn is_empty(&self) -> bool {
        self.log.is_empty() && self.touched.is_empty()
    }

    /// View statistics accumulated while rendering against this delta.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// The cache-topology seam: where one session's lookups and inserts go.
pub enum CacheView {
    /// Session-owned cache — the pre-sharing behavior, bit-for-bit.
    Private(GroupedRadianceCache),
    /// Pool-shared: reads check the session's own delta overlay first
    /// (freshest), then the frozen epoch snapshot; writes go to the
    /// delta only.
    Shared {
        snapshot: Arc<CacheSnapshot>,
        delta: CacheDelta,
        /// Snapshot-reload DRAM bytes still to charge — the session's
        /// amortized share of the once-per-pool-epoch snapshot swap,
        /// consumed by the next rendered frame.
        pending_snapshot_bytes: u64,
    },
    /// Pool-shared with world-space keys: same epoch protocol as
    /// `Shared` (frozen snapshot reads + private delta writes), but the
    /// tag survives pose, tier, and resolution changes.
    World {
        snapshot: Arc<WorldSnapshot>,
        delta: WorldDelta,
        /// Full source scene the world keys index by global Gaussian
        /// ID. Tier reductions are prefix subsamples, so reduced-tier
        /// IDs stay valid indices into the full scene — one scene Arc
        /// serves every tier.
        scene: Arc<GaussianScene>,
        /// Alpha-record length: the query is gated on the first k
        /// significant Gaussians exactly like the geometry scopes; the
        /// key just collapses to the first one's world cell.
        k: usize,
        /// Snapshot-reload + decay-sweep DRAM bytes still to charge
        /// (the session's amortized share, consumed by the next frame).
        pending_snapshot_bytes: u64,
    },
}

impl CacheView {
    pub fn private(cache: GroupedRadianceCache) -> Self {
        CacheView::Private(cache)
    }

    /// A shared view over a snapshot, with a fresh (empty) delta. The
    /// freshly attached session must reload the whole snapshot once, so
    /// the full swap traffic is pending; pool installs that follow a
    /// merge amortize over the sharer count instead
    /// ([`Self::install_snapshot`]).
    pub fn shared(snapshot: Arc<CacheSnapshot>) -> Self {
        let delta = CacheDelta::new(snapshot.geometry());
        let pending = snapshot.swap_traffic_bytes() as u64;
        CacheView::Shared { snapshot, delta, pending_snapshot_bytes: pending }
    }

    /// A world-scope view over the pool snapshot, with a fresh (empty)
    /// delta. Like [`Self::shared`], the freshly attached session
    /// reloads the whole snapshot once.
    pub fn world(snapshot: Arc<WorldSnapshot>, scene: Arc<GaussianScene>, k: usize) -> Self {
        let pending = snapshot.swap_traffic_bytes() as u64;
        CacheView::World {
            snapshot,
            delta: WorldDelta::new(),
            scene,
            k,
            pending_snapshot_bytes: pending,
        }
    }

    /// Whether lookups go through pool-shared state — the structural
    /// contention flag the cost models price ([`FrameWorkload::cache_shared`]).
    /// World scope shares one table pool-wide, so it counts.
    ///
    /// [`FrameWorkload::cache_shared`]: crate::pipeline::stage::FrameWorkload::cache_shared
    pub fn is_shared(&self) -> bool {
        matches!(self, CacheView::Shared { .. } | CacheView::World { .. })
    }

    pub fn k(&self) -> usize {
        match self {
            CacheView::Private(c) => c.k(),
            CacheView::Shared { delta, .. } => delta.overlay.k(),
            CacheView::World { k, .. } => *k,
        }
    }

    /// Worst-case probe-chain length a shared lookup walks — the
    /// multiplier on [`shared_lookup_cost_s`]. Geometry scopes resolve
    /// a tag in one set access; the world table may chain.
    ///
    /// [`shared_lookup_cost_s`]: crate::sim::cost::CostModel::shared_lookup_cost_s
    pub fn shared_probe_len(&self) -> u32 {
        match self {
            CacheView::Private(_) | CacheView::Shared { .. } => 1,
            CacheView::World { snapshot, .. } => snapshot.params.probe_len.max(1),
        }
    }

    /// Lifetime view statistics (bank stats under private scope, delta
    /// stats under shared).
    pub fn stats(&self) -> CacheStats {
        match self {
            CacheView::Private(c) => c.stats(),
            CacheView::Shared { delta, .. } => delta.stats,
            CacheView::World { delta, .. } => delta.stats,
        }
    }

    /// Detach the accumulated delta, leaving a fresh one behind (`None`
    /// under private scope). The pool calls this at every epoch
    /// boundary, in session-index order.
    pub fn take_delta(&mut self) -> Option<CacheDelta> {
        match self {
            CacheView::Private(_) | CacheView::World { .. } => None,
            CacheView::Shared { delta, .. } => {
                let fresh = CacheDelta::new(delta.geometry());
                Some(std::mem::replace(delta, fresh))
            }
        }
    }

    /// Detach the accumulated world delta, leaving a fresh one behind
    /// (`None` outside world scope). Epoch-boundary path, session-index
    /// order — same contract as [`Self::take_delta`].
    pub fn take_world_delta(&mut self) -> Option<WorldDelta> {
        match self {
            CacheView::World { delta, .. } => Some(std::mem::take(delta)),
            _ => None,
        }
    }

    /// Swap in the next epoch's merged snapshot. `sharers` is how many
    /// sessions read this snapshot: the once-per-pool-epoch save+reload
    /// traffic is split across them, so the pool as a whole is charged
    /// the swap once — not once per session per frame. Re-installing
    /// the same snapshot (a sharer-count refresh) charges nothing.
    pub fn install_snapshot(&mut self, snap: Arc<CacheSnapshot>, sharers: usize) {
        if let CacheView::Shared { snapshot, delta, pending_snapshot_bytes } = self {
            if Arc::ptr_eq(snapshot, &snap) {
                return;
            }
            if snap.geometry() != delta.geometry() {
                // Defensive: a geometry change must come with a fresh
                // delta (set_tier rebuilds the whole view; this path
                // covers direct installs only).
                *delta = CacheDelta::new(snap.geometry());
            }
            *pending_snapshot_bytes +=
                (snap.swap_traffic_bytes() as u64).div_ceil(sharers.max(1) as u64);
            *snapshot = snap;
        }
    }

    /// Swap in the next epoch's merged world snapshot. The amortized
    /// share covers the snapshot save+reload *and* the merge's decay
    /// sweep — both once-per-pool-epoch DRAM costs. Re-installing the
    /// same snapshot charges nothing.
    pub fn install_world_snapshot(&mut self, snap: Arc<WorldSnapshot>, sharers: usize) {
        if let CacheView::World { snapshot, pending_snapshot_bytes, .. } = self {
            if Arc::ptr_eq(snapshot, &snap) {
                return;
            }
            *pending_snapshot_bytes += (snap.swap_traffic_bytes() as u64 + snap.decay_sweep_bytes)
                .div_ceil(sharers.max(1) as u64);
            *snapshot = snap;
        }
    }

    /// DRAM swap traffic to charge the frame that is being rendered
    /// right now. Private: the whole cache is spilled/refilled around
    /// the frame's tile batches, every frame (the pre-sharing model,
    /// unchanged). Shared: the session's delta working set is
    /// saved+reloaded each frame exactly like a private cache of the
    /// same occupancy, plus whatever share of the epoch's snapshot swap
    /// is still pending (consumed here, charged once per install).
    pub fn swap_bytes_for_frame(&mut self) -> u64 {
        match self {
            CacheView::Private(c) => c.swap_traffic_bytes() as u64,
            CacheView::Shared { delta, pending_snapshot_bytes, .. } => {
                let snapshot_share = std::mem::take(pending_snapshot_bytes);
                snapshot_share + delta.overlay.swap_traffic_bytes() as u64
            }
            CacheView::World { delta, pending_snapshot_bytes, .. } => {
                let snapshot_share = std::mem::take(pending_snapshot_bytes);
                snapshot_share + (delta.overlay.len() * WORLD_ENTRY_BYTES * 2) as u64
            }
        }
    }
}

/// Pool-wide owner of the shared snapshots, keyed by [`CacheGeometry`]
/// (sessions on different serving tiers render different tile grids and
/// therefore share with their geometry peers only — a `set_tier` swap
/// invalidates just that session's delta, never the snapshots).
///
/// The hub is only ever touched from the pool's coordination thread
/// (construction, tier application, epoch merges); during rendering,
/// sessions hold their own `Arc<CacheSnapshot>` and never reach the
/// hub, so the mutex is uncontended and cannot order-scramble anything.
#[derive(Debug, Default)]
pub struct CacheHub {
    snapshots: Mutex<HashMap<CacheGeometry, Arc<CacheSnapshot>>>,
    /// The pool-wide world-scope snapshot (one table for every tier and
    /// resolution; `None` until the first world-scope session attaches).
    world: Mutex<Option<Arc<WorldSnapshot>>>,
}

impl CacheHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// The current snapshot for a geometry (an empty epoch-0 snapshot
    /// is created on first request).
    pub fn snapshot_for(&self, geom: CacheGeometry) -> Arc<CacheSnapshot> {
        self.snapshots
            .lock()
            .expect("cache hub poisoned")
            .entry(geom)
            .or_insert_with(|| Arc::new(CacheSnapshot::empty(geom)))
            .clone()
    }

    /// Merge session deltas into next-epoch snapshots **in the order
    /// given** — the pool passes session-index order, which is the
    /// whole determinism contract: the merged contents (values, pLRU
    /// state, evictions) depend only on that order, never on how many
    /// threads rendered the epoch. Geometries untouched by any delta
    /// keep their current snapshot (same `Arc`, same epoch), so idle
    /// epochs charge no snapshot swap.
    pub fn merge_in_order(&self, deltas: Vec<CacheDelta>) {
        let mut map = self.snapshots.lock().expect("cache hub poisoned");
        // BTreeMap, not HashMap: publication below iterates this map, and
        // the publish order must be a function of the deltas alone (hash
        // iteration order is seeded per-process). Today publish order is
        // not value-observable — geometries are independent keys — but
        // keeping it canonical (ascending CacheGeometry) means log
        // readers, future cross-geometry accounting, and the detlint R1
        // rule never have to reason about it.
        let mut dirty: BTreeMap<CacheGeometry, (GroupedRadianceCache, u64)> = BTreeMap::new();
        for d in deltas {
            if d.log.is_empty() {
                continue;
            }
            let geom = d.geometry();
            let (work, _) = dirty.entry(geom).or_insert_with(|| match map.get(&geom) {
                Some(s) => (s.cache.clone(), s.epoch),
                None => (GroupedRadianceCache::new(geom.tiles_x, geom.tiles_y, geom.k), 0),
            });
            work.replay(&d.log);
        }
        for (geom, (cache, epoch)) in dirty {
            map.insert(geom, Arc::new(CacheSnapshot { cache, epoch: epoch + 1 }));
        }
    }

    /// The pool-wide world snapshot (an empty epoch-0 snapshot with
    /// `params` is created on first request). Unlike
    /// [`Self::snapshot_for`] there is no geometry key: world keys are
    /// geometry-independent, so every tier and resolution reads the
    /// same table.
    pub fn world_snapshot(&self, params: WorldParams) -> Arc<WorldSnapshot> {
        self.world
            .lock()
            .expect("cache hub poisoned")
            .get_or_insert_with(|| Arc::new(WorldSnapshot::empty(params)))
            .clone()
    }

    /// Merge world deltas into the next-epoch snapshot, returning the
    /// cells freed by the decay sweep.
    ///
    /// The pool passes session-index order, but unlike
    /// [`Self::merge_in_order`] the outcome does **not** trust replay
    /// order — it is a function of the delta *set* plus each delta's
    /// session index:
    ///
    /// 1. **Refresh.** The union of snapshot-hit keys (a set union —
    ///    order-free) resets each found cell's lifetime to full.
    /// 2. **Decay.** A slot-order sweep ages every occupied,
    ///    un-refreshed cell by one epoch; cells at zero are freed — the
    ///    eviction policy.
    /// 3. **Insert.** Per key, one winner is chosen by max (candidate
    ///    lifetime, session index), where candidate lifetime = base
    ///    lifetime + (within-epoch re-store count - 1) — the
    ///    lifetime/frequency-weighted merge. Winners land in ascending
    ///    key order through the same probe/claim path queries use, so
    ///    same-cell conflicts between *different* keys resolve
    ///    deterministically too (first claim wins; an exhausted chain
    ///    replaces its weakest slot only when strictly stronger).
    ///
    /// Every step is independent of how sessions were partitioned
    /// across threads, pipeline depths, or schedulers — the world
    /// scope's half of the bitwise-determinism contract.
    ///
    /// Deltas with nothing to act on keep the current snapshot (same
    /// `Arc`, same epoch), so idle epochs charge no swap or sweep.
    pub fn merge_world_in_order(&self, deltas: Vec<WorldDelta>) -> u64 {
        if deltas.iter().all(|d| d.is_empty()) {
            return 0;
        }
        let mut guard = self.world.lock().expect("cache hub poisoned");
        let (params, mut table, epoch) = match guard.as_ref() {
            Some(cur) => (cur.params, cur.table.clone(), cur.epoch),
            None => return 0,
        };
        let cells = table.cells();

        // (1) Lifetime refresh over the union of touched keys.
        let touched: BTreeSet<u64> =
            deltas.iter().flat_map(|d| d.touched.iter().copied()).collect();
        let mut refreshed = vec![false; cells];
        for &key in &touched {
            if let (Some(slot), _) = table.find(key, params.probe_len) {
                table.lifetimes[slot] = params.lifetime;
                refreshed[slot] = true;
            }
        }

        // (2) Decay sweep: the sweep reads every occupied entry and
        // writes aged lifetimes back — once-per-pool-epoch DRAM,
        // amortized over sharers at install time.
        let mut decay_evictions = 0u64;
        let occupied = table.occupancy() as u64;
        for slot in 0..cells {
            if table.checksums[slot] != 0 && !refreshed[slot] {
                table.lifetimes[slot] = table.lifetimes[slot].saturating_sub(1);
                if table.lifetimes[slot] == 0 {
                    table.checksums[slot] = 0;
                    table.values[slot] = [0.0; 3];
                    decay_evictions += 1;
                }
            }
        }
        let decay_sweep_bytes = occupied * WORLD_ENTRY_BYTES as u64;

        // (3) Lifetime/frequency-weighted winner per key, inserted in
        // ascending key order.
        let mut winners: BTreeMap<u64, (u16, usize, [f32; 3])> = BTreeMap::new();
        for (si, d) in deltas.iter().enumerate() {
            for ins in &d.log {
                let granted = u32::from(params.lifetime)
                    .saturating_add(ins.freq.saturating_sub(1))
                    .min(u32::from(u16::MAX)) as u16;
                let cand = (granted, si, ins.value);
                winners
                    .entry(ins.key)
                    .and_modify(|w| {
                        if (granted, si) > (w.0, w.1) {
                            *w = cand;
                        }
                    })
                    .or_insert(cand);
            }
        }
        for (key, (granted, _si, value)) in winners {
            table.insert(key, value, granted, params.probe_len);
        }

        *guard = Some(Arc::new(WorldSnapshot {
            table,
            params,
            epoch: epoch + 1,
            decay_sweep_bytes,
        }));
        decay_evictions
    }
}

/// Per-pixel outcome of cached rasterization.
#[derive(Debug, Clone, Copy, Default)]
pub struct PixelOutcome {
    /// Gaussians iterated by this pixel (stops early on cache hit).
    pub iterated: u32,
    /// Significant Gaussians encountered while iterating.
    pub significant: u32,
    /// True when the pixel's value came from the cache.
    pub hit: bool,
    /// Hit provenance: true when the value came from the pool-shared
    /// frozen snapshot rather than the session's own inserts (always
    /// false under private scope).
    pub snapshot_hit: bool,
    /// Gaussians the *uncached* pipeline would have iterated. Equal to
    /// `iterated` except on hit pixels rendered with
    /// `record_uncached = true`, where the scan continues (without
    /// compositing) to recover the exact plain-rasterizer count.
    pub uncached_iterated: u32,
    /// Significant Gaussians the uncached pipeline would have seen.
    pub uncached_significant: u32,
}

/// Output of radiance-cached rasterization.
pub struct CachedRasterOutput {
    pub image: Image,
    pub outcomes: Vec<PixelOutcome>,
    pub stats: CacheStats,
    /// Per-pixel uncached counts (present when `record_uncached` was
    /// requested): exactly what a plain [`rasterize`] stats pass over
    /// the same projected set would produce, recovered in this single
    /// pass.
    ///
    /// [`rasterize`]: crate::pipeline::raster::rasterize
    pub uncached: Option<RasterStats>,
}

/// Rasterize with radiance caching (paper Fig. 10).
///
/// Per pixel: composite until the first k significant Gaussians are seen
/// (the alpha-record), query the cache with their IDs; on hit, emit the
/// cached value and stop; on miss, finish compositing and insert.
/// Serial over tiles because the cache is shared mutable state — exactly
/// the lock-contention hazard the paper ascribes to RC-on-GPU; the
/// accelerator sims recover parallelism by charging per-bank timing.
pub fn rasterize_cached(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cache: &mut GroupedRadianceCache,
) -> CachedRasterOutput {
    rasterize_cached_ex(projected, bins, width, height, cache, false)
}

/// [`rasterize_cached`] with optional single-pass recording of the
/// *uncached* per-pixel counts (see [`CachedRasterOutput::uncached`]):
/// hit pixels continue scanning their tile list without compositing, so
/// the RC-GPU cost model gets the exact uncached warp structure without
/// a second full rasterization.
pub fn rasterize_cached_ex(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cache: &mut GroupedRadianceCache,
    record_uncached: bool,
) -> CachedRasterOutput {
    rasterize_cached_source(
        projected,
        bins,
        width,
        height,
        &mut TileSource::Private(cache),
        record_uncached,
    )
}

/// Report only one call's statistics: `after` minus `before`.
fn stats_delta(after: CacheStats, before: CacheStats) -> CacheStats {
    let mut probe_hist = [0u64; PROBE_HIST_BUCKETS];
    for (i, p) in probe_hist.iter_mut().enumerate() {
        *p = after.probe_hist[i] - before.probe_hist[i];
    }
    CacheStats {
        lookups: after.lookups - before.lookups,
        hits: after.hits - before.hits,
        snapshot_hits: after.snapshot_hits - before.snapshot_hits,
        inserts: after.inserts - before.inserts,
        evictions: after.evictions - before.evictions,
        short_rays: after.short_rays - before.short_rays,
        decay_evictions: after.decay_evictions - before.decay_evictions,
        probe_hist,
    }
}

/// [`rasterize_cached_ex`] over the topology seam: both scopes run the
/// same loop driver; only the per-tile bank construction differs.
pub fn rasterize_cached_view(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    view: &mut CacheView,
    record_uncached: bool,
) -> CachedRasterOutput {
    let mut source = match view {
        CacheView::Private(cache) => TileSource::Private(cache),
        CacheView::Shared { snapshot, delta, .. } => {
            debug_assert_eq!(
                snapshot.geometry(),
                delta.geometry(),
                "snapshot/delta geometry split"
            );
            TileSource::Shared { snapshot: &**snapshot, delta }
        }
        CacheView::World { snapshot, delta, scene, k, .. } => TileSource::World {
            snapshot: &**snapshot,
            delta,
            positions: &scene.pos,
            cam: projected.cam_pos,
            k: *k,
        },
    };
    rasterize_cached_source(projected, bins, width, height, &mut source, record_uncached)
}

/// Where a rasterization call's per-tile banks come from — the driver's
/// end of the topology seam. Private: the session's own mutable bank.
/// Shared: a frozen snapshot bank paired with the session's delta
/// overlay/log — the snapshot is never written, so concurrent sessions
/// cannot observe each other mid-epoch; sharing becomes visible only
/// through the deterministic epoch merge.
enum TileSource<'s> {
    Private(&'s mut GroupedRadianceCache),
    Shared { snapshot: &'s CacheSnapshot, delta: &'s mut CacheDelta },
    World {
        snapshot: &'s WorldSnapshot,
        delta: &'s mut WorldDelta,
        positions: &'s [Vec3],
        cam: [f32; 3],
        k: usize,
    },
}

impl TileSource<'_> {
    fn k(&self) -> usize {
        match self {
            TileSource::Private(c) => c.k(),
            TileSource::Shared { delta, .. } => delta.overlay.k(),
            TileSource::World { k, .. } => *k,
        }
    }

    fn stats(&self) -> CacheStats {
        match self {
            TileSource::Private(c) => c.stats(),
            TileSource::Shared { delta, .. } => delta.stats,
            TileSource::World { delta, .. } => delta.stats,
        }
    }
}

/// The one tile/pixel loop driver both topologies share — any change to
/// tile iteration, edge clamping, or stats assembly lands on private
/// and shared scope alike, preserving their documented equivalence.
fn rasterize_cached_source(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    source: &mut TileSource<'_>,
    record_uncached: bool,
) -> CachedRasterOutput {
    let ts = bins.tile_size;
    let k = source.k();
    let mut image = Image::new(width, height);
    let mut outcomes = vec![PixelOutcome::default(); width * height];
    let stats_before = source.stats();

    for ty in 0..bins.tiles_y {
        for tx in 0..bins.tiles_x {
            let tile = ty * bins.tiles_x + tx;
            let splats = gather_tile(projected, bins.list(tile));
            match source {
                TileSource::Private(cache) => run_tile(
                    cache.bank_for_tile_mut(tx, ty),
                    &splats,
                    (tx, ty),
                    ts,
                    (width, height),
                    k,
                    record_uncached,
                    &mut image,
                    &mut outcomes,
                ),
                TileSource::Shared { snapshot, delta } => {
                    let CacheDelta { overlay, log, last_in_set, stats } = &mut **delta;
                    let group = overlay.group_for_tile(tx, ty) as u32;
                    let mut bank = SharedBank {
                        frozen: snapshot.cache.bank_for_tile(tx, ty),
                        overlay: overlay.bank_for_tile_mut(tx, ty),
                        log,
                        last_in_set,
                        stats,
                        group,
                    };
                    run_tile(
                        &mut bank,
                        &splats,
                        (tx, ty),
                        ts,
                        (width, height),
                        k,
                        record_uncached,
                        &mut image,
                        &mut outcomes,
                    );
                }
                TileSource::World { snapshot, delta, positions, cam, .. } => {
                    let WorldDelta { overlay, log, log_index, touched, touched_set, stats } =
                        &mut **delta;
                    let mut bank = WorldBank {
                        frozen: snapshot,
                        overlay,
                        log,
                        log_index,
                        touched,
                        touched_set,
                        stats,
                        positions,
                        cam: *cam,
                    };
                    run_tile(
                        &mut bank,
                        &splats,
                        (tx, ty),
                        ts,
                        (width, height),
                        k,
                        record_uncached,
                        &mut image,
                        &mut outcomes,
                    );
                }
            }
        }
    }

    let stats = stats_delta(source.stats(), stats_before);
    let uncached = record_uncached.then(|| RasterStats {
        iterated: outcomes.iter().map(|o| o.uncached_iterated).collect(),
        significant: outcomes.iter().map(|o| o.uncached_significant).collect(),
    });
    CachedRasterOutput { image, outcomes, stats, uncached }
}

/// One tile's pixel loop over a cache endpoint.
#[allow(clippy::too_many_arguments)]
fn run_tile<B: PixelCache>(
    bank: &mut B,
    splats: &[GatheredSplat],
    (tx, ty): (usize, usize),
    ts: usize,
    (width, height): (usize, usize),
    k: usize,
    record_uncached: bool,
    image: &mut Image,
    outcomes: &mut [PixelOutcome],
) {
    for ly in 0..ts {
        let y = ty * ts + ly;
        if y >= height {
            break;
        }
        for lx in 0..ts {
            let x = tx * ts + lx;
            if x >= width {
                break;
            }
            let (value, outcome) = composite_pixel_cached_generic(
                splats,
                x as f32 + 0.5,
                y as f32 + 0.5,
                k,
                bank,
                record_uncached,
            );
            image.set(x, y, value);
            outcomes[y * width + x] = outcome;
        }
    }
}

/// One pixel with cache interaction. Mirrors `raster::composite_pixel`
/// semantics exactly for the compositing math (including the gathered
/// significance-radius fast reject).
pub fn composite_pixel_cached(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut RadianceCache,
) -> ([f32; 3], PixelOutcome) {
    composite_pixel_cached_ex(splats, px, py, k, bank, false)
}

/// [`composite_pixel_cached`] with optional uncached-count recording: on
/// a hit, the scan continues past the cache cutoff — counting, not
/// compositing — so the outcome also carries the exact counts the plain
/// compositor would have produced for this pixel.
pub fn composite_pixel_cached_ex(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut RadianceCache,
    record_uncached: bool,
) -> ([f32; 3], PixelOutcome) {
    composite_pixel_cached_generic(splats, px, py, k, bank, record_uncached)
}

/// The per-pixel cache endpoint the compositor talks to — one tile's
/// end of the topology seam. Private scope is a bank; shared scope is a
/// frozen bank + the session's delta overlay/log.
trait PixelCache {
    /// Query a tag: the cached RGB plus provenance (`true` = served
    /// from the shared frozen snapshot).
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)>;
    /// Record a fully-composited value under its tag.
    fn store(&mut self, ids: &[u32], value: [f32; 3]);
    /// Note an uncacheable short ray.
    fn short_ray(&mut self);
}

impl PixelCache for RadianceCache {
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)> {
        self.lookup(ids).map(|v| (v, false))
    }

    fn store(&mut self, ids: &[u32], value: [f32; 3]) {
        self.insert(ids, value);
    }

    fn short_ray(&mut self) {
        self.stats.short_rays += 1;
    }
}

/// One tile's shared-scope cache endpoint: frozen snapshot bank +
/// session-private overlay bank + the delta's insertion log (with its
/// set-level compaction cursor) and stats.
struct SharedBank<'a> {
    frozen: &'a RadianceCache,
    overlay: &'a mut RadianceCache,
    log: &'a mut Vec<LoggedInsert>,
    last_in_set: &'a mut HashMap<(u32, u32), u32>,
    stats: &'a mut CacheStats,
    group: u32,
}

impl PixelCache for SharedBank<'_> {
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)> {
        self.stats.lookups += 1;
        // The session's own inserts are freshest: overlay first.
        if let Some(v) = self.overlay.probe_touch(ids) {
            self.stats.hits += 1;
            return Some((v, false));
        }
        if let Some(v) = self.frozen.probe(ids) {
            self.stats.hits += 1;
            self.stats.snapshot_hits += 1;
            return Some((v, true));
        }
        None
    }

    fn store(&mut self, ids: &[u32], value: [f32; 3]) {
        let mut rec = LoggedInsert {
            group: self.group,
            k: ids.len() as u8,
            ids: [0; MAX_SIG_K],
            value,
        };
        rec.ids[..ids.len()].copy_from_slice(ids);
        // Set-level net-effect coalescing: when the most recent insert
        // into this (group, set) carries the same tag, replaying
        // [X=a, <other-set inserts>, X=b] is state-identical to
        // replaying [X=b at X=a's position, <other-set inserts>] —
        // inserts into other sets never touch this set's ways or pLRU
        // bits, and the later insert is an in-place update touching
        // exactly the way the earlier one placed (X cannot be evicted
        // in between: nothing else landed in its set). So the earlier
        // entry absorbs the new value, exactly — `tests` pins bitwise
        // replay equivalence. Re-misses of the same hot tags across an
        // epoch's frames (the dominant log growth) collapse to one
        // entry per tag run, bounding delta memory by tag alternations
        // per touched set rather than the epoch's miss count.
        let set = self.overlay.set_index(ids) as u32;
        let key = (self.group, set);
        let coalesced = match self.last_in_set.get(&key) {
            Some(&idx) => {
                let last = &mut self.log[idx as usize];
                if last.k == rec.k && last.ids == rec.ids {
                    last.value = rec.value;
                    true
                } else {
                    false
                }
            }
            None => false,
        };
        if !coalesced {
            self.last_in_set.insert(key, self.log.len() as u32);
            self.log.push(rec);
        }
        match self.overlay.insert_tracked(ids, value) {
            InsertOutcome::Updated => {}
            InsertOutcome::Filled => self.stats.inserts += 1,
            InsertOutcome::Evicted => {
                self.stats.inserts += 1;
                self.stats.evictions += 1;
            }
        }
    }

    fn short_ray(&mut self) {
        self.stats.short_rays += 1;
    }
}

/// One tile's world-scope cache endpoint: the frozen world snapshot +
/// the session's overlay/log/touched state. Unlike the geometry scopes
/// there are no per-tile banks — every tile probes the same table; the
/// struct is rebuilt per tile only to mirror the driver's shape.
struct WorldBank<'a> {
    frozen: &'a WorldSnapshot,
    overlay: &'a mut HashMap<u64, [f32; 3]>,
    log: &'a mut Vec<WorldInsert>,
    log_index: &'a mut HashMap<u64, u32>,
    touched: &'a mut Vec<u64>,
    touched_set: &'a mut HashSet<u64>,
    stats: &'a mut CacheStats,
    positions: &'a [Vec3],
    cam: [f32; 3],
}

impl WorldBank<'_> {
    /// The tag collapses to the *first* significant Gaussian's world
    /// cell + view-direction bucket: rays whose integration starts at
    /// the same surface from the same direction band share radiance
    /// across poses, tiers, and resolutions. The query stays gated on a
    /// full k-long alpha-record (identical control flow to the geometry
    /// scopes — the coarser key can only widen the hit set).
    fn key_for(&self, ids: &[u32]) -> u64 {
        world_key_for(self.positions[ids[0] as usize], self.cam, &self.frozen.params)
    }
}

impl PixelCache for WorldBank<'_> {
    fn query(&mut self, ids: &[u32]) -> Option<([f32; 3], bool)> {
        self.stats.lookups += 1;
        let key = self.key_for(ids);
        // The session's own inserts are freshest: overlay first (a
        // point lookup — hash iteration stays off the render path).
        if let Some(&v) = self.overlay.get(&key) {
            self.stats.hits += 1;
            return Some((v, false));
        }
        let (slot, probes) = self.frozen.table.find(key, self.frozen.params.probe_len);
        self.stats.record_probe(probes);
        if let Some(slot) = slot {
            self.stats.hits += 1;
            self.stats.snapshot_hits += 1;
            if self.touched_set.insert(key) {
                self.touched.push(key);
            }
            return Some((self.frozen.table.values[slot], true));
        }
        None
    }

    fn store(&mut self, ids: &[u32], value: [f32; 3]) {
        let key = self.key_for(ids);
        match self.log_index.get(&key) {
            Some(&idx) => {
                // Per-key net-effect fold: the merge is last-value-wins
                // per (key, session), so collapsing re-stores in place
                // is exact; `freq` keeps the re-store count for the
                // lifetime-weighted merge.
                let e = &mut self.log[idx as usize];
                e.value = value;
                e.freq = e.freq.saturating_add(1);
            }
            None => {
                self.log_index.insert(key, self.log.len() as u32);
                self.log.push(WorldInsert { key, value, freq: 1 });
                self.stats.inserts += 1;
            }
        }
        self.overlay.insert(key, value);
    }

    fn short_ray(&mut self) {
        self.stats.short_rays += 1;
    }
}

/// The compositing loop shared by both topologies — identical math and
/// control flow to the original private-path compositor; only the cache
/// endpoint is generic.
fn composite_pixel_cached_generic<C: PixelCache>(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    k: usize,
    bank: &mut C,
    record_uncached: bool,
) -> ([f32; 3], PixelOutcome) {
    let mut c = [0.0f32; 3];
    let mut t = 1.0f32;
    let mut iterated = 0u32;
    let mut significant = 0u32;
    let mut sig_ids = [0u32; MAX_SIG_K];
    let mut sig_n = 0usize;
    let mut queried = false;

    for (si, s) in splats.iter().enumerate() {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        if sig_n < k {
            sig_ids[sig_n] = s.id;
            sig_n += 1;
        }
        significant += 1;
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            // Terminated before the cache query resolved: value is final
            // and identical to the uncached pipeline's.
            return (
                c,
                PixelOutcome {
                    iterated,
                    significant,
                    hit: false,
                    snapshot_hit: false,
                    uncached_iterated: iterated,
                    uncached_significant: significant,
                },
            );
        }
        let w = alpha * t;
        c[0] += w * s.color[0];
        c[1] += w * s.color[1];
        c[2] += w * s.color[2];
        t = test_t;

        // Once the alpha-record fills, query the cache (paper step 4).
        if sig_n == k && !queried {
            queried = true;
            if let Some((value, from_snapshot)) = bank.query(&sig_ids[..k]) {
                // Hit: the cached RGB replaces the remaining integration.
                // When recording, keep scanning (count-only, same math
                // and transmittance) to recover the uncached counts the
                // plain compositor would have produced.
                let (ui, us) = if record_uncached {
                    scan_uncached(&splats[si + 1..], px, py, t, iterated, significant)
                } else {
                    (iterated, significant)
                };
                return (
                    value,
                    PixelOutcome {
                        iterated,
                        significant,
                        hit: true,
                        snapshot_hit: from_snapshot,
                        uncached_iterated: ui,
                        uncached_significant: us,
                    },
                );
            }
        }
    }

    // Miss (or short ray): full value computed; update the cache.
    if queried {
        bank.store(&sig_ids[..k], c);
    } else {
        bank.short_ray();
    }
    (
        c,
        PixelOutcome {
            iterated,
            significant,
            hit: false,
            snapshot_hit: false,
            uncached_iterated: iterated,
            uncached_significant: significant,
        },
    )
}

/// Continue a pixel's tile-list scan past a cache hit without
/// accumulating color: replicates the plain compositor's control flow
/// (fast reject, alpha test, early termination) so the returned counts
/// are bit-identical to an uncached stats pass.
fn scan_uncached(
    rest: &[GatheredSplat],
    px: f32,
    py: f32,
    mut t: f32,
    mut iterated: u32,
    mut significant: u32,
) -> (u32, u32) {
    for s in rest {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        significant += 1;
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            break;
        }
        t = test_t;
    }
    (iterated, significant)
}

/// The radiance-cached [`RasterBackend`]: the RC raster stage of the
/// frame loop, carrying per-session cache state across frames — a
/// private [`GroupedRadianceCache`] or a shared snapshot + delta,
/// behind the [`CacheView`] topology seam.
pub struct CachedRaster {
    view: CacheView,
    record_uncached: bool,
}

impl CachedRaster {
    /// Private scope: the session owns its cache outright (today's
    /// behavior, bit-for-bit). `record_uncached` asks every frame for
    /// single-pass uncached per-pixel counts (required by cost models
    /// whose `needs_uncached_stats` is true, e.g. the GPU warp model).
    pub fn new(cache: GroupedRadianceCache, record_uncached: bool) -> Self {
        CachedRaster { view: CacheView::private(cache), record_uncached }
    }

    /// Shared scope: render against a pool snapshot, logging inserts
    /// into a fresh session delta.
    pub fn shared(snapshot: Arc<CacheSnapshot>, record_uncached: bool) -> Self {
        CachedRaster { view: CacheView::shared(snapshot), record_uncached }
    }

    /// World scope: render against the pool's world-space snapshot,
    /// logging inserts into a fresh session delta. `scene` must be the
    /// *full* source scene (tier reductions are prefix subsamples, so
    /// reduced-tier Gaussian IDs stay valid indices into it); `k` is
    /// the alpha-record length gating the query.
    pub fn world(
        snapshot: Arc<WorldSnapshot>,
        scene: Arc<GaussianScene>,
        k: usize,
        record_uncached: bool,
    ) -> Self {
        CachedRaster { view: CacheView::world(snapshot, scene, k), record_uncached }
    }

    /// The underlying cache view (for occupancy/stats inspection).
    pub fn view(&self) -> &CacheView {
        &self.view
    }
}

impl RasterBackend for CachedRaster {
    fn label(&self) -> &'static str {
        "radiance-cached"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        let out = rasterize_cached_view(
            projected,
            bins,
            width,
            height,
            &mut self.view,
            self.record_uncached,
        );
        let swap_bytes = self.view.swap_bytes_for_frame();
        RasterFrame {
            image: out.image,
            work: RasterWork {
                width,
                height,
                consumed: out.outcomes.iter().map(|o| o.iterated).collect(),
                significant: out.outcomes.iter().map(|o| o.significant).collect(),
                uncached: out.uncached,
                cache_outcomes: Some(
                    out.outcomes
                        .iter()
                        .map(|o| match (o.hit, o.snapshot_hit) {
                            (true, true) => 3u8,
                            (true, false) => 2,
                            _ => 1,
                        })
                        .collect(),
                ),
                cache: out.stats,
                cache_shared: self.view.is_shared(),
                shared_probe_len: self.view.shared_probe_len(),
                swap_bytes,
            },
        }
    }

    fn take_cache_delta(&mut self) -> Option<CacheDelta> {
        self.view.take_delta()
    }

    fn install_cache_snapshot(&mut self, snapshot: Arc<CacheSnapshot>, sharers: usize) {
        self.view.install_snapshot(snapshot, sharers);
    }

    fn take_world_delta(&mut self) -> Option<WorldDelta> {
        self.view.take_world_delta()
    }

    fn install_world_snapshot(&mut self, snapshot: Arc<WorldSnapshot>, sharers: usize) {
        self.view.install_world_snapshot(snapshot, sharers);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Intrinsics, Pose};
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::pipeline::raster::{rasterize, RasterConfig};
    use crate::pipeline::sort::bin_and_sort;
    use crate::scene::synth::test_scene;

    #[test]
    fn index_tag_deterministic_and_sensitive() {
        let cache = RadianceCache::paper_default(5);
        let ids = [100, 200, 300, 400, 500];
        let (s1, t1) = cache.index_tag(&ids);
        let (s2, t2) = cache.index_tag(&ids);
        assert_eq!((s1, t1), (s2, t2));
        let ids2 = [100, 200, 300, 400, 1000]; // differs above bit 3
        // Changing one ID changes index and/or tag.
        assert_ne!(cache.index_tag(&ids2), (s1, t1));
        assert!(s1 < CACHE_SETS);
    }

    #[test]
    fn id_bits_outside_window_ignored() {
        // Bits below CACHE_ID_LO_BIT (=3) are not part of index/tag:
        // matches the paper's 3rd..18th-LSB field.
        let cache = RadianceCache::paper_default(2);
        let a = cache.index_tag(&[0b1000, 0b10000]);
        let b = cache.index_tag(&[0b1001, 0b10111]);
        assert_eq!(a, b);
    }

    #[test]
    fn lookup_insert_roundtrip() {
        let mut cache = RadianceCache::paper_default(5);
        let ids = [1 << 3, 2 << 3, 3 << 3, 4 << 3, 5 << 3];
        assert!(cache.lookup(&ids).is_none());
        cache.insert(&ids, [0.1, 0.2, 0.3]);
        assert_eq!(cache.lookup(&ids), Some([0.1, 0.2, 0.3]));
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(cache.stats.lookups, 2);
    }

    #[test]
    fn plru_evicts_cold_way() {
        let mut cache = RadianceCache::new(4, 2, 1);
        // 5 tags mapping to the same set (set bits = lowest index bit of
        // the 16-bit field; craft IDs that share it).
        let mk = |i: u32| [((i << 1) | 0) << CACHE_ID_LO_BIT];
        for i in 0..4 {
            cache.insert(&mk(i), [i as f32; 3]);
        }
        assert_eq!(cache.occupancy(), 4);
        // Touch tags 1..3 so tag 0 becomes the pLRU victim.
        for i in 1..4 {
            assert!(cache.lookup(&mk(i)).is_some());
        }
        cache.insert(&mk(9), [9.0; 3]);
        assert_eq!(cache.stats.evictions, 1);
        assert!(cache.lookup(&mk(0)).is_none(), "cold way should be evicted");
        assert!(cache.lookup(&mk(9)).is_some());
    }

    #[test]
    fn flush_empties() {
        let mut cache = RadianceCache::paper_default(3);
        cache.insert(&[8, 16, 24], [0.5; 3]);
        assert_eq!(cache.occupancy(), 1);
        cache.flush();
        assert_eq!(cache.occupancy(), 0);
        assert!(cache.lookup(&[8, 16, 24]).is_none());
    }

    /// Test scene with the oversized-Gaussian tail clamped — the regime
    /// cache-aware fine-tuning produces (Sec. 3.3); the unclamped tail is
    /// exercised by the fig13/fig21 harnesses instead.
    fn clamped_scene(seed: u64, n: usize) -> crate::scene::GaussianScene {
        let mut scene = test_scene(seed, n);
        let cap = 0.06; // ~5x the median scale for SyntheticSmall
        for s in scene.scale.iter_mut() {
            s.x = s.x.min(cap);
            s.y = s.y.min(cap);
            s.z = s.z.min(cap);
        }
        scene
    }

    fn render_setup() -> (crate::pipeline::project::ProjectedScene, crate::pipeline::sort::TileBins, Intrinsics)
    {
        let scene = clamped_scene(77, 4000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        (p, bins, intr)
    }

    #[test]
    fn cold_cache_first_frame_stays_faithful() {
        // Frame 0: the cache starts empty but fills as pixels complete,
        // so *intra-frame* hits occur between pixels sharing the same
        // initial significant Gaussians (the paper's ray-similarity
        // insight applied within a frame). Quality must stay near-exact.
        let (p, bins, intr) = render_setup();
        let plain = rasterize(&p, &bins, intr.width, intr.height, &RasterConfig::default());
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let cached = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        let q = crate::metrics::psnr(&plain.image, &cached.image);
        assert!(q > 28.0, "first-frame RC quality {q} dB");
        // Miss pixels must be bit-exact: check a hit-free pixel.
        let miss_idx = cached
            .outcomes
            .iter()
            .position(|o| !o.hit)
            .expect("some pixel missed");
        let (x, y) = (miss_idx % intr.width, miss_idx / intr.width);
        assert_eq!(plain.image.at(x, y), cached.image.at(x, y));
    }

    #[test]
    fn second_frame_hits_and_saves_work() {
        let (p, bins, intr) = render_setup();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let first = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        let second = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        assert!(second.stats.hit_rate() > 0.5, "hit rate {}", second.stats.hit_rate());
        // Identical pose -> replay reproduces the first frame closely
        // (hit pixels return cached values; those were themselves RC
        // outputs, so the images converge rather than match bitwise).
        let q = crate::metrics::psnr(&first.image, &second.image);
        assert!(q > 38.0, "same-pose replay diverged: {q} dB");
        // Work saved: hits iterate less than the first pass.
        let w1: u64 = first.outcomes.iter().map(|o| o.iterated as u64).sum();
        let w2: u64 = second.outcomes.iter().map(|o| o.iterated as u64).sum();
        assert!(w2 < w1, "cached pass did not save work: {w1} -> {w2}");
    }

    #[test]
    fn nearby_pose_still_hits_often() {
        let scene = clamped_scene(77, 4000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose1 = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let pose2 = Pose::look_at(Vec3::new(0.01, 0.002, -4.0), Vec3::ZERO);
        let p1 = project(&scene, &pose1, &intr, 0.2, 100.0, 0.0);
        let b1 = bin_and_sort(&p1, &intr, 16, 0.0);
        let p2 = project(&scene, &pose2, &intr, 0.2, 100.0, 0.0);
        let b2 = bin_and_sort(&p2, &intr, 16, 0.0);
        let mut cache = GroupedRadianceCache::new(b1.tiles_x, b1.tiles_y, 5);
        rasterize_cached(&p1, &b1, intr.width, intr.height, &mut cache);
        let out = rasterize_cached(&p2, &b2, intr.width, intr.height, &mut cache);
        assert!(
            out.stats.hit_rate() > 0.3,
            "nearby pose hit rate {}",
            out.stats.hit_rate()
        );
        // Quality: overall PSNR stays high, and the *median* hit-pixel
        // color error reproduces the paper's Fig. 12 claim (average color
        // difference ~0.5-1.0 out of 255 for k=5). The tail is heavier
        // than in trained scenes (DESIGN.md §8: synthetic statistics),
        // which is what cache-aware fine-tuning addresses.
        let exact = rasterize(&p2, &b2, intr.width, intr.height, &RasterConfig::default());
        let psnr = crate::metrics::psnr(&exact.image, &out.image);
        assert!(psnr > 27.0, "cached quality {psnr} dB");
        let mut diffs: Vec<f32> = out
            .outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| o.hit)
            .map(|(i, _)| {
                let (x, y) = (i % intr.width, i / intr.width);
                let a = out.image.at(x, y);
                let b = exact.image.at(x, y);
                ((a[0] - b[0]).abs() + (a[1] - b[1]).abs() + (a[2] - b[2]).abs()) / 3.0
                    * 255.0
            })
            .collect();
        diffs.sort_by(f32::total_cmp);
        let median = diffs[diffs.len() / 2];
        assert!(median < 3.0, "median hit color diff {median}/255 (paper: <1.0)");
    }

    #[test]
    fn single_pass_uncached_stats_match_two_pass() {
        // The RC-GPU cost model used to re-rasterize the whole frame
        // uncached just to recover warp aggregates; the single-pass
        // recording must reproduce that second pass bit-for-bit.
        let (p, bins, intr) = render_setup();
        let plain_cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let plain = rasterize(&p, &bins, intr.width, intr.height, &plain_cfg);
        let plain_stats = plain.stats.unwrap();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        // Cold pass (intra-frame hits) and warm pass (heavy hits): the
        // recorded uncached counts must match the plain pass in both.
        for pass in 0..2 {
            let out =
                rasterize_cached_ex(&p, &bins, intr.width, intr.height, &mut cache, true);
            let unc = out.uncached.expect("recording requested");
            assert_eq!(unc.iterated, plain_stats.iterated, "pass {pass} iterated");
            assert_eq!(unc.significant, plain_stats.significant, "pass {pass} significant");
            if pass == 1 {
                assert!(out.stats.hits > 0, "warm pass should hit");
            }
        }
    }

    #[test]
    fn unrecorded_pass_reports_actual_counts() {
        let (p, bins, intr) = render_setup();
        let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, 5);
        let out = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
        assert!(out.uncached.is_none());
        for o in &out.outcomes {
            assert_eq!(o.uncached_iterated, o.iterated);
            assert_eq!(o.uncached_significant, o.significant);
        }
    }

    #[test]
    fn smaller_k_hits_more() {
        let (p, bins, intr) = render_setup();
        let mut rates = Vec::new();
        for k in [2usize, 5, 8] {
            let mut cache = GroupedRadianceCache::new(bins.tiles_x, bins.tiles_y, k);
            rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
            let out = rasterize_cached(&p, &bins, intr.width, intr.height, &mut cache);
            rates.push(out.stats.hit_rate());
        }
        // Fig. 24: hit rate falls as the alpha-record grows. Same-pose
        // replay saturates near 100%, so only the endpoints separate
        // cleanly here; the full monotone sweep is fig24's harness (which
        // uses a moving trajectory).
        assert!(rates[0] > rates[2], "rates {rates:?}");
        assert!(rates[0] > 0.9, "k=2 same-pose replay should saturate: {rates:?}");
    }

    #[test]
    fn groups_are_independent_banks() {
        let mut cache = GroupedRadianceCache::new(8, 8, 5);
        assert_eq!(cache.num_banks(), 4);
        let ids = [8, 16, 24, 32, 40];
        cache.bank_for_tile_mut(0, 0).insert(&ids, [1.0; 3]);
        assert!(cache.bank_for_tile_mut(0, 0).lookup(&ids).is_some());
        assert!(cache.bank_for_tile_mut(7, 7).lookup(&ids).is_none());
        // The read accessor probes without exclusive access — the split
        // that makes Arc-shared snapshots possible at all.
        assert!(cache.bank_for_tile(0, 0).probe(&ids).is_some());
        assert!(cache.bank_for_tile(7, 7).probe(&ids).is_none());
    }

    #[test]
    fn swap_traffic_grows_with_occupancy() {
        let mut cache = GroupedRadianceCache::new(4, 4, 5);
        assert_eq!(cache.swap_traffic_bytes(), 0);
        cache.bank_for_tile_mut(0, 0).insert(&[8, 16, 24, 32, 40], [0.5; 3]);
        assert_eq!(cache.swap_traffic_bytes(), 26); // 13 B x 2 directions
    }

    #[test]
    fn stats_merge_and_hit_rate_on_empty_and_partial() {
        // Empty stats: no lookups -> defined 0.0 hit rate, and merging
        // an empty into an empty stays empty.
        let mut a = CacheStats::default();
        assert_eq!(a.hit_rate(), 0.0);
        a.merge(&CacheStats::default());
        assert_eq!(a, CacheStats::default());
        // Partial: merge accumulates every field and hit_rate follows.
        let b = CacheStats {
            lookups: 8,
            hits: 2,
            snapshot_hits: 1,
            inserts: 6,
            evictions: 1,
            short_rays: 3,
            decay_evictions: 2,
            probe_hist: [4, 3, 1, 0, 0, 0, 0, 0],
        };
        a.merge(&b);
        assert_eq!(a, b);
        assert_eq!(a.hit_rate(), 0.25);
        let c = CacheStats { lookups: 8, hits: 6, ..CacheStats::default() };
        a.merge(&c);
        assert_eq!(a.lookups, 16);
        assert_eq!(a.hits, 8);
        assert_eq!(a.snapshot_hits, 1);
        assert_eq!(a.inserts, 6);
        assert_eq!(a.hit_rate(), 0.5);
        // Merging empty into partial changes nothing.
        let before = a;
        a.merge(&CacheStats::default());
        assert_eq!(a, before);
    }

    #[test]
    fn frozen_probe_never_mutates() {
        let mut bank = RadianceCache::paper_default(5);
        let ids = [8, 16, 24, 32, 40];
        bank.insert(&ids, [0.25; 3]);
        let stats = bank.stats;
        for _ in 0..3 {
            assert_eq!(bank.probe(&ids), Some([0.25; 3]));
            assert_eq!(bank.probe(&[48, 56, 64, 72, 80]), None);
        }
        assert_eq!(bank.stats, stats, "probe must not touch stats");
        assert_eq!(bank.occupancy(), 1);
    }

    fn geom(tiles: usize, k: usize) -> CacheGeometry {
        CacheGeometry { tiles_x: tiles, tiles_y: tiles, k }
    }

    #[test]
    fn shared_view_overlay_snapshot_precedence_and_provenance() {
        // Snapshot holds tag A; the session inserts tag B and re-inserts
        // A with a fresher value: lookups must prefer the overlay, and
        // provenance must tell snapshot hits from own hits.
        let g = geom(4, 5);
        let ids_a = [8u32, 16, 24, 32, 40];
        let ids_b = [48u32, 56, 64, 72, 80];
        let mut base = CacheSnapshot::empty(g);
        base.cache.bank_for_tile_mut(0, 0).insert(&ids_a, [0.1; 3]);
        let snap = Arc::new(base);
        let mut view = CacheView::shared(snap.clone());
        let CacheView::Shared { snapshot, delta, .. } = &mut view else { unreachable!() };
        let probe = |snapshot: &CacheSnapshot, delta: &mut CacheDelta, ids: &[u32]| {
            let group = delta.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snapshot.cache.bank_for_tile(0, 0),
                overlay: delta.overlay.bank_for_tile_mut(0, 0),
                log: &mut delta.log,
                last_in_set: &mut delta.last_in_set,
                stats: &mut delta.stats,
                group,
            };
            bank.query(ids)
        };
        assert_eq!(probe(&**snapshot, delta, &ids_a), Some(([0.1; 3], true)), "snapshot hit");
        assert_eq!(probe(&**snapshot, delta, &ids_b), None);
        // Session inserts B and overrides A.
        {
            let group = delta.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snapshot.cache.bank_for_tile(0, 0),
                overlay: delta.overlay.bank_for_tile_mut(0, 0),
                log: &mut delta.log,
                last_in_set: &mut delta.last_in_set,
                stats: &mut delta.stats,
                group,
            };
            bank.store(&ids_b, [0.5; 3]);
            bank.store(&ids_a, [0.9; 3]);
        }
        assert_eq!(probe(&**snapshot, delta, &ids_b), Some(([0.5; 3], false)), "own hit");
        assert_eq!(probe(&**snapshot, delta, &ids_a), Some(([0.9; 3], false)), "overlay wins");
        let s = delta.stats();
        assert_eq!(s.lookups, 5);
        assert_eq!(s.hits, 3);
        assert_eq!(s.snapshot_hits, 1);
        assert_eq!(s.inserts, 2);
        assert_eq!(delta.len(), 2, "every store is logged, updates included");
        // The snapshot itself never changed.
        assert_eq!(snap.occupancy(), 1);
        assert_eq!(snap.lookup(0, 0, &ids_a), Some([0.1; 3]));
    }

    #[test]
    fn compacted_log_replays_bitwise_identically_to_uncompacted() {
        // The set-level coalescing contract: a compacted delta log,
        // replayed into a (non-empty) snapshot, must produce a cache
        // whose entries AND pLRU state match an uncompacted
        // insert-by-insert replay of the exact store sequence — while
        // the log itself stays bounded by tag alternations per set.
        let g = geom(4, 2);
        // k = 2, 1024 sets => 5 index bits per ID: `field(hi, lo)`
        // places `lo` in the set-index bits and `hi` in the tag bits,
        // so same-`lo` ids share a set and same-`hi` ids share a tag.
        let field = |hi: u32, lo: u32| ((hi << 5) | lo) << 3;
        let tag_a = [field(0, 1), field(0, 2)]; // set S1
        let tag_b = [field(1, 1), field(0, 2)]; // set S1, different tag
        let tag_c = [field(0, 3), field(0, 4)]; // a different set S2

        // Non-empty initial state: the snapshot already holds tag A.
        let mut base = CacheSnapshot::empty(g);
        base.cache.bank_for_tile_mut(0, 0).insert(&tag_a, [0.05; 3]);
        let snap = Arc::new(base);

        // The store sequence, with same-set repeats (fold), an
        // other-set interleave (must not break the fold), and a tag
        // alternation (must NOT fold).
        let seq: Vec<([u32; 2], [f32; 3])> = vec![
            (tag_a, [0.1; 3]),
            (tag_a, [0.2; 3]), // folds into the previous entry
            (tag_b, [0.3; 3]), // same set, new tag: alternation
            (tag_c, [0.4; 3]), // other set
            (tag_a, [0.5; 3]), // set's last insert is B: no fold
            (tag_c, [0.6; 3]), // folds across the set boundary above
            (tag_a, [0.7; 3]), // folds into the 0.5 entry: C was other-set
        ];

        let mut delta = CacheDelta::new(g);
        // Uncompacted reference: every store applied in true order.
        let mut reference = snap.cache.clone();
        {
            let group = delta.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snap.cache.bank_for_tile(0, 0),
                overlay: delta.overlay.bank_for_tile_mut(0, 0),
                log: &mut delta.log,
                last_in_set: &mut delta.last_in_set,
                stats: &mut delta.stats,
                group,
            };
            for (ids, v) in &seq {
                bank.store(ids, *v);
                reference.bank_for_tile_mut(0, 0).insert_tracked(ids, *v);
            }
        }
        assert_eq!(delta.len(), 4, "7 stores must compact to 4 log entries");

        let mut merged = snap.cache.clone();
        merged.replay(&delta.log);
        assert!(
            merged.state_eq(&reference),
            "compacted replay diverged from uncompacted replay"
        );
        // And the values landed: the folds kept the *last* value.
        assert_eq!(merged.bank_for_tile(0, 0).probe(&tag_a), Some([0.7; 3]));
        assert_eq!(merged.bank_for_tile(0, 0).probe(&tag_b), Some([0.3; 3]));
        assert_eq!(merged.bank_for_tile(0, 0).probe(&tag_c), Some([0.6; 3]));

        // The ordered multi-session merge stays equivalent too:
        // session 1's (compacted) delta replayed before session 2's
        // must match the sequential uncompacted replay of both.
        let mk = |stores: &[([u32; 2], [f32; 3])], reference: &mut GroupedRadianceCache| {
            let mut d = CacheDelta::new(g);
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snap.cache.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            for (ids, v) in stores {
                bank.store(ids, *v);
                reference.bank_for_tile_mut(0, 0).insert_tracked(ids, *v);
            }
            d
        };
        let mut reference = snap.cache.clone();
        let d1 = mk(&[(tag_a, [0.11; 3]), (tag_a, [0.12; 3])], &mut reference);
        let d2 = mk(&[(tag_b, [0.21; 3]), (tag_a, [0.22; 3])], &mut reference);
        assert_eq!(d1.len(), 1, "session 1's run of A folds to one entry");
        let mut merged = snap.cache.clone();
        merged.replay(&d1.log);
        merged.replay(&d2.log);
        assert!(merged.state_eq(&reference), "ordered merge equivalence broke");

        // A detached delta starts with a fresh compaction cursor.
        let mut d = CacheDelta::new(g);
        {
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: snap.cache.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            bank.store(&tag_a, [0.9; 3]);
        }
        let mut view = CacheView::Shared {
            snapshot: snap.clone(),
            delta: d,
            pending_snapshot_bytes: 0,
        };
        let taken = view.take_delta().unwrap();
        assert_eq!(taken.len(), 1);
        let CacheView::Shared { delta, .. } = &view else { unreachable!() };
        assert!(delta.is_empty() && delta.last_in_set.is_empty());
    }

    #[test]
    fn hub_merges_deltas_in_session_index_order() {
        let g = geom(4, 5);
        let hub = CacheHub::new();
        let empty = hub.snapshot_for(g);
        assert_eq!(empty.epoch(), 0);
        let ids = [8u32, 16, 24, 32, 40];
        // Two sessions insert the same tag with different values: the
        // later session's insert must win (session-index replay order).
        let mk_delta = |value: [f32; 3]| {
            let mut d = CacheDelta::new(g);
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let mut bank = SharedBank {
                frozen: empty.cache.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            bank.store(&ids, value);
            d
        };
        hub.merge_in_order(vec![mk_delta([0.1; 3]), mk_delta([0.7; 3])]);
        let merged = hub.snapshot_for(g);
        assert_eq!(merged.epoch(), 1);
        assert_eq!(merged.lookup(0, 0, &ids), Some([0.7; 3]), "later session wins");
        assert_eq!(merged.occupancy(), 1);
        // Reversed order flips the winner — order is the contract.
        let hub2 = CacheHub::new();
        hub2.merge_in_order(vec![mk_delta([0.7; 3]), mk_delta([0.1; 3])]);
        assert_eq!(hub2.snapshot_for(g).lookup(0, 0, &ids), Some([0.1; 3]));
        // An all-empty merge keeps the snapshot (same Arc, same epoch).
        let before = hub.snapshot_for(g);
        hub.merge_in_order(vec![CacheDelta::new(g)]);
        assert!(Arc::ptr_eq(&before, &hub.snapshot_for(g)));
    }

    #[test]
    fn multi_geometry_merge_publishes_deterministically() {
        // Pins the publish contract behind the dirty-map BTreeMap swap:
        // a merge touching several geometries at once must produce
        // snapshots that are a pure function of the delta sequence —
        // identical across repeated merges into fresh hubs — with
        // last-session-wins within each geometry and untouched
        // geometries keeping their exact Arc.
        let ga = geom(4, 5);
        let gb = geom(8, 5);
        let gc = geom(2, 5); // never dirtied
        let ids = [8u32, 16, 24, 32, 40];
        let mk_delta = |g: CacheGeometry, value: [f32; 3]| {
            let mut d = CacheDelta::new(g);
            let group = d.overlay.group_for_tile(0, 0) as u32;
            let frozen = GroupedRadianceCache::new(g.tiles_x, g.tiles_y, g.k);
            let mut bank = SharedBank {
                frozen: frozen.bank_for_tile(0, 0),
                overlay: d.overlay.bank_for_tile_mut(0, 0),
                log: &mut d.log,
                last_in_set: &mut d.last_in_set,
                stats: &mut d.stats,
                group,
            };
            bank.store(&ids, value);
            d
        };
        // Interleave geometries so the dirty map sees gb before ga is
        // finished — publish order must still be canonical.
        let run = || {
            let hub = CacheHub::new();
            let untouched = hub.snapshot_for(gc);
            hub.merge_in_order(vec![
                mk_delta(ga, [0.1; 3]),
                mk_delta(gb, [0.4; 3]),
                mk_delta(ga, [0.9; 3]),
            ]);
            assert!(
                Arc::ptr_eq(&untouched, &hub.snapshot_for(gc)),
                "untouched geometry must keep its Arc"
            );
            assert_eq!(hub.snapshot_for(gc).epoch(), 0);
            (hub.snapshot_for(ga), hub.snapshot_for(gb))
        };
        let (a1, b1) = run();
        let (a2, b2) = run();
        assert_eq!(a1.epoch(), 1);
        assert_eq!(b1.epoch(), 1);
        assert_eq!(a1.lookup(0, 0, &ids), Some([0.9; 3]), "last session wins");
        assert_eq!(b1.lookup(0, 0, &ids), Some([0.4; 3]));
        assert!(a1.cache.state_eq(&a2.cache), "merge must be a pure function of deltas");
        assert!(b1.cache.state_eq(&b2.cache));
        assert_eq!(a1.epoch(), a2.epoch());
        assert_eq!(b1.epoch(), b2.epoch());
    }

    #[test]
    fn shared_swap_traffic_charged_once_per_snapshot_install() {
        let g = geom(4, 5);
        let mut base = CacheSnapshot::empty(g);
        // Leading IDs spread across sets (low index bits vary), so all
        // ten inserts coexist without evictions.
        for i in 0..10u32 {
            base.cache.bank_for_tile_mut(0, 0).insert(&[(i + 1) << 3, 16, 24, 32, 40], [0.5; 3]);
        }
        assert_eq!(base.occupancy(), 10);
        let bytes = base.swap_traffic_bytes() as u64;
        assert_eq!(bytes, 10 * 13 * 2);
        let snap = Arc::new(base);

        // Private scope: the whole occupancy is charged EVERY frame.
        let mut private = CacheView::private(snap.cache.clone());
        assert_eq!(private.swap_bytes_for_frame(), bytes);
        assert_eq!(private.swap_bytes_for_frame(), bytes);

        // Shared scope: the snapshot share is charged once per install,
        // then only the session's own delta working set.
        let mut view = CacheView::shared(snap.clone());
        assert_eq!(view.swap_bytes_for_frame(), bytes, "fresh attach reloads once");
        assert_eq!(view.swap_bytes_for_frame(), 0, "steady frames charge only the delta");
        // Re-installing the same snapshot (sharer refresh) is free.
        view.install_snapshot(snap.clone(), 4);
        assert_eq!(view.swap_bytes_for_frame(), 0);
        // A new merged snapshot charges the amortized share only.
        let next = Arc::new(CacheSnapshot { cache: snap.cache.clone(), epoch: snap.epoch() + 1 });
        view.install_snapshot(next, 4);
        assert_eq!(view.swap_bytes_for_frame(), bytes.div_ceil(4));
        assert_eq!(view.swap_bytes_for_frame(), 0);
    }

    #[test]
    fn shared_rasterization_hits_across_sessions_after_merge() {
        // Session A renders a frame (cold snapshot), the pool merges its
        // delta, session B renders the same pose against the merged
        // snapshot: B's first frame must hit where A inserted, with
        // snapshot provenance — the cross-session redundancy win.
        let (p, bins, intr) = render_setup();
        let g = CacheGeometry { tiles_x: bins.tiles_x, tiles_y: bins.tiles_y, k: 5 };
        let hub = CacheHub::new();
        let mut a = CacheView::shared(hub.snapshot_for(g));
        let cold =
            rasterize_cached_view(&p, &bins, intr.width, intr.height, &mut a, false);
        assert_eq!(cold.stats.snapshot_hits, 0, "cold snapshot cannot hit");
        hub.merge_in_order(vec![a.take_delta().unwrap()]);

        let mut b = CacheView::shared(hub.snapshot_for(g));
        let warm =
            rasterize_cached_view(&p, &bins, intr.width, intr.height, &mut b, false);
        assert!(
            warm.stats.snapshot_hits > 0,
            "cross-session hits expected: {:?}",
            warm.stats
        );
        assert!(warm.stats.hit_rate() > cold.stats.hit_rate());
        // Provenance is consistent between stats and outcomes.
        let snap_hits =
            warm.outcomes.iter().filter(|o| o.snapshot_hit).count() as u64;
        assert_eq!(snap_hits, warm.stats.snapshot_hits);
        // B hits at least as often as a private second pass over the
        // same pose would, since A's inserts cover the same rays.
        assert!(warm.stats.hit_rate() > 0.5, "hit rate {}", warm.stats.hit_rate());
    }

    // ---- world-space hash cache -------------------------------------

    fn wparams(cells: usize, lifetime: u16) -> WorldParams {
        WorldParams {
            cells,
            base_cell_size: 0.25,
            lod_distance: 4.0,
            lifetime,
            probe_len: 4,
            dir_buckets: 4,
        }
    }

    /// Build a session delta the way [`WorldBank::store`]/`query` would:
    /// one compacted log entry per key plus the touched-key set.
    fn wdelta(inserts: &[(u64, [f32; 3], u32)], touched: &[u64]) -> WorldDelta {
        let mut d = WorldDelta::new();
        for &(key, value, freq) in inserts {
            d.log_index.insert(key, d.log.len() as u32);
            d.log.push(WorldInsert { key, value, freq });
            d.overlay.insert(key, value);
        }
        for &key in touched {
            if d.touched_set.insert(key) {
                d.touched.push(key);
            }
        }
        d
    }

    fn world_table_eq(a: &WorldSnapshot, b: &WorldSnapshot) -> bool {
        a.table.checksums == b.table.checksums
            && a.table.values == b.table.values
            && a.table.lifetimes == b.table.lifetimes
    }

    #[test]
    fn world_cell_size_doubles_with_distance() {
        let p = wparams(64, 3);
        assert_eq!(world_cell_size(0.0, &p), p.base_cell_size);
        assert_eq!(world_cell_size(p.lod_distance * 0.9, &p), p.base_cell_size);
        assert_eq!(world_cell_size(p.lod_distance * 2.0, &p), p.base_cell_size * 2.0);
        assert_eq!(world_cell_size(p.lod_distance * 5.0, &p), p.base_cell_size * 4.0);
        // Two nearby surface points split fine cells up close but share
        // one coarse cell seen from afar — the positional LOD.
        let a = Vec3::new(0.05, 0.0, 0.0);
        let b = Vec3::new(0.30, 0.0, 0.0);
        let near_cam = [0.0f32, 0.0, -1.0];
        let far_cam = [0.0f32, 0.0, -40.0];
        assert_ne!(world_key_for(a, near_cam, &p), world_key_for(b, near_cam, &p));
        assert_eq!(world_key_for(a, far_cam, &p), world_key_for(b, far_cam, &p));
    }

    #[test]
    fn world_probe_chain_never_exceeds_bound() {
        let params = wparams(61, 3);
        let mut table = WorldRadianceCache::new(params.cells);
        // Saturate the table with twice as many distinct keys as cells.
        for i in 0..122 {
            let key = world_key([i, 1, 2], [0, 1, 2]);
            table.insert(key, [i as f32; 3], 3, params.probe_len);
        }
        assert!(table.occupancy() <= table.cells());
        // Every lookup — hit, miss, or chain-exhausted — stays bounded.
        for i in 0..488 {
            let key = world_key([i, 7, 9], [1, 0, 3]);
            let (_, probes) = table.find(key, params.probe_len);
            assert!(probes >= 1 && probes <= params.probe_len, "probe count {probes}");
        }
        // A full chain with no strictly-weaker slot drops the insert.
        let mut full = WorldRadianceCache::new(4);
        for key in [0u64, 4, 8, 12] {
            assert!(full.insert(key, [0.5; 3], 5, 4));
        }
        assert_eq!(full.occupancy(), 4);
        assert!(!full.insert(16, [0.9; 3], 5, 4), "equal lifetime must not displace");
        assert!(full.insert(16, [0.9; 3], 6, 4), "strictly stronger replaces the weakest");
        assert_eq!(full.occupancy(), 4);
    }

    #[test]
    fn world_checksum_collisions_never_alias_cells() {
        let cells = 64usize;
        let mut table = WorldRadianceCache::new(cells);
        let k1 = world_key([3, 1, 4], [1, 2, 3]);
        let k2 = k1 + cells as u64; // same slot-chain start, distinct key
        assert_eq!(world_slot(k1, cells), world_slot(k2, cells));
        assert_ne!(world_checksum(k1), world_checksum(k2));
        assert!(table.insert(k1, [0.1; 3], 5, 4));
        // The occupied cell belongs to k1's checksum: k2 must probe past
        // it, not read it.
        let (miss, _) = table.find(k2, 4);
        assert!(miss.is_none(), "a foreign checksum must not alias the cell");
        assert!(table.insert(k2, [0.9; 3], 5, 4));
        let (s1, _) = table.find(k1, 4);
        let (s2, _) = table.find(k2, 4);
        let (s1, s2) = (s1.unwrap(), s2.unwrap());
        assert_ne!(s1, s2);
        assert_eq!(table.values[s1], [0.1; 3]);
        assert_eq!(table.values[s2], [0.9; 3]);
    }

    #[test]
    fn world_merge_weighs_lifetime_frequency_then_session_index() {
        let params = wparams(64, 3);
        let k = world_key([1, 2, 3], [0, 0, 0]);
        // Higher within-epoch frequency beats a later session index...
        let hub = CacheHub::new();
        hub.world_snapshot(params);
        hub.merge_world_in_order(vec![
            wdelta(&[(k, [0.1; 3], 3)], &[]),
            wdelta(&[(k, [0.9; 3], 1)], &[]),
        ]);
        let snap = hub.world_snapshot(params);
        let (slot, _) = snap.table.find(k, params.probe_len);
        let slot = slot.unwrap();
        assert_eq!(snap.table.values[slot], [0.1; 3]);
        assert_eq!(snap.table.lifetimes[slot], params.lifetime + 2);
        // ... and on equal frequency the higher session index wins.
        let hub2 = CacheHub::new();
        hub2.world_snapshot(params);
        hub2.merge_world_in_order(vec![
            wdelta(&[(k, [0.1; 3], 2)], &[]),
            wdelta(&[(k, [0.9; 3], 2)], &[]),
        ]);
        let snap2 = hub2.world_snapshot(params);
        let (slot2, _) = snap2.table.find(k, params.probe_len);
        assert_eq!(snap2.table.values[slot2.unwrap()], [0.9; 3]);
    }

    #[test]
    fn world_decay_evicts_unrefreshed_and_refresh_protects() {
        let params = wparams(64, 2);
        let hub = CacheHub::new();
        hub.world_snapshot(params);
        let ka = world_key([1, 0, 0], [0, 0, 0]);
        let kb = world_key([2, 0, 0], [0, 0, 0]);
        let seed = wdelta(&[(ka, [0.4; 3], 1), (kb, [0.7; 3], 1)], &[]);
        assert_eq!(hub.merge_world_in_order(vec![seed]), 0);
        let s1 = hub.world_snapshot(params);
        assert_eq!(s1.epoch(), 1);
        assert_eq!(s1.occupancy(), 2);
        assert_eq!(s1.decay_sweep_bytes(), 0, "the first merge swept an empty table");
        // Epoch 2: only ka is hit, so kb ages 2 -> 1 while ka resets.
        assert_eq!(hub.merge_world_in_order(vec![wdelta(&[], &[ka])]), 0);
        let s2 = hub.world_snapshot(params);
        assert_eq!(s2.occupancy(), 2);
        assert_eq!(s2.decay_sweep_bytes(), 2 * WORLD_ENTRY_BYTES as u64);
        // Epoch 3: kb hits zero and is freed; ka survives refreshed.
        assert_eq!(hub.merge_world_in_order(vec![wdelta(&[], &[ka])]), 1);
        let s3 = hub.world_snapshot(params);
        assert_eq!(s3.epoch(), 3);
        assert_eq!(s3.occupancy(), 1);
        assert_eq!(s3.probe(ka).0, Some([0.4; 3]));
        assert_eq!(s3.probe(kb).0, None);
        // Idle epochs keep the same snapshot Arc: no swap, no sweep.
        assert_eq!(hub.merge_world_in_order(vec![WorldDelta::new()]), 0);
        assert!(Arc::ptr_eq(&s3, &hub.world_snapshot(params)));
    }

    #[test]
    fn world_merge_independent_of_delta_partitioning() {
        // The same insert/refresh stream split 1/2/4 ways across session
        // deltas (disjoint keys per session, as distinct viewers
        // produce) must merge to a bitwise-identical table — the merge
        // is a function of the delta set, not of how sessions were
        // scheduled onto threads.
        let params = wparams(97, 3);
        let keys: Vec<u64> = (0..64).map(|i| world_key([i, 0, 0], [0, 0, 0])).collect();
        let inserts: Vec<(u64, [f32; 3], u32)> = keys
            .iter()
            .enumerate()
            .map(|(i, &k)| (k, [i as f32; 3], 1 + (i as u32 % 3)))
            .collect();
        let touched: Vec<u64> = keys.iter().copied().step_by(2).collect();
        let merge = |ways: usize| {
            let hub = CacheHub::new();
            hub.world_snapshot(params);
            let split = |items: &[(u64, [f32; 3], u32)], hit: &[u64]| -> Vec<WorldDelta> {
                (0..ways)
                    .map(|w| {
                        let part: Vec<_> =
                            items.iter().copied().skip(w).step_by(ways).collect();
                        let t: Vec<_> = hit.iter().copied().skip(w).step_by(ways).collect();
                        wdelta(&part, &t)
                    })
                    .collect()
            };
            hub.merge_world_in_order(split(&inserts, &[]));
            hub.merge_world_in_order(split(&[], &touched));
            hub.world_snapshot(params)
        };
        let one = merge(1);
        let two = merge(2);
        let four = merge(4);
        assert_eq!(one.epoch(), 2);
        assert!(one.occupancy() > 0);
        assert!(world_table_eq(&one, &two), "2-way split diverged from serial merge");
        assert!(world_table_eq(&one, &four), "4-way split diverged from serial merge");
    }

    #[test]
    fn world_scope_half_res_session_hits_full_res_entries() {
        // A full-res session renders and merges; a half-res session at
        // the same pose shares the SAME pool snapshot (world keys carry
        // no tile geometry) and its keys — quantized Gaussian positions
        // — coincide with the full-res session's, so it must hit.
        // Geometry-keyed sharing structurally cannot do this: the
        // half-res tile grid is a different CacheGeometry.
        let scene = Arc::new(clamped_scene(77, 4000));
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let full = Intrinsics::with_fov(128, 128, 0.9);
        let half = crate::lumina::ds2::half_intrinsics(&full);
        let params = wparams(65_536, 30);
        let hub = CacheHub::new();

        let pf = project(&scene, &pose, &full, 0.2, 100.0, 0.0);
        let bf = bin_and_sort(&pf, &full, 16, 0.0);
        let mut a = CacheView::world(hub.world_snapshot(params), scene.clone(), 5);
        let cold = rasterize_cached_view(&pf, &bf, full.width, full.height, &mut a, false);
        assert_eq!(cold.stats.snapshot_hits, 0, "cold snapshot cannot hit");
        assert!(cold.stats.inserts > 0);
        hub.merge_world_in_order(vec![a.take_world_delta().unwrap()]);

        let ph = project(&scene, &pose, &half, 0.2, 100.0, 0.0);
        let bh = bin_and_sort(&ph, &half, 16, 0.0);
        let mut b = CacheView::world(hub.world_snapshot(params), scene.clone(), 5);
        let warm = rasterize_cached_view(&ph, &bh, half.width, half.height, &mut b, false);
        assert!(
            warm.stats.snapshot_hits > 0,
            "cross-resolution hits expected: {:?}",
            warm.stats
        );
        assert!(warm.stats.probes_recorded() > 0, "frozen probes must be histogrammed");
    }

    #[test]
    fn probe_histogram_buckets_saturate_and_merge() {
        let mut s = CacheStats::default();
        s.record_probe(1);
        s.record_probe(2);
        s.record_probe(8);
        s.record_probe(20); // saturates into the last bucket
        assert_eq!(s.probe_hist, [1, 1, 0, 0, 0, 0, 0, 2]);
        assert_eq!(s.probes_recorded(), 4);
        let mut merged = CacheStats::default();
        merged.merge(&s);
        merged.merge(&s);
        assert_eq!(merged.probe_hist, [2, 2, 0, 0, 0, 0, 0, 4]);
        assert_eq!(merged.probes_recorded(), 8);
    }
}

