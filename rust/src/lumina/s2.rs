//! S^2 — Sorting-Sharing (paper Sec. 3.1).
//!
//! Two concurrent paths (Fig. 7):
//!
//! * **Speculative sorting**: at the start of each sharing window, predict
//!   a future pose from the last two poses with the constant-velocity
//!   model (Eqns. 2-3: `v_j = (F_j - F_{j-1}) / dt`,
//!   `S_k = F_j + v * (N/2) dt`), project the scene at that pose with an
//!   **expanded viewport** (margin in pixels, applied to both culling and
//!   tile binning), and depth-sort every tile once.
//! * **Sorting-shared rendering**: every frame in the window reuses the
//!   speculative tile lists and depth *order*, re-evaluating only the
//!   cheap per-Gaussian state at the current pose: SH colors (required by
//!   the paper) and screen geometry (a sortless, binless pass).
//!
//! The scheduler also exposes the stale-order error metric (fraction of
//! adjacent pairs out of order at the render pose) used by the paper's
//! "only 0.2% of orders change" claim, and a rapid-rotation kill switch
//! (Sec. 8).
//!
//! **Sort topology** (DESIGN.md §5): the speculative sort is the same
//! redundant work across *viewers*, not just across frames — N
//! convergent sessions of one scene would otherwise run N identical
//! sorts per window. Sort ownership is therefore a seam ([`SortView`])
//! with two implementations: `Private` — the session drives its own
//! [`S2Scheduler`], bit-for-bit the pre-seam behavior — and `Clustered`
//! — a pool groups sessions at epoch boundaries by sort geometry and
//! predicted-pose proximity ([`SortHub`]), elects the lowest session
//! index of each cluster as leader, computes one [`SharedSort`] per
//! cluster on the pool's coordination thread, and publishes it as a
//! frozen `Arc<SharedSort>` every member renders against — still
//! refreshing colors/geometry at its *own* pose each frame, and still
//! free to drop to private per-frame sorts when its rotation outruns
//! the kill switch.

use std::sync::Arc;

use crate::camera::{Intrinsics, Pose};
use crate::pipeline::project::{project, refresh_colors, reproject_geometry, ProjectedScene};
use crate::pipeline::sort::{bin_and_sort, TileBins};
use crate::scene::GaussianScene;

/// What a frame cost the pipeline, for the hardware simulators.
#[derive(Debug, Clone, Copy, Default)]
pub struct S2FrameWork {
    /// Speculative sort executed this frame (projection + binning + sort).
    pub sorted: bool,
    /// Gaussians projected by the speculative sort (0 when reused).
    pub projected_gaussians: usize,
    /// Tile-list entries produced by the speculative sort (0 when reused).
    pub sort_entries: usize,
    /// Candidate (splat, tile) pairs the speculative sort's binning
    /// stage intersection-tested (0 when reused) — see
    /// [`TileBins::rect_candidates`].
    pub bin_candidates: usize,
    /// Per-frame recompute work: Gaussians whose color/geometry were
    /// refreshed for the current pose.
    pub refreshed_gaussians: usize,
}

/// A speculative sort shared across a window of frames.
#[derive(Debug, Clone)]
pub struct SharedSort {
    /// Pose the sort was computed at (the predicted S_k).
    pub sort_pose: Pose,
    /// Projected set at the sort pose (geometry gets re-evaluated per
    /// frame; `ids` and tile-list membership stay frozen).
    pub projected: ProjectedScene,
    /// Frozen tile lists + per-tile depth order.
    pub bins: TileBins,
}

/// Run the speculative-sort pipeline once: project the scene at
/// `sort_pose` with the expanded viewport, bin and depth-sort every
/// tile. The one sort implementation behind both ends of the
/// [`SortView`] seam — the private scheduler and the pool's
/// cluster-leader path cannot drift apart.
pub fn speculative_sort(
    scene: &GaussianScene,
    sort_pose: Pose,
    intr: &Intrinsics,
    near: f32,
    far: f32,
    tile_size: usize,
    margin: f32,
) -> SharedSort {
    let projected = project(scene, &sort_pose, intr, near, far, margin);
    let bins = bin_and_sort(&projected, intr, tile_size, margin);
    SharedSort { sort_pose, projected, bins }
}

/// Sorting-shared rendering against a frozen sort: clone the frozen
/// set and re-evaluate screen geometry + SH colors at the *current*
/// pose. Tile membership and depth order stay from the speculative
/// sort. Returns the refreshed set, the (cloned) frozen bins, and the
/// refreshed-Gaussian count.
fn refresh_frame(
    shared: &SharedSort,
    scene: &GaussianScene,
    pose: &Pose,
    intr: &Intrinsics,
) -> (ProjectedScene, TileBins, usize) {
    let mut projected = shared.projected.clone();
    reproject_geometry(&mut projected, scene, pose, intr);
    refresh_colors(&mut projected, scene, pose);
    let refreshed = projected.len();
    (projected, shared.bins.clone(), refreshed)
}

/// S^2 scheduler state.
pub struct S2Scheduler {
    /// Frames sharing one sorting result (paper default 6).
    pub sharing_window: usize,
    /// Expanded viewport margin in pixels per dimension (paper default 4).
    pub expanded_margin: f32,
    /// Disable sharing above this angular velocity (rad/frame) — the
    /// Sec. 8 rapid-rotation kill switch; `f32::INFINITY` disables.
    pub max_rotation_per_frame: f32,
    near: f32,
    far: f32,
    tile_size: usize,
    shared: Option<SharedSort>,
    frames_in_window: usize,
    prev_pose: Option<Pose>,
}

/// Per-frame output of the scheduler: the projection + bins to rasterize
/// with, plus work accounting.
pub struct S2Frame {
    pub projected: ProjectedScene,
    pub bins: TileBins,
    pub work: S2FrameWork,
    /// True when this frame fell back to a full pipeline run (cold start
    /// or kill switch).
    pub full_pipeline: bool,
}

impl S2Scheduler {
    pub fn new(
        sharing_window: usize,
        expanded_margin: usize,
        tile_size: usize,
        near: f32,
        far: f32,
    ) -> Self {
        S2Scheduler {
            sharing_window: sharing_window.max(1),
            expanded_margin: expanded_margin as f32,
            max_rotation_per_frame: f32::INFINITY,
            near,
            far,
            tile_size,
            shared: None,
            frames_in_window: 0,
            prev_pose: None,
        }
    }

    /// Forget all cross-frame state: the shared sort, the window
    /// position, and the pose history. Required when the pipeline
    /// resolution or raster backend is swapped mid-run (tier changes) —
    /// a stale speculative sort would reference the old tile grid.
    pub fn reset(&mut self) {
        self.shared = None;
        self.frames_in_window = 0;
        self.prev_pose = None;
    }

    /// Predict the sorting pose for the upcoming window (Eqns. 2-3):
    /// extrapolate N/2 frame intervals ahead so the sort sits at the
    /// center of the window it serves.
    pub fn predict_sort_pose(&self, cur: &Pose) -> Pose {
        match &self.prev_pose {
            Some(prev) => Pose::extrapolate(prev, cur, self.sharing_window as f32 / 2.0),
            None => *cur,
        }
    }

    /// True when inter-frame rotation exceeds the kill-switch threshold.
    fn rotation_too_fast(&self, cur: &Pose) -> bool {
        match &self.prev_pose {
            Some(prev) => prev.angular_distance(cur) > self.max_rotation_per_frame,
            None => false,
        }
    }

    /// Process one frame: reuse or recompute the shared sort, then return
    /// per-frame projection state (fresh geometry + colors, stale order).
    pub fn frame(
        &mut self,
        scene: &GaussianScene,
        pose: &Pose,
        intr: &Intrinsics,
    ) -> S2Frame {
        let kill = self.rotation_too_fast(pose);
        let cold = self.prev_pose.is_none();
        let need_sort =
            self.shared.is_none() || self.frames_in_window >= self.sharing_window || kill;

        let mut work = S2FrameWork::default();
        let mut full_pipeline = false;
        if need_sort {
            let sort_pose = if kill { *pose } else { self.predict_sort_pose(pose) };
            let shared = speculative_sort(
                scene,
                sort_pose,
                intr,
                self.near,
                self.far,
                self.tile_size,
                self.expanded_margin,
            );
            work.sorted = true;
            work.projected_gaussians = shared.projected.len();
            work.sort_entries = shared.bins.total_entries();
            work.bin_candidates = shared.bins.rect_candidates();
            // A full-pipeline frame is one whose sort ran at the render
            // pose itself (nothing speculative about it): a cold start
            // — no pose history to extrapolate, so the predicted pose
            // *is* the current pose — or the rapid-rotation kill
            // switch. Window-expiry sorts extrapolate ahead and are
            // speculative at any window length, window 1 included.
            full_pipeline = kill || cold;
            self.shared = Some(shared);
            self.frames_in_window = 0;
        }
        self.frames_in_window += 1;
        self.prev_pose = Some(*pose);

        let shared = self.shared.as_ref().expect("shared sort present");
        let (projected, bins, refreshed) = refresh_frame(shared, scene, pose, intr);
        work.refreshed_gaussians = refreshed;

        S2Frame { projected, bins, work, full_pipeline }
    }

    /// Stale-order error among each pixel's *significant* Gaussians: the
    /// fraction of adjacent significant pairs (in the shared rendering
    /// order) whose true depth order at the render pose is inverted.
    ///
    /// This is the paper's "only 0.2% of these Gaussian orders are
    /// changed" metric (Sec. 3.1): significant Gaussians "are likely
    /// separated apart after sorting", so their relative order is robust
    /// to pose drift — unlike near-tie neighbors in the raw tile list.
    /// Pixels are sampled on a `stride`-spaced grid.
    pub fn stale_order_fraction_sampled(
        frame: &S2Frame,
        width: usize,
        height: usize,
        stride: usize,
    ) -> f64 {
        use crate::constants::{ALPHA_MAX, ALPHA_MIN};
        let p = &frame.projected;
        let ts = frame.bins.tile_size;
        let mut checked = 0u64;
        let mut swapped = 0u64;
        let mut depths: Vec<f32> = Vec::with_capacity(32);
        for y in (0..height).step_by(stride) {
            for x in (0..width).step_by(stride) {
                let tile = (y / ts) * frame.bins.tiles_x + x / ts;
                let (px, py) = (x as f32 + 0.5, y as f32 + 0.5);
                depths.clear();
                for &idx in frame.bins.list(tile) {
                    let i = idx as usize;
                    let [mx, my] = p.means[i];
                    let dx = px - mx;
                    let dy = py - my;
                    let conic = p.conics[i];
                    let power = -0.5 * (conic.a * dx * dx + conic.c * dy * dy)
                        - conic.b * dx * dy;
                    if power > 0.0 {
                        continue;
                    }
                    let alpha = (p.opacity[i] * power.exp()).min(ALPHA_MAX);
                    if alpha < ALPHA_MIN {
                        continue;
                    }
                    depths.push(p.depths[i]);
                    if depths.len() >= 24 {
                        break;
                    }
                }
                for w in depths.windows(2) {
                    checked += 1;
                    if w[0] > w[1] {
                        swapped += 1;
                    }
                }
            }
        }
        if checked == 0 {
            0.0
        } else {
            swapped as f64 / checked as f64
        }
    }

    /// Access the current shared sort (for tests/analysis).
    pub fn shared(&self) -> Option<&SharedSort> {
        self.shared.as_ref()
    }
}

/// Work accounting for one speculative sort, carried from the pool's
/// epoch-boundary computation to the cluster leader's next frame so the
/// cost models charge the sort exactly once per cluster per epoch.
#[derive(Debug, Clone, Copy)]
pub struct SortWork {
    pub projected_gaussians: usize,
    pub sort_entries: usize,
    pub bin_candidates: usize,
}

impl SortWork {
    /// The work a computed [`SharedSort`] represents.
    pub fn of(sort: &SharedSort) -> Self {
        SortWork {
            projected_gaussians: sort.projected.len(),
            sort_entries: sort.bins.total_entries(),
            bin_candidates: sort.bins.rect_candidates(),
        }
    }
}

/// A session's end of the pool-clustered sort topology: the frozen
/// cluster sort it renders against (installed by the pool at epoch
/// boundaries), plus its own [`S2Scheduler`] — which the session keeps
/// for its *parameters and pose history only* (kill-switch velocity,
/// boundary pose prediction). Followers never mutate window state they
/// do not own: the scheduler's `shared`/`frames_in_window` fields stay
/// untouched on this path.
pub struct ClusteredSort {
    sched: S2Scheduler,
    /// The cluster's frozen epoch sort (`None` until the pool's first
    /// install, and again after a tier swap resets the view — both fall
    /// back to private per-frame sorts until the next re-cluster).
    shared: Option<Arc<SharedSort>>,
    /// Leader only: sort work computed at the epoch boundary, charged
    /// to this session's next rendered frame.
    pending: Option<SortWork>,
    /// Members of this session's cluster (itself included).
    sharers: usize,
    /// Whether this session is its cluster's leader (lowest index).
    leader: bool,
}

impl ClusteredSort {
    fn new(sched: S2Scheduler) -> Self {
        ClusteredSort { sched, shared: None, pending: None, sharers: 1, leader: true }
    }

    fn frame(&mut self, scene: &GaussianScene, pose: &Pose, intr: &Intrinsics) -> S2Frame {
        let kill = self.sched.rotation_too_fast(pose);
        let cluster_sort = if kill { None } else { self.shared.clone() };
        let frame = match cluster_sort {
            Some(shared) => {
                // Render against the cluster's frozen sort, refreshing
                // geometry + colors at this session's own pose. The
                // leader's first frame after an install carries the
                // boundary sort's work; followers report pure reuse.
                let mut work = S2FrameWork::default();
                if let Some(w) = self.pending.take() {
                    work.sorted = true;
                    work.projected_gaussians = w.projected_gaussians;
                    work.sort_entries = w.sort_entries;
                    work.bin_candidates = w.bin_candidates;
                }
                let (projected, bins, refreshed) = refresh_frame(&shared, scene, pose, intr);
                work.refreshed_gaussians = refreshed;
                S2Frame { projected, bins, work, full_pipeline: false }
            }
            None => {
                // Kill switch (or no cluster sort installed yet): a
                // private full-pipeline sort at the render pose. The
                // cluster's shared sort is left untouched — the session
                // drops out for this frame only and rejoins the moment
                // its rotation slows (or the next install lands).
                let shared = speculative_sort(
                    scene,
                    *pose,
                    intr,
                    self.sched.near,
                    self.sched.far,
                    self.sched.tile_size,
                    self.sched.expanded_margin,
                );
                let mut work = S2FrameWork {
                    sorted: true,
                    projected_gaussians: shared.projected.len(),
                    sort_entries: shared.bins.total_entries(),
                    bin_candidates: shared.bins.rect_candidates(),
                    refreshed_gaussians: 0,
                };
                let (projected, bins, refreshed) = refresh_frame(&shared, scene, pose, intr);
                work.refreshed_gaussians = refreshed;
                S2Frame { projected, bins, work, full_pipeline: true }
            }
        };
        self.sched.prev_pose = Some(*pose);
        frame
    }

    fn reset(&mut self) {
        self.sched.reset();
        self.shared = None;
        self.pending = None;
        self.sharers = 1;
        self.leader = true;
    }
}

/// The sort-topology seam: who owns a session's speculative sort.
///
/// `Private` is bit-for-bit the pre-seam behavior — the session's own
/// [`S2Scheduler`] sorts once per sharing window. `Clustered` renders
/// against a pool-published frozen [`SharedSort`] (one per pose
/// cluster per epoch), mirroring the radiance cache's snapshot/merge
/// topology: everything a session reads during an epoch is frozen or
/// session-local, so output is bitwise identical at any thread count
/// and pipeline depth.
pub enum SortView {
    Private(S2Scheduler),
    Clustered(ClusteredSort),
}

impl SortView {
    /// Session-owned windowed sorting (the pre-seam behavior).
    pub fn private(sched: S2Scheduler) -> Self {
        SortView::Private(sched)
    }

    /// Pool-clustered sorting; private per-frame fallback until the
    /// pool installs the first cluster sort.
    pub fn clustered(sched: S2Scheduler) -> Self {
        SortView::Clustered(ClusteredSort::new(sched))
    }

    pub fn is_clustered(&self) -> bool {
        matches!(self, SortView::Clustered(_))
    }

    /// Process one frame through whichever topology owns the sort.
    pub fn frame(&mut self, scene: &GaussianScene, pose: &Pose, intr: &Intrinsics) -> S2Frame {
        match self {
            SortView::Private(sched) => sched.frame(scene, pose, intr),
            SortView::Clustered(c) => c.frame(scene, pose, intr),
        }
    }

    /// Forget all cross-frame state: the (cluster) sort, window
    /// position, pose history, and any pending leader work.
    pub fn reset(&mut self) {
        match self {
            SortView::Private(sched) => sched.reset(),
            SortView::Clustered(c) => c.reset(),
        }
    }

    /// The underlying scheduler (parameters + pose history).
    pub fn scheduler(&self) -> &S2Scheduler {
        match self {
            SortView::Private(sched) => sched,
            SortView::Clustered(c) => &c.sched,
        }
    }

    pub fn scheduler_mut(&mut self) -> &mut S2Scheduler {
        match self {
            SortView::Private(sched) => sched,
            SortView::Clustered(c) => &mut c.sched,
        }
    }

    /// The pose this session would speculative-sort at, extrapolated
    /// `horizon` frame intervals past `next` (the next pose it will
    /// render) — what the pool clusters sessions by. Falls back to
    /// `next` without pose history, exactly like
    /// [`S2Scheduler::predict_sort_pose`].
    pub fn predicted_pose(&self, next: &Pose, horizon: f32) -> Pose {
        match &self.scheduler().prev_pose {
            Some(prev) => Pose::extrapolate(prev, next, horizon),
            None => *next,
        }
    }

    /// Install the cluster's frozen epoch sort. The leader additionally
    /// takes on the sort's work accounting, charged to its next frame.
    /// A no-op for private views.
    pub fn install_shared_sort(&mut self, sort: Arc<SharedSort>, leader: bool, sharers: usize) {
        if let SortView::Clustered(c) = self {
            c.pending = if leader { Some(SortWork::of(&sort)) } else { None };
            c.shared = Some(sort);
            c.sharers = sharers.max(1);
            c.leader = leader;
        }
    }

    /// Sessions sharing this view's sort (itself included); 1 for
    /// private views and for clustered views awaiting their first
    /// install.
    pub fn sharers(&self) -> usize {
        match self {
            SortView::Private(_) => 1,
            SortView::Clustered(c) => c.sharers,
        }
    }

    /// Whether this session pays for its own sorts (private views and
    /// cluster leaders) rather than reusing a leader's.
    pub fn is_cluster_leader(&self) -> bool {
        match self {
            SortView::Private(_) => true,
            SortView::Clustered(c) => c.leader,
        }
    }
}

/// The sort-geometry key: sessions may share one speculative sort only
/// when their frontends project the *same scene* onto the *same grid*.
/// `scene_gaussians` is the scene-identity proxy — a reduced-tier
/// session projects a prefix subsample whose indices are meaningless
/// against the full scene (and vice versa), and the half-res tier bins
/// a different tile grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SortGeometry {
    pub width: usize,
    pub height: usize,
    pub tile_size: usize,
    pub scene_gaussians: usize,
}

/// One session's input to an epoch-boundary clustering round.
#[derive(Debug, Clone, Copy)]
pub struct SortCandidate {
    /// Session index in the pool (the determinism anchor: clusters and
    /// leader election depend only on these indices and the candidate
    /// poses, never on thread scheduling).
    pub session: usize,
    pub geometry: SortGeometry,
    /// Predicted sort pose for the upcoming epoch.
    pub pose: Pose,
}

/// Pool-level owner of the sort-clustering policy: groups sessions at
/// epoch boundaries by sort geometry and predicted-pose proximity so
/// one leader sort per cluster serves every member. Clustering runs on
/// the pool's coordination thread only, so — like the cache hub's
/// merge — it cannot be order-scrambled by rendering threads.
#[derive(Debug, Clone, Copy)]
pub struct SortHub {
    cluster_radius: f32,
    position_radius: f32,
}

impl SortHub {
    /// `cluster_radius` is the maximum angular distance (radians)
    /// between predicted poses of a leader and any member it absorbs.
    /// This constructor keeps the historical rotation-only gate
    /// (positional spread unbounded); pools use
    /// [`Self::with_position_radius`].
    pub fn new(cluster_radius: f32) -> Self {
        Self::with_position_radius(cluster_radius, f32::INFINITY)
    }

    /// [`Self::new`] plus the translation-aware gate: a member must
    /// also sit within `position_radius` world units of the leader's
    /// predicted position. Distant viewers with parallel gaze see
    /// disjoint tile lists — sorting them together trades follower
    /// quality for nothing — so the pool path bounds both terms.
    pub fn with_position_radius(cluster_radius: f32, position_radius: f32) -> Self {
        SortHub { cluster_radius, position_radius }
    }

    pub fn cluster_radius(&self) -> f32 {
        self.cluster_radius
    }

    pub fn position_radius(&self) -> f32 {
        self.position_radius
    }

    /// Greedy index-ordered clustering: walk candidates in session
    /// order; each still-unassigned session founds a cluster (becoming
    /// its leader — lowest index by construction) and absorbs every
    /// later unassigned session with the same sort geometry whose
    /// predicted pose sits within the cluster radius — angular
    /// ([`Pose::angular_distance`]) *and* positional (Euclidean, world
    /// units) — of the leader's. Every candidate lands in exactly one
    /// cluster (possibly a singleton), and the result is a pure
    /// function of the candidate list — deterministic at any thread
    /// count.
    ///
    /// Within the gates, the S² expanded margin plus the per-frame
    /// geometry refresh absorbs the members' residual spread, exactly
    /// as it absorbs pose drift across a private window. Margin
    /// auto-widening with cluster spread remains a ROADMAP follow-on.
    pub fn cluster(&self, cands: &[SortCandidate]) -> Vec<Vec<usize>> {
        let mut assigned = vec![false; cands.len()];
        let mut clusters = Vec::new();
        for i in 0..cands.len() {
            if assigned[i] {
                continue;
            }
            assigned[i] = true;
            let leader = &cands[i];
            let mut members = vec![leader.session];
            for j in i + 1..cands.len() {
                if assigned[j] || cands[j].geometry != leader.geometry {
                    continue;
                }
                if leader.pose.angular_distance(&cands[j].pose) <= self.cluster_radius
                    && (leader.pose.position - cands[j].pose.position).norm()
                        <= self.position_radius
                {
                    assigned[j] = true;
                    members.push(cands[j].session);
                }
            }
            clusters.push(members);
        }
        clusters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::trajectory::{generate, TrajectoryKind};
    use crate::math::Vec3;
    use crate::scene::synth::test_scene;

    fn setup() -> (GaussianScene, Vec<Pose>, Intrinsics) {
        let scene = test_scene(31, 5000);
        let traj = generate(TrajectoryKind::VrHeadMotion, 7, 30, 1.3);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        (scene, traj.poses, intr)
    }

    #[test]
    fn sorts_once_per_window() {
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let mut sorts = 0;
        for pose in poses.iter().take(18) {
            let f = sched.frame(&scene, pose, &intr);
            if f.work.sorted {
                sorts += 1;
            }
        }
        assert_eq!(sorts, 3, "18 frames / window 6 = 3 sorts");
    }

    #[test]
    fn window_one_sorts_every_frame() {
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(1, 0, 16, 0.2, 100.0);
        for pose in poses.iter().take(5) {
            let f = sched.frame(&scene, pose, &intr);
            assert!(f.work.sorted);
        }
    }

    #[test]
    fn shared_frames_match_full_render_closely() {
        // The S^2 image should differ from the full pipeline by far less
        // than the image's dynamic range (sub-dB-scale artifacts only).
        use crate::pipeline::raster::{rasterize, RasterConfig};
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 8, 16, 0.2, 100.0);
        let mut worst = 0.0f64;
        for pose in poses.iter().take(12) {
            let f = sched.frame(&scene, pose, &intr);
            let shared_img =
                rasterize(&f.projected, &f.bins, intr.width, intr.height, &RasterConfig::default());
            let full_p = project(&scene, pose, &intr, 0.2, 100.0, 0.0);
            let full_b = bin_and_sort(&full_p, &intr, 16, 0.0);
            let full_img =
                rasterize(&full_p, &full_b, intr.width, intr.height, &RasterConfig::default());
            worst = worst.max(shared_img.image.mean_abs_diff(&full_img.image));
        }
        assert!(worst < 0.02, "mean abs diff {worst} too high for shared sorting");
    }

    #[test]
    fn stale_order_fraction_is_small() {
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let mut max_frac = 0.0f64;
        for pose in poses.iter().take(12) {
            let f = sched.frame(&scene, pose, &intr);
            max_frac = max_frac.max(S2Scheduler::stale_order_fraction_sampled(
                &f, intr.width, intr.height, 8,
            ));
        }
        // Paper: ~0.2% (significant-Gaussian order changes); allow slack
        // for the synthetic scene's denser depth ties.
        assert!(max_frac < 0.05, "stale order fraction {max_frac}");
    }

    #[test]
    fn kill_switch_forces_sorting() {
        let (scene, _, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        sched.max_rotation_per_frame = 0.01; // ~0.6 deg/frame
        // A fast-rotating pose sequence.
        let poses: Vec<Pose> = (0..8)
            .map(|i| {
                let th = i as f32 * 0.1; // 5.7 deg/frame: way over threshold
                Pose::look_at(
                    Vec3::new(4.0 * th.sin(), 0.3, -4.0 * th.cos()),
                    Vec3::ZERO,
                )
            })
            .collect();
        let mut sorts = 0;
        for pose in &poses {
            let f = sched.frame(&scene, pose, &intr);
            if f.work.sorted {
                sorts += 1;
            }
        }
        assert_eq!(sorts, poses.len(), "kill switch must force per-frame sorting");
    }

    #[test]
    fn prediction_extrapolates_forward() {
        let (scene, _, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let p0 = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let p1 = Pose::look_at(Vec3::new(0.1, 0.0, -4.0), Vec3::ZERO);
        sched.frame(&scene, &p0, &intr);
        let pred = sched.predict_sort_pose(&p1);
        // Velocity 0.1/frame, window 6 -> predicted 0.3 ahead of p1.
        assert!((pred.position.x - (0.1 + 0.3)).abs() < 1e-4);
    }

    #[test]
    fn full_pipeline_flags_cold_start_and_kill_switch_only() {
        // Regression: the flag used to read `sorted && window == 1`,
        // which missed cold-start/kill-switch sorts at window > 1 and
        // mislabeled warm window-1 sorts (which are speculative).
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let f0 = sched.frame(&scene, &poses[0], &intr);
        assert!(f0.work.sorted && f0.full_pipeline, "cold start is a full-pipeline run");
        for pose in poses.iter().take(13).skip(1) {
            let f = sched.frame(&scene, pose, &intr);
            assert!(
                !f.full_pipeline,
                "window-expiry sorts are speculative, not full-pipeline"
            );
        }

        let mut w1 = S2Scheduler::new(1, 0, 16, 0.2, 100.0);
        assert!(w1.frame(&scene, &poses[0], &intr).full_pipeline, "cold window-1 start");
        let f = w1.frame(&scene, &poses[1], &intr);
        assert!(f.work.sorted, "window 1 still sorts every frame");
        assert!(!f.full_pipeline, "warm window-1 sorts extrapolate: speculative");

        let mut k = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        k.max_rotation_per_frame = -1.0; // any rotation trips the switch
        let _ = k.frame(&scene, &poses[0], &intr);
        let f = k.frame(&scene, &poses[1], &intr);
        assert!(f.work.sorted && f.full_pipeline, "kill-switch sorts are full-pipeline");
    }

    #[test]
    fn clustered_view_reuses_installed_sort_and_charges_leader_once() {
        let (scene, poses, intr) = setup();
        let sched = || S2Scheduler::new(6, 4, 16, 0.2, 100.0);

        // Without an installed cluster sort, the view falls back to a
        // private full-pipeline sort every frame.
        let mut orphan = SortView::clustered(sched());
        assert!(orphan.is_clustered());
        assert_eq!(orphan.sharers(), 1);
        for pose in poses.iter().take(2) {
            let f = orphan.frame(&scene, pose, &intr);
            assert!(f.work.sorted && f.full_pipeline, "no cluster sort => private sort");
        }

        // Install a cluster sort: the leader's next frame carries the
        // sort's work exactly once, followers report pure reuse, and
        // both refresh at their own pose.
        let sort = Arc::new(speculative_sort(&scene, poses[0], &intr, 0.2, 100.0, 16, 4.0));
        let mut leader = SortView::clustered(sched());
        let mut follower = SortView::clustered(sched());
        leader.install_shared_sort(sort.clone(), true, 2);
        follower.install_shared_sort(sort.clone(), false, 2);
        assert!(leader.is_cluster_leader() && !follower.is_cluster_leader());
        assert_eq!(leader.sharers(), 2);

        let lf = leader.frame(&scene, &poses[1], &intr);
        assert!(lf.work.sorted, "leader's first frame carries the boundary sort");
        assert_eq!(lf.work.sort_entries, sort.bins.total_entries());
        assert!(!lf.full_pipeline, "the cluster sort is speculative");
        let lf2 = leader.frame(&scene, &poses[2], &intr);
        assert!(!lf2.work.sorted, "the sort is charged exactly once");
        assert!(lf2.work.refreshed_gaussians > 0);

        let ff = follower.frame(&scene, &poses[2], &intr);
        assert!(!ff.work.sorted, "followers never sort");
        assert!(ff.work.refreshed_gaussians > 0, "followers still refresh per frame");
        // The refresh really ran at the follower's own pose: geometry
        // differs from the frozen sort-pose set.
        assert_ne!(ff.projected.means, sort.projected.means);

        // A kill-switch frame drops to a private sort without touching
        // the installed cluster sort.
        follower.scheduler_mut().max_rotation_per_frame = -1.0;
        let kf = follower.frame(&scene, &poses[3], &intr);
        assert!(kf.work.sorted && kf.full_pipeline, "kill switch forces a private sort");
        follower.scheduler_mut().max_rotation_per_frame = f32::INFINITY;
        let rf = follower.frame(&scene, &poses[4], &intr);
        assert!(!rf.work.sorted, "the cluster sort survives a member's kill frame");

        // Reset clears the installed sort and pending work.
        leader.reset();
        assert_eq!(leader.sharers(), 1);
        let f = leader.frame(&scene, &poses[3], &intr);
        assert!(f.full_pipeline, "after reset the view is cold again");
    }

    #[test]
    fn sort_hub_clusters_by_geometry_and_pose_with_lowest_index_leader() {
        let hub = SortHub::new(0.2);
        assert_eq!(hub.cluster_radius(), 0.2);
        let geom = |g: usize| SortGeometry {
            width: 128,
            height: 128,
            tile_size: 16,
            scene_gaussians: g,
        };
        let pose = |th: f32| {
            Pose::look_at(Vec3::new(4.0 * th.sin(), 0.3, -4.0 * th.cos()), Vec3::ZERO)
        };
        let cands = vec![
            SortCandidate { session: 0, geometry: geom(5000), pose: pose(0.00) },
            SortCandidate { session: 1, geometry: geom(5000), pose: pose(0.05) },
            // Same pose, different scene (reduced tier): never clusters.
            SortCandidate { session: 2, geometry: geom(2500), pose: pose(0.05) },
            // Same geometry, far pose: its own cluster.
            SortCandidate { session: 3, geometry: geom(5000), pose: pose(1.50) },
            // Close to session 3's pose: joins the later cluster.
            SortCandidate { session: 4, geometry: geom(5000), pose: pose(1.55) },
        ];
        let clusters = hub.cluster(&cands);
        assert_eq!(clusters, vec![vec![0, 1], vec![2], vec![3, 4]]);

        // A generous radius merges geometry peers regardless of pose;
        // leaders stay the lowest session index.
        let wide = SortHub::new(10.0);
        let clusters = wide.cluster(&cands);
        assert_eq!(clusters, vec![vec![0, 1, 3, 4], vec![2]]);

        // Zero candidates: zero clusters.
        assert!(hub.cluster(&[]).is_empty());
    }

    #[test]
    fn position_gate_splits_far_apart_parallel_gaze_pair() {
        // Two viewers 40 world units apart, both looking straight down
        // -z (identical rotation, angular distance 0). The rotation-only
        // hub clusters them; the translation-aware gate must not — their
        // tile lists are disjoint, so a shared sort only costs follower
        // quality.
        let geometry = SortGeometry {
            width: 128,
            height: 128,
            tile_size: 16,
            scene_gaussians: 5000,
        };
        let gaze = |x: f32| {
            Pose::look_at(Vec3::new(x, 0.0, -4.0), Vec3::new(x, 0.0, 0.0))
        };
        let cands = vec![
            SortCandidate { session: 0, geometry, pose: gaze(0.0) },
            SortCandidate { session: 1, geometry, pose: gaze(40.0) },
            // A third viewer near session 0: stays absorbed.
            SortCandidate { session: 2, geometry, pose: gaze(1.0) },
        ];
        let legacy = SortHub::new(0.2);
        assert_eq!(
            legacy.cluster(&cands),
            vec![vec![0, 1, 2]],
            "rotation-only gate clusters parallel gaze regardless of distance"
        );
        let gated = SortHub::with_position_radius(0.2, 16.0);
        assert_eq!(gated.position_radius(), 16.0);
        assert_eq!(
            gated.cluster(&cands),
            vec![vec![0, 2], vec![1]],
            "positional gate splits the far pair, keeps the near one"
        );
    }

    #[test]
    fn expanded_margin_readmits_edge_gaussians() {
        let (scene, poses, intr) = setup();
        let mut tight = S2Scheduler::new(6, 0, 16, 0.2, 100.0);
        let mut loose = S2Scheduler::new(6, 16, 16, 0.2, 100.0);
        let ft = tight.frame(&scene, &poses[0], &intr);
        let fl = loose.frame(&scene, &poses[0], &intr);
        assert!(fl.projected.len() >= ft.projected.len());
        assert!(fl.bins.total_entries() > ft.bins.total_entries());
    }
}
