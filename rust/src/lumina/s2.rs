//! S^2 — Sorting-Sharing (paper Sec. 3.1).
//!
//! Two concurrent paths (Fig. 7):
//!
//! * **Speculative sorting**: at the start of each sharing window, predict
//!   a future pose from the last two poses with the constant-velocity
//!   model (Eqns. 2-3: `v_j = (F_j - F_{j-1}) / dt`,
//!   `S_k = F_j + v * (N/2) dt`), project the scene at that pose with an
//!   **expanded viewport** (margin in pixels, applied to both culling and
//!   tile binning), and depth-sort every tile once.
//! * **Sorting-shared rendering**: every frame in the window reuses the
//!   speculative tile lists and depth *order*, re-evaluating only the
//!   cheap per-Gaussian state at the current pose: SH colors (required by
//!   the paper) and screen geometry (a sortless, binless pass).
//!
//! The scheduler also exposes the stale-order error metric (fraction of
//! adjacent pairs out of order at the render pose) used by the paper's
//! "only 0.2% of orders change" claim, and a rapid-rotation kill switch
//! (Sec. 8).

use crate::camera::{Intrinsics, Pose};
use crate::pipeline::project::{project, refresh_colors, reproject_geometry, ProjectedScene};
use crate::pipeline::sort::{bin_and_sort, TileBins};
use crate::scene::GaussianScene;

/// What a frame cost the pipeline, for the hardware simulators.
#[derive(Debug, Clone, Copy, Default)]
pub struct S2FrameWork {
    /// Speculative sort executed this frame (projection + binning + sort).
    pub sorted: bool,
    /// Gaussians projected by the speculative sort (0 when reused).
    pub projected_gaussians: usize,
    /// Tile-list entries produced by the speculative sort (0 when reused).
    pub sort_entries: usize,
    /// Per-frame recompute work: Gaussians whose color/geometry were
    /// refreshed for the current pose.
    pub refreshed_gaussians: usize,
}

/// A speculative sort shared across a window of frames.
#[derive(Debug, Clone)]
pub struct SharedSort {
    /// Pose the sort was computed at (the predicted S_k).
    pub sort_pose: Pose,
    /// Projected set at the sort pose (geometry gets re-evaluated per
    /// frame; `ids` and tile-list membership stay frozen).
    pub projected: ProjectedScene,
    /// Frozen tile lists + per-tile depth order.
    pub bins: TileBins,
}

/// S^2 scheduler state.
pub struct S2Scheduler {
    /// Frames sharing one sorting result (paper default 6).
    pub sharing_window: usize,
    /// Expanded viewport margin in pixels per dimension (paper default 4).
    pub expanded_margin: f32,
    /// Disable sharing above this angular velocity (rad/frame) — the
    /// Sec. 8 rapid-rotation kill switch; `f32::INFINITY` disables.
    pub max_rotation_per_frame: f32,
    near: f32,
    far: f32,
    tile_size: usize,
    shared: Option<SharedSort>,
    frames_in_window: usize,
    prev_pose: Option<Pose>,
}

/// Per-frame output of the scheduler: the projection + bins to rasterize
/// with, plus work accounting.
pub struct S2Frame {
    pub projected: ProjectedScene,
    pub bins: TileBins,
    pub work: S2FrameWork,
    /// True when this frame fell back to a full pipeline run (cold start
    /// or kill switch).
    pub full_pipeline: bool,
}

impl S2Scheduler {
    pub fn new(
        sharing_window: usize,
        expanded_margin: usize,
        tile_size: usize,
        near: f32,
        far: f32,
    ) -> Self {
        S2Scheduler {
            sharing_window: sharing_window.max(1),
            expanded_margin: expanded_margin as f32,
            max_rotation_per_frame: f32::INFINITY,
            near,
            far,
            tile_size,
            shared: None,
            frames_in_window: 0,
            prev_pose: None,
        }
    }

    /// Forget all cross-frame state: the shared sort, the window
    /// position, and the pose history. Required when the pipeline
    /// resolution or raster backend is swapped mid-run (tier changes) —
    /// a stale speculative sort would reference the old tile grid.
    pub fn reset(&mut self) {
        self.shared = None;
        self.frames_in_window = 0;
        self.prev_pose = None;
    }

    /// Predict the sorting pose for the upcoming window (Eqns. 2-3):
    /// extrapolate N/2 frame intervals ahead so the sort sits at the
    /// center of the window it serves.
    pub fn predict_sort_pose(&self, cur: &Pose) -> Pose {
        match &self.prev_pose {
            Some(prev) => Pose::extrapolate(prev, cur, self.sharing_window as f32 / 2.0),
            None => *cur,
        }
    }

    /// True when inter-frame rotation exceeds the kill-switch threshold.
    fn rotation_too_fast(&self, cur: &Pose) -> bool {
        match &self.prev_pose {
            Some(prev) => prev.angular_distance(cur) > self.max_rotation_per_frame,
            None => false,
        }
    }

    /// Process one frame: reuse or recompute the shared sort, then return
    /// per-frame projection state (fresh geometry + colors, stale order).
    pub fn frame(
        &mut self,
        scene: &GaussianScene,
        pose: &Pose,
        intr: &Intrinsics,
    ) -> S2Frame {
        let kill = self.rotation_too_fast(pose);
        let need_sort =
            self.shared.is_none() || self.frames_in_window >= self.sharing_window || kill;

        let mut work = S2FrameWork::default();
        if need_sort {
            let sort_pose = if kill { *pose } else { self.predict_sort_pose(pose) };
            let projected =
                project(scene, &sort_pose, intr, self.near, self.far, self.expanded_margin);
            let bins = bin_and_sort(&projected, intr, self.tile_size, self.expanded_margin);
            work.sorted = true;
            work.projected_gaussians = projected.len();
            work.sort_entries = bins.total_entries();
            self.shared = Some(SharedSort { sort_pose, projected, bins });
            self.frames_in_window = 0;
        }
        self.frames_in_window += 1;
        self.prev_pose = Some(*pose);

        let shared = self.shared.as_ref().expect("shared sort present");
        // Sorting-shared rendering: clone the frozen set, re-evaluate
        // geometry + colors at the *current* pose. Tile membership and
        // depth order stay from the speculative sort.
        let mut projected = shared.projected.clone();
        reproject_geometry(&mut projected, scene, pose, intr);
        refresh_colors(&mut projected, scene, pose);
        work.refreshed_gaussians = projected.len();

        S2Frame {
            projected,
            bins: shared.bins.clone(),
            work,
            full_pipeline: work.sorted && self.sharing_window == 1,
        }
    }

    /// Stale-order error among each pixel's *significant* Gaussians: the
    /// fraction of adjacent significant pairs (in the shared rendering
    /// order) whose true depth order at the render pose is inverted.
    ///
    /// This is the paper's "only 0.2% of these Gaussian orders are
    /// changed" metric (Sec. 3.1): significant Gaussians "are likely
    /// separated apart after sorting", so their relative order is robust
    /// to pose drift — unlike near-tie neighbors in the raw tile list.
    /// Pixels are sampled on a `stride`-spaced grid.
    pub fn stale_order_fraction_sampled(
        frame: &S2Frame,
        width: usize,
        height: usize,
        stride: usize,
    ) -> f64 {
        use crate::constants::{ALPHA_MAX, ALPHA_MIN};
        let p = &frame.projected;
        let ts = frame.bins.tile_size;
        let mut checked = 0u64;
        let mut swapped = 0u64;
        let mut depths: Vec<f32> = Vec::with_capacity(32);
        for y in (0..height).step_by(stride) {
            for x in (0..width).step_by(stride) {
                let tile = (y / ts) * frame.bins.tiles_x + x / ts;
                let (px, py) = (x as f32 + 0.5, y as f32 + 0.5);
                depths.clear();
                for &idx in &frame.bins.lists[tile] {
                    let i = idx as usize;
                    let [mx, my] = p.means[i];
                    let dx = px - mx;
                    let dy = py - my;
                    let conic = p.conics[i];
                    let power = -0.5 * (conic.a * dx * dx + conic.c * dy * dy)
                        - conic.b * dx * dy;
                    if power > 0.0 {
                        continue;
                    }
                    let alpha = (p.opacity[i] * power.exp()).min(ALPHA_MAX);
                    if alpha < ALPHA_MIN {
                        continue;
                    }
                    depths.push(p.depths[i]);
                    if depths.len() >= 24 {
                        break;
                    }
                }
                for w in depths.windows(2) {
                    checked += 1;
                    if w[0] > w[1] {
                        swapped += 1;
                    }
                }
            }
        }
        if checked == 0 {
            0.0
        } else {
            swapped as f64 / checked as f64
        }
    }

    /// Access the current shared sort (for tests/analysis).
    pub fn shared(&self) -> Option<&SharedSort> {
        self.shared.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::trajectory::{generate, TrajectoryKind};
    use crate::math::Vec3;
    use crate::scene::synth::test_scene;

    fn setup() -> (GaussianScene, Vec<Pose>, Intrinsics) {
        let scene = test_scene(31, 5000);
        let traj = generate(TrajectoryKind::VrHeadMotion, 7, 30, 1.3);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        (scene, traj.poses, intr)
    }

    #[test]
    fn sorts_once_per_window() {
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let mut sorts = 0;
        for pose in poses.iter().take(18) {
            let f = sched.frame(&scene, pose, &intr);
            if f.work.sorted {
                sorts += 1;
            }
        }
        assert_eq!(sorts, 3, "18 frames / window 6 = 3 sorts");
    }

    #[test]
    fn window_one_sorts_every_frame() {
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(1, 0, 16, 0.2, 100.0);
        for pose in poses.iter().take(5) {
            let f = sched.frame(&scene, pose, &intr);
            assert!(f.work.sorted);
        }
    }

    #[test]
    fn shared_frames_match_full_render_closely() {
        // The S^2 image should differ from the full pipeline by far less
        // than the image's dynamic range (sub-dB-scale artifacts only).
        use crate::pipeline::raster::{rasterize, RasterConfig};
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 8, 16, 0.2, 100.0);
        let mut worst = 0.0f64;
        for pose in poses.iter().take(12) {
            let f = sched.frame(&scene, pose, &intr);
            let shared_img =
                rasterize(&f.projected, &f.bins, intr.width, intr.height, &RasterConfig::default());
            let full_p = project(&scene, pose, &intr, 0.2, 100.0, 0.0);
            let full_b = bin_and_sort(&full_p, &intr, 16, 0.0);
            let full_img =
                rasterize(&full_p, &full_b, intr.width, intr.height, &RasterConfig::default());
            worst = worst.max(shared_img.image.mean_abs_diff(&full_img.image));
        }
        assert!(worst < 0.02, "mean abs diff {worst} too high for shared sorting");
    }

    #[test]
    fn stale_order_fraction_is_small() {
        let (scene, poses, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let mut max_frac = 0.0f64;
        for pose in poses.iter().take(12) {
            let f = sched.frame(&scene, pose, &intr);
            max_frac = max_frac.max(S2Scheduler::stale_order_fraction_sampled(
                &f, intr.width, intr.height, 8,
            ));
        }
        // Paper: ~0.2% (significant-Gaussian order changes); allow slack
        // for the synthetic scene's denser depth ties.
        assert!(max_frac < 0.05, "stale order fraction {max_frac}");
    }

    #[test]
    fn kill_switch_forces_sorting() {
        let (scene, _, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        sched.max_rotation_per_frame = 0.01; // ~0.6 deg/frame
        // A fast-rotating pose sequence.
        let poses: Vec<Pose> = (0..8)
            .map(|i| {
                let th = i as f32 * 0.1; // 5.7 deg/frame: way over threshold
                Pose::look_at(
                    Vec3::new(4.0 * th.sin(), 0.3, -4.0 * th.cos()),
                    Vec3::ZERO,
                )
            })
            .collect();
        let mut sorts = 0;
        for pose in &poses {
            let f = sched.frame(&scene, pose, &intr);
            if f.work.sorted {
                sorts += 1;
            }
        }
        assert_eq!(sorts, poses.len(), "kill switch must force per-frame sorting");
    }

    #[test]
    fn prediction_extrapolates_forward() {
        let (scene, _, intr) = setup();
        let mut sched = S2Scheduler::new(6, 4, 16, 0.2, 100.0);
        let p0 = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let p1 = Pose::look_at(Vec3::new(0.1, 0.0, -4.0), Vec3::ZERO);
        sched.frame(&scene, &p0, &intr);
        let pred = sched.predict_sort_pose(&p1);
        // Velocity 0.1/frame, window 6 -> predicted 0.3 ahead of p1.
        assert!((pred.position.x - (0.1 + 0.3)).abs() < 1e-4);
    }

    #[test]
    fn expanded_margin_readmits_edge_gaussians() {
        let (scene, poses, intr) = setup();
        let mut tight = S2Scheduler::new(6, 0, 16, 0.2, 100.0);
        let mut loose = S2Scheduler::new(6, 16, 16, 0.2, 100.0);
        let ft = tight.frame(&scene, &poses[0], &intr);
        let fl = loose.frame(&scene, &poses[0], &intr);
        assert!(fl.projected.len() >= ft.projected.len());
        assert!(fl.bins.total_entries() > ft.bins.total_entries());
    }
}
