//! `lumina` — the launcher CLI for the LuminSys reproduction.
//!
//! Subcommands:
//!   render    render a trajectory under one hardware variant
//!   serve     run N concurrent viewer sessions over one shared scene
//!   loadtest  population-scale churn scenarios with SLO reporting
//!   compare   run every paper variant on one config (Fig. 22 style)
//!   quality   per-frame quality vs the exact pipeline (Fig. 20 style)
//!   gen-scene synthesize a scene and write it as LGSC (CI caches this)
//!   runtime   load the AOT artifacts and smoke-execute them via PJRT
//!             (requires the `xla-runtime` build feature)
//!   info      print the resolved config
//!
//! Common flags: --config <toml>, --set key=value (repeatable),
//! --frames N, --out <path> (render/gen-scene), --sessions N /
//! --pipeline-depth D (serve).

use anyhow::{Context, Result};

use lumina::config::{HardwareVariant, LuminaConfig, Tier};
use lumina::coordinator::{AdmissionController, Coordinator, SessionPool};
use lumina::runtime::ArtifactRuntime;
use lumina::util::cli;

const VALUE_KEYS: &[&str] = &[
    "config",
    "set",
    "frames",
    "out",
    "variant",
    "artifacts",
    "sessions",
    "target-fps",
    "tiers",
    "pipeline-depth",
    "raster-substages",
    "cache-scope",
    "sort-scope",
    "scheduler",
    "scenario",
    "seed",
    "epochs",
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, VALUE_KEYS);
    match args.subcommand.as_deref() {
        Some("render") => cmd_render(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadtest") => cmd_loadtest(&args),
        Some("compare") => cmd_compare(&args),
        Some("quality") => cmd_quality(&args),
        Some("gen-scene") => cmd_gen_scene(&args),
        Some("runtime") => cmd_runtime(&args),
        Some("info") => cmd_info(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand: {o}\n");
            }
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    eprintln!(
        "lumina — real-time mobile neural rendering (paper reproduction)\n\
         \n\
         USAGE: lumina <render|serve|loadtest|compare|quality|runtime|info> [flags]\n\
         \n\
         FLAGS:\n\
           --config <file.toml>   load a run configuration\n\
           --set key=value        override a config field (repeatable)\n\
           --variant <name>       hardware variant (gpu, s2-gpu, rc-gpu,\n\
                                  nru-gpu, s2-acc, rc-acc, lumina, gscore,\n\
                                  lumina-gscore-frontend, ds2-gpu)\n\
           --frames <n>           trajectory length\n\
           --out <prefix>         write rendered frames as PPM\n\
           --sessions <n>         concurrent viewer sessions (serve cmd)\n\
           --target-fps <fps>     pool simulated-FPS target; enables the\n\
                                  tiered admission controller (serve cmd)\n\
           --tiers <ladder>       tier ladder, best first, e.g.\n\
                                  full,reduced,half (serve cmd)\n\
           --pipeline-depth <d>   frame slots per session: 1 synchronous,\n\
                                  2 double-buffered — frame N+1's frontend\n\
                                  overlaps frame N's raster, 3 chunk-\n\
                                  interleaved raster sub-stages (serve cmd)\n\
           --raster-substages <n> tile-range chunks per frame at\n\
                                  pipeline depth 3 (serve cmd)\n\
           --cache-scope <s>      radiance-cache ownership: private\n\
                                  (per-session), shared (one pool-wide\n\
                                  snapshot/merge cache per tile geometry),\n\
                                  or world (pose/tier/resolution-invariant\n\
                                  world-space hash cache) (serve cmd)\n\
           --sort-scope <s>       S^2 speculative-sort ownership: private\n\
                                  (per-session windows) or clustered (one\n\
                                  pool-wide sort per pose cluster per\n\
                                  epoch) (serve cmd)\n\
           --scheduler <s>        pool stage scheduler: session (each\n\
                                  worker owns whole sessions) or stealing\n\
                                  (idle workers claim other sessions'\n\
                                  stage tasks; bitwise-identical output)\n\
                                  (serve + loadtest cmds)\n\
           --scenario <name>      loadtest scenario: poisson_churn,\n\
                                  diurnal_ramp, flash_crowd,\n\
                                  spectator_broadcast, teleport_stress;\n\
                                  prints the SLO report as JSON\n\
           --seed <n>             loadtest churn/pose seed (default 7)\n\
           --epochs <n>           override the scenario's epoch count\n\
           --smoke                loadtest CI pair: flash_crowd twice\n\
                                  (byte-identical reports enforced) plus\n\
                                  spectator_broadcast under clustered and\n\
                                  private sort scopes; emits metric/ rows\n\
                                  to $LUMINA_BENCH_JSON\n\
           --artifacts <dir>      AOT artifact directory (runtime cmd)"
    );
}

fn load_config(args: &cli::Args) -> Result<LuminaConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => LuminaConfig::load(path)?,
        None => LuminaConfig::quick_test(),
    };
    if let Some(v) = args.get("variant") {
        cfg.variant = HardwareVariant::parse(v)?;
    }
    if let Some(f) = args.get("frames") {
        cfg.camera.frames = f.parse().context("--frames must be an integer")?;
    }
    for spec in args.get_all("set") {
        cfg.apply_override(spec)?;
    }
    Ok(cfg)
}

fn cmd_render(args: &cli::Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out_prefix = args.get("out").map(str::to_string);
    println!(
        "rendering {} frames | variant={} | scene={} Gaussians | {}x{}",
        cfg.camera.frames,
        cfg.variant.label(),
        cfg.gaussian_count(),
        cfg.camera.width,
        cfg.camera.height
    );
    let mut coord = Coordinator::new(cfg)?;
    let mut report = lumina::coordinator::RunReport::new(coord.cfg.variant.label());
    let mut frame_idx = 0usize;
    while coord.remaining() > 0 {
        let f = coord.step()?;
        if let Some(prefix) = &out_prefix {
            let path = format!("{prefix}_{frame_idx:04}.ppm");
            f.image.write_ppm(&path)?;
        }
        report.push(f.report);
        frame_idx += 1;
    }
    println!("{}", report.summary());
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(t) = args.get("target-fps") {
        let t: f64 = t.parse().context("--target-fps must be a number")?;
        anyhow::ensure!(
            t >= 0.0 && t.is_finite(),
            "--target-fps must be finite and >= 0 (0 disables admission control), got {t}"
        );
        cfg.pool.target_fps = t;
    }
    if let Some(t) = args.get("tiers") {
        cfg.pool.tiers = Tier::parse_ladder(t)?;
    }
    if let Some(d) = args.get("pipeline-depth") {
        let d: usize = d.parse().context("--pipeline-depth must be an integer")?;
        // Route through the config validator (1..=3).
        cfg.apply_override(&format!("pool.pipeline_depth={d}"))?;
    }
    if let Some(s) = args.get("raster-substages") {
        let s: usize = s.parse().context("--raster-substages must be an integer")?;
        // Route through the config validator (>= 1).
        cfg.apply_override(&format!("pool.raster_substages={s}"))?;
    }
    if let Some(s) = args.get("cache-scope") {
        // Route through the config validator (private|shared).
        cfg.apply_override(&format!("pool.cache_scope={s}"))?;
    }
    if let Some(s) = args.get("sort-scope") {
        // Route through the config validator (private|clustered).
        cfg.apply_override(&format!("pool.sort_scope={s}"))?;
    }
    if let Some(s) = args.get("scheduler") {
        // Route through the config validator (session|stealing).
        cfg.apply_override(&format!("pool.scheduler={s}"))?;
    }
    let n: usize = args.get_parsed("sessions", 4);
    println!(
        "serving {n} sessions | variant={} | scene={} Gaussians | {} frames each @ {}x{} \
         | pipeline depth {} | cache scope {} | sort scope {} | scheduler {}",
        cfg.variant.label(),
        cfg.gaussian_count(),
        cfg.camera.frames,
        cfg.camera.width,
        cfg.camera.height,
        cfg.pool.pipeline_depth,
        cfg.pool.cache_scope.label(),
        cfg.pool.sort_scope.label(),
        cfg.pool.scheduler.label()
    );
    let admission = cfg.pool.target_fps > 0.0;
    let mut pool = SessionPool::builder(cfg.clone()).sessions(n).build()?;
    let report = if admission {
        let ctrl = AdmissionController::from_config(&cfg)?;
        println!(
            "admission control: target {:.1} pool sim-fps | ladder [{}]",
            ctrl.target_fps(),
            Tier::ladder_name(ctrl.ladder()),
        );
        pool.serve(&ctrl)?
    } else {
        pool.run()?
    };
    for (i, r) in report.sessions.iter().enumerate() {
        println!("  session {i} [{}]: {}", r.tier_sequence().join(">"), r.summary());
    }
    println!("{}", report.summary());
    if admission {
        println!(
            "pool sim-fps {:.1} vs target {:.1} -> {}",
            report.pool_fps(),
            cfg.pool.target_fps,
            if report.pool_fps() >= cfg.pool.target_fps { "target held" } else { "TARGET MISSED" }
        );
    }
    Ok(())
}

fn cmd_loadtest(args: &cli::Args) -> Result<()> {
    use lumina::workload::{run_loadtest, LoadtestOptions, Scenario};
    let base = load_config(args)?;
    let seed = args.try_parsed::<u64>("seed")?.unwrap_or(7);
    let epochs = args.try_parsed::<usize>("epochs")?;
    let smoke = args.has_flag("smoke") || std::env::var("LUMINA_BENCH_SMOKE").is_ok();
    // `load_config` already applied --set to `base`, but the scenario
    // preset re-binds pose family / scopes / variant on top of it; the
    // specs are threaded through again so user overrides win over the
    // preset too (applying a key=value override twice is idempotent).
    let mut overrides: Vec<String> = args.get_all("set").to_vec();
    if let Some(s) = args.get("scheduler") {
        // Threaded as an override so it survives the scenario preset,
        // and validated by the config parser (session|stealing).
        overrides.push(format!("pool.scheduler={s}"));
    }
    match args.get("scenario") {
        Some(name) => {
            let scenario = Scenario::parse(name)?;
            let opts = LoadtestOptions { scenario, seed, epochs, smoke, overrides };
            let report = run_loadtest(base, &opts)?;
            let json = report.to_json();
            eprintln!(
                "{}: {} frames over {} epochs | p50/p95/p99 {}/{}/{} ns | {} refused | {} demotions",
                report.scenario,
                report.total_frames,
                report.epochs.len(),
                report.p50_ns,
                report.p95_ns,
                report.p99_ns,
                report.refusals,
                report.demotions,
            );
            if let Some(path) = args.get("out") {
                std::fs::write(path, &json)
                    .with_context(|| format!("writing loadtest report to {path}"))?;
                eprintln!("wrote {path}");
            }
            // stdout carries exactly the report bytes: the determinism
            // contract is `lumina loadtest ... | sha256sum`-able.
            println!("{json}");
            Ok(())
        }
        None if smoke => loadtest_smoke(base, seed, epochs, &overrides),
        None => {
            let names: Vec<&str> = Scenario::all().iter().map(|s| s.name()).collect();
            anyhow::bail!(
                "loadtest needs --scenario <name> (or --smoke for the CI pair); \
                 scenarios: {}",
                names.join(", ")
            )
        }
    }
}

/// The CI smoke pair behind `lumina loadtest --smoke`:
///
/// 1. `flash_crowd` twice at the same seed — the two reports must be
///    byte-identical (churn + admission refusals are deterministic);
/// 2. `spectator_broadcast` under clustered then private sort scope —
///    the clustered-scope p99 must not exceed the private-scope p99
///    (bench_gate enforces both invariants from the metric/ rows);
/// 3. `flash_crowd` under the world-space cache scope at 1, 2, and 4
///    render threads — the three reports must be byte-identical (the
///    world merge is a function of the delta set, never of how
///    sessions were scheduled onto threads).
///
/// Rows are written through [`lumina::util::bench::results_json`]
/// directly rather than via `bench::Runner`, whose positional-arg
/// filter would swallow the `loadtest` subcommand word.
fn loadtest_smoke(
    base: LuminaConfig,
    seed: u64,
    epochs: Option<usize>,
    overrides: &[String],
) -> Result<()> {
    use lumina::util::bench::{results_json, Measurement};
    use lumina::workload::{run_loadtest, LoadtestOptions, Scenario};
    use std::time::Duration;
    let opts = |scenario: Scenario, extra: &[&str]| LoadtestOptions {
        scenario,
        seed,
        epochs,
        smoke: true,
        overrides: overrides
            .iter()
            .cloned()
            .chain(extra.iter().map(|s| s.to_string()))
            .collect(),
    };
    let mut rows: Vec<Measurement> = Vec::new();
    let mut metric = |rows: &mut Vec<Measurement>, name: &str, value: u64| {
        let d = Duration::from_nanos(value);
        eprintln!("{name:<44} {value:>12}");
        rows.push(Measurement {
            name: name.to_string(),
            iters: 1,
            min: d,
            median: d,
            mean: d,
        });
    };

    let flash1 = run_loadtest(base.clone(), &opts(Scenario::FlashCrowd, &[]))?;
    let flash2 = run_loadtest(base.clone(), &opts(Scenario::FlashCrowd, &[]))?;
    anyhow::ensure!(
        flash1.to_json() == flash2.to_json(),
        "flash_crowd loadtest reports diverged at seed {seed}: determinism regression"
    );
    // Same scenario under the pool-wide stealing scheduler: every SLO
    // byte must match the per-session run (schedulers may only move
    // work between workers, never change what is rendered or planned).
    let flash_steal =
        run_loadtest(base.clone(), &opts(Scenario::FlashCrowd, &["pool.scheduler=stealing"]))?;
    anyhow::ensure!(
        flash1.to_json() == flash_steal.to_json(),
        "flash_crowd loadtest report changed under pool.scheduler=stealing at seed {seed}: \
         scheduler parity regression"
    );
    eprintln!(
        "flash_crowd x2 @ seed {seed}: byte-identical | stealing parity OK | {} frames | \
         {} refused | {} demotions",
        flash1.total_frames, flash1.refusals, flash1.demotions
    );
    metric(&mut rows, "metric/loadtest_refusals_run1", flash1.refusals as u64);
    metric(&mut rows, "metric/loadtest_refusals_run2", flash2.refusals as u64);
    metric(&mut rows, "metric/loadtest_flash_p99_ns", flash1.p99_ns);
    // Per-scheduler refusal/demotion rows for the bench gate's parity
    // invariant, plus the occupancy model's idle/critical-path sums
    // (identical fields on both reports — the model is an epoch-shape
    // function, so emitting each scheduler's own view keeps the gate
    // honest).
    metric(&mut rows, "metric/loadtest_refusals_session", flash1.refusals as u64);
    metric(&mut rows, "metric/loadtest_refusals_stealing", flash_steal.refusals as u64);
    metric(&mut rows, "metric/loadtest_demotions_session", flash1.demotions as u64);
    metric(&mut rows, "metric/loadtest_demotions_stealing", flash_steal.demotions as u64);
    metric(&mut rows, "metric/steal_idle_worker_frames", flash_steal.steal_idle_worker_frames);
    metric(
        &mut rows,
        "metric/session_idle_worker_frames",
        flash1.session_idle_worker_frames,
    );
    metric(
        &mut rows,
        "metric/steal_epoch_critical_path",
        flash_steal.steal_epoch_critical_path_frames,
    );

    let clustered = run_loadtest(
        base.clone(),
        &opts(Scenario::SpectatorBroadcast, &["pool.sort_scope=clustered"]),
    )?;
    let private = run_loadtest(
        base.clone(),
        &opts(Scenario::SpectatorBroadcast, &["pool.sort_scope=private"]),
    )?;
    eprintln!(
        "spectator_broadcast: clustered p99 {} ns ({} sorts) vs private p99 {} ns ({} sorts)",
        clustered.p99_ns, clustered.sorted_frames, private.p99_ns, private.sorted_frames
    );
    metric(&mut rows, "metric/loadtest_broadcast_p99_clustered_ns", clustered.p99_ns);
    metric(&mut rows, "metric/loadtest_broadcast_p99_private_ns", private.p99_ns);
    metric(&mut rows, "metric/loadtest_broadcast_sorted_clustered", clustered.sorted_frames as u64);
    metric(&mut rows, "metric/loadtest_broadcast_sorted_private", private.sorted_frames as u64);

    // World-scope determinism across render thread counts: the same
    // flash-crowd churn with every pooled session on the world-space
    // hash cache must serialize byte-identically at 1, 2, and 4
    // threads (the epoch merge is a function of the delta set alone).
    let world_json: Vec<String> = [1usize, 2, 4]
        .iter()
        .map(|&threads| {
            lumina::util::par::set_num_threads(threads);
            let r = run_loadtest(
                base.clone(),
                &opts(Scenario::FlashCrowd, &["pool.cache_scope=world"]),
            )
            .map(|r| r.to_json());
            lumina::util::par::set_num_threads(0);
            r
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        world_json[0] == world_json[1] && world_json[0] == world_json[2],
        "world-scope flash_crowd loadtest diverged across 1/2/4 threads at seed {seed}: \
         world-cache determinism regression"
    );
    eprintln!("flash_crowd @ world scope: byte-identical across 1/2/4 threads");

    if let Ok(path) = std::env::var("LUMINA_BENCH_JSON") {
        std::fs::write(&path, results_json("loadtest", &rows))
            .with_context(|| format!("writing bench rows to {path}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_compare(args: &cli::Args) -> Result<()> {
    let base = load_config(args)?;
    println!(
        "comparing variants | scene={} Gaussians | {} frames @ {}x{}",
        base.gaussian_count(),
        base.camera.frames,
        base.camera.width,
        base.camera.height
    );
    let mut baseline_time = None;
    let mut baseline_energy = None;
    for variant in HardwareVariant::evaluation_set() {
        let mut cfg = base.clone();
        cfg.variant = variant;
        let mut coord = Coordinator::new(cfg)?;
        let report = coord.run()?;
        let t = report.mean_time_s();
        let e = report.mean_energy_j();
        if variant == HardwareVariant::Gpu {
            baseline_time = Some(t);
            baseline_energy = Some(e);
        }
        let speedup = baseline_time.map(|b| b / t).unwrap_or(1.0);
        let energy = baseline_energy.map(|b| e / b).unwrap_or(1.0);
        println!(
            "{}  speedup={:>5.2}x  norm-energy={:>5.2}",
            report.summary(),
            speedup,
            energy
        );
    }
    Ok(())
}

fn cmd_quality(args: &cli::Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!(
        "quality run | variant={} | {} frames",
        cfg.variant.label(),
        cfg.camera.frames
    );
    let mut coord = Coordinator::new(cfg)?;
    let mut report = lumina::coordinator::RunReport::new(coord.cfg.variant.label());
    while coord.remaining() > 0 {
        let f = coord.step_with_quality()?;
        println!(
            "frame {:>3}: psnr={:>6.2} dB  time={:>7.3} ms  hit={:>5.1}%",
            f.report.frame,
            f.report.psnr_vs_ref.unwrap_or(f64::NAN),
            f.report.time_s * 1e3,
            f.report.cache.hit_rate() * 100.0
        );
        report.push(f.report);
    }
    println!("{}", report.summary());
    Ok(())
}

fn cmd_gen_scene(args: &cli::Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").context("gen-scene needs --out <path.lgsc>")?;
    let scene = lumina::scene::synth::synth_scene(
        cfg.scene.class,
        cfg.scene.seed,
        cfg.gaussian_count(),
    );
    lumina::scene::io::write_scene(out, &scene)?;
    println!(
        "wrote {} Gaussians (class {:?}, seed {}) to {out}",
        scene.len(),
        cfg.scene.class,
        cfg.scene.seed
    );
    Ok(())
}

fn cmd_runtime(args: &cli::Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    println!("loading AOT artifacts from {dir}/ ...");
    let rt = ArtifactRuntime::load(dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {:?}", rt.artifact_names());
    // Smoke-execute the SH kernel with a trivial input.
    let dirs = vec![[0.0f32, 0.0, 1.0]];
    let mut coeffs = [[0.0f32; 3]; lumina::constants::SH_COEFFS];
    coeffs[0] = [1.0, 1.0, 1.0];
    let rgb = rt.sh_eval_chunk(&dirs, &[coeffs])?;
    println!("sh_eval smoke: {:?} (expect ~[0.782, 0.782, 0.782])", rgb[0]);
    println!("runtime OK");
    Ok(())
}

fn cmd_info(args: &cli::Args) -> Result<()> {
    let cfg = load_config(args)?;
    print!("{}", cfg.to_toml());
    Ok(())
}
