//! Minimal linear-algebra substrate: 3-vectors, 3x3/4x4 matrices, quaternions.
//!
//! Deliberately small and dependency-free; only what projection, camera
//! motion, and covariance math need. Row-major storage throughout.

/// A 3-component f32 vector.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    #[inline]
    pub fn new(x: f32, y: f32, z: f32) -> Self {
        Vec3 { x, y, z }
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline]
    pub fn norm(self) -> f32 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n > 1e-12 {
            self * (1.0 / n)
        } else {
            Vec3::ZERO
        }
    }

    #[inline]
    pub fn lerp(self, o: Vec3, t: f32) -> Vec3 {
        self + (o - self) * t
    }

    #[inline]
    pub fn to_array(self) -> [f32; 3] {
        [self.x, self.y, self.z]
    }

    #[inline]
    pub fn from_array(a: [f32; 3]) -> Self {
        Vec3::new(a[0], a[1], a[2])
    }
}

impl std::ops::Add for Vec3 {
    type Output = Vec3;
    #[inline]
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl std::ops::Sub for Vec3 {
    type Output = Vec3;
    #[inline]
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl std::ops::Mul<f32> for Vec3 {
    type Output = Vec3;
    #[inline]
    fn mul(self, s: f32) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl std::ops::Neg for Vec3 {
    type Output = Vec3;
    #[inline]
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

/// Row-major 3x3 matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mat3 {
    pub m: [[f32; 3]; 3],
}

impl Mat3 {
    pub const IDENTITY: Mat3 = Mat3 {
        m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]],
    };

    #[inline]
    pub fn from_rows(r0: [f32; 3], r1: [f32; 3], r2: [f32; 3]) -> Self {
        Mat3 { m: [r0, r1, r2] }
    }

    #[inline]
    pub fn mul_vec(&self, v: Vec3) -> Vec3 {
        Vec3::new(
            self.m[0][0] * v.x + self.m[0][1] * v.y + self.m[0][2] * v.z,
            self.m[1][0] * v.x + self.m[1][1] * v.y + self.m[1][2] * v.z,
            self.m[2][0] * v.x + self.m[2][1] * v.y + self.m[2][2] * v.z,
        )
    }

    pub fn mul(&self, o: &Mat3) -> Mat3 {
        let mut r = [[0.0f32; 3]; 3];
        for (i, row) in r.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = (0..3).map(|k| self.m[i][k] * o.m[k][j]).sum();
            }
        }
        Mat3 { m: r }
    }

    #[inline]
    pub fn transpose(&self) -> Mat3 {
        let m = &self.m;
        Mat3::from_rows(
            [m[0][0], m[1][0], m[2][0]],
            [m[0][1], m[1][1], m[2][1]],
            [m[0][2], m[1][2], m[2][2]],
        )
    }

    /// Scale columns by s (i.e. self * diag(s)).
    #[inline]
    pub fn scale_cols(&self, s: Vec3) -> Mat3 {
        let m = &self.m;
        Mat3::from_rows(
            [m[0][0] * s.x, m[0][1] * s.y, m[0][2] * s.z],
            [m[1][0] * s.x, m[1][1] * s.y, m[1][2] * s.z],
            [m[2][0] * s.x, m[2][1] * s.y, m[2][2] * s.z],
        )
    }
}

/// Unit quaternion (w, x, y, z) for rotations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quat {
    pub w: f32,
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Quat {
    pub const IDENTITY: Quat = Quat { w: 1.0, x: 0.0, y: 0.0, z: 0.0 };

    #[inline]
    pub fn new(w: f32, x: f32, y: f32, z: f32) -> Self {
        Quat { w, x, y, z }
    }

    pub fn from_axis_angle(axis: Vec3, angle: f32) -> Self {
        let a = axis.normalized();
        let (s, c) = (angle * 0.5).sin_cos();
        Quat::new(c, a.x * s, a.y * s, a.z * s)
    }

    #[inline]
    pub fn normalized(self) -> Quat {
        let n = (self.w * self.w + self.x * self.x + self.y * self.y + self.z * self.z).sqrt();
        if n > 1e-12 {
            Quat::new(self.w / n, self.x / n, self.y / n, self.z / n)
        } else {
            Quat::IDENTITY
        }
    }

    pub fn mul(self, o: Quat) -> Quat {
        Quat::new(
            self.w * o.w - self.x * o.x - self.y * o.y - self.z * o.z,
            self.w * o.x + self.x * o.w + self.y * o.z - self.z * o.y,
            self.w * o.y - self.x * o.z + self.y * o.w + self.z * o.x,
            self.w * o.z + self.x * o.y - self.y * o.x + self.z * o.w,
        )
    }

    /// Rotation matrix of the normalized quaternion.
    pub fn to_mat3(self) -> Mat3 {
        let q = self.normalized();
        let (w, x, y, z) = (q.w, q.x, q.y, q.z);
        Mat3::from_rows(
            [
                1.0 - 2.0 * (y * y + z * z),
                2.0 * (x * y - w * z),
                2.0 * (x * z + w * y),
            ],
            [
                2.0 * (x * y + w * z),
                1.0 - 2.0 * (x * x + z * z),
                2.0 * (y * z - w * x),
            ],
            [
                2.0 * (x * z - w * y),
                2.0 * (y * z + w * x),
                1.0 - 2.0 * (x * x + y * y),
            ],
        )
    }

    /// Spherical linear interpolation (shortest arc).
    pub fn slerp(self, other: Quat, t: f32) -> Quat {
        let a = self.normalized();
        let mut b = other.normalized();
        let mut dot = a.w * b.w + a.x * b.x + a.y * b.y + a.z * b.z;
        if dot < 0.0 {
            b = Quat::new(-b.w, -b.x, -b.y, -b.z);
            dot = -dot;
        }
        if dot > 0.9995 {
            // Nearly parallel: nlerp.
            return Quat::new(
                a.w + (b.w - a.w) * t,
                a.x + (b.x - a.x) * t,
                a.y + (b.y - a.y) * t,
                a.z + (b.z - a.z) * t,
            )
            .normalized();
        }
        let theta = dot.clamp(-1.0, 1.0).acos();
        let (s0, s1) = (((1.0 - t) * theta).sin(), (t * theta).sin());
        let inv = 1.0 / theta.sin();
        Quat::new(
            (a.w * s0 + b.w * s1) * inv,
            (a.x * s0 + b.x * s1) * inv,
            (a.y * s0 + b.y * s1) * inv,
            (a.z * s0 + b.z * s1) * inv,
        )
    }

    #[inline]
    pub fn rotate(self, v: Vec3) -> Vec3 {
        self.to_mat3().mul_vec(v)
    }
}

/// Symmetric 2x2 matrix packed as (a, b, c) = [[a, b], [b, c]].
/// Used for projected covariances and their inverses (conics).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Sym2 {
    pub a: f32,
    pub b: f32,
    pub c: f32,
}

impl Sym2 {
    #[inline]
    pub fn det(self) -> f32 {
        self.a * self.c - self.b * self.b
    }

    /// Inverse (the conic), or None when degenerate.
    #[inline]
    pub fn inverse(self) -> Option<Sym2> {
        let d = self.det();
        if d.abs() < 1e-12 {
            return None;
        }
        Some(Sym2 { a: self.c / d, b: -self.b / d, c: self.a / d })
    }

    /// Largest eigenvalue (for the 3-sigma screen-space radius).
    #[inline]
    pub fn max_eigenvalue(self) -> f32 {
        let mid = 0.5 * (self.a + self.c);
        mid + (mid * mid - self.det()).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() < tol, "{a} != {b} (tol {tol})");
    }

    #[test]
    fn vec3_ops() {
        let v = Vec3::new(1.0, 2.0, 3.0);
        let w = Vec3::new(4.0, -5.0, 6.0);
        assert_close(v.dot(w), 12.0, 1e-6);
        let c = v.cross(w);
        // orthogonal to both
        assert_close(c.dot(v), 0.0, 1e-4);
        assert_close(c.dot(w), 0.0, 1e-4);
        assert_close(v.normalized().norm(), 1.0, 1e-6);
    }

    #[test]
    fn quat_identity_rotation() {
        let v = Vec3::new(0.3, -0.7, 0.2);
        let r = Quat::IDENTITY.rotate(v);
        assert_close((r - v).norm(), 0.0, 1e-6);
    }

    #[test]
    fn quat_axis_angle_90deg() {
        let q = Quat::from_axis_angle(Vec3::new(0.0, 0.0, 1.0), std::f32::consts::FRAC_PI_2);
        let r = q.rotate(Vec3::new(1.0, 0.0, 0.0));
        assert_close(r.x, 0.0, 1e-6);
        assert_close(r.y, 1.0, 1e-6);
    }

    #[test]
    fn quat_mat_orthonormal() {
        let q = Quat::new(0.3, -0.2, 0.9, 0.1);
        let m = q.to_mat3();
        let mt = m.transpose();
        let id = m.mul(&mt);
        for i in 0..3 {
            for j in 0..3 {
                assert_close(id.m[i][j], if i == j { 1.0 } else { 0.0 }, 1e-5);
            }
        }
    }

    #[test]
    fn quat_composition_matches_matrix_product() {
        let q1 = Quat::from_axis_angle(Vec3::new(1.0, 0.5, 0.0), 0.7);
        let q2 = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 1.0), -0.3);
        let v = Vec3::new(0.2, 0.4, -0.8);
        let via_quat = q1.mul(q2).rotate(v);
        let via_mat = q1.to_mat3().mul(&q2.to_mat3()).mul_vec(v);
        assert_close((via_quat - via_mat).norm(), 0.0, 1e-5);
    }

    #[test]
    fn slerp_endpoints_and_midpoint() {
        let a = Quat::IDENTITY;
        let b = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), 1.0);
        let s0 = a.slerp(b, 0.0);
        let s1 = a.slerp(b, 1.0);
        let sm = a.slerp(b, 0.5);
        let expect_mid = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), 0.5);
        for (got, want) in [(s0, a), (s1, b), (sm, expect_mid)] {
            let d = got.w * want.w + got.x * want.x + got.y * want.y + got.z * want.z;
            assert!(d.abs() > 1.0 - 1e-5, "slerp mismatch: {got:?} vs {want:?}");
        }
    }

    #[test]
    fn sym2_inverse_roundtrip() {
        let s = Sym2 { a: 2.0, b: 0.5, c: 1.5 };
        let inv = s.inverse().unwrap();
        // s * inv == identity (symmetric product)
        assert_close(s.a * inv.a + s.b * inv.b, 1.0, 1e-5);
        assert_close(s.a * inv.b + s.b * inv.c, 0.0, 1e-5);
        assert_close(s.b * inv.b + s.c * inv.c, 1.0, 1e-5);
    }

    #[test]
    fn sym2_eigenvalue_bounds_trace() {
        let s = Sym2 { a: 3.0, b: 1.0, c: 2.0 };
        let e = s.max_eigenvalue();
        assert!(e >= 3.0 && e <= 5.0);
    }

    #[test]
    fn mat3_scale_cols() {
        let m = Mat3::IDENTITY.scale_cols(Vec3::new(2.0, 3.0, 4.0));
        assert_close(m.m[0][0], 2.0, 1e-6);
        assert_close(m.m[1][1], 3.0, 1e-6);
        assert_close(m.m[2][2], 4.0, 1e-6);
    }
}
