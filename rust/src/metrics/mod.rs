//! Image-quality metrics: PSNR, SSIM, and an LPIPS proxy.
//!
//! LPIPS in the paper uses a pretrained VGG; no pretrained network is
//! available at build time, so `lpips_proxy` is a multi-scale
//! gradient-magnitude perceptual distance (DESIGN.md §8): it responds to
//! the same artifact classes the paper's LPIPS flags (tile-edge seams,
//! large-Gaussian smears) and is monotone in perceptual severity, but its
//! absolute values are not comparable to VGG-LPIPS.

use crate::pipeline::Image;

/// Peak signal-to-noise ratio in dB over RGB in [0, 1].
pub fn psnr(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.data.len(), b.data.len(), "image size mismatch");
    let mut se = 0.0f64;
    for (pa, pb) in a.data.iter().zip(&b.data) {
        for c in 0..3 {
            let d = (pa[c].clamp(0.0, 1.0) - pb[c].clamp(0.0, 1.0)) as f64;
            se += d * d;
        }
    }
    let mse = se / (a.data.len() * 3) as f64;
    if mse <= 1e-12 {
        return 100.0; // identical images: cap like common tooling
    }
    10.0 * (1.0 / mse).log10()
}

/// Mean SSIM with an 8x8 box window over the luma-like mean of RGB.
/// (The paper uses the standard 11x11 Gaussian SSIM; a box window changes
/// absolute values slightly but preserves ordering between methods.)
pub fn ssim(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    let w = 8usize;
    let (c1, c2) = (0.01f64 * 0.01, 0.03f64 * 0.03);
    let gray = |img: &Image| -> Vec<f64> {
        img.data
            .iter()
            .map(|p| ((p[0] + p[1] + p[2]) / 3.0).clamp(0.0, 1.0) as f64)
            .collect()
    };
    let ga = gray(a);
    let gb = gray(b);
    let mut total = 0.0;
    let mut count = 0usize;
    let (width, height) = (a.width, a.height);
    for by in (0..height).step_by(w) {
        for bx in (0..width).step_by(w) {
            let mut ma = 0.0;
            let mut mb = 0.0;
            let mut n = 0.0;
            for y in by..(by + w).min(height) {
                for x in bx..(bx + w).min(width) {
                    ma += ga[y * width + x];
                    mb += gb[y * width + x];
                    n += 1.0;
                }
            }
            ma /= n;
            mb /= n;
            let mut va = 0.0;
            let mut vb = 0.0;
            let mut cov = 0.0;
            for y in by..(by + w).min(height) {
                for x in bx..(bx + w).min(width) {
                    let da = ga[y * width + x] - ma;
                    let db = gb[y * width + x] - mb;
                    va += da * da;
                    vb += db * db;
                    cov += da * db;
                }
            }
            va /= n;
            vb /= n;
            cov /= n;
            let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                / ((ma * ma + mb * mb + c1) * (va + vb + c2));
            total += s;
            count += 1;
        }
    }
    total / count as f64
}

/// Multi-scale gradient-magnitude perceptual distance (LPIPS proxy).
/// 0 = identical; larger = perceptually worse. See module docs.
pub fn lpips_proxy(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    let mut total = 0.0;
    let mut scale_a = a.clone();
    let mut scale_b = b.clone();
    let mut weight = 1.0;
    let mut wsum = 0.0;
    for _ in 0..3 {
        total += weight * grad_dist(&scale_a, &scale_b);
        wsum += weight;
        weight *= 0.5;
        if scale_a.width < 16 || scale_a.height < 16
            || scale_a.width % 2 != 0 || scale_a.height % 2 != 0
        {
            break;
        }
        scale_a = scale_a.downsample2();
        scale_b = scale_b.downsample2();
    }
    total / wsum
}

fn grad_dist(a: &Image, b: &Image) -> f64 {
    let (w, h) = (a.width, a.height);
    let lum = |img: &Image, x: usize, y: usize| -> f32 {
        let p = img.at(x, y);
        (p[0] + p[1] + p[2]) / 3.0
    };
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for y in 0..h.saturating_sub(1) {
        for x in 0..w.saturating_sub(1) {
            let gax = lum(a, x + 1, y) - lum(a, x, y);
            let gay = lum(a, x, y + 1) - lum(a, x, y);
            let gbx = lum(b, x + 1, y) - lum(b, x, y);
            let gby = lum(b, x, y + 1) - lum(b, x, y);
            let ma = (gax * gax + gay * gay).sqrt();
            let mb = (gbx * gbx + gby * gby).sqrt();
            // Contrast-normalized gradient difference.
            let d = ((gax - gbx).powi(2) + (gay - gby).powi(2)).sqrt();
            acc += (d / (ma + mb + 0.05)) as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        acc / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy(img: &Image, amp: f32, seed: u32) -> Image {
        let mut out = img.clone();
        let mut state = seed;
        for p in out.data.iter_mut() {
            for c in p.iter_mut() {
                state = state.wrapping_mul(1664525).wrapping_add(1013904223);
                let r = (state >> 8) as f32 / (1u32 << 24) as f32 - 0.5;
                *c = (*c + amp * r).clamp(0.0, 1.0);
            }
        }
        out
    }

    fn gradient_image(w: usize, h: usize) -> Image {
        let mut img = Image::new(w, h);
        for y in 0..h {
            for x in 0..w {
                img.set(x, y, [x as f32 / w as f32, y as f32 / h as f32, 0.5]);
            }
        }
        img
    }

    #[test]
    fn psnr_identical_is_high() {
        let img = gradient_image(32, 32);
        assert_eq!(psnr(&img, &img), 100.0);
    }

    #[test]
    fn psnr_monotone_in_noise() {
        let img = gradient_image(64, 64);
        let small = psnr(&img, &noisy(&img, 0.01, 1));
        let large = psnr(&img, &noisy(&img, 0.1, 2));
        assert!(small > large);
        assert!(small > 35.0 && large > 15.0);
    }

    #[test]
    fn psnr_known_value() {
        // Constant offset of 0.1 -> MSE 0.01 -> PSNR 20 dB.
        let a = Image::new(16, 16);
        let mut b = Image::new(16, 16);
        for p in b.data.iter_mut() {
            *p = [0.1, 0.1, 0.1];
        }
        assert!((psnr(&a, &b) - 20.0).abs() < 1e-6);
    }

    #[test]
    fn ssim_bounds_and_identity() {
        let img = gradient_image(64, 64);
        assert!((ssim(&img, &img) - 1.0).abs() < 1e-9);
        let s = ssim(&img, &noisy(&img, 0.2, 3));
        assert!(s < 1.0 && s > 0.0);
    }

    #[test]
    fn ssim_monotone_in_noise() {
        let img = gradient_image(64, 64);
        let s1 = ssim(&img, &noisy(&img, 0.02, 4));
        let s2 = ssim(&img, &noisy(&img, 0.2, 5));
        assert!(s1 > s2);
    }

    #[test]
    fn lpips_proxy_identity_and_monotone() {
        let img = gradient_image(64, 64);
        assert_eq!(lpips_proxy(&img, &img), 0.0);
        let d1 = lpips_proxy(&img, &noisy(&img, 0.02, 6));
        let d2 = lpips_proxy(&img, &noisy(&img, 0.2, 7));
        assert!(d1 > 0.0);
        assert!(d2 > d1);
    }

    #[test]
    fn lpips_proxy_flags_structural_artifacts() {
        // A tile-seam artifact (the Fig. 8 failure) should register more
        // than an equal-energy global brightness shift.
        let img = gradient_image(64, 64);
        let mut seam = img.clone();
        for y in 0..64 {
            for x in 30..34 {
                let mut p = seam.at(x, y);
                p[0] = (p[0] + 0.3).min(1.0);
                seam.set(x, y, p);
            }
        }
        let mut shift = img.clone();
        // Equal total |delta| spread uniformly.
        let delta = 0.3 * (4.0 * 64.0) / (64.0 * 64.0);
        for p in shift.data.iter_mut() {
            p[0] = (p[0] + delta).min(1.0);
        }
        assert!(lpips_proxy(&img, &seam) > lpips_proxy(&img, &shift));
    }
}
