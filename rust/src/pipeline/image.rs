//! RGB framebuffer with f32 channels.

/// A height x width x 3 image, row-major, f32 channels in [0, 1]-ish range
/// (compositing can momentarily exceed 1 before background blending).
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub data: Vec<[f32; 3]>,
}

impl Image {
    /// A black image.
    pub fn new(width: usize, height: usize) -> Self {
        Image { width, height, data: vec![[0.0; 3]; width * height] }
    }

    #[inline]
    pub fn at(&self, x: usize, y: usize) -> [f32; 3] {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: [f32; 3]) {
        self.data[y * self.width + x] = v;
    }

    /// Mean absolute difference against another image of the same size.
    pub fn mean_abs_diff(&self, other: &Image) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        let total: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                ((a[0] - b[0]).abs() + (a[1] - b[1]).abs() + (a[2] - b[2]).abs()) as f64
            })
            .sum();
        total / (self.data.len() * 3) as f64
    }

    /// Downsample by 2x (box filter). Panics on odd dimensions.
    pub fn downsample2(&self) -> Image {
        assert!(self.width % 2 == 0 && self.height % 2 == 0);
        let (w, h) = (self.width / 2, self.height / 2);
        let mut out = Image::new(w, h);
        for y in 0..h {
            for x in 0..w {
                let mut acc = [0.0f32; 3];
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let p = self.at(2 * x + dx, 2 * y + dy);
                    for c in 0..3 {
                        acc[c] += p[c];
                    }
                }
                out.set(x, y, [acc[0] / 4.0, acc[1] / 4.0, acc[2] / 4.0]);
            }
        }
        out
    }

    /// Upsample by 2x with bilinear interpolation (the DS-2 baseline's
    /// second half).
    pub fn upsample2(&self) -> Image {
        let (w, h) = (self.width * 2, self.height * 2);
        let mut out = Image::new(w, h);
        for y in 0..h {
            for x in 0..w {
                // Map output pixel center to input coordinates.
                let sx = (x as f32 + 0.5) / 2.0 - 0.5;
                let sy = (y as f32 + 0.5) / 2.0 - 0.5;
                let x0 = sx.floor().clamp(0.0, (self.width - 1) as f32) as usize;
                let y0 = sy.floor().clamp(0.0, (self.height - 1) as f32) as usize;
                let x1 = (x0 + 1).min(self.width - 1);
                let y1 = (y0 + 1).min(self.height - 1);
                let fx = (sx - x0 as f32).clamp(0.0, 1.0);
                let fy = (sy - y0 as f32).clamp(0.0, 1.0);
                let mut v = [0.0f32; 3];
                for c in 0..3 {
                    let top = self.at(x0, y0)[c] * (1.0 - fx) + self.at(x1, y0)[c] * fx;
                    let bot = self.at(x0, y1)[c] * (1.0 - fx) + self.at(x1, y1)[c] * fx;
                    v[c] = top * (1.0 - fy) + bot * fy;
                }
                out.set(x, y, v);
            }
        }
        out
    }

    /// Write a binary PPM (P6) with 8-bit channels for eyeballing results.
    pub fn write_ppm(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        use std::io::Write;
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "P6\n{} {}\n255", self.width, self.height)?;
        for px in &self.data {
            let bytes = [
                (px[0].clamp(0.0, 1.0) * 255.0).round() as u8,
                (px[1].clamp(0.0, 1.0) * 255.0).round() as u8,
                (px[2].clamp(0.0, 1.0) * 255.0).round() as u8,
            ];
            w.write_all(&bytes)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get() {
        let mut img = Image::new(4, 3);
        img.set(2, 1, [0.5, 0.25, 1.0]);
        assert_eq!(img.at(2, 1), [0.5, 0.25, 1.0]);
        assert_eq!(img.at(0, 0), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn down_up_roundtrip_constant() {
        let mut img = Image::new(8, 8);
        for p in img.data.iter_mut() {
            *p = [0.3, 0.6, 0.9];
        }
        let round = img.downsample2().upsample2();
        for p in &round.data {
            for c in 0..3 {
                assert!((p[c] - [0.3, 0.6, 0.9][c]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn downsample_averages() {
        let mut img = Image::new(2, 2);
        img.set(0, 0, [1.0, 0.0, 0.0]);
        img.set(1, 0, [0.0, 1.0, 0.0]);
        img.set(0, 1, [0.0, 0.0, 1.0]);
        img.set(1, 1, [1.0, 1.0, 1.0]);
        let d = img.downsample2();
        assert_eq!(d.at(0, 0), [0.5, 0.5, 0.5]);
    }

    #[test]
    fn mean_abs_diff_zero_for_same() {
        let img = Image::new(4, 4);
        assert_eq!(img.mean_abs_diff(&img), 0.0);
    }
}
