//! The 3DGS rendering pipeline substrate: Projection -> Sorting ->
//! Rasterization (paper Fig. 1), plus the framebuffer type and the
//! frame-loop stage graph.
//!
//! Every stage exposes the statistics hooks the paper's characterization
//! figures need (per-pixel iterated/significant Gaussian counts, tile
//! occupancy, order-change rates).
//!
//! The [`stage`] module is the seam the coordinator composes against:
//! a [`stage::FrontendStage`] (projection + sorting, S²-aware) and a
//! [`stage::RasterBackend`] (plain / radiance-cached / DS-2) produce a
//! measured [`stage::FrameWorkload`], which the pluggable cost models in
//! [`crate::sim::cost`] price per hardware target.

pub mod image;
pub mod project;
pub mod raster;
pub mod sort;
pub mod stage;

pub use image::Image;
pub use project::{project, ProjectedScene};
pub use raster::{rasterize, RasterConfig, RasterOutput, RasterStats};
pub use sort::{bin_and_sort, TileBins};
pub use stage::{FrameWorkload, FrontendStage, PlainRaster, RasterBackend, RasterChunk, RasterFrame};
