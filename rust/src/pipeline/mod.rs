//! The 3DGS rendering pipeline substrate: Projection -> Sorting ->
//! Rasterization (paper Fig. 1), plus the framebuffer type.
//!
//! Every stage exposes the statistics hooks the paper's characterization
//! figures need (per-pixel iterated/significant Gaussian counts, tile
//! occupancy, order-change rates).

pub mod image;
pub mod project;
pub mod raster;
pub mod sort;

pub use image::Image;
pub use project::{project, ProjectedScene};
pub use raster::{rasterize, RasterConfig, RasterOutput, RasterStats};
pub use sort::{bin_and_sort, TileBins};
