//! Projection stage: frustum culling + EWA splatting of 3D Gaussians to
//! screen space (paper Fig. 1, step 1).
//!
//! For each visible Gaussian: camera transform, perspective projection of
//! the mean, first-order (Jacobian) projection of the 3D covariance to a
//! 2x2 screen covariance, +0.3 px low-pass dilation, conic inversion, and
//! the 3-sigma cutoff radius used for tile intersection.

use crate::camera::{Intrinsics, Pose};
use crate::constants::ALPHA_MIN;
use crate::math::Sym2;
use crate::scene::sh::eval_color;
use crate::scene::GaussianScene;
use crate::util::par;

/// Screen-space (projected) Gaussians, compacted to the visible set.
///
/// `ids[i]` is the index into the source [`GaussianScene`] — the *global
/// Gaussian ID* the radiance cache tags are built from.
#[derive(Debug, Clone, Default)]
pub struct ProjectedScene {
    pub ids: Vec<u32>,
    /// 2D means in pixel coordinates.
    pub means: Vec<[f32; 2]>,
    /// Inverse 2D covariance (conic), packed (a, b, c).
    pub conics: Vec<Sym2>,
    /// Camera-space depth (distance along the optical axis).
    pub depths: Vec<f32>,
    /// 3-sigma screen radius in pixels.
    pub radii: Vec<f32>,
    /// Opacity copied from the scene.
    pub opacity: Vec<f32>,
    /// View-dependent RGB (SH evaluated at this pose).
    pub colors: Vec<[f32; 3]>,
    /// Squared significance radius (see [`significance_radius_sq`]),
    /// hoisted here so tile binning and every rasterizer read one
    /// per-splat value instead of recomputing it per (splat, tile).
    pub r2_sig: Vec<f32>,
    /// Camera position the projection (or latest reprojection) was
    /// evaluated at. The world-space radiance cache derives its
    /// view-direction buckets and distance-scaled cell sizes from this,
    /// so it must track the *render* pose, not the speculative sort pose.
    pub cam_pos: [f32; 3],
}

impl ProjectedScene {
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

/// Squared significance radius of a projected Gaussian: alpha >= 1/255
/// requires |d|^2 <= r2_sig, conservatively, from the conic's smallest
/// eigenvalue (q(d) = a dx^2 + 2b dx dy + c dy^2 >= lambda_min |d|^2,
/// and alpha >= ALPHA_MIN iff q <= 2 ln(opacity/ALPHA_MIN)). Negative
/// (-1.0) when the splat can never be significant at any pixel — its
/// opacity is already below 1/255. Pose-dependent through the conic, so
/// [`reproject_geometry`] recomputes it alongside means/conics/depths.
#[inline]
pub fn significance_radius_sq(conic: &Sym2, opacity: f32) -> f32 {
    let qmax = 2.0 * (opacity / ALPHA_MIN).ln();
    let mid = 0.5 * (conic.a + conic.c);
    let det = conic.a * conic.c - conic.b * conic.b;
    let lambda_min = (mid - (mid * mid - det).max(0.0).sqrt()).max(1e-12);
    if qmax <= 0.0 {
        -1.0
    } else {
        qmax / lambda_min
    }
}

/// Result of projecting a single Gaussian (pre-compaction).
struct Splat {
    id: u32,
    mean: [f32; 2],
    conic: Sym2,
    depth: f32,
    radius: f32,
    opacity: f32,
    color: [f32; 3],
    r2_sig: f32,
}

/// Project `scene` under `pose`/`intr`. Gaussians outside the near/far
/// range, with degenerate covariance, or whose 3-sigma footprint misses an
/// (optionally margin-expanded) viewport are culled.
///
/// `margin_px` expands the cull viewport on every side — the S^2 expanded
/// viewport (paper Sec. 3.1) projects at a *predicted* pose with a margin
/// so nearby rendered poses still find their Gaussians.
pub fn project(
    scene: &GaussianScene,
    pose: &Pose,
    intr: &Intrinsics,
    near: f32,
    far: f32,
    margin_px: f32,
) -> ProjectedScene {
    let w2c = pose.world_to_cam();
    let cam_center = pose.position;
    let (fx, fy, cx, cy) = (intr.fx, intr.fy, intr.cx, intr.cy);
    let (width, height) = (intr.width as f32, intr.height as f32);

    let splats: Vec<Option<Splat>> = par::par_map(scene.len(), |i| {
            let cam = w2c.mul_vec(scene.pos[i] - cam_center);
            let z = cam.z;
            if z < near || z > far {
                return None;
            }
            let inv_z = 1.0 / z;
            let mx = fx * cam.x * inv_z + cx;
            let my = fy * cam.y * inv_z + cy;

            // 3D covariance in camera frame: W Sigma W^T where
            // Sigma = R S S^T R^T.
            let r = scene.quat[i].to_mat3();
            let m = r.scale_cols(scene.scale[i]); // R * diag(s)
            let sigma = m.mul(&m.transpose());
            let cov_cam = w2c.mul(&sigma).mul(&w2c.transpose());

            // Jacobian of perspective projection at the center.
            let j00 = fx * inv_z;
            let j02 = -fx * cam.x * inv_z * inv_z;
            let j11 = fy * inv_z;
            let j12 = -fy * cam.y * inv_z * inv_z;

            // cov2d = J cov_cam J^T for the 2x3 Jacobian above.
            let c = &cov_cam.m;
            let a = j00 * (j00 * c[0][0] + j02 * c[2][0])
                + j02 * (j00 * c[0][2] + j02 * c[2][2]);
            let b = j00 * (j11 * c[0][1] + j12 * c[0][2])
                + j02 * (j11 * c[2][1] + j12 * c[2][2]);
            let d = j11 * (j11 * c[1][1] + j12 * c[2][1])
                + j12 * (j11 * c[1][2] + j12 * c[2][2]);

            // Low-pass dilation (official +0.3 px) guarantees a minimum
            // footprint; also guarantees invertibility.
            let cov2d = Sym2 { a: a + 0.3, b, c: d + 0.3 };
            let conic = cov2d.inverse()?;
            let radius = 3.0 * cov2d.max_eigenvalue().sqrt();

            // Viewport cull with margin.
            if mx + radius < -margin_px
                || mx - radius > width + margin_px
                || my + radius < -margin_px
                || my - radius > height + margin_px
            {
                return None;
            }

            let opacity = scene.opacity[i];
            Some(Splat {
                id: i as u32,
                mean: [mx, my],
                conic,
                depth: z,
                radius,
                opacity,
                color: eval_color(scene.pos[i], cam_center, &scene.sh[i]),
                r2_sig: significance_radius_sq(&conic, opacity),
            })
        });

    let mut out = ProjectedScene::default();
    out.cam_pos = [cam_center.x, cam_center.y, cam_center.z];
    let visible = splats.iter().flatten().count();
    out.ids.reserve(visible);
    out.means.reserve(visible);
    out.conics.reserve(visible);
    out.depths.reserve(visible);
    out.radii.reserve(visible);
    out.opacity.reserve(visible);
    out.colors.reserve(visible);
    out.r2_sig.reserve(visible);
    for s in splats.into_iter().flatten() {
        out.ids.push(s.id);
        out.means.push(s.mean);
        out.conics.push(s.conic);
        out.depths.push(s.depth);
        out.radii.push(s.radius);
        out.opacity.push(s.opacity);
        out.colors.push(s.color);
        out.r2_sig.push(s.r2_sig);
    }
    out
}

/// Refresh only the view-dependent colors of an already-projected scene
/// at a new pose — what S^2 sorting-shared rendering does per frame
/// (paper Sec. 3.1: "each Gaussian color needs to be recalculated using
/// pretrained Spherical Harmonic coefficients").
pub fn refresh_colors(
    projected: &mut ProjectedScene,
    scene: &GaussianScene,
    pose: &Pose,
) {
    let cam_center = pose.position;
    let ids = &projected.ids;
    let colors = &mut projected.colors;
    // Chunked parallel update; chunk index recovers the id offset.
    const CHUNK: usize = 4096;
    par::par_chunks_mut(colors, CHUNK, |ci, chunk| {
        let base = ci * CHUNK;
        for (j, color) in chunk.iter_mut().enumerate() {
            let id = ids[base + j] as usize;
            *color = eval_color(scene.pos[id], cam_center, &scene.sh[id]);
        }
    });
}

/// Re-project the geometry (means/conics/depths) of the retained Gaussian
/// set at a new pose, keeping the set membership fixed. Used by
/// sorting-shared rendering: tile lists and depth *order* come from the
/// speculative sort; per-Gaussian geometry is evaluated fresh (a cheap,
/// embarrassingly parallel pass with no binning or sorting).
pub fn reproject_geometry(
    projected: &mut ProjectedScene,
    scene: &GaussianScene,
    pose: &Pose,
    intr: &Intrinsics,
) {
    let w2c = pose.world_to_cam();
    let cam_center = pose.position;
    let (fx, fy, cx, cy) = (intr.fx, intr.fy, intr.cx, intr.cy);
    projected.cam_pos = [cam_center.x, cam_center.y, cam_center.z];
    let n = projected.len();
    let ids = std::mem::take(&mut projected.ids);
    let means = &mut projected.means;
    let conics = &mut projected.conics;
    let depths = &mut projected.depths;
    let r2_sigs = &mut projected.r2_sig;
    let opacity = &projected.opacity;
    // Parallel over disjoint index blocks; each block owns its slice of
    // the arrays via raw split — simpler: compute into fresh vecs.
    let results: Vec<([f32; 2], crate::math::Sym2, f32, f32)> = par::par_map(n, |k| {
            let i = ids[k] as usize;
            let cam = w2c.mul_vec(scene.pos[i] - cam_center);
            let z = cam.z.max(1e-6);
            let inv_z = 1.0 / z;
            let mean = [fx * cam.x * inv_z + cx, fy * cam.y * inv_z + cy];
            let depth = cam.z;

            let r = scene.quat[i].to_mat3();
            let m = r.scale_cols(scene.scale[i]);
            let sigma = m.mul(&m.transpose());
            let cov_cam = w2c.mul(&sigma).mul(&w2c.transpose());
            let j00 = fx * inv_z;
            let j02 = -fx * cam.x * inv_z * inv_z;
            let j11 = fy * inv_z;
            let j12 = -fy * cam.y * inv_z * inv_z;
            let c = &cov_cam.m;
            let a = j00 * (j00 * c[0][0] + j02 * c[2][0])
                + j02 * (j00 * c[0][2] + j02 * c[2][2]);
            let b = j00 * (j11 * c[0][1] + j12 * c[0][2])
                + j02 * (j11 * c[2][1] + j12 * c[2][2]);
            let d = j11 * (j11 * c[1][1] + j12 * c[2][1])
                + j12 * (j11 * c[1][2] + j12 * c[2][2]);
            let cov2d = Sym2 { a: a + 0.3, b, c: d + 0.3 };
            let conic = cov2d.inverse().unwrap_or(Sym2 { a: 1.0, b: 0.0, c: 1.0 });
            // The significance radius follows the conic to the new pose
            // (opacity — hence qmax — is pose-invariant).
            (mean, conic, depth, significance_radius_sq(&conic, opacity[k]))
        });
    for (k, (m, cn, d, r2)) in results.into_iter().enumerate() {
        means[k] = m;
        conics[k] = cn;
        depths[k] = d;
        r2_sigs[k] = r2;
    }
    projected.ids = ids;
    debug_assert_eq!(projected.len(), n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::constants::SH_COEFFS;
    use crate::math::Quat;
    use crate::scene::synth::test_scene;

    fn simple_scene_at(positions: &[Vec3]) -> GaussianScene {
        let mut s = GaussianScene::default();
        for &p in positions {
            s.push(
                p,
                Vec3::new(0.05, 0.05, 0.05),
                Quat::IDENTITY,
                0.8,
                [[0.1; 3]; SH_COEFFS],
            );
        }
        s
    }

    fn cam() -> (Pose, Intrinsics) {
        (
            Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO),
            Intrinsics::with_fov(128, 128, 0.8),
        )
    }

    #[test]
    fn center_projects_to_principal_point() {
        let scene = simple_scene_at(&[Vec3::ZERO]);
        let (pose, intr) = cam();
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        assert_eq!(p.len(), 1);
        assert!((p.means[0][0] - intr.cx).abs() < 1e-3);
        assert!((p.means[0][1] - intr.cy).abs() < 1e-3);
        assert!((p.depths[0] - 4.0).abs() < 1e-4);
    }

    #[test]
    fn culls_behind_camera() {
        let scene = simple_scene_at(&[Vec3::new(0.0, 0.0, -10.0)]);
        let (pose, intr) = cam();
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        assert!(p.is_empty());
    }

    #[test]
    fn culls_outside_viewport_unless_margin() {
        // A point far off to the side.
        let scene = simple_scene_at(&[Vec3::new(10.0, 0.0, 0.0)]);
        let (pose, intr) = cam();
        let strict = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        assert!(strict.is_empty());
        // An enormous margin readmits it.
        let loose = project(&scene, &pose, &intr, 0.2, 100.0, 10_000.0);
        assert_eq!(loose.len(), 1);
    }

    #[test]
    fn conic_positive_definite() {
        let scene = test_scene(3, 500);
        let (pose, intr) = cam();
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        assert!(!p.is_empty());
        for conic in &p.conics {
            assert!(conic.a > 0.0 && conic.c > 0.0 && conic.det() > 0.0);
        }
    }

    #[test]
    fn closer_gaussian_has_larger_radius() {
        let scene = simple_scene_at(&[Vec3::new(0.0, 0.0, -1.0), Vec3::new(0.0, 0.0, 2.0)]);
        let (pose, intr) = cam();
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        assert_eq!(p.len(), 2);
        // ids preserve scene order; id 0 is nearer to the camera.
        let r_near = p.radii[p.ids.iter().position(|&i| i == 0).unwrap()];
        let r_far = p.radii[p.ids.iter().position(|&i| i == 1).unwrap()];
        assert!(r_near > r_far);
    }

    #[test]
    fn reproject_matches_full_projection() {
        let scene = test_scene(5, 300);
        let (pose, intr) = cam();
        let mut p = project(&scene, &pose, &intr, 0.2, 100.0, 64.0);
        // Move the camera slightly and reproject the same set.
        let pose2 = Pose::look_at(Vec3::new(0.05, 0.01, -4.0), Vec3::ZERO);
        reproject_geometry(&mut p, &scene, &pose2, &intr);
        let full = project(&scene, &pose2, &intr, 0.2, 100.0, 64.0);
        // Every Gaussian retained by both must agree exactly.
        for (i, id) in p.ids.iter().enumerate() {
            if let Some(j) = full.ids.iter().position(|x| x == id) {
                assert!((p.means[i][0] - full.means[j][0]).abs() < 1e-3);
                assert!((p.means[i][1] - full.means[j][1]).abs() < 1e-3);
                assert!((p.depths[i] - full.depths[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn significance_radius_tracks_opacity_and_pose() {
        let scene = test_scene(7, 800);
        let (pose, intr) = cam();
        let mut p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        assert_eq!(p.r2_sig.len(), p.len());
        use crate::constants::ALPHA_MIN;
        for i in 0..p.len() {
            // Negative exactly when the splat can never pass the 1/255
            // alpha test; otherwise it matches the hoisted formula.
            if p.opacity[i] <= ALPHA_MIN {
                assert_eq!(p.r2_sig[i], -1.0);
            } else {
                assert_eq!(p.r2_sig[i], significance_radius_sq(&p.conics[i], p.opacity[i]));
                assert!(p.r2_sig[i] > 0.0);
            }
        }
        // Reprojection refreshes the radius with the new conics.
        let pose2 = Pose::look_at(Vec3::new(0.3, 0.1, -3.0), Vec3::ZERO);
        reproject_geometry(&mut p, &scene, &pose2, &intr);
        for i in 0..p.len() {
            assert_eq!(p.r2_sig[i], significance_radius_sq(&p.conics[i], p.opacity[i]));
        }
    }

    #[test]
    fn refresh_colors_changes_view_dependent() {
        let mut scene = test_scene(6, 100);
        // Give everything strong view dependence.
        for sh in scene.sh.iter_mut() {
            sh[1] = [2.0, 0.0, 0.0];
        }
        let (pose, intr) = cam();
        let mut p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let before = p.colors.clone();
        let pose2 = Pose::look_at(Vec3::new(0.0, 3.0, -3.0), Vec3::ZERO);
        refresh_colors(&mut p, &scene, &pose2);
        let changed = p
            .colors
            .iter()
            .zip(&before)
            .filter(|(a, b)| a != b)
            .count();
        assert!(changed > p.len() / 2);
    }
}
