//! Rasterization stage: per-tile front-to-back alpha compositing
//! (paper Fig. 1 step 3, Eqn. 1), with the statistics hooks behind the
//! paper's characterization figures.
//!
//! Semantics match the official 3DGS CUDA rasterizer and the L1 Pallas
//! kernel exactly (see `python/compile/kernels/ref.py`): positive exponent
//! -> skip; alpha = min(0.99, opacity * exp(power)); alpha < 1/255 -> skip
//! (insignificant); test_T = T*(1-alpha) < 1e-4 -> terminate *without*
//! accumulating; otherwise C += alpha*T*color, T = test_T.

use super::image::Image;
use super::project::ProjectedScene;
use super::sort::TileBins;
use crate::constants::{ALPHA_MAX, ALPHA_MIN, T_EPS};
use crate::util::par;

/// Maximum alpha-record length supported by [`SigRecord`] (fig24 sweeps
/// k in 1..=10).
pub const MAX_SIG_K: usize = 10;

/// The first up-to-k significant Gaussian IDs a pixel encountered, in
/// depth order — the radiance-cache tag material (paper Sec. 3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SigRecord {
    pub ids: [u32; MAX_SIG_K],
    pub len: u8,
}

impl Default for SigRecord {
    fn default() -> Self {
        SigRecord { ids: [u32::MAX; MAX_SIG_K], len: 0 }
    }
}

impl SigRecord {
    #[inline]
    pub fn push(&mut self, id: u32) -> bool {
        if (self.len as usize) < MAX_SIG_K {
            self.ids[self.len as usize] = id;
            self.len += 1;
            true
        } else {
            false
        }
    }

    /// The first `k` IDs, or None if fewer than `k` were recorded.
    pub fn first_k(&self, k: usize) -> Option<&[u32]> {
        if (self.len as usize) >= k {
            Some(&self.ids[..k])
        } else {
            None
        }
    }
}

/// Rasterization options.
#[derive(Debug, Clone, Copy)]
pub struct RasterConfig {
    /// Collect per-pixel iterated/significant counts (Figs. 3-5, 11).
    pub collect_stats: bool,
    /// Record the first-k significant Gaussian IDs per pixel (k = the
    /// alpha-record length; 0 disables recording).
    pub sig_record_k: usize,
}

impl Default for RasterConfig {
    fn default() -> Self {
        RasterConfig { collect_stats: false, sig_record_k: 0 }
    }
}

/// Per-pixel rasterization statistics.
#[derive(Debug, Clone, Default)]
pub struct RasterStats {
    /// Gaussians iterated (encountered in the tile list before
    /// termination) per pixel.
    pub iterated: Vec<u32>,
    /// Significant Gaussians (alpha >= 1/255, actually composited or
    /// terminal) per pixel.
    pub significant: Vec<u32>,
}

impl RasterStats {
    /// Mean Gaussians iterated per pixel.
    pub fn mean_iterated(&self) -> f64 {
        mean_u32(&self.iterated)
    }

    /// Mean significant Gaussians per pixel.
    pub fn mean_significant(&self) -> f64 {
        mean_u32(&self.significant)
    }

    /// Percentage of iterated Gaussians that were significant (Fig. 4).
    pub fn significant_fraction(&self) -> f64 {
        let it: u64 = self.iterated.iter().map(|&v| v as u64).sum();
        let sig: u64 = self.significant.iter().map(|&v| v as u64).sum();
        if it == 0 {
            0.0
        } else {
            sig as f64 / it as f64
        }
    }
}

fn mean_u32(v: &[u32]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().map(|&x| x as u64).sum::<u64>() as f64 / v.len() as f64
    }
}

/// Full rasterization output.
#[derive(Debug, Clone)]
pub struct RasterOutput {
    pub image: Image,
    pub stats: Option<RasterStats>,
    /// Per-pixel significant-ID records (row-major), present when
    /// `sig_record_k > 0`.
    pub sig_records: Option<Vec<SigRecord>>,
}

/// A tile-local copy of one projected Gaussian's raster state, gathered
/// contiguously so the per-pixel loop streams sequentially instead of
/// chasing `list` indices into the projected SoA (the #1 hot-path win of
/// the perf pass; see DESIGN.md §"Raster hot path").
#[derive(Debug, Clone, Copy)]
pub struct GatheredSplat {
    pub mean: [f32; 2],
    pub conic_a: f32,
    pub conic_b: f32,
    pub conic_c: f32,
    pub opacity: f32,
    pub color: [f32; 3],
    pub id: u32,
    /// Squared significance radius: alpha >= 1/255 requires
    /// |d|^2 <= r2_sig (conservative, from the conic's smallest
    /// eigenvalue). Negative when the splat can never be significant.
    /// Lets the hot loop reject most pixels without the exp().
    /// Computed once per splat at projection time
    /// ([`ProjectedScene::r2_sig`]) — the same value exact-intersection
    /// binning culls whole (splat, tile) pairs with.
    pub r2_sig: f32,
}

/// Gather a tile's Gaussian list into contiguous splat records.
pub fn gather_tile(projected: &ProjectedScene, list: &[u32]) -> Vec<GatheredSplat> {
    list.iter()
        .map(|&idx| {
            let i = idx as usize;
            let conic = projected.conics[i];
            GatheredSplat {
                mean: projected.means[i],
                conic_a: conic.a,
                conic_b: conic.b,
                conic_c: conic.c,
                opacity: projected.opacity[i],
                color: projected.colors[i],
                id: projected.ids[i],
                r2_sig: projected.r2_sig[i],
            }
        })
        .collect()
}

/// Evaluate one gathered splat at a pixel: the shared fast-reject +
/// alpha math of **every** compositing loop (plain, cached, and the
/// single-pass uncached continuation in `lumina::rc`). Returns `None`
/// when the splat is insignificant (alpha < 1/255) at this pixel.
///
/// The cheap conservative reject comes first: outside the significance
/// radius the Gaussian cannot pass the 1/255 test (no exp needed).
#[inline(always)]
pub fn splat_alpha(s: &GatheredSplat, px: f32, py: f32) -> Option<f32> {
    let dx = px - s.mean[0];
    let dy = py - s.mean[1];
    if dx * dx + dy * dy > s.r2_sig {
        return None;
    }
    let power = -0.5 * (s.conic_a * dx * dx + s.conic_c * dy * dy) - s.conic_b * dx * dy;
    if power > 0.0 {
        return None;
    }
    let alpha = (s.opacity * power.exp()).min(ALPHA_MAX);
    if alpha < ALPHA_MIN {
        return None;
    }
    Some(alpha)
}

/// Composite one pixel against gathered (contiguous) splats.
#[inline]
pub fn composite_pixel_gathered(
    splats: &[GatheredSplat],
    px: f32,
    py: f32,
    record_k: usize,
) -> ([f32; 3], f32, u32, u32, SigRecord) {
    let mut c = [0.0f32; 3];
    let mut t = 1.0f32;
    let mut iterated = 0u32;
    let mut significant = 0u32;
    let mut rec = SigRecord::default();
    for s in splats {
        iterated += 1;
        let Some(alpha) = splat_alpha(s, px, py) else {
            continue;
        };
        significant += 1;
        if (rec.len as usize) < record_k {
            rec.push(s.id);
        }
        let test_t = t * (1.0 - alpha);
        if test_t < T_EPS {
            break;
        }
        let w = alpha * t;
        c[0] += w * s.color[0];
        c[1] += w * s.color[1];
        c[2] += w * s.color[2];
        t = test_t;
    }
    (c, t, iterated, significant, rec)
}

/// Composite one pixel against a depth-sorted tile list.
///
/// Returns (rgb, transmittance, iterated, significant, record). A thin
/// gather-then-composite wrapper over [`composite_pixel_gathered`] so
/// the skip/terminate alpha semantics live in exactly one place.
#[inline]
pub fn composite_pixel(
    projected: &ProjectedScene,
    list: &[u32],
    px: f32,
    py: f32,
    record_k: usize,
) -> ([f32; 3], f32, u32, u32, SigRecord) {
    composite_pixel_gathered(&gather_tile(projected, list), px, py, record_k)
}

/// One tile's rendered block (tile-local, row-major ts x ts).
struct TileOut {
    color: Vec<[f32; 3]>,
    iterated: Vec<u32>,
    significant: Vec<u32>,
    recs: Vec<SigRecord>,
}

/// Incremental rasterizer behind the `RasterChunk` sub-stage seam:
/// tiles are rendered range by range (each range parallel over its
/// tiles), accumulated per tile, and assembled once at [`finish`].
/// Every tile's block is a pure function of `(projected, bins, cfg)`
/// and assembly is sequential in tile order, so the output is bitwise
/// identical no matter how the tile range is chunked across sub-stages
/// or threads.
///
/// [`finish`]: PartialRaster::finish
pub struct PartialRaster {
    width: usize,
    height: usize,
    tiles_x: usize,
    tile_size: usize,
    cfg: RasterConfig,
    tiles: Vec<Option<TileOut>>,
}

impl PartialRaster {
    pub fn new(bins: &TileBins, width: usize, height: usize, cfg: &RasterConfig) -> Self {
        let mut tiles = Vec::with_capacity(bins.tile_count());
        tiles.resize_with(bins.tile_count(), || None);
        PartialRaster {
            width,
            height,
            tiles_x: bins.tiles_x,
            tile_size: bins.tile_size,
            cfg: *cfg,
            tiles,
        }
    }

    /// Render one contiguous tile range (parallel over its tiles, with
    /// per-tile contiguous gathering — see [`GatheredSplat`]).
    pub fn render_tiles(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        range: std::ops::Range<usize>,
    ) {
        let ts = self.tile_size;
        let (width, height) = (self.width, self.height);
        let record_k = self.cfg.sig_record_k;
        let want_stats = self.cfg.collect_stats;
        let base = range.start;
        let outs: Vec<TileOut> = par::par_map(range.len(), |j| {
            let tile = base + j;
            let splats = gather_tile(projected, bins.list(tile));
            let (ox, oy) = bins.tile_origin(tile);
            let mut out = TileOut {
                color: vec![[0.0; 3]; ts * ts],
                iterated: if want_stats { vec![0; ts * ts] } else { Vec::new() },
                significant: if want_stats { vec![0; ts * ts] } else { Vec::new() },
                recs: if record_k > 0 {
                    vec![SigRecord::default(); ts * ts]
                } else {
                    Vec::new()
                },
            };
            for ly in 0..ts {
                let py = oy + ly as f32 + 0.5;
                if oy as usize + ly >= height {
                    break;
                }
                for lx in 0..ts {
                    if ox as usize + lx >= width {
                        break;
                    }
                    let px = ox + lx as f32 + 0.5;
                    let (c, _t, it, sg, rec) =
                        composite_pixel_gathered(&splats, px, py, record_k);
                    let off = ly * ts + lx;
                    out.color[off] = c;
                    if want_stats {
                        out.iterated[off] = it;
                        out.significant[off] = sg;
                    }
                    if record_k > 0 {
                        out.recs[off] = rec;
                    }
                }
            }
            out
        });
        for (j, out) in outs.into_iter().enumerate() {
            self.tiles[base + j] = Some(out);
        }
    }

    /// Assemble the framebuffer (sequential; ~1% of the render cost).
    /// Tiles never rendered stay black/zero.
    pub fn finish(self) -> RasterOutput {
        let (width, height, ts) = (self.width, self.height, self.tile_size);
        let n_px = width * height;
        let mut image = Image::new(width, height);
        let mut stats = self.cfg.collect_stats.then(|| RasterStats {
            iterated: vec![0; n_px],
            significant: vec![0; n_px],
        });
        let mut sig_records =
            (self.cfg.sig_record_k > 0).then(|| vec![SigRecord::default(); n_px]);
        for (tile, tout) in self.tiles.iter().enumerate() {
            let Some(tout) = tout else {
                continue;
            };
            let tx = tile % self.tiles_x;
            let ty = tile / self.tiles_x;
            for ly in 0..ts {
                let y = ty * ts + ly;
                if y >= height {
                    break;
                }
                let row = y * width;
                for lx in 0..ts {
                    let x = tx * ts + lx;
                    if x >= width {
                        break;
                    }
                    let off = ly * ts + lx;
                    image.data[row + x] = tout.color[off];
                    if let Some(st) = stats.as_mut() {
                        st.iterated[row + x] = tout.iterated[off];
                        st.significant[row + x] = tout.significant[off];
                    }
                    if let Some(recs) = sig_records.as_mut() {
                        recs[row + x] = tout.recs[off];
                    }
                }
            }
        }
        RasterOutput { image, stats, sig_records }
    }
}

/// Rasterize all tiles of `bins` into an image: the whole-frame
/// convenience wrapper over [`PartialRaster`].
pub fn rasterize(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    cfg: &RasterConfig,
) -> RasterOutput {
    let mut acc = PartialRaster::new(bins, width, height, cfg);
    acc.render_tiles(projected, bins, 0..bins.tile_count());
    acc.finish()
}

/// Per-pixel contribution profile for the paper's Fig. 11: the sorted
/// (descending) normalized contribution weights of every composited
/// Gaussian for a sample of pixels. Returns a vector per sampled pixel of
/// `alpha_i * Gamma_i` weights normalized to sum 1.
pub fn contribution_profile(
    projected: &ProjectedScene,
    bins: &TileBins,
    width: usize,
    height: usize,
    stride: usize,
) -> Vec<Vec<f32>> {
    let ts = bins.tile_size;
    let mut profiles = Vec::new();
    let mut gathered_tile = usize::MAX;
    let mut splats: Vec<GatheredSplat> = Vec::new();
    for y in (0..height).step_by(stride) {
        for x in (0..width).step_by(stride) {
            let tile = (y / ts) * bins.tiles_x + x / ts;
            if tile != gathered_tile {
                splats = gather_tile(projected, bins.list(tile));
                gathered_tile = tile;
            }
            let (px, py) = (x as f32 + 0.5, y as f32 + 0.5);
            let mut weights = Vec::new();
            let mut t = 1.0f32;
            for s in &splats {
                let Some(alpha) = splat_alpha(s, px, py) else {
                    continue;
                };
                let test_t = t * (1.0 - alpha);
                if test_t < T_EPS {
                    break;
                }
                weights.push(alpha * t);
                t = test_t;
            }
            let sum: f32 = weights.iter().sum();
            if sum > 0.0 {
                for w in weights.iter_mut() {
                    *w /= sum;
                }
                weights.sort_by(|a, b| b.partial_cmp(a).unwrap());
                profiles.push(weights);
            }
        }
    }
    profiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Intrinsics, Pose};
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::pipeline::sort::bin_and_sort;
    use crate::scene::synth::test_scene;

    fn render_setup(n: usize) -> (ProjectedScene, TileBins, Intrinsics) {
        let scene = test_scene(21, n);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        (p, bins, intr)
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn renders_nonempty_image() {
        let (p, bins, intr) = render_setup(3000);
        let out = rasterize(&p, &bins, intr.width, intr.height, &RasterConfig::default());
        let lit = out.image.data.iter().filter(|p| p[0] + p[1] + p[2] > 0.01).count();
        assert!(lit > 1000, "only {lit} lit pixels");
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn stats_collected_and_sane() {
        let (p, bins, intr) = render_setup(3000);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let out = rasterize(&p, &bins, intr.width, intr.height, &cfg);
        let stats = out.stats.unwrap();
        assert_eq!(stats.iterated.len(), 128 * 128);
        assert!(stats.mean_iterated() > 1.0);
        // Significance sparsity: far fewer significant than iterated.
        // (Exact-intersection binning already removed the entries that
        // could never be significant anywhere in their tile, so this
        // fraction sits higher than the paper's raw Fig. 4 ratio.)
        let frac = stats.significant_fraction();
        assert!(frac > 0.0 && frac < 0.75, "significant fraction {frac}");
        // significant <= iterated pointwise.
        for (s, i) in stats.significant.iter().zip(&stats.iterated) {
            assert!(s <= i);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn sig_records_match_stats() {
        let (p, bins, intr) = render_setup(2000);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 5 };
        let out = rasterize(&p, &bins, intr.width, intr.height, &cfg);
        let stats = out.stats.unwrap();
        let recs = out.sig_records.unwrap();
        for (rec, &sig) in recs.iter().zip(&stats.significant) {
            assert_eq!(rec.len as u32, sig.min(5), "record len vs significant count");
            // Recorded IDs are real scene IDs.
            for &id in &rec.ids[..rec.len as usize] {
                assert!(p.ids.contains(&id));
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn pixel_compositor_matches_rasterize() {
        let (p, bins, intr) = render_setup(1500);
        let out = rasterize(&p, &bins, intr.width, intr.height, &RasterConfig::default());
        for (x, y) in [(3usize, 5usize), (64, 64), (127, 100)] {
            let tile = (y / 16) * bins.tiles_x + x / 16;
            let (c, _, _, _, _) = composite_pixel(
                &p,
                bins.list(tile),
                x as f32 + 0.5,
                y as f32 + 0.5,
                0,
            );
            assert_eq!(out.image.at(x, y), c);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn gathered_reject_matches_ungathered_reference() {
        // The r2_sig fast reject must be semantically neutral: the
        // gathered compositor agrees bitwise with a raw reference loop
        // that evaluates every splat's full alpha math.
        let (p, bins, _intr) = render_setup(2000);
        for (x, y) in [(0usize, 0usize), (17, 42), (64, 64), (90, 127), (127, 127)] {
            let tile = (y / 16) * bins.tiles_x + x / 16;
            let (px, py) = (x as f32 + 0.5, y as f32 + 0.5);
            let mut c = [0.0f32; 3];
            let mut t = 1.0f32;
            let mut significant = 0u32;
            for &idx in bins.list(tile) {
                let i = idx as usize;
                let [mx, my] = p.means[i];
                let dx = px - mx;
                let dy = py - my;
                let conic = p.conics[i];
                let power =
                    -0.5 * (conic.a * dx * dx + conic.c * dy * dy) - conic.b * dx * dy;
                if power > 0.0 {
                    continue;
                }
                let alpha = (p.opacity[i] * power.exp()).min(ALPHA_MAX);
                if alpha < ALPHA_MIN {
                    continue;
                }
                significant += 1;
                let test_t = t * (1.0 - alpha);
                if test_t < T_EPS {
                    break;
                }
                let w = alpha * t;
                let color = p.colors[i];
                c[0] += w * color[0];
                c[1] += w * color[1];
                c[2] += w * color[2];
                t = test_t;
            }
            let (gc, gt, _it, gsig, _rec) =
                composite_pixel(&p, bins.list(tile), px, py, 0);
            assert_eq!(gc, c, "color diverges at ({x},{y})");
            assert_eq!(gt, t, "transmittance diverges at ({x},{y})");
            assert_eq!(gsig, significant, "significant count diverges at ({x},{y})");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn partial_raster_chunked_matches_whole_frame() {
        // Rendering in arbitrary tile-range sub-stages must be bitwise
        // identical to the one-shot path (the RasterChunk determinism
        // guarantee PipelinedSession depth 3 relies on).
        let (p, bins, intr) = render_setup(2500);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 3 };
        let whole = rasterize(&p, &bins, intr.width, intr.height, &cfg);
        for n_chunks in [2usize, 3, 7] {
            let mut acc = PartialRaster::new(&bins, intr.width, intr.height, &cfg);
            let n_tiles = bins.tile_count();
            let per = n_tiles.div_ceil(n_chunks);
            let mut lo = 0;
            while lo < n_tiles {
                let hi = (lo + per).min(n_tiles);
                acc.render_tiles(&p, &bins, lo..hi);
                lo = hi;
            }
            let out = acc.finish();
            assert_eq!(out.image.data, whole.image.data, "{n_chunks} chunks");
            assert_eq!(
                out.stats.as_ref().unwrap().iterated,
                whole.stats.as_ref().unwrap().iterated
            );
            assert_eq!(out.sig_records, whole.sig_records);
        }
    }

    #[test]
    fn tiny_scene_chunked_compositing_matches_whole_frame() {
        // Miri-sized cousin of `partial_raster_chunked_matches_whole_frame`:
        // small enough to run interpreted, still driving the parallel
        // tile map, the compositor, and the PartialRaster accumulator
        // over multiple sub-stage splits.
        let scene = test_scene(23, 160);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(32, 32, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 3 };
        let whole = rasterize(&p, &bins, intr.width, intr.height, &cfg);
        let lit = whole.image.data.iter().filter(|p| p[0] + p[1] + p[2] > 0.01).count();
        assert!(lit > 0, "degenerate scene");
        for n_chunks in [2usize, 3] {
            let mut acc = PartialRaster::new(&bins, intr.width, intr.height, &cfg);
            let n_tiles = bins.tile_count();
            let per = n_tiles.div_ceil(n_chunks);
            let mut lo = 0;
            while lo < n_tiles {
                let hi = (lo + per).min(n_tiles);
                acc.render_tiles(&p, &bins, lo..hi);
                lo = hi;
            }
            let out = acc.finish();
            assert_eq!(out.image.data, whole.image.data, "{n_chunks} chunks");
            assert_eq!(out.sig_records, whole.sig_records);
        }
        // Spot-check the pixel compositor against the full pass.
        for (x, y) in [(5usize, 7usize), (16, 16), (31, 20)] {
            let tile = (y / 16) * bins.tiles_x + x / 16;
            let (c, _, _, _, _) =
                composite_pixel(&p, bins.list(tile), x as f32 + 0.5, y as f32 + 0.5, 0);
            assert_eq!(whole.image.at(x, y), c);
        }
    }

    #[test]
    fn empty_projection_renders_black() {
        let p = ProjectedScene::default();
        let intr = Intrinsics::with_fov(64, 64, 0.9);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        let out = rasterize(&p, &bins, 64, 64, &RasterConfig::default());
        assert!(out.image.data.iter().all(|p| *p == [0.0, 0.0, 0.0]));
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn contribution_profile_normalized_descending() {
        let (p, bins, intr) = render_setup(3000);
        let profiles = contribution_profile(&p, &bins, intr.width, intr.height, 16);
        assert!(!profiles.is_empty());
        for prof in &profiles {
            let sum: f32 = prof.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
            for w in prof.windows(2) {
                assert!(w[0] >= w[1]);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-frame render is too slow interpreted")]
    fn non_square_image() {
        let scene = test_scene(22, 1000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(96, 48, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        let out = rasterize(&p, &bins, intr.width, intr.height, &RasterConfig::default());
        assert_eq!(out.image.data.len(), 96 * 48);
    }
}
