//! Tile binning + per-tile depth sorting (paper Fig. 1, step 2).
//!
//! Every projected Gaussian is inserted into the lists of all tiles its
//! 3-sigma footprint (optionally expanded by the S^2 tile margin) touches;
//! each tile's list is then sorted front-to-back by depth. The per-tile
//! order is exactly what the Sorted Splatting Table of Fig. 1 holds, and
//! what S^2 shares across frames.

use super::project::ProjectedScene;
use crate::camera::Intrinsics;
use crate::util::par;

/// Per-tile sorted Gaussian lists.
///
/// `lists[tile]` holds indices into the [`ProjectedScene`] arrays (NOT
/// global Gaussian IDs — those are `projected.ids[index]`), sorted by
/// ascending depth.
#[derive(Debug, Clone, Default)]
pub struct TileBins {
    pub tiles_x: usize,
    pub tiles_y: usize,
    pub tile_size: usize,
    pub lists: Vec<Vec<u32>>,
}

impl TileBins {
    pub fn tile_count(&self) -> usize {
        self.tiles_x * self.tiles_y
    }

    /// Total tile-Gaussian intersections (the Sorting workload size).
    pub fn total_entries(&self) -> usize {
        self.lists.iter().map(|l| l.len()).sum()
    }

    /// Tile origin in pixels.
    pub fn tile_origin(&self, tile: usize) -> (f32, f32) {
        let tx = tile % self.tiles_x;
        let ty = tile / self.tiles_x;
        ((tx * self.tile_size) as f32, (ty * self.tile_size) as f32)
    }
}

/// Bin projected Gaussians into tiles and depth-sort each list.
///
/// `margin_px` expands each Gaussian's footprint during binning — the
/// tile-granularity realization of the S^2 expanded viewport: a sort
/// computed at the predicted pose must still cover Gaussians that drift
/// across tile borders within the sharing window (paper Fig. 8).
pub fn bin_and_sort(
    projected: &ProjectedScene,
    intr: &Intrinsics,
    tile_size: usize,
    margin_px: f32,
) -> TileBins {
    let (tiles_x, tiles_y) = intr.tiles(tile_size);
    let n_tiles = tiles_x * tiles_y;

    // Pass 1 (parallel): per-Gaussian tile ranges.
    let ranges: Vec<(u32, u32, u32, u32)> = par::par_map(projected.len(), |i| {
            let [mx, my] = projected.means[i];
            let r = projected.radii[i] + margin_px;
            let x0 = ((mx - r) / tile_size as f32).floor().max(0.0) as u32;
            let y0 = ((my - r) / tile_size as f32).floor().max(0.0) as u32;
            let x1 = (((mx + r) / tile_size as f32).floor() as i64)
                .clamp(-1, tiles_x as i64 - 1) as i64;
            let y1 = (((my + r) / tile_size as f32).floor() as i64)
                .clamp(-1, tiles_y as i64 - 1) as i64;
            if x1 < x0 as i64 || y1 < y0 as i64 {
                (1, 0, 1, 0) // empty range
            } else {
                (x0, x1 as u32, y0, y1 as u32)
            }
        });

    // Pass 2: scatter into per-tile lists (counting first to avoid
    // reallocation).
    let mut counts = vec![0usize; n_tiles];
    for &(x0, x1, y0, y1) in &ranges {
        if x1 < x0 || y1 < y0 {
            continue;
        }
        for ty in y0..=y1 {
            for tx in x0..=x1 {
                counts[ty as usize * tiles_x + tx as usize] += 1;
            }
        }
    }
    let mut lists: Vec<Vec<u32>> = counts.iter().map(|&c| Vec::with_capacity(c)).collect();
    for (i, &(x0, x1, y0, y1)) in ranges.iter().enumerate() {
        if x1 < x0 || y1 < y0 {
            continue;
        }
        for ty in y0..=y1 {
            for tx in x0..=x1 {
                lists[ty as usize * tiles_x + tx as usize].push(i as u32);
            }
        }
    }

    // Pass 3 (parallel): per-tile depth sort, stable on f32 key bits so
    // equal depths keep insertion (scene) order like the CUDA radix sort.
    par::par_chunks_mut(&mut lists, 8, |_ci, chunk| {
        for list in chunk {
            list.sort_by_key(|&i| f32_sort_key(projected.depths[i as usize]));
        }
    });

    TileBins { tiles_x, tiles_y, tile_size, lists }
}

/// Order-preserving mapping from (positive) f32 depth to u32 radix key.
#[inline]
pub fn f32_sort_key(depth: f32) -> u32 {
    let bits = depth.to_bits();
    // Positive floats compare like their bit patterns; flip negatives.
    if bits & 0x8000_0000 != 0 {
        !bits
    } else {
        bits | 0x8000_0000
    }
}

/// Fraction of adjacent ordered pairs whose relative order differs
/// between two sorted lists over the same ID universe — the paper's
/// "0.2% of Gaussian orders changed" metric (Sec. 3.1), used by the
/// fig12/fig23 harnesses and S^2 quality analysis.
pub fn order_change_fraction(a: &[u32], b: &[u32]) -> f64 {
    use std::collections::HashMap;
    if a.len() < 2 {
        return 0.0;
    }
    let pos_b: HashMap<u32, usize> = b.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    let mut checked = 0usize;
    let mut changed = 0usize;
    for w in a.windows(2) {
        if let (Some(&pa), Some(&pb)) = (pos_b.get(&w[0]), pos_b.get(&w[1])) {
            checked += 1;
            if pa > pb {
                changed += 1;
            }
        }
    }
    if checked == 0 {
        0.0
    } else {
        changed as f64 / checked as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Pose;
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::scene::synth::test_scene;

    fn setup() -> (ProjectedScene, Intrinsics) {
        let scene = test_scene(9, 2000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        (project(&scene, &pose, &intr, 0.2, 100.0, 0.0), intr)
    }

    #[test]
    fn lists_are_depth_sorted() {
        let (p, intr) = setup();
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        assert_eq!(bins.tile_count(), 64);
        for list in &bins.lists {
            for w in list.windows(2) {
                assert!(p.depths[w[0] as usize] <= p.depths[w[1] as usize]);
            }
        }
    }

    #[test]
    fn every_gaussian_lands_in_a_covering_tile() {
        let (p, intr) = setup();
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        for (i, m) in p.means.iter().enumerate() {
            // A Gaussian whose center is inside the image must appear in
            // the tile containing its center.
            if m[0] >= 0.0 && m[0] < 128.0 && m[1] >= 0.0 && m[1] < 128.0 {
                let tx = (m[0] / 16.0) as usize;
                let ty = (m[1] / 16.0) as usize;
                let list = &bins.lists[ty * bins.tiles_x + tx];
                assert!(
                    list.contains(&(i as u32)),
                    "gaussian {i} center {m:?} missing from tile ({tx},{ty})"
                );
            }
        }
    }

    #[test]
    fn margin_grows_lists() {
        let (p, intr) = setup();
        let tight = bin_and_sort(&p, &intr, 16, 0.0);
        let loose = bin_and_sort(&p, &intr, 16, 8.0);
        assert!(loose.total_entries() > tight.total_entries());
    }

    #[test]
    fn sort_key_monotone() {
        let depths = [0.1f32, 0.5, 1.0, 2.0, 100.0, 1e-3];
        let mut sorted = depths;
        sorted.sort_by(f32::total_cmp);
        let mut by_key = depths;
        by_key.sort_by_key(|d| f32_sort_key(*d));
        assert_eq!(sorted, by_key);
    }

    #[test]
    fn sort_key_handles_negatives() {
        let mut vals = [-2.0f32, 3.0, -0.5, 0.0, 1.5];
        let mut by_key = vals;
        vals.sort_by(f32::total_cmp);
        by_key.sort_by_key(|d| f32_sort_key(*d));
        assert_eq!(vals, by_key);
    }

    #[test]
    fn order_change_zero_for_identical() {
        let a = vec![1, 2, 3, 4, 5];
        assert_eq!(order_change_fraction(&a, &a), 0.0);
    }

    #[test]
    fn order_change_detects_swap() {
        let a = vec![1, 2, 3, 4];
        let b = vec![2, 1, 3, 4];
        let f = order_change_fraction(&a, &b);
        assert!(f > 0.0 && f < 1.0);
    }

    #[test]
    fn tile_origin_math() {
        let bins = TileBins { tiles_x: 4, tiles_y: 3, tile_size: 16, lists: vec![] };
        assert_eq!(bins.tile_origin(0), (0.0, 0.0));
        assert_eq!(bins.tile_origin(5), (16.0, 16.0));
        assert_eq!(bins.tile_origin(11), (48.0, 32.0));
    }
}
