//! Tile binning + per-tile depth sorting (paper Fig. 1, step 2).
//!
//! Binning is *exact-intersection* (FlashGS-style): a projected Gaussian
//! enters a tile's list only if the tile square intersects its
//! significance circle — the radius within which the 1/255 alpha test
//! can pass (`ProjectedScene::r2_sig`), inflated by the S^2 tile margin.
//! Candidates come from the classic 3-sigma bounding-rect walk, so the
//! exact lists are always a subset of the rect lists, and splats whose
//! opacity already sits below 1/255 (negative `r2_sig`) are dropped
//! outright. Culled (splat, tile) pairs contribute to no pixel — every
//! pixel center in the tile sits even farther from the mean than the
//! tile square does — so images are bitwise identical to rect binning
//! while the per-tile lists (and everything priced off them) shrink.
//! See DESIGN.md §"Raster hot path".
//!
//! The scatter is a two-pass prefix-sum: per-chunk per-tile counts, an
//! exclusive scan into per-(chunk, tile) write segments, then parallel
//! writes into one flat entry buffer. Chunks are ascending splat ranges
//! and each tile's segments are laid out in chunk order, so the per-tile
//! pre-sort order is exactly the serial insertion (ascending splat
//! index) order — the stable depth sort, and therefore every image, is
//! unchanged at any thread count.
//!
//! Each tile's list is then sorted front-to-back by depth. The per-tile
//! order is exactly what the Sorted Splatting Table of Fig. 1 holds, and
//! what S^2 shares across frames.

use super::project::ProjectedScene;
use crate::camera::Intrinsics;
use crate::util::par;

/// Per-tile sorted Gaussian lists in one flat buffer.
///
/// [`TileBins::list`] yields tile `t`'s slice of indices into the
/// [`ProjectedScene`] arrays (NOT global Gaussian IDs — those are
/// `projected.ids[index]`), sorted by ascending depth.
#[derive(Debug, Clone, Default)]
pub struct TileBins {
    pub tiles_x: usize,
    pub tiles_y: usize,
    pub tile_size: usize,
    /// Flat entry buffer; tile `t` owns `entries[offsets[t]..offsets[t+1]]`.
    entries: Vec<u32>,
    /// Exclusive per-tile prefix offsets into `entries` (len tile_count+1).
    offsets: Vec<usize>,
    /// Candidate (splat, tile) pairs the bounding-rect walk examined —
    /// the exact-intersection test count, and (in rect mode) the entry
    /// count itself. This is the binning work term the cost models price.
    rect_candidates: usize,
}

impl TileBins {
    /// An empty grid (no entries) — the starting point for hand-built
    /// bins in tests.
    pub fn empty(tiles_x: usize, tiles_y: usize, tile_size: usize) -> Self {
        TileBins {
            tiles_x,
            tiles_y,
            tile_size,
            entries: Vec::new(),
            offsets: vec![0; tiles_x * tiles_y + 1],
            rect_candidates: 0,
        }
    }

    pub fn tile_count(&self) -> usize {
        self.tiles_x * self.tiles_y
    }

    /// Tile `tile`'s depth-sorted list of projected-scene indices.
    #[inline]
    pub fn list(&self, tile: usize) -> &[u32] {
        &self.entries[self.offsets[tile]..self.offsets[tile + 1]]
    }

    /// Total tile-Gaussian intersections (the Sorting workload size).
    pub fn total_entries(&self) -> usize {
        self.entries.len()
    }

    /// Candidate (splat, tile) pairs examined by the binning pass (the
    /// bounding-rect walk the exact test filters).
    pub fn rect_candidates(&self) -> usize {
        self.rect_candidates
    }

    /// Tile origin in pixels.
    pub fn tile_origin(&self, tile: usize) -> (f32, f32) {
        let tx = tile % self.tiles_x;
        let ty = tile / self.tiles_x;
        ((tx * self.tile_size) as f32, (ty * self.tile_size) as f32)
    }
}

/// Bin projected Gaussians into tiles with exact-intersection culling
/// and depth-sort each list.
///
/// `margin_px` expands each Gaussian's footprint during binning — the
/// tile-granularity realization of the S^2 expanded viewport: a sort
/// computed at the predicted pose must still cover Gaussians that drift
/// across tile borders within the sharing window (paper Fig. 8). Both
/// the rect candidate walk and the significance circle are inflated by
/// the margin, so exact culling keeps exactly the covering discipline
/// rect binning had.
pub fn bin_and_sort(
    projected: &ProjectedScene,
    intr: &Intrinsics,
    tile_size: usize,
    margin_px: f32,
) -> TileBins {
    bin_with_mode(projected, intr, tile_size, margin_px, true)
}

/// Bounding-rect-only binning (the pre-overhaul behavior): every tile
/// the 3-sigma rect touches gets an entry. Retained as the reference
/// side of the exact-culling equivalence property tests and the
/// `metric/binned_entries_rect` bench row.
pub fn bin_and_sort_rect(
    projected: &ProjectedScene,
    intr: &Intrinsics,
    tile_size: usize,
    margin_px: f32,
) -> TileBins {
    bin_with_mode(projected, intr, tile_size, margin_px, false)
}

/// Splats per scatter chunk: the prefix-sum granule. Small enough that
/// paper-scale scenes split across every core, large enough that the
/// per-chunk tile-count rows stay cheap.
const SCATTER_CHUNK: usize = 4096;

/// One splat's binning candidate: inclusive tile rect + squared cull
/// radius (`f32::INFINITY` in rect mode). `x1 < x0` encodes "no tiles".
#[derive(Clone, Copy)]
struct BinRange {
    x0: u32,
    x1: u32,
    y0: u32,
    y1: u32,
    r2_cull: f32,
}

impl BinRange {
    const EMPTY: BinRange = BinRange { x0: 1, x1: 0, y0: 1, y1: 0, r2_cull: 0.0 };

    /// Tiles the bounding rect covers (candidate pairs examined).
    fn rect_area(&self) -> usize {
        if self.x1 < self.x0 || self.y1 < self.y0 {
            0
        } else {
            (self.x1 - self.x0 + 1) as usize * (self.y1 - self.y0 + 1) as usize
        }
    }
}

/// Conservative exact test: does the significance circle around `mean`
/// (squared radius `r2_cull`) intersect tile `(tx, ty)`? Distances are
/// measured to the closest point of the tile *square*; every pixel
/// center inside the tile is at least 0.5 px farther, so a rejected
/// pair cannot pass the per-pixel significance reject either.
#[inline(always)]
fn circle_hits_tile(mean: [f32; 2], tx: u32, ty: u32, ts: f32, r2_cull: f32) -> bool {
    let x0 = tx as f32 * ts;
    let y0 = ty as f32 * ts;
    let dx = mean[0] - mean[0].clamp(x0, x0 + ts);
    let dy = mean[1] - mean[1].clamp(y0, y0 + ts);
    dx * dx + dy * dy <= r2_cull
}

/// Visit every covered tile of one candidate, in row-major order.
#[inline]
fn for_each_covered_tile(
    rg: &BinRange,
    mean: [f32; 2],
    ts: f32,
    tiles_x: usize,
    mut f: impl FnMut(usize),
) {
    for ty in rg.y0..=rg.y1 {
        for tx in rg.x0..=rg.x1 {
            if circle_hits_tile(mean, tx, ty, ts, rg.r2_cull) {
                f(ty as usize * tiles_x + tx as usize);
            }
        }
    }
}

fn bin_with_mode(
    projected: &ProjectedScene,
    intr: &Intrinsics,
    tile_size: usize,
    margin_px: f32,
    exact: bool,
) -> TileBins {
    bin_with_chunk(projected, intr, tile_size, margin_px, exact, SCATTER_CHUNK)
}

/// [`bin_with_mode`] with an explicit scatter-chunk granule. Production
/// always uses [`SCATTER_CHUNK`]; tests inject small chunks to exercise
/// many-chunk prefix sums on miri-sized scenes and to pin the invariant
/// that the granule never changes output.
fn bin_with_chunk(
    projected: &ProjectedScene,
    intr: &Intrinsics,
    tile_size: usize,
    margin_px: f32,
    exact: bool,
    scatter_chunk: usize,
) -> TileBins {
    assert!(scatter_chunk > 0);
    let (tiles_x, tiles_y) = intr.tiles(tile_size);
    let n_tiles = tiles_x * tiles_y;
    let n = projected.len();
    let ts = tile_size as f32;

    // Pass 1 (parallel): per-Gaussian candidate rect + cull radius.
    let ranges: Vec<BinRange> = par::par_map(n, |i| {
        let r2_sig = projected.r2_sig[i];
        if exact && r2_sig < 0.0 {
            // Opacity below 1/255: insignificant at every pixel of every
            // tile, at every pose (opacity is pose-invariant).
            return BinRange::EMPTY;
        }
        let [mx, my] = projected.means[i];
        let r = projected.radii[i] + margin_px;
        let x0 = ((mx - r) / ts).floor().max(0.0) as u32;
        let y0 = ((my - r) / ts).floor().max(0.0) as u32;
        let x1 = (((mx + r) / ts).floor() as i64).clamp(-1, tiles_x as i64 - 1);
        let y1 = (((my + r) / ts).floor() as i64).clamp(-1, tiles_y as i64 - 1);
        if x1 < x0 as i64 || y1 < y0 as i64 {
            BinRange::EMPTY
        } else {
            let r2_cull = if exact {
                // Margin-inflated significance radius: the same drift
                // allowance the rect walk gets, so S^2 shared sorts stay
                // covering under pose drift.
                let rc = r2_sig.max(0.0).sqrt() + margin_px;
                rc * rc
            } else {
                f32::INFINITY
            };
            BinRange { x0, x1: x1 as u32, y0, y1: y1 as u32, r2_cull }
        }
    });
    let rect_candidates: usize = ranges.iter().map(BinRange::rect_area).sum();

    // Pass 2a (parallel): per-chunk per-tile entry counts.
    let n_chunks = n.div_ceil(scatter_chunk).max(1);
    let means = &projected.means;
    let counts: Vec<Vec<u32>> = par::par_map(n_chunks, |ci| {
        let mut c = vec![0u32; n_tiles];
        let lo = ci * scatter_chunk;
        let hi = (lo + scatter_chunk).min(n);
        for i in lo..hi {
            for_each_covered_tile(&ranges[i], means[i], ts, tiles_x, |t| c[t] += 1);
        }
        c
    });

    // Exclusive scans: per-tile base offsets into the flat buffer, and
    // each chunk's starting write cursor per tile (tile base + counts of
    // all earlier chunks). Tile segments ordered by chunk — i.e. by
    // ascending splat index — reproduce serial insertion order exactly.
    let mut offsets = vec![0usize; n_tiles + 1];
    for t in 0..n_tiles {
        let tile_total: usize = counts.iter().map(|c| c[t] as usize).sum();
        offsets[t + 1] = offsets[t] + tile_total;
    }
    let mut starts: Vec<Vec<usize>> = Vec::with_capacity(n_chunks);
    let mut cursor: Vec<usize> = offsets[..n_tiles].to_vec();
    for c in &counts {
        starts.push(cursor.clone());
        for t in 0..n_tiles {
            cursor[t] += c[t] as usize;
        }
    }

    // Pass 2b (parallel): scatter. Each chunk owns disjoint per-tile
    // segments of the flat buffer and walks its splats in ascending
    // order, so every slot is written exactly once.
    let total = offsets[n_tiles];
    let mut entries = vec![0u32; total];
    {
        let ptr = par::SendPtr::new(entries.as_mut_ptr());
        let ranges = &ranges;
        let starts = &starts;
        par::par_blocks(n_chunks, n_chunks, |ci, _range| {
            let mut cur = starts[ci].clone();
            let lo = ci * scatter_chunk;
            let hi = (lo + scatter_chunk).min(n);
            for i in lo..hi {
                for_each_covered_tile(&ranges[i], means[i], ts, tiles_x, |t| {
                    // SAFETY: chunk `ci` writes tile `t` only in
                    // `starts[ci][t] .. starts[ci][t] + counts[ci][t]`
                    // — `cur[t]` begins at the exclusive prefix sum of
                    // earlier chunks' counts and advances once per
                    // entry, and pass 2a counted with the identical
                    // covered-tile walk, so the cursor never crosses
                    // into chunk `ci+1`'s segment. Segments are
                    // pairwise disjoint and tile `entries` exactly;
                    // every slot is written exactly once. The
                    // par_blocks scope borrows `entries` via `ptr`'s
                    // construction above and joins all workers before
                    // this block ends.
                    unsafe {
                        *ptr.get().add(cur[t]) = i as u32;
                    }
                    cur[t] += 1;
                });
            }
        });
    }

    // Pass 3 (parallel): per-tile depth sort, stable on f32 key bits so
    // equal depths keep insertion (scene) order like the CUDA radix sort.
    let mut slices: Vec<&mut [u32]> = Vec::with_capacity(n_tiles);
    let mut rest: &mut [u32] = &mut entries;
    for t in 0..n_tiles {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(offsets[t + 1] - offsets[t]);
        slices.push(head);
        rest = tail;
    }
    par::par_chunks_mut(&mut slices, 8, |_ci, chunk| {
        for list in chunk.iter_mut() {
            list.sort_by_key(|&i| f32_sort_key(projected.depths[i as usize]));
        }
    });
    drop(slices);

    TileBins { tiles_x, tiles_y, tile_size, entries, offsets, rect_candidates }
}

/// Order-preserving mapping from (positive) f32 depth to u32 radix key.
#[inline]
pub fn f32_sort_key(depth: f32) -> u32 {
    let bits = depth.to_bits();
    // Positive floats compare like their bit patterns; flip negatives.
    if bits & 0x8000_0000 != 0 {
        !bits
    } else {
        bits | 0x8000_0000
    }
}

/// Fraction of adjacent ordered pairs whose relative order differs
/// between two sorted lists over the same ID universe — the paper's
/// "0.2% of Gaussian orders changed" metric (Sec. 3.1), used by the
/// fig12/fig23 harnesses and S^2 quality analysis.
pub fn order_change_fraction(a: &[u32], b: &[u32]) -> f64 {
    if a.len() < 2 {
        return 0.0;
    }
    // Sorted (id, position) table + binary search rather than a HashMap:
    // the lookup is probe-only either way, but keeping hash collections
    // out of render-path modules entirely is cheaper than arguing which
    // uses observe iteration order (detlint R1).
    let mut pos_b: Vec<(u32, usize)> = b.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    pos_b.sort_unstable();
    let lookup = |id: u32| -> Option<usize> {
        pos_b.binary_search_by_key(&id, |&(v, _)| v).ok().map(|k| pos_b[k].1)
    };
    let mut checked = 0usize;
    let mut changed = 0usize;
    for w in a.windows(2) {
        if let (Some(pa), Some(pb)) = (lookup(w[0]), lookup(w[1])) {
            checked += 1;
            if pa > pb {
                changed += 1;
            }
        }
    }
    if checked == 0 {
        0.0
    } else {
        changed as f64 / checked as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Pose;
    use crate::math::Vec3;
    use crate::pipeline::project::project;
    use crate::scene::synth::test_scene;

    fn setup() -> (ProjectedScene, Intrinsics) {
        let scene = test_scene(9, 2000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        (project(&scene, &pose, &intr, 0.2, 100.0, 0.0), intr)
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-scene binning is too slow interpreted")]
    fn lists_are_depth_sorted() {
        let (p, intr) = setup();
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        assert_eq!(bins.tile_count(), 64);
        for t in 0..bins.tile_count() {
            for w in bins.list(t).windows(2) {
                assert!(p.depths[w[0] as usize] <= p.depths[w[1] as usize]);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-scene binning is too slow interpreted")]
    fn every_gaussian_lands_in_a_covering_tile() {
        let (p, intr) = setup();
        let bins = bin_and_sort(&p, &intr, 16, 0.0);
        let rect = bin_and_sort_rect(&p, &intr, 16, 0.0);
        for (i, m) in p.means.iter().enumerate() {
            // A Gaussian whose center is inside the image must appear in
            // the tile containing its center — the closest-point distance
            // to that tile is zero, so exact culling keeps it unless the
            // splat can never be significant (negative r2_sig), in which
            // case it must appear in *no* tile.
            if m[0] >= 0.0 && m[0] < 128.0 && m[1] >= 0.0 && m[1] < 128.0 {
                let tx = (m[0] / 16.0) as usize;
                let ty = (m[1] / 16.0) as usize;
                let tile = ty * bins.tiles_x + tx;
                if p.r2_sig[i] >= 0.0 {
                    assert!(
                        bins.list(tile).contains(&(i as u32)),
                        "gaussian {i} center {m:?} missing from tile ({tx},{ty})"
                    );
                } else {
                    for t in 0..bins.tile_count() {
                        assert!(!bins.list(t).contains(&(i as u32)));
                    }
                }
                // Rect binning keeps even never-significant splats.
                assert!(rect.list(tile).contains(&(i as u32)));
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-scene binning is too slow interpreted")]
    fn exact_lists_are_ordered_subsets_of_rect_lists() {
        let (p, intr) = setup();
        for margin in [0.0f32, 8.0] {
            let exact = bin_and_sort(&p, &intr, 16, margin);
            let rect = bin_and_sort_rect(&p, &intr, 16, margin);
            assert!(exact.total_entries() <= exact.rect_candidates());
            assert!(exact.rect_candidates() <= rect.total_entries());
            assert_eq!(rect.rect_candidates(), rect.total_entries());
            for t in 0..exact.tile_count() {
                // Subset *and* same relative order: filtering rect's
                // list to exact's membership reproduces exact's list.
                let e = exact.list(t);
                let r = rect.list(t);
                assert!(e.len() <= r.len());
                let filtered: Vec<u32> =
                    r.iter().copied().filter(|i| e.contains(i)).collect();
                assert_eq!(e, &filtered[..], "tile {t} order diverges");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "12k-splat scene is too slow interpreted")]
    fn parallel_scatter_matches_serial_reference() {
        // Enough splats to span several scatter chunks.
        let scene = test_scene(12, 12_000);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        let bins = bin_and_sort_rect(&p, &intr, 16, 0.0);

        // The pre-overhaul serial algorithm: index-major pushes, then a
        // stable per-tile depth sort.
        let (tiles_x, tiles_y) = intr.tiles(16);
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); tiles_x * tiles_y];
        for i in 0..p.len() {
            let [mx, my] = p.means[i];
            let r = p.radii[i];
            let x0 = ((mx - r) / 16.0).floor().max(0.0) as u32;
            let y0 = ((my - r) / 16.0).floor().max(0.0) as u32;
            let x1 = (((mx + r) / 16.0).floor() as i64).clamp(-1, tiles_x as i64 - 1);
            let y1 = (((my + r) / 16.0).floor() as i64).clamp(-1, tiles_y as i64 - 1);
            if x1 < x0 as i64 || y1 < y0 as i64 {
                continue;
            }
            for ty in y0..=y1 as u32 {
                for tx in x0..=x1 as u32 {
                    lists[ty as usize * tiles_x + tx as usize].push(i as u32);
                }
            }
        }
        for list in lists.iter_mut() {
            list.sort_by_key(|&i| f32_sort_key(p.depths[i as usize]));
        }
        assert!(p.len() > 2 * SCATTER_CHUNK, "scene too small to exercise chunking");
        for t in 0..bins.tile_count() {
            assert_eq!(bins.list(t), &lists[t][..], "tile {t}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "full-scene binning is too slow interpreted")]
    fn margin_grows_lists() {
        let (p, intr) = setup();
        let tight = bin_and_sort(&p, &intr, 16, 0.0);
        let loose = bin_and_sort(&p, &intr, 16, 8.0);
        assert!(loose.total_entries() > tight.total_entries());
    }

    #[test]
    fn scatter_chunk_size_invariant() {
        // The scatter granule is a scheduling knob, not a semantic one:
        // any chunk size must produce bit-identical bins. Small scene +
        // tiny chunks keeps this miri-runnable while exercising a
        // many-chunk prefix sum (400 splats / 64 = 7 chunks).
        let scene = test_scene(7, 400);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let intr = Intrinsics::with_fov(64, 64, 0.9);
        let p = project(&scene, &pose, &intr, 0.2, 100.0, 0.0);
        for exact in [true, false] {
            let reference = bin_with_chunk(&p, &intr, 16, 0.0, exact, SCATTER_CHUNK);
            assert!(reference.total_entries() > 0, "degenerate scene");
            for chunk in [64, 97, 1024] {
                let got = bin_with_chunk(&p, &intr, 16, 0.0, exact, chunk);
                assert_eq!(got.entries, reference.entries, "chunk={chunk} exact={exact}");
                assert_eq!(got.offsets, reference.offsets, "chunk={chunk} exact={exact}");
                assert_eq!(got.rect_candidates, reference.rect_candidates);
            }
        }
    }

    #[test]
    fn sort_key_monotone() {
        let depths = [0.1f32, 0.5, 1.0, 2.0, 100.0, 1e-3];
        let mut sorted = depths;
        sorted.sort_by(f32::total_cmp);
        let mut by_key = depths;
        by_key.sort_by_key(|d| f32_sort_key(*d));
        assert_eq!(sorted, by_key);
    }

    #[test]
    fn sort_key_handles_negatives() {
        let mut vals = [-2.0f32, 3.0, -0.5, 0.0, 1.5];
        let mut by_key = vals;
        vals.sort_by(f32::total_cmp);
        by_key.sort_by_key(|d| f32_sort_key(*d));
        assert_eq!(vals, by_key);
    }

    #[test]
    fn order_change_zero_for_identical() {
        let a = vec![1, 2, 3, 4, 5];
        assert_eq!(order_change_fraction(&a, &a), 0.0);
    }

    #[test]
    fn order_change_detects_swap() {
        let a = vec![1, 2, 3, 4];
        let b = vec![2, 1, 3, 4];
        let f = order_change_fraction(&a, &b);
        assert!(f > 0.0 && f < 1.0);
    }

    #[test]
    fn tile_origin_math() {
        let bins = TileBins::empty(4, 3, 16);
        assert_eq!(bins.tile_origin(0), (0.0, 0.0));
        assert_eq!(bins.tile_origin(5), (16.0, 16.0));
        assert_eq!(bins.tile_origin(11), (48.0, 32.0));
    }
}
