//! The frame-loop stage graph: explicit stages with a measured
//! [`FrameWorkload`] record flowing between them.
//!
//! A frame is produced by two functional stages and priced by pluggable
//! cost models (see [`crate::sim::cost`]):
//!
//! ```text
//!   pose ──> FrontendStage ──(projected, bins)──> RasterBackend ──> image
//!                 │                                    │
//!                 └──────────── FrameWorkload <────────┘
//!                                    │
//!                     FrontendCostModel + CostModel
//!                        (GPU / LuminCore / GSCore)
//! ```
//!
//! * [`FrontendStage`] — projection + tile binning + depth sorting,
//!   S²-aware: with a scheduler attached it reuses the speculative sort
//!   across the sharing window (paper Sec. 3.1) and reports how much
//!   frontend work actually ran.
//! * [`RasterBackend`] — the rasterization stage behind one trait:
//!   [`PlainRaster`] (exact 3DGS), [`crate::lumina::rc::CachedRaster`]
//!   (radiance-cached, optionally recording single-pass uncached stats),
//!   and [`crate::lumina::ds2::Ds2Raster`] (half-res + upsample).
//! * [`FrameWorkload`] — everything the functional stages measured about
//!   the frame, in the exact units the hardware cost models consume.
//!   [`FrameWorkload::aggregate`] collapses it into the O(tiles)
//!   [`AggregateWorkload`] the admission controller's fast rung-pricing
//!   path re-scales.
//! * [`PipelinedSession`] — the frame-queue state machine for async
//!   frame pipelining: frame N+1's frontend runs concurrently with
//!   queued frames' rasterization on a split thread budget, bitwise
//!   invisible in the output. At depth 3 rasterization is interleaved
//!   at [`RasterChunk`] (tile-range) granularity so two frames' raster
//!   work can straddle one dispatch.
//!
//! The coordinator composes these as trait objects; no stage knows which
//! hardware variant is being modeled.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::camera::{Intrinsics, Pose};
use crate::config::Tier;
use crate::lumina::rc::{CacheDelta, CacheSnapshot, CacheStats, WorldDelta, WorldSnapshot};
use crate::lumina::s2::{S2Scheduler, SortView};
use crate::pipeline::image::Image;
use crate::pipeline::project::{project, ProjectedScene};
use crate::pipeline::raster::{rasterize, PartialRaster, RasterConfig, RasterStats};
use crate::pipeline::sort::{bin_and_sort, TileBins};
use crate::scene::GaussianScene;
use crate::util::par;

/// Everything one frame's functional stages measured, in the units the
/// hardware cost models consume. Produced by [`FrameWorkload::from_stages`]
/// out of a [`FrontendOutput`] and a [`RasterFrame`].
#[derive(Debug, Clone)]
pub struct FrameWorkload {
    /// Frame index within the trajectory.
    pub frame: usize,
    /// Rendered framebuffer width in pixels (the *pipeline* resolution —
    /// half the session resolution for DS-2).
    pub width: usize,
    /// Rendered framebuffer height in pixels.
    pub height: usize,
    /// Tile edge in pixels.
    pub tile_size: usize,
    /// Tile grid width.
    pub tiles_x: usize,
    /// Tile grid height.
    pub tiles_y: usize,
    /// Per-tile sorted-list lengths (row-major tile order).
    pub tile_list_lens: Vec<usize>,
    /// Scene size: projection frustum-culls every Gaussian.
    pub scene_gaussians: usize,
    /// Whether projection + sorting actually ran this frame (false on
    /// S²-shared frames).
    pub sorted: bool,
    /// Tile-list entries produced by sorting (0 when `!sorted`).
    pub sort_entries: usize,
    /// Candidate (splat, tile) pairs the binning rect walk examined
    /// before exact-intersection culling (0 when `!sorted` — S²-shared
    /// frames reuse the leader's bins without re-testing). The frontend
    /// cost models price the per-candidate intersection tests from
    /// this; `sort_entries` only counts the survivors.
    pub bin_candidates: usize,
    /// Gaussians whose SH color / screen geometry were re-evaluated for
    /// the current pose (the per-frame S² refresh; 0 without S²).
    pub refreshed_gaussians: usize,
    /// Per-pixel Gaussians consumed as run (early termination and cache
    /// cutoffs included). Row-major, `width * height`.
    pub consumed: Vec<u32>,
    /// Per-pixel significant Gaussians encountered while consuming.
    pub significant: Vec<u32>,
    /// Per-pixel counts the *uncached* pipeline would have produced,
    /// recorded in the same rasterization pass (present when the raster
    /// backend was asked to record them; the GPU cost model prices RC's
    /// warp-bound time from these).
    pub uncached: Option<RasterStats>,
    /// Per-pixel cache interaction: 1 = miss, 2 = hit from the
    /// session's own inserts, 3 = hit from the pool-shared snapshot
    /// (None without RC).
    pub cache_outcomes: Option<Vec<u8>>,
    /// Radiance-cache statistics for the frame (hit provenance
    /// included: [`CacheStats::snapshot_hits`]).
    pub cache: CacheStats,
    /// Whether the frame rendered against a pool-shared cache snapshot.
    /// A *structural* property of the session — shared-scope lookups
    /// pay port/lock contention at any tier, with or without a warm
    /// cache — so unlike the stats it survives
    /// [`Self::tier_estimate`]'s normalization and the cost models can
    /// keep pricing the contention the paper warns about.
    pub cache_shared: bool,
    /// Worst-case probe-chain length a shared lookup walks (1 for the
    /// geometry scopes, whose tag resolves in one set access;
    /// `pool.world_probe_len` under world scope). Structural like
    /// `cache_shared` — it multiplies the shared-lookup contention the
    /// cost models charge, and survives tier normalization.
    pub shared_probe_len: u32,
    /// LuminCache group save/reload traffic (bytes). Scope-aware at the
    /// source: a private cache swaps per frame, a shared snapshot is
    /// charged once per pool epoch (amortized over its sharers).
    pub swap_bytes: u64,
}

impl FrameWorkload {
    /// Assemble the workload record from the two stage outputs.
    pub fn from_stages(
        frame: usize,
        scene_gaussians: usize,
        frontend: &FrontendOutput,
        raster: RasterWork,
    ) -> Self {
        let bins = &frontend.bins;
        FrameWorkload {
            frame,
            width: raster.width,
            height: raster.height,
            tile_size: bins.tile_size,
            tiles_x: bins.tiles_x,
            tiles_y: bins.tiles_y,
            tile_list_lens: (0..bins.tile_count()).map(|t| bins.list(t).len()).collect(),
            scene_gaussians,
            sorted: frontend.sorted,
            sort_entries: frontend.sort_entries,
            bin_candidates: frontend.bin_candidates,
            refreshed_gaussians: frontend.refreshed_gaussians,
            consumed: raster.consumed,
            significant: raster.significant,
            uncached: raster.uncached,
            cache_outcomes: raster.cache_outcomes,
            cache: raster.cache,
            cache_shared: raster.cache_shared,
            shared_probe_len: raster.shared_probe_len,
            swap_bytes: raster.swap_bytes,
        }
    }

    /// Framebuffer pixel count.
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// The frontend-stage scalars the frontend cost models price.
    pub fn frontend_work(&self) -> FrontendWork {
        FrontendWork {
            scene_gaussians: self.scene_gaussians,
            sorted: self.sorted,
            sort_entries: self.sort_entries,
            bin_candidates: self.bin_candidates,
            refreshed_gaussians: self.refreshed_gaussians,
        }
    }

    /// Collapse the per-pixel record into an O(tiles) aggregate — the
    /// admission controller's fast rung-pricing representation (built
    /// once per session per planning round; every ladder rung is then
    /// re-scaled in O(tiles) instead of re-gridding `width * height`
    /// pixel counts). The record is normalized first, exactly like the
    /// per-pixel [`Self::tier_estimate`] path.
    pub fn aggregate(&self) -> AggregateWorkload {
        let w = self.normalized();
        let mut tiles = Vec::with_capacity(w.tiles_x * w.tiles_y);
        let ts = w.tile_size.max(1);
        for ty in 0..w.tiles_y {
            for tx in 0..w.tiles_x {
                let mut t = TileAggregate {
                    list_len: w.tile_list_lens[ty * w.tiles_x + tx],
                    width: ts.min(w.width.saturating_sub(tx * ts)) as u32,
                    height: ts.min(w.height.saturating_sub(ty * ts)) as u32,
                    ..TileAggregate::default()
                };
                for ly in 0..t.height as usize {
                    let y = ty * ts + ly;
                    for lx in 0..t.width as usize {
                        let x = tx * ts + lx;
                        let off = y * w.width + x;
                        let c = w.consumed[off];
                        t.iter_sum += c as u64;
                        t.sig_sum += w.significant[off] as u64;
                        t.iter_max = t.iter_max.max(c);
                    }
                }
                tiles.push(t);
            }
        }
        AggregateWorkload {
            width: w.width,
            height: w.height,
            tile_size: w.tile_size,
            tiles_x: w.tiles_x,
            tiles_y: w.tiles_y,
            scene_gaussians: w.scene_gaussians,
            sorted: w.sorted,
            sort_entries: w.sort_entries,
            bin_candidates: w.bin_candidates,
            refreshed_gaussians: w.refreshed_gaussians,
            cache_shared: w.cache_shared,
            shared_probe_len: w.shared_probe_len,
            swap_bytes: w.swap_bytes,
            tiles,
        }
    }

    /// True when the frame went through a radiance cache.
    pub fn uses_cache(&self) -> bool {
        self.cache_outcomes.is_some()
    }

    /// Mean Gaussians iterated per pixel (as run).
    pub fn mean_iterated(&self) -> f64 {
        if self.consumed.is_empty() {
            0.0
        } else {
            self.consumed.iter().map(|&v| v as f64).sum::<f64>() / self.consumed.len() as f64
        }
    }

    /// Estimate what this workload would look like served under `target`
    /// tier, given it was *measured* under `measured` tier — the record
    /// the admission controller feeds through the cost-model seams to
    /// price a tier mix without re-rendering.
    ///
    /// The estimate is deterministic (integer/f64 arithmetic only, no
    /// sampling) and deliberately conservative: the demoted tiers are
    /// priced slightly above their observed cost so the controller errs
    /// toward refusing work rather than missing its FPS target. Cache
    /// outcome maps are stripped (and recorded uncached counts take the
    /// place of the hit-shortened as-run ones) so all tiers price the
    /// same cold-cache structural quantity.
    pub fn tier_estimate(
        &self,
        measured: Tier,
        target: Tier,
        reduced_fraction: f64,
    ) -> FrameWorkload {
        self.estimate_full(measured, reduced_fraction)
            .estimate_from_full(target, reduced_fraction)
    }

    /// Strip per-run extras so tier estimates price comparably.
    fn normalized(&self) -> FrameWorkload {
        let mut w = self.clone();
        // Price the *uncached* per-pixel structure when the raster pass
        // recorded it: cache hits shorten the as-run counts, but the
        // planner's conservative contract wants what the frame costs
        // without a warm cache — tier swaps reset the cache, so a plan
        // that banks on yesterday's hit rate would blow the budget the
        // moment it re-tiers. The remaining cache interplay (lookup
        // overhead, outcome maps) is stripped so every tier prices the
        // same structural quantity; swap traffic is kept (real transfer
        // work), conservatively unscaled by the tier transforms.
        if let Some(u) = w.uncached.take() {
            w.consumed = u.iterated;
            w.significant = u.significant;
        }
        w.cache_outcomes = None;
        w.cache = CacheStats::default();
        // `cache_shared` is deliberately kept: the shared-lookup
        // contention is structural (paid at any tier, warm or cold), so
        // the planner must keep pricing it.
        w
    }

    /// Undo the measured tier's scaling: an estimate of the same frame
    /// served at full tier.
    fn estimate_full(&self, measured: Tier, reduced_fraction: f64) -> FrameWorkload {
        let mut w = self.normalized();
        match measured {
            Tier::Full => {}
            Tier::Reduced => w.scale_gaussian_load(1.0 / reduced_fraction),
            Tier::Half => {
                let (tw, th) = (w.width * 2, w.height * 2);
                w.resample(tw, th, 1.0 / HALF_LIST_GROWTH, 1.0 / HALF_ENTRY_KEEP);
            }
        }
        w
    }

    /// Apply a target tier's scaling to a full-tier workload estimate.
    fn estimate_from_full(mut self, target: Tier, reduced_fraction: f64) -> FrameWorkload {
        match target {
            Tier::Full => {}
            Tier::Reduced => self.scale_gaussian_load(reduced_fraction),
            Tier::Half => {
                let (tw, th) = ((self.width / 2).max(1), (self.height / 2).max(1));
                self.resample(tw, th, HALF_LIST_GROWTH, HALF_ENTRY_KEEP);
            }
        }
        self
    }

    /// Scale everything that tracks the Gaussian budget (the reduced
    /// tier serves a `f`-fraction prefix of the scene; projection,
    /// sorting, and per-pixel iteration all shrink with it).
    fn scale_gaussian_load(&mut self, f: f64) {
        self.scene_gaussians = scale_round(self.scene_gaussians, f);
        self.sort_entries = scale_round(self.sort_entries, f);
        self.bin_candidates = scale_round(self.bin_candidates, f);
        self.refreshed_gaussians = scale_round(self.refreshed_gaussians, f);
        for l in self.tile_list_lens.iter_mut() {
            *l = scale_round(*l, f);
        }
        scale_counts_in_place(&mut self.consumed, f);
        scale_counts_in_place(&mut self.significant, f);
    }

    /// Re-grid the per-pixel record to `new_w x new_h` (nearest
    /// neighbor), scaling each count by `per_pixel_scale` and the
    /// sort/tile-list totals by `entry_scale`. Projection cost
    /// (`scene_gaussians`, `refreshed_gaussians`) is untouched: the
    /// frontend frustum-culls the whole scene at any resolution.
    fn resample(
        &mut self,
        new_w: usize,
        new_h: usize,
        per_pixel_scale: f64,
        entry_scale: f64,
    ) {
        let (old_w, old_h) = (self.width, self.height);
        let consumed = resample_grid(&self.consumed, old_w, old_h, new_w, new_h, per_pixel_scale);
        let significant =
            resample_grid(&self.significant, old_w, old_h, new_w, new_h, per_pixel_scale);
        self.consumed = consumed;
        self.significant = significant;
        self.width = new_w;
        self.height = new_h;
        self.tiles_x = new_w.div_ceil(self.tile_size.max(1));
        self.tiles_y = new_h.div_ceil(self.tile_size.max(1));
        self.sort_entries = scale_round(self.sort_entries, entry_scale);
        self.bin_candidates = scale_round(self.bin_candidates, entry_scale);
        // Tile lists: preserve the scaled total, spread uniformly — the
        // admission estimate does not track spatial distribution.
        let total: usize = self.tile_list_lens.iter().sum();
        let tiles = (self.tiles_x * self.tiles_y).max(1);
        let per_tile = scale_round(total, entry_scale).div_ceil(tiles);
        self.tile_list_lens = vec![per_tile; self.tiles_x * self.tiles_y];
    }
}

/// Per-pixel list growth when the pipeline drops to half resolution:
/// each half-res tile covers 2x the world area, so every pixel iterates
/// a longer list and the savings are sublinear in pixel count (see
/// `lumina::ds2` — DS-2 is a quality baseline, not a 4x-speed one).
/// Deliberately conservative: overestimating the demoted tier's cost
/// makes the admission controller refuse work rather than miss target.
const HALF_LIST_GROWTH: f64 = 1.5;

/// Sort-entry (and tile-list total) retention at half resolution: the
/// tile count quarters but each surviving tile binds more Gaussians.
const HALF_ENTRY_KEEP: f64 = 0.75;

fn scale_round(x: usize, f: f64) -> usize {
    (x as f64 * f).round() as usize
}

fn scale_counts_in_place(v: &mut [u32], f: f64) {
    for x in v.iter_mut() {
        *x = (*x as f64 * f).round() as u32;
    }
}

/// Nearest-neighbor re-grid of a row-major per-pixel count field, with
/// a per-sample scale factor.
fn resample_grid(
    v: &[u32],
    old_w: usize,
    old_h: usize,
    new_w: usize,
    new_h: usize,
    scale: f64,
) -> Vec<u32> {
    if old_w == 0 || old_h == 0 || v.is_empty() {
        return vec![0; new_w * new_h];
    }
    let mut out = Vec::with_capacity(new_w * new_h);
    for r in 0..new_h {
        let sr = (r * old_h / new_h).min(old_h - 1);
        for c in 0..new_w {
            let sc = (c * old_w / new_w).min(old_w - 1);
            out.push((v[sr * old_w + sc] as f64 * scale).round() as u32);
        }
    }
    out
}

/// The frontend-stage scalars a frontend cost model prices — common to
/// the exact per-pixel [`FrameWorkload`] and the O(tiles)
/// [`AggregateWorkload`].
#[derive(Debug, Clone, Copy)]
pub struct FrontendWork {
    pub scene_gaussians: usize,
    pub sorted: bool,
    pub sort_entries: usize,
    /// Candidate (splat, tile) pairs the binning stage intersection-tested
    /// (0 when `!sorted`).
    pub bin_candidates: usize,
    pub refreshed_gaussians: usize,
}

/// Per-tile statistics of a workload: sums, the deepest lane, and the
/// tile's sorted-list length — enough for the cost models to price a
/// frame without the per-pixel grids.
#[derive(Debug, Clone, Copy, Default)]
pub struct TileAggregate {
    /// Sorted-list length of the tile.
    pub list_len: usize,
    /// Tile extent actually covered, in pixels (edge tiles are
    /// partial). Kept as a geometry, not a bare count, so warp-shaped
    /// pricing can reconstruct how many 2x16 warps the tile spans.
    pub width: u32,
    pub height: u32,
    /// Summed per-pixel consumed counts.
    pub iter_sum: u64,
    /// Summed per-pixel significant counts.
    pub sig_sum: u64,
    /// Deepest per-pixel consumed count (bounds the feature stream and
    /// the non-remapped PE time).
    pub iter_max: u32,
}

impl TileAggregate {
    /// Pixels the tile covers.
    pub fn pixels(&self) -> u32 {
        self.width * self.height
    }
}

/// O(tiles) aggregate of a [`FrameWorkload`]: the admission
/// controller's fast rung-pricing record. Tier re-scaling
/// ([`Self::tier_estimate`]) mirrors the exact per-pixel transforms but
/// costs O(tiles) per rung; pricing assumes per-pixel counts are
/// uniform within a tile, bounded by the tile's recorded maximum —
/// conservative where it deviates, so the planner still errs toward
/// refusing work (see `tests/admission.rs` for the decision-parity
/// pin).
#[derive(Debug, Clone)]
pub struct AggregateWorkload {
    pub width: usize,
    pub height: usize,
    pub tile_size: usize,
    pub tiles_x: usize,
    pub tiles_y: usize,
    pub scene_gaussians: usize,
    pub sorted: bool,
    pub sort_entries: usize,
    /// Candidate (splat, tile) pairs the binning stage intersection-tested
    /// (0 when `!sorted`), mirrored from the per-pixel record.
    pub bin_candidates: usize,
    pub refreshed_gaussians: usize,
    /// Shared-cache scope flag, mirrored from the per-pixel record so
    /// both pricing paths charge the same contention.
    pub cache_shared: bool,
    /// Shared-lookup probe-chain bound, mirrored from the per-pixel
    /// record (see [`FrameWorkload::shared_probe_len`]).
    pub shared_probe_len: u32,
    pub swap_bytes: u64,
    pub tiles: Vec<TileAggregate>,
}

impl AggregateWorkload {
    /// The frontend-stage scalars the frontend cost models price.
    pub fn frontend_work(&self) -> FrontendWork {
        FrontendWork {
            scene_gaussians: self.scene_gaussians,
            sorted: self.sorted,
            sort_entries: self.sort_entries,
            bin_candidates: self.bin_candidates,
            refreshed_gaussians: self.refreshed_gaussians,
        }
    }

    /// Total consumed Gaussian-pixel pairs (the GSCore pricing input).
    pub fn iter_total(&self) -> u64 {
        self.tiles.iter().map(|t| t.iter_sum).sum()
    }

    /// O(tiles) mirror of [`FrameWorkload::tier_estimate`]: estimate
    /// this aggregate re-served under `target` tier given it was
    /// measured under `measured`.
    pub fn tier_estimate(
        &self,
        measured: Tier,
        target: Tier,
        reduced_fraction: f64,
    ) -> AggregateWorkload {
        self.estimate_full(measured, reduced_fraction)
            .estimate_from_full(target, reduced_fraction)
    }

    fn estimate_full(&self, measured: Tier, reduced_fraction: f64) -> AggregateWorkload {
        match measured {
            Tier::Full => self.clone(),
            Tier::Reduced => {
                let mut w = self.clone();
                w.scale_gaussian_load(1.0 / reduced_fraction);
                w
            }
            Tier::Half => self.resample(
                self.width * 2,
                self.height * 2,
                1.0 / HALF_LIST_GROWTH,
                1.0 / HALF_ENTRY_KEEP,
            ),
        }
    }

    fn estimate_from_full(self, target: Tier, reduced_fraction: f64) -> AggregateWorkload {
        match target {
            Tier::Full => self,
            Tier::Reduced => {
                let mut w = self;
                w.scale_gaussian_load(reduced_fraction);
                w
            }
            Tier::Half => self.resample(
                (self.width / 2).max(1),
                (self.height / 2).max(1),
                HALF_LIST_GROWTH,
                HALF_ENTRY_KEEP,
            ),
        }
    }

    /// Mirror of the per-pixel record's `scale_gaussian_load` over tile
    /// sums.
    fn scale_gaussian_load(&mut self, f: f64) {
        self.scene_gaussians = scale_round(self.scene_gaussians, f);
        self.sort_entries = scale_round(self.sort_entries, f);
        self.bin_candidates = scale_round(self.bin_candidates, f);
        self.refreshed_gaussians = scale_round(self.refreshed_gaussians, f);
        for t in self.tiles.iter_mut() {
            t.list_len = scale_round(t.list_len, f);
            // Round at per-pixel granularity (scaled mean, then summed)
            // like the exact path rounds each pixel's count.
            let px = f64::from(t.pixels().max(1));
            t.iter_sum = ((t.iter_sum as f64 / px * f).round() * px) as u64;
            t.sig_sum = ((t.sig_sum as f64 / px * f).round() * px) as u64;
            t.iter_max = (t.iter_max as f64 * f).round() as u32;
        }
    }

    /// Mirror of the per-pixel record's `resample` at tile granularity: each
    /// new tile averages the old tiles its pixels nearest-neighbor
    /// sample from (means scaled by `per_pixel_scale`, maxima kept as
    /// block maxima — conservative), and tile lists are spread
    /// uniformly from the `entry_scale`d total exactly like the
    /// per-pixel path.
    fn resample(
        &self,
        new_w: usize,
        new_h: usize,
        per_pixel_scale: f64,
        entry_scale: f64,
    ) -> AggregateWorkload {
        let ts = self.tile_size.max(1);
        let new_tx = new_w.div_ceil(ts);
        let new_ty = new_h.div_ceil(ts);
        let old_tx_n = self.tiles_x.max(1);
        let old_ty_n = self.tiles_y.max(1);
        let mut tiles = Vec::with_capacity(new_tx * new_ty);
        for ty in 0..new_ty {
            // Old tile rows sourced by this new tile's rows under the
            // nearest-neighbor pixel mapping.
            let y0 = ((ty * ts * self.height / new_h) / ts).min(old_ty_n - 1);
            let y1 = ((((ty + 1) * ts - 1).min(new_h - 1) * self.height / new_h) / ts)
                .min(old_ty_n - 1);
            for tx in 0..new_tx {
                let x0 = ((tx * ts * self.width / new_w) / ts).min(old_tx_n - 1);
                let x1 = ((((tx + 1) * ts - 1).min(new_w - 1) * self.width / new_w) / ts)
                    .min(old_tx_n - 1);
                let (mut px, mut it, mut sg, mut mx) = (0u64, 0u64, 0u64, 0u32);
                for oy in y0..=y1 {
                    for ox in x0..=x1 {
                        let o = &self.tiles[oy * old_tx_n + ox];
                        px += u64::from(o.pixels());
                        it += o.iter_sum;
                        sg += o.sig_sum;
                        mx = mx.max(o.iter_max);
                    }
                }
                let tw = ts.min(new_w - tx * ts) as u32;
                let th = ts.min(new_h - ty * ts) as u32;
                let new_px = u64::from(tw) * u64::from(th);
                let mean_it = if px > 0 { it as f64 / px as f64 } else { 0.0 };
                let mean_sg = if px > 0 { sg as f64 / px as f64 } else { 0.0 };
                // Round the scaled mean at per-pixel granularity, like
                // the exact path rounds each resampled pixel.
                tiles.push(TileAggregate {
                    list_len: 0, // spread uniformly below
                    width: tw,
                    height: th,
                    iter_sum: ((mean_it * per_pixel_scale).round() * new_px as f64) as u64,
                    sig_sum: ((mean_sg * per_pixel_scale).round() * new_px as f64) as u64,
                    iter_max: (mx as f64 * per_pixel_scale).round() as u32,
                });
            }
        }
        let total: usize = self.tiles.iter().map(|t| t.list_len).sum();
        let n = (new_tx * new_ty).max(1);
        let per_tile = scale_round(total, entry_scale).div_ceil(n);
        for t in tiles.iter_mut() {
            t.list_len = per_tile;
        }
        AggregateWorkload {
            width: new_w,
            height: new_h,
            tile_size: self.tile_size,
            tiles_x: new_tx,
            tiles_y: new_ty,
            scene_gaussians: self.scene_gaussians,
            sorted: self.sorted,
            sort_entries: scale_round(self.sort_entries, entry_scale),
            bin_candidates: scale_round(self.bin_candidates, entry_scale),
            refreshed_gaussians: self.refreshed_gaussians,
            cache_shared: self.cache_shared,
            shared_probe_len: self.shared_probe_len,
            swap_bytes: self.swap_bytes,
            tiles,
        }
    }
}

/// What the frontend stage produced for one frame.
pub struct FrontendOutput {
    /// Projected Gaussian set to rasterize (S²: geometry/colors refreshed
    /// at the render pose, order frozen from the speculative sort).
    pub projected: ProjectedScene,
    /// Per-tile sorted lists.
    pub bins: TileBins,
    /// Whether projection + sorting ran this frame.
    pub sorted: bool,
    /// Tile-list entries sorted (0 when reused).
    pub sort_entries: usize,
    /// Candidate (splat, tile) pairs the binning stage intersection-tested
    /// (0 when reused) — see [`TileBins::rect_candidates`].
    pub bin_candidates: usize,
    /// Gaussians refreshed for the current pose (S² only).
    pub refreshed_gaussians: usize,
}

/// Projection + sorting stage, S²-aware.
///
/// The `Plain` form runs the classic per-frame pipeline; the `S2` form
/// delegates to a [`SortView`] — the sort-topology seam: a private
/// [`S2Scheduler`] (speculative sort shared across the session's own
/// window) or a pool-clustered view rendering against a frozen cluster
/// sort. Either way the view owns its own near/far/tile-size state.
pub enum FrontendStage {
    Plain { near: f32, far: f32, tile_size: usize },
    /// Boxed: the view carries the shared sort's projected set, which
    /// would dwarf the `Plain` variant inline.
    S2(Box<SortView>),
}

impl FrontendStage {
    /// Classic per-frame projection + sorting.
    pub fn plain(near: f32, far: f32, tile_size: usize) -> Self {
        FrontendStage::Plain { near, far, tile_size }
    }

    /// Sorting-sharing frontend driven by a session-private
    /// [`S2Scheduler`] (the pre-seam behavior, bit-for-bit).
    pub fn with_s2(s2: S2Scheduler) -> Self {
        FrontendStage::S2(Box::new(SortView::private(s2)))
    }

    /// Sorting-sharing frontend over an explicit [`SortView`] (pools
    /// compose the clustered topology through this).
    pub fn with_sort_view(view: SortView) -> Self {
        FrontendStage::S2(Box::new(view))
    }

    /// True when this frontend shares sorting across frames.
    pub fn uses_s2(&self) -> bool {
        matches!(self, FrontendStage::S2(_))
    }

    /// The S² sort view, if this frontend has one.
    pub fn sort_view(&self) -> Option<&SortView> {
        match self {
            FrontendStage::S2(v) => Some(v),
            FrontendStage::Plain { .. } => None,
        }
    }

    pub fn sort_view_mut(&mut self) -> Option<&mut SortView> {
        match self {
            FrontendStage::S2(v) => Some(v),
            FrontendStage::Plain { .. } => None,
        }
    }

    /// Drop cross-frame state (the S² shared sort). Required when the
    /// raster backend or the pipeline resolution is swapped mid-run —
    /// tier promotion/demotion — since a stale speculative sort would
    /// reference the old tile grid.
    pub fn reset(&mut self) {
        if let FrontendStage::S2(v) = self {
            v.reset();
        }
    }

    /// Run the frontend for one pose.
    pub fn run(
        &mut self,
        scene: &GaussianScene,
        pose: &Pose,
        intr: &Intrinsics,
    ) -> FrontendOutput {
        match self {
            FrontendStage::S2(s2) => {
                let f = s2.frame(scene, pose, intr);
                FrontendOutput {
                    projected: f.projected,
                    bins: f.bins,
                    sorted: f.work.sorted,
                    sort_entries: f.work.sort_entries,
                    bin_candidates: f.work.bin_candidates,
                    refreshed_gaussians: f.work.refreshed_gaussians,
                }
            }
            FrontendStage::Plain { near, far, tile_size } => {
                let projected = project(scene, pose, intr, *near, *far, 0.0);
                let bins = bin_and_sort(&projected, intr, *tile_size, 0.0);
                let sort_entries = bins.total_entries();
                let bin_candidates = bins.rect_candidates();
                FrontendOutput {
                    projected,
                    bins,
                    sorted: true,
                    sort_entries,
                    bin_candidates,
                    refreshed_gaussians: 0,
                }
            }
        }
    }
}

/// What a raster backend measured while rendering (the raster half of a
/// [`FrameWorkload`]; the image travels separately so backends can
/// post-process it).
pub struct RasterWork {
    pub width: usize,
    pub height: usize,
    pub consumed: Vec<u32>,
    pub significant: Vec<u32>,
    pub uncached: Option<RasterStats>,
    pub cache_outcomes: Option<Vec<u8>>,
    pub cache: CacheStats,
    /// True when the backend rendered against a pool-shared cache
    /// snapshot (see [`FrameWorkload::cache_shared`]).
    pub cache_shared: bool,
    /// Shared-lookup probe-chain bound (see
    /// [`FrameWorkload::shared_probe_len`]; 1 for single-access scopes).
    pub shared_probe_len: u32,
    pub swap_bytes: u64,
}

/// One rendered frame from a raster backend.
pub struct RasterFrame {
    pub image: Image,
    pub work: RasterWork,
}

/// Default number of [`RasterChunk`] sub-stages a frame's rasterization
/// is split into under pipelining (`pool.raster_substages`). Should be
/// at least `pipeline_depth - 1` so each dispatch has a sub-frame unit
/// of raster work to interleave.
pub const DEFAULT_RASTER_SUBSTAGES: usize = 4;

/// One deterministic sub-stage of a frame's rasterization: a contiguous
/// row-major tile range. The schedule-granularity seam for
/// `pipeline_depth > 2`: [`PipelinedSession`] dispatches chunks instead
/// of whole frames, so one frame's raster work can straddle two
/// dispatches while later frontends run.
#[derive(Debug, Clone)]
pub struct RasterChunk {
    /// Sub-stage index within the frame (0-based).
    pub index: usize,
    /// Total sub-stages the frame was split into.
    pub count: usize,
    /// The tiles (row-major indices into the frame's [`TileBins`]) this
    /// sub-stage rasterizes.
    pub tiles: std::ops::Range<usize>,
}

impl RasterChunk {
    /// Whether this is the frame's final sub-stage — the one whose
    /// [`RasterBackend::render_chunk`] call yields the frame.
    pub fn is_last(&self) -> bool {
        self.index + 1 == self.count
    }

    /// Split `tile_count` tiles into at most `substages` contiguous
    /// near-equal ranges covering every tile exactly once. Always
    /// returns at least one chunk so the frame-yielding `is_last` call
    /// happens even for degenerate grids.
    pub fn plan(tile_count: usize, substages: usize) -> Vec<RasterChunk> {
        let count = substages.max(1).min(tile_count.max(1));
        let base = tile_count / count;
        let rem = tile_count % count;
        let mut chunks = Vec::with_capacity(count);
        let mut start = 0;
        for index in 0..count {
            let len = base + usize::from(index < rem);
            chunks.push(RasterChunk { index, count, tiles: start..start + len });
            start += len;
        }
        chunks
    }
}

/// The rasterization stage behind one seam: plain, radiance-cached, or
/// DS-2 — the coordinator neither knows nor cares which.
pub trait RasterBackend: Send {
    /// Short name for reports.
    fn label(&self) -> &'static str;

    /// Rasterize one frame, measuring per-pixel work.
    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame;

    /// Rasterize one sub-stage of a frame. Chunks of a frame arrive in
    /// order (`0..count`) with the same `projected`/`bins`, and the
    /// `is_last` call returns the finished frame. The default keeps
    /// stateless backends correct by deferring the whole frame to the
    /// last chunk — bitwise identical, just without sub-frame overlap;
    /// backends that can accumulate (see [`PlainRaster`]) override it.
    fn render_chunk(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
        chunk: &RasterChunk,
    ) -> Option<RasterFrame> {
        if chunk.is_last() {
            Some(self.render(projected, bins, width, height))
        } else {
            None
        }
    }

    /// Post-process the framebuffer into the session's output resolution
    /// (identity for everything but DS-2's 2x upsample).
    fn finalize(&self, image: Image) -> Image {
        image
    }

    /// Detach the session's accumulated shared-cache insert delta,
    /// leaving a fresh one behind. `None` under private scope and for
    /// uncached backends. The pool calls this at every epoch boundary,
    /// in session-index order — the shared-scope determinism contract.
    fn take_cache_delta(&mut self) -> Option<CacheDelta> {
        None
    }

    /// Install the next epoch's merged cache snapshot (no-op under
    /// private scope / uncached backends). `sharers` amortizes the
    /// once-per-pool-epoch snapshot swap traffic across the sessions
    /// reading it.
    fn install_cache_snapshot(&mut self, _snapshot: Arc<CacheSnapshot>, _sharers: usize) {}

    /// Detach the session's accumulated world-scope insert delta,
    /// leaving a fresh one behind. `None` outside world scope. Same
    /// epoch-boundary, session-index-order contract as
    /// [`Self::take_cache_delta`].
    fn take_world_delta(&mut self) -> Option<WorldDelta> {
        None
    }

    /// Install the next epoch's merged world snapshot (no-op outside
    /// world scope). `sharers` amortizes the once-per-pool-epoch
    /// snapshot swap + decay-sweep traffic across the sessions reading
    /// it.
    fn install_world_snapshot(&mut self, _snapshot: Arc<WorldSnapshot>, _sharers: usize) {}
}

/// Exact 3DGS rasterization (no cache). Holds the partially rasterized
/// frame between [`RasterBackend::render_chunk`] calls so sub-stage
/// dispatch does real incremental work instead of deferring to the last
/// chunk.
#[derive(Default)]
pub struct PlainRaster {
    partial: Option<PartialRaster>,
}

impl PlainRaster {
    pub fn new() -> Self {
        PlainRaster::default()
    }

    fn raster_config() -> RasterConfig {
        RasterConfig { collect_stats: true, sig_record_k: 0 }
    }

    fn frame_from(out: crate::pipeline::raster::RasterOutput, width: usize, height: usize) -> RasterFrame {
        let stats = out.stats.expect("stats requested");
        RasterFrame {
            image: out.image,
            work: RasterWork {
                width,
                height,
                consumed: stats.iterated,
                significant: stats.significant,
                uncached: None,
                cache_outcomes: None,
                cache: CacheStats::default(),
                cache_shared: false,
                shared_probe_len: 1,
                swap_bytes: 0,
            },
        }
    }
}

impl RasterBackend for PlainRaster {
    fn label(&self) -> &'static str {
        "plain"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        self.partial = None;
        let out = rasterize(projected, bins, width, height, &Self::raster_config());
        Self::frame_from(out, width, height)
    }

    fn render_chunk(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
        chunk: &RasterChunk,
    ) -> Option<RasterFrame> {
        if chunk.index == 0 {
            self.partial = None;
        }
        let acc = self
            .partial
            .get_or_insert_with(|| PartialRaster::new(bins, width, height, &Self::raster_config()));
        acc.render_tiles(projected, bins, chunk.tiles.clone());
        if chunk.is_last() {
            let out = self.partial.take().expect("partial frame present").finish();
            Some(Self::frame_from(out, width, height))
        } else {
            None
        }
    }
}

/// Input for the next frame's frontend dispatch.
pub struct NextFrameInput<'a> {
    /// Frame index within the trajectory.
    pub frame: usize,
    /// Scene the frame renders (the session's LoD scene on the reduced
    /// tier).
    pub scene: &'a GaussianScene,
    pub pose: &'a Pose,
    /// Pipeline intrinsics (half the session resolution for DS-2/half
    /// tier).
    pub intr: &'a Intrinsics,
}

/// A frame mid-flight through the slot machine: frontend done,
/// rasterization pending.
pub struct PendingFrame {
    pub frame: usize,
    /// Scene size captured at feed time (the reduced tier's subsample,
    /// not the shared scene).
    pub scene_gaussians: usize,
    pub frontend: FrontendOutput,
}

/// A frame whose raster stage just finished; the owner assembles the
/// [`FrameWorkload`] and prices it.
pub struct CompletedFrame {
    pub frame: usize,
    pub scene_gaussians: usize,
    pub frontend: FrontendOutput,
    pub raster: RasterFrame,
}

/// Feed-time metadata for a frame entering the queue via
/// [`PipelinedSession::apply_dispatch`] — the borrow-free subset of
/// [`NextFrameInput`] an external scheduler can hold across a dispatch
/// while the frontend output is produced elsewhere.
#[derive(Debug, Clone, Copy)]
pub struct FeedMeta {
    pub frame: usize,
    /// Scene size captured at feed time (the reduced tier's subsample,
    /// not the shared scene).
    pub scene_gaussians: usize,
}

/// One dispatch's raster ready-set: the (queue index, chunk range)
/// pairs [`PipelinedSession::plan_dispatch`] fixed before any stage
/// runs. Ranges execute strictly in order ([`PipelinedSession::
/// run_plan`]); the plan is pure data, so a scheduler can compute it
/// under exclusive access and execute it later on any worker.
#[derive(Debug, Clone, Default)]
pub struct DispatchPlan {
    ranges: Vec<(usize, std::ops::Range<usize>)>,
}

impl DispatchPlan {
    /// No raster work this dispatch (priming feed or idle).
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Total raster chunks the plan dispatches.
    pub fn chunk_count(&self) -> usize {
        self.ranges.iter().map(|(_, r)| r.len()).sum()
    }
}

/// A queued frame: frontend done, rasterization split into
/// [`RasterChunk`]s and partially dispatched.
struct InFlightFrame {
    frame: PendingFrame,
    chunks: Vec<RasterChunk>,
    /// Next chunk index to dispatch (chunks run strictly in order).
    next_chunk: usize,
}

impl InFlightFrame {
    /// Chunks to dispatch per advance so the frame's raster finishes
    /// within `cap` (= depth - 1) dispatches of being fed.
    fn burst(&self, cap: usize) -> usize {
        self.chunks.len().div_ceil(cap.max(1)).max(1)
    }
}

/// The pipelined frame-queue state machine: the unit of stage-level
/// scheduling.
///
/// A session holds up to `depth - 1` frames *in flight* — their
/// frontends (projection + S² speculative sort) have run, their
/// rasterization has not finished — and each [`Self::advance`] dispatch
/// runs the next frame's frontend concurrently with queued frames'
/// raster sub-stages ([`RasterChunk`]s) on a split thread budget.
///
/// * Depth 1 is synchronous: a fed frame completes in the same
///   dispatch — the determinism baseline.
/// * Depth 2 is the classic double buffer: one frame in flight, its
///   whole raster overlapping the next frontend.
/// * Depth 3 holds two frames in flight and interleaves their raster
///   work at chunk granularity: each dispatch finishes the head's
///   remaining chunks and starts a burst of the second frame's, so a
///   frame's rasterization straddles two dispatches. Meaningful only
///   when `raster_substages >= depth - 1`; fewer sub-stages degenerate
///   to depth-2 scheduling.
///
/// Raster chunks only ever run for frames fed on *earlier* dispatches
/// (their frontends are complete), frames rasterize strictly in feed
/// order, and chunks run in order within a frame — so the overlap is
/// bitwise invisible in the output: any depth produces exactly the
/// frames depth 1 does, at any thread count (`tests/sessions.rs`).
pub struct PipelinedSession {
    depth: usize,
    substages: usize,
    queue: VecDeque<InFlightFrame>,
}

impl PipelinedSession {
    /// `depth` is clamped to the supported 1 (synchronous) ..= 3
    /// (chunk-interleaved) range; sub-stage count defaults to
    /// [`DEFAULT_RASTER_SUBSTAGES`].
    pub fn new(depth: usize) -> Self {
        Self::with_substages(depth, DEFAULT_RASTER_SUBSTAGES)
    }

    /// As [`Self::new`] with an explicit raster sub-stage count
    /// (`pool.raster_substages`; clamped to >= 1).
    pub fn with_substages(depth: usize, substages: usize) -> Self {
        PipelinedSession {
            depth: depth.clamp(1, 3),
            substages: substages.max(1),
            queue: VecDeque::new(),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Raster sub-stages each frame is split into.
    pub fn substages(&self) -> usize {
        self.substages
    }

    /// Frames whose frontend ran but whose raster has not finished
    /// (0 ..= depth - 1).
    pub fn in_flight(&self) -> usize {
        self.queue.len()
    }

    /// One dispatch of the state machine: feed `next`'s frontend (when
    /// given) while dispatching queued frames' raster chunks,
    /// overlapping the two on a split thread budget when both are
    /// ready. Returns the frame that completed — `None` on a priming
    /// dispatch that only starts a frontend, or when idle.
    ///
    /// `width`/`height` are the pipeline resolution the queued frames
    /// rasterize at; callers must not change it while frames are in
    /// flight (drain first — see `Coordinator::set_tier`).
    pub fn advance(
        &mut self,
        frontend: &mut FrontendStage,
        raster: &mut dyn RasterBackend,
        next: Option<NextFrameInput<'_>>,
        width: usize,
        height: usize,
    ) -> Option<CompletedFrame> {
        if self.depth <= 1 {
            // Synchronous: a fed frame runs both stages back to back and
            // completes immediately; nothing is ever in flight.
            let n = next?;
            let fo = frontend.run(n.scene, n.pose, n.intr);
            let rf = raster.render(&fo.projected, &fo.bins, width, height);
            return Some(CompletedFrame {
                frame: n.frame,
                scene_gaussians: n.scene.len(),
                frontend: fo,
                raster: rf,
            });
        }
        if next.is_none() && self.queue.is_empty() {
            return None;
        }
        let plan = self.plan_dispatch(next.is_some());
        let (rf, fo) = run_dispatch(frontend, raster, next.as_ref(), self, &plan, width, height);
        let fed = match (next, fo) {
            (Some(n), Some(fo)) => Some((
                FeedMeta {
                    frame: n.frame,
                    scene_gaussians: n.scene.len(),
                },
                fo,
            )),
            _ => None,
        };
        self.apply_dispatch(&plan, rf, fed)
    }

    /// Compute this dispatch's raster ready-set, fixed before any stage
    /// runs. `feeding` says whether a next frame's frontend will run
    /// alongside (it shapes burst sizing exactly as [`Self::advance`]'s
    /// `next.is_some()` does). Pure: does not mutate the queue —
    /// a scheduler computes the plan under exclusive access, runs it
    /// (and the frontend) on any workers, then commits with
    /// [`Self::apply_dispatch`].
    ///
    /// Only the head may finish (at most one completion per dispatch);
    /// a trailing frame's burst is capped one chunk short so its
    /// frame-yielding call waits until it is the head. Depth 1 never
    /// queues frames, so its plan is always empty.
    pub fn plan_dispatch(&self, feeding: bool) -> DispatchPlan {
        let mut plan = DispatchPlan::default();
        if self.depth <= 1 {
            return plan;
        }
        let cap = self.depth - 1;
        if let Some(head) = self.queue.front() {
            let end = if !feeding || self.queue.len() >= cap {
                // Drain, or the queue is full and must yield a slot:
                // finish the head.
                head.chunks.len()
            } else {
                (head.next_chunk + head.burst(cap)).min(head.chunks.len())
            };
            if end > head.next_chunk {
                plan.ranges.push((0, head.next_chunk..end));
            }
            if feeding && self.queue.len() >= cap && self.queue.len() >= 2 {
                let q1 = &self.queue[1];
                let end = (q1.next_chunk + q1.burst(cap)).min(q1.chunks.len() - 1);
                if end > q1.next_chunk {
                    plan.ranges.push((1, q1.next_chunk..end));
                }
            }
        }
        plan
    }

    /// Execute a plan's raster chunks strictly in order on `raster`.
    /// Read-only on the queue (chunk cursors move in
    /// [`Self::apply_dispatch`]), so the raster stage can run while the
    /// owning session's frontend runs elsewhere. Returns the head
    /// frame's finished raster when the plan reached its last chunk.
    pub fn run_plan(
        &self,
        raster: &mut dyn RasterBackend,
        plan: &DispatchPlan,
        width: usize,
        height: usize,
    ) -> Option<RasterFrame> {
        let mut out = None;
        for (qi, chunks) in &plan.ranges {
            let fe = &self.queue[*qi].frame.frontend;
            for ci in chunks.clone() {
                let chunk = &self.queue[*qi].chunks[ci];
                if let Some(rf) =
                    raster.render_chunk(&fe.projected, &fe.bins, width, height, chunk)
                {
                    out = Some(rf);
                }
            }
        }
        out
    }

    /// Commit a dispatch: advance chunk cursors past `plan`, pop the
    /// head when its raster finished (`raster_out`), and enqueue the
    /// frontend output of a frame fed this dispatch. Returns the
    /// completed frame, exactly as [`Self::advance`] does.
    pub fn apply_dispatch(
        &mut self,
        plan: &DispatchPlan,
        raster_out: Option<RasterFrame>,
        fed: Option<(FeedMeta, FrontendOutput)>,
    ) -> Option<CompletedFrame> {
        for (qi, r) in &plan.ranges {
            self.queue[*qi].next_chunk = r.end;
        }
        let completed = raster_out.map(|rf| {
            let head = self.queue.pop_front().expect("raster output implies a head frame");
            debug_assert_eq!(head.next_chunk, head.chunks.len());
            CompletedFrame {
                frame: head.frame.frame,
                scene_gaussians: head.frame.scene_gaussians,
                frontend: head.frame.frontend,
                raster: rf,
            }
        });
        if let Some((meta, fo)) = fed {
            let chunks = RasterChunk::plan(fo.bins.tile_count(), self.substages);
            self.queue.push_back(InFlightFrame {
                frame: PendingFrame {
                    frame: meta.frame,
                    scene_gaussians: meta.scene_gaussians,
                    frontend: fo,
                },
                chunks,
                next_chunk: 0,
            });
        }
        completed
    }
}

/// Run this dispatch's raster chunk plan and (when fed) the next
/// frame's frontend stage, concurrently when the thread budget allows.
/// The stages are independent (disjoint mutable state, no dataflow
/// between them — the plan only covers frames whose frontends already
/// ran), so concurrent and sequential execution produce identical
/// results; the budget only decides wall-clock time. Returns the
/// finished head frame when the plan reached its last chunk, and the
/// frontend output when `next` was fed.
fn run_dispatch(
    frontend: &mut FrontendStage,
    raster: &mut dyn RasterBackend,
    next: Option<&NextFrameInput<'_>>,
    pipe: &PipelinedSession,
    plan: &DispatchPlan,
    width: usize,
    height: usize,
) -> (Option<RasterFrame>, Option<FrontendOutput>) {
    let run_plan = |raster: &mut dyn RasterBackend| pipe.run_plan(raster, plan, width, height);
    let Some(n) = next else {
        return (run_plan(raster), None);
    };
    // detlint: allow(thread-count) -- scheduling site: picks serial vs overlapped stage dispatch and splits the budget; stage outputs are identical either way
    let total = par::num_threads();
    if total < 2 || plan.is_empty() {
        // A single worker gains nothing from two OS threads; an empty
        // plan has nothing to overlap with.
        let rf = run_plan(raster);
        let fo = frontend.run(n.scene, n.pose, n.intr);
        return (rf, Some(fo));
    }
    // Stage-level dispatch: the raster stage (typically the heavier) takes
    // the front share of the split budget, the frontend the rest; each
    // stage thread installs its share thread-locally so the nested
    // `par_*` calls cannot oversubscribe the machine.
    let (raster_share, frontend_share) = par::split_pair(total);
    std::thread::scope(|scope| {
        let rh = scope.spawn(|| {
            let _budget = par::local_budget_guard(raster_share);
            run_plan(raster)
        });
        let fh = scope.spawn(move || {
            let _budget = par::local_budget_guard(frontend_share);
            frontend.run(n.scene, n.pose, n.intr)
        });
        let rf = rh.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
        let fo = fh.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
        (rf, Some(fo))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Pose;
    use crate::constants::TILE;
    use crate::math::Vec3;
    use crate::scene::synth::test_scene;

    #[test]
    fn plain_frontend_sorts_every_frame() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        for _ in 0..3 {
            let out = fe.run(&scene, &pose, &intr);
            assert!(out.sorted);
            assert_eq!(out.sort_entries, out.bins.total_entries());
            assert_eq!(out.refreshed_gaussians, 0);
        }
    }

    #[test]
    fn s2_frontend_amortizes_sorting() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::with_s2(S2Scheduler::new(4, 2, TILE, 0.2, 100.0));
        assert!(fe.uses_s2());
        let mut sorts = 0;
        for _ in 0..8 {
            let out = fe.run(&scene, &pose, &intr);
            if out.sorted {
                sorts += 1;
            }
            assert!(out.refreshed_gaussians > 0);
        }
        assert_eq!(sorts, 2, "8 frames / window 4");
    }

    #[test]
    fn tier_estimate_scales_and_roundtrips() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let fo = fe.run(&scene, &pose, &intr);
        let mut raster = PlainRaster::new();
        let frame = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
        let w = FrameWorkload::from_stages(0, scene.len(), &fo, frame.work);

        // Full -> Full is the normalized identity.
        let id = w.tier_estimate(Tier::Full, Tier::Full, 0.5);
        assert_eq!(id.width, w.width);
        assert_eq!(id.consumed, w.consumed);
        assert_eq!(id.sort_entries, w.sort_entries);

        // Half tier quarters the pixel grid and keeps the scene size
        // (projection culls the whole scene at any resolution).
        let half = w.tier_estimate(Tier::Full, Tier::Half, 0.5);
        assert_eq!((half.width, half.height), (64, 64));
        assert_eq!(half.scene_gaussians, w.scene_gaussians);
        assert_eq!(half.tile_list_lens.len(), half.tiles_x * half.tiles_y);
        assert_eq!(half.consumed.len(), 64 * 64);
        assert!(half.sort_entries < w.sort_entries);

        // Reduced tier scales the Gaussian load by the fraction.
        let red = w.tier_estimate(Tier::Full, Tier::Reduced, 0.5);
        assert_eq!((red.width, red.height), (w.width, w.height));
        assert_eq!(red.scene_gaussians, w.scene_gaussians / 2);
        assert!(red.sort_entries < w.sort_entries);
        assert!(red.mean_iterated() < w.mean_iterated());

        // Measured-at-reduced inverts back to (approximately) full.
        let back = red.tier_estimate(Tier::Reduced, Tier::Full, 0.5);
        assert_eq!(back.scene_gaussians, w.scene_gaussians);
        let drift = (back.mean_iterated() - w.mean_iterated()).abs();
        assert!(drift <= 1.0, "round-trip drift {drift} too large");

        // Half round-trip restores the grid shape.
        let back = half.tier_estimate(Tier::Half, Tier::Full, 0.5);
        assert_eq!((back.width, back.height), (w.width, w.height));
    }

    #[test]
    fn pipelined_session_matches_synchronous_stepping() {
        // Depth-2 slot machine over plain stages must produce exactly
        // the frames of back-to-back stepping, one dispatch behind.
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let poses: Vec<Pose> = (0..4)
            .map(|i| {
                Pose::look_at(Vec3::new(0.1 * i as f32, 0.0, -4.0), Vec3::ZERO)
            })
            .collect();

        // Reference: synchronous.
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let mut raster = PlainRaster::new();
        let mut reference = Vec::new();
        for pose in &poses {
            let fo = fe.run(&scene, pose, &intr);
            let rf = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
            reference.push((rf.image.data.clone(), rf.work.consumed.clone()));
        }

        // Pipelined: feed all poses, then drain.
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let mut raster = PlainRaster::new();
        let mut pipe = PipelinedSession::new(2);
        assert_eq!(pipe.depth(), 2);
        let mut got = Vec::new();
        for (i, pose) in poses.iter().enumerate() {
            let next = NextFrameInput { frame: i, scene: &scene, pose, intr: &intr };
            let done =
                pipe.advance(&mut fe, &mut raster, Some(next), intr.width, intr.height);
            if i == 0 {
                assert!(done.is_none(), "priming dispatch completes nothing");
                assert_eq!(pipe.in_flight(), 1);
            }
            if let Some(d) = done {
                assert_eq!(d.frame, i - 1, "completion is one dispatch behind");
                got.push((d.raster.image.data, d.raster.work.consumed));
            }
        }
        let d = pipe
            .advance(&mut fe, &mut raster, None, intr.width, intr.height)
            .expect("drain completes the in-flight frame");
        assert_eq!(d.frame, poses.len() - 1);
        got.push((d.raster.image.data, d.raster.work.consumed));
        assert_eq!(pipe.in_flight(), 0);
        assert!(pipe
            .advance(&mut fe, &mut raster, None, intr.width, intr.height)
            .is_none());
        assert_eq!(got.len(), reference.len());
        for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(g.0, r.0, "frame {i} image diverged");
            assert_eq!(g.1, r.1, "frame {i} stats diverged");
        }
    }

    #[test]
    fn depth_one_session_is_synchronous() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let mut raster = PlainRaster::new();
        let mut pipe = PipelinedSession::new(1);
        let next = NextFrameInput { frame: 0, scene: &scene, pose: &pose, intr: &intr };
        let done = pipe.advance(&mut fe, &mut raster, Some(next), intr.width, intr.height);
        assert!(done.is_some(), "depth 1 completes the fed frame immediately");
        assert_eq!(pipe.in_flight(), 0);
        // Depths outside 1..=3 clamp.
        assert_eq!(PipelinedSession::new(0).depth(), 1);
        assert_eq!(PipelinedSession::new(7).depth(), 3);
    }

    #[test]
    fn depth_three_session_interleaves_chunks_and_matches_synchronous() {
        // Depth-3 chunk interleaving must produce exactly the frames of
        // back-to-back stepping, two dispatches behind, with raster
        // work genuinely split across dispatches.
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let poses: Vec<Pose> = (0..5)
            .map(|i| {
                Pose::look_at(Vec3::new(0.1 * i as f32, 0.0, -4.0), Vec3::ZERO)
            })
            .collect();

        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let mut raster = PlainRaster::new();
        let mut reference = Vec::new();
        for pose in &poses {
            let fo = fe.run(&scene, pose, &intr);
            let rf = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
            reference.push((rf.image.data.clone(), rf.work.consumed.clone()));
        }

        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let mut raster = PlainRaster::new();
        let mut pipe = PipelinedSession::with_substages(3, 4);
        assert_eq!(pipe.depth(), 3);
        assert_eq!(pipe.substages(), 4);
        let mut got = Vec::new();
        for (i, pose) in poses.iter().enumerate() {
            let next = NextFrameInput { frame: i, scene: &scene, pose, intr: &intr };
            let done =
                pipe.advance(&mut fe, &mut raster, Some(next), intr.width, intr.height);
            if i < 2 {
                assert!(done.is_none(), "dispatch {i} completes nothing while priming");
            }
            if let Some(d) = done {
                assert_eq!(d.frame, i - 2, "completion is two dispatches behind");
                got.push((d.raster.image.data, d.raster.work.consumed));
            }
        }
        assert_eq!(pipe.in_flight(), 2);
        while pipe.in_flight() > 0 {
            let d = pipe
                .advance(&mut fe, &mut raster, None, intr.width, intr.height)
                .expect("drain completes the head frame");
            got.push((d.raster.image.data, d.raster.work.consumed));
        }
        assert!(pipe
            .advance(&mut fe, &mut raster, None, intr.width, intr.height)
            .is_none());
        assert_eq!(got.len(), reference.len());
        for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(g.0, r.0, "frame {i} image diverged");
            assert_eq!(g.1, r.1, "frame {i} stats diverged");
        }
    }

    #[test]
    fn raster_chunk_plan_covers_tiles_exactly_once() {
        for (tiles, sub) in [(64, 4), (64, 3), (7, 4), (1, 4), (0, 4), (5, 1), (12, 12)] {
            let plan = RasterChunk::plan(tiles, sub);
            assert!(!plan.is_empty());
            assert!(plan.len() <= sub.max(1));
            assert!(plan.last().unwrap().is_last());
            let mut next = 0usize;
            for (i, c) in plan.iter().enumerate() {
                assert_eq!(c.index, i);
                assert_eq!(c.count, plan.len());
                assert_eq!(c.tiles.start, next, "tiles {tiles} sub {sub} contiguous");
                next = c.tiles.end;
            }
            assert_eq!(next, tiles, "tiles {tiles} sub {sub} covers all tiles");
        }
    }

    #[test]
    fn aggregate_matches_exact_on_uniform_workloads() {
        // On a perfectly uniform per-pixel record (every admission-test
        // synthetic demand is one), the O(tiles) aggregate transforms
        // must track the exact per-pixel transforms.
        let side = 64usize;
        let tiles = side.div_ceil(16);
        let w = FrameWorkload {
            frame: 0,
            width: side,
            height: side,
            tile_size: 16,
            tiles_x: tiles,
            tiles_y: tiles,
            tile_list_lens: vec![100; tiles * tiles],
            scene_gaussians: 10_000,
            sorted: true,
            sort_entries: 50_000,
            bin_candidates: 60_000,
            refreshed_gaussians: 0,
            consumed: vec![100; side * side],
            significant: vec![10; side * side],
            uncached: None,
            cache_outcomes: None,
            cache: CacheStats::default(),
            cache_shared: false,
            shared_probe_len: 1,
            swap_bytes: 0,
        };
        for (measured, target) in [
            (Tier::Full, Tier::Full),
            (Tier::Full, Tier::Reduced),
            (Tier::Full, Tier::Half),
            (Tier::Reduced, Tier::Full),
            (Tier::Half, Tier::Full),
        ] {
            let exact = w.tier_estimate(measured, target, 0.5);
            let agg = w.aggregate().tier_estimate(measured, target, 0.5);
            assert_eq!((agg.width, agg.height), (exact.width, exact.height));
            assert_eq!((agg.tiles_x, agg.tiles_y), (exact.tiles_x, exact.tiles_y));
            assert_eq!(agg.scene_gaussians, exact.scene_gaussians);
            assert_eq!(agg.sort_entries, exact.sort_entries);
            assert_eq!(agg.bin_candidates, exact.bin_candidates);
            assert_eq!(
                agg.tiles.iter().map(|t| t.list_len).sum::<usize>(),
                exact.tile_list_lens.iter().sum::<usize>(),
                "{measured:?}->{target:?} tile-list totals"
            );
            assert_eq!(
                agg.iter_total(),
                exact.consumed.iter().map(|&v| v as u64).sum::<u64>(),
                "{measured:?}->{target:?} consumed totals"
            );
            let exact_max = exact.consumed.iter().copied().max().unwrap_or(0);
            for t in &agg.tiles {
                assert_eq!(t.iter_max, exact_max, "{measured:?}->{target:?} maxima");
            }
            assert_eq!(
                agg.tiles.iter().map(|t| u64::from(t.pixels())).sum::<u64>(),
                (exact.width * exact.height) as u64
            );
        }
    }

    #[test]
    fn plain_raster_workload_consistent() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let fo = fe.run(&scene, &pose, &intr);
        let mut raster = PlainRaster::new();
        let frame = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
        let w = FrameWorkload::from_stages(0, scene.len(), &fo, frame.work);
        assert_eq!(w.pixels(), 128 * 128);
        assert_eq!(w.consumed.len(), w.pixels());
        assert!(!w.uses_cache());
        assert!(w.mean_iterated() > 0.0);
        assert_eq!(w.tile_list_lens.len(), w.tiles_x * w.tiles_y);
        assert_eq!(frame.image.data.len(), w.pixels());
    }
}
