//! The frame-loop stage graph: explicit stages with a measured
//! [`FrameWorkload`] record flowing between them.
//!
//! A frame is produced by two functional stages and priced by pluggable
//! cost models (see [`crate::sim::cost`]):
//!
//! ```text
//!   pose ──> FrontendStage ──(projected, bins)──> RasterBackend ──> image
//!                 │                                    │
//!                 └──────────── FrameWorkload <────────┘
//!                                    │
//!                     FrontendCostModel + CostModel
//!                        (GPU / LuminCore / GSCore)
//! ```
//!
//! * [`FrontendStage`] — projection + tile binning + depth sorting,
//!   S²-aware: with a scheduler attached it reuses the speculative sort
//!   across the sharing window (paper Sec. 3.1) and reports how much
//!   frontend work actually ran.
//! * [`RasterBackend`] — the rasterization stage behind one trait:
//!   [`PlainRaster`] (exact 3DGS), [`crate::lumina::rc::CachedRaster`]
//!   (radiance-cached, optionally recording single-pass uncached stats),
//!   and [`crate::lumina::ds2::Ds2Raster`] (half-res + upsample).
//! * [`FrameWorkload`] — everything the functional stages measured about
//!   the frame, in the exact units the hardware cost models consume.
//!
//! The coordinator composes these as trait objects; no stage knows which
//! hardware variant is being modeled.

use crate::camera::{Intrinsics, Pose};
use crate::lumina::rc::CacheStats;
use crate::lumina::s2::S2Scheduler;
use crate::pipeline::image::Image;
use crate::pipeline::project::{project, ProjectedScene};
use crate::pipeline::raster::{rasterize, RasterConfig, RasterStats};
use crate::pipeline::sort::{bin_and_sort, TileBins};
use crate::scene::GaussianScene;

/// Everything one frame's functional stages measured, in the units the
/// hardware cost models consume. Produced by [`FrameWorkload::from_stages`]
/// out of a [`FrontendOutput`] and a [`RasterFrame`].
#[derive(Debug, Clone)]
pub struct FrameWorkload {
    /// Frame index within the trajectory.
    pub frame: usize,
    /// Rendered framebuffer width in pixels (the *pipeline* resolution —
    /// half the session resolution for DS-2).
    pub width: usize,
    /// Rendered framebuffer height in pixels.
    pub height: usize,
    /// Tile edge in pixels.
    pub tile_size: usize,
    /// Tile grid width.
    pub tiles_x: usize,
    /// Tile grid height.
    pub tiles_y: usize,
    /// Per-tile sorted-list lengths (row-major tile order).
    pub tile_list_lens: Vec<usize>,
    /// Scene size: projection frustum-culls every Gaussian.
    pub scene_gaussians: usize,
    /// Whether projection + sorting actually ran this frame (false on
    /// S²-shared frames).
    pub sorted: bool,
    /// Tile-list entries produced by sorting (0 when `!sorted`).
    pub sort_entries: usize,
    /// Gaussians whose SH color / screen geometry were re-evaluated for
    /// the current pose (the per-frame S² refresh; 0 without S²).
    pub refreshed_gaussians: usize,
    /// Per-pixel Gaussians consumed as run (early termination and cache
    /// cutoffs included). Row-major, `width * height`.
    pub consumed: Vec<u32>,
    /// Per-pixel significant Gaussians encountered while consuming.
    pub significant: Vec<u32>,
    /// Per-pixel counts the *uncached* pipeline would have produced,
    /// recorded in the same rasterization pass (present when the raster
    /// backend was asked to record them; the GPU cost model prices RC's
    /// warp-bound time from these).
    pub uncached: Option<RasterStats>,
    /// Per-pixel cache interaction: 1 = miss, 2 = hit (None without RC).
    pub cache_outcomes: Option<Vec<u8>>,
    /// Radiance-cache statistics for the frame.
    pub cache: CacheStats,
    /// LuminCache group save/reload traffic (bytes).
    pub swap_bytes: u64,
}

impl FrameWorkload {
    /// Assemble the workload record from the two stage outputs.
    pub fn from_stages(
        frame: usize,
        scene_gaussians: usize,
        frontend: &FrontendOutput,
        raster: RasterWork,
    ) -> Self {
        let bins = &frontend.bins;
        FrameWorkload {
            frame,
            width: raster.width,
            height: raster.height,
            tile_size: bins.tile_size,
            tiles_x: bins.tiles_x,
            tiles_y: bins.tiles_y,
            tile_list_lens: bins.lists.iter().map(|l| l.len()).collect(),
            scene_gaussians,
            sorted: frontend.sorted,
            sort_entries: frontend.sort_entries,
            refreshed_gaussians: frontend.refreshed_gaussians,
            consumed: raster.consumed,
            significant: raster.significant,
            uncached: raster.uncached,
            cache_outcomes: raster.cache_outcomes,
            cache: raster.cache,
            swap_bytes: raster.swap_bytes,
        }
    }

    /// Framebuffer pixel count.
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// True when the frame went through a radiance cache.
    pub fn uses_cache(&self) -> bool {
        self.cache_outcomes.is_some()
    }

    /// Mean Gaussians iterated per pixel (as run).
    pub fn mean_iterated(&self) -> f64 {
        if self.consumed.is_empty() {
            0.0
        } else {
            self.consumed.iter().map(|&v| v as f64).sum::<f64>() / self.consumed.len() as f64
        }
    }
}

/// What the frontend stage produced for one frame.
pub struct FrontendOutput {
    /// Projected Gaussian set to rasterize (S²: geometry/colors refreshed
    /// at the render pose, order frozen from the speculative sort).
    pub projected: ProjectedScene,
    /// Per-tile sorted lists.
    pub bins: TileBins,
    /// Whether projection + sorting ran this frame.
    pub sorted: bool,
    /// Tile-list entries sorted (0 when reused).
    pub sort_entries: usize,
    /// Gaussians refreshed for the current pose (S² only).
    pub refreshed_gaussians: usize,
}

/// Projection + sorting stage, S²-aware.
///
/// The `Plain` form runs the classic per-frame pipeline; the `S2` form
/// delegates to an [`S2Scheduler`] (speculative sort shared across the
/// window, per-frame geometry/color refresh), which owns its own
/// near/far/tile-size state.
pub enum FrontendStage {
    Plain { near: f32, far: f32, tile_size: usize },
    /// Boxed: the scheduler carries the shared sort's projected set,
    /// which would dwarf the `Plain` variant inline.
    S2(Box<S2Scheduler>),
}

impl FrontendStage {
    /// Classic per-frame projection + sorting.
    pub fn plain(near: f32, far: f32, tile_size: usize) -> Self {
        FrontendStage::Plain { near, far, tile_size }
    }

    /// Sorting-sharing frontend driven by an [`S2Scheduler`].
    pub fn with_s2(s2: S2Scheduler) -> Self {
        FrontendStage::S2(Box::new(s2))
    }

    /// True when this frontend shares sorting across frames.
    pub fn uses_s2(&self) -> bool {
        matches!(self, FrontendStage::S2(_))
    }

    /// Run the frontend for one pose.
    pub fn run(
        &mut self,
        scene: &GaussianScene,
        pose: &Pose,
        intr: &Intrinsics,
    ) -> FrontendOutput {
        match self {
            FrontendStage::S2(s2) => {
                let f = s2.frame(scene, pose, intr);
                FrontendOutput {
                    projected: f.projected,
                    bins: f.bins,
                    sorted: f.work.sorted,
                    sort_entries: f.work.sort_entries,
                    refreshed_gaussians: f.work.refreshed_gaussians,
                }
            }
            FrontendStage::Plain { near, far, tile_size } => {
                let projected = project(scene, pose, intr, *near, *far, 0.0);
                let bins = bin_and_sort(&projected, intr, *tile_size, 0.0);
                let sort_entries = bins.total_entries();
                FrontendOutput {
                    projected,
                    bins,
                    sorted: true,
                    sort_entries,
                    refreshed_gaussians: 0,
                }
            }
        }
    }
}

/// What a raster backend measured while rendering (the raster half of a
/// [`FrameWorkload`]; the image travels separately so backends can
/// post-process it).
pub struct RasterWork {
    pub width: usize,
    pub height: usize,
    pub consumed: Vec<u32>,
    pub significant: Vec<u32>,
    pub uncached: Option<RasterStats>,
    pub cache_outcomes: Option<Vec<u8>>,
    pub cache: CacheStats,
    pub swap_bytes: u64,
}

/// One rendered frame from a raster backend.
pub struct RasterFrame {
    pub image: Image,
    pub work: RasterWork,
}

/// The rasterization stage behind one seam: plain, radiance-cached, or
/// DS-2 — the coordinator neither knows nor cares which.
pub trait RasterBackend: Send {
    /// Short name for reports.
    fn label(&self) -> &'static str;

    /// Rasterize one frame, measuring per-pixel work.
    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame;

    /// Post-process the framebuffer into the session's output resolution
    /// (identity for everything but DS-2's 2x upsample).
    fn finalize(&self, image: Image) -> Image {
        image
    }
}

/// Exact 3DGS rasterization (no cache).
pub struct PlainRaster;

impl RasterBackend for PlainRaster {
    fn label(&self) -> &'static str {
        "plain"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let out = rasterize(projected, bins, width, height, &cfg);
        let stats = out.stats.expect("stats requested");
        RasterFrame {
            image: out.image,
            work: RasterWork {
                width,
                height,
                consumed: stats.iterated,
                significant: stats.significant,
                uncached: None,
                cache_outcomes: None,
                cache: CacheStats::default(),
                swap_bytes: 0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Pose;
    use crate::constants::TILE;
    use crate::math::Vec3;
    use crate::scene::synth::test_scene;

    #[test]
    fn plain_frontend_sorts_every_frame() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        for _ in 0..3 {
            let out = fe.run(&scene, &pose, &intr);
            assert!(out.sorted);
            assert_eq!(out.sort_entries, out.bins.total_entries());
            assert_eq!(out.refreshed_gaussians, 0);
        }
    }

    #[test]
    fn s2_frontend_amortizes_sorting() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::with_s2(S2Scheduler::new(4, 2, TILE, 0.2, 100.0));
        assert!(fe.uses_s2());
        let mut sorts = 0;
        for _ in 0..8 {
            let out = fe.run(&scene, &pose, &intr);
            if out.sorted {
                sorts += 1;
            }
            assert!(out.refreshed_gaussians > 0);
        }
        assert_eq!(sorts, 2, "8 frames / window 4");
    }

    #[test]
    fn plain_raster_workload_consistent() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let fo = fe.run(&scene, &pose, &intr);
        let mut raster = PlainRaster;
        let frame = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
        let w = FrameWorkload::from_stages(0, scene.len(), &fo, frame.work);
        assert_eq!(w.pixels(), 128 * 128);
        assert_eq!(w.consumed.len(), w.pixels());
        assert!(!w.uses_cache());
        assert!(w.mean_iterated() > 0.0);
        assert_eq!(w.tile_list_lens.len(), w.tiles_x * w.tiles_y);
        assert_eq!(frame.image.data.len(), w.pixels());
    }
}
