//! The frame-loop stage graph: explicit stages with a measured
//! [`FrameWorkload`] record flowing between them.
//!
//! A frame is produced by two functional stages and priced by pluggable
//! cost models (see [`crate::sim::cost`]):
//!
//! ```text
//!   pose ──> FrontendStage ──(projected, bins)──> RasterBackend ──> image
//!                 │                                    │
//!                 └──────────── FrameWorkload <────────┘
//!                                    │
//!                     FrontendCostModel + CostModel
//!                        (GPU / LuminCore / GSCore)
//! ```
//!
//! * [`FrontendStage`] — projection + tile binning + depth sorting,
//!   S²-aware: with a scheduler attached it reuses the speculative sort
//!   across the sharing window (paper Sec. 3.1) and reports how much
//!   frontend work actually ran.
//! * [`RasterBackend`] — the rasterization stage behind one trait:
//!   [`PlainRaster`] (exact 3DGS), [`crate::lumina::rc::CachedRaster`]
//!   (radiance-cached, optionally recording single-pass uncached stats),
//!   and [`crate::lumina::ds2::Ds2Raster`] (half-res + upsample).
//! * [`FrameWorkload`] — everything the functional stages measured about
//!   the frame, in the exact units the hardware cost models consume.
//!
//! The coordinator composes these as trait objects; no stage knows which
//! hardware variant is being modeled.

use crate::camera::{Intrinsics, Pose};
use crate::config::Tier;
use crate::lumina::rc::CacheStats;
use crate::lumina::s2::S2Scheduler;
use crate::pipeline::image::Image;
use crate::pipeline::project::{project, ProjectedScene};
use crate::pipeline::raster::{rasterize, RasterConfig, RasterStats};
use crate::pipeline::sort::{bin_and_sort, TileBins};
use crate::scene::GaussianScene;

/// Everything one frame's functional stages measured, in the units the
/// hardware cost models consume. Produced by [`FrameWorkload::from_stages`]
/// out of a [`FrontendOutput`] and a [`RasterFrame`].
#[derive(Debug, Clone)]
pub struct FrameWorkload {
    /// Frame index within the trajectory.
    pub frame: usize,
    /// Rendered framebuffer width in pixels (the *pipeline* resolution —
    /// half the session resolution for DS-2).
    pub width: usize,
    /// Rendered framebuffer height in pixels.
    pub height: usize,
    /// Tile edge in pixels.
    pub tile_size: usize,
    /// Tile grid width.
    pub tiles_x: usize,
    /// Tile grid height.
    pub tiles_y: usize,
    /// Per-tile sorted-list lengths (row-major tile order).
    pub tile_list_lens: Vec<usize>,
    /// Scene size: projection frustum-culls every Gaussian.
    pub scene_gaussians: usize,
    /// Whether projection + sorting actually ran this frame (false on
    /// S²-shared frames).
    pub sorted: bool,
    /// Tile-list entries produced by sorting (0 when `!sorted`).
    pub sort_entries: usize,
    /// Gaussians whose SH color / screen geometry were re-evaluated for
    /// the current pose (the per-frame S² refresh; 0 without S²).
    pub refreshed_gaussians: usize,
    /// Per-pixel Gaussians consumed as run (early termination and cache
    /// cutoffs included). Row-major, `width * height`.
    pub consumed: Vec<u32>,
    /// Per-pixel significant Gaussians encountered while consuming.
    pub significant: Vec<u32>,
    /// Per-pixel counts the *uncached* pipeline would have produced,
    /// recorded in the same rasterization pass (present when the raster
    /// backend was asked to record them; the GPU cost model prices RC's
    /// warp-bound time from these).
    pub uncached: Option<RasterStats>,
    /// Per-pixel cache interaction: 1 = miss, 2 = hit (None without RC).
    pub cache_outcomes: Option<Vec<u8>>,
    /// Radiance-cache statistics for the frame.
    pub cache: CacheStats,
    /// LuminCache group save/reload traffic (bytes).
    pub swap_bytes: u64,
}

impl FrameWorkload {
    /// Assemble the workload record from the two stage outputs.
    pub fn from_stages(
        frame: usize,
        scene_gaussians: usize,
        frontend: &FrontendOutput,
        raster: RasterWork,
    ) -> Self {
        let bins = &frontend.bins;
        FrameWorkload {
            frame,
            width: raster.width,
            height: raster.height,
            tile_size: bins.tile_size,
            tiles_x: bins.tiles_x,
            tiles_y: bins.tiles_y,
            tile_list_lens: bins.lists.iter().map(|l| l.len()).collect(),
            scene_gaussians,
            sorted: frontend.sorted,
            sort_entries: frontend.sort_entries,
            refreshed_gaussians: frontend.refreshed_gaussians,
            consumed: raster.consumed,
            significant: raster.significant,
            uncached: raster.uncached,
            cache_outcomes: raster.cache_outcomes,
            cache: raster.cache,
            swap_bytes: raster.swap_bytes,
        }
    }

    /// Framebuffer pixel count.
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// True when the frame went through a radiance cache.
    pub fn uses_cache(&self) -> bool {
        self.cache_outcomes.is_some()
    }

    /// Mean Gaussians iterated per pixel (as run).
    pub fn mean_iterated(&self) -> f64 {
        if self.consumed.is_empty() {
            0.0
        } else {
            self.consumed.iter().map(|&v| v as f64).sum::<f64>() / self.consumed.len() as f64
        }
    }

    /// Estimate what this workload would look like served under `target`
    /// tier, given it was *measured* under `measured` tier — the record
    /// the admission controller feeds through the cost-model seams to
    /// price a tier mix without re-rendering.
    ///
    /// The estimate is deterministic (integer/f64 arithmetic only, no
    /// sampling) and deliberately conservative: the demoted tiers are
    /// priced slightly above their observed cost so the controller errs
    /// toward refusing work rather than missing its FPS target. Cache
    /// outcome maps are stripped (and recorded uncached counts take the
    /// place of the hit-shortened as-run ones) so all tiers price the
    /// same cold-cache structural quantity.
    pub fn tier_estimate(
        &self,
        measured: Tier,
        target: Tier,
        reduced_fraction: f64,
    ) -> FrameWorkload {
        self.estimate_full(measured, reduced_fraction)
            .estimate_from_full(target, reduced_fraction)
    }

    /// Strip per-run extras so tier estimates price comparably.
    fn normalized(&self) -> FrameWorkload {
        let mut w = self.clone();
        // Price the *uncached* per-pixel structure when the raster pass
        // recorded it: cache hits shorten the as-run counts, but the
        // planner's conservative contract wants what the frame costs
        // without a warm cache — tier swaps reset the cache, so a plan
        // that banks on yesterday's hit rate would blow the budget the
        // moment it re-tiers. The remaining cache interplay (lookup
        // overhead, outcome maps) is stripped so every tier prices the
        // same structural quantity; swap traffic is kept (real transfer
        // work), conservatively unscaled by the tier transforms.
        if let Some(u) = w.uncached.take() {
            w.consumed = u.iterated;
            w.significant = u.significant;
        }
        w.cache_outcomes = None;
        w.cache = CacheStats::default();
        w
    }

    /// Undo the measured tier's scaling: an estimate of the same frame
    /// served at full tier.
    fn estimate_full(&self, measured: Tier, reduced_fraction: f64) -> FrameWorkload {
        let mut w = self.normalized();
        match measured {
            Tier::Full => {}
            Tier::Reduced => w.scale_gaussian_load(1.0 / reduced_fraction),
            Tier::Half => {
                let (tw, th) = (w.width * 2, w.height * 2);
                w.resample(tw, th, 1.0 / HALF_LIST_GROWTH, 1.0 / HALF_ENTRY_KEEP);
            }
        }
        w
    }

    /// Apply a target tier's scaling to a full-tier workload estimate.
    fn estimate_from_full(mut self, target: Tier, reduced_fraction: f64) -> FrameWorkload {
        match target {
            Tier::Full => {}
            Tier::Reduced => self.scale_gaussian_load(reduced_fraction),
            Tier::Half => {
                let (tw, th) = ((self.width / 2).max(1), (self.height / 2).max(1));
                self.resample(tw, th, HALF_LIST_GROWTH, HALF_ENTRY_KEEP);
            }
        }
        self
    }

    /// Scale everything that tracks the Gaussian budget (the reduced
    /// tier serves a `f`-fraction prefix of the scene; projection,
    /// sorting, and per-pixel iteration all shrink with it).
    fn scale_gaussian_load(&mut self, f: f64) {
        self.scene_gaussians = scale_round(self.scene_gaussians, f);
        self.sort_entries = scale_round(self.sort_entries, f);
        self.refreshed_gaussians = scale_round(self.refreshed_gaussians, f);
        for l in self.tile_list_lens.iter_mut() {
            *l = scale_round(*l, f);
        }
        scale_counts_in_place(&mut self.consumed, f);
        scale_counts_in_place(&mut self.significant, f);
    }

    /// Re-grid the per-pixel record to `new_w x new_h` (nearest
    /// neighbor), scaling each count by `per_pixel_scale` and the
    /// sort/tile-list totals by `entry_scale`. Projection cost
    /// (`scene_gaussians`, `refreshed_gaussians`) is untouched: the
    /// frontend frustum-culls the whole scene at any resolution.
    fn resample(
        &mut self,
        new_w: usize,
        new_h: usize,
        per_pixel_scale: f64,
        entry_scale: f64,
    ) {
        let (old_w, old_h) = (self.width, self.height);
        let consumed = resample_grid(&self.consumed, old_w, old_h, new_w, new_h, per_pixel_scale);
        let significant =
            resample_grid(&self.significant, old_w, old_h, new_w, new_h, per_pixel_scale);
        self.consumed = consumed;
        self.significant = significant;
        self.width = new_w;
        self.height = new_h;
        self.tiles_x = new_w.div_ceil(self.tile_size.max(1));
        self.tiles_y = new_h.div_ceil(self.tile_size.max(1));
        self.sort_entries = scale_round(self.sort_entries, entry_scale);
        // Tile lists: preserve the scaled total, spread uniformly — the
        // admission estimate does not track spatial distribution.
        let total: usize = self.tile_list_lens.iter().sum();
        let tiles = (self.tiles_x * self.tiles_y).max(1);
        let per_tile = scale_round(total, entry_scale).div_ceil(tiles);
        self.tile_list_lens = vec![per_tile; self.tiles_x * self.tiles_y];
    }
}

/// Per-pixel list growth when the pipeline drops to half resolution:
/// each half-res tile covers 2x the world area, so every pixel iterates
/// a longer list and the savings are sublinear in pixel count (see
/// `lumina::ds2` — DS-2 is a quality baseline, not a 4x-speed one).
/// Deliberately conservative: overestimating the demoted tier's cost
/// makes the admission controller refuse work rather than miss target.
const HALF_LIST_GROWTH: f64 = 1.5;

/// Sort-entry (and tile-list total) retention at half resolution: the
/// tile count quarters but each surviving tile binds more Gaussians.
const HALF_ENTRY_KEEP: f64 = 0.75;

fn scale_round(x: usize, f: f64) -> usize {
    (x as f64 * f).round() as usize
}

fn scale_counts_in_place(v: &mut [u32], f: f64) {
    for x in v.iter_mut() {
        *x = (*x as f64 * f).round() as u32;
    }
}

/// Nearest-neighbor re-grid of a row-major per-pixel count field, with
/// a per-sample scale factor.
fn resample_grid(
    v: &[u32],
    old_w: usize,
    old_h: usize,
    new_w: usize,
    new_h: usize,
    scale: f64,
) -> Vec<u32> {
    if old_w == 0 || old_h == 0 || v.is_empty() {
        return vec![0; new_w * new_h];
    }
    let mut out = Vec::with_capacity(new_w * new_h);
    for r in 0..new_h {
        let sr = (r * old_h / new_h).min(old_h - 1);
        for c in 0..new_w {
            let sc = (c * old_w / new_w).min(old_w - 1);
            out.push((v[sr * old_w + sc] as f64 * scale).round() as u32);
        }
    }
    out
}

/// What the frontend stage produced for one frame.
pub struct FrontendOutput {
    /// Projected Gaussian set to rasterize (S²: geometry/colors refreshed
    /// at the render pose, order frozen from the speculative sort).
    pub projected: ProjectedScene,
    /// Per-tile sorted lists.
    pub bins: TileBins,
    /// Whether projection + sorting ran this frame.
    pub sorted: bool,
    /// Tile-list entries sorted (0 when reused).
    pub sort_entries: usize,
    /// Gaussians refreshed for the current pose (S² only).
    pub refreshed_gaussians: usize,
}

/// Projection + sorting stage, S²-aware.
///
/// The `Plain` form runs the classic per-frame pipeline; the `S2` form
/// delegates to an [`S2Scheduler`] (speculative sort shared across the
/// window, per-frame geometry/color refresh), which owns its own
/// near/far/tile-size state.
pub enum FrontendStage {
    Plain { near: f32, far: f32, tile_size: usize },
    /// Boxed: the scheduler carries the shared sort's projected set,
    /// which would dwarf the `Plain` variant inline.
    S2(Box<S2Scheduler>),
}

impl FrontendStage {
    /// Classic per-frame projection + sorting.
    pub fn plain(near: f32, far: f32, tile_size: usize) -> Self {
        FrontendStage::Plain { near, far, tile_size }
    }

    /// Sorting-sharing frontend driven by an [`S2Scheduler`].
    pub fn with_s2(s2: S2Scheduler) -> Self {
        FrontendStage::S2(Box::new(s2))
    }

    /// True when this frontend shares sorting across frames.
    pub fn uses_s2(&self) -> bool {
        matches!(self, FrontendStage::S2(_))
    }

    /// Drop cross-frame state (the S² shared sort). Required when the
    /// raster backend or the pipeline resolution is swapped mid-run —
    /// tier promotion/demotion — since a stale speculative sort would
    /// reference the old tile grid.
    pub fn reset(&mut self) {
        if let FrontendStage::S2(s2) = self {
            s2.reset();
        }
    }

    /// Run the frontend for one pose.
    pub fn run(
        &mut self,
        scene: &GaussianScene,
        pose: &Pose,
        intr: &Intrinsics,
    ) -> FrontendOutput {
        match self {
            FrontendStage::S2(s2) => {
                let f = s2.frame(scene, pose, intr);
                FrontendOutput {
                    projected: f.projected,
                    bins: f.bins,
                    sorted: f.work.sorted,
                    sort_entries: f.work.sort_entries,
                    refreshed_gaussians: f.work.refreshed_gaussians,
                }
            }
            FrontendStage::Plain { near, far, tile_size } => {
                let projected = project(scene, pose, intr, *near, *far, 0.0);
                let bins = bin_and_sort(&projected, intr, *tile_size, 0.0);
                let sort_entries = bins.total_entries();
                FrontendOutput {
                    projected,
                    bins,
                    sorted: true,
                    sort_entries,
                    refreshed_gaussians: 0,
                }
            }
        }
    }
}

/// What a raster backend measured while rendering (the raster half of a
/// [`FrameWorkload`]; the image travels separately so backends can
/// post-process it).
pub struct RasterWork {
    pub width: usize,
    pub height: usize,
    pub consumed: Vec<u32>,
    pub significant: Vec<u32>,
    pub uncached: Option<RasterStats>,
    pub cache_outcomes: Option<Vec<u8>>,
    pub cache: CacheStats,
    pub swap_bytes: u64,
}

/// One rendered frame from a raster backend.
pub struct RasterFrame {
    pub image: Image,
    pub work: RasterWork,
}

/// The rasterization stage behind one seam: plain, radiance-cached, or
/// DS-2 — the coordinator neither knows nor cares which.
pub trait RasterBackend: Send {
    /// Short name for reports.
    fn label(&self) -> &'static str;

    /// Rasterize one frame, measuring per-pixel work.
    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame;

    /// Post-process the framebuffer into the session's output resolution
    /// (identity for everything but DS-2's 2x upsample).
    fn finalize(&self, image: Image) -> Image {
        image
    }
}

/// Exact 3DGS rasterization (no cache).
pub struct PlainRaster;

impl RasterBackend for PlainRaster {
    fn label(&self) -> &'static str {
        "plain"
    }

    fn render(
        &mut self,
        projected: &ProjectedScene,
        bins: &TileBins,
        width: usize,
        height: usize,
    ) -> RasterFrame {
        let cfg = RasterConfig { collect_stats: true, sig_record_k: 0 };
        let out = rasterize(projected, bins, width, height, &cfg);
        let stats = out.stats.expect("stats requested");
        RasterFrame {
            image: out.image,
            work: RasterWork {
                width,
                height,
                consumed: stats.iterated,
                significant: stats.significant,
                uncached: None,
                cache_outcomes: None,
                cache: CacheStats::default(),
                swap_bytes: 0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Pose;
    use crate::constants::TILE;
    use crate::math::Vec3;
    use crate::scene::synth::test_scene;

    #[test]
    fn plain_frontend_sorts_every_frame() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        for _ in 0..3 {
            let out = fe.run(&scene, &pose, &intr);
            assert!(out.sorted);
            assert_eq!(out.sort_entries, out.bins.total_entries());
            assert_eq!(out.refreshed_gaussians, 0);
        }
    }

    #[test]
    fn s2_frontend_amortizes_sorting() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::with_s2(S2Scheduler::new(4, 2, TILE, 0.2, 100.0));
        assert!(fe.uses_s2());
        let mut sorts = 0;
        for _ in 0..8 {
            let out = fe.run(&scene, &pose, &intr);
            if out.sorted {
                sorts += 1;
            }
            assert!(out.refreshed_gaussians > 0);
        }
        assert_eq!(sorts, 2, "8 frames / window 4");
    }

    #[test]
    fn tier_estimate_scales_and_roundtrips() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let fo = fe.run(&scene, &pose, &intr);
        let mut raster = PlainRaster;
        let frame = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
        let w = FrameWorkload::from_stages(0, scene.len(), &fo, frame.work);

        // Full -> Full is the normalized identity.
        let id = w.tier_estimate(Tier::Full, Tier::Full, 0.5);
        assert_eq!(id.width, w.width);
        assert_eq!(id.consumed, w.consumed);
        assert_eq!(id.sort_entries, w.sort_entries);

        // Half tier quarters the pixel grid and keeps the scene size
        // (projection culls the whole scene at any resolution).
        let half = w.tier_estimate(Tier::Full, Tier::Half, 0.5);
        assert_eq!((half.width, half.height), (64, 64));
        assert_eq!(half.scene_gaussians, w.scene_gaussians);
        assert_eq!(half.tile_list_lens.len(), half.tiles_x * half.tiles_y);
        assert_eq!(half.consumed.len(), 64 * 64);
        assert!(half.sort_entries < w.sort_entries);

        // Reduced tier scales the Gaussian load by the fraction.
        let red = w.tier_estimate(Tier::Full, Tier::Reduced, 0.5);
        assert_eq!((red.width, red.height), (w.width, w.height));
        assert_eq!(red.scene_gaussians, w.scene_gaussians / 2);
        assert!(red.sort_entries < w.sort_entries);
        assert!(red.mean_iterated() < w.mean_iterated());

        // Measured-at-reduced inverts back to (approximately) full.
        let back = red.tier_estimate(Tier::Reduced, Tier::Full, 0.5);
        assert_eq!(back.scene_gaussians, w.scene_gaussians);
        let drift = (back.mean_iterated() - w.mean_iterated()).abs();
        assert!(drift <= 1.0, "round-trip drift {drift} too large");

        // Half round-trip restores the grid shape.
        let back = half.tier_estimate(Tier::Half, Tier::Full, 0.5);
        assert_eq!((back.width, back.height), (w.width, w.height));
    }

    #[test]
    fn plain_raster_workload_consistent() {
        let scene = test_scene(9, 3000);
        let intr = Intrinsics::with_fov(128, 128, 0.9);
        let pose = Pose::look_at(Vec3::new(0.0, 0.0, -4.0), Vec3::ZERO);
        let mut fe = FrontendStage::plain(0.2, 100.0, TILE);
        let fo = fe.run(&scene, &pose, &intr);
        let mut raster = PlainRaster;
        let frame = raster.render(&fo.projected, &fo.bins, intr.width, intr.height);
        let w = FrameWorkload::from_stages(0, scene.len(), &fo, frame.work);
        assert_eq!(w.pixels(), 128 * 128);
        assert_eq!(w.consumed.len(), w.pixels());
        assert!(!w.uses_cache());
        assert!(w.mean_iterated() > 0.0);
        assert_eq!(w.tile_list_lens.len(), w.tiles_x * w.tiles_y);
        assert_eq!(frame.image.data.len(), w.pixels());
    }
}
