//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the layer that keeps Python off the request path: `make
//! artifacts` runs once at build time; the Rust binary then loads
//! `artifacts/*.hlo.txt` and executes the compiled kernels with concrete
//! buffers (see `pjrt.rs` for the mechanics).
//!
//! The PJRT path needs the vendored `xla` crate (xla_extension 0.5.1) —
//! an external native dependency — so it is gated behind the
//! off-by-default `xla-runtime` Cargo feature. The default build gets a
//! [`stub`] with the identical API whose constructors fail with
//! guidance, keeping the launcher, examples, and parity tests compiling
//! (they skip at runtime). Enable with
//! `cargo build --features xla-runtime` after adding the vendored `xla`
//! dependency to `Cargo.toml` (see the comment there).

use anyhow::{bail, Result};

use crate::constants::TILE;

#[cfg(feature = "xla-runtime")]
mod pjrt;
#[cfg(feature = "xla-runtime")]
pub use pjrt::ArtifactRuntime;

/// API-level stand-in for the non-vendored `xla` crate: keeps the whole
/// PJRT path type-checking under `--features xla-runtime` (the CI
/// feature-matrix step) while the real dependency stays commented out.
/// Compiled out when `xla-vendored` routes `pjrt.rs` to the real crate.
#[cfg(all(feature = "xla-runtime", not(feature = "xla-vendored")))]
pub(crate) mod xla_api_stub;

#[cfg(not(feature = "xla-runtime"))]
mod stub;
#[cfg(not(feature = "xla-runtime"))]
pub use stub::ArtifactRuntime;

/// Compositing constants recorded in `artifacts/manifest.toml`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ManifestConstants {
    pub tile: usize,
    pub g_chunk: usize,
    pub tile_batch: usize,
    pub sh_chunk: usize,
    pub alpha_min: f32,
    pub alpha_max: f32,
    pub t_eps: f32,
}

/// Carry state for chunked tile rasterization via the AOT kernel.
#[derive(Debug, Clone)]
pub struct TileCarry {
    /// (TILE*TILE*3) accumulated RGB.
    pub color: Vec<f32>,
    /// (TILE*TILE) transmittance.
    pub transmittance: Vec<f32>,
    /// (TILE*TILE) termination flags (0/1).
    pub done: Vec<f32>,
}

impl TileCarry {
    pub fn fresh() -> Self {
        TileCarry {
            color: vec![0.0; TILE * TILE * 3],
            transmittance: vec![1.0; TILE * TILE],
            done: vec![0.0; TILE * TILE],
        }
    }
}

/// Error for every stub entry point.
#[allow(dead_code)]
pub(crate) fn unavailable<T>() -> Result<T> {
    bail!(
        "the PJRT artifact runtime is unavailable: lumina was built without the \
         `xla-runtime` feature. Rebuild with `cargo build --features xla-runtime` \
         (requires the vendored `xla` crate; see Cargo.toml)."
    )
}
