//! The real PJRT-backed artifact runtime (`xla-runtime` feature): loads
//! `artifacts/*.hlo.txt` (`HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `client.compile`) and executes the
//! compiled kernels with concrete buffers. HLO *text* is the interchange
//! format because the crate's xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos (64-bit instruction ids) — see
//! /opt/xla-example/README.md.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

// Without the vendored `xla` crate (it is not in the offline crate
// set), the whole module type-checks against the API stub so the CI
// feature-matrix `cargo check --features xla-runtime` keeps this path
// from bit-rotting; enabling `xla-vendored` (plus the real dependency
// in Cargo.toml) routes these paths to the genuine crate.
#[cfg(not(feature = "xla-vendored"))]
use super::xla_api_stub as xla;

use super::{ManifestConstants, TileCarry};
use crate::constants::{G_CHUNK, SH_CHUNK, SH_COEFFS, TILE};
use crate::util::minitoml;

/// A compiled artifact registry bound to a PJRT client.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Constants recorded by the AOT manifest (sanity-checked against
    /// `crate::constants`).
    pub manifest_constants: ManifestConstants,
    dir: PathBuf,
}

impl ArtifactRuntime {
    /// Load every artifact listed in `<dir>/manifest.toml` and compile it
    /// on a fresh CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let root = minitoml::parse(&text).map_err(|e| anyhow::anyhow!(e.to_string()))?;

        let cget = |k: &str| -> Result<f64> {
            root.get_path(&format!("constants.{k}"))
                .and_then(|v| v.as_float())
                .with_context(|| format!("manifest missing constants.{k}"))
        };
        let manifest_constants = ManifestConstants {
            tile: cget("tile")? as usize,
            g_chunk: cget("g_chunk")? as usize,
            tile_batch: cget("tile_batch")? as usize,
            sh_chunk: cget("sh_chunk")? as usize,
            alpha_min: cget("alpha_min")? as f32,
            alpha_max: cget("alpha_max")? as f32,
            t_eps: cget("t_eps")? as f32,
        };
        // The Rust pipeline and the AOT kernels must share semantics.
        if manifest_constants.tile != TILE
            || manifest_constants.g_chunk != G_CHUNK
            || manifest_constants.sh_chunk != SH_CHUNK
        {
            bail!(
                "artifact manifest constants {manifest_constants:?} disagree with crate constants; \
                 rebuild artifacts"
            );
        }

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        let artifacts = root
            .get_path("artifacts")
            .and_then(|v| v.as_table())
            .context("manifest missing [artifacts]")?;
        for (name, entry) in artifacts {
            let file = entry
                .get_path("file")
                .and_then(|v| v.as_str())
                .with_context(|| format!("artifact {name} missing file"))?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(ArtifactRuntime { client, executables, manifest_constants, dir })
    }

    /// Artifact directory this runtime loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Names of loaded artifacts.
    pub fn artifact_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        names.sort();
        names
    }

    /// PJRT platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))
    }

    /// Execute the `raster_tile` artifact: one chunk of up to G_CHUNK
    /// depth-sorted Gaussians composited onto one tile, with carry.
    ///
    /// Inputs are padded to G_CHUNK with zero-opacity rows (skipped by
    /// the kernel's significance test).
    #[allow(clippy::too_many_arguments)]
    pub fn raster_tile_chunk(
        &self,
        means: &[[f32; 2]],
        conics: &[[f32; 3]],
        opacs: &[f32],
        colors: &[[f32; 3]],
        origin: [f32; 2],
        carry: &TileCarry,
    ) -> Result<TileCarry> {
        let g = means.len();
        if g > G_CHUNK {
            bail!("chunk of {g} exceeds G_CHUNK={G_CHUNK}");
        }
        let mut m = vec![0f32; G_CHUNK * 2];
        let mut cn = vec![0f32; G_CHUNK * 3];
        let mut op = vec![0f32; G_CHUNK];
        let mut cl = vec![0f32; G_CHUNK * 3];
        for i in 0..g {
            m[i * 2..i * 2 + 2].copy_from_slice(&means[i]);
            cn[i * 3..i * 3 + 3].copy_from_slice(&conics[i]);
            op[i] = opacs[i];
            cl[i * 3..i * 3 + 3].copy_from_slice(&colors[i]);
        }
        let t = TILE as i64;
        let args = [
            xla::Literal::vec1(&m).reshape(&[G_CHUNK as i64, 2])?,
            xla::Literal::vec1(&cn).reshape(&[G_CHUNK as i64, 3])?,
            xla::Literal::vec1(&op),
            xla::Literal::vec1(&cl).reshape(&[G_CHUNK as i64, 3])?,
            xla::Literal::vec1(&origin),
            xla::Literal::vec1(&carry.color).reshape(&[t, t, 3])?,
            xla::Literal::vec1(&carry.transmittance).reshape(&[t, t])?,
            xla::Literal::vec1(&carry.done).reshape(&[t, t])?,
        ];
        let result = self.exe("raster_tile")?.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            bail!("raster_tile returned {} outputs, expected 3", parts.len());
        }
        let mut it = parts.into_iter();
        let mut take = |name: &str| {
            it.next().with_context(|| format!("raster_tile tuple missing {name} output"))
        };
        Ok(TileCarry {
            color: take("color")?.to_vec::<f32>()?,
            transmittance: take("transmittance")?.to_vec::<f32>()?,
            done: take("done")?.to_vec::<f32>()?,
        })
    }

    /// Execute the `sh_eval` artifact for up to SH_CHUNK Gaussians.
    /// Returns per-Gaussian RGB.
    pub fn sh_eval_chunk(
        &self,
        dirs: &[[f32; 3]],
        coeffs: &[[[f32; 3]; SH_COEFFS]],
    ) -> Result<Vec<[f32; 3]>> {
        let n = dirs.len();
        if n > SH_CHUNK {
            bail!("chunk of {n} exceeds SH_CHUNK={SH_CHUNK}");
        }
        let mut d = vec![0f32; SH_CHUNK * 3];
        let mut c = vec![0f32; SH_CHUNK * SH_COEFFS * 3];
        for i in 0..n {
            d[i * 3..i * 3 + 3].copy_from_slice(&dirs[i]);
            for k in 0..SH_COEFFS {
                let off = (i * SH_COEFFS + k) * 3;
                c[off..off + 3].copy_from_slice(&coeffs[i][k]);
            }
        }
        let args = [
            xla::Literal::vec1(&d).reshape(&[SH_CHUNK as i64, 3])?,
            xla::Literal::vec1(&c).reshape(&[SH_CHUNK as i64, SH_COEFFS as i64, 3])?,
        ];
        let result =
            self.exe("sh_eval")?.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?.to_vec::<f32>()?;
        Ok((0..n).map(|i| [out[i * 3], out[i * 3 + 1], out[i * 3 + 2]]).collect())
    }

    /// Execute the `alpha_front` artifact: frontend alphas of a Gaussian
    /// chunk over one tile. Returns (G_CHUNK, TILE, TILE) row-major.
    pub fn alpha_front_chunk(
        &self,
        means: &[[f32; 2]],
        conics: &[[f32; 3]],
        opacs: &[f32],
        origin: [f32; 2],
    ) -> Result<Vec<f32>> {
        let g = means.len();
        if g > G_CHUNK {
            bail!("chunk of {g} exceeds G_CHUNK={G_CHUNK}");
        }
        let mut m = vec![0f32; G_CHUNK * 2];
        let mut cn = vec![0f32; G_CHUNK * 3];
        let mut op = vec![0f32; G_CHUNK];
        for i in 0..g {
            m[i * 2..i * 2 + 2].copy_from_slice(&means[i]);
            cn[i * 3..i * 3 + 3].copy_from_slice(&conics[i]);
            op[i] = opacs[i];
        }
        let args = [
            xla::Literal::vec1(&m).reshape(&[G_CHUNK as i64, 2])?,
            xla::Literal::vec1(&cn).reshape(&[G_CHUNK as i64, 3])?,
            xla::Literal::vec1(&op),
            xla::Literal::vec1(&origin),
        ];
        let result =
            self.exe("alpha_front")?.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Rasterize one full tile (arbitrary list length) by chunking
    /// through the AOT kernel with carried state.
    pub fn raster_tile_full(
        &self,
        means: &[[f32; 2]],
        conics: &[[f32; 3]],
        opacs: &[f32],
        colors: &[[f32; 3]],
        origin: [f32; 2],
    ) -> Result<TileCarry> {
        let mut carry = TileCarry::fresh();
        let n = means.len();
        let mut s = 0usize;
        while s < n {
            let e = (s + G_CHUNK).min(n);
            carry = self.raster_tile_chunk(
                &means[s..e],
                &conics[s..e],
                &opacs[s..e],
                &colors[s..e],
                origin,
                &carry,
            )?;
            s = e;
        }
        Ok(carry)
    }
}
